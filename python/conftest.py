import os
import sys

# Make `compile.*` importable regardless of pytest rootdir.
sys.path.insert(0, os.path.dirname(__file__))

import jax  # noqa: E402

jax.config.update("jax_platform_name", "cpu")
