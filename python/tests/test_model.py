"""L2 model: shapes, numerics, and prefill/decode cache consistency."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile import model as M

CFG = M.MICRO


@pytest.fixture(scope="module")
def params():
    return M.init_params(jax.random.PRNGKey(0), CFG)


def test_param_spec_matches_params(params):
    spec = M.param_spec(CFG)
    flat = M.params_to_list(params, CFG)
    assert len(spec) == len(flat)
    for (name, shape, dtype), arr in zip(spec, flat):
        assert tuple(arr.shape) == tuple(shape), name
        want = {"f32": jnp.float32, "u32": jnp.uint32}[dtype]
        assert arr.dtype == want, name


def test_params_roundtrip(params):
    flat = M.params_to_list(params, CFG)
    back = M.params_from_list(flat, CFG)
    flat2 = M.params_to_list(back, CFG)
    for a, b in zip(flat, flat2):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_prefill_shapes_finite(params):
    toks = jnp.asarray(np.random.default_rng(0).integers(0, CFG.vocab, (2, 8)), jnp.int32)
    logits, kc, vc = M.prefill(params, toks, CFG)
    assert logits.shape == (2, 8, CFG.vocab)
    assert kc.shape == (CFG.n_layers, 2, CFG.max_seq, CFG.n_kv_heads, CFG.head_dim)
    assert bool(jnp.isfinite(logits).all())
    # cache beyond T must remain zero
    assert float(jnp.abs(kc[:, :, 8:]).max()) == 0.0


def test_decode_shapes_finite(params):
    toks = jnp.asarray([[1, 2, 3, 4]], jnp.int32)
    _, kc, vc = M.prefill(params, toks, CFG)
    logits, kc2, vc2 = M.decode_step(
        params, jnp.asarray([5], jnp.int32), jnp.asarray([4], jnp.int32), kc, vc, CFG
    )
    assert logits.shape == (1, CFG.vocab)
    assert bool(jnp.isfinite(logits).all())
    # exactly position 4 was written
    assert float(jnp.abs(kc2[:, :, 5:]).max()) == 0.0
    assert float(jnp.abs(kc2[:, :, 4]).max()) > 0.0


def test_decode_consistent_with_prefill(params):
    """Teacher-forced decode must reproduce prefill logits step by step."""
    rng = np.random.default_rng(1)
    toks = rng.integers(0, CFG.vocab, (1, 6)).astype(np.int32)
    full_logits, _, _ = M.prefill(params, jnp.asarray(toks), CFG)

    # prefill the first token only, then decode the rest token by token
    logits, kc, vc = M.prefill(params, jnp.asarray(toks[:, :1]), CFG)
    step_logits = [np.asarray(logits[:, 0])]
    for t in range(1, 6):
        lg, kc, vc = M.decode_step(
            params, jnp.asarray(toks[:, t]), jnp.asarray([t], jnp.int32), kc, vc, CFG
        )
        step_logits.append(np.asarray(lg))
    got = np.stack(step_logits, axis=1)  # (1, 6, V)
    np.testing.assert_allclose(got, np.asarray(full_logits), rtol=2e-3, atol=2e-3)


def test_batch_invariance(params):
    """Row b of a batched prefill == prefill of that row alone."""
    rng = np.random.default_rng(2)
    toks = rng.integers(0, CFG.vocab, (2, 5)).astype(np.int32)
    lg_b, _, _ = M.prefill(params, jnp.asarray(toks), CFG)
    lg_0, _, _ = M.prefill(params, jnp.asarray(toks[:1]), CFG)
    np.testing.assert_allclose(np.asarray(lg_b[0]), np.asarray(lg_0[0]), rtol=1e-4, atol=1e-4)


def test_param_count_sane():
    assert M.MICRO.param_count < M.MINI.param_count
    assert M.MINI.param_count > 1_000_000


def test_config_head_dim():
    assert CFG.head_dim * CFG.n_heads == CFG.dim


def test_decode_mixed_positions(params):
    """Continuous-batching contract: a group mixing sequences at different
    depths must decode each row as if alone."""
    rng = np.random.default_rng(5)
    a = rng.integers(0, CFG.vocab, (1, 6)).astype(np.int32)
    c = rng.integers(0, CFG.vocab, (1, 3)).astype(np.int32)

    # reference: each alone (decode one step after its own prefill)
    _, ka, va = M.prefill(params, jnp.asarray(a), CFG)
    lg_a, _, _ = M.decode_step(params, jnp.asarray([9]), jnp.asarray([6]), ka, va, CFG)
    _, kc_, vc_ = M.prefill(params, jnp.asarray(c), CFG)
    lg_c, _, _ = M.decode_step(params, jnp.asarray([11]), jnp.asarray([3]), kc_, vc_, CFG)

    # mixed group: slot 0 at pos 6, slot 1 at pos 3
    kg = jnp.concatenate([ka, kc_], axis=1)
    vg = jnp.concatenate([va, vc_], axis=1)
    lg, _, _ = M.decode_step(
        params, jnp.asarray([9, 11]), jnp.asarray([6, 3]), kg, vg, CFG
    )
    np.testing.assert_allclose(np.asarray(lg[0]), np.asarray(lg_a[0]), rtol=2e-3, atol=2e-3)
    np.testing.assert_allclose(np.asarray(lg[1]), np.asarray(lg_c[0]), rtol=2e-3, atol=2e-3)
