"""L1 Pallas kernel vs pure-jnp oracles -- the core correctness signal.

The kernel must agree EXACTLY (integer math) with both the dense decoded
matmul and the naive bit-wise decompose/recover pipeline, across shapes
(including non-multiples of the tile and of 32) and precisions 1..6 bits.
"""

import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings
from hypothesis import strategies as st

from compile.kernels.bitmm import apmm, apmm_packed, default_blocks
from compile.kernels.ref import (
    bitwise_matmul_ref,
    dense_matmul_ref,
    popcount_dot_ref,
    quantized_linear_ref,
)
from compile.quant import encode_bipolar, pack_along_k, quantize_bipolar


def _codes(rng, m, k, n, nw, nx):
    wc = jnp.asarray(rng.integers(0, 1 << nw, (m, k)).astype(np.uint32))
    xc = jnp.asarray(rng.integers(0, 1 << nx, (k, n)).astype(np.uint32))
    return wc, xc


# ------------------------------------------------------------- unit tests --


def test_popcount_dot_identity():
    """K - 2*popc(xor) == the true +-1 dot product."""
    rng = np.random.default_rng(0)
    w = rng.integers(0, 2, (3, 64)).astype(np.uint32)
    x = rng.integers(0, 2, (64, 5)).astype(np.uint32)
    got = np.asarray(popcount_dot_ref(jnp.asarray(w), jnp.asarray(x)))
    want = (2 * w.astype(np.int64) - 1) @ (2 * x.astype(np.int64) - 1)
    np.testing.assert_array_equal(got, want)


def test_bitwise_ref_matches_dense():
    rng = np.random.default_rng(1)
    for (m, k, n, nw, nx) in [(4, 32, 4, 1, 1), (3, 64, 5, 2, 3), (6, 96, 2, 4, 4)]:
        wc, xc = _codes(rng, m, k, n, nw, nx)
        np.testing.assert_array_equal(
            np.asarray(bitwise_matmul_ref(wc, xc, nw, nx)),
            np.asarray(dense_matmul_ref(wc, xc, nw, nx)),
        )


@pytest.mark.parametrize(
    "m,k,n,nw,nx",
    [
        (8, 64, 8, 1, 1),
        (8, 64, 8, 2, 2),
        (16, 128, 16, 3, 4),
        (1, 32, 1, 1, 2),  # degenerate 1x1 output
        (5, 96, 7, 2, 2),  # non-pow2 M/N
        (4, 40, 6, 3, 3),  # K not a multiple of 32 (padding path)
        (2, 33, 3, 2, 2),  # K barely over a word
        (7, 32, 9, 6, 5),  # wide precisions
    ],
)
def test_kernel_exact_vs_dense(m, k, n, nw, nx):
    rng = np.random.default_rng(42 + m + k + n)
    wc, xc = _codes(rng, m, k, n, nw, nx)
    np.testing.assert_array_equal(
        np.asarray(apmm(wc, xc, nw, nx)),
        np.asarray(dense_matmul_ref(wc, xc, nw, nx)),
    )


def test_kernel_multiblock_grid():
    """Shapes forcing a >1 grid in every dimension."""
    rng = np.random.default_rng(7)
    m, k, n, nw, nx = 96, 2048, 80, 2, 2
    wc, xc = _codes(rng, m, k, n, nw, nx)
    got = np.asarray(apmm(wc, xc, nw, nx, blocks=(32, 16, 8)))
    want = np.asarray(dense_matmul_ref(wc, xc, nw, nx))
    np.testing.assert_array_equal(got, want)


def test_kernel_block_shape_invariance():
    """Result must not depend on the BlockSpec tiling."""
    rng = np.random.default_rng(8)
    m, k, n, nw, nx = 32, 256, 32, 2, 3
    wc, xc = _codes(rng, m, k, n, nw, nx)
    want = np.asarray(dense_matmul_ref(wc, xc, nw, nx))
    for blocks in [(32, 32, 8), (16, 16, 4), (8, 32, 2), (32, 8, 8)]:
        got = np.asarray(apmm(wc, xc, nw, nx, blocks=blocks))
        np.testing.assert_array_equal(got, want, err_msg=f"blocks={blocks}")


def test_extreme_codes():
    """All-zeros / all-ones codes (the +-qmax corners)."""
    m, k, n, nw, nx = 4, 64, 4, 3, 3
    for wfill in (0, (1 << nw) - 1):
        for xfill in (0, (1 << nx) - 1):
            wc = jnp.full((m, k), wfill, jnp.uint32)
            xc = jnp.full((k, n), xfill, jnp.uint32)
            np.testing.assert_array_equal(
                np.asarray(apmm(wc, xc, nw, nx)),
                np.asarray(dense_matmul_ref(wc, xc, nw, nx)),
            )


def test_default_blocks_divide_padded():
    for m, n, kp in [(1, 1, 1), (64, 64, 16), (100, 3, 5), (4096, 4096, 128)]:
        bm, bn, bkp = default_blocks(m, n, kp)
        assert bm <= 64 and bn <= 64 and bkp <= 16
        assert bm > 0 and bn > 0 and bkp > 0


def test_packed_entrypoint_rejects_mismatch():
    wp = jnp.zeros((2, 8, 4), jnp.uint32)
    xp = jnp.zeros((2, 8, 5), jnp.uint32)
    with pytest.raises(ValueError):
        apmm_packed(wp, xp, k_logical=128, nw=2, nx=2)
    with pytest.raises(ValueError):
        apmm_packed(wp, wp, k_logical=128, nw=3, nx=2)


# ------------------------------------------------------ hypothesis sweeps --


@settings(max_examples=30, deadline=None)
@given(
    m=st.integers(1, 24),
    k=st.integers(1, 96),
    n=st.integers(1, 24),
    nw=st.integers(1, 5),
    nx=st.integers(1, 5),
    seed=st.integers(0, 2**31),
)
def test_kernel_exact_hypothesis(m, k, n, nw, nx, seed):
    rng = np.random.default_rng(seed)
    wc, xc = _codes(rng, m, k, n, nw, nx)
    np.testing.assert_array_equal(
        np.asarray(apmm(wc, xc, nw, nx)),
        np.asarray(dense_matmul_ref(wc, xc, nw, nx)),
    )


@settings(max_examples=10, deadline=None)
@given(
    m=st.integers(1, 8),
    k=st.integers(16, 80),
    n=st.integers(1, 12),
    nw=st.integers(1, 4),
    nx=st.integers(1, 4),
    seed=st.integers(0, 2**31),
)
def test_quantized_linear_matches_ref(m, k, n, nw, nx, seed):
    from compile.kernels.bitmm import quantized_linear

    rng = np.random.default_rng(seed)
    x = jnp.asarray(rng.normal(size=(m, k)).astype(np.float32))
    w = jnp.asarray(rng.normal(size=(n, k)).astype(np.float32))
    wq, ws = quantize_bipolar(w, nw, axis=-1)
    w_code = encode_bipolar(wq, nw)
    wp = pack_along_k(jnp.pad(w_code, ((0, 0), (0, (-k) % 32))), nw)
    got = np.asarray(
        quantized_linear(x, wp, ws.reshape(-1), k_logical=k, nw=nw, nx=nx)
    )
    want = np.asarray(quantized_linear_ref(x, w_code, ws.reshape(-1), nw, nx))
    np.testing.assert_allclose(got, want, rtol=1e-4, atol=1e-4)
