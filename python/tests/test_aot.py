"""AOT path: HLO text emission, manifest integrity, golden vectors."""

import json
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

import compile.aot as A
from compile import model as M
from compile.kernels.ref import dense_matmul_ref

ART = os.path.join(os.path.dirname(__file__), "..", "..", "artifacts")


def test_to_hlo_text_smoke(tmp_path):
    entry = A.lower_apmm(str(tmp_path), 8, 64, 8, 2, 2)
    text = (tmp_path / entry["hlo"]).read_text()
    assert text.startswith("HloModule"), "must be HLO text, not a proto"
    assert "ENTRY" in text
    # parameters in declared order: wp then xp
    assert entry["inputs"][0]["name"] == "wp"
    assert entry["inputs"][0]["shape"] == [2, 8, 2]


def test_weights_file_roundtrip(tmp_path):
    cfg = M.MICRO
    params = M.init_params(jax.random.PRNGKey(0), cfg)
    entries = A.write_weights(str(tmp_path), params, cfg)
    blob = (tmp_path / "weights.bin").read_bytes()
    flat = M.params_to_list(params, cfg)
    assert len(entries) == len(flat)
    total = sum(e["nbytes"] for e in entries)
    assert total == len(blob)
    for e, arr in zip(entries, flat):
        raw = blob[e["offset"] : e["offset"] + e["nbytes"]]
        got = np.frombuffer(raw, dtype=A.DTYPE_MAP[e["dtype"]]).reshape(e["shape"])
        np.testing.assert_array_equal(got, np.asarray(arr))


@pytest.mark.skipif(not os.path.exists(os.path.join(ART, "manifest.json")), reason="run `make artifacts` first")
def test_manifest_integrity():
    with open(os.path.join(ART, "manifest.json")) as f:
        man = json.load(f)
    assert man["version"] == 1
    names = set()
    for exe in man["executables"]:
        assert exe["name"] not in names, "duplicate executable name"
        names.add(exe["name"])
        assert os.path.exists(os.path.join(ART, exe["hlo"])), exe["hlo"]
        for io in exe["inputs"] + exe["outputs"]:
            assert io["dtype"] in A.DTYPE_MAP
    if man["model"] is not None:
        wf = os.path.join(ART, man["model"]["weights_file"])
        size = os.path.getsize(wf)
        last = man["model"]["weights"][-1]
        assert last["offset"] + last["nbytes"] == size


@pytest.mark.skipif(not os.path.exists(os.path.join(ART, "golden_apmm.json")), reason="run `make artifacts` first")
def test_golden_vectors_recompute():
    with open(os.path.join(ART, "golden_apmm.json")) as f:
        golden = json.load(f)
    assert len(golden["cases"]) >= 4
    for case in golden["cases"]:
        m, k, n = case["m"], case["k"], case["n"]
        wc = jnp.asarray(np.array(case["w_code"], np.uint32).reshape(m, k))
        xc = jnp.asarray(np.array(case["x_code"], np.uint32).reshape(k, n))
        y = np.asarray(dense_matmul_ref(wc, xc, case["nw"], case["nx"]))
        np.testing.assert_array_equal(y.flatten(), np.array(case["y"], np.int32))


def test_gemm_grid_covers_paper_precisions():
    """The artifact grid must include the paper's headline configs."""
    assert (1, 2) in A.GEMM_PRECISIONS  # W1A2
    assert (2, 2) in A.GEMM_PRECISIONS  # W2A2
    assert (3, 4) in A.GEMM_PRECISIONS  # W3A4
