"""Properties of the bipolar-INT format and quantizers (mirrors rust quant/)."""

import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings
from hypothesis import strategies as st

from compile.quant import (
    bipolar_qmax,
    decode_bipolar,
    dequantize_bipolar,
    encode_bipolar,
    pack_along_k,
    planes_from_code,
    quantize_bipolar,
)


@given(st.integers(min_value=1, max_value=8))
def test_qmax(bits):
    assert bipolar_qmax(bits) == 2**bits - 1


@pytest.mark.parametrize("bits", [1, 2, 3, 4, 8])
def test_encode_decode_roundtrip_all_values(bits):
    qmax = bipolar_qmax(bits)
    vals = jnp.arange(-qmax, qmax + 1, 2, dtype=jnp.int32)  # all odd values
    assert vals.shape[0] == 2**bits
    codes = encode_bipolar(vals, bits)
    assert int(codes.min()) == 0 and int(codes.max()) == 2**bits - 1
    back = decode_bipolar(codes, bits)
    np.testing.assert_array_equal(np.asarray(back), np.asarray(vals))


@pytest.mark.parametrize("bits", [1, 2, 3, 4])
def test_planes_decode_identity(bits):
    """sum_i (2*plane_i - 1) 2^i must reconstruct the decoded value (Eq. 1)."""
    rng = np.random.default_rng(0)
    code = jnp.asarray(rng.integers(0, 1 << bits, (5, 7)).astype(np.uint32))
    planes = planes_from_code(code, bits)
    recon = sum(
        (2 * planes[i].astype(jnp.int32) - 1) * (1 << i) for i in range(bits)
    )
    np.testing.assert_array_equal(np.asarray(recon), np.asarray(decode_bipolar(code, bits)))


@settings(max_examples=25, deadline=None)
@given(
    bits=st.integers(min_value=1, max_value=6),
    seed=st.integers(min_value=0, max_value=2**31),
)
def test_quantize_produces_odd_in_range(bits, seed):
    rng = np.random.default_rng(seed)
    x = jnp.asarray(rng.normal(size=(4, 16)).astype(np.float32))
    q, scale = quantize_bipolar(x, bits, axis=-1)
    qn = np.asarray(q)
    qmax = bipolar_qmax(bits)
    assert np.all(qn % 2 != 0), "bipolar values must be odd"
    assert np.all(np.abs(qn) <= qmax)
    assert np.all(np.asarray(scale) > 0)


def test_quantize_error_bound():
    """RTN onto the odd grid: |x - s*q| <= s (grid step is 2s)."""
    rng = np.random.default_rng(1)
    x = jnp.asarray(rng.normal(size=(8, 32)).astype(np.float32))
    for bits in (2, 3, 4, 6):
        q, scale = quantize_bipolar(x, bits, axis=-1)
        err = np.abs(np.asarray(x) - np.asarray(dequantize_bipolar(q, scale)))
        assert err.max() <= np.asarray(scale).max() * (1 + 1e-5)


def test_quantize_symmetry():
    """Quantizing -x must give exactly -q (no zero-point asymmetry)."""
    rng = np.random.default_rng(2)
    x = jnp.asarray(rng.normal(size=(4, 24)).astype(np.float32))
    for bits in (1, 2, 3):
        q1, s1 = quantize_bipolar(x, bits, axis=-1)
        q2, s2 = quantize_bipolar(-x, bits, axis=-1)
        np.testing.assert_allclose(np.asarray(s1), np.asarray(s2), rtol=1e-6)
        # grid is symmetric; ties x/s == even integers may round either way
        mask = np.abs(np.asarray(x) / np.asarray(s1) % 2.0 - 0.0) > 1e-4
        np.testing.assert_array_equal(np.asarray(q1)[mask], -np.asarray(q2)[mask])


@pytest.mark.parametrize("bits,k", [(1, 32), (2, 64), (3, 96), (4, 128)])
def test_pack_unpack(bits, k):
    rng = np.random.default_rng(3)
    code = jnp.asarray(rng.integers(0, 1 << bits, (6, k)).astype(np.uint32))
    packed = pack_along_k(code, bits)
    assert packed.shape == (bits, 6, k // 32)
    # unpack by hand and compare with planes
    planes = np.asarray(planes_from_code(code, bits))
    pk = np.asarray(packed)
    for i in range(bits):
        for r in range(6):
            for w in range(k // 32):
                for b in range(32):
                    assert ((pk[i, r, w] >> b) & 1) == planes[i, r, w * 32 + b]


def test_per_tensor_vs_per_channel():
    rng = np.random.default_rng(4)
    x = jnp.asarray(rng.normal(size=(3, 8)).astype(np.float32) * np.array([[1.0], [10.0], [100.0]], np.float32))
    _, s_tensor = quantize_bipolar(x, 4, axis=None)
    _, s_chan = quantize_bipolar(x, 4, axis=-1)
    assert np.asarray(s_tensor).size == 1
    assert np.asarray(s_chan).shape == (3, 1)
    # per-channel adapts to each row's range
    assert np.asarray(s_chan)[0, 0] < np.asarray(s_chan)[2, 0]
