"""Pure-jnp correctness oracles for the arbitrary-precision MatMul.

Two independent references:

  * ``dense_matmul_ref``  -- decode both operands to plain int32 and matmul.
    The ground truth: no bit tricks at all.
  * ``bitwise_matmul_ref`` -- the paper's Sec. 3.2 pipeline written naively
    (decompose -> n_w*n_x 1-bit XOR/popcount GEMMs -> shift-add recovery)
    but without packing or tiling.  Validates the *math* of the recovery
    dataflow in isolation from the Pallas kernel's memory layout.

The Pallas kernel (bitmm.py) must agree with both.
"""

from __future__ import annotations

import jax.numpy as jnp

from compile.quant import decode_bipolar, encode_bipolar, planes_from_code, quantize_bipolar

__all__ = [
    "dense_matmul_ref",
    "bitwise_matmul_ref",
    "popcount_dot_ref",
    "quantized_linear_ref",
]


def dense_matmul_ref(w_code, x_code, nw: int, nx: int):
    """Ground truth: decode bipolar codes to int values, plain int matmul.

    w_code: uint32 (M, K) codes in [0, 2^nw);  x_code: uint32 (K, N).
    Returns int32 (M, N).
    """
    w = decode_bipolar(w_code, nw)
    x = decode_bipolar(x_code, nx)
    return jnp.matmul(w, x, preferred_element_type=jnp.int32)


def popcount_dot_ref(w_plane, x_plane):
    """1-bit bipolar GEMM via the XOR/popcount identity.

    w_plane: {0,1} (M, K); x_plane: {0,1} (K, N).
    dot_pm1[m, n] = K - 2 * popcount(w[m, :] XOR x[:, n]).
    Emulates the tensor-core BMMA-XOR op + its scalar recovery.
    """
    k = w_plane.shape[-1]
    xor = jnp.bitwise_xor(w_plane[:, None, :], x_plane.T[None, :, :])
    pop = jnp.sum(xor.astype(jnp.int32), axis=-1)
    return k - 2 * pop


def bitwise_matmul_ref(w_code, x_code, nw: int, nx: int):
    """The paper's decompose / 1-bit-GEMM / recover pipeline, naively.

    Y = sum_{i,j} 2^{i+j} * D_ij   with   D_ij = K - 2*popc(W_i ^ X_j).
    """
    w_planes = planes_from_code(w_code, nw)  # (nw, M, K)
    x_planes = planes_from_code(x_code, nx)  # (nx, K, N)
    m, n = w_code.shape[0], x_code.shape[1]
    y = jnp.zeros((m, n), dtype=jnp.int32)
    for i in range(nw):
        for j in range(nx):
            d_ij = popcount_dot_ref(w_planes[i], x_planes[j])
            y = y + (d_ij << (i + j))
    return y


def quantized_linear_ref(x, w_code, w_scale, nw: int, nx: int):
    """Float-in/float-out reference for the quantized linear layer.

    x: float (M, K); w_code: uint32 (N, K) codes (output-channel-major);
    w_scale: (N,) or scalar.  Dynamically quantizes x per-row to nx-bit
    bipolar, then y = (Xq Wq^T) * x_scale * w_scale.
    """
    xq, x_scale = quantize_bipolar(x, nx, axis=-1)  # (M, K), (M, 1)
    x_code = encode_bipolar(xq, nx)
    y_int = dense_matmul_ref(w_code, x_code.T, nw, nx)  # (N, M)
    return (y_int.T.astype(jnp.float32) * x_scale) * jnp.reshape(w_scale, (1, -1))
