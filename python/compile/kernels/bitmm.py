"""L1 Pallas kernel: arbitrary-precision bipolar-INT MatMul.

TPU rethink of the paper's tensor-core design (DESIGN.md Sec. 3):

  * the BMMA-XOR 1-bit GEMM becomes an XNOR/popcount inner product over
    K-packed ``uint32`` lanes (``lax.population_count`` on the VPU);
  * the threadblock (b_m x b_n, K chunked by b_k) schedule becomes a
    Pallas grid ``(M/bm, N/bn, Kp/bkp)`` whose BlockSpecs express the
    HBM<->VMEM streaming the paper wrote with threadblocks;
  * Sec. 4.2's "recover in shared memory, never in global memory" becomes
    "recover on the VMEM-resident accumulator inside the kernel" -- the
    shift-add over all n_w*n_x plane pairs happens on the output block
    before it is ever written back;
  * Sec. 4.2 (4)'s fragment reuse (one weight plane against all activation
    planes) is the kernel's loop order: outer over weight planes, inner
    over activation planes;
  * Sec. 4.1's plane concatenation: each operand arrives as ONE packed
    array ``(n_planes, rows, K/32)`` streamed by a single BlockSpec.

Operand layout
--------------
  wp : uint32 (n_w, M, Kp)   weight bit planes, packed along K (LSB-first
                             lanes), plane i = bit i of the bipolar code.
  xp : uint32 (n_x, N, Kp)   activation planes, N-major (i.e. X^T) so the
                             XOR runs along the contiguous K axis.
  out: int32  (M, N)

Math (Sec. 3.2): with bipolar decode v = sum_i (2 b_i - 1) 2^i,

  Y = C - 2 * sum_{i,j} 2^{i+j} popc(W_i ^ X_j),
  C = K * (2^{n_w} - 1) * (2^{n_x} - 1).

Zero-padding K (in whole 32-bit words, zeros in BOTH operands) adds
XOR = 0 -> popc 0, so only the *logical* K enters through C and padding is
exact.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax import lax
from jax.experimental import pallas as pl

from compile.quant import pack_along_k, quantize_pack_activations

__all__ = ["apmm_packed", "apmm", "quantized_linear", "default_blocks"]


def _apmm_kernel(w_ref, x_ref, o_ref, *, nw: int, nx: int, c_const: int):
    """One (bm, bn) output block, one bkp-wide K chunk.

    Grid = (M/bm, N/bn, Kp/bkp); the output block stays resident in VMEM
    across the K dimension (innermost grid axis) and accumulates -- the
    recovery never leaves fast memory.
    """

    @pl.when(pl.program_id(2) == 0)
    def _init():  # bake the bipolar constant in once per output block
        o_ref[...] = jnp.full(o_ref.shape, c_const, dtype=jnp.int32)

    acc = jnp.zeros(o_ref.shape, dtype=jnp.int32)
    for i in range(nw):  # one weight plane ...
        w_i = w_ref[i]  # (bm, bkp) uint32
        for j in range(nx):  # ... against ALL activation planes (Sec 4.2 (4))
            x_j = x_ref[j]  # (bn, bkp) uint32
            xor = jnp.bitwise_xor(w_i[:, None, :], x_j[None, :, :])
            popc = jnp.sum(lax.population_count(xor).astype(jnp.int32), axis=-1)
            acc = acc + (popc << (i + j))  # activation+weight shift fused

    o_ref[...] = o_ref[...] - 2 * acc


def default_blocks(m: int, n: int, kp: int) -> tuple[int, int, int]:
    """Pick (bm, bn, bkp) balancing VMEM footprint vs grid overhead.

    Footprint per step ~= (nw*bm + nx*bn)*bkp*4 bytes of planes plus the
    bm*bn*4 accumulator plus the bm*bn*bkp*4 XOR intermediate; 64x64x16 is
    ~300 KB -- comfortably double-bufferable in 16 MB VMEM.
    """

    def pick(dim: int, cap: int) -> int:
        b = 1
        while b * 2 <= min(dim, cap):
            b *= 2
        return b

    return pick(m, 64), pick(n, 64), pick(kp, 16)


def _pad_axis(a, axis: int, mult: int):
    size = a.shape[axis]
    pad = (-size) % mult
    if pad == 0:
        return a
    widths = [(0, 0)] * a.ndim
    widths[axis] = (0, pad)
    return jnp.pad(a, widths)


@functools.partial(
    jax.jit, static_argnames=("k_logical", "nw", "nx", "blocks", "interpret")
)
def apmm_packed(wp, xp, *, k_logical: int, nw: int, nx: int, blocks=None, interpret=True):
    """Arbitrary-precision MatMul on pre-packed bit planes.

    wp: uint32 (nw, M, Kp); xp: uint32 (nx, N, Kp); returns int32 (M, N).
    ``k_logical`` is the true reduction length (<= Kp*32); the difference
    must be zero-padded words in both operands.
    """
    if wp.shape[0] != nw or xp.shape[0] != nx:
        raise ValueError("plane-count mismatch between operands and nw/nx")
    if wp.shape[2] != xp.shape[2]:
        raise ValueError(f"packed-K mismatch: {wp.shape} vs {xp.shape}")
    m, n, kp = wp.shape[1], xp.shape[1], wp.shape[2]
    bm, bn, bkp = blocks if blocks is not None else default_blocks(m, n, kp)

    wp = _pad_axis(wp, 1, bm)
    xp = _pad_axis(xp, 1, bn)
    wp = _pad_axis(wp, 2, bkp)
    xp = _pad_axis(xp, 2, bkp)
    mp, np_, kpp = wp.shape[1], xp.shape[1], wp.shape[2]

    c_const = k_logical * ((1 << nw) - 1) * ((1 << nx) - 1)
    kernel = functools.partial(_apmm_kernel, nw=nw, nx=nx, c_const=c_const)
    out = pl.pallas_call(
        kernel,
        grid=(mp // bm, np_ // bn, kpp // bkp),
        in_specs=[
            pl.BlockSpec((nw, bm, bkp), lambda im, jn, ik: (0, im, ik)),
            pl.BlockSpec((nx, bn, bkp), lambda im, jn, ik: (0, jn, ik)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda im, jn, ik: (im, jn)),
        out_shape=jax.ShapeDtypeStruct((mp, np_), jnp.int32),
        interpret=interpret,
    )(wp, xp)
    return out[:m, :n]


def apmm(w_code, x_code, nw: int, nx: int, blocks=None, interpret=True):
    """End-to-end integer AP-MatMul from unpacked codes.

    w_code: uint32 (M, K) bipolar codes; x_code: uint32 (K, N).
    Packs along K (padding K to a multiple of 32 with zero bits is exact
    only through the C-constant trick, see module docstring) and runs the
    kernel.  Returns int32 (M, N).
    """
    k = w_code.shape[1]
    if x_code.shape[0] != k:
        raise ValueError(f"inner-dim mismatch: {w_code.shape} vs {x_code.shape}")
    w_padded = _pad_axis(w_code, 1, 32)
    x_padded = _pad_axis(x_code.T, 1, 32)  # N-major layout for the kernel
    wp = pack_along_k(w_padded, nw)
    xp = pack_along_k(x_padded, nx)
    # zero-pad words hold code 0; code 0 decodes to -qmax, NOT zero -- but
    # the XOR identity only ever sees equal padding in both operands, whose
    # popcount contribution is zero, so correctness rides on k_logical.
    return apmm_packed(
        wp, xp, k_logical=k, nw=nw, nx=nx, blocks=blocks, interpret=interpret
    )


def quantized_linear(x, wp, w_scale, *, k_logical: int, nw: int, nx: int, interpret=True):
    """Float->float quantized linear layer: y = x @ W^T (W stored packed).

    x: float (M, K); wp: uint32 (nw, N, Kp) pre-packed weight planes
    (output-channel-major); w_scale: float (N,) per-channel scales.
    Activations are dynamically quantized per-row to nx-bit bipolar.
    Returns float32 (M, N).

    Padding order matters: quantize on the TRUE K first, then zero-pad the
    *codes* to a word boundary -- padding the floats first would quantize
    0.0 to a nonzero bipolar code and corrupt the XOR identity.
    """
    from compile.quant import encode_bipolar, quantize_bipolar

    xq, x_scale = quantize_bipolar(x, nx, axis=-1)  # (M, K), (M, 1)
    x_code = _pad_axis(encode_bipolar(xq, nx), 1, 32)
    xp = pack_along_k(x_code, nx)  # (nx, M, Kp)
    # apmm_packed(wp (nw,N,Kp), xp (nx,M,Kp)) -> (N, M); transpose to (M, N)
    y_int = apmm_packed(
        wp, xp, k_logical=k_logical, nw=nw, nx=nx, interpret=interpret
    ).T
    return y_int.astype(jnp.float32) * x_scale * jnp.reshape(w_scale, (1, -1))
