"""AOT lowering: JAX/Pallas -> HLO text + weights + manifest (build-time).

Emits into ``artifacts/``:

  * ``apmm_w{nw}a{nx}_{M}x{K}x{N}.hlo.txt``  -- standalone AP-GEMM
    executables over a shape x precision grid (kernel integration tests +
    the measured bench).
  * ``model_prefill_b{B}_t{T}.hlo.txt`` / ``model_decode_b{B}.hlo.txt`` --
    the L2 model entry points, weights as leading parameters.
  * ``weights.bin``   -- raw little-endian tensors in param_spec order.
  * ``golden_apmm.json`` -- small cross-language test vectors (inputs +
    expected outputs) so the Rust ``bitmm`` substrate can verify against
    the Python oracle bit-for-bit.
  * ``manifest.json`` -- everything the Rust runtime needs to load the
    above (shapes, dtypes, argument order, offsets).

HLO *text* is the interchange format -- jax >= 0.5 emits protos with
64-bit instruction ids that xla_extension 0.5.1 rejects; the text parser
reassigns ids (see /opt/xla-example/README.md).
"""

from __future__ import annotations

import argparse
import json
import os

import jax
import jax.numpy as jnp
import numpy as np
from jax._src.lib import xla_client as xc

from compile import model as M
from compile.kernels.bitmm import apmm_packed
from compile.kernels.ref import dense_matmul_ref
from compile.quant import pack_along_k

jax.config.update("jax_platform_name", "cpu")

# Standalone GEMM artifact grid: (M, K, N) x (nw, nx).
GEMM_SHAPES = [(64, 256, 64), (128, 512, 128)]
GEMM_PRECISIONS = [(1, 2), (2, 2), (3, 4)]

# Model entry-point grid.
PREFILL_BATCHES = [(1, 16), (2, 16), (4, 16)]  # (B, T)
DECODE_BATCHES = [1, 2, 4, 8]

DTYPE_MAP = {"f32": np.float32, "u32": np.uint32, "i32": np.int32}


def to_hlo_text(lowered) -> str:
    """stablehlo -> XlaComputation -> HLO text (return_tuple=True)."""
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def _spec(shape, dtype):
    return jax.ShapeDtypeStruct(shape, DTYPE_MAP[dtype])


def _io(name, dtype, shape):
    return {"name": name, "dtype": dtype, "shape": list(shape)}


def lower_apmm(out_dir, m, k, n, nw, nx):
    kp = (k + 31) // 32
    name = f"apmm_w{nw}a{nx}_{m}x{k}x{n}"

    def fn(wp, xp):
        return (apmm_packed(wp, xp, k_logical=k, nw=nw, nx=nx, interpret=True),)

    lowered = jax.jit(fn).lower(
        _spec((nw, m, kp), "u32"), _spec((nx, n, kp), "u32")
    )
    path = f"{name}.hlo.txt"
    with open(os.path.join(out_dir, path), "w") as f:
        f.write(to_hlo_text(lowered))
    return {
        "name": name,
        "kind": "apmm",
        "hlo": path,
        "inputs": [_io("wp", "u32", (nw, m, kp)), _io("xp", "u32", (nx, n, kp))],
        "outputs": [_io("y", "i32", (m, n))],
        "meta": {"m": m, "k": k, "n": n, "nw": nw, "nx": nx},
    }


def write_weights(out_dir, params, cfg):
    """weights.bin + spec-with-offsets; returns the spec entries."""
    spec = M.param_spec(cfg)
    flat = M.params_to_list(params, cfg)
    assert len(spec) == len(flat)
    entries = []
    offset = 0
    with open(os.path.join(out_dir, "weights.bin"), "wb") as f:
        for (name, shape, dtype), arr in zip(spec, flat):
            a = np.asarray(arr).astype(DTYPE_MAP[dtype])
            assert tuple(a.shape) == tuple(shape), (name, a.shape, shape)
            raw = a.tobytes()  # C-order little-endian
            f.write(raw)
            entries.append(
                {"name": name, "dtype": dtype, "shape": list(shape), "offset": offset, "nbytes": len(raw)}
            )
            offset += len(raw)
    return entries


def lower_prefill(out_dir, params, cfg, b, t):
    name = f"model_prefill_b{b}_t{t}"
    spec = M.param_spec(cfg)

    def fn(*args):
        flat, (tokens,) = args[: len(spec)], args[len(spec) :]
        p = M.params_from_list(list(flat), cfg)
        return M.prefill(p, tokens, cfg)

    arg_specs = [_spec(s, d) for (_, s, d) in spec] + [_spec((b, t), "i32")]
    lowered = jax.jit(fn).lower(*arg_specs)
    path = f"{name}.hlo.txt"
    with open(os.path.join(out_dir, path), "w") as f:
        f.write(to_hlo_text(lowered))
    kv = (cfg.n_layers, b, cfg.max_seq, cfg.n_kv_heads, cfg.head_dim)
    return {
        "name": name,
        "kind": "prefill",
        "hlo": path,
        "inputs": [_io("tokens", "i32", (b, t))],
        "outputs": [
            _io("logits", "f32", (b, t, cfg.vocab)),
            _io("k_cache", "f32", kv),
            _io("v_cache", "f32", kv),
        ],
        "meta": {"batch": b, "seq": t},
    }


def lower_decode(out_dir, params, cfg, b):
    name = f"model_decode_b{b}"
    spec = M.param_spec(cfg)
    kv = (cfg.n_layers, b, cfg.max_seq, cfg.n_kv_heads, cfg.head_dim)

    def fn(*args):
        flat = args[: len(spec)]
        token, pos, k_cache, v_cache = args[len(spec) :]
        p = M.params_from_list(list(flat), cfg)
        return M.decode_step(p, token, pos, k_cache, v_cache, cfg)

    arg_specs = [_spec(s, d) for (_, s, d) in spec] + [
        _spec((b,), "i32"),
        _spec((b,), "i32"),  # per-slot positions (continuous batching)
        _spec(kv, "f32"),
        _spec(kv, "f32"),
    ]
    lowered = jax.jit(fn).lower(*arg_specs)
    path = f"{name}.hlo.txt"
    with open(os.path.join(out_dir, path), "w") as f:
        f.write(to_hlo_text(lowered))
    return {
        "name": name,
        "kind": "decode",
        "hlo": path,
        "inputs": [
            _io("token", "i32", (b,)),
            _io("pos", "i32", (b,)),
            _io("k_cache", "f32", kv),
            _io("v_cache", "f32", kv),
        ],
        "outputs": [
            _io("logits", "f32", (b, cfg.vocab)),
            _io("k_cache", "f32", kv),
            _io("v_cache", "f32", kv),
        ],
        "meta": {"batch": b},
    }


def write_golden(out_dir, rng):
    """Cross-language vectors: rust bitmm must reproduce these exactly."""
    cases = []
    for (m, k, n), (nw, nx) in [
        ((4, 64, 4), (1, 1)),
        ((3, 32, 5), (2, 2)),
        ((8, 96, 6), (3, 4)),
        ((5, 40, 7), (4, 3)),  # K not a multiple of 32
    ]:
        wc = rng.integers(0, 1 << nw, (m, k)).astype(np.uint32)
        xc_ = rng.integers(0, 1 << nx, (k, n)).astype(np.uint32)
        y = np.asarray(dense_matmul_ref(jnp.asarray(wc), jnp.asarray(xc_), nw, nx))
        cases.append(
            {
                "m": m,
                "k": k,
                "n": n,
                "nw": nw,
                "nx": nx,
                "w_code": wc.flatten().tolist(),
                "x_code": xc_.flatten().tolist(),
                "y": y.flatten().tolist(),
            }
        )
    with open(os.path.join(out_dir, "golden_apmm.json"), "w") as f:
        json.dump({"cases": cases}, f)


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--out", default="../artifacts")
    ap.add_argument("--skip-model", action="store_true", help="GEMM artifacts only (fast)")
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()
    os.makedirs(args.out, exist_ok=True)

    rng = np.random.default_rng(args.seed)
    executables = []

    for m, k, n in GEMM_SHAPES:
        for nw, nx in GEMM_PRECISIONS:
            executables.append(lower_apmm(args.out, m, k, n, nw, nx))
            print(f"lowered {executables[-1]['name']}")

    cfg = M.MINI
    manifest = {
        "version": 1,
        "model": None,
        "executables": executables,
    }
    if not args.skip_model:
        params = M.init_params(jax.random.PRNGKey(args.seed), cfg)
        weight_entries = write_weights(args.out, params, cfg)
        manifest["model"] = {
            "config": {
                "vocab": cfg.vocab,
                "dim": cfg.dim,
                "n_layers": cfg.n_layers,
                "n_heads": cfg.n_heads,
                "n_kv_heads": cfg.n_kv_heads,
                "ffn": cfg.ffn,
                "max_seq": cfg.max_seq,
                "nw": cfg.nw,
                "nx": cfg.nx,
            },
            "weights_file": "weights.bin",
            "weights": weight_entries,
        }
        for b, t in PREFILL_BATCHES:
            executables.append(lower_prefill(args.out, params, cfg, b, t))
            print(f"lowered {executables[-1]['name']}")
        for b in DECODE_BATCHES:
            executables.append(lower_decode(args.out, params, cfg, b))
            print(f"lowered {executables[-1]['name']}")

    write_golden(args.out, rng)
    with open(os.path.join(args.out, "manifest.json"), "w") as f:
        json.dump(manifest, f, indent=1)
    print(f"wrote manifest with {len(executables)} executables to {args.out}")


if __name__ == "__main__":
    main()
