"""L2: Llama-style decoder with all projections through the AP kernel.

Build-time JAX model.  Every linear layer stores its weights as bipolar
bit planes (packed uint32, Sec. 4.1 layout) + per-output-channel scales
and runs through the L1 Pallas kernel; activations are dynamically
quantized per token.  Attention math (softmax, RoPE, cache) stays f32.

Entry points lowered by aot.py:

  * ``prefill(params, tokens)``          -> logits, k_cache, v_cache
  * ``decode_step(params, token, pos, k_cache, v_cache)`` -> logits, caches

Weights are *parameters* of the lowered HLO (not constants): the Rust
runtime loads them once from ``artifacts/weights.bin`` and keeps them
device-resident.  ``param_spec`` fixes the flat ordering shared with the
manifest.
"""

from __future__ import annotations

from dataclasses import dataclass, field

import jax
import jax.numpy as jnp
import numpy as np

from compile.kernels.bitmm import quantized_linear
from compile.quant import encode_bipolar, pack_along_k, quantize_bipolar

__all__ = [
    "ModelConfig",
    "MINI",
    "MICRO",
    "init_params",
    "param_spec",
    "params_to_list",
    "params_from_list",
    "prefill",
    "decode_step",
]


@dataclass(frozen=True)
class ModelConfig:
    """Architecture + precision config (the W{nw}A{nx} pair is first-class)."""

    vocab: int = 1024
    dim: int = 256
    n_layers: int = 4
    n_heads: int = 8
    n_kv_heads: int = 4
    ffn: int = 512
    max_seq: int = 128
    nw: int = 2  # weight bits  (bipolar)
    nx: int = 2  # activation bits (bipolar)
    rope_theta: float = 10000.0

    @property
    def head_dim(self) -> int:
        return self.dim // self.n_heads

    @property
    def param_count(self) -> int:
        """Dense-equivalent parameter count (before bit packing)."""
        per_layer = (
            self.dim * self.dim  # q
            + 2 * self.dim * (self.n_kv_heads * self.head_dim)  # k, v
            + self.dim * self.dim  # o
            + 3 * self.dim * self.ffn  # gate, up, down
            + 2 * self.dim  # norms
        )
        return self.vocab * self.dim * 2 + self.n_layers * per_layer + self.dim


# Presets: MICRO for fast tests, MINI for the end-to-end serving example.
MICRO = ModelConfig(vocab=256, dim=64, n_layers=2, n_heads=4, n_kv_heads=2, ffn=128, max_seq=32)
MINI = ModelConfig()


def _kp(k: int) -> int:
    return (k + 31) // 32


def _quantize_weight(key, shape, nw: int):
    """Random-init a dense weight, quantize to bipolar, return packed planes
    + per-row (output-channel) scales."""
    out, k = shape
    w = jax.random.normal(key, (out, k), dtype=jnp.float32) / np.sqrt(k)
    q, scale = quantize_bipolar(w, nw, axis=-1)
    code = encode_bipolar(q, nw)
    code = jnp.pad(code, ((0, 0), (0, (-k) % 32)))
    return {
        "planes": pack_along_k(code, nw),  # (nw, out, Kp)
        "scale": scale.reshape(-1),  # (out,)
    }


def init_params(key, cfg: ModelConfig):
    keys = jax.random.split(key, 3 + cfg.n_layers)
    kvd = cfg.n_kv_heads * cfg.head_dim
    params = {
        "tok_emb": jax.random.normal(keys[0], (cfg.vocab, cfg.dim), jnp.float32) * 0.02,
        "final_norm": jnp.ones((cfg.dim,), jnp.float32),
        "lm_head": _quantize_weight(keys[1], (cfg.vocab, cfg.dim), cfg.nw),
        "layers": [],
    }
    for li in range(cfg.n_layers):
        lk = jax.random.split(keys[3 + li], 7)
        params["layers"].append(
            {
                "attn_norm": jnp.ones((cfg.dim,), jnp.float32),
                "q": _quantize_weight(lk[0], (cfg.dim, cfg.dim), cfg.nw),
                "k": _quantize_weight(lk[1], (kvd, cfg.dim), cfg.nw),
                "v": _quantize_weight(lk[2], (kvd, cfg.dim), cfg.nw),
                "o": _quantize_weight(lk[3], (cfg.dim, cfg.dim), cfg.nw),
                "mlp_norm": jnp.ones((cfg.dim,), jnp.float32),
                "gate": _quantize_weight(lk[4], (cfg.ffn, cfg.dim), cfg.nw),
                "up": _quantize_weight(lk[5], (cfg.ffn, cfg.dim), cfg.nw),
                "down": _quantize_weight(lk[6], (cfg.dim, cfg.ffn), cfg.nw),
            }
        )
    return params


def param_spec(cfg: ModelConfig):
    """Flat (name, shape, dtype) list -- THE ordering contract with Rust.

    The manifest writes this list; the Rust runtime feeds weight literals
    in exactly this order ahead of the activation arguments.
    """
    kvd = cfg.n_kv_heads * cfg.head_dim

    def qw(name, out, k):
        return [
            (f"{name}.planes", (cfg.nw, out, _kp(k)), "u32"),
            (f"{name}.scale", (out,), "f32"),
        ]

    spec = [
        ("tok_emb", (cfg.vocab, cfg.dim), "f32"),
        ("final_norm", (cfg.dim,), "f32"),
        *qw("lm_head", cfg.vocab, cfg.dim),
    ]
    for li in range(cfg.n_layers):
        p = f"layers.{li}"
        spec += [(f"{p}.attn_norm", (cfg.dim,), "f32")]
        spec += qw(f"{p}.q", cfg.dim, cfg.dim)
        spec += qw(f"{p}.k", kvd, cfg.dim)
        spec += qw(f"{p}.v", kvd, cfg.dim)
        spec += qw(f"{p}.o", cfg.dim, cfg.dim)
        spec += [(f"{p}.mlp_norm", (cfg.dim,), "f32")]
        spec += qw(f"{p}.gate", cfg.ffn, cfg.dim)
        spec += qw(f"{p}.up", cfg.ffn, cfg.dim)
        spec += qw(f"{p}.down", cfg.dim, cfg.ffn)
    return spec


def params_to_list(params, cfg: ModelConfig):
    out = [params["tok_emb"], params["final_norm"], params["lm_head"]["planes"], params["lm_head"]["scale"]]
    for layer in params["layers"]:
        out.append(layer["attn_norm"])
        for name in ("q", "k", "v", "o"):
            out += [layer[name]["planes"], layer[name]["scale"]]
        out.append(layer["mlp_norm"])
        for name in ("gate", "up", "down"):
            out += [layer[name]["planes"], layer[name]["scale"]]
    return out


def params_from_list(flat, cfg: ModelConfig):
    it = iter(flat)

    def qw():
        return {"planes": next(it), "scale": next(it)}

    params = {
        "tok_emb": next(it),
        "final_norm": next(it),
        "lm_head": qw(),
        "layers": [],
    }
    for _ in range(cfg.n_layers):
        layer = {"attn_norm": next(it)}
        for name in ("q", "k", "v", "o"):
            layer[name] = qw()
        layer["mlp_norm"] = next(it)
        for name in ("gate", "up", "down"):
            layer[name] = qw()
        params["layers"].append(layer)
    return params


# ---------------------------------------------------------------- forward --


def _rmsnorm(x, w, eps=1e-5):
    var = jnp.mean(x * x, axis=-1, keepdims=True)
    return x * jax.lax.rsqrt(var + eps) * w


def _qlinear(x2d, w, cfg: ModelConfig, k_logical: int, interpret=True):
    """(M, K) float -> (M, out) float through the AP kernel."""
    return quantized_linear(
        x2d, w["planes"], w["scale"], k_logical=k_logical, nw=cfg.nw, nx=cfg.nx, interpret=interpret
    )


def _rope(x, pos, theta: float):
    """x: (B, S, H, Dh); pos: (B, S) absolute positions (per batch row —
    decode groups mix sequences at different positions)."""
    dh = x.shape[-1]
    half = dh // 2
    freqs = theta ** (-jnp.arange(0, half, dtype=jnp.float32) / half)
    angles = pos[..., None].astype(jnp.float32) * freqs  # (B, S, half)
    cos = jnp.cos(angles)[:, :, None, :]
    sin = jnp.sin(angles)[:, :, None, :]
    x1, x2 = x[..., :half], x[..., half:]
    return jnp.concatenate([x1 * cos - x2 * sin, x1 * sin + x2 * cos], axis=-1)


def _attention(q, k, v, mask):
    """q: (B,S,H,Dh); k,v: (B,S_kv,Hkv,Dh); mask additive, broadcastable
    to (B, 1, S, S_kv)."""
    b, s, h, dh = q.shape
    hkv = k.shape[2]
    rep = h // hkv
    k = jnp.repeat(k, rep, axis=2)
    v = jnp.repeat(v, rep, axis=2)
    scores = jnp.einsum("bshd,bthd->bhst", q, k) / np.sqrt(dh)
    scores = scores + mask
    probs = jax.nn.softmax(scores, axis=-1)
    return jnp.einsum("bhst,bthd->bshd", probs, v)


def _block(x, layer, cfg: ModelConfig, pos, k_slice, v_slice, mask, interpret=True):
    """One transformer block over S tokens given S_kv cached K/V (which
    already include this step's keys).  x: (B, S, D); pos: (B, S)."""
    b, s, d = x.shape
    h = _rmsnorm(x, layer["attn_norm"])
    h2 = h.reshape(b * s, d)
    q = _qlinear(h2, layer["q"], cfg, d, interpret).reshape(b, s, cfg.n_heads, cfg.head_dim)
    q = _rope(q, pos, cfg.rope_theta)
    attn = _attention(q, k_slice, v_slice, mask)
    attn = _qlinear(attn.reshape(b * s, d), layer["o"], cfg, d, interpret).reshape(b, s, d)
    x = x + attn
    h = _rmsnorm(x, layer["mlp_norm"])
    h2 = h.reshape(b * s, d)
    gate = _qlinear(h2, layer["gate"], cfg, d, interpret)
    up = _qlinear(h2, layer["up"], cfg, d, interpret)
    mlp = _qlinear(jax.nn.silu(gate) * up, layer["down"], cfg, cfg.ffn, interpret)
    return x + mlp.reshape(b, s, d)


def _project_kv(h2, layer, cfg, b, s, pos, interpret=True):
    kvd = cfg.n_kv_heads * cfg.head_dim
    k = _qlinear(h2, layer["k"], cfg, cfg.dim, interpret).reshape(b, s, cfg.n_kv_heads, cfg.head_dim)
    v = _qlinear(h2, layer["v"], cfg, cfg.dim, interpret).reshape(b, s, cfg.n_kv_heads, cfg.head_dim)
    return _rope(k, pos, cfg.rope_theta), v


def prefill(params, tokens, cfg: ModelConfig, interpret=True):
    """tokens: int32 (B, T).  Returns (logits (B,T,V), k_cache, v_cache)
    with caches of shape (L, B, max_seq, Hkv, Dh), positions [0, T) filled."""
    b, t = tokens.shape
    x = params["tok_emb"][tokens]  # (B, T, D)
    pos = jnp.broadcast_to(jnp.arange(t, dtype=jnp.int32), (b, t))
    kvshape = (cfg.n_layers, b, cfg.max_seq, cfg.n_kv_heads, cfg.head_dim)
    k_cache = jnp.zeros(kvshape, jnp.float32)
    v_cache = jnp.zeros(kvshape, jnp.float32)
    causal = jnp.where(
        jnp.arange(t)[:, None] >= jnp.arange(t)[None, :], 0.0, -1e9
    ).astype(jnp.float32)[None, None, :, :]
    for li, layer in enumerate(params["layers"]):
        h = _rmsnorm(x, layer["attn_norm"]).reshape(b * t, cfg.dim)
        k_new, v_new = _project_kv(h, layer, cfg, b, t, pos, interpret)
        k_cache = k_cache.at[li, :, :t].set(k_new)
        v_cache = v_cache.at[li, :, :t].set(v_new)
        x = _block(x, layer, cfg, pos, k_new, v_new, causal, interpret)
    x = _rmsnorm(x, params["final_norm"])
    logits = _qlinear(x.reshape(b * t, cfg.dim), params["lm_head"], cfg, cfg.dim, interpret)
    return logits.reshape(b, t, cfg.vocab), k_cache, v_cache


def decode_step(params, token, pos, k_cache, v_cache, cfg: ModelConfig, interpret=True):
    """One autoregressive step with PER-SLOT positions (the continuous-
    batching contract: a decode group may mix sequences at different
    depths).

    token: int32 (B,); pos: int32 (B,) — the cache index each row writes;
    caches: (L, B, max_seq, Hkv, Dh).  Returns (logits (B,V), k_cache,
    v_cache) with row b updated at pos[b].
    """
    b = token.shape[0]
    x = params["tok_emb"][token][:, None, :]  # (B, 1, D)
    pos = pos.astype(jnp.int32)
    pos_bs = pos[:, None]  # (B, 1)
    # row b attends to [0, pos[b]]; future slots masked
    mask = jnp.where(
        jnp.arange(cfg.max_seq)[None, :] <= pos[:, None], 0.0, -1e9
    ).astype(jnp.float32)[:, None, None, :]
    rows = jnp.arange(b)
    for li, layer in enumerate(params["layers"]):
        h = _rmsnorm(x, layer["attn_norm"]).reshape(b, cfg.dim)
        k_new, v_new = _project_kv(h, layer, cfg, b, 1, pos_bs, interpret)
        # scatter row b's new K/V at its own position
        k_cache = k_cache.at[li, rows, pos].set(k_new[:, 0])
        v_cache = v_cache.at[li, rows, pos].set(v_new[:, 0])
        x = _block(x, layer, cfg, pos_bs, k_cache[li], v_cache[li], mask, interpret)
    x = _rmsnorm(x, params["final_norm"])
    logits = _qlinear(x.reshape(b, cfg.dim), params["lm_head"], cfg, cfg.dim, interpret)
    return logits, k_cache, v_cache
