"""Bipolar-INT quantization utilities (L2, build-time).

The paper's bipolar-INT format (Sec. 3.1): an n-bit word x = x_{n-1}..x_0
decodes as

    (x)_D = sum_i (2*x_i - 1) * 2^i

i.e. every bit is +-1 weighted by 2^i.  The representable set is the 2^n
*odd* integers in [-(2^n - 1), 2^n - 1] -- symmetric, zero-point-free, and
every bit plane obeys the same sign rule (no special-cased MSB as in
two's-complement, no zero-point correction as in unsigned quantization).

Encoding: for an odd integer v in range,

    code = (v + (2^n - 1)) / 2          (an unsigned n-bit integer)

and the bit planes of `code` are exactly the x_i above.

This module mirrors rust/src/quant/ bit-for-bit; golden vectors in the
test suites keep the two in sync.
"""

from __future__ import annotations

import functools

import jax.numpy as jnp

__all__ = [
    "bipolar_qmax",
    "quantize_bipolar",
    "dequantize_bipolar",
    "encode_bipolar",
    "decode_bipolar",
    "planes_from_code",
    "pack_planes",
    "pack_along_k",
    "quantize_pack_activations",
]


def bipolar_qmax(bits: int) -> int:
    """Largest magnitude representable by an n-bit bipolar-INT (2^n - 1)."""
    if not 1 <= bits <= 16:
        raise ValueError(f"bits must be in 1..16, got {bits}")
    return (1 << bits) - 1


def quantize_bipolar(x, bits: int, axis=None):
    """Symmetric round-to-nearest-odd quantization onto the bipolar grid.

    Returns (q, scale) with x ~= q * scale, q odd integers in
    [-(2^n-1), 2^n-1].  `axis` selects per-channel scales (reduced over the
    complementary axes); None means per-tensor.
    """
    qmax = bipolar_qmax(bits)
    if axis is None:
        amax = jnp.max(jnp.abs(x))
    else:
        amax = jnp.max(jnp.abs(x), axis=axis, keepdims=True)
    scale = jnp.maximum(amax, 1e-8) / qmax
    t = x / scale
    # nearest odd integer: 2*round((t-1)/2) + 1
    q = 2.0 * jnp.round((t - 1.0) / 2.0) + 1.0
    q = jnp.clip(q, -qmax, qmax)
    return q.astype(jnp.int32), scale


def dequantize_bipolar(q, scale):
    """Inverse of quantize_bipolar (up to rounding)."""
    return q.astype(jnp.float32) * scale


def encode_bipolar(q, bits: int):
    """Odd integer values -> unsigned n-bit codes: code = (v + qmax) >> 1."""
    qmax = bipolar_qmax(bits)
    return ((q + qmax) >> 1).astype(jnp.uint32)


def decode_bipolar(code, bits: int):
    """Unsigned n-bit codes -> odd integer values: v = 2*code - qmax."""
    qmax = bipolar_qmax(bits)
    return (2 * code.astype(jnp.int32)) - qmax


def planes_from_code(code, bits: int):
    """Split codes into bit planes: returns uint32 array (bits, *code.shape)
    with planes[i] = (code >> i) & 1 (LSB first)."""
    shifts = jnp.arange(bits, dtype=jnp.uint32).reshape((bits,) + (1,) * code.ndim)
    return (code[None, ...] >> shifts) & jnp.uint32(1)


@functools.partial(jnp.vectorize, signature="(k)->(p)")
def _pack32(bits_row):
    """Pack a length-K row of {0,1} into K/32 uint32 words, LSB-first lanes."""
    k = bits_row.shape[0]
    words = bits_row.reshape(k // 32, 32).astype(jnp.uint32)
    lanes = jnp.arange(32, dtype=jnp.uint32)
    return jnp.sum(words << lanes, axis=-1, dtype=jnp.uint32)


def pack_planes(planes):
    """Pack bit planes along the last axis into uint32 words.

    planes: uint32 {0,1}, shape (..., K) with K % 32 == 0.
    Returns uint32 shape (..., K//32).  Bit b of word w corresponds to
    column w*32 + b (LSB-first) -- the paper's Sec. 4.1 step-2 reassembly
    into the GPU-native 32-bit unsigned format.
    """
    k = planes.shape[-1]
    if k % 32 != 0:
        raise ValueError(f"K ({k}) must be a multiple of 32")
    return _pack32(planes)


def pack_along_k(code, bits: int):
    """codes (..., K) -> packed planes (bits, ..., K//32), the kernel's
    operand layout (decompose -> reassemble -> concatenate, Sec. 4.1)."""
    return pack_planes(planes_from_code(code, bits))


def quantize_pack_activations(x, bits: int):
    """Dynamic per-row activation quantization + packing.

    x: float (M, K) with K % 32 == 0.  Returns (packed, scale):
    packed uint32 (bits, M, K//32), scale float (M, 1).
    """
    q, scale = quantize_bipolar(x, bits, axis=-1)
    code = encode_bipolar(q, bits)
    return pack_along_k(code, bits), scale
