//! T2: regenerate paper Table 2 (Llama2-7B MatMul latency/speedup).
fn main() {
    apllm::bench::print_table2();
}
