//! MK: REAL measured latencies on this host (not simulated):
//!   * pure-Rust bitmm AP-GEMM across precisions — with **pack time and
//!     compute time measured separately** (the §3.3 pack-once split), vs
//!     the f32 GEMM baseline and the decoded-int naive GEMM;
//!   * PJRT execution of the AOT Pallas artifacts (pjrt feature +
//!     artifacts present).
//!
//! The relative ordering mirrors the paper's core claim at CPU scale:
//! bit-packed XNOR-popcount GEMM beats dense arithmetic at equal logical
//! shape, cost scales with n_w·n_x, and packing is a one-time cost the
//! prepacked ABI keeps off the hot path.

use apllm::bench::bench_fn;
use apllm::bitfmt::IntFormat;
use apllm::bitmm::{
    apmm_bipolar, apmm_bipolar_packed, apmm_bipolar_unfused_packed, gemm_f32, naive_gemm_decoded,
    pack_codes, CodeMatrix,
};
use apllm::model::PrecisionConfig;
use apllm::util::Rng;

fn main() {
    // --smoke: the CI job runs one tiny shape through every section so
    // the perf tables can't rot unbuilt
    let smoke = std::env::args().any(|a| a == "--smoke");
    println!("== measured: CPU bitmm vs dense baselines ==");
    let (m, k, n) = if smoke { (64usize, 512usize, 64usize) } else { (256, 2048, 256) };
    println!("shape {m}x{k}x{n}{}\n", if smoke { " (smoke)" } else { "" });

    let precisions: &[PrecisionConfig] = if smoke {
        &[PrecisionConfig::W1A1, PrecisionConfig::W2A2]
    } else {
        &[
            PrecisionConfig::W1A1,
            PrecisionConfig::W1A2,
            PrecisionConfig::W2A2,
            PrecisionConfig::W3A4,
            PrecisionConfig::W4A4,
            PrecisionConfig::W8A8,
        ]
    };
    // (label, pairs, pack_s, compute_s, total_s)
    let mut rows = Vec::new();
    for &prec in precisions {
        let w = CodeMatrix::random(m, k, prec.nw, 1);
        let xt = CodeMatrix::random(n, k, prec.nx, 2);
        let wp = pack_codes(&w);
        let xp = pack_codes(&xt);
        let label = prec.label();
        let rp = bench_fn(&format!("bitmm {label} pack (both operands)"), 1, 7, || {
            std::hint::black_box(pack_codes(&w));
            std::hint::black_box(pack_codes(&xt));
        });
        let rc = bench_fn(&format!("bitmm {label} compute (prepacked core)"), 1, 7, || {
            std::hint::black_box(apmm_bipolar_packed(&wp, &xp, Default::default()));
        });
        let rt = bench_fn(&format!("bitmm {label} pack+compute (wrapper)"), 1, 7, || {
            std::hint::black_box(apmm_bipolar(&w, &xt, Default::default()));
        });
        rows.push((label, prec.plane_pairs(), rp.median_s, rc.median_s, rt.median_s));
    }

    // unfused (the paper's naive dataflow) at one precision for contrast
    {
        let p = PrecisionConfig::W2A2;
        let w = CodeMatrix::random(m, k, p.nw, 1);
        let xt = CodeMatrix::random(n, k, p.nx, 2);
        let wp = pack_codes(&w);
        let xp = pack_codes(&xt);
        bench_fn("bitmm W2A2 (UNFUSED recovery, prepacked)", 1, 5, || {
            std::hint::black_box(apmm_bipolar_unfused_packed(&wp, &xp));
        });
    }

    // dense baselines at the same logical shape
    {
        let w = CodeMatrix::random(m, k, 4, 3);
        let xt = CodeMatrix::random(n, k, 4, 4);
        bench_fn("naive decoded int GEMM (W4A4 values)", 1, 5, || {
            std::hint::black_box(naive_gemm_decoded(&w, &xt, IntFormat::Bipolar));
        });
        let mut rng = Rng::with_seed(9);
        let a: Vec<f32> = (0..m * k).map(|_| rng.normal()).collect();
        let bt: Vec<f32> = (0..n * k).map(|_| rng.normal()).collect();
        bench_fn("dense f32 GEMM", 1, 5, || {
            std::hint::black_box(gemm_f32(&a, &bt, m, n, k));
        });
    }

    // §3.3 split: pack is a near-constant tax the pack-once ABI pays once;
    // compute scales with plane pairs
    println!("\npack vs compute split (medians):");
    println!(
        "{:<8}{:>7}{:>12}{:>12}{:>12}{:>14}",
        "config", "pairs", "pack ms", "compute ms", "total ms", "pack share"
    );
    for (label, pairs, tp, tc, tt) in &rows {
        println!(
            "{:<8}{:>7}{:>12.2}{:>12.2}{:>12.2}{:>13.1}%",
            label,
            pairs,
            tp * 1e3,
            tc * 1e3,
            tt * 1e3,
            100.0 * tp / tt
        );
    }

    // scaling check: prepacked compute cost should grow ~linearly in
    // plane pairs (packing excluded — it scales with bits, not pairs)
    println!("\nplane-pair scaling of the prepacked core (median vs W1A1):");
    let base = rows[0].3;
    for (_, pairs, _, tc, _) in &rows {
        println!("  {:>2} pairs: {:>8.2} ms  ({:.2}x base)", pairs, tc * 1e3, tc / base);
    }

    pjrt_section();
}

#[cfg(feature = "pjrt")]
fn pjrt_section() {
    use apllm::bitmm::{pack_codes_u32, transpose_codes};
    let dir = std::path::PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("artifacts");
    if dir.join("manifest.json").exists() {
        println!("\n== measured: PJRT Pallas artifacts (interpret-mode HLO on CPU) ==");
        let engine = apllm::runtime::Engine::load(&dir).expect("engine");
        for spec in engine.manifest().by_kind("apmm") {
            let (am, ak, an) = (
                spec.meta_usize("m").unwrap(),
                spec.meta_usize("k").unwrap(),
                spec.meta_usize("n").unwrap(),
            );
            let (nw, nx) =
                (spec.meta_usize("nw").unwrap() as u32, spec.meta_usize("nx").unwrap() as u32);
            let w = CodeMatrix::random(am, ak, nw, 5);
            let x = CodeMatrix::random(ak, an, nx, 6);
            let wp = pack_codes_u32(&w);
            let xp = pack_codes_u32(&transpose_codes(&x));
            let spec = spec.clone();
            bench_fn(&format!("pjrt {}", spec.name), 1, 5, || {
                std::hint::black_box(engine.run_apmm(&spec, &wp, &xp).unwrap());
            });
        }
    } else {
        println!("\n(skipping PJRT section: run `make artifacts`)");
    }
}

#[cfg(not(feature = "pjrt"))]
fn pjrt_section() {
    println!("\n(skipping PJRT section: built without the pjrt feature)");
}
