//! F7: regenerate paper Fig. 7 (end-to-end inference speedup vs FP16).
fn main() {
    apllm::bench::print_fig7();
}
