//! AB2: §4.1/§4.2 memory-scheduling knob ablation.
fn main() {
    apllm::bench::print_ablation_sched();
}
