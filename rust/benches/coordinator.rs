//! L3 coordinator bench: engine throughput and batcher overhead under
//! synthetic load (SimBackend — isolates coordination cost from compute).
//! The engine runs under `AdmissionPolicy::Reserve` — the retired group
//! scheduler's full-budget admission — so the series stays comparable
//! with the pre-fold numbers.

use apllm::bench::bench_fn;
use apllm::coordinator::{
    AdmissionPolicy, Backend, Batcher, BatcherConfig, Engine, EngineConfig, GenParams, Request,
    SimBackend,
};
use std::time::{Duration, Instant};

fn engine_run(n_requests: usize, max_running: usize, step_latency: Duration) -> f64 {
    let mut backend = SimBackend::new(1024, 128, vec![1, 2, 4, 8]);
    backend.step_latency = step_latency;
    let mut e = Engine::new(
        backend,
        EngineConfig {
            kv_blocks: 256,
            block_tokens: 16,
            max_running,
            admission: AdmissionPolicy::Reserve,
            ..EngineConfig::default()
        },
    );
    for i in 0..n_requests {
        e.submit(Request::new(
            i as u64,
            vec![1, 2, 3, 4, 5, 6, 7, 8],
            GenParams { max_new_tokens: 16, sample: false, seed: i as u64 },
        ));
    }
    let out = e.run_to_completion().unwrap();
    assert_eq!(out.len(), n_requests);
    e.metrics.throughput_tok_s()
}

fn main() {
    println!("== coordinator: engine overhead (SimBackend, zero device latency) ==");
    for max_running in [1usize, 2, 4, 8] {
        let label = format!("engine (reserve admission) 64 reqs, max_running={max_running}");
        bench_fn(&label, 1, 5, || {
            std::hint::black_box(engine_run(64, max_running, Duration::ZERO));
        });
    }

    println!("\n== coordinator: batching payoff with 1ms simulated step latency ==");
    for max_running in [1usize, 4, 8] {
        let tput = engine_run(32, max_running, Duration::from_millis(1));
        println!("  max_running={max_running}: {tput:.0} tok/s");
    }

    println!("\n== coordinator: pack-once AP-GEMM backend (real bitmm logits) ==");
    {
        let run = |workers: usize| {
            let mut backend = SimBackend::with_ap_gemm(256, 128, vec![1, 2, 4, 8], 256, 2, 2, 7);
            backend.set_workers(workers);
            let mut e = Engine::new(
                backend,
                EngineConfig {
                    kv_blocks: 256,
                    block_tokens: 16,
                    max_running: 8,
                    admission: AdmissionPolicy::Reserve,
                    ..EngineConfig::default()
                },
            );
            for i in 0..32usize {
                e.submit(Request::new(
                    i as u64,
                    vec![1, 2, 3, 4, 5, 6, 7, 8],
                    GenParams { max_new_tokens: 16, sample: false, seed: i as u64 },
                ));
            }
            let out = e.run_to_completion().unwrap();
            assert_eq!(out.len(), 32);
            e
        };
        for workers in [1usize, 2] {
            let label =
                format!("engine (reserve admission) 32 reqs over prepacked W2A2 lm-head, {workers}w");
            bench_fn(&label, 1, 5, || {
                std::hint::black_box(run(workers));
            });
        }
        let e = run(1);
        let stats = e.backend().ap_stats().unwrap();
        println!(
            "  tok/s {:.0}; weight packs {} (packed once, {} bytes resident), act packs {}, arena allocs {}, reuses {}",
            e.metrics.throughput_tok_s(),
            stats.weight_packs,
            e.backend().packed_weight_bytes(),
            stats.act_packs,
            stats.arena_allocs,
            stats.arena_reuses
        );
    }

    println!("\n== batcher: admission cost ==");
    bench_fn("batcher push+poll 10k requests", 1, 5, || {
        let mut b = Batcher::new(BatcherConfig::default());
        let now = Instant::now();
        let mut out = 0usize;
        for i in 0..10_000u64 {
            b.push(Request::new(i, vec![1], GenParams::default()));
            if let Some(g) = b.poll(now + Duration::from_millis(i)) {
                out += g.len();
            }
        }
        while let Some(g) = b.poll(now + Duration::from_secs(3600)) {
            out += g.len();
        }
        assert_eq!(out, 10_000);
    });
}
