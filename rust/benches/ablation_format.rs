//! AB1: bipolar vs signed vs unsigned decomposition formats.
fn main() {
    apllm::bench::print_ablation_format();
}
