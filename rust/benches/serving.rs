//! Steady-state serving bench: Poisson arrivals replayed in wall-clock
//! time through the continuous-batching engine over the pack-once AP-GEMM
//! backend (real prepacked bitmm logits).  Three sections:
//!
//! 1. rate × throughput/latency table (TTFT/ITL percentiles come from the
//!    streamed per-token events);
//! 2. **prefix-sharing workload** — Poisson arrivals over a small set of
//!    shared system prompts, run with the hash-based prefix cache on and
//!    off, reporting the KV blocks sharing saved;
//! 3. (`--cluster`) a multi-replica cluster behind `Router::LeastLoaded`
//!    on the shared-prefix trace, with per-replica load/KV breakdown.
//!
//! `cargo bench --bench serving` for the full table; pass `--smoke` for
//! the one-row CI job (and `--smoke --cluster` for the cluster smoke)
//! that keeps these paths building and running.

use apllm::coordinator::trace::{generate, TraceConfig};
use apllm::coordinator::{
    replay_trace, responses_of, ArrivalKind, BatcherConfig, Cluster, Engine, EngineConfig,
    KvSharing, RoutePolicy, SimBackend, Stepper, TokenEvent,
};
use apllm::model::PrecisionConfig;
use std::time::Duration;

fn ap_backend() -> SimBackend {
    SimBackend::with_ap_gemm(256, 512, vec![1, 2, 4, 8], 128, 2, 2, 7)
}

fn engine_cfg(prefix_sharing: bool) -> EngineConfig {
    EngineConfig {
        kv_blocks: 96,
        block_tokens: 8,
        max_running: 8,
        batcher: BatcherConfig { batch_sizes: vec![1, 2, 4, 8], max_wait: Duration::ZERO },
        prefix_sharing,
    }
}

fn shared_prefix_trace(rate: f64, requests: usize) -> Vec<apllm::coordinator::trace::TimedRequest> {
    generate(&TraceConfig {
        kind: ArrivalKind::Poisson { rate },
        requests,
        prompt_len: (2, 8), // tail after the shared prefix
        max_new: (4, 12),
        vocab: 256,
        seed: 7,
        shared_prefixes: 4, // a small pool of "system prompts"
        prefix_len: 24,
    })
}

fn kv_line(s: &KvSharing) -> String {
    format!(
        "fresh {:>5} | shared {:>5} | restored {:>5} | cow {:>3} | peak used {:>4}",
        s.fresh_allocs, s.shared_live, s.cache_restores, s.cow_copies, s.peak_used
    )
}

fn steady_state(rates: &[f64], requests: usize) {
    println!("== serving: continuous-batching engine, Poisson arrivals, prepacked W2A2 lm-head ==");
    println!(
        "{:>8} {:>6} {:>9} {:>6} {:>9} {:>14} {:>14} {:>14} {:>14}",
        "rate/s",
        "done",
        "tok/s",
        "occ",
        "preempt",
        "queue p50/p95",
        "ttft p50/p95",
        "itl p50/p95",
        "total p50/p95"
    );
    for &rate in rates {
        let mut eng = Engine::new(ap_backend(), engine_cfg(true));
        let trace = generate(&TraceConfig {
            kind: ArrivalKind::Poisson { rate },
            requests,
            prompt_len: (4, 16),
            max_new: (4, 12),
            vocab: 256,
            seed: 7,
            ..TraceConfig::default()
        });
        let events = replay_trace(&mut eng, &trace).expect("replay");
        let out = responses_of(&events);
        assert_eq!(out.len() as u64, eng.counters().completed + eng.counters().rejected);
        assert_eq!(
            eng.pool().free_blocks(),
            eng.pool().total_blocks(),
            "steady-state run must not leak KV blocks"
        );
        let n_tok = events.iter().filter(|e| matches!(e, TokenEvent::Token { .. })).count();
        assert_eq!(n_tok as u64, eng.metrics.tokens_generated, "every token streamed");
        let m = &eng.metrics;
        let ms = |v: f64| v * 1e3;
        println!(
            "{:>8.0} {:>6} {:>9.0} {:>6.2} {:>9} {:>7.1}/{:<6.1} {:>7.1}/{:<6.1} {:>7.1}/{:<6.1} {:>7.1}/{:<6.1}",
            rate,
            m.requests_done,
            m.throughput_tok_s(),
            m.mean_occupancy(),
            m.preemptions,
            ms(m.queue.percentile(50.0)),
            ms(m.queue.percentile(95.0)),
            ms(m.ttft.percentile(50.0)),
            ms(m.ttft.percentile(95.0)),
            ms(m.itl.percentile(50.0)),
            ms(m.itl.percentile(95.0)),
            ms(m.total.percentile(50.0)),
            ms(m.total.percentile(95.0)),
        );
        let s = eng.backend().ap_stats().expect("ap backend");
        assert_eq!(s.weight_packs, 1, "weights must be packed once per run");
    }
    println!("(latencies in ms; occupancy = mean decode batch size; weights packed once per run)");
}

fn prefix_sharing(rate: f64, requests: usize) {
    println!("\n== serving: shared-prefix workload (4 system prompts × 24 tokens), rate {rate}/s ==");
    let mut saved = [0u64; 2];
    for (slot, sharing) in [(0usize, true), (1usize, false)] {
        let mut eng = Engine::new(ap_backend(), engine_cfg(sharing));
        let trace = shared_prefix_trace(rate, requests);
        let events = replay_trace(&mut eng, &trace).expect("replay");
        let out = responses_of(&events);
        assert_eq!(out.len(), requests);
        assert_eq!(eng.pool().free_blocks(), eng.pool().total_blocks(), "no leaked blocks");
        eng.pool().check_invariants().expect("pool invariants after drain");
        let s = eng.pool().sharing();
        saved[slot] = s.fresh_allocs;
        let m = &eng.metrics;
        let ms = |v: f64| v * 1e3;
        println!(
            "  prefix cache {:>3}: {} | ttft p50/p95 {:>6.1}/{:<6.1} ms | itl p50/p95 {:>5.1}/{:<5.1} ms",
            if sharing { "on" } else { "off" },
            kv_line(&s),
            ms(m.ttft.percentile(50.0)),
            ms(m.ttft.percentile(95.0)),
            ms(m.itl.percentile(50.0)),
            ms(m.itl.percentile(95.0)),
        );
    }
    let (with, without) = (saved[0], saved[1]);
    println!(
        "  KV blocks saved by sharing: {} of {} ({:.0}%)",
        without.saturating_sub(with),
        without,
        100.0 * without.saturating_sub(with) as f64 / without.max(1) as f64
    );
}

fn cluster(rate: f64, requests: usize, replicas: usize) {
    println!(
        "\n== serving: {replicas}-replica cluster (LeastLoaded router), shared-prefix trace, rate {rate}/s =="
    );
    let mut c = Cluster::new(RoutePolicy::LeastLoaded);
    for i in 0..replicas {
        c.add_replica(format!("r{i}"), PrecisionConfig::W2A2, ap_backend(), engine_cfg(true));
    }
    let trace = shared_prefix_trace(rate, requests);
    let events = replay_trace(&mut c, &trace).expect("replay");
    let out = responses_of(&events);
    assert_eq!(out.len(), requests);
    assert_eq!(c.router().inflight(), 0, "router load accounting drained");
    c.check_invariants().expect("cluster invariants after drain");
    let m = c.metrics();
    let ms = |v: f64| v * 1e3;
    println!(
        "  merged: {} done | {:.0} tok/s | ttft p50/p95 {:.1}/{:.1} ms | itl p50/p95 {:.1}/{:.1} ms",
        m.requests_done,
        m.throughput_tok_s(),
        ms(m.ttft.percentile(50.0)),
        ms(m.ttft.percentile(95.0)),
        ms(m.itl.percentile(50.0)),
        ms(m.itl.percentile(95.0)),
    );
    for (eng, rep) in c.engines().iter().zip(c.router().replicas()) {
        println!(
            "  {} ({}): completed {:>4} | {}",
            rep.name,
            rep.precision.label(),
            eng.counters().completed,
            kv_line(&eng.pool().sharing()),
        );
        assert_eq!(eng.pool().free_blocks(), eng.pool().total_blocks(), "replica leaked blocks");
    }
}

fn main() {
    let args: Vec<String> = std::env::args().collect();
    let smoke = args.iter().any(|a| a == "--smoke");
    let cluster_mode = args.iter().any(|a| a == "--cluster");

    if cluster_mode {
        let (rate, requests, replicas) = if smoke { (400.0, 12, 2) } else { (200.0, 64, 3) };
        cluster(rate, requests, replicas);
        return;
    }
    let (rates, requests): (&[f64], usize) =
        if smoke { (&[400.0], 8) } else { (&[50.0, 200.0, 800.0], 48) };
    steady_state(rates, requests);
    let (pr_rate, pr_requests) = if smoke { (400.0, 12) } else { (200.0, 64) };
    prefix_sharing(pr_rate, pr_requests);
}
