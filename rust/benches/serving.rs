//! Steady-state serving bench: Poisson arrivals replayed in wall-clock
//! time through the continuous-batching engine over the pack-once AP-GEMM
//! backend (real prepacked bitmm logits).  Prints a rate × throughput /
//! latency table — the serving-layer counterpart of the kernel benches.
//!
//! `cargo bench --bench serving` for the full table; pass `--smoke` for
//! the one-row CI job that keeps this target building and running.

use apllm::coordinator::trace::{generate, TraceConfig};
use apllm::coordinator::{
    replay_trace, ArrivalKind, BatcherConfig, Engine, EngineConfig, SimBackend,
};
use std::time::Duration;

fn main() {
    let smoke = std::env::args().any(|a| a == "--smoke");
    let (rates, requests): (&[f64], usize) =
        if smoke { (&[400.0], 8) } else { (&[50.0, 200.0, 800.0], 48) };

    println!("== serving: continuous-batching engine, Poisson arrivals, prepacked W2A2 lm-head ==");
    println!(
        "{:>8} {:>6} {:>9} {:>6} {:>9} {:>14} {:>14} {:>14}",
        "rate/s", "done", "tok/s", "occ", "preempt", "queue p50/p95", "ttft p50/p95", "total p50/p95"
    );
    for &rate in rates {
        let backend = SimBackend::with_ap_gemm(256, 512, vec![1, 2, 4, 8], 128, 2, 2, 7);
        let mut eng = Engine::new(
            backend,
            EngineConfig {
                kv_blocks: 96,
                block_tokens: 8,
                max_running: 8,
                batcher: BatcherConfig {
                    batch_sizes: vec![1, 2, 4, 8],
                    max_wait: Duration::ZERO,
                },
            },
        );
        let trace = generate(&TraceConfig {
            kind: ArrivalKind::Poisson { rate },
            requests,
            prompt_len: (4, 16),
            max_new: (4, 12),
            vocab: 256,
            seed: 7,
        });
        let out = replay_trace(&mut eng, &trace).expect("replay");
        assert_eq!(out.len() as u64, eng.counters().completed);
        assert_eq!(
            eng.pool().free_blocks(),
            eng.pool().total_blocks(),
            "steady-state run must not leak KV blocks"
        );
        let m = &eng.metrics;
        let ms = |v: f64| v * 1e3;
        println!(
            "{:>8.0} {:>6} {:>9.0} {:>6.2} {:>9} {:>7.1}/{:<6.1} {:>7.1}/{:<6.1} {:>7.1}/{:<6.1}",
            rate,
            m.requests_done,
            m.throughput_tok_s(),
            m.mean_occupancy(),
            m.preemptions,
            ms(m.queue.percentile(50.0)),
            ms(m.queue.percentile(95.0)),
            ms(m.ttft.percentile(50.0)),
            ms(m.ttft.percentile(95.0)),
            ms(m.total.percentile(50.0)),
            ms(m.total.percentile(95.0)),
        );
        let s = eng.backend().ap_stats().expect("ap backend");
        assert_eq!(s.weight_packs, 1, "weights must be packed once per run");
    }
    println!("(latencies in ms; occupancy = mean decode batch size; weights packed once per run)");
}
