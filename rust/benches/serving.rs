//! Steady-state serving bench: Poisson arrivals replayed in wall-clock
//! time through the continuous-batching engine over the pack-once AP-GEMM
//! backend (real prepacked bitmm logits).  Sections:
//!
//! 1. rate × throughput/latency table (TTFT/ITL percentiles come from the
//!    streamed per-token events);
//! 2. **prefix-sharing workload** — skewed Poisson arrivals over a small
//!    set of shared system prompts, run with the hash-based prefix cache
//!    under **LRU eviction, the LIFO baseline, and sharing off**,
//!    reporting the KV blocks sharing saved and the cache hit/restore
//!    rates each eviction policy sustains;
//! 3. **mixed-precision cluster over ONE superset store** — a W4A4 and a
//!    W2A2 replica slicing the same 4-bit `PackedWeightStore` (the
//!    any-precision memory model), reporting the weight bytes saved vs
//!    dedicated per-precision stores plus the cross-precision
//!    migration/re-prefill counters;
//! 4. (`--cluster`) a multi-replica cluster behind `Router::LeastLoaded`
//!    on the shared-prefix trace — one deliberately undersized "hot"
//!    replica so preemptive rebalancing is visible — with per-replica
//!    load/KV/migration breakdown.
//!
//! 5. **thread scaling** — the intra-replica GEMM sharding tentpole: a
//!    prepacked W4A4 GEMM microbench across every shard policy
//!    (rows/cols/planes) × worker count (1/2/4), each run asserted
//!    bit-identical to the serial kernel, plus end-to-end engine
//!    tokens/s at 1/2/4 workers over one trace with the token streams
//!    asserted byte-identical across worker counts.
//!
//! 6. **self-speculative decoding** — a decode-heavy trace through a W4
//!    engine drafting `--spec-k` tokens per step from the `--draft-bits`
//!    MSB plane prefix of the same pack and verifying them in one batched
//!    wide decode; streams asserted byte-identical to the spec_k=0 run,
//!    reporting accept rate, accepted-length histogram, mean tokens per
//!    decode step, and wall-clock tok/s against the plain baseline.
//!
//! 7. **disaggregated prefill/decode** — a role-split cluster (`--roles
//!    p,d` by default: one prefill replica handing every freshly
//!    prefilled sequence to a decode replica) vs an all-Mixed cluster of
//!    the same size on a bursty prefill-heavy trace, streams asserted
//!    byte-identical to the mixed oracle, reporting per-role TTFT/ITL
//!    against the mixed baseline plus the handoff counters.
//!
//! 8. **admission policy** — Reserve (the retired group scheduler's
//!    full-budget admission, now `EngineConfig::admission`) vs Optimistic
//!    on one overloaded Poisson backlog over a tight KV pool: admitted
//!    count inside a fixed probe window, preemption/resume counters, and
//!    drain goodput, with Reserve asserted preemption-free and Optimistic
//!    asserted to admit at least as much.
//!
//! `cargo bench --bench serving` for the full table; pass `--smoke` for
//! the one-row CI job (and `--smoke --cluster` for the cluster smoke)
//! that keeps these paths building and running.  `--json <path>` emits
//! the machine-readable `BENCH_serving.json` artifact CI uploads; the
//! writer sanity-checks every recorded number (finite, and non-zero
//! where zero would mean "the bench measured nothing") and panics on
//! violations so a rotten run fails the job instead of shipping NaNs.

use apllm::bitmm::{apmm_bipolar_packed_into, pack_codes, ApmmOpts, CodeMatrix, ShardPolicy};
use apllm::coordinator::trace::{generate, TimedRequest, TraceConfig};
use apllm::coordinator::{
    replay_trace, responses_of, superset_store, AdmissionPolicy, ArrivalKind, BatcherConfig,
    Cluster, ClusterSpec, Engine, EngineConfig, EvictionPolicy, KvPool, KvSharing, ReplicaRole,
    ReplicaSpec, RoutePolicy, SimBackend, Stepper, TokenEvent,
};
use apllm::model::PrecisionConfig;
use apllm::util::json::Json;
use std::collections::BTreeMap;
use std::time::{Duration, Instant};

fn ap_backend() -> SimBackend {
    SimBackend::with_ap_gemm(256, 512, vec![1, 2, 4, 8], 128, 2, 2, 7)
}

fn engine_cfg(prefix_sharing: bool, eviction: EvictionPolicy, kv_blocks: usize) -> EngineConfig {
    EngineConfig {
        kv_blocks,
        block_tokens: 8,
        max_running: 8,
        batcher: BatcherConfig { batch_sizes: vec![1, 2, 4, 8], max_wait: Duration::ZERO },
        prefix_sharing,
        eviction,
        workers: 0,
        spec_k: 0,
        draft_bits: 0,
        prefill_hold: false, // Cluster::new flips this on for prefill roles
        admission: AdmissionPolicy::Optimistic,
    }
}

fn shared_prefix_trace(rate: f64, requests: usize) -> Vec<TimedRequest> {
    generate(&TraceConfig {
        kind: ArrivalKind::Poisson { rate },
        requests,
        prompt_len: (2, 8), // tail after the shared prefix
        max_new: (4, 12),
        vocab: 256,
        seed: 7,
        shared_prefixes: 4, // a small pool of "system prompts"
        prefix_len: 24,
        prefix_skew: 0.35, // hot-system-prompt popularity
    })
}

fn kv_line(s: &KvSharing) -> String {
    format!(
        "fresh {:>5} | shared {:>5} | restored {:>5} | cow {:>3} | evicted {:>4} | peak used {:>4}",
        s.fresh_allocs, s.shared_live, s.cache_restores, s.cow_copies, s.evictions, s.peak_used
    )
}

// ------------------------------------------------------ JSON artifact --

/// Finite-checked number: the artifact must never contain NaN/inf.
fn num(label: &str, v: f64) -> Json {
    assert!(v.is_finite(), "bench sanity: {label} is not finite ({v})");
    Json::Num(v)
}

/// Finite AND strictly positive — for numbers where zero means the bench
/// measured nothing (throughput, completions).
fn pos(label: &str, v: f64) -> Json {
    assert!(v > 0.0, "bench sanity: {label} must be > 0, got {v}");
    num(label, v)
}

fn obj(fields: Vec<(&str, Json)>) -> Json {
    Json::Obj(fields.into_iter().map(|(k, v)| (k.to_string(), v)).collect())
}

fn sharing_json(s: &KvSharing) -> Json {
    obj(vec![
        ("fresh_allocs", num("fresh_allocs", s.fresh_allocs as f64)),
        ("shared_live", num("shared_live", s.shared_live as f64)),
        ("cache_restores", num("cache_restores", s.cache_restores as f64)),
        ("cow_copies", num("cow_copies", s.cow_copies as f64)),
        ("evictions", num("evictions", s.evictions as f64)),
        ("hit_rate", num("hit_rate", s.hit_rate())),
        ("restore_rate", num("restore_rate", s.restore_rate())),
    ])
}

/// Deterministic eviction-policy probe — no wall clock, no engine: two
/// 9-token prompts alternating through a tight 6-block pool (the same
/// workload the kv unit test pins down).  Under LRU every warm re-admit
/// restores its prefix blocks; under LIFO the tail allocations pop
/// exactly the blocks the previous request just registered, so its
/// cache never survives.  The bench asserts LRU out-restores LIFO and
/// ships both rates in the artifact, so CI gates the LRU property
/// itself rather than a timing-dependent replay.
fn policy_probe() -> Json {
    let run = |policy: EvictionPolicy| {
        let mut p = KvPool::with_policy(6, 4, policy);
        let pa: Vec<i32> = (0..9).collect();
        let pb: Vec<i32> = (100..109).collect();
        for i in 0..10u64 {
            let pr = if i % 2 == 0 { &pa } else { &pb };
            p.admit_shared(i, pr).expect("probe admit");
            p.release(i).expect("probe release");
        }
        p.sharing()
    };
    let lru = run(EvictionPolicy::Lru);
    let lifo = run(EvictionPolicy::Lifo);
    println!(
        "  eviction probe (deterministic): LRU restore rate {:.0}% vs LIFO {:.0}%",
        100.0 * lru.restore_rate(),
        100.0 * lifo.restore_rate()
    );
    assert!(
        lru.restore_rate() > lifo.restore_rate(),
        "LRU must out-restore the LIFO baseline (lru {:.2} vs lifo {:.2})",
        lru.restore_rate(),
        lifo.restore_rate()
    );
    obj(vec![
        ("lru_restores", num("lru_restores", lru.cache_restores as f64)),
        ("lifo_restores", num("lifo_restores", lifo.cache_restores as f64)),
        ("lru_restore_rate", pos("lru_restore_rate", lru.restore_rate())),
        ("lifo_restore_rate", num("lifo_restore_rate", lifo.restore_rate())),
    ])
}

// ----------------------------------------------------------- sections --

fn steady_state(rates: &[f64], requests: usize) -> Json {
    println!("== serving: continuous-batching engine, Poisson arrivals, prepacked W2A2 lm-head ==");
    println!(
        "{:>8} {:>6} {:>9} {:>6} {:>9} {:>14} {:>14} {:>14} {:>14}",
        "rate/s",
        "done",
        "tok/s",
        "occ",
        "preempt",
        "queue p50/p95",
        "ttft p50/p95",
        "itl p50/p95",
        "total p50/p95"
    );
    let mut rows = Vec::new();
    for &rate in rates {
        let mut eng = Engine::new(ap_backend(), engine_cfg(true, EvictionPolicy::Lru, 96));
        let trace = generate(&TraceConfig {
            kind: ArrivalKind::Poisson { rate },
            requests,
            prompt_len: (4, 16),
            max_new: (4, 12),
            vocab: 256,
            seed: 7,
            ..TraceConfig::default()
        });
        let events = replay_trace(&mut eng, &trace).expect("replay");
        let out = responses_of(&events);
        assert_eq!(out.len() as u64, eng.counters().completed + eng.counters().rejected);
        assert_eq!(
            eng.pool().free_blocks(),
            eng.pool().total_blocks(),
            "steady-state run must not leak KV blocks"
        );
        let n_tok = events.iter().filter(|e| matches!(e, TokenEvent::Token { .. })).count();
        assert_eq!(n_tok as u64, eng.metrics.tokens_generated, "every token streamed");
        let m = &eng.metrics;
        let ms = |v: f64| v * 1e3;
        println!(
            "{:>8.0} {:>6} {:>9.0} {:>6.2} {:>9} {:>7.1}/{:<6.1} {:>7.1}/{:<6.1} {:>7.1}/{:<6.1} {:>7.1}/{:<6.1}",
            rate,
            m.requests_done,
            m.throughput_tok_s(),
            m.mean_occupancy(),
            m.preemptions,
            ms(m.queue.percentile(50.0)),
            ms(m.queue.percentile(95.0)),
            ms(m.ttft.percentile(50.0)),
            ms(m.ttft.percentile(95.0)),
            ms(m.itl.percentile(50.0)),
            ms(m.itl.percentile(95.0)),
            ms(m.total.percentile(50.0)),
            ms(m.total.percentile(95.0)),
        );
        let s = eng.backend().ap_stats().expect("ap backend");
        assert_eq!(s.weight_packs, 1, "weights must be packed once per run");
        rows.push(obj(vec![
            ("rate", num("rate", rate)),
            ("done", pos("done", m.requests_done as f64)),
            ("tok_s", pos("tok_s", m.throughput_tok_s())),
            ("occupancy", pos("occupancy", m.mean_occupancy())),
            ("preemptions", num("preemptions", m.preemptions as f64)),
            ("ttft_p50_ms", num("ttft_p50_ms", ms(m.ttft.percentile(50.0)))),
            ("ttft_p95_ms", num("ttft_p95_ms", ms(m.ttft.percentile(95.0)))),
            ("itl_p50_ms", num("itl_p50_ms", ms(m.itl.percentile(50.0)))),
            ("itl_p95_ms", num("itl_p95_ms", ms(m.itl.percentile(95.0)))),
        ]));
    }
    println!("(latencies in ms; occupancy = mean decode batch size; weights packed once per run)");
    Json::Arr(rows)
}

fn prefix_sharing(rate: f64, requests: usize) -> Json {
    println!(
        "\n== serving: shared-prefix workload (4 system prompts × 24 tokens, skewed), rate {rate}/s =="
    );
    // a pool tight enough that eviction policy matters: the prefix
    // working set survives under LRU but not under the LIFO baseline
    let kv_blocks = 28;
    let variants: [(&str, bool, EvictionPolicy); 3] = [
        ("lru", true, EvictionPolicy::Lru),
        ("lifo", true, EvictionPolicy::Lifo),
        ("off", false, EvictionPolicy::Lru),
    ];
    let mut fresh = BTreeMap::new();
    let mut policies = BTreeMap::new();
    for (label, sharing, eviction) in variants {
        let mut eng = Engine::new(ap_backend(), engine_cfg(sharing, eviction, kv_blocks));
        let trace = shared_prefix_trace(rate, requests);
        let events = replay_trace(&mut eng, &trace).expect("replay");
        let out = responses_of(&events);
        assert_eq!(out.len(), requests);
        assert_eq!(eng.pool().free_blocks(), eng.pool().total_blocks(), "no leaked blocks");
        eng.pool().check_invariants().expect("pool invariants after drain");
        let s = eng.pool().sharing();
        fresh.insert(label, s.fresh_allocs);
        policies.insert(label.to_string(), sharing_json(&s));
        let m = &eng.metrics;
        let ms = |v: f64| v * 1e3;
        println!(
            "  {label:>4}: {} | hit {:>3.0}% restore {:>3.0}% | ttft p50/p95 {:>6.1}/{:<6.1} ms | itl p50/p95 {:>5.1}/{:<5.1} ms",
            kv_line(&s),
            100.0 * s.hit_rate(),
            100.0 * s.restore_rate(),
            ms(m.ttft.percentile(50.0)),
            ms(m.ttft.percentile(95.0)),
            ms(m.itl.percentile(50.0)),
            ms(m.itl.percentile(95.0)),
        );
    }
    let (with, without) = (fresh["lru"], fresh["off"]);
    println!(
        "  KV blocks saved by sharing (LRU vs off): {} of {} ({:.0}%)",
        without.saturating_sub(with),
        without,
        100.0 * without.saturating_sub(with) as f64 / without.max(1) as f64
    );
    obj(vec![
        ("rate", num("rate", rate)),
        ("requests", pos("requests", requests as f64)),
        ("kv_blocks", num("kv_blocks", kv_blocks as f64)),
        ("policies", Json::Obj(policies)),
        ("policy_probe", policy_probe()),
        ("baseline_fresh", pos("baseline_fresh", without as f64)),
        ("blocks_saved", num("blocks_saved", without.saturating_sub(with) as f64)),
    ])
}

/// Mixed-precision cluster over **one** superset weight store: a W4A4
/// "hot" replica (undersized pool, so sequences swap out and — with no
/// same-precision peer — requantize) and a W2A2 "cold" replica, both
/// slicing the same 4-bit pack.  Reports the §4.1-at-deployment-scale
/// number: weight bytes the one-store design saves over dedicated
/// per-precision stores (deterministic, so CI gates on it), plus the
/// cross-precision migration counters from the trace replay.
fn mixed_precision(rate: f64, requests: usize) -> Json {
    println!(
        "\n== serving: mixed-precision cluster (W4A4 hot + W2A2 cold) over ONE 4-bit superset \
         store, rate {rate}/s =="
    );
    let store = superset_store(256, 128, 4, 7);
    let superset_bytes = store.packed_bytes();
    // dedicated per-precision stores would hold one pack per precision
    let per_precision_bytes = store.packed_bytes_at(4) + store.packed_bytes_at(2);
    let saved = per_precision_bytes - superset_bytes;
    println!(
        "  weight bytes: superset {superset_bytes} vs per-precision stores \
         {per_precision_bytes} → saved {saved} ({:.0}%)",
        100.0 * saved as f64 / per_precision_bytes as f64
    );

    let mut spec = ClusterSpec::new(RoutePolicy::LeastLoaded);
    for (i, (p, kv_blocks)) in
        [(PrecisionConfig::W4A4, 24usize), (PrecisionConfig::W2A2, 96)].iter().enumerate()
    {
        spec = spec.replica(
            ReplicaSpec::new(format!("r{i}-{}", p.label()), *p)
                .engine(engine_cfg(true, EvictionPolicy::Lru, *kv_blocks)),
        );
    }
    let mut c = Cluster::new(spec, |r| {
        SimBackend::with_shared_store(
            512,
            vec![1, 2, 4, 8],
            store.clone(),
            r.precision.nw,
            r.precision.nx,
        )
    });
    let trace = shared_prefix_trace(rate, requests);
    let events = replay_trace(&mut c, &trace).expect("replay");
    let out = responses_of(&events);
    assert_eq!(out.len(), requests);
    c.check_invariants().expect("cluster invariants after drain");
    let mut reprefills = 0u64;
    for eng in c.engines() {
        assert_eq!(
            eng.backend().packed_weight_bytes(),
            superset_bytes,
            "every replica must serve the one superset pack"
        );
        assert_eq!(
            eng.backend().ap_stats().expect("ap backend").weight_packs,
            0,
            "weights packed once, outside the replicas"
        );
        assert_eq!(eng.pool().free_blocks(), eng.pool().total_blocks(), "replica leaked blocks");
        reprefills += eng.counters().reprefills;
    }
    let m = c.metrics();
    println!(
        "  {} done | {:.0} tok/s | {} migrations ({} requantized, {} re-prefills)",
        m.requests_done,
        m.throughput_tok_s(),
        c.migrations(),
        c.requants(),
        reprefills,
    );
    obj(vec![
        ("rate", num("rate", rate)),
        ("requests", pos("requests", requests as f64)),
        ("weight_bytes_superset", pos("weight_bytes_superset", superset_bytes as f64)),
        (
            "weight_bytes_per_precision",
            pos("weight_bytes_per_precision", per_precision_bytes as f64),
        ),
        ("weight_bytes_saved", pos("weight_bytes_saved", saved as f64)),
        ("done", pos("done", m.requests_done as f64)),
        ("tok_s", pos("tok_s", m.throughput_tok_s())),
        ("migrations", num("migrations", c.migrations() as f64)),
        ("requants", num("requants", c.requants() as f64)),
        ("reprefills", num("reprefills", reprefills as f64)),
    ])
}

/// Intra-replica GEMM sharding scaling: microbench every shard policy ×
/// worker count on one prepacked W4A4 GEMM (decode-shaped: large M = the
/// vocab, small N = the batch), asserting each run bit-identical to the
/// serial kernel, then the same worker sweep end-to-end through the
/// engine with the token streams asserted byte-identical.  The first
/// table pass warms every pool, so the timed `speedup_2w` ratio CI gates
/// on measures steady-state dispatch, not thread spawn.
fn thread_scaling(smoke: bool) -> Json {
    let (m, k, n, iters) = if smoke { (512, 512, 32, 3) } else { (1024, 1024, 64, 5) };
    println!("\n== serving: thread scaling (worker pool, {m}x{k}x{n} W4A4 GEMM shards) ==");
    let wp = pack_codes(&CodeMatrix::random(m, k, 4, 11));
    let xp = pack_codes(&CodeMatrix::random(n, k, 4, 13));
    let serial_opts = ApmmOpts { shard: ShardPolicy::Serial, ..ApmmOpts::default() };
    let mut serial = vec![0i32; m * n];
    apmm_bipolar_packed_into(&wp, &xp, serial_opts, &mut serial);

    let policies =
        [("rows", ShardPolicy::Rows), ("cols", ShardPolicy::Cols), ("planes", ShardPolicy::Planes)];
    println!("  {:>8} {:>8} {:>10}", "policy", "workers", "best ms");
    let mut gemm_rows = Vec::new();
    let mut rows_best = BTreeMap::new();
    let mut y = vec![0i32; m * n];
    for (label, shard) in policies {
        for workers in [1usize, 2, 4] {
            let opts = ApmmOpts { shard, workers, ..ApmmOpts::default() };
            let mut best = f64::INFINITY;
            for _ in 0..iters {
                let t0 = Instant::now();
                apmm_bipolar_packed_into(&wp, &xp, opts, &mut y);
                best = best.min(t0.elapsed().as_secs_f64());
            }
            assert_eq!(y, serial, "{label} @ {workers}w must be bit-identical to serial");
            if shard == ShardPolicy::Rows {
                rows_best.insert(workers, best);
            }
            println!("  {label:>8} {workers:>8} {:>10.3}", best * 1e3);
            gemm_rows.push(obj(vec![
                ("policy", Json::Str(label.into())),
                ("workers", pos("workers", workers as f64)),
                ("best_ms", pos("best_ms", best * 1e3)),
            ]));
        }
    }
    let speedup_2w = rows_best[&1] / rows_best[&2];
    println!("  rows-policy speedup at 2 workers: {speedup_2w:.2}x");

    // end-to-end: same trace at 1/2/4 engine workers; throughput may move,
    // the streamed bytes must not
    let (rate, requests) = if smoke { (400.0, 8) } else { (200.0, 48) };
    let trace = shared_prefix_trace(rate, requests);
    let mut engine_rows = Vec::new();
    let mut reference: Option<Vec<(u64, usize, i32)>> = None;
    for workers in [1usize, 2, 4] {
        let cfg = EngineConfig { workers, ..engine_cfg(true, EvictionPolicy::Lru, 96) };
        let mut eng = Engine::new(ap_backend(), cfg);
        let events = replay_trace(&mut eng, &trace).expect("replay");
        // wall-clock replay interleaves requests differently run to run;
        // per-request streams are the deterministic contract, so compare
        // (id, step, token) triples order-insensitively
        let mut stream: Vec<(u64, usize, i32)> = events
            .iter()
            .filter_map(|e| match e {
                TokenEvent::Token { id, token, step } => Some((id.0, *step, *token)),
                _ => None,
            })
            .collect();
        stream.sort_unstable();
        match &reference {
            None => reference = Some(stream),
            Some(r) => {
                assert_eq!(&stream, r, "token stream must be byte-identical at {workers} workers")
            }
        }
        let tok_s = eng.metrics.throughput_tok_s();
        let done = eng.metrics.requests_done;
        println!("  engine @ {workers}w: {done:>4} done | {tok_s:>7.0} tok/s");
        engine_rows.push(obj(vec![
            ("workers", pos("workers", workers as f64)),
            ("done", pos("done", done as f64)),
            ("tok_s", pos("tok_s", tok_s)),
        ]));
    }
    obj(vec![
        ("gemm", Json::Arr(gemm_rows)),
        ("gemm_speedup_2w", pos("gemm_speedup_2w", speedup_2w)),
        ("engine", Json::Arr(engine_rows)),
    ])
}

/// Self-speculative decoding from the plane-prefix store: draft `spec_k`
/// tokens per sequence per step from the `draft_bits`-bit MSB plane
/// prefix of the SAME W4 superset pack (zero extra weight bytes), verify
/// all positions in ONE wide batched decode, accept the longest agreeing
/// prefix.  Greedy acceptance makes the streams byte-identical to plain
/// decode — asserted here over the full decode-heavy trace — so the
/// section reports pure throughput: accept rate, accepted-length
/// histogram, mean tokens per decode step (the CI-gated number), and
/// wall-clock tok/s against the spec_k=0 baseline.
fn speculative(smoke: bool, spec_k: usize, draft_bits: u32) -> Json {
    println!(
        "\n== serving: self-speculative decoding (W{draft_bits}-of-W4 draft, spec_k {spec_k}, \
         batched verify) =="
    );
    assert!(spec_k > 0, "--spec-k 0 would bench nothing");
    let (rate, requests) = if smoke { (400.0, 10) } else { (150.0, 48) };
    // decode-heavy shape: short prompts, 16–32 new tokens — the workload
    // where accepted drafts translate into saved decode steps
    let trace =
        generate(&TraceConfig { vocab: 256, ..TraceConfig::decode_heavy(requests, rate, 7) });
    // W4 serving over a sim with batch sizes wide enough that the spec
    // clone rows ride in the same decode group as the real rows
    let backend = || SimBackend::with_ap_gemm(256, 512, vec![1, 2, 4, 8, 16, 32], 128, 4, 2, 7);
    let run = |k: usize| {
        let cfg = EngineConfig { spec_k: k, draft_bits, ..engine_cfg(true, EvictionPolicy::Lru, 96) };
        let mut eng = Engine::new(backend(), cfg);
        assert_eq!(eng.spec_k(), k, "W4 sim backend must accept the draft config");
        let events = replay_trace(&mut eng, &trace).expect("replay");
        // wall-clock replay interleaves admissions differently run to
        // run; the per-request (id, step, token) triples are the
        // deterministic contract, so compare them order-insensitively
        let mut stream: Vec<(u64, usize, i32)> = events
            .iter()
            .filter_map(|e| match e {
                TokenEvent::Token { id, token, step } => Some((id.0, *step, *token)),
                _ => None,
            })
            .collect();
        stream.sort_unstable();
        assert_eq!(
            eng.pool().free_blocks(),
            eng.pool().total_blocks(),
            "spec run must not leak KV blocks"
        );
        (stream, eng)
    };
    let (base_stream, base) = run(0);
    let (spec_stream, spec) = run(spec_k);
    assert_eq!(
        spec_stream, base_stream,
        "speculative streams must be byte-identical to plain decode"
    );
    let c = spec.counters();
    assert!(c.drafted > 0, "the spec run must actually draft");
    let m = &spec.metrics;
    let steps = m.spec_tokens_per_step.count() as f64;
    let mean_tok_step = 1.0 + c.accepted as f64 / steps.max(1.0);
    assert!(
        mean_tok_step >= 1.2,
        "speculation must beat plain decode on tokens/step, got {mean_tok_step:.2}"
    );
    let (base_tok_s, spec_tok_s) = (base.metrics.throughput_tok_s(), m.throughput_tok_s());
    println!(
        "  drafted {} accepted {} ({:.0}%) | {mean_tok_step:.2} tok/step | accept-len hist {:?}",
        c.drafted,
        c.accepted,
        100.0 * m.spec_accept_rate(),
        m.spec_accept_hist
    );
    println!(
        "  tok/s: {base_tok_s:.0} plain vs {spec_tok_s:.0} speculative ({:.2}x, wall-clock)",
        spec_tok_s / base_tok_s
    );
    obj(vec![
        ("spec_k", pos("spec_k", spec_k as f64)),
        ("draft_bits", pos("draft_bits", draft_bits as f64)),
        ("drafted", pos("drafted", c.drafted as f64)),
        ("accepted", num("accepted", c.accepted as f64)),
        ("accept_rate", num("accept_rate", m.spec_accept_rate())),
        ("mean_tokens_per_step", pos("mean_tokens_per_step", mean_tok_step)),
        (
            "accept_hist",
            Json::Arr(m.spec_accept_hist.iter().map(|&v| Json::Num(v as f64)).collect()),
        ),
        ("tok_s_plain", pos("tok_s_plain", base_tok_s)),
        ("tok_s_spec", pos("tok_s_spec", spec_tok_s)),
        ("speedup", pos("speedup", spec_tok_s / base_tok_s)),
        ("streams_identical", Json::Bool(true)),
    ])
}

/// Disaggregated prefill/decode serving: a role-split cluster (one
/// replica per `--roles` entry) vs an all-Mixed cluster of the same
/// size, both replaying the same bursty prefill-heavy trace over the
/// same W2A2 pack-once backend.  The split topology absorbs each prefill
/// burst on the prefill tier and hands every sequence to the decode tier
/// (`PrefillDone` + `Migrated` per handoff), so the section reports
/// **per-role TTFT/ITL** against the mixed baseline's merged numbers —
/// with the streams asserted byte-identical to the mixed oracle: roles
/// redistribute work, they must never change a token.
fn disaggregated(smoke: bool, roles: &[ReplicaRole]) -> Json {
    let labels: Vec<&str> = roles.iter().map(|r| r.label()).collect();
    println!(
        "\n== serving: disaggregated prefill/decode cluster (roles {}) vs mixed baseline, \
         bursty prefill-heavy trace ==",
        labels.join(",")
    );
    assert!(roles.len() >= 2, "disaggregation needs at least two replicas");
    let (requests, burst) = if smoke { (10, 5) } else { (48, 8) };
    let trace = generate(&TraceConfig {
        vocab: 256,
        ..TraceConfig::prefill_heavy(requests, burst, 0.05, 7)
    });

    let build = |topology: &[ReplicaRole]| {
        let mut spec = ClusterSpec::new(RoutePolicy::LeastLoaded);
        for (i, &role) in topology.iter().enumerate() {
            spec = spec.replica(
                ReplicaSpec::new(format!("r{i}-{}", role.label()), PrecisionConfig::W2A2)
                    .role(role)
                    .engine(engine_cfg(true, EvictionPolicy::Lru, 96)),
            );
        }
        Cluster::new(spec, |_| ap_backend())
    };
    let stream_of = |events: &[TokenEvent]| {
        let mut s: Vec<(u64, usize, i32)> = events
            .iter()
            .filter_map(|e| match e {
                TokenEvent::Token { id, token, step } => Some((id.0, *step, *token)),
                _ => None,
            })
            .collect();
        s.sort_unstable();
        s
    };

    let mixed_roles = vec![ReplicaRole::Mixed; roles.len()];
    let mut split = build(roles);
    let mut mixed = build(&mixed_roles);
    let split_events = replay_trace(&mut split, &trace).expect("replay split");
    let mixed_events = replay_trace(&mut mixed, &trace).expect("replay mixed");
    assert_eq!(responses_of(&split_events).len(), requests);
    assert_eq!(responses_of(&mixed_events).len(), requests);
    // the tentpole contract: disaggregation redistributes work without
    // changing a single streamed byte
    assert_eq!(
        stream_of(&split_events),
        stream_of(&mixed_events),
        "role-split streams must be byte-identical to the mixed oracle"
    );
    split.check_invariants().expect("split cluster invariants");
    mixed.check_invariants().expect("mixed cluster invariants");
    for c in [&split, &mixed] {
        for eng in c.engines() {
            assert_eq!(eng.pool().free_blocks(), eng.pool().total_blocks(), "leaked KV blocks");
        }
        assert_eq!(c.router().inflight(), 0, "router load accounting drained");
    }
    // every handoff streamed its marker
    let prefill_done =
        split_events.iter().filter(|e| matches!(e, TokenEvent::PrefillDone { .. })).count();
    assert_eq!(prefill_done as u64, split.prefill_handoffs(), "every handoff streamed");
    let has_split_pair = roles.iter().any(|r| *r == ReplicaRole::Prefill)
        && roles.iter().any(|r| r.accepts_decode());
    if has_split_pair {
        assert!(
            split.prefill_handoffs() > 0,
            "a prefill replica with a decode-capable peer must hand off"
        );
    }
    assert_eq!(mixed.prefill_handoffs(), 0, "mixed replicas never hand off");

    let ms = |v: f64| v * 1e3;
    let sm = split.metrics();
    let mm = mixed.metrics();
    println!(
        "  split: {} done | {:.0} tok/s | {} handoffs ({} migrations) | mixed: {} done | {:.0} tok/s",
        sm.requests_done,
        sm.throughput_tok_s(),
        split.prefill_handoffs(),
        split.migrations(),
        mm.requests_done,
        mm.throughput_tok_s(),
    );
    let mut per_role = Vec::new();
    for role in [ReplicaRole::Prefill, ReplicaRole::Decode, ReplicaRole::Mixed] {
        if !roles.contains(&role) {
            continue;
        }
        let m = split.metrics_for_role(role);
        println!(
            "  role {:>7}: done {:>4} | tokens {:>5} | ttft p50/p95 {:>6.1}/{:<6.1} ms | \
             itl p50/p95 {:>5.1}/{:<5.1} ms",
            role.label(),
            m.requests_done,
            m.tokens_generated,
            ms(m.ttft.percentile(50.0)),
            ms(m.ttft.percentile(95.0)),
            ms(m.itl.percentile(50.0)),
            ms(m.itl.percentile(95.0)),
        );
        per_role.push(obj(vec![
            ("role", Json::Str(role.label().into())),
            ("done", num("done", m.requests_done as f64)),
            ("tokens", num("tokens", m.tokens_generated as f64)),
            ("ttft_p50_ms", num("ttft_p50_ms", ms(m.ttft.percentile(50.0)))),
            ("ttft_p95_ms", num("ttft_p95_ms", ms(m.ttft.percentile(95.0)))),
            ("itl_p50_ms", num("itl_p50_ms", ms(m.itl.percentile(50.0)))),
            ("itl_p95_ms", num("itl_p95_ms", ms(m.itl.percentile(95.0)))),
        ]));
    }
    println!(
        "  mixed baseline: ttft p50/p95 {:.1}/{:.1} ms | itl p50/p95 {:.1}/{:.1} ms",
        ms(mm.ttft.percentile(50.0)),
        ms(mm.ttft.percentile(95.0)),
        ms(mm.itl.percentile(50.0)),
        ms(mm.itl.percentile(95.0)),
    );
    obj(vec![
        ("roles", Json::Arr(labels.iter().map(|l| Json::Str((*l).into())).collect())),
        ("requests", pos("requests", requests as f64)),
        ("done", pos("done", sm.requests_done as f64)),
        ("tok_s", pos("tok_s", sm.throughput_tok_s())),
        ("prefill_handoffs", num("prefill_handoffs", split.prefill_handoffs() as f64)),
        ("migrations", num("migrations", split.migrations() as f64)),
        ("per_role", Json::Arr(per_role)),
        (
            "mixed_baseline",
            obj(vec![
                ("done", pos("mixed done", mm.requests_done as f64)),
                ("tok_s", pos("mixed tok_s", mm.throughput_tok_s())),
                ("ttft_p50_ms", num("ttft_p50_ms", ms(mm.ttft.percentile(50.0)))),
                ("ttft_p95_ms", num("ttft_p95_ms", ms(mm.ttft.percentile(95.0)))),
                ("itl_p50_ms", num("itl_p50_ms", ms(mm.itl.percentile(50.0)))),
                ("itl_p95_ms", num("itl_p95_ms", ms(mm.itl.percentile(95.0)))),
            ]),
        ),
        ("streams_identical", Json::Bool(true)),
    ])
}

fn cluster(rate: f64, requests: usize, replicas: usize) -> Json {
    println!(
        "\n== serving: {replicas}-replica cluster (LeastLoaded router, hot replica 0), \
         shared-prefix trace, rate {rate}/s =="
    );
    let mut spec = ClusterSpec::new(RoutePolicy::LeastLoaded);
    for i in 0..replicas {
        // replica 0 is deliberately undersized so swap-outs pile up on
        // it and the rebalancer has something to migrate
        let kv_blocks = if i == 0 { 24 } else { 96 };
        spec = spec.replica(
            ReplicaSpec::new(format!("r{i}"), PrecisionConfig::W2A2)
                .engine(engine_cfg(true, EvictionPolicy::Lru, kv_blocks)),
        );
    }
    let mut c = Cluster::new(spec, |_| ap_backend());
    let trace = shared_prefix_trace(rate, requests);
    let events = replay_trace(&mut c, &trace).expect("replay");
    let out = responses_of(&events);
    assert_eq!(out.len(), requests);
    assert_eq!(c.router().inflight(), 0, "router load accounting drained");
    c.check_invariants().expect("cluster invariants after drain");
    let migrated_events =
        events.iter().filter(|e| matches!(e, TokenEvent::Migrated { .. })).count();
    assert_eq!(migrated_events as u64, c.migrations(), "every migration streamed");
    let m = c.metrics();
    let ms = |v: f64| v * 1e3;
    println!(
        "  merged: {} done | {:.0} tok/s | {} migrations | ttft p50/p95 {:.1}/{:.1} ms | itl p50/p95 {:.1}/{:.1} ms",
        m.requests_done,
        m.throughput_tok_s(),
        c.migrations(),
        ms(m.ttft.percentile(50.0)),
        ms(m.ttft.percentile(95.0)),
        ms(m.itl.percentile(50.0)),
        ms(m.itl.percentile(95.0)),
    );
    let mut per_replica = Vec::new();
    for (eng, rep) in c.engines().iter().zip(c.router().replicas()) {
        let cnt = eng.counters();
        println!(
            "  {} ({}): completed {:>4} | exported {:>3} | imported {:>3} | {}",
            rep.name,
            rep.precision.label(),
            cnt.completed,
            cnt.exported,
            cnt.imported,
            kv_line(&eng.pool().sharing()),
        );
        assert_eq!(eng.pool().free_blocks(), eng.pool().total_blocks(), "replica leaked blocks");
        per_replica.push(obj(vec![
            ("name", Json::Str(rep.name.clone())),
            ("completed", num("completed", cnt.completed as f64)),
            ("exported", num("exported", cnt.exported as f64)),
            ("imported", num("imported", cnt.imported as f64)),
            ("sharing", sharing_json(&eng.pool().sharing())),
        ]));
    }
    obj(vec![
        ("rate", num("rate", rate)),
        ("requests", pos("requests", requests as f64)),
        ("replicas", pos("replicas", replicas as f64)),
        ("done", pos("done", m.requests_done as f64)),
        ("tok_s", pos("tok_s", m.throughput_tok_s())),
        ("migrations", num("migrations", c.migrations() as f64)),
        ("itl_p50_ms", num("itl_p50_ms", ms(m.itl.percentile(50.0)))),
        ("itl_p95_ms", num("itl_p95_ms", ms(m.itl.percentile(95.0)))),
        ("per_replica", Json::Arr(per_replica)),
    ])
}

/// Reserve vs Optimistic admission over the SAME overloaded Poisson
/// workload on a deliberately tight KV pool: the whole trace lands as an
/// up-front backlog, a fixed probe window counts how much each policy
/// has admitted, then both drain for goodput.  Optimistic books only the
/// prompt and grows per token (preempting on pressure), so it must admit
/// at least as much as Reserve inside the probe window; Reserve books
/// `prompt + max_new` up front, so it must never preempt.  Both
/// contracts are asserted here (and gated again in CI off the artifact).
fn admission(smoke: bool) -> Json {
    println!("\n== serving: admission policy (Reserve vs Optimistic), overloaded Poisson backlog ==");
    let requests = if smoke { 12 } else { 48 };
    let probe_steps = 3;
    let trace = generate(&TraceConfig {
        kind: ArrivalKind::Poisson { rate: 800.0 },
        requests,
        prompt_len: (4, 12),
        max_new: (8, 16),
        vocab: 256,
        seed: 11,
        shared_prefixes: 0,
        prefix_len: 0,
        prefix_skew: 0.0,
    });
    let run = |policy: AdmissionPolicy| {
        // 12 blocks × 8 tokens: far below the backlog's aggregate budget,
        // so admission policy — not compute — decides the schedule
        let cfg = EngineConfig { admission: policy, ..engine_cfg(false, EvictionPolicy::Lru, 12) };
        let mut eng = Engine::new(ap_backend(), cfg);
        eng.start_clock();
        for tr in &trace {
            eng.submit(tr.request.clone());
        }
        let mut events = Vec::new();
        for _ in 0..probe_steps {
            events.extend(eng.step().expect("probe step"));
        }
        let admitted_at_probe = eng.counters().prefills;
        while !eng.is_idle() {
            events.extend(eng.step().expect("drain step"));
        }
        eng.stop_clock();
        let done = responses_of(&events).len();
        assert_eq!(done, requests, "overload must delay, not drop, requests");
        assert_eq!(
            eng.pool().free_blocks(),
            eng.pool().total_blocks(),
            "policy {policy:?} leaked KV blocks"
        );
        let cnt = eng.counters();
        let tok_s = eng.metrics.throughput_tok_s();
        println!(
            "  {policy:?}: admitted {admitted_at_probe} in {probe_steps} steps | done {done} | \
             {tok_s:.0} tok/s | preemptions {} | resumes {}",
            cnt.preemptions, cnt.resumes
        );
        (admitted_at_probe, cnt, tok_s, done)
    };
    let (res_admitted, res_cnt, res_tok_s, res_done) = run(AdmissionPolicy::Reserve);
    let (opt_admitted, opt_cnt, opt_tok_s, opt_done) = run(AdmissionPolicy::Optimistic);
    assert_eq!(res_cnt.preemptions, 0, "Reserve booked the full budget yet preempted");
    assert!(
        opt_admitted >= res_admitted,
        "Optimistic admitted {opt_admitted} < Reserve {res_admitted} in the probe window"
    );
    let policy_obj = |admitted: u64, cnt: apllm::coordinator::EngineCounters, tok_s: f64, done: usize| {
        obj(vec![
            ("admitted_at_probe", num("admitted_at_probe", admitted as f64)),
            ("preemptions", num("preemptions", cnt.preemptions as f64)),
            ("resumes", num("resumes", cnt.resumes as f64)),
            ("done", pos("done", done as f64)),
            ("tok_s", pos("tok_s", tok_s)),
        ])
    };
    obj(vec![
        ("requests", pos("requests", requests as f64)),
        ("probe_steps", pos("probe_steps", probe_steps as f64)),
        ("reserve", policy_obj(res_admitted, res_cnt, res_tok_s, res_done)),
        ("optimistic", policy_obj(opt_admitted, opt_cnt, opt_tok_s, opt_done)),
    ])
}

fn main() {
    let args: Vec<String> = std::env::args().collect();
    let smoke = args.iter().any(|a| a == "--smoke");
    let cluster_mode = args.iter().any(|a| a == "--cluster");
    let json_path = args
        .iter()
        .position(|a| a == "--json")
        .map(|i| args.get(i + 1).expect("--json needs a path").clone());
    let flag_num = |name: &str, default: u64| -> u64 {
        args.iter()
            .position(|a| a == name)
            .map(|i| {
                args.get(i + 1)
                    .and_then(|v| v.parse().ok())
                    .unwrap_or_else(|| panic!("{name} needs a number"))
            })
            .unwrap_or(default)
    };
    let spec_k = flag_num("--spec-k", 4) as usize;
    let draft_bits = flag_num("--draft-bits", 3) as u32;
    let roles: Vec<ReplicaRole> = args
        .iter()
        .position(|a| a == "--roles")
        .map(|i| args.get(i + 1).expect("--roles needs p,d[,m]").clone())
        .unwrap_or_else(|| "p,d".to_string())
        .split(',')
        .map(|s| ReplicaRole::parse(s).unwrap_or_else(|| panic!("bad role {s:?} in --roles")))
        .collect();

    let mut report: BTreeMap<String, Json> = BTreeMap::new();
    report.insert("schema".into(), Json::Num(1.0));
    report.insert("smoke".into(), Json::Bool(smoke));
    report.insert(
        "mode".into(),
        Json::Str(if cluster_mode { "cluster" } else { "engine" }.into()),
    );

    if cluster_mode {
        let (rate, requests, replicas) = if smoke { (400.0, 12, 2) } else { (200.0, 64, 3) };
        report.insert("cluster".into(), cluster(rate, requests, replicas));
    } else {
        let (rates, requests): (&[f64], usize) =
            if smoke { (&[400.0], 8) } else { (&[50.0, 200.0, 800.0], 48) };
        report.insert("steady".into(), steady_state(rates, requests));
        let (pr_rate, pr_requests) = if smoke { (400.0, 12) } else { (200.0, 64) };
        report.insert("prefix_sharing".into(), prefix_sharing(pr_rate, pr_requests));
        report.insert("mixed_precision".into(), mixed_precision(pr_rate, pr_requests));
        report.insert("thread_scaling".into(), thread_scaling(smoke));
        report.insert("speculative".into(), speculative(smoke, spec_k, draft_bits));
        report.insert("disaggregated".into(), disaggregated(smoke, &roles));
        report.insert("admission".into(), admission(smoke));
    }

    if let Some(path) = json_path {
        let doc = Json::Obj(report);
        // round-trip through the parser: the artifact a CI consumer reads
        // must be well-formed JSON, not just a string we hoped was
        Json::parse(&doc.to_string()).expect("bench artifact must be valid JSON");
        std::fs::write(&path, format!("{doc}\n")).expect("write bench artifact");
        println!("\nwrote bench artifact: {path}");
    }
}
