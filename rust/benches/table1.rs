//! T1: regenerate paper Table 1 (square MatMul latency/speedup).
fn main() {
    apllm::bench::print_table1();
}
