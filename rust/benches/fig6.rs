//! F6: regenerate paper Fig. 6 (TOPS on Llama2-7B shapes).
fn main() {
    apllm::bench::print_fig6();
}
