//! F5: regenerate paper Fig. 5 (TOPS vs square size, incl. APNN/BSTC/BTC).
fn main() {
    apllm::bench::print_fig5();
}
