//! `cargo run -p xtask -- lint` — repo-local static analysis for `apllm`.
//!
//! Four safety rules over `rust/src`, enforced in CI beside fmt/clippy:
//!
//! 1. **unsafe-allowlist** — the `unsafe` keyword may appear only in the
//!    three audited modules (`util/par.rs`, `bitmm/apmm.rs`,
//!    `bitmm/planes.rs`).  Everything else relies on the workspace-level
//!    `unsafe_code = "deny"` lint *and* this check, so a stray
//!    `#[allow(unsafe_code)]` can't silently widen the audited surface.
//! 2. **safety-comment** — every `unsafe` site inside the allowlist must
//!    carry a `// SAFETY:` comment on the same line or in the contiguous
//!    comment block directly above it.
//! 3. **narrowing-cast** — `as i32` / `as u32` casts are banned in
//!    `bitmm` kernel bodies (the accumulator-overflow class fixed in
//!    PR 2) unless annotated with `// lint: allow(narrowing-cast)` on the
//!    same line or the line above, stating why the cast is exact.
//! 4. **raw-spawn** — `std::thread::spawn` / `thread::Builder` may appear
//!    only in `util/par.rs`, `util/sync.rs` and `util/loom.rs`: all other
//!    code must go through the worker pool so the loom/Miri/tsan lanes
//!    actually cover the crate's threading.
//!
//! Scanning is textual but comment/string-aware: sources are stripped
//! (comments, string/char literals blanked, newlines kept) before rules
//! run, `#[cfg(test)]` regions and files named `tests.rs` are skipped,
//! and `unsafe fn(..)` *function-pointer types* are exempt from rules
//! 1–2 (they declare no unsafe operation).

use std::fmt;
use std::fs;
use std::path::{Path, PathBuf};
use std::process::ExitCode;

/// Modules audited for `unsafe` (must match the `#[allow(unsafe_code)]`
/// grants in `util/mod.rs` and `bitmm/mod.rs`).
const UNSAFE_ALLOWLIST: &[&str] = &["util/par.rs", "bitmm/apmm.rs", "bitmm/planes.rs"];

/// Modules allowed to start OS threads directly.
const SPAWN_ALLOWLIST: &[&str] = &["util/par.rs", "util/sync.rs", "util/loom.rs"];

/// Escape-hatch marker for rule 3.
const CAST_ESCAPE: &str = "lint: allow(narrowing-cast)";

#[derive(Debug, Clone, Copy, PartialEq, Eq)]
enum Rule {
    UnsafeOutsideAllowlist,
    UnsafeWithoutSafetyComment,
    NarrowingCastInKernel,
    RawThreadSpawn,
}

impl fmt::Display for Rule {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(match self {
            Rule::UnsafeOutsideAllowlist => "unsafe-allowlist",
            Rule::UnsafeWithoutSafetyComment => "safety-comment",
            Rule::NarrowingCastInKernel => "narrowing-cast",
            Rule::RawThreadSpawn => "raw-spawn",
        })
    }
}

#[derive(Debug)]
struct Violation {
    file: String,
    line: usize, // 1-based
    rule: Rule,
    msg: String,
}

impl fmt::Display for Violation {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{}:{}: [{}] {}", self.file, self.line, self.rule, self.msg)
    }
}

fn is_ident_byte(b: u8) -> bool {
    b.is_ascii_alphanumeric() || b == b'_'
}

/// Length of the UTF-8 sequence starting with `lead` (1 for ASCII/invalid).
fn utf8_len(lead: u8) -> usize {
    match lead {
        0xC0..=0xDF => 2,
        0xE0..=0xEF => 3,
        0xF0..=0xF7 => 4,
        _ => 1,
    }
}

/// Blank comments, string literals and char literals out of `src`,
/// preserving byte positions of everything else and every newline (so
/// line numbers in the stripped text match the original).
fn strip_code(src: &str) -> String {
    let b = src.as_bytes();
    let mut out: Vec<u8> = Vec::with_capacity(b.len());
    let mut i = 0;
    // Push `n` bytes from position `i` as blanks, newlines kept.
    let blank = |out: &mut Vec<u8>, b: &[u8], from: usize, to: usize| {
        for &c in &b[from..to] {
            out.push(if c == b'\n' { b'\n' } else { b' ' });
        }
    };
    while i < b.len() {
        let c = b[i];
        // line comment
        if c == b'/' && b.get(i + 1) == Some(&b'/') {
            let mut j = i;
            while j < b.len() && b[j] != b'\n' {
                j += 1;
            }
            blank(&mut out, b, i, j);
            i = j;
            continue;
        }
        // block comment (Rust block comments nest)
        if c == b'/' && b.get(i + 1) == Some(&b'*') {
            let mut depth = 1usize;
            let mut j = i + 2;
            while j < b.len() && depth > 0 {
                if b[j] == b'/' && b.get(j + 1) == Some(&b'*') {
                    depth += 1;
                    j += 2;
                } else if b[j] == b'*' && b.get(j + 1) == Some(&b'/') {
                    depth -= 1;
                    j += 2;
                } else {
                    j += 1;
                }
            }
            blank(&mut out, b, i, j);
            i = j;
            continue;
        }
        // raw string literal r"..." / r#"..."# (the `b` of `br"..."` is
        // already emitted as code, which is harmless)
        if c == b'r' && !(i > 0 && is_ident_byte(b[i - 1])) {
            let mut h = i + 1;
            while b.get(h) == Some(&b'#') {
                h += 1;
            }
            if b.get(h) == Some(&b'"') {
                let hashes = h - (i + 1);
                let mut j = h + 1;
                while j < b.len() {
                    let closes = b[j] == b'"'
                        && b[j + 1..].iter().take_while(|&&x| x == b'#').count() >= hashes;
                    if closes {
                        j += 1 + hashes;
                        break;
                    }
                    j += 1;
                }
                blank(&mut out, b, i, j);
                i = j;
                continue;
            }
        }
        // string literal
        if c == b'"' {
            let mut j = i + 1;
            while j < b.len() {
                if b[j] == b'\\' {
                    j = (j + 2).min(b.len());
                } else if b[j] == b'"' {
                    j += 1;
                    break;
                } else {
                    j += 1;
                }
            }
            blank(&mut out, b, i, j);
            i = j;
            continue;
        }
        // char literal vs lifetime
        if c == b'\'' {
            let mut char_end = None; // byte index just past the closing quote
            if b.get(i + 1) == Some(&b'\\') {
                let mut j = i + 3; // skip backslash + escaped byte
                while j < b.len() && b[j] != b'\'' && b[j] != b'\n' {
                    j += 1;
                }
                if b.get(j) == Some(&b'\'') {
                    char_end = Some(j + 1);
                }
            } else if let Some(&first) = b.get(i + 1) {
                let l = utf8_len(first);
                if first != b'\'' && b.get(i + 1 + l) == Some(&b'\'') {
                    char_end = Some(i + 2 + l);
                }
            }
            if let Some(end) = char_end {
                blank(&mut out, b, i, end);
                i = end;
                continue;
            }
            // lifetime tick: keep as code
            out.push(b'\'');
            i += 1;
            continue;
        }
        out.push(c);
        i += 1;
    }
    // Blanking is byte-for-byte ASCII, so the output stays valid UTF-8.
    String::from_utf8(out).expect("stripping preserves UTF-8")
}

/// Per-line mask of `#[cfg(test)]`-gated regions (attribute line + the
/// brace-delimited item it introduces).
fn test_region_mask(stripped_lines: &[&str]) -> Vec<bool> {
    let mut mask = vec![false; stripped_lines.len()];
    let mut depth: i64 = 0;
    let mut armed = false;
    let mut region_depth: Option<i64> = None;
    for (ln, line) in stripped_lines.iter().enumerate() {
        if region_depth.is_some() {
            mask[ln] = true;
        }
        if line.contains("#[cfg(test)]") || line.contains("#[cfg(all(test") {
            armed = true;
            mask[ln] = true;
        }
        for ch in line.bytes() {
            match ch {
                b'{' => {
                    if armed && region_depth.is_none() {
                        region_depth = Some(depth);
                        armed = false;
                        mask[ln] = true;
                    }
                    depth += 1;
                }
                b'}' => {
                    depth -= 1;
                    if region_depth == Some(depth) {
                        region_depth = None;
                    }
                }
                // `#[cfg(test)] use foo;` — gates a braceless item
                b';' => {
                    if armed && region_depth.is_none() {
                        armed = false;
                    }
                }
                _ => {}
            }
        }
    }
    mask
}

/// First occurrence of `word` in `line` delimited by non-identifier bytes.
fn find_word(line: &str, word: &str) -> Option<usize> {
    let bytes = line.as_bytes();
    let mut start = 0;
    while let Some(pos) = line[start..].find(word) {
        let p = start + pos;
        let before_ok = p == 0 || !is_ident_byte(bytes[p - 1]);
        let after = p + word.len();
        let after_ok = after >= bytes.len() || !is_ident_byte(bytes[after]);
        if before_ok && after_ok {
            return Some(p);
        }
        start = p + 1;
    }
    None
}

/// Rule 2 adjacency: `SAFETY:` on the same raw line, or anywhere in the
/// contiguous run of comment/attribute lines directly above it.
fn has_adjacent_safety(raw_lines: &[&str], ln: usize) -> bool {
    if raw_lines[ln].contains("SAFETY") {
        return true;
    }
    let mut i = ln;
    while i > 0 {
        i -= 1;
        let t = raw_lines[i].trim_start();
        if t.starts_with("//") || t.starts_with("#[") || t.starts_with("#!") {
            if t.contains("SAFETY") {
                return true;
            }
        } else {
            break;
        }
    }
    false
}

/// Lint one file. `rel` is the path relative to the `src` root, with `/`
/// separators (e.g. `bitmm/apmm.rs`).
fn lint_source(rel: &str, src: &str) -> Vec<Violation> {
    let mut out = Vec::new();
    if Path::new(rel).file_name().is_some_and(|f| f == "tests.rs") {
        return out;
    }
    let stripped = strip_code(src);
    let code_lines: Vec<&str> = stripped.lines().collect();
    let raw_lines: Vec<&str> = src.lines().collect();
    let in_test = test_region_mask(&code_lines);

    let unsafe_ok = UNSAFE_ALLOWLIST.iter().any(|a| rel == *a);
    let spawn_ok = SPAWN_ALLOWLIST.iter().any(|a| rel == *a);
    let kernel = rel.starts_with("bitmm/");

    for (ln, code) in code_lines.iter().enumerate() {
        if in_test[ln] {
            continue;
        }
        let line_no = ln + 1;

        // rules 1–2: unsafe keyword
        if let Some(p) = find_word(code, "unsafe") {
            // `unsafe fn(` is a fn-pointer *type*, not an unsafe op
            let rest = code[p + "unsafe".len()..].trim_start();
            let fn_ptr_type = rest
                .strip_prefix("fn")
                .is_some_and(|r| r.trim_start().starts_with('('));
            if !fn_ptr_type {
                if !unsafe_ok {
                    out.push(Violation {
                        file: rel.to_string(),
                        line: line_no,
                        rule: Rule::UnsafeOutsideAllowlist,
                        msg: format!(
                            "`unsafe` outside the audited modules ({})",
                            UNSAFE_ALLOWLIST.join(", ")
                        ),
                    });
                } else if !has_adjacent_safety(&raw_lines, ln) {
                    out.push(Violation {
                        file: rel.to_string(),
                        line: line_no,
                        rule: Rule::UnsafeWithoutSafetyComment,
                        msg: "`unsafe` without an adjacent `// SAFETY:` comment".to_string(),
                    });
                }
            }
        }

        // rule 3: narrowing casts in kernel bodies
        if kernel {
            for pat in ["as i32", "as u32"] {
                if find_word(code, pat).is_some() {
                    let escaped = raw_lines[ln].contains(CAST_ESCAPE)
                        || (ln > 0 && raw_lines[ln - 1].contains(CAST_ESCAPE));
                    if !escaped {
                        out.push(Violation {
                            file: rel.to_string(),
                            line: line_no,
                            rule: Rule::NarrowingCastInKernel,
                            msg: format!(
                                "`{pat}` in a bitmm kernel body (PR 2 overflow class); widen \
                                 to i64 or annotate `// {CAST_ESCAPE} — <why exact>`"
                            ),
                        });
                    }
                }
            }
        }

        // rule 4: raw thread spawns
        if !spawn_ok && (code.contains("thread::spawn(") || code.contains("thread::Builder")) {
            out.push(Violation {
                file: rel.to_string(),
                line: line_no,
                rule: Rule::RawThreadSpawn,
                msg: "direct OS-thread spawn; route through `util::par` \
                      (`WorkerPool` / `spawn_named`) so the concurrency CI lanes cover it"
                    .to_string(),
            });
        }
    }
    out
}

/// Recursively collect `.rs` files under `root`, sorted for determinism.
fn rs_files(root: &Path) -> std::io::Result<Vec<PathBuf>> {
    let mut out = Vec::new();
    let mut stack = vec![root.to_path_buf()];
    while let Some(dir) = stack.pop() {
        for entry in fs::read_dir(&dir)? {
            let path = entry?.path();
            if path.is_dir() {
                stack.push(path);
            } else if path.extension().is_some_and(|e| e == "rs") {
                out.push(path);
            }
        }
    }
    out.sort();
    Ok(out)
}

/// Lint every `.rs` file under `root`; returns (files scanned, violations).
fn lint_tree(root: &Path) -> std::io::Result<(usize, Vec<Violation>)> {
    let files = rs_files(root)?;
    let mut violations = Vec::new();
    for path in &files {
        let rel = path
            .strip_prefix(root)
            .unwrap_or(path)
            .components()
            .map(|c| c.as_os_str().to_string_lossy())
            .collect::<Vec<_>>()
            .join("/");
        let src = fs::read_to_string(path)?;
        violations.extend(lint_source(&rel, &src));
    }
    Ok((files.len(), violations))
}

fn default_src_root() -> PathBuf {
    Path::new(env!("CARGO_MANIFEST_DIR")).join("../src")
}

fn main() -> ExitCode {
    let args: Vec<String> = std::env::args().skip(1).collect();
    match args.first().map(String::as_str) {
        Some("lint") => {
            let root = args.get(1).map(PathBuf::from).unwrap_or_else(default_src_root);
            match lint_tree(&root) {
                Ok((n, violations)) => {
                    if violations.is_empty() {
                        println!("xtask lint: OK ({n} files, 0 violations)");
                        ExitCode::SUCCESS
                    } else {
                        for v in &violations {
                            eprintln!("{v}");
                        }
                        eprintln!("xtask lint: {} violation(s) in {n} files", violations.len());
                        ExitCode::FAILURE
                    }
                }
                Err(e) => {
                    eprintln!("xtask lint: cannot scan {}: {e}", root.display());
                    ExitCode::FAILURE
                }
            }
        }
        _ => {
            eprintln!("usage: cargo run -p xtask -- lint [src-root]");
            ExitCode::FAILURE
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn rules(rel: &str, src: &str) -> Vec<Rule> {
        lint_source(rel, src).into_iter().map(|v| v.rule).collect()
    }

    #[test]
    fn strip_blanks_comments_and_strings_but_keeps_lines() {
        let src = "let a = \"unsafe\"; // unsafe in comment\n/* unsafe\nblock */ let b = 'x';\n";
        let got = strip_code(src);
        assert_eq!(got.lines().count(), src.lines().count());
        assert!(!got.contains("unsafe"));
        assert!(got.contains("let a ="));
        assert!(got.contains("let b ="));
    }

    #[test]
    fn strip_handles_raw_strings_and_lifetimes() {
        let src = "let r = r#\"unsafe \" quote\"#;\nfn f<'a>(x: &'a str) {}\n";
        let got = strip_code(src);
        assert!(!got.contains("unsafe"));
        assert!(got.contains("fn f<'a>(x: &'a str) {}"));
    }

    // The ISSUE's meta-test: the lint must FAIL on a seeded violation.
    #[test]
    fn seeded_unsafe_outside_allowlist_fails() {
        let src = "pub fn f(p: *mut u8) { unsafe { *p = 0; } }\n";
        assert_eq!(rules("coordinator/server.rs", src), vec![Rule::UnsafeOutsideAllowlist]);
    }

    #[test]
    fn unsafe_in_allowlisted_module_needs_safety_comment() {
        let bad = "pub fn f(p: *mut u8) {\n    unsafe { *p = 0; }\n}\n";
        assert_eq!(rules("util/par.rs", bad), vec![Rule::UnsafeWithoutSafetyComment]);
        let good = "pub fn f(p: *mut u8) {\n    // SAFETY: p is valid\n    unsafe { *p = 0; }\n}\n";
        assert_eq!(rules("util/par.rs", good), vec![]);
        let multi = "pub fn f(p: *mut u8) {\n    // SAFETY: p is valid and\n    \
                     // exclusively owned here\n    unsafe { *p = 0; }\n}\n";
        assert_eq!(rules("util/par.rs", multi), vec![]);
    }

    #[test]
    fn fn_pointer_type_is_not_an_unsafe_op() {
        let src = "struct J { call: unsafe fn(*const (), usize) }\n";
        assert_eq!(rules("util/par.rs", src), vec![]);
        // ...but an unsafe fn *declaration* still needs a SAFETY comment
        let decl = "unsafe fn call_thunk(p: *const ()) {}\n";
        assert_eq!(rules("util/par.rs", decl), vec![Rule::UnsafeWithoutSafetyComment]);
    }

    #[test]
    fn narrowing_casts_flagged_only_in_kernels_and_escapable() {
        let src = "fn f(k: usize) -> i32 { k as i32 }\n";
        assert_eq!(rules("bitmm/recover.rs", src), vec![Rule::NarrowingCastInKernel]);
        assert_eq!(rules("coordinator/server.rs", src), vec![]);
        let escaped =
            "// lint: allow(narrowing-cast) — k < 2^31\nfn f(k: usize) -> i32 { k as i32 }\n";
        assert_eq!(rules("bitmm/recover.rs", escaped), vec![]);
        // `as u32` and identifiers containing the pattern
        let cast = "let x = y as u32;\n";
        assert_eq!(rules("bitmm/apmm.rs", cast), vec![Rule::NarrowingCastInKernel]);
        assert_eq!(rules("bitmm/apmm.rs", "let has_i32 = true;\n"), vec![]);
    }

    #[test]
    fn raw_spawn_flagged_outside_par() {
        let src = "let h = std::thread::spawn(|| {});\n";
        assert_eq!(rules("coordinator/server.rs", src), vec![Rule::RawThreadSpawn]);
        assert_eq!(rules("util/par.rs", src), vec![]);
        // the pool's own named-spawn helper is fine everywhere
        assert_eq!(rules("coordinator/server.rs", "thread::spawn_named(\"x\", || {});\n"), vec![]);
    }

    #[test]
    fn cfg_test_regions_and_tests_rs_are_skipped() {
        let src = "#[cfg(test)]\nmod tests {\n    fn f() { unsafe { bad() } }\n}\n";
        assert_eq!(rules("coordinator/server.rs", src), vec![]);
        let src2 = "fn f() { unsafe { bad() } }\n";
        assert_eq!(rules("bitmm/tests.rs", src2), vec![]);
        // code after the region closes is linted again
        let src3 = "#[cfg(test)]\nmod tests {\n}\nfn f() { unsafe { bad() } }\n";
        assert_eq!(rules("coordinator/server.rs", src3), vec![Rule::UnsafeOutsideAllowlist]);
    }

    // The other half of the acceptance criterion: the audited tree passes.
    #[test]
    fn real_tree_is_clean() {
        let (n, violations) = lint_tree(&default_src_root()).expect("scan rust/src");
        assert!(n > 20, "expected to scan the real tree, got {n} files");
        let msgs: Vec<String> = violations.iter().map(|v| v.to_string()).collect();
        assert!(violations.is_empty(), "violations:\n{}", msgs.join("\n"));
    }
}
