//! Integration tests over the real AOT artifacts: PJRT execution vs the
//! pure-Rust substrate, golden cross-language vectors, and the model
//! runner.  All tests skip (pass with a notice) when `artifacts/` is
//! missing — run `make artifacts` first for full coverage.  Needs the
//! `pjrt` feature (the default build is offline).
#![cfg(feature = "pjrt")]

use apllm::bitmm::{apmm_bipolar, pack_codes_u32, transpose_codes, ApmmOpts, CodeMatrix};
use apllm::runtime::{Engine, ModelRunner};
use apllm::util::Json;

fn artifacts() -> Option<std::path::PathBuf> {
    let dir = std::path::PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("artifacts");
    if dir.join("manifest.json").exists() {
        Some(dir)
    } else {
        eprintln!("SKIP: no artifacts/ (run `make artifacts`)");
        None
    }
}

#[test]
fn golden_vectors_match_python_oracle() {
    let Some(dir) = artifacts() else { return };
    let src = std::fs::read_to_string(dir.join("golden_apmm.json")).unwrap();
    let j = Json::parse(&src).unwrap();
    let cases = j.get("cases").and_then(Json::as_arr).unwrap();
    assert!(cases.len() >= 4);
    for case in cases {
        let g = |k: &str| case.get(k).and_then(Json::as_usize).unwrap();
        let (m, k, n, nw, nx) = (g("m"), g("k"), g("n"), g("nw") as u32, g("nx") as u32);
        let vec_u32 = |key: &str| -> Vec<u32> {
            case.get(key)
                .and_then(Json::as_arr)
                .unwrap()
                .iter()
                .map(|v| v.as_f64().unwrap() as u32)
                .collect()
        };
        let w = CodeMatrix::new(m, k, nw, vec_u32("w_code"));
        let x = CodeMatrix::new(k, n, nx, vec_u32("x_code"));
        let want: Vec<i32> = case
            .get("y")
            .and_then(Json::as_arr)
            .unwrap()
            .iter()
            .map(|v| v.as_f64().unwrap() as i32)
            .collect();
        let got = apmm_bipolar(&w, &transpose_codes(&x), ApmmOpts::default());
        assert_eq!(got, want, "golden case {m}x{k}x{n} W{nw}A{nx}");
    }
}

#[test]
fn pjrt_apmm_matches_bitmm() {
    let Some(dir) = artifacts() else { return };
    let engine = Engine::load(&dir).unwrap();
    let specs: Vec<_> = engine.manifest().by_kind("apmm").into_iter().cloned().collect();
    assert!(!specs.is_empty(), "manifest must list apmm executables");
    for spec in specs {
        let (m, k, n) = (
            spec.meta_usize("m").unwrap(),
            spec.meta_usize("k").unwrap(),
            spec.meta_usize("n").unwrap(),
        );
        let (nw, nx) = (spec.meta_usize("nw").unwrap() as u32, spec.meta_usize("nx").unwrap() as u32);
        let w = CodeMatrix::random(m, k, nw, 101);
        let x = CodeMatrix::random(k, n, nx, 102);
        let xt = transpose_codes(&x);
        let y_pjrt = engine.run_apmm(&spec, &pack_codes_u32(&w), &pack_codes_u32(&xt)).unwrap();
        let y_rust = apmm_bipolar(&w, &xt, ApmmOpts::default());
        assert_eq!(y_pjrt, y_rust, "{}", spec.name);
    }
}

#[test]
fn pjrt_apmm_rejects_bad_operands() {
    let Some(dir) = artifacts() else { return };
    let engine = Engine::load(&dir).unwrap();
    let spec = engine.manifest().by_kind("apmm")[0].clone();
    let err = engine.run_apmm(&spec, &[0u32; 3], &[0u32; 3]).unwrap_err().to_string();
    assert!(err.contains("don't match"), "err: {err}");
}

#[test]
fn model_prefill_decode_roundtrip() {
    let Some(dir) = artifacts() else { return };
    let engine = Engine::load(&dir).unwrap();
    let runner = ModelRunner::new(&engine).unwrap();
    let cfg = runner.cfg;
    assert!(runner.max_batch() >= 4);

    // batch 1: prefill then three decode steps
    let prompt: Vec<i32> = (1..9).collect();
    let (logits, mut kv) = runner.prefill(&prompt, 1, 8).unwrap();
    assert_eq!(logits.len() % cfg.vocab, 0);
    assert!(logits.iter().all(|x| x.is_finite()), "prefill logits finite");
    assert_eq!(kv.batch, 1);
    let pos0 = kv.pos[0];

    let mut tok = 9i32;
    for step in 0..3 {
        let lg = runner.decode(&[tok], &mut kv).unwrap();
        assert_eq!(lg.len(), cfg.vocab);
        assert!(lg.iter().all(|x| x.is_finite()), "decode step {step}");
        // greedy next token
        tok = lg
            .iter()
            .enumerate()
            .max_by(|a, b| a.1.partial_cmp(b.1).unwrap())
            .map(|(i, _)| i as i32)
            .unwrap();
        assert_eq!(kv.pos[0], pos0 + step + 1);
    }
}

#[test]
fn model_decode_batch2_consistent_with_batch1() {
    // Row 0 of a batch-2 decode must equal the same request decoded alone.
    let Some(dir) = artifacts() else { return };
    let engine = Engine::load(&dir).unwrap();
    let runner = ModelRunner::new(&engine).unwrap();
    let cfg = runner.cfg;

    let p0: Vec<i32> = (1..17).collect();
    let p1: Vec<i32> = (20..36).collect();
    let (_, mut kv1) = runner.prefill(&p0, 1, 16).unwrap();
    let lg1 = runner.decode(&[5], &mut kv1).unwrap();

    let mut both = p0.clone();
    both.extend(&p1);
    let (_, mut kv2) = runner.prefill(&both, 2, 16).unwrap();
    let lg2 = runner.decode(&[5, 7], &mut kv2).unwrap();

    for i in 0..cfg.vocab {
        assert!(
            (lg1[i] - lg2[i]).abs() < 2e-3,
            "batch invariance: logit {i}: {} vs {}",
            lg1[i],
            lg2[i]
        );
    }
}

#[test]
fn decode_exhausts_kv_gracefully() {
    let Some(dir) = artifacts() else { return };
    let engine = Engine::load(&dir).unwrap();
    let runner = ModelRunner::new(&engine).unwrap();
    let cfg = runner.cfg;
    let (_, mut kv) = runner.prefill(&(1..17).collect::<Vec<_>>(), 1, 16).unwrap();
    kv.pos = vec![cfg.max_seq; 1]; // fast-forward to the edge
    let err = runner.decode(&[1], &mut kv).unwrap_err().to_string();
    assert!(err.contains("exhausted"), "err: {err}");
}

// ------------------------------------------------------- failure injection --

#[test]
fn corrupt_weights_rejected() {
    // truncated weights.bin must fail loading with a clear error, not UB
    let Some(dir) = artifacts() else { return };
    let tmp = std::env::temp_dir().join(format!("apllm-corrupt-{}", std::process::id()));
    let _ = std::fs::remove_dir_all(&tmp);
    std::fs::create_dir_all(&tmp).unwrap();
    for f in std::fs::read_dir(&dir).unwrap() {
        let f = f.unwrap();
        std::fs::copy(f.path(), tmp.join(f.file_name())).unwrap();
    }
    let blob = std::fs::read(tmp.join("weights.bin")).unwrap();
    std::fs::write(tmp.join("weights.bin"), &blob[..blob.len() / 2]).unwrap();
    let engine = Engine::load(&tmp).unwrap();
    let err = match ModelRunner::new(&engine) {
        Err(e) => e.to_string(),
        Ok(_) => panic!("truncated weights must not load"),
    };
    assert!(err.contains("out of range"), "err: {err}");
    let _ = std::fs::remove_dir_all(&tmp);
}

#[test]
fn truncated_hlo_rejected() {
    // a mangled HLO file must fail at compile, not crash the client
    let Some(dir) = artifacts() else { return };
    let tmp = std::env::temp_dir().join(format!("apllm-badhlo-{}", std::process::id()));
    let _ = std::fs::remove_dir_all(&tmp);
    std::fs::create_dir_all(&tmp).unwrap();
    std::fs::copy(dir.join("manifest.json"), tmp.join("manifest.json")).unwrap();
    // write garbage for every referenced HLO
    let engine_src = Engine::load(&dir).unwrap();
    for e in &engine_src.manifest().executables {
        std::fs::write(tmp.join(&e.hlo), "HloModule broken\nENTRY {").unwrap();
    }
    let engine = Engine::load(&tmp).unwrap();
    let name = engine.manifest().executables[0].name.clone();
    assert!(engine.compile(&name).is_err(), "garbage HLO must not compile");
    let _ = std::fs::remove_dir_all(&tmp);
}

#[test]
fn missing_executable_name_errors() {
    let Some(dir) = artifacts() else { return };
    let engine = Engine::load(&dir).unwrap();
    let err = match engine.compile("does_not_exist") {
        Err(e) => e.to_string(),
        Ok(_) => panic!("unknown executable must not compile"),
    };
    assert!(err.contains("does_not_exist"), "err: {err}");
}
