//! Coordinator over the REAL PJRT backend: continuous batching with
//! mixed-depth sequences against the AOT model artifacts, driven by the
//! one serving engine (`AdmissionPolicy::Reserve` replays the retired
//! group scheduler's semantics bit-for-bit).
//! Skips gracefully when `artifacts/` is absent; needs the `pjrt` feature.
#![cfg(feature = "pjrt")]

mod common;

use apllm::coordinator::backend::{Backend, PjrtBackend};
use apllm::coordinator::{
    AdmissionPolicy, Engine, EngineConfig, GenParams, Request,
};
use apllm::runtime::{Engine as RuntimeEngine, ModelRunner};
use common::{legacy_scheduler_events, project};

fn artifacts() -> Option<std::path::PathBuf> {
    let dir = std::path::PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("artifacts");
    if dir.join("manifest.json").exists() {
        Some(dir)
    } else {
        eprintln!("SKIP: no artifacts/ (run `make artifacts`)");
        None
    }
}

fn reserve_cfg(kv_blocks: usize, block_tokens: usize, max_running: usize) -> EngineConfig {
    EngineConfig {
        kv_blocks,
        block_tokens,
        max_running,
        admission: AdmissionPolicy::Reserve,
        ..EngineConfig::default()
    }
}

fn workload() -> Vec<Request> {
    (0..6u64)
        .map(|i| {
            let prompt: Vec<i32> = (1..(4 + i as i32 % 5)).collect();
            Request::new(
                i,
                prompt,
                GenParams { max_new_tokens: 4 + (i as usize % 3), sample: false, seed: i },
            )
        })
        .collect()
}

#[test]
fn pjrt_backend_prefill_decode_mixed_depths() {
    let Some(dir) = artifacts() else { return };
    let engine = RuntimeEngine::load(&dir).unwrap();
    let runner = ModelRunner::new(&engine).unwrap();
    let mut backend = PjrtBackend::new(&runner).unwrap();
    let vocab = backend.vocab();

    // two sequences at different depths, decoded as one group
    let (lg_a, mut kv_a) = backend.prefill_one(&[1, 2, 3, 4, 5, 6]).unwrap();
    let (lg_b, mut kv_b) = backend.prefill_one(&[7, 8, 9]).unwrap();
    assert_eq!(lg_a.len(), vocab);
    assert_eq!(kv_a.pos, 6);
    assert_eq!(kv_b.pos, 3);
    assert!(lg_a.iter().all(|x| x.is_finite()));
    assert!(lg_b.iter().all(|x| x.is_finite()));

    // reference: decode each alone
    let (mut kv_a2, mut kv_b2) = (kv_a.clone(), kv_b.clone());
    let solo_a = backend.decode_batch(&[10], &mut [&mut kv_a2]).unwrap();
    let solo_b = backend.decode_batch(&[11], &mut [&mut kv_b2]).unwrap();

    // mixed group must match the solo results row-by-row
    let group = backend.decode_batch(&[10, 11], &mut [&mut kv_a, &mut kv_b]).unwrap();
    assert_eq!(kv_a.pos, 7);
    assert_eq!(kv_b.pos, 4);
    for i in 0..vocab {
        assert!(
            (group[0][i] - solo_a[0][i]).abs() < 2e-3,
            "row a logit {i}: {} vs {}",
            group[0][i],
            solo_a[0][i]
        );
        assert!(
            (group[1][i] - solo_b[0][i]).abs() < 2e-3,
            "row b logit {i}: {} vs {}",
            group[1][i],
            solo_b[0][i]
        );
    }
}

#[test]
fn reserve_engine_end_to_end_over_pjrt() {
    let Some(dir) = artifacts() else { return };
    let engine = RuntimeEngine::load(&dir).unwrap();
    let runner = ModelRunner::new(&engine).unwrap();
    let backend = PjrtBackend::new(&runner).unwrap();

    let mut eng = Engine::new(backend, reserve_cfg(64, 16, 4));
    for r in workload() {
        eng.submit(r);
    }
    let mut out = eng.run_to_completion().unwrap();
    assert_eq!(out.len(), 6);
    out.sort_by_key(|r| r.id);
    for (i, r) in out.iter().enumerate() {
        assert_eq!(r.tokens.len(), 4 + (i % 3), "request {i} token count");
        let vocab = eng.backend().vocab() as i32;
        assert!(r.tokens.iter().all(|&t| t >= 0 && t < vocab));
    }
    assert!(eng.metrics.mean_occupancy() > 1.0, "batching must engage");
    assert_eq!(eng.metrics.tokens_generated as usize, 4 + 5 + 6 + 4 + 5 + 6);
    // speculation auto-disarms over PJRT (real device KV, not
    // position-only), and Reserve never preempts
    assert_eq!(eng.spec_k(), 0);
    assert_eq!(eng.counters().preemptions, 0);
    assert_eq!(eng.pool().free_blocks(), eng.pool().total_blocks(), "KV leak");
}

/// Golden-fixture parity over the real backend: the Reserve engine's
/// stream must match the retired group scheduler's, replayed by the
/// oracle in `common` against a fresh `PjrtBackend` on the same runner.
#[test]
fn reserve_engine_matches_scheduler_stream_over_pjrt() {
    let Some(dir) = artifacts() else { return };
    let engine = RuntimeEngine::load(&dir).unwrap();
    let runner = ModelRunner::new(&engine).unwrap();

    let golden =
        legacy_scheduler_events(PjrtBackend::new(&runner).unwrap(), 64, 16, 4, workload());

    let mut eng = Engine::new(PjrtBackend::new(&runner).unwrap(), reserve_cfg(64, 16, 4));
    for r in workload() {
        eng.submit(r);
    }
    let events = eng.run_to_completion_events().unwrap();
    assert_eq!(project(&events), golden, "Reserve engine diverged from the scheduler oracle");
    assert_eq!(eng.counters().preemptions, 0);
    assert_eq!(eng.pool().free_blocks(), eng.pool().total_blocks(), "KV leak");
}

#[test]
fn reserve_engine_determinism_over_pjrt() {
    let Some(dir) = artifacts() else { return };
    let engine = RuntimeEngine::load(&dir).unwrap();
    let runner = ModelRunner::new(&engine).unwrap();
    let run = |runner: &ModelRunner| {
        let backend = PjrtBackend::new(runner).unwrap();
        let mut eng = Engine::new(backend, reserve_cfg(64, 16, 8));
        for i in 0..3u64 {
            eng.submit(Request::new(
                i,
                vec![2, 4, 6, 8],
                GenParams { max_new_tokens: 5, sample: false, seed: i },
            ));
        }
        let mut out = eng.run_to_completion().unwrap();
        out.sort_by_key(|r| r.id);
        out.iter().map(|r| r.tokens.clone()).collect::<Vec<_>>()
    };
    assert_eq!(run(&runner), run(&runner), "greedy decode must be deterministic");
}
