//! Coordinator over the REAL PJRT backend: continuous batching with
//! mixed-depth sequences against the AOT model artifacts.
//! Skips gracefully when `artifacts/` is absent; needs the `pjrt` feature.
#![cfg(feature = "pjrt")]

use apllm::coordinator::backend::{Backend, PjrtBackend};
use apllm::coordinator::{GenParams, Request, Scheduler, SchedulerConfig};
use apllm::runtime::{Engine, ModelRunner};

fn artifacts() -> Option<std::path::PathBuf> {
    let dir = std::path::PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("artifacts");
    if dir.join("manifest.json").exists() {
        Some(dir)
    } else {
        eprintln!("SKIP: no artifacts/ (run `make artifacts`)");
        None
    }
}

#[test]
fn pjrt_backend_prefill_decode_mixed_depths() {
    let Some(dir) = artifacts() else { return };
    let engine = Engine::load(&dir).unwrap();
    let runner = ModelRunner::new(&engine).unwrap();
    let mut backend = PjrtBackend::new(&runner).unwrap();
    let vocab = backend.vocab();

    // two sequences at different depths, decoded as one group
    let (lg_a, mut kv_a) = backend.prefill_one(&[1, 2, 3, 4, 5, 6]).unwrap();
    let (lg_b, mut kv_b) = backend.prefill_one(&[7, 8, 9]).unwrap();
    assert_eq!(lg_a.len(), vocab);
    assert_eq!(kv_a.pos, 6);
    assert_eq!(kv_b.pos, 3);
    assert!(lg_a.iter().all(|x| x.is_finite()));
    assert!(lg_b.iter().all(|x| x.is_finite()));

    // reference: decode each alone
    let (mut kv_a2, mut kv_b2) = (kv_a.clone(), kv_b.clone());
    let solo_a = backend.decode_batch(&[10], &mut [&mut kv_a2]).unwrap();
    let solo_b = backend.decode_batch(&[11], &mut [&mut kv_b2]).unwrap();

    // mixed group must match the solo results row-by-row
    let group = backend.decode_batch(&[10, 11], &mut [&mut kv_a, &mut kv_b]).unwrap();
    assert_eq!(kv_a.pos, 7);
    assert_eq!(kv_b.pos, 4);
    for i in 0..vocab {
        assert!(
            (group[0][i] - solo_a[0][i]).abs() < 2e-3,
            "row a logit {i}: {} vs {}",
            group[0][i],
            solo_a[0][i]
        );
        assert!(
            (group[1][i] - solo_b[0][i]).abs() < 2e-3,
            "row b logit {i}: {} vs {}",
            group[1][i],
            solo_b[0][i]
        );
    }
}

#[test]
fn scheduler_end_to_end_over_pjrt() {
    let Some(dir) = artifacts() else { return };
    let engine = Engine::load(&dir).unwrap();
    let runner = ModelRunner::new(&engine).unwrap();
    let backend = PjrtBackend::new(&runner).unwrap();

    let mut sched = Scheduler::new(
        backend,
        SchedulerConfig { kv_blocks: 64, block_tokens: 16, max_running: 4 },
    );
    for i in 0..6u64 {
        let prompt: Vec<i32> = (1..(4 + i as i32 % 5)).collect();
        sched.submit(Request::new(
            i,
            prompt,
            GenParams { max_new_tokens: 4 + (i as usize % 3), sample: false, seed: i },
        ));
    }
    let mut out = sched.run_to_completion().unwrap();
    assert_eq!(out.len(), 6);
    out.sort_by_key(|r| r.id);
    for (i, r) in out.iter().enumerate() {
        assert_eq!(r.tokens.len(), 4 + (i % 3), "request {i} token count");
        let vocab = sched.backend().vocab() as i32;
        assert!(r.tokens.iter().all(|&t| t >= 0 && t < vocab));
    }
    assert!(sched.metrics.mean_occupancy() > 1.0, "batching must engage");
    assert_eq!(sched.metrics.tokens_generated as usize, 4 + 5 + 6 + 4 + 5 + 6);
}

#[test]
fn scheduler_determinism_over_pjrt() {
    let Some(dir) = artifacts() else { return };
    let engine = Engine::load(&dir).unwrap();
    let runner = ModelRunner::new(&engine).unwrap();
    let run = |runner: &ModelRunner| {
        let backend = PjrtBackend::new(runner).unwrap();
        let mut sched = Scheduler::new(backend, SchedulerConfig::default());
        for i in 0..3u64 {
            sched.submit(Request::new(
                i,
                vec![2, 4, 6, 8],
                GenParams { max_new_tokens: 5, sample: false, seed: i },
            ));
        }
        let mut out = sched.run_to_completion().unwrap();
        out.sort_by_key(|r| r.id);
        out.iter().map(|r| r.tokens.clone()).collect::<Vec<_>>()
    };
    assert_eq!(run(&runner), run(&runner), "greedy decode must be deterministic");
}
