//! Curated suite for the Miri / ThreadSanitizer CI lanes
//! (`cargo miri test --test miri_suite`), sized for an interpreter that
//! runs ~1000× slower than native.  `harness = false` so the whole run
//! is one deterministic `main` with explicit teardown: Miri reports any
//! thread still alive at process exit as a leak, so the suite ends with
//! [`apllm::util::shutdown_pools`].
//!
//! Coverage targets the crate's unsafe surface:
//! * the `util::par` epoch protocol (`par_for`, nested submit,
//!   `par_chunks_mut` exact-coverage slicing);
//! * `SendPtr` disjoint-write aliasing/provenance (the pattern the
//!   column-shard and plane-pair kernels rely on);
//! * the `bitmm` packed kernels across every `ShardPolicy`, so the
//!   `unsafe` scatter in `pack_rows_into` and the raw-pointer writes in
//!   `apmm` run under the borrow tracker;
//! * worker panic propagation (pool stays usable afterwards).
//!
//! The suite also runs under the plain test harness (it is a normal
//! integration test), where it takes milliseconds.

use apllm::bitfmt::IntFormat;
use apllm::bitmm::{
    apmm_bipolar_packed, apmm_weighted_packed_opts, naive_gemm_decoded, pack_codes, ApmmOpts,
    CodeMatrix, ShardPolicy,
};
use apllm::util::{global_pool, par_chunks_mut, par_for, set_threads, shutdown_pools, SendPtr};
use std::sync::atomic::{AtomicUsize, Ordering};

/// Small-but-multithreaded problem sizes: Miri's scheduler interleaves
/// real threads, so 2 workers already exercise the handshake; SIZE keeps
/// the interpreter budget in seconds.
const SIZE: usize = 64;

fn par_for_covers_every_index() {
    let hits = AtomicUsize::new(0);
    par_for(SIZE, |_| {
        hits.fetch_add(1, Ordering::Relaxed);
    });
    assert_eq!(hits.load(Ordering::Relaxed), SIZE);
}

fn nested_submit_runs_inline() {
    let total = AtomicUsize::new(0);
    par_for(4, |_| {
        // A job that submits again must be inlined, not deadlock.
        par_for(3, |j| {
            total.fetch_add(j + 1, Ordering::Relaxed);
        });
    });
    assert_eq!(total.load(Ordering::Relaxed), 4 * (1 + 2 + 3));
}

fn par_chunks_mut_partitions_exactly() {
    let mut data = vec![0u32; SIZE + 7]; // non-multiple of chunk size
    par_chunks_mut(&mut data, 16, |ci, chunk| {
        for v in chunk.iter_mut() {
            *v = ci as u32 + 1;
        }
    });
    assert!(data.iter().all(|&v| v != 0), "every element written exactly once");
}

// The one deliberate `unsafe` outside the audited modules: it *is* the
// aliasing pattern under test, in a test target the xtask lint does not
// scan (it lints `src/` only).
#[allow(unsafe_code)]
fn sendptr_disjoint_writes() {
    // The kernels' aliasing pattern, distilled: one allocation, every
    // job writes its own element through a shared raw pointer.
    let mut out = vec![0usize; SIZE];
    let ptr = SendPtr::new(out.as_mut_ptr());
    global_pool().run(SIZE, |i| {
        // SAFETY: index `i` is handed to exactly one job, so writes are
        // disjoint; `out` outlives the epoch handshake in `run`.
        unsafe { *ptr.get().add(i) = i + 1 };
    });
    for (i, &v) in out.iter().enumerate() {
        assert_eq!(v, i + 1);
    }
}

fn worker_panic_propagates_and_pool_survives() {
    let caught = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
        par_for(8, |i| {
            if i == 3 {
                panic!("planted panic");
            }
        });
    }));
    assert!(caught.is_err(), "worker panic must reach the submitter");
    // The pool must have drained the failed epoch and still be usable.
    let hits = AtomicUsize::new(0);
    par_for(SIZE, |_| {
        hits.fetch_add(1, Ordering::Relaxed);
    });
    assert_eq!(hits.load(Ordering::Relaxed), SIZE);
}

fn bitmm_kernels_under_all_policies() {
    // Exercises pack_rows_into's parallel scatter and both raw-pointer
    // kernel paths (Cols, Planes) with k spanning >1 packed word.
    let w = CodeMatrix::random(3, 70, 3, 1);
    let xt = CodeMatrix::random(4, 70, 2, 2);
    let wp = pack_codes(&w);
    let xp = pack_codes(&xt);
    let want_b = naive_gemm_decoded(&w, &xt, IntFormat::Bipolar);
    let want_s = naive_gemm_decoded(&w, &xt, IntFormat::Signed);
    for shard in ShardPolicy::ALL {
        let opts = ApmmOpts { shard, tile_m: 2, tile_n: 2, workers: 2 };
        assert_eq!(apmm_bipolar_packed(&wp, &xp, opts), want_b, "bipolar {shard:?}");
        assert_eq!(
            apmm_weighted_packed_opts(&wp, &xp, IntFormat::Signed, opts),
            want_s,
            "signed {shard:?}"
        );
    }
}

fn main() {
    // Pin the worker count up front: deterministic across lanes, and it
    // keeps Miri from needing host env/parallelism queries mid-suite.
    set_threads(2);

    let tests: &[(&str, fn())] = &[
        ("par_for_covers_every_index", par_for_covers_every_index),
        ("nested_submit_runs_inline", nested_submit_runs_inline),
        ("par_chunks_mut_partitions_exactly", par_chunks_mut_partitions_exactly),
        ("sendptr_disjoint_writes", sendptr_disjoint_writes),
        ("worker_panic_propagates_and_pool_survives", worker_panic_propagates_and_pool_survives),
        ("bitmm_kernels_under_all_policies", bitmm_kernels_under_all_policies),
    ];
    for (name, f) in tests {
        println!("miri_suite::{name} ...");
        f();
        println!("miri_suite::{name} ok");
    }

    // Join every pooled worker so Miri's leak check sees a clean exit.
    shutdown_pools();
    println!("miri_suite: {} tests ok", tests.len());
}
