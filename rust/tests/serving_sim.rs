//! End-to-end pack-once serving (no artifacts needed): the continuous-
//! batching scheduler over `SimBackend::with_ap_gemm`, whose logits come
//! from the real prepacked bitmm kernel.  Verifies the §3.3 contract at
//! the serving layer: weights are decomposed+packed exactly once for the
//! whole run, activations recycle arena buffers, and generation is
//! deterministic.

use apllm::coordinator::{GenParams, Request, Scheduler, SchedulerConfig, SimBackend};

fn ap_backend(seed: u64) -> SimBackend {
    SimBackend::with_ap_gemm(96, 128, vec![1, 2, 4, 8], 128, 2, 2, seed)
}

fn req(id: u64, prompt_len: usize, max_new: usize) -> Request {
    Request::new(
        id,
        (1..=prompt_len as i32).collect(),
        GenParams { max_new_tokens: max_new, sample: false, seed: id },
    )
}

#[test]
fn scheduler_over_pack_once_backend() {
    let mut sched = Scheduler::new(
        ap_backend(3),
        SchedulerConfig { kv_blocks: 64, block_tokens: 16, max_running: 4 },
    );
    for i in 0..6u64 {
        sched.submit(req(i, 4 + (i as usize % 3), 5));
    }
    let out = sched.run_to_completion().unwrap();
    assert_eq!(out.len(), 6);
    assert!(out.iter().all(|r| r.tokens.len() == 5));
    let vocab = sched.backend().vocab as i32;
    assert!(out.iter().all(|r| r.tokens.iter().all(|&t| (0..vocab).contains(&t))));
    assert!(sched.metrics.mean_occupancy() > 1.0, "batching must engage");

    let s = sched.backend().ap_stats().unwrap();
    assert_eq!(s.weight_packs, 1, "weights packed exactly once for the whole run");
    // every prefill and every decode step packed one activation batch...
    let steps = sched.backend().prefills + sched.backend().decode_steps;
    assert_eq!(s.act_packs, steps);
    // ...and after warm-up those packs came from recycled buffers: one
    // allocation per distinct batch shape, everything else reused
    assert_eq!(s.arena_allocs + s.arena_reuses, s.act_packs);
    assert!(
        s.arena_allocs <= 4,
        "at most one buffer per decode group size, got {}",
        s.arena_allocs
    );
    assert!(s.arena_reuses > s.arena_allocs, "steady state must reuse");
}

#[test]
fn pack_once_serving_is_deterministic() {
    let run = || {
        let mut sched = Scheduler::new(ap_backend(9), SchedulerConfig::default());
        for i in 0..4u64 {
            sched.submit(req(i, 3, 4));
        }
        let mut out = sched.run_to_completion().unwrap();
        out.sort_by_key(|r| r.id);
        out.iter().map(|r| r.tokens.clone()).collect::<Vec<_>>()
    };
    assert_eq!(run(), run(), "greedy decode over prepacked weights must be deterministic");
}

#[test]
fn sim_serving_demo_reports_pack_once() {
    let a = apllm::coordinator::cli::ServeArgs {
        requests: 6,
        rate_per_s: 500.0,
        max_new: 4,
        prompt_len: 5,
        seed: 1,
        sim: true,
        ..Default::default()
    };
    let report = apllm::coordinator::cli::run_sim_serving_demo(&a).unwrap();
    assert!(report.contains("pack-once: weight packs 1"), "report was:\n{report}");
    assert!(report.contains("arena reuses"));
}

#[test]
fn engine_serving_demo_reports_pack_once_and_clean_kv() {
    let a = apllm::coordinator::cli::ServeArgs {
        requests: 8,
        rate_per_s: 500.0,
        max_new: 4,
        prompt_len: 5,
        seed: 2,
        sim: true,
        ..Default::default()
    };
    let report = apllm::coordinator::cli::run_engine_serving_demo(&a).unwrap();
    assert!(report.contains("pack-once: weight packs 1"), "report was:\n{report}");
    assert!(report.contains("kv: 64/64 blocks free"), "report was:\n{report}");
    assert!(report.contains("engine: steps"));
}

#[test]
fn cluster_serving_demo_reports_per_replica_breakdown() {
    let a = apllm::coordinator::cli::ServeArgs {
        requests: 10,
        rate_per_s: 500.0,
        max_new: 4,
        prompt_len: 5,
        seed: 3,
        sim: true,
        replicas: 3,
        ..Default::default()
    };
    let report = apllm::coordinator::cli::run_cluster_serving_demo(&a).unwrap();
    assert!(report.contains("cluster: 3 replicas"), "report was:\n{report}");
    assert!(report.contains("policy LeastLoaded"), "report was:\n{report}");
    assert!(report.contains("routed 10, completed 10, unroutable 0"), "report was:\n{report}");
    assert!(report.contains("r0 (W2A2)") && report.contains("r2 (W2A2)"), "report was:\n{report}");
    // every replica drained its pool: "kv free N/N" lines with equal sides
    for line in report.lines().filter(|l| l.contains("kv free")) {
        let frag = line.split("kv free ").nth(1).unwrap();
        let nums: Vec<&str> = frag.split(['/', ',']).take(2).collect();
        assert_eq!(nums[0], nums[1], "leaked blocks in: {line}");
    }
}
