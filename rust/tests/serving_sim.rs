//! End-to-end pack-once serving (no artifacts needed): the continuous-
//! batching engine over `SimBackend::with_ap_gemm`, whose logits come
//! from the real prepacked bitmm kernel.  Verifies the §3.3 contract at
//! the serving layer: weights are decomposed+packed exactly once for the
//! whole run, activations recycle arena buffers, and generation is
//! deterministic — plus the `AdmissionPolicy::Reserve` parity fixtures
//! against the retired group scheduler's replayed event stream.

mod common;

use apllm::coordinator::{
    AdmissionPolicy, Engine, EngineConfig, GenParams, Request, SimBackend,
};
use common::{legacy_scheduler_events, project};

fn ap_backend(seed: u64) -> SimBackend {
    SimBackend::with_ap_gemm(96, 128, vec![1, 2, 4, 8], 128, 2, 2, seed)
}

fn req(id: u64, prompt_len: usize, max_new: usize) -> Request {
    Request::new(
        id,
        (1..=prompt_len as i32).collect(),
        GenParams { max_new_tokens: max_new, sample: false, seed: id },
    )
}

fn reserve_cfg(kv_blocks: usize, block_tokens: usize, max_running: usize) -> EngineConfig {
    EngineConfig {
        kv_blocks,
        block_tokens,
        max_running,
        admission: AdmissionPolicy::Reserve,
        ..EngineConfig::default()
    }
}

#[test]
fn reserve_engine_over_pack_once_backend() {
    let mut eng = Engine::new(ap_backend(3), reserve_cfg(64, 16, 4));
    for i in 0..6u64 {
        eng.submit(req(i, 4 + (i as usize % 3), 5));
    }
    let out = eng.run_to_completion().unwrap();
    assert_eq!(out.len(), 6);
    assert!(out.iter().all(|r| r.tokens.len() == 5));
    let vocab = eng.backend().vocab as i32;
    assert!(out.iter().all(|r| r.tokens.iter().all(|&t| (0..vocab).contains(&t))));
    assert!(eng.metrics.mean_occupancy() > 1.0, "batching must engage");
    assert_eq!(eng.counters().preemptions, 0, "Reserve never preempts");
    assert_eq!(eng.pool().free_blocks(), eng.pool().total_blocks());

    let s = eng.backend().ap_stats().unwrap();
    assert_eq!(s.weight_packs, 1, "weights packed exactly once for the whole run");
    // every prefill and every decode step packed one activation batch...
    let steps = eng.backend().prefills + eng.backend().decode_steps;
    assert_eq!(s.act_packs, steps);
    // ...and after warm-up those packs came from recycled buffers: one
    // allocation per distinct batch shape, everything else reused
    assert_eq!(s.arena_allocs + s.arena_reuses, s.act_packs);
    assert!(
        s.arena_allocs <= 4,
        "at most one buffer per decode group size, got {}",
        s.arena_allocs
    );
    assert!(s.arena_reuses > s.arena_allocs, "steady state must reuse");
}

#[test]
fn pack_once_serving_is_deterministic() {
    let run = || {
        let mut eng = Engine::new(ap_backend(9), reserve_cfg(64, 16, 8));
        for i in 0..4u64 {
            eng.submit(req(i, 3, 4));
        }
        let mut out = eng.run_to_completion().unwrap();
        out.sort_by_key(|r| r.id);
        out.iter().map(|r| r.tokens.clone()).collect::<Vec<_>>()
    };
    assert_eq!(run(), run(), "greedy decode over prepacked weights must be deterministic");
}

/// The golden-fixture parity contract: on the suite's standard workload,
/// the `Reserve` engine's stream is byte-identical (modulo wall-clock
/// latency fields) to the retired group scheduler's, replayed by the
/// line-faithful oracle in `common`.
#[test]
fn reserve_engine_matches_group_scheduler_stream() {
    let workload: Vec<Request> = (0..6u64).map(|i| req(i, 4 + (i as usize % 3), 5)).collect();
    let golden = legacy_scheduler_events(ap_backend(3), 64, 16, 4, workload.clone());

    let mut eng = Engine::new(ap_backend(3), reserve_cfg(64, 16, 4));
    for r in workload {
        eng.submit(r);
    }
    let events = eng.run_to_completion_events().unwrap();
    assert_eq!(project(&events), golden, "Reserve engine diverged from the scheduler oracle");
    assert_eq!(eng.counters().preemptions, 0);
    assert_eq!(eng.counters().resumes, 0);
    assert_eq!(eng.pool().free_blocks(), eng.pool().total_blocks(), "KV leak");
    eng.pool().check_invariants().unwrap();
}

/// Same contract under KV pressure: a pool too small for all admissions
/// forces head-of-line blocking, and both sides must serialize the same
/// way — admissions interleave with completions, never a preemption.
#[test]
fn reserve_engine_matches_scheduler_stream_under_kv_pressure() {
    // budget per request: 8 + 8 = 16 tokens = 2 blocks of 8; a 5-block
    // pool fits two sequences, so the fifth admission waits on memory
    let workload: Vec<Request> = (0..5u64).map(|i| req(i, 8, 8)).collect();
    let golden = legacy_scheduler_events(ap_backend(7), 5, 8, 8, workload.clone());
    assert!(
        golden.iter().any(|e| matches!(e, common::Ev::Admitted(_))),
        "sanity: oracle admitted work"
    );

    let mut eng = Engine::new(ap_backend(7), reserve_cfg(5, 8, 8));
    for r in workload {
        eng.submit(r);
    }
    let events = eng.run_to_completion_events().unwrap();
    assert_eq!(project(&events), golden, "Reserve engine diverged under KV pressure");
    assert_eq!(eng.counters().preemptions, 0);
    assert_eq!(eng.pool().free_blocks(), eng.pool().total_blocks(), "KV leak");
}

#[test]
fn sim_serving_demo_reports_pack_once() {
    let a = apllm::coordinator::cli::ServeArgs {
        requests: 6,
        rate_per_s: 500.0,
        max_new: 4,
        prompt_len: 5,
        seed: 1,
        sim: true,
        ..Default::default()
    };
    let report = apllm::coordinator::cli::run_sim_serving_demo(&a).unwrap();
    assert!(report.contains("pack-once: weight packs 1"), "report was:\n{report}");
    assert!(report.contains("arena reuses"));
    assert!(report.contains("engine: steps"), "report was:\n{report}");
}

#[test]
fn engine_serving_demo_reports_pack_once_and_clean_kv() {
    let a = apllm::coordinator::cli::ServeArgs {
        requests: 8,
        rate_per_s: 500.0,
        max_new: 4,
        prompt_len: 5,
        seed: 2,
        sim: true,
        ..Default::default()
    };
    let report = apllm::coordinator::cli::run_engine_serving_demo(&a).unwrap();
    assert!(report.contains("pack-once: weight packs 1"), "report was:\n{report}");
    assert!(report.contains("kv: 128/128 blocks free"), "report was:\n{report}");
    assert!(report.contains("engine: steps"));
}

#[test]
fn cluster_serving_demo_reports_per_replica_breakdown() {
    let a = apllm::coordinator::cli::ServeArgs {
        requests: 10,
        rate_per_s: 500.0,
        max_new: 4,
        prompt_len: 5,
        seed: 3,
        sim: true,
        replicas: 3,
        ..Default::default()
    };
    let report = apllm::coordinator::cli::run_cluster_serving_demo(&a).unwrap();
    assert!(report.contains("cluster: 3 replicas"), "report was:\n{report}");
    assert!(report.contains("policy LeastLoaded"), "report was:\n{report}");
    assert!(report.contains("routed 10, completed 10, unroutable 0"), "report was:\n{report}");
    assert!(report.contains("r0 (W2A2)") && report.contains("r2 (W2A2)"), "report was:\n{report}");
    // every replica drained its pool: "kv free N/N" lines with equal sides
    for line in report.lines().filter(|l| l.contains("kv free")) {
        let frag = line.split("kv free ").nth(1).unwrap();
        let nums: Vec<&str> = frag.split(['/', ',']).take(2).collect();
        assert_eq!(nums[0], nums[1], "leaked blocks in: {line}");
    }
}
