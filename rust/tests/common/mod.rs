//! Shared helpers for the serving integration suites.
//!
//! The load-bearing piece is [`legacy_scheduler_events`]: a line-faithful
//! port of the retired group scheduler's step loop
//! (`coordinator/scheduler.rs`, deleted when its reserve-the-full-budget
//! admission semantics were folded into the engine as
//! `AdmissionPolicy::Reserve`).  It is the golden oracle the parity
//! tests replay: the engine under `Reserve` must stream a byte-identical
//! [`Ev`] sequence on the same workload.  The oracle deliberately skips
//! the metrics/wall-clock bookkeeping the real scheduler carried —
//! [`project`] strips exactly those nondeterministic fields from the
//! engine's stream before comparison, so both sides compare on
//! scheduling decisions and token bytes alone.

#![allow(dead_code)]

use apllm::coordinator::backend::{Backend, SeqKv};
use apllm::coordinator::{sample_token, KvPool, Request, TokenEvent};
use std::collections::VecDeque;

/// Timing-free projection of a [`TokenEvent`] stream: scheduling
/// decisions and token bytes only (responses carry wall-clock latency
/// fields that can never be replayed bit-exactly).
#[derive(Debug, Clone, PartialEq, Eq)]
pub enum Ev {
    Admitted(u64),
    Token { id: u64, step: usize, token: i32 },
    Preempted(u64),
    Resumed(u64),
    Finished { id: u64, tokens: Vec<i32> },
    /// Cluster-only markers (`PrefillDone`/`Migrated`/`Requantized`) —
    /// present so a parity mismatch names the stray variant instead of
    /// panicking in the projection.
    Other(&'static str),
}

pub fn project(events: &[TokenEvent]) -> Vec<Ev> {
    events
        .iter()
        .map(|e| match e {
            TokenEvent::Admitted { id } => Ev::Admitted(id.0),
            TokenEvent::Token { id, token, step } => {
                Ev::Token { id: id.0, step: *step, token: *token }
            }
            TokenEvent::Preempted { id } => Ev::Preempted(id.0),
            TokenEvent::Resumed { id } => Ev::Resumed(id.0),
            TokenEvent::Finished { id, response } => {
                Ev::Finished { id: id.0, tokens: response.tokens.clone() }
            }
            TokenEvent::PrefillDone { .. } => Ev::Other("prefill_done"),
            TokenEvent::Migrated { .. } => Ev::Other("migrated"),
            TokenEvent::Requantized { .. } => Ev::Other("requantized"),
        })
        .collect()
}

struct Active {
    req: Request,
    kv: SeqKv,
    next_token: i32,
    generated: Vec<i32>,
}

/// Replay the retired group scheduler over `backend`: full-budget
/// (`prompt + max_new`) reservation at admission, head-of-line blocking
/// on KV pressure, batch-1 prefill streaming the first token, one
/// batched decode per step, completions scanned with `swap_remove` —
/// never a preemption.  Steps until drained; returns the projected
/// event stream and asserts the pool comes back empty (zero KV leaks).
pub fn legacy_scheduler_events<B: Backend>(
    mut backend: B,
    kv_blocks: usize,
    block_tokens: usize,
    max_running: usize,
    reqs: Vec<Request>,
) -> Vec<Ev> {
    let max_running = max_running.min(*backend.supported_batches().last().unwrap());
    let mut pool = KvPool::new(kv_blocks, block_tokens);
    let mut queue: VecDeque<Request> = reqs.into();
    let mut running: Vec<Active> = Vec::new();
    let mut events = Vec::new();

    while !queue.is_empty() || !running.is_empty() {
        // 1+2: admission + prefill
        while running.len() < max_running {
            let Some(front) = queue.front() else { break };
            if front.prompt.is_empty() || front.prompt.len() > backend.max_prompt() {
                let req = queue.pop_front().unwrap();
                events.push(Ev::Finished { id: req.id.0, tokens: Vec::new() });
                continue;
            }
            let budget = front.prompt.len() + front.params.max_new_tokens;
            if !pool.can_admit(budget) {
                break; // head-of-line blocks until memory frees
            }
            let req = queue.pop_front().unwrap();
            pool.admit(req.id.0, budget).expect("oracle: can_admit then admit");
            events.push(Ev::Admitted(req.id.0));
            let (logits, kv) = backend.prefill_one(&req.prompt).expect("oracle: prefill");
            let tok = sample_token(&logits, &req.params, 0);
            events.push(Ev::Token { id: req.id.0, step: 0, token: tok });
            running.push(Active { req, kv, next_token: tok, generated: vec![tok] });
        }

        // 3: one batched decode over everything still below max_new
        let mut decode_idx: Vec<usize> = (0..running.len())
            .filter(|&i| running[i].generated.len() < running[i].req.params.max_new_tokens)
            .collect();
        if let Some(&maxb) = backend.supported_batches().last() {
            decode_idx.truncate(maxb);
        }
        if !decode_idx.is_empty() {
            let tokens: Vec<i32> = decode_idx.iter().map(|&i| running[i].next_token).collect();
            let mut kv_refs: Vec<&mut SeqKv> = running
                .iter_mut()
                .enumerate()
                .filter(|(i, _)| decode_idx.contains(i))
                .map(|(_, a)| &mut a.kv)
                .collect();
            let logits = backend.decode_batch(&tokens, &mut kv_refs).expect("oracle: decode");
            for (j, &i) in decode_idx.iter().enumerate() {
                let step = running[i].generated.len();
                let tok = sample_token(&logits[j], &running[i].req.params, step);
                let a = &mut running[i];
                a.next_token = tok;
                a.generated.push(tok);
                events.push(Ev::Token { id: a.req.id.0, step, token: tok });
            }
        }

        // 4: completion — swap_remove scan (the scramble shapes the
        // interleaving of every later decode, so parity depends on it)
        let mut i = 0;
        while i < running.len() {
            let done = running[i].generated.len() >= running[i].req.params.max_new_tokens
                || running[i].kv.pos >= backend.max_seq();
            if done {
                let a = running.swap_remove(i);
                pool.release(a.req.id.0).expect("oracle: release");
                events.push(Ev::Finished { id: a.req.id.0, tokens: a.generated });
            } else {
                i += 1;
            }
        }
    }

    assert_eq!(pool.free_blocks(), pool.total_blocks(), "oracle leaked KV blocks");
    pool.check_invariants().expect("oracle pool invariants");
    events
}
