//! Multi-replica cluster, end to end over the pack-once AP-GEMM backend
//! (no artifacts needed) — the PR's acceptance contract:
//!
//! * a 3-replica cluster behind `Router::LeastLoaded` serves a
//!   shared-prefix trace with **streamed `TokenEvent`s whose
//!   concatenation per request is byte-identical to the unbatched
//!   oracle** (each replica checked against its own independently
//!   constructed oracle backend);
//! * with the prefix cache on, the same trace allocates **measurably
//!   fewer KV blocks** than the no-sharing baseline;
//! * after drain: zero leaked blocks or refcounts on every replica's
//!   pool (`check_invariants`), and the router's load accounting is
//!   conserved and empty.

use apllm::coordinator::trace::{generate, TraceConfig};
use apllm::coordinator::{
    drive_unbatched, responses_of, ArrivalKind, Cluster, EngineConfig, Request, RoutePolicy,
    SimBackend, Stepper, TokenEvent,
};
use apllm::model::PrecisionConfig;
use std::collections::HashMap;

/// Every replica (and every oracle) is built with these parameters —
/// identical model replicas, as a real deployment would scale out.
fn replica_backend() -> SimBackend {
    SimBackend::with_ap_gemm(64, 256, vec![1, 2, 4, 8], 64, 2, 2, 17)
}

fn engine_cfg(prefix_sharing: bool) -> EngineConfig {
    EngineConfig {
        kv_blocks: 24,
        block_tokens: 4,
        max_running: 8,
        prefix_sharing,
        ..Default::default()
    }
}

/// Shared-prefix workload: 3 "system prompts" of 12 tokens, short tails.
fn shared_prefix_requests(n: usize) -> Vec<Request> {
    generate(&TraceConfig {
        kind: ArrivalKind::Poisson { rate: 1000.0 },
        requests: n,
        prompt_len: (1, 5), // tail after the prefix
        max_new: (2, 8),
        vocab: 64,
        seed: 23,
        shared_prefixes: 3,
        prefix_len: 12,
    })
    .into_iter()
    .map(|t| t.request)
    .collect()
}

fn build_cluster(sharing: bool) -> Cluster<SimBackend> {
    let mut c = Cluster::new(RoutePolicy::LeastLoaded);
    for i in 0..3 {
        c.add_replica(
            format!("r{i}"),
            PrecisionConfig::W2A2,
            replica_backend(),
            engine_cfg(sharing),
        );
    }
    c
}

#[test]
fn three_replica_cluster_streams_oracle_identical_tokens_and_saves_blocks() {
    let reqs = shared_prefix_requests(36);

    // three INDEPENDENT unbatched oracles, one per replica — identically
    // constructed, so every request has the same ground truth no matter
    // where the router places it; computing all three and cross-checking
    // pins that down rather than assuming it
    let mut oracles: Vec<SimBackend> = (0..3).map(|_| replica_backend()).collect();
    let want: Vec<Vec<i32>> = reqs
        .iter()
        .map(|r| {
            let per_oracle: Vec<Vec<i32>> = oracles
                .iter_mut()
                .map(|o| drive_unbatched(o, &r.prompt, &r.params).unwrap())
                .collect();
            assert!(
                per_oracle.windows(2).all(|w| w[0] == w[1]),
                "identically-built replicas must agree on request {}",
                r.id.0
            );
            per_oracle.into_iter().next().unwrap()
        })
        .collect();

    let mut fresh_allocs = [0u64; 2];
    for (slot, sharing) in [(0usize, true), (1usize, false)] {
        let mut cluster = build_cluster(sharing);
        for r in &reqs {
            cluster.submit(r.clone());
        }
        let events = cluster.run_to_completion_events().unwrap();

        // (a) per-request streamed tokens ≡ unbatched oracle ≡ response
        let mut streams: HashMap<u64, Vec<i32>> = HashMap::new();
        for ev in &events {
            if let TokenEvent::Token { id, token, .. } = ev {
                streams.entry(id.0).or_default().push(*token);
            }
        }
        let mut out = responses_of(&events);
        out.sort_by_key(|r| r.id);
        assert_eq!(out.len(), reqs.len());
        for (resp, want) in out.iter().zip(&want) {
            assert!(!resp.tokens.is_empty(), "request {} rejected", resp.id.0);
            assert_eq!(resp.tokens, *want, "request {} ≠ oracle (sharing={sharing})", resp.id.0);
            assert_eq!(
                &streams[&resp.id.0], want,
                "request {} stream ≠ oracle (sharing={sharing})",
                resp.id.0
            );
        }

        // (c) zero leaks anywhere after drain
        cluster.check_invariants().unwrap();
        for eng in cluster.engines() {
            assert_eq!(eng.pool().free_blocks(), eng.pool().total_blocks(), "leaked blocks");
            assert_eq!(eng.pool().used_blocks(), 0, "leaked refcounts");
        }
        assert_eq!(cluster.router().inflight(), 0, "router accounting drained");
        assert_eq!(cluster.router().routed, reqs.len() as u64);
        assert_eq!(cluster.router().completed, reqs.len() as u64);

        // all three replicas actually served (LeastLoaded spreads 36 reqs)
        let busy = cluster.engines().iter().filter(|e| e.counters().completed > 0).count();
        assert_eq!(busy, 3, "every replica must serve under least-loaded routing");

        fresh_allocs[slot] =
            cluster.engines().iter().map(|e| e.pool().sharing().fresh_allocs).sum();
        if sharing {
            let hits: u64 =
                cluster.engines().iter().map(|e| e.pool().sharing().shared_live).sum();
            let restores: u64 =
                cluster.engines().iter().map(|e| e.pool().sharing().cache_restores).sum();
            assert!(hits + restores > 0, "shared-prefix traffic must hit the prefix cache");
        }
    }

    // (b) sharing allocates measurably fewer blocks on the same trace
    assert!(
        fresh_allocs[0] < fresh_allocs[1],
        "prefix sharing allocated {} fresh blocks vs baseline {}",
        fresh_allocs[0],
        fresh_allocs[1]
    );
}

#[test]
fn mixed_precision_cluster_pins_requests_to_matching_replicas() {
    // two precisions behind one endpoint (the Any-Precision deployment
    // story): pinned requests land only on matching replicas
    let mut c = Cluster::new(RoutePolicy::LeastLoaded);
    c.add_replica("w2", PrecisionConfig::W2A2, replica_backend(), engine_cfg(true));
    c.add_replica(
        "w1",
        PrecisionConfig::W1A1,
        SimBackend::with_ap_gemm(64, 256, vec![1, 2, 4, 8], 64, 1, 1, 29),
        engine_cfg(true),
    );
    for i in 0..8u64 {
        let pin = if i % 2 == 0 { PrecisionConfig::W2A2 } else { PrecisionConfig::W1A1 };
        let mut r = Request::new(
            i,
            (1..=6).collect(),
            apllm::coordinator::GenParams { max_new_tokens: 4, sample: false, seed: i },
        );
        r = r.with_precision(pin);
        c.submit(r);
    }
    let events = c.run_to_completion_events().unwrap();
    let out = responses_of(&events);
    assert_eq!(out.len(), 8);
    assert!(out.iter().all(|r| r.tokens.len() == 4));
    assert_eq!(c.engine(0).counters().completed, 4, "W2A2 pins went to w2");
    assert_eq!(c.engine(1).counters().completed, 4, "W1A1 pins went to w1");
    assert_eq!(c.unroutable(), 0);
    c.check_invariants().unwrap();
}
