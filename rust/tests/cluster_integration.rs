//! Multi-replica cluster, end to end over the pack-once AP-GEMM backend
//! (no artifacts needed) — the acceptance contract across PRs 3 and 4:
//!
//! * a 3-replica cluster behind `Router::LeastLoaded` serves a
//!   shared-prefix trace with **streamed `TokenEvent`s whose
//!   concatenation per request is byte-identical to the unbatched
//!   oracle** (each replica checked against its own independently
//!   constructed oracle backend);
//! * with the prefix cache on, the same trace allocates **measurably
//!   fewer KV blocks** than the no-sharing baseline;
//! * a hot replica's swapped sequence **migrates to a peer and resumes
//!   there with a byte-identical token stream** (`Preempted` →
//!   `Migrated` → `Resumed` in order), deterministic and property-tested
//!   under random churn;
//! * after drain: zero leaked blocks or refcounts on every replica's
//!   pool (`check_invariants`), and the router's load accounting is
//!   conserved and empty — migration accounting included;
//! * (PR 5) a **mixed-precision cluster serves from one superset weight
//!   store** — `packed_bytes` equals the superset pack alone — and a
//!   swapped sequence **migrates across the precision boundary via
//!   re-prefill** with a byte-identical greedy token stream (already
//!   streamed bytes teacher-forced, continuation pinned by a composite
//!   two-precision oracle) and zero leaked KV blocks on both replicas;
//! * (PR 9) a **disaggregated prefill/decode cluster** (one prefill-role
//!   and one decode-role replica, built through the `ClusterSpec` /
//!   `ReplicaSpec` API like every cluster here) serves a bursty
//!   prefill-heavy workload with streams byte-identical to both the
//!   unbatched oracle and an all-Mixed cluster, `PrefillDone`
//!   immediately preceding each handoff's `Migrated`, every migration
//!   targeting a decode-capable replica, and zero KV leaks — with a
//!   property test driving random role topologies through tight pools.

use apllm::coordinator::trace::{generate, TraceConfig};
use apllm::coordinator::{
    drive_unbatched, responses_of, sample_token, superset_store, ArrivalKind, Backend, Cluster,
    ClusterSpec, EngineConfig, GenParams, ReplicaRole, ReplicaSpec, Request, RoutePolicy,
    SimBackend, Stepper, TokenEvent,
};
use apllm::model::PrecisionConfig;
use apllm::util::proptest::forall;
use std::collections::HashMap;

/// Every replica (and every oracle) is built with these parameters —
/// identical model replicas, as a real deployment would scale out.
fn replica_backend() -> SimBackend {
    SimBackend::with_ap_gemm(64, 256, vec![1, 2, 4, 8], 64, 2, 2, 17)
}

fn engine_cfg(prefix_sharing: bool) -> EngineConfig {
    EngineConfig {
        kv_blocks: 24,
        block_tokens: 4,
        max_running: 8,
        prefix_sharing,
        ..Default::default()
    }
}

/// Shared-prefix workload: 3 "system prompts" of 12 tokens, short tails.
fn shared_prefix_requests(n: usize) -> Vec<Request> {
    generate(&TraceConfig {
        kind: ArrivalKind::Poisson { rate: 1000.0 },
        requests: n,
        prompt_len: (1, 5), // tail after the prefix
        max_new: (2, 8),
        vocab: 64,
        seed: 23,
        shared_prefixes: 3,
        prefix_len: 12,
        prefix_skew: 0.0,
    })
    .into_iter()
    .map(|t| t.request)
    .collect()
}

fn build_cluster(sharing: bool) -> Cluster<SimBackend> {
    let mut spec = ClusterSpec::new(RoutePolicy::LeastLoaded);
    for i in 0..3 {
        spec = spec.replica(
            ReplicaSpec::new(format!("r{i}"), PrecisionConfig::W2A2).engine(engine_cfg(sharing)),
        );
    }
    Cluster::new(spec, |_| replica_backend())
}

#[test]
fn three_replica_cluster_streams_oracle_identical_tokens_and_saves_blocks() {
    let reqs = shared_prefix_requests(36);

    // three INDEPENDENT unbatched oracles, one per replica — identically
    // constructed, so every request has the same ground truth no matter
    // where the router places it; computing all three and cross-checking
    // pins that down rather than assuming it
    let mut oracles: Vec<SimBackend> = (0..3).map(|_| replica_backend()).collect();
    let want: Vec<Vec<i32>> = reqs
        .iter()
        .map(|r| {
            let per_oracle: Vec<Vec<i32>> = oracles
                .iter_mut()
                .map(|o| drive_unbatched(o, &r.prompt, &r.params).unwrap())
                .collect();
            assert!(
                per_oracle.windows(2).all(|w| w[0] == w[1]),
                "identically-built replicas must agree on request {}",
                r.id.0
            );
            per_oracle.into_iter().next().unwrap()
        })
        .collect();

    let mut fresh_allocs = [0u64; 2];
    for (slot, sharing) in [(0usize, true), (1usize, false)] {
        let mut cluster = build_cluster(sharing);
        for r in &reqs {
            cluster.submit(r.clone());
        }
        let events = cluster.run_to_completion_events().unwrap();

        // (a) per-request streamed tokens ≡ unbatched oracle ≡ response
        let mut streams: HashMap<u64, Vec<i32>> = HashMap::new();
        for ev in &events {
            if let TokenEvent::Token { id, token, .. } = ev {
                streams.entry(id.0).or_default().push(*token);
            }
        }
        let mut out = responses_of(&events);
        out.sort_by_key(|r| r.id);
        assert_eq!(out.len(), reqs.len());
        for (resp, want) in out.iter().zip(&want) {
            assert!(!resp.tokens.is_empty(), "request {} rejected", resp.id.0);
            assert_eq!(resp.tokens, *want, "request {} ≠ oracle (sharing={sharing})", resp.id.0);
            assert_eq!(
                &streams[&resp.id.0], want,
                "request {} stream ≠ oracle (sharing={sharing})",
                resp.id.0
            );
        }

        // (c) zero leaks anywhere after drain
        cluster.check_invariants().unwrap();
        for eng in cluster.engines() {
            assert_eq!(eng.pool().free_blocks(), eng.pool().total_blocks(), "leaked blocks");
            assert_eq!(eng.pool().used_blocks(), 0, "leaked refcounts");
        }
        assert_eq!(cluster.router().inflight(), 0, "router accounting drained");
        assert_eq!(cluster.router().routed, reqs.len() as u64);
        assert_eq!(cluster.router().completed, reqs.len() as u64);

        // all three replicas actually served (LeastLoaded spreads 36 reqs)
        let busy = cluster.engines().iter().filter(|e| e.counters().completed > 0).count();
        assert_eq!(busy, 3, "every replica must serve under least-loaded routing");

        fresh_allocs[slot] =
            cluster.engines().iter().map(|e| e.pool().sharing().fresh_allocs).sum();
        if sharing {
            let hits: u64 =
                cluster.engines().iter().map(|e| e.pool().sharing().shared_live).sum();
            let restores: u64 =
                cluster.engines().iter().map(|e| e.pool().sharing().cache_restores).sum();
            assert!(hits + restores > 0, "shared-prefix traffic must hit the prefix cache");
        }
    }

    // (b) sharing allocates measurably fewer blocks on the same trace
    assert!(
        fresh_allocs[0] < fresh_allocs[1],
        "prefix sharing allocated {} fresh blocks vs baseline {}",
        fresh_allocs[0],
        fresh_allocs[1]
    );
}

/// Two-replica cluster with a deliberately undersized "hot" replica 0 —
/// the migration scenario's fixture.
fn hot_cold_cluster() -> Cluster<SimBackend> {
    let spec = ClusterSpec::new(RoutePolicy::LeastLoaded)
        .replica(
            ReplicaSpec::new("hot", PrecisionConfig::W2A2)
                .engine(EngineConfig { kv_blocks: 6, block_tokens: 4, ..engine_cfg(true) }),
        )
        .replica(
            ReplicaSpec::new("cold", PrecisionConfig::W2A2)
                .engine(EngineConfig { kv_blocks: 32, block_tokens: 4, ..engine_cfg(true) }),
        );
    Cluster::new(spec, |_| replica_backend())
}

#[test]
fn hot_replica_swapped_sequence_resumes_on_peer_with_identical_stream() {
    // budgets of 20 tokens each: two of them overflow the hot replica's
    // 6-block pool mid-decode.  LeastLoaded routes A→hot, B→cold, C→hot
    // (ties break by index), so decoding preempts C on the hot replica,
    // which cannot resume it while A runs — the rebalancer must hand it
    // to the cold replica, where the stream continues byte-identically.
    let reqs: Vec<Request> = [100, 200, 300]
        .iter()
        .enumerate()
        .map(|(i, &base)| {
            Request::new(
                i as u64,
                (base..base + 12).collect(),
                GenParams { max_new_tokens: 8, sample: false, seed: i as u64 },
            )
        })
        .collect();
    let mut oracle = replica_backend();
    let want: Vec<Vec<i32>> =
        reqs.iter().map(|r| drive_unbatched(&mut oracle, &r.prompt, &r.params).unwrap()).collect();

    let mut cluster = hot_cold_cluster();
    for r in &reqs {
        cluster.submit(r.clone());
    }
    let events = cluster.run_to_completion_events().unwrap();

    // the migration is visible and well-ordered in the stream:
    // Preempted(C) precedes Migrated(C, hot→cold) precedes Resumed(C)
    let lifecycle: Vec<&TokenEvent> = events
        .iter()
        .filter(|ev| {
            ev.id().0 == 2
                && matches!(
                    ev,
                    TokenEvent::Preempted { .. }
                        | TokenEvent::Migrated { .. }
                        | TokenEvent::Resumed { .. }
                )
        })
        .collect();
    assert!(
        matches!(lifecycle[0], TokenEvent::Preempted { .. }),
        "first transition {lifecycle:?}"
    );
    assert!(
        matches!(lifecycle[1], TokenEvent::Migrated { from: 0, to: 1, .. }),
        "second transition {lifecycle:?}"
    );
    assert!(matches!(lifecycle[2], TokenEvent::Resumed { .. }), "third transition {lifecycle:?}");
    assert_eq!(cluster.migrations(), 1);
    assert_eq!(cluster.engine(0).counters().exported, 1);
    assert_eq!(cluster.engine(1).counters().imported, 1);
    assert_eq!(cluster.engine(1).counters().resumes, 1, "C resumed on the peer");
    assert_eq!(cluster.engine(0).counters().completed, 1);
    assert_eq!(cluster.engine(1).counters().completed, 2);

    // byte-identical streams, both as responses and as streamed tokens
    let mut streams: HashMap<u64, Vec<i32>> = HashMap::new();
    for ev in &events {
        if let TokenEvent::Token { id, token, .. } = ev {
            streams.entry(id.0).or_default().push(*token);
        }
    }
    let mut out = responses_of(&events);
    out.sort_by_key(|r| r.id);
    assert_eq!(out.len(), 3);
    for (resp, want) in out.iter().zip(&want) {
        assert_eq!(resp.tokens, *want, "request {} ≠ oracle", resp.id.0);
        assert_eq!(&streams[&resp.id.0], want, "request {} stream ≠ oracle", resp.id.0);
    }

    // zero leaks on BOTH replicas, conserved router, balanced migration
    // bookkeeping
    cluster.check_invariants().unwrap();
    for (i, eng) in cluster.engines().iter().enumerate() {
        assert_eq!(eng.pool().free_blocks(), eng.pool().total_blocks(), "replica {i} leaked");
        assert_eq!(eng.pool().used_blocks(), 0, "replica {i} leaked refcounts");
    }
    assert_eq!(cluster.router().inflight(), 0);
    assert_eq!(cluster.router().migrated, 1);
}

#[test]
fn prop_migration_preserves_streams_with_zero_leaks_on_both_replicas() {
    // random workloads through the hot/cold pair: whatever the
    // preemption/migration interleaving, every stream matches the
    // unbatched oracle and both pools drain clean.  The hot pool is 6
    // blocks × 4 tokens, so budgets are capped at 24 tokens to keep every
    // request individually admissible (no rejects to special-case).
    let total_migrations = std::cell::Cell::new(0u64);
    forall(16, |rng| {
        let n = rng.usize(3, 14);
        let reqs: Vec<Request> = (0..n)
            .map(|i| {
                let plen = rng.usize(1, 13);
                let max_new = rng.usize(1, 21 - plen); // budget ≤ 20 tokens (5 of 6 blocks)
                let base = rng.u32(1, 50) as i32;
                Request::new(
                    i as u64,
                    (base..base + plen as i32).collect(),
                    GenParams { max_new_tokens: max_new, sample: rng.bool(), seed: i as u64 },
                )
            })
            .collect();
        let mut oracle = replica_backend();
        let want: Vec<Vec<i32>> = reqs
            .iter()
            .map(|r| drive_unbatched(&mut oracle, &r.prompt, &r.params).unwrap())
            .collect();

        let mut cluster = hot_cold_cluster();
        for r in &reqs {
            cluster.submit(r.clone());
        }
        let events = cluster.run_to_completion_events().unwrap();
        let mut out = responses_of(&events);
        out.sort_by_key(|r| r.id);
        assert_eq!(out.len(), n);
        for (resp, want) in out.iter().zip(&want) {
            assert_eq!(resp.tokens, *want, "request {} ≠ oracle under migration", resp.id.0);
        }
        cluster.check_invariants().unwrap_or_else(|e| panic!("invariant: {e}"));
        for (i, eng) in cluster.engines().iter().enumerate() {
            assert_eq!(eng.pool().free_blocks(), eng.pool().total_blocks(), "replica {i} leaked");
            eng.pool().check_invariants().unwrap_or_else(|e| panic!("replica {i}: {e}"));
        }
        assert_eq!(cluster.router().inflight(), 0);
        total_migrations.set(total_migrations.get() + cluster.migrations());
    });
    assert!(
        total_migrations.get() > 0,
        "the hot/cold fixture must exercise migration at least once across seeds"
    );
}

#[test]
fn mixed_precision_cluster_serves_one_store_and_requantizes_via_reprefill() {
    // THE any-precision acceptance scenario: a W4A4 "hot" replica (tiny
    // pool) and a W2A2 "cold" replica serve from ONE shared 4-bit
    // superset store.  LeastLoaded lands A→hot, B→cold, C→hot; decoding
    // preempts C on the hot replica with no same-precision peer, so the
    // rebalancer crosses the precision boundary: C's KV is dropped, the
    // cold replica re-prefills prompt + generated tokens at W2A2, and the
    // stream continues — already-streamed bytes untouched, continuation
    // generated at the new precision and pinned by a composite oracle.
    let store = superset_store(64, 64, 4, 17);
    let superset_bytes = store.packed_bytes();
    assert!(superset_bytes > 0);
    assert_eq!(store.packed_bytes_at(4), superset_bytes, "the superset IS the 4-bit pack");
    assert_eq!(
        store.packed_bytes_at(2) * 2,
        superset_bytes,
        "a dedicated 2-bit store would cost half the superset again"
    );
    let backend_at = |nw: u32, nx: u32| {
        SimBackend::with_shared_store(256, vec![1, 2, 4, 8], store.clone(), nw, nx)
    };

    let spec = ClusterSpec::new(RoutePolicy::LeastLoaded)
        .replica(
            ReplicaSpec::new("hot-w4", PrecisionConfig::W4A4)
                .engine(EngineConfig { kv_blocks: 6, block_tokens: 4, ..engine_cfg(true) }),
        )
        .replica(
            ReplicaSpec::new("cold-w2", PrecisionConfig::W2A2)
                .engine(EngineConfig { kv_blocks: 32, block_tokens: 4, ..engine_cfg(true) }),
        );
    let mut cluster = Cluster::new(spec, |r| backend_at(r.precision.nw, r.precision.nx));
    // ONE store for the whole cluster: every replica reports the same
    // superset bytes (count it once) and nobody packed anything itself
    for eng in cluster.engines() {
        assert_eq!(eng.backend().packed_weight_bytes(), superset_bytes);
        assert_eq!(eng.backend().ap_stats().unwrap().weight_packs, 0, "packed once, outside");
    }

    let reqs: Vec<Request> = [100, 200, 300]
        .iter()
        .enumerate()
        .map(|(i, &base)| {
            Request::new(
                i as u64,
                (base..base + 12).collect(),
                GenParams { max_new_tokens: 8, sample: false, seed: i as u64 },
            )
        })
        .collect();
    for r in &reqs {
        cluster.submit(r.clone());
    }
    let events = cluster.run_to_completion_events().unwrap();

    // exactly one cross-precision migration, with the full stream grammar
    let requants: Vec<_> = events
        .iter()
        .filter_map(|ev| match ev {
            TokenEvent::Requantized { id, from_bits, to_bits } => {
                Some((id.0, *from_bits, *to_bits))
            }
            _ => None,
        })
        .collect();
    assert_eq!(requants, vec![(2, PrecisionConfig::W4A4, PrecisionConfig::W2A2)]);
    assert_eq!(cluster.migrations(), 1);
    assert_eq!(cluster.requants(), 1);
    assert_eq!(cluster.engine(0).counters().exported, 1);
    assert_eq!(cluster.engine(1).counters().imported, 1);
    assert_eq!(cluster.engine(1).counters().reprefills, 1, "cold rebuilt C's KV at W2A2");
    assert_eq!(cluster.engine(1).counters().resumes, 1);
    let lifecycle: Vec<&TokenEvent> = events
        .iter()
        .filter(|ev| {
            ev.id().0 == 2
                && !matches!(ev, TokenEvent::Token { .. } | TokenEvent::Admitted { .. })
        })
        .collect();
    assert!(matches!(lifecycle[0], TokenEvent::Preempted { .. }), "{lifecycle:?}");
    assert!(matches!(lifecycle[1], TokenEvent::Migrated { from: 0, to: 1, .. }), "{lifecycle:?}");
    assert!(matches!(lifecycle[2], TokenEvent::Requantized { .. }), "{lifecycle:?}");
    assert!(matches!(lifecycle[3], TokenEvent::Resumed { .. }), "{lifecycle:?}");

    // per-request streams
    let mut streams: HashMap<u64, Vec<i32>> = HashMap::new();
    let mut c_tokens_before_requant = 0usize;
    let mut seen_requant = false;
    for ev in &events {
        match ev {
            TokenEvent::Requantized { .. } => seen_requant = true,
            TokenEvent::Token { id, token, .. } => {
                if id.0 == 2 && !seen_requant {
                    c_tokens_before_requant += 1;
                }
                streams.entry(id.0).or_default().push(*token);
            }
            _ => {}
        }
    }
    let mut out = responses_of(&events);
    out.sort_by_key(|r| r.id);
    assert_eq!(out.len(), 3);
    for resp in &out {
        assert_eq!(streams[&resp.id.0], resp.tokens, "stream ≠ response for {:?}", resp.id);
        assert_eq!(resp.tokens.len(), 8);
    }

    // A ran wholly at W4A4, B wholly at W2A2: plain unbatched oracles
    // over fresh backends sharing the SAME store
    let mut oracle4 = backend_at(4, 4);
    let mut oracle2 = backend_at(2, 2);
    let want_a = drive_unbatched(&mut oracle4, &reqs[0].prompt, &reqs[0].params).unwrap();
    let want_b = drive_unbatched(&mut oracle2, &reqs[1].prompt, &reqs[1].params).unwrap();
    assert_eq!(out[0].tokens, want_a, "A ≠ W4A4 oracle");
    assert_eq!(out[1].tokens, want_b, "B ≠ W2A2 oracle");

    // C is the composite: its first g tokens are the W4A4 stream's
    // prefix (BYTE-IDENTICAL — requantization must not rewrite history),
    // and the continuation is exactly what a W2A2 re-prefill of
    // prompt + those tokens generates (greedy, seeded per step)
    let g = c_tokens_before_requant;
    assert!(g >= 1 && g < 8, "C must be mid-stream when it requantizes, got {g}");
    let want_c4 = drive_unbatched(&mut oracle4, &reqs[2].prompt, &reqs[2].params).unwrap();
    assert_eq!(out[2].tokens[..g], want_c4[..g], "pre-requant bytes rewritten");
    let mut want_c = out[2].tokens[..g].to_vec();
    let mut content = reqs[2].prompt.clone();
    content.extend_from_slice(&want_c[..g - 1]); // the gth token hasn't been fed yet
    let (_discarded, mut kv) = oracle2.prefill_one(&content).unwrap();
    while want_c.len() < reqs[2].params.max_new_tokens {
        let step = want_c.len();
        let logits = oracle2.decode_batch(&[want_c[step - 1]], &mut [&mut kv]).unwrap();
        want_c.push(sample_token(&logits[0], &reqs[2].params, step));
    }
    assert_eq!(out[2].tokens, want_c, "C ≠ composite W4A4→re-prefill→W2A2 oracle");
    // (whether the W2A2 tail *happens* to coincide with the W4A4 one is
    // model-dependent; the backend unit tests pin that the two precisions
    // really read different plane prefixes)

    // zero leaks on BOTH replicas, conserved router, balanced accounting
    cluster.check_invariants().unwrap();
    for (i, eng) in cluster.engines().iter().enumerate() {
        assert_eq!(eng.pool().free_blocks(), eng.pool().total_blocks(), "replica {i} leaked");
        assert_eq!(eng.pool().used_blocks(), 0, "replica {i} leaked refcounts");
    }
    assert_eq!(cluster.router().inflight(), 0);
    assert_eq!(cluster.router().migrated, 1);
}

#[test]
fn mixed_precision_cluster_pins_requests_to_matching_replicas() {
    // two precisions behind one endpoint (the Any-Precision deployment
    // story): pinned requests land only on matching replicas
    let spec = ClusterSpec::new(RoutePolicy::LeastLoaded)
        .replica(ReplicaSpec::new("w2", PrecisionConfig::W2A2).engine(engine_cfg(true)))
        .replica(ReplicaSpec::new("w1", PrecisionConfig::W1A1).engine(engine_cfg(true)));
    let mut c = Cluster::new(spec, |r| {
        if r.precision == PrecisionConfig::W1A1 {
            SimBackend::with_ap_gemm(64, 256, vec![1, 2, 4, 8], 64, 1, 1, 29)
        } else {
            replica_backend()
        }
    });
    for i in 0..8u64 {
        let pin = if i % 2 == 0 { PrecisionConfig::W2A2 } else { PrecisionConfig::W1A1 };
        let mut r = Request::new(
            i,
            (1..=6).collect(),
            apllm::coordinator::GenParams { max_new_tokens: 4, sample: false, seed: i },
        );
        r = r.with_precision(pin);
        c.submit(r);
    }
    let events = c.run_to_completion_events().unwrap();
    let out = responses_of(&events);
    assert_eq!(out.len(), 8);
    assert!(out.iter().all(|r| r.tokens.len() == 4));
    assert_eq!(c.engine(0).counters().completed, 4, "W2A2 pins went to w2");
    assert_eq!(c.engine(1).counters().completed, 4, "W1A1 pins went to w1");
    assert_eq!(c.unroutable(), 0);
    c.check_invariants().unwrap();
}

/// Per-request lifecycle grammar around migrations, as a paused-state
/// machine: a `Migrated` is only legal while its request is paused (its
/// own `PrefillDone` or `Preempted` streamed, with no token since — a
/// swapped sequence may migrate more than once under churn without a
/// fresh `Preempted`), no token streams while paused, and every pause
/// ends in a `Resumed` before the run drains.
fn assert_migration_grammar(events: &[TokenEvent]) {
    use std::collections::HashSet;
    let mut paused: HashSet<u64> = HashSet::new();
    for ev in events {
        match ev {
            TokenEvent::PrefillDone { id } | TokenEvent::Preempted { id } => {
                paused.insert(id.0);
            }
            TokenEvent::Migrated { id, .. } => {
                assert!(
                    paused.contains(&id.0),
                    "Migrated for {} without a preceding PrefillDone/Preempted pause",
                    id.0
                );
            }
            TokenEvent::Resumed { id } => {
                assert!(paused.remove(&id.0), "Resumed for {} while not paused", id.0);
            }
            TokenEvent::Token { id, .. } => {
                assert!(!paused.contains(&id.0), "request {} streamed a token while paused", id.0);
            }
            _ => {}
        }
    }
    assert!(paused.is_empty(), "requests still paused after drain: {paused:?}");
}

#[test]
fn disaggregated_split_cluster_streams_match_mixed_oracle_with_clean_handoffs() {
    // THE PR 9 acceptance scenario: a prefill-role replica and a
    // decode-role replica serve a bursty prefill-heavy trace.  Every
    // request admits on the prefill replica, prefills, streams
    // PrefillDone immediately before its Migrated, and decodes to
    // completion on the decode replica — with every streamed byte
    // identical to BOTH the unbatched oracle and an all-Mixed cluster of
    // the same shape (disaggregation redistributes work; it never
    // changes tokens).
    let reqs: Vec<Request> = generate(&TraceConfig {
        vocab: 64,
        ..TraceConfig::prefill_heavy(10, 4, 0.0, 23)
    })
    .into_iter()
    .map(|t| t.request)
    .collect();
    let mut oracle = replica_backend();
    let want: Vec<Vec<i32>> =
        reqs.iter().map(|r| drive_unbatched(&mut oracle, &r.prompt, &r.params).unwrap()).collect();

    let build = |roles: [ReplicaRole; 2]| {
        let spec = ClusterSpec::new(RoutePolicy::LeastLoaded)
            .replica(
                ReplicaSpec::new(format!("r0-{}", roles[0].label()), PrecisionConfig::W2A2)
                    .role(roles[0])
                    .engine(EngineConfig { kv_blocks: 32, block_tokens: 4, ..engine_cfg(true) }),
            )
            .replica(
                // the decode tier is provisioned so every handoff fits
                // (10 requests × ≤14 blocks each, decode slots > 10) —
                // the prefill replica should never have to decode locally
                ReplicaSpec::new(format!("r1-{}", roles[1].label()), PrecisionConfig::W2A2)
                    .role(roles[1])
                    .engine(EngineConfig {
                        kv_blocks: 160,
                        block_tokens: 4,
                        max_running: 12,
                        ..engine_cfg(true)
                    }),
            );
        Cluster::new(spec, |_| replica_backend())
    };
    let sorted_stream = |events: &[TokenEvent]| {
        let mut s: Vec<(u64, usize, i32)> = events
            .iter()
            .filter_map(|e| match e {
                TokenEvent::Token { id, token, step } => Some((id.0, *step, *token)),
                _ => None,
            })
            .collect();
        s.sort_unstable();
        s
    };

    let mut split = build([ReplicaRole::Prefill, ReplicaRole::Decode]);
    let mut mixed = build([ReplicaRole::Mixed, ReplicaRole::Mixed]);
    for r in &reqs {
        split.submit(r.clone());
        mixed.submit(r.clone());
    }
    let split_events = split.run_to_completion_events().unwrap();
    let mixed_events = mixed.run_to_completion_events().unwrap();

    // streams: split ≡ mixed ≡ unbatched oracle, per request and in full
    assert_eq!(sorted_stream(&split_events), sorted_stream(&mixed_events));
    let mut out = responses_of(&split_events);
    out.sort_by_key(|r| r.id);
    assert_eq!(out.len(), reqs.len());
    for (resp, want) in out.iter().zip(&want) {
        assert!(!resp.tokens.is_empty(), "request {} rejected", resp.id.0);
        assert_eq!(resp.tokens, *want, "request {} ≠ oracle on the split cluster", resp.id.0);
    }

    // handoffs happened, were all voluntary, and all landed on the
    // decode-capable replica
    assert!(split.prefill_handoffs() > 0, "prefill tier must hand work to the decode tier");
    assert_eq!(split.prefill_handoffs(), split.migrations(), "all moves were handoffs here");
    let prefill_done =
        split_events.iter().filter(|e| matches!(e, TokenEvent::PrefillDone { .. })).count();
    assert_eq!(prefill_done as u64, split.prefill_handoffs(), "every handoff streamed a marker");
    for (i, ev) in split_events.iter().enumerate() {
        // the handoff marker is adjacent: PrefillDone streams immediately
        // before its own Migrated
        if let TokenEvent::PrefillDone { id } = ev {
            assert!(
                matches!(split_events.get(i + 1),
                    Some(TokenEvent::Migrated { id: m, .. }) if m == id),
                "PrefillDone for {} not immediately followed by its Migrated",
                id.0
            );
        }
        if let TokenEvent::Migrated { to, .. } = ev {
            assert!(
                split.router().replicas()[*to].role.accepts_decode(),
                "migration targeted a prefill-only replica"
            );
        }
    }
    assert_migration_grammar(&split_events);
    assert_migration_grammar(&mixed_events);
    // the decode replica never admits fresh work; the prefill replica
    // never finishes a stream (its holds always found a taker here)
    assert_eq!(split.engine(1).counters().prefills, 0, "decode replica must not prefill");
    assert_eq!(split.engine(0).counters().completed, 0, "prefill replica must not decode");
    assert_eq!(split.engine(1).counters().completed as usize, reqs.len());
    assert!(mixed.prefill_handoffs() == 0, "mixed replicas never hold or hand off");

    // zero KV leaks on both tiers, router drained, invariants hold
    for c in [&split, &mixed] {
        c.check_invariants().unwrap();
        for (i, eng) in c.engines().iter().enumerate() {
            assert_eq!(eng.pool().free_blocks(), eng.pool().total_blocks(), "replica {i} leaked");
            assert_eq!(eng.pool().used_blocks(), 0, "replica {i} leaked refcounts");
        }
        assert_eq!(c.router().inflight(), 0);
    }
}

#[test]
fn prop_random_role_topologies_respect_roles_and_match_the_oracle() {
    // random role assignments over 2–3 replicas with tight pools: under
    // any interleaving of handoffs, preemptions, and rebalances, every
    // stream matches the unbatched oracle, a decoding sequence never
    // lands on a prefill-only replica, and both pools drain clean.
    let total_handoffs = std::cell::Cell::new(0u64);
    forall(16, |rng| {
        let n_replicas = rng.usize(2, 4);
        let roles: Vec<ReplicaRole> = (0..n_replicas)
            .map(|i| {
                if i == 0 {
                    // replica 0 is always prefill-capable so every
                    // request routes (the spec builder insists on one)
                    if rng.bool() { ReplicaRole::Prefill } else { ReplicaRole::Mixed }
                } else {
                    match rng.usize(0, 3) {
                        0 => ReplicaRole::Prefill,
                        1 => ReplicaRole::Decode,
                        _ => ReplicaRole::Mixed,
                    }
                }
            })
            .collect();
        let mut spec = ClusterSpec::new(RoutePolicy::LeastLoaded);
        for (i, &role) in roles.iter().enumerate() {
            // prefill-capable pools stay tight (6 blocks = 24 tokens, so
            // concurrent budgets preempt); pure decode pools are roomier
            let kv_blocks = if role == ReplicaRole::Decode { 32 } else { 6 };
            spec = spec.replica(
                ReplicaSpec::new(format!("r{i}"), PrecisionConfig::W2A2)
                    .role(role)
                    .engine(EngineConfig { kv_blocks, block_tokens: 4, ..engine_cfg(true) }),
            );
        }
        let mut cluster = Cluster::new(spec, |_| replica_backend());

        let n = rng.usize(3, 12);
        let reqs: Vec<Request> = (0..n)
            .map(|i| {
                let plen = rng.usize(1, 13);
                let max_new = rng.usize(1, 21 - plen); // budget ≤ 20 tokens (5 of 6 blocks)
                let base = rng.u32(1, 50) as i32;
                Request::new(
                    i as u64,
                    (base..base + plen as i32).collect(),
                    GenParams { max_new_tokens: max_new, sample: rng.bool(), seed: i as u64 },
                )
            })
            .collect();
        let mut oracle = replica_backend();
        let want: Vec<Vec<i32>> = reqs
            .iter()
            .map(|r| drive_unbatched(&mut oracle, &r.prompt, &r.params).unwrap())
            .collect();
        for r in &reqs {
            cluster.submit(r.clone());
        }
        let events = cluster.run_to_completion_events().unwrap();
        let mut out = responses_of(&events);
        out.sort_by_key(|r| r.id);
        assert_eq!(out.len(), n);
        for (resp, want) in out.iter().zip(&want) {
            assert_eq!(resp.tokens, *want, "request {} ≠ oracle (roles {roles:?})", resp.id.0);
        }
        // the role contract under churn: every migration — handoff or
        // rebalance — landed on a decode-capable replica
        for ev in &events {
            if let TokenEvent::Migrated { to, .. } = ev {
                assert!(
                    roles[*to].accepts_decode(),
                    "migration to prefill-only replica {to} (roles {roles:?})"
                );
            }
        }
        assert_migration_grammar(&events);
        cluster.check_invariants().unwrap_or_else(|e| panic!("invariant: {e}"));
        for (i, eng) in cluster.engines().iter().enumerate() {
            assert_eq!(eng.pool().free_blocks(), eng.pool().total_blocks(), "replica {i} leaked");
            eng.pool().check_invariants().unwrap_or_else(|e| panic!("replica {i}: {e}"));
        }
        assert_eq!(cluster.router().inflight(), 0);
        total_handoffs.set(total_handoffs.get() + cluster.prefill_handoffs());
    });
    assert!(
        total_handoffs.get() > 0,
        "random topologies must exercise the prefill→decode handoff at least once"
    );
}
