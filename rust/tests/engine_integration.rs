//! Continuous-batching engine, end to end over the pack-once AP-GEMM
//! backend (no artifacts needed).  The acceptance contract:
//!
//! * ≥ 64 requests with mixed prompt/decode lengths complete through the
//!   iteration-level loop with **token streams identical to the unbatched
//!   path** (same backend driven one request at a time) — and the
//!   concatenation of each request's streamed `Token` events is
//!   byte-identical to its terminal response;
//! * zero KV blocks leak, with the pool invariants holding under the
//!   admit/decode/finish/preempt churn the tight pool forces (prefix
//!   sharing on: the common prompt heads share refcounted blocks);
//! * weights are decomposed+packed **exactly once** for the whole run,
//!   every step packing only its activation batch through the recycling
//!   arena;
//! * self-speculative decoding (low-bit plane-prefix draft, wide batched
//!   verify) changes how many backend calls run, never what streams:
//!   spec_k ∈ {0, 2, 4} produce byte-identical token streams under the
//!   same churn.

use apllm::coordinator::{
    drive_unbatched, responses_of, Engine, EngineConfig, GenParams, Request, SimBackend,
    TokenEvent,
};
use std::collections::HashMap;

/// AP-GEMM sim backend: logits from the real prepacked bitmm kernel.
fn ap_backend(seed: u64) -> SimBackend {
    SimBackend::with_ap_gemm(64, 256, vec![1, 2, 4, 8], 64, 2, 2, seed)
}

fn req(id: u64, prompt_len: usize, max_new: usize) -> Request {
    Request::new(
        id,
        (1..=prompt_len as i32).collect(),
        GenParams { max_new_tokens: max_new, sample: false, seed: id },
    )
}

/// Unbatched ground truth via the library's shared reference oracle.
fn unbatched(backend: &mut SimBackend, r: &Request) -> Vec<i32> {
    drive_unbatched(backend, &r.prompt, &r.params).unwrap()
}

/// Per-request concatenation of streamed `Token` payloads.
fn streamed_tokens(events: &[TokenEvent]) -> HashMap<u64, Vec<i32>> {
    let mut m: HashMap<u64, Vec<i32>> = HashMap::new();
    for ev in events {
        if let TokenEvent::Token { id, token, .. } = ev {
            m.entry(id.0).or_default().push(*token);
        }
    }
    m
}

#[test]
fn engine_64_requests_match_unbatched_with_zero_leaks_and_one_weight_pack() {
    // mixed lengths: prompts 1..=16, budgets 1..=12 — the (1..=plen)
    // prompts are prefixes of one another, so the prefix cache shares
    // their heads while the tight pool still forces preemption churn
    let reqs: Vec<Request> = (0..64u64)
        .map(|i| req(i, 1 + (i as usize * 7) % 16, 1 + (i as usize * 5) % 12))
        .collect();

    // ground truth over an identically-constructed backend
    let mut reference = ap_backend(11);
    let want: Vec<Vec<i32>> = reqs.iter().map(|r| unbatched(&mut reference, r)).collect();

    // tight pool: 16 blocks × 4 tokens against 8 concurrent sequences of
    // up to 28-token budgets — decode growth must hit the allocator's
    // clean failure and preempt
    let cfg = EngineConfig { kv_blocks: 16, block_tokens: 4, max_running: 8, ..Default::default() };
    let mut eng = Engine::new(ap_backend(11), cfg);
    for r in &reqs {
        eng.submit(r.clone());
    }
    let events = eng.run_to_completion_events().unwrap();
    let mut out = responses_of(&events);
    out.sort_by_key(|r| r.id);

    // every request completes with the unbatched token stream, and the
    // streamed events concatenate to exactly that stream
    assert_eq!(out.len(), 64);
    let streams = streamed_tokens(&events);
    for (resp, want) in out.iter().zip(&want) {
        assert_eq!(resp.tokens, *want, "request {} diverged from unbatched path", resp.id.0);
        assert_eq!(&streams[&resp.id.0], want, "request {} stream ≠ response", resp.id.0);
    }

    // churn actually happened, and conserved every block
    let c = eng.counters();
    assert!(c.preemptions > 0, "tight pool must force preemption, counters: {c:?}");
    assert_eq!(c.resumes, c.preemptions);
    assert_eq!(c.completed, 64);
    assert_eq!(eng.pool().free_blocks(), 16, "zero KV-block leaks");
    eng.pool().check_invariants().unwrap();
    // the common (1..=N) prompt heads really shared blocks
    assert!(eng.pool().sharing().shared_live > 0, "prefix cache must hit on shared heads");

    // §3.3 under churn: one weight pack for the whole run, one activation
    // pack per backend step, recycled buffers in steady state
    let s = eng.backend().ap_stats().unwrap();
    assert_eq!(s.weight_packs, 1, "weights must be packed exactly once");
    let steps = eng.backend().prefills + eng.backend().decode_steps;
    assert_eq!(s.act_packs, steps);
    assert_eq!(s.arena_allocs + s.arena_reuses, s.act_packs);
    assert!(
        s.arena_allocs <= 8,
        "at most one plane buffer per batch size, got {}",
        s.arena_allocs
    );
    assert!(s.arena_reuses > s.arena_allocs, "steady state must reuse");
}

#[test]
fn engine_matches_unbatched_under_sampling_too() {
    // seeded Gumbel sampling is per-(request, step): batching, sharing
    // and preemption must not perturb sampled streams either
    let reqs: Vec<Request> = (0..12u64)
        .map(|i| {
            Request::new(
                i,
                (1..=(2 + (i as usize * 3) % 9) as i32).collect(),
                GenParams { max_new_tokens: 2 + (i as usize) % 7, sample: true, seed: 1000 + i },
            )
        })
        .collect();
    let mut reference = ap_backend(5);
    let want: Vec<Vec<i32>> = reqs.iter().map(|r| unbatched(&mut reference, r)).collect();

    let cfg = EngineConfig { kv_blocks: 8, block_tokens: 4, max_running: 4, ..Default::default() };
    let mut eng = Engine::new(ap_backend(5), cfg);
    for r in &reqs {
        eng.submit(r.clone());
    }
    let mut out = eng.run_to_completion().unwrap();
    out.sort_by_key(|r| r.id);
    assert_eq!(out.len(), 12);
    for (resp, want) in out.iter().zip(&want) {
        assert_eq!(resp.tokens, *want, "sampled request {} diverged", resp.id.0);
    }
    assert_eq!(eng.pool().free_blocks(), 8);
}

#[test]
fn streams_byte_identical_across_worker_counts() {
    // the intra-GEMM sharding tentpole end to end: per-shard i64 partial
    // sums are exact, so fanning the logits GEMM across 1, 2 or 4 pool
    // workers must not move a single streamed byte — preemption churn,
    // prefix sharing and all
    let reqs: Vec<Request> =
        (0..24u64).map(|i| req(i, 1 + (i as usize * 7) % 16, 1 + (i as usize * 5) % 10)).collect();
    let run = |workers: usize| {
        let cfg = EngineConfig {
            kv_blocks: 16,
            block_tokens: 4,
            max_running: 8,
            workers,
            ..Default::default()
        };
        let mut eng = Engine::new(ap_backend(29), cfg);
        for r in &reqs {
            eng.submit(r.clone());
        }
        let events = eng.run_to_completion_events().unwrap();
        let mut out = responses_of(&events);
        out.sort_by_key(|r| r.id);
        assert_eq!(out.len(), 24);
        assert_eq!(eng.pool().free_blocks(), 16, "zero KV-block leaks at {workers} workers");
        let tokens: Vec<Vec<i32>> = out.into_iter().map(|r| r.tokens).collect();
        (streamed_tokens(&events), tokens)
    };
    let (ref_streams, ref_tokens) = run(1);
    for workers in [2usize, 4] {
        let (streams, tokens) = run(workers);
        assert_eq!(tokens, ref_tokens, "responses diverged at {workers} workers");
        assert_eq!(streams, ref_streams, "streamed events diverged at {workers} workers");
    }
}

#[test]
fn streams_byte_identical_across_spec_k_under_preemption_churn() {
    // the speculative-decoding tentpole end to end: drafting from the
    // 3-bit plane prefix and verifying at W4 is a pure execution
    // strategy — whatever the spec_k, through the tight pool's
    // preemption churn, prefix sharing, and a mix of greedy and sampled
    // requests, not one streamed byte may move
    let w4 = |seed: u64| SimBackend::with_ap_gemm(64, 256, vec![1, 2, 4, 8, 16], 64, 4, 2, seed);
    let reqs: Vec<Request> = (0..24u64)
        .map(|i| {
            let mut r = req(i, 1 + (i as usize * 7) % 16, 1 + (i as usize * 5) % 10);
            if i % 3 == 0 {
                // sampled acceptance must hold too: draft and verify
                // replay the same seeded Gumbel stream per (seed, step)
                r.params.sample = true;
                r.params.seed = 500 + i;
            }
            r
        })
        .collect();
    let run = |spec_k: usize| {
        let cfg = EngineConfig {
            kv_blocks: 16,
            block_tokens: 4,
            max_running: 8,
            spec_k,
            draft_bits: 3,
            ..Default::default()
        };
        let mut eng = Engine::new(w4(17), cfg);
        for r in &reqs {
            eng.submit(r.clone());
        }
        let events = eng.run_to_completion_events().unwrap();
        let mut out = responses_of(&events);
        out.sort_by_key(|r| r.id);
        assert_eq!(out.len(), 24);
        assert_eq!(eng.pool().free_blocks(), 16, "KV leak at spec_k {spec_k}");
        eng.pool().check_invariants().unwrap();
        let c = eng.counters();
        if spec_k == 0 {
            assert_eq!(c.drafted, 0, "spec_k=0 must never draft");
        } else {
            assert!(c.drafted > 0, "spec_k {spec_k} never drafted");
            assert!(c.accepted <= c.drafted);
        }
        assert!(c.preemptions > 0, "churn must preempt at spec_k {spec_k}");
        let tokens: Vec<Vec<i32>> = out.into_iter().map(|r| r.tokens).collect();
        (streamed_tokens(&events), tokens)
    };
    let (ref_streams, ref_tokens) = run(0);
    for spec_k in [2usize, 4] {
        let (streams, tokens) = run(spec_k);
        assert_eq!(tokens, ref_tokens, "responses diverged at spec_k {spec_k}");
        assert_eq!(streams, ref_streams, "streamed events diverged at spec_k {spec_k}");
    }
}

#[test]
fn event_stream_lifecycle_is_well_formed_under_preemption_churn() {
    // per request: exactly one Admitted, Preempted/Resumed strictly
    // alternating after it, exactly one terminal Finished, and no Token
    // while swapped out
    let reqs: Vec<Request> = (0..24u64).map(|i| req(i, 1 + (i as usize * 7) % 16, 6)).collect();
    let cfg = EngineConfig { kv_blocks: 12, block_tokens: 4, max_running: 8, ..Default::default() };
    let mut eng = Engine::new(ap_backend(3), cfg);
    for r in &reqs {
        eng.submit(r.clone());
    }
    let events = eng.run_to_completion_events().unwrap();
    assert!(eng.counters().preemptions > 0, "churn must preempt");

    #[derive(PartialEq, Debug)]
    enum St {
        Unseen,
        Running,
        Swapped,
        Done,
    }
    let mut state: HashMap<u64, St> = HashMap::new();
    for ev in &events {
        let id = ev.id().0;
        let st = state.entry(id).or_insert(St::Unseen);
        match ev {
            TokenEvent::Admitted { .. } => {
                assert_eq!(*st, St::Unseen, "req {id} admitted twice");
                *st = St::Running;
            }
            TokenEvent::Token { .. } => {
                assert_eq!(*st, St::Running, "req {id} token while {st:?}");
            }
            TokenEvent::Preempted { .. } => {
                assert_eq!(*st, St::Running, "req {id} preempted while {st:?}");
                *st = St::Swapped;
            }
            TokenEvent::Migrated { .. } | TokenEvent::Requantized { .. } => {
                // only a cluster's rebalancer emits these, and only for
                // swapped sequences; a lone engine must never produce one
                panic!("req {id} migrated/requantized outside a cluster");
            }
            TokenEvent::Resumed { .. } => {
                assert_eq!(*st, St::Swapped, "req {id} resumed while {st:?}");
                *st = St::Running;
            }
            TokenEvent::Finished { response, .. } => {
                assert_eq!(*st, St::Running, "req {id} finished while {st:?}");
                assert!(!response.tokens.is_empty());
                *st = St::Done;
            }
        }
    }
    assert_eq!(state.len(), 24);
    assert!(state.values().all(|s| *s == St::Done), "every request reached Done");
    assert_eq!(eng.pool().free_blocks(), 12);
}
