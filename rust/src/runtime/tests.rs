//! Runtime unit tests that don't require artifacts (manifest parsing on
//! synthetic JSON); the PJRT integration tests live in
//! `rust/tests/integration.rs` and skip gracefully when `artifacts/` is
//! absent.

use super::manifest::*;
use std::io::Write;

fn write_manifest(dir: &std::path::Path, body: &str) {
    let mut f = std::fs::File::create(dir.join("manifest.json")).unwrap();
    f.write_all(body.as_bytes()).unwrap();
}

fn tmpdir(tag: &str) -> std::path::PathBuf {
    let d = std::env::temp_dir().join(format!("apllm-test-{tag}-{}", std::process::id()));
    std::fs::create_dir_all(&d).unwrap();
    d
}

const SAMPLE: &str = r#"{
 "version": 1,
 "model": {
   "config": {"vocab": 256, "dim": 64, "n_layers": 2, "n_heads": 4,
              "n_kv_heads": 2, "ffn": 128, "max_seq": 32, "nw": 2, "nx": 2},
   "weights_file": "weights.bin",
   "weights": [
     {"name": "tok_emb", "dtype": "f32", "shape": [256, 64], "offset": 0, "nbytes": 65536}
   ]
 },
 "executables": [
  {"name": "apmm_w2a2_64x256x64", "kind": "apmm", "hlo": "a.hlo.txt",
   "inputs": [{"name": "wp", "dtype": "u32", "shape": [2, 64, 8]},
              {"name": "xp", "dtype": "u32", "shape": [2, 64, 8]}],
   "outputs": [{"name": "y", "dtype": "i32", "shape": [64, 64]}],
   "meta": {"m": 64, "k": 256, "n": 64, "nw": 2, "nx": 2}},
  {"name": "model_decode_b2", "kind": "decode", "hlo": "d.hlo.txt",
   "inputs": [], "outputs": [], "meta": {"batch": 2}},
  {"name": "model_prefill_b2_t16", "kind": "prefill", "hlo": "p.hlo.txt",
   "inputs": [], "outputs": [], "meta": {"batch": 2, "seq": 16}}
 ]
}"#;

#[test]
fn manifest_parses_typed() {
    let d = tmpdir("manifest");
    write_manifest(&d, SAMPLE);
    let m = Manifest::load(&d).unwrap();
    assert_eq!(m.version, 1);
    assert_eq!(m.executables.len(), 3);

    let apmm = m.find("apmm_w2a2_64x256x64").unwrap();
    assert_eq!(apmm.kind, "apmm");
    assert_eq!(apmm.inputs[0].dtype, DType::U32);
    assert_eq!(apmm.inputs[0].elements(), 2 * 64 * 8);
    assert_eq!(apmm.meta_usize("k").unwrap(), 256);
    assert!(apmm.meta_usize("missing").is_err());

    let model = m.model.as_ref().unwrap();
    assert_eq!(model.config.dim, 64);
    assert_eq!(model.config.head_dim(), 16);
    assert_eq!(model.config.kv_elements(2), 2 * 2 * 32 * 2 * 16);
    assert_eq!(model.weights[0].nbytes, 65536);
}

#[test]
fn manifest_lookup_helpers() {
    let d = tmpdir("lookup");
    write_manifest(&d, SAMPLE);
    let m = Manifest::load(&d).unwrap();
    assert_eq!(m.by_kind("decode").len(), 1);
    assert!(m.decode_for_batch(2).is_ok());
    assert!(m.decode_for_batch(4).is_err());
    assert!(m.prefill_for(2, 10).is_ok(), "seq 16 bucket covers t=10");
    assert!(m.prefill_for(2, 20).is_err(), "no bucket ≥ 20");
    assert!(m.find("nope").is_err());
}

#[test]
fn manifest_null_model() {
    let d = tmpdir("nullmodel");
    write_manifest(&d, r#"{"version": 1, "model": null, "executables": []}"#);
    let m = Manifest::load(&d).unwrap();
    assert!(m.model.is_none());
    assert!(m.executables.is_empty());
}

#[test]
fn manifest_missing_file_errors() {
    let d = tmpdir("missing");
    let _ = std::fs::remove_file(d.join("manifest.json"));
    let err = Manifest::load(&d).unwrap_err().to_string();
    assert!(err.contains("make artifacts"), "err was: {err}");
}

#[test]
fn dtype_parse() {
    assert_eq!(DType::parse("f32").unwrap(), DType::F32);
    assert_eq!(DType::parse("u32").unwrap(), DType::U32);
    assert!(DType::parse("f64").is_err());
}
