//! The PJRT engine: compile-once executable cache + typed execution, and
//! the `ModelRunner` serving the L2 model.

use super::manifest::{ExecSpec, Manifest, ModelCfg};
use super::tensor::{lit_i32, lit_u32};
use crate::anyhow::{anyhow, bail, Context, Result};
use std::collections::HashMap;
use xla::{Literal, PjRtClient, PjRtLoadedExecutable};

/// Loads the manifest, compiles HLO-text executables on the PJRT CPU
/// client (once, cached), and executes them.
///
/// Not `Send`: PJRT handles are thread-affine here; the coordinator owns
/// an `Engine` on a dedicated executor thread (see `coordinator`).
pub struct Engine {
    client: PjRtClient,
    manifest: Manifest,
    cache: std::cell::RefCell<HashMap<String, std::rc::Rc<PjRtLoadedExecutable>>>,
}

impl Engine {
    /// Load `<dir>/manifest.json` and start a PJRT CPU client.
    pub fn load(dir: &std::path::Path) -> Result<Self> {
        let manifest = Manifest::load(dir)?;
        let client = PjRtClient::cpu().map_err(|e| anyhow!("PJRT cpu client: {e:?}"))?;
        Ok(Self { client, manifest, cache: Default::default() })
    }

    pub fn manifest(&self) -> &Manifest {
        &self.manifest
    }

    pub fn client(&self) -> &PjRtClient {
        &self.client
    }

    /// Compile (or fetch the cached) executable by manifest name.
    pub fn compile(&self, name: &str) -> Result<std::rc::Rc<PjRtLoadedExecutable>> {
        if let Some(exe) = self.cache.borrow().get(name) {
            return Ok(exe.clone());
        }
        let spec = self.manifest.find(name)?;
        let path = self.manifest.dir.join(&spec.hlo);
        let proto = xla::HloModuleProto::from_text_file(&path)
            .map_err(|e| anyhow!("parsing HLO text {}: {e:?}", path.display()))?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = std::rc::Rc::new(
            self.client.compile(&comp).map_err(|e| anyhow!("compiling {name}: {e:?}"))?,
        );
        self.cache.borrow_mut().insert(name.to_string(), exe.clone());
        Ok(exe)
    }

    /// Eagerly compile every executable of the given kinds (startup
    /// warm-up so the serve loop never compiles inline).
    pub fn warmup(&self, kinds: &[&str]) -> Result<usize> {
        let names: Vec<String> = self
            .manifest
            .executables
            .iter()
            .filter(|e| kinds.contains(&e.kind.as_str()))
            .map(|e| e.name.clone())
            .collect();
        for n in &names {
            self.compile(n)?;
        }
        Ok(names.len())
    }

    /// Number of executables compiled so far (cache introspection).
    pub fn compiled_count(&self) -> usize {
        self.cache.borrow().len()
    }

    /// Execute by name; returns the flattened tuple outputs.
    /// Accepts anything borrowing `Literal` (owned or `&Literal`).
    pub fn execute<L: std::borrow::Borrow<Literal>>(
        &self,
        name: &str,
        inputs: &[L],
    ) -> Result<Vec<Literal>> {
        let exe = self.compile(name)?;
        let out = exe.execute::<L>(inputs).map_err(|e| anyhow!("executing {name}: {e:?}"))?;
        let result =
            out[0][0].to_literal_sync().map_err(|e| anyhow!("fetching {name} output: {e:?}"))?;
        result.to_tuple().map_err(|e| anyhow!("untupling {name}: {e:?}"))
    }

    /// Run a standalone AP-GEMM artifact on pre-packed u32 planes.
    ///
    /// `wp`: `(nw, M, Kp)` u32 planes; `xp`: `(nx, N, Kp)`.  Returns the
    /// `(M, N)` i32 result.
    pub fn run_apmm(&self, spec: &ExecSpec, wp: &[u32], xp: &[u32]) -> Result<Vec<i32>> {
        if spec.kind != "apmm" {
            bail!("{} is not an apmm executable", spec.name);
        }
        let wspec = &spec.inputs[0];
        let xspec = &spec.inputs[1];
        if wp.len() != wspec.elements() || xp.len() != xspec.elements() {
            bail!(
                "{}: operand sizes {}/{} don't match spec {}/{}",
                spec.name,
                wp.len(),
                xp.len(),
                wspec.elements(),
                xspec.elements()
            );
        }
        let inputs = [lit_u32(wp, &wspec.shape)?, lit_u32(xp, &xspec.shape)?];
        let out = self.execute(&spec.name, &inputs)?;
        let y = out.first().context("apmm output")?;
        Ok(y.to_vec::<i32>().map_err(|e| anyhow!("apmm output: {e:?}"))?)
    }
}

/// Serving-side handle to the L2 model: weights loaded once and reused
/// across steps; KV caches threaded through as literals.
pub struct ModelRunner<'e> {
    engine: &'e Engine,
    weights: Vec<Literal>,
    pub cfg: ModelCfg,
}

/// A generation group's state (one prefill + N decode steps).
pub struct KvState {
    pub k: Literal,
    pub v: Literal,
    pub batch: usize,
    /// Next position to be written, per batch slot (continuous batching:
    /// slots may sit at different depths).
    pub pos: Vec<usize>,
}

impl<'e> ModelRunner<'e> {
    /// Load `weights.bin` into literals in manifest (== python
    /// `param_spec`) order.
    pub fn new(engine: &'e Engine) -> Result<Self> {
        let spec = engine
            .manifest()
            .model
            .as_ref()
            .context("manifest has no model section (aot.py --skip-model?)")?
            .clone();
        let blob = std::fs::read(engine.manifest().dir.join(&spec.weights_file))
            .context("reading weights.bin")?;
        let mut weights = Vec::with_capacity(spec.weights.len());
        for w in &spec.weights {
            let raw = blob
                .get(w.offset..w.offset + w.nbytes)
                .with_context(|| format!("weight {} out of range", w.name))?;
            // all dtypes are 4-byte little-endian; reinterpret accordingly
            let lit = match w.dtype {
                super::manifest::DType::F32 => {
                    let v: Vec<f32> = raw
                        .chunks_exact(4)
                        .map(|c| f32::from_le_bytes(c.try_into().unwrap()))
                        .collect();
                    super::tensor::lit_f32(&v, &w.shape)?
                }
                super::manifest::DType::U32 => {
                    let v: Vec<u32> = raw
                        .chunks_exact(4)
                        .map(|c| u32::from_le_bytes(c.try_into().unwrap()))
                        .collect();
                    lit_u32(&v, &w.shape)?
                }
                super::manifest::DType::I32 => {
                    let v: Vec<i32> = raw
                        .chunks_exact(4)
                        .map(|c| i32::from_le_bytes(c.try_into().unwrap()))
                        .collect();
                    lit_i32(&v, &w.shape)?
                }
            };
            weights.push(lit);
        }
        Ok(Self { engine, weights, cfg: spec.config })
    }

    pub fn engine(&self) -> &Engine {
        self.engine
    }

    /// Largest decode batch the artifacts support.
    pub fn max_batch(&self) -> usize {
        self.engine
            .manifest()
            .by_kind("decode")
            .iter()
            .filter_map(|e| e.meta.get("batch").copied())
            .max()
            .unwrap_or(1)
    }

    /// Prefill `tokens` (row-major `(b, t)`); returns logits
    /// `(b, t_exec, vocab)` and the KV state positioned at `t_exec`.
    pub fn prefill(&self, tokens: &[i32], b: usize, t: usize) -> Result<(Vec<f32>, KvState)> {
        let spec = self.engine.manifest().prefill_for(b, t)?.clone();
        let t_exec = spec.meta_usize("seq")?;
        // pad the prompt into the compiled seq bucket
        let mut padded = vec![0i32; b * t_exec];
        for r in 0..b {
            padded[r * t_exec..r * t_exec + t].copy_from_slice(&tokens[r * t..(r + 1) * t]);
        }
        let tok = lit_i32(&padded, &[b, t_exec])?;
        let mut args: Vec<&Literal> = self.weights.iter().collect();
        args.push(&tok);
        let out = self.engine.execute(&spec.name, &args)?;
        let mut it = out.into_iter();
        let logits_lit = it.next().context("prefill logits")?;
        let k = it.next().context("prefill k_cache")?;
        let v = it.next().context("prefill v_cache")?;
        let logits = logits_lit.to_vec::<f32>().map_err(|e| anyhow!("logits: {e:?}"))?;
        Ok((logits, KvState { k, v, batch: b, pos: vec![t_exec; b] }))
    }

    /// One decode step for the group: `tokens` has `kv.batch` entries;
    /// row `i` writes its KV at `kv.pos[i]`.  Returns per-row logits
    /// `(b, vocab)` and advances every slot's position.
    pub fn decode(&self, tokens: &[i32], kv: &mut KvState) -> Result<Vec<f32>> {
        let b = kv.batch;
        if tokens.len() != b {
            bail!("decode: {} tokens for batch {b}", tokens.len());
        }
        if let Some(&p) = kv.pos.iter().find(|&&p| p >= self.cfg.max_seq) {
            bail!("decode: KV cache exhausted (pos {p} >= max_seq {})", self.cfg.max_seq);
        }
        let spec = self.engine.manifest().decode_for_batch(b)?.clone();
        let tok = lit_i32(tokens, &[b])?;
        let pos_i32: Vec<i32> = kv.pos.iter().map(|&p| p as i32).collect();
        let pos = lit_i32(&pos_i32, &[b])?;
        let mut args: Vec<&Literal> = self.weights.iter().collect();
        args.push(&tok);
        args.push(&pos);
        args.push(&kv.k);
        args.push(&kv.v);
        let out = self.engine.execute(&spec.name, &args)?;
        let mut it = out.into_iter();
        let logits_lit = it.next().context("decode logits")?;
        kv.k = it.next().context("decode k_cache")?;
        kv.v = it.next().context("decode v_cache")?;
        for p in kv.pos.iter_mut() {
            *p += 1;
        }
        Ok(logits_lit.to_vec::<f32>().map_err(|e| anyhow!("logits: {e:?}"))?)
    }

    /// Raw per-slot decode for the continuous scheduler: the caller owns
    /// the KV literals and position vector explicitly.
    pub fn decode_raw(
        &self,
        tokens: &[i32],
        pos: &[i32],
        k: &Literal,
        v: &Literal,
    ) -> Result<(Vec<f32>, Literal, Literal)> {
        let b = tokens.len();
        if pos.len() != b {
            bail!("decode_raw: {} positions for {b} tokens", pos.len());
        }
        let spec = self.engine.manifest().decode_for_batch(b)?.clone();
        let tok = lit_i32(tokens, &[b])?;
        let pos_l = lit_i32(pos, &[b])?;
        let mut args: Vec<&Literal> = self.weights.iter().collect();
        args.push(&tok);
        args.push(&pos_l);
        args.push(k);
        args.push(v);
        let out = self.engine.execute(&spec.name, &args)?;
        let mut it = out.into_iter();
        let logits = it
            .next()
            .context("decode logits")?
            .to_vec::<f32>()
            .map_err(|e| anyhow!("logits: {e:?}"))?;
        let k_out = it.next().context("decode k_cache")?;
        let v_out = it.next().context("decode v_cache")?;
        Ok((logits, k_out, v_out))
    }
}
