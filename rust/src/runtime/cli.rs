//! `apllm gemm` — run a packed AP-GEMM through a PJRT artifact and verify
//! it against the pure-Rust `bitmm` substrate.

use super::{artifacts_dir, Engine};
use crate::bitmm::{apmm_bipolar, pack_codes_u32, transpose_codes, ApmmOpts, CodeMatrix};
use crate::model::PrecisionConfig;

pub fn cmd_gemm(args: &[String]) {
    let mut prec = PrecisionConfig::W2A2;
    let mut iter = args.iter();
    while let Some(a) = iter.next() {
        if a == "--prec" {
            let v = iter.next().expect("--prec needs a value");
            prec = PrecisionConfig::parse(v).expect("bad precision (expected e.g. W2A2)");
        }
    }

    let engine = Engine::load(&artifacts_dir()).expect("loading artifacts");
    let specs: Vec<_> = engine
        .manifest()
        .by_kind("apmm")
        .into_iter()
        .filter(|e| {
            e.meta.get("nw") == Some(&(prec.nw as usize))
                && e.meta.get("nx") == Some(&(prec.nx as usize))
        })
        .cloned()
        .collect();
    if specs.is_empty() {
        eprintln!("no apmm artifact for {prec} — regenerate with `make artifacts`");
        std::process::exit(1);
    }

    for spec in specs {
        let (m, k, n) = (
            spec.meta_usize("m").unwrap(),
            spec.meta_usize("k").unwrap(),
            spec.meta_usize("n").unwrap(),
        );
        let w = CodeMatrix::random(m, k, prec.nw, 7);
        let x = CodeMatrix::random(k, n, prec.nx, 8);
        let xt = transpose_codes(&x);
        let wp = pack_codes_u32(&w);
        let xp = pack_codes_u32(&xt);

        let t0 = std::time::Instant::now();
        let y_pjrt = engine.run_apmm(&spec, &wp, &xp).expect("PJRT execution");
        let t_pjrt = t0.elapsed();
        let t0 = std::time::Instant::now();
        let y_rust = apmm_bipolar(&w, &xt, ApmmOpts::default());
        let t_rust = t0.elapsed();

        let ok = y_pjrt == y_rust;
        println!(
            "{}: {}x{}x{}  pjrt={:.2?} rust={:.2?}  match={}",
            spec.name, m, k, n, t_pjrt, t_rust, ok
        );
        if !ok {
            let diff = y_pjrt.iter().zip(&y_rust).filter(|(a, b)| a != b).count();
            eprintln!("MISMATCH: {diff}/{} elements differ", y_rust.len());
            std::process::exit(1);
        }
    }
    println!("gemm: all artifacts verified against bitmm");
}
