//! Literal construction helpers (typed host→XLA marshaling).

use crate::anyhow::Result;
use xla::Literal;

fn dims_i64(dims: &[usize]) -> Vec<i64> {
    dims.iter().map(|&d| d as i64).collect()
}

/// `u32` tensor literal with the given shape.
pub fn lit_u32(data: &[u32], dims: &[usize]) -> Result<Literal> {
    debug_assert_eq!(data.len(), dims.iter().product::<usize>());
    Ok(Literal::vec1(data).reshape(&dims_i64(dims))?)
}

/// `i32` tensor literal.
pub fn lit_i32(data: &[i32], dims: &[usize]) -> Result<Literal> {
    debug_assert_eq!(data.len(), dims.iter().product::<usize>());
    Ok(Literal::vec1(data).reshape(&dims_i64(dims))?)
}

/// `f32` tensor literal.
pub fn lit_f32(data: &[f32], dims: &[usize]) -> Result<Literal> {
    debug_assert_eq!(data.len(), dims.iter().product::<usize>());
    Ok(Literal::vec1(data).reshape(&dims_i64(dims))?)
}

/// Rank-0 `i32` literal (the decode `pos` argument).
pub fn lit_i32_scalar(v: i32) -> Literal {
    Literal::scalar(v)
}
