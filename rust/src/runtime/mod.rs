//! PJRT runtime: load + execute the AOT artifacts emitted by
//! `python/compile/aot.py`.
//!
//! Flow (see /opt/xla-example and DESIGN.md): `artifacts/manifest.json`
//! names HLO-text executables; each is parsed with
//! `HloModuleProto::from_text_file`, compiled once on the PJRT CPU client,
//! and cached.  Model weights load from `weights.bin` straight into
//! device-resident `PjRtBuffer`s so the serving hot path never re-uploads
//! them (`execute_b`).  Python is never on this path.
//!
//! The engine (and everything touching the external `xla` crate) is gated
//! behind the `pjrt` cargo feature so the default build stays fully
//! offline; manifest parsing is always available.

#[cfg(feature = "pjrt")]
mod engine;
mod manifest;
#[cfg(feature = "pjrt")]
mod tensor;

#[cfg(feature = "pjrt")]
pub mod cli;

#[cfg(feature = "pjrt")]
pub use engine::{Engine, ModelRunner};
pub use manifest::{DType, ExecSpec, IoSpec, Manifest, ModelCfg, ModelSpec, WeightEntry};
#[cfg(feature = "pjrt")]
pub use tensor::{lit_f32, lit_i32, lit_i32_scalar, lit_u32};

/// Default artifacts directory (overridable with `APLLM_ARTIFACTS`).
pub fn artifacts_dir() -> std::path::PathBuf {
    std::env::var("APLLM_ARTIFACTS").unwrap_or_else(|_| "artifacts".into()).into()
}

#[cfg(test)]
mod tests;
