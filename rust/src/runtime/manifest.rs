//! Typed view of `artifacts/manifest.json` (parsed with `util::json`).

use crate::util::Json;
use crate::anyhow::{anyhow, bail, Context, Result};
use std::path::{Path, PathBuf};

/// Artifact tensor element type.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum DType {
    F32,
    U32,
    I32,
}

impl DType {
    pub fn parse(s: &str) -> Result<Self> {
        match s {
            "f32" => Ok(DType::F32),
            "u32" => Ok(DType::U32),
            "i32" => Ok(DType::I32),
            other => bail!("unknown dtype {other}"),
        }
    }

    pub fn size_bytes(self) -> usize {
        4
    }
}

/// One input/output tensor of an executable.
#[derive(Debug, Clone)]
pub struct IoSpec {
    pub name: String,
    pub dtype: DType,
    pub shape: Vec<usize>,
}

impl IoSpec {
    pub fn elements(&self) -> usize {
        self.shape.iter().product()
    }
}

/// One AOT-compiled executable.
#[derive(Debug, Clone)]
pub struct ExecSpec {
    pub name: String,
    /// "apmm" | "prefill" | "decode".
    pub kind: String,
    /// HLO text file, relative to the artifacts dir.
    pub hlo: String,
    pub inputs: Vec<IoSpec>,
    pub outputs: Vec<IoSpec>,
    /// Free-form metadata (m/k/n/nw/nx for apmm; batch/seq for model).
    pub meta: std::collections::BTreeMap<String, usize>,
}

impl ExecSpec {
    pub fn meta_usize(&self, key: &str) -> Result<usize> {
        self.meta.get(key).copied().ok_or_else(|| anyhow!("{}: missing meta {key}", self.name))
    }
}

/// One tensor in `weights.bin`.
#[derive(Debug, Clone)]
pub struct WeightEntry {
    pub name: String,
    pub dtype: DType,
    pub shape: Vec<usize>,
    pub offset: usize,
    pub nbytes: usize,
}

/// Model architecture parameters (mirrors python `ModelConfig`).
#[derive(Debug, Clone, Copy)]
pub struct ModelCfg {
    pub vocab: usize,
    pub dim: usize,
    pub n_layers: usize,
    pub n_heads: usize,
    pub n_kv_heads: usize,
    pub ffn: usize,
    pub max_seq: usize,
    pub nw: u32,
    pub nx: u32,
}

impl ModelCfg {
    pub fn head_dim(&self) -> usize {
        self.dim / self.n_heads
    }

    /// Elements of one KV cache tensor for batch `b`.
    pub fn kv_elements(&self, b: usize) -> usize {
        self.n_layers * b * self.max_seq * self.n_kv_heads * self.head_dim()
    }
}

/// The model section of the manifest.
#[derive(Debug, Clone)]
pub struct ModelSpec {
    pub config: ModelCfg,
    pub weights_file: String,
    pub weights: Vec<WeightEntry>,
}

/// The whole manifest.
#[derive(Debug, Clone)]
pub struct Manifest {
    pub dir: PathBuf,
    pub version: usize,
    pub model: Option<ModelSpec>,
    pub executables: Vec<ExecSpec>,
}

fn io_spec(j: &Json) -> Result<IoSpec> {
    Ok(IoSpec {
        name: j.get("name").and_then(Json::as_str).unwrap_or_default().to_string(),
        dtype: DType::parse(j.get("dtype").and_then(Json::as_str).context("io dtype")?)?,
        shape: j
            .get("shape")
            .and_then(Json::as_arr)
            .context("io shape")?
            .iter()
            .map(|d| d.as_usize().context("shape dim"))
            .collect::<Result<_>>()?,
    })
}

impl Manifest {
    /// Parse `<dir>/manifest.json`.
    pub fn load(dir: &Path) -> Result<Self> {
        let path = dir.join("manifest.json");
        let src = std::fs::read_to_string(&path)
            .with_context(|| format!("reading {} (run `make artifacts`)", path.display()))?;
        let j = Json::parse(&src).map_err(|e| anyhow!("parsing {}: {e}", path.display()))?;

        let version = j.get("version").and_then(Json::as_usize).context("manifest version")?;
        let mut executables = Vec::new();
        for e in j.get("executables").and_then(Json::as_arr).context("executables")? {
            let mut meta = std::collections::BTreeMap::new();
            if let Some(Json::Obj(m)) = e.get("meta") {
                for (k, v) in m {
                    if let Some(u) = v.as_usize() {
                        meta.insert(k.clone(), u);
                    }
                }
            }
            executables.push(ExecSpec {
                name: e.get("name").and_then(Json::as_str).context("exe name")?.to_string(),
                kind: e.get("kind").and_then(Json::as_str).context("exe kind")?.to_string(),
                hlo: e.get("hlo").and_then(Json::as_str).context("exe hlo")?.to_string(),
                inputs: e
                    .get("inputs")
                    .and_then(Json::as_arr)
                    .context("inputs")?
                    .iter()
                    .map(io_spec)
                    .collect::<Result<_>>()?,
                outputs: e
                    .get("outputs")
                    .and_then(Json::as_arr)
                    .context("outputs")?
                    .iter()
                    .map(io_spec)
                    .collect::<Result<_>>()?,
                meta,
            });
        }

        let model = match j.get("model") {
            None | Some(Json::Null) => None,
            Some(mj) => {
                let c = mj.get("config").context("model config")?;
                let g = |k: &str| c.get(k).and_then(Json::as_usize).context(format!("config {k}"));
                let config = ModelCfg {
                    vocab: g("vocab")?,
                    dim: g("dim")?,
                    n_layers: g("n_layers")?,
                    n_heads: g("n_heads")?,
                    n_kv_heads: g("n_kv_heads")?,
                    ffn: g("ffn")?,
                    max_seq: g("max_seq")?,
                    nw: g("nw")? as u32,
                    nx: g("nx")? as u32,
                };
                let mut weights = Vec::new();
                for w in mj.get("weights").and_then(Json::as_arr).context("weights")? {
                    weights.push(WeightEntry {
                        name: w.get("name").and_then(Json::as_str).context("w name")?.to_string(),
                        dtype: DType::parse(w.get("dtype").and_then(Json::as_str).context("w dtype")?)?,
                        shape: w
                            .get("shape")
                            .and_then(Json::as_arr)
                            .context("w shape")?
                            .iter()
                            .map(|d| d.as_usize().context("w dim"))
                            .collect::<Result<_>>()?,
                        offset: w.get("offset").and_then(Json::as_usize).context("w offset")?,
                        nbytes: w.get("nbytes").and_then(Json::as_usize).context("w nbytes")?,
                    });
                }
                Some(ModelSpec {
                    config,
                    weights_file: mj
                        .get("weights_file")
                        .and_then(Json::as_str)
                        .context("weights_file")?
                        .to_string(),
                    weights,
                })
            }
        };

        Ok(Manifest { dir: dir.to_path_buf(), version, model, executables })
    }

    pub fn find(&self, name: &str) -> Result<&ExecSpec> {
        self.executables
            .iter()
            .find(|e| e.name == name)
            .ok_or_else(|| anyhow!("no executable named {name} in manifest"))
    }

    /// All executables of a given kind.
    pub fn by_kind(&self, kind: &str) -> Vec<&ExecSpec> {
        self.executables.iter().filter(|e| e.kind == kind).collect()
    }

    /// The decode executable for batch size `b`.
    pub fn decode_for_batch(&self, b: usize) -> Result<&ExecSpec> {
        self.by_kind("decode")
            .into_iter()
            .find(|e| e.meta.get("batch") == Some(&b))
            .ok_or_else(|| anyhow!("no decode executable for batch {b}"))
    }

    /// The prefill executable for batch `b` (any seq bucket ≥ needed).
    pub fn prefill_for(&self, b: usize, t: usize) -> Result<&ExecSpec> {
        self.by_kind("prefill")
            .into_iter()
            .filter(|e| e.meta.get("batch") == Some(&b))
            .find(|e| e.meta.get("seq").map(|s| *s >= t).unwrap_or(false))
            .ok_or_else(|| anyhow!("no prefill executable for batch {b}, seq {t}"))
    }
}
