//! Structural model of OUR kernel: tiling, shared-memory footprint, and
//! global-memory traffic, with the §4.1/§4.2 optimizations as knobs.

/// Output-block tiling (the paper's `b_m × b_n`, K chunked by `b_k`).
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct TileConfig {
    pub bm: usize,
    pub bn: usize,
    pub bk: usize,
}

impl Default for TileConfig {
    fn default() -> Self {
        // the configuration the paper's §4.2 scheduling targets: one
        // output block per SM, all plane pairs resident
        Self { bm: 64, bn: 64, bk: 512 }
    }
}

impl TileConfig {
    /// Shrink `bk` (then `bm`/`bn`) until the double-buffered smem
    /// footprint at `nw`/`nx` bits fits `budget` bytes — how a real launch
    /// would size itself for wide precisions like W8A8.
    pub fn fit(nw: u32, nx: u32, budget: usize) -> Self {
        let mut t = Self::default();
        loop {
            let opts = OursOpts { tiles: t, ..OursOpts::paper() };
            if smem_bytes_per_block(nw, nx, &opts) <= budget {
                return t;
            }
            if t.bk > 64 {
                t.bk /= 2;
            } else if t.bm > 16 {
                t.bm /= 2;
                t.bn /= 2;
            } else {
                return t; // smallest supported tile
            }
        }
    }
}

/// The §4.1/§4.2 optimization knobs (all-on == the paper's kernel).
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct OursOpts {
    /// §4.2 ①②: recover in shared memory/fragments (fused) instead of
    /// writing every `D_ij` back to global memory.
    pub fused_recovery: bool,
    /// §4.1: bit-plane packing into native 32-bit words (off = each
    /// sub-byte element stored in an 8-bit slot).
    pub packed: bool,
    /// §4.2 ③: double-buffered smem so transfer overlaps compute.
    pub double_buffer: bool,
    /// §4.2 ④: each fragment holds one weight plane against ALL
    /// activation planes (off = weight planes re-fetched per activation
    /// plane).
    pub frag_reuse: bool,
    /// §3.3: weights arrive pre-decomposed + pre-packed (pack-once, off
    /// the hot path).  Off = every GEMM call decomposes and re-packs its
    /// weight operand inline, paying an extra streaming pass over W.
    pub prepacked: bool,
    pub tiles: TileConfig,
}

impl OursOpts {
    /// The paper's full configuration.
    pub fn paper() -> Self {
        Self {
            fused_recovery: true,
            packed: true,
            double_buffer: true,
            frag_reuse: true,
            prepacked: true,
            tiles: TileConfig::default(),
        }
    }

    /// Everything off — the naive Fig. 4 flow.
    pub fn naive() -> Self {
        Self {
            fused_recovery: false,
            packed: false,
            double_buffer: false,
            frag_reuse: false,
            prepacked: false,
            tiles: TileConfig::default(),
        }
    }
}

/// Bytes one on-the-fly pack pass over a `rows × cols` operand at `bits`
/// moves (§3.3 off): read the byte-padded codes, write the bit-exact
/// packed planes.  Bandwidth-bound — the decomposition itself is shifts
/// and masks.
pub fn pack_pass_bytes(rows: usize, cols: usize, bits: u32) -> f64 {
    let elems = rows as f64 * cols as f64;
    elems * (stored_bits(bits, false) + stored_bits(bits, true)) / 8.0
}

/// Stored bits per element under the knobs: packed = exactly `bits`
/// (§4.1's claim), unpacked = padded to the next byte slot.
fn stored_bits(bits: u32, packed: bool) -> f64 {
    if packed {
        bits as f64
    } else {
        (bits as f64 / 8.0).ceil() * 8.0
    }
}

/// Memory traffic of our kernel for `(M,K)×(K,N)` at `nw`/`nx` bits.
///
/// Per output block `(bm, bn)`: the W tile (`bm × K`, all `nw` planes) and
/// the X tile (`K × bn`, all `nx` planes) stream once per block — so W is
/// read once per block *column* and X once per block *row*.  The first
/// read of each operand is compulsory DRAM traffic; repeats hit L2 (the
/// packed operands fit the 6 MB L2 at every size the paper evaluates).
/// With frag_reuse off (§4.2 ④) the weight tile is re-fetched for every
/// activation plane.  Output is requantized to 8-bit for the next layer.
pub fn ours_traffic(
    m: usize,
    k: usize,
    n: usize,
    nw: u32,
    nx: u32,
    opts: &OursOpts,
) -> super::baselines::Traffic {
    let t = &opts.tiles;
    let col_blocks = n.div_ceil(t.bn) as f64;
    let row_blocks = m.div_ceil(t.bm) as f64;
    let wbits = stored_bits(nw, opts.packed);
    let xbits = stored_bits(nx, opts.packed);
    let w_once = m as f64 * k as f64 * wbits / 8.0;
    let x_once = k as f64 * n as f64 * xbits / 8.0;
    let w_reads = col_blocks * if opts.frag_reuse { 1.0 } else { nx as f64 };
    let x_reads = row_blocks;
    let y_traffic = m as f64 * n as f64;
    super::baselines::Traffic {
        dram: w_once + x_once + y_traffic,
        l2: (w_reads - 1.0).max(0.0) * w_once + (x_reads - 1.0).max(0.0) * x_once,
    }
}

/// Shared-memory bytes one block claims: double-buffered W/X plane tiles
/// plus the fragment-recovery staging area (`n_w·b_m × n_x·b_n` i32 before
/// folding, §4.2 ②).
pub fn smem_bytes_per_block(nw: u32, nx: u32, opts: &OursOpts) -> usize {
    let t = &opts.tiles;
    let buf = if opts.double_buffer { 2 } else { 1 };
    let planes = (nw as usize * t.bm + nx as usize * t.bn) * t.bk / 8 * buf;
    let recovery = if opts.fused_recovery { 4 * t.bm * t.bn } else { 0 };
    planes + recovery
}

/// Number of thread blocks the launch produces.
pub fn blocks_launched(m: usize, n: usize, opts: &OursOpts) -> usize {
    m.div_ceil(opts.tiles.bm) * n.div_ceil(opts.tiles.bn)
}
