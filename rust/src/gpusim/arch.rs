//! Device parameters (published RTX 3090 / GA102 numbers).

/// GPU device model.
#[derive(Debug, Clone, Copy)]
pub struct Gpu {
    pub name: &'static str,
    pub sms: usize,
    pub clock_hz: f64,
    /// Peak global-memory bandwidth (bytes/s).
    pub mem_bw: f64,
    /// Achievable fraction of peak bandwidth for streaming GEMM loads.
    pub bw_efficiency: f64,
    /// Effective L2 bandwidth for tile re-reads (bytes/s).
    pub l2_bw: f64,
    /// Shared memory per SM (bytes).
    pub smem_per_sm: usize,
    /// Max shared memory a single block may claim (bytes).
    pub smem_per_block: usize,
    pub l2_bytes: usize,
    /// Documented dense tensor-core peaks (ops/s) — used only for
    /// roofline *reporting*, not for the fitted curves.
    pub peak_fp16_tc: f64,
    pub peak_int8_tc: f64,
    pub peak_int4_tc: f64,
    pub peak_int1_tc: f64,
    pub peak_fp32_cuda: f64,
}

impl Gpu {
    /// NVIDIA GeForce RTX 3090 (GA102, Ampere) — the paper's testbed.
    pub fn rtx3090() -> Self {
        Self {
            name: "RTX 3090 (GA102)",
            sms: 82,
            clock_hz: 1.695e9,
            mem_bw: 936.2e9,
            bw_efficiency: 0.82,
            l2_bw: 2.5e12,
            smem_per_sm: 128 * 1024,
            smem_per_block: 100 * 1024,
            l2_bytes: 6 * 1024 * 1024,
            peak_fp32_cuda: 35.6e12,
            peak_fp16_tc: 71e12,   // FP16 with FP32 accumulate, dense
            peak_int8_tc: 142e12,  // dense
            peak_int4_tc: 284e12,  // dense
            peak_int1_tc: 1136e12, // BMMA XOR, dense
        }
    }

    pub fn eff_bandwidth(&self) -> f64 {
        self.mem_bw * self.bw_efficiency
    }

    /// Roofline fraction a fitted rate represents against the documented
    /// peak for `kind` ("fp32" | "fp16" | "int8" | "int4" | "int1").
    pub fn roofline_fraction(&self, rate_ops: f64, kind: &str) -> f64 {
        let peak = match kind {
            "fp32" => self.peak_fp32_cuda,
            "fp16" => self.peak_fp16_tc,
            "int8" => self.peak_int8_tc,
            "int4" => self.peak_int4_tc,
            "int1" => self.peak_int1_tc,
            _ => panic!("unknown roofline kind {kind}"),
        };
        rate_ops / peak
    }
}
