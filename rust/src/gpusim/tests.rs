use super::*;
use crate::model::PrecisionConfig;
use crate::util::proptest::forall;

fn sim() -> Simulator {
    Simulator::rtx3090()
}

#[test]
fn calibration_hits_anchors() {
    // every fitted scheme reproduces its own anchors within tolerance
    let gpu = Gpu::rtx3090();
    for (key, anchors) in ANCHORS.iter() {
        let rep = CalibrationReport::build(&gpu, key, anchors).unwrap();
        // The paper's own anchors are mutually inconsistent under any
        // smooth 3-parameter rate curve (its 1k→2k→4k scaling factors are
        // 2.1× and 2.6× for 8× work each) — 65% worst-case is the
        // practical floor; the ordering/factor tests below are the real
        // reproduction criteria.
        assert!(
            rep.max_rel_err < 0.65,
            "{key}: max rel err {:.2} (params {:?})",
            rep.max_rel_err,
            rep.params
        );
    }
}

#[test]
fn table1_ordering_at_4k() {
    // paper Table 1, 4k column: FP32 > FP16 > INT4 > W3A4 ≈ INT1 > W2A2 > W1A2
    let s = sim();
    let t = |sc: &Scheme| s.simulate(sc, 4096, 4096, 4096).unwrap().time_s;
    let fp32 = t(&Scheme::Fp32);
    let fp16 = t(&Scheme::Fp16);
    let i4 = t(&Scheme::CutlassInt4);
    let i1 = t(&Scheme::CutlassInt1);
    let w3a4 = t(&Scheme::ours(PrecisionConfig::W3A4));
    let w2a2 = t(&Scheme::ours(PrecisionConfig::W2A2));
    let w1a2 = t(&Scheme::ours(PrecisionConfig::W1A2));
    assert!(fp32 > fp16 && fp16 > i4 && i4 > i1, "FP/CUTLASS ladder");
    assert!(w3a4 < i4, "W3A4 beats CUTLASS INT4 (paper: 184 vs 386 µs)");
    assert!(w2a2 < i1 && w1a2 < i1, "W2A2/W1A2 beat CUTLASS INT1");
    // headline factors: W1A2 ≈ 5.5× INT1, W2A2 ≈ 3.5× INT1 (±40%)
    let r1 = i1 / w1a2;
    let r2 = i1 / w2a2;
    assert!((3.2..8.0).contains(&r1), "INT1/W1A2 = {r1:.2}");
    assert!((2.0..5.5).contains(&r2), "INT1/W2A2 = {r2:.2}");
}

#[test]
fn table1_speedups_vs_fp32() {
    // W1A2 @4k ≈ 193× FP32; W2A2 ≈ 122×; tolerate ±40%
    let s = sim();
    let fp32 = s.simulate(&Scheme::Fp32, 4096, 4096, 4096).unwrap().time_s;
    let w1a2 = s.simulate(&Scheme::ours(PrecisionConfig::W1A2), 4096, 4096, 4096).unwrap().time_s;
    let w2a2 = s.simulate(&Scheme::ours(PrecisionConfig::W2A2), 4096, 4096, 4096).unwrap().time_s;
    assert!((120.0..280.0).contains(&(fp32 / w1a2)), "got {:.0}", fp32 / w1a2);
    assert!((75.0..180.0).contains(&(fp32 / w2a2)), "got {:.0}", fp32 / w2a2);
}

#[test]
fn apnn_crossover() {
    // Fig. 5: APNN-TC wins at small sizes, loses badly at ≥1k
    let s = sim();
    let ours = Scheme::ours(PrecisionConfig::W1A2);
    let apnn = Scheme::ApnnTc(PrecisionConfig::W1A2);
    let small_ours = s.simulate(&ours, 256, 256, 256).unwrap().time_s;
    let small_apnn = s.simulate(&apnn, 256, 256, 256).unwrap().time_s;
    assert!(small_apnn < small_ours, "APNN should win at 256³");
    let big_ours = s.simulate(&ours, 4096, 4096, 4096).unwrap().time_s;
    let big_apnn = s.simulate(&apnn, 4096, 4096, 4096).unwrap().time_s;
    assert!(big_apnn / big_ours > 20.0, "ours ≥20× at 4k, got {:.1}", big_apnn / big_ours);
}

#[test]
fn monotonicity_in_size() {
    let s = sim();
    for scheme in [
        Scheme::Fp16,
        Scheme::CutlassInt1,
        Scheme::ours(PrecisionConfig::W2A2),
    ] {
        let mut last = 0.0;
        for size in [128, 256, 512, 1024, 2048, 4096] {
            let t = s.simulate(&scheme, size, size, size).unwrap().time_s;
            assert!(t > last, "{}: non-monotone at {size}", scheme.label());
            last = t;
        }
    }
}

#[test]
fn monotonicity_in_bits() {
    let s = sim();
    // more plane pairs ⇒ more work ⇒ ≥ time at fixed calibration curve...
    // (only valid within one fitted key, so compare structural work)
    let w22 = scheme_work(&Scheme::ours(PrecisionConfig::W2A2), 1024, 1024, 1024);
    let w34 = scheme_work(&Scheme::ours(PrecisionConfig::W3A4), 1024, 1024, 1024);
    let w11 = scheme_work(&Scheme::ours(PrecisionConfig::W1A1), 1024, 1024, 1024);
    assert!(w11 < w22 && w22 < w34);
}

#[test]
fn ablation_knobs_strictly_hurt() {
    let s = sim();
    let p = PrecisionConfig::W2A2;
    let base = s.simulate(&Scheme::ours(p), 4096, 4096, 4096).unwrap().time_s;
    for (name, opts) in [
        ("no fused recovery", OursOpts { fused_recovery: false, ..OursOpts::paper() }),
        ("no packing", OursOpts { packed: false, ..OursOpts::paper() }),
        ("no double buffer", OursOpts { double_buffer: false, ..OursOpts::paper() }),
        ("no frag reuse", OursOpts { frag_reuse: false, ..OursOpts::paper() }),
        ("no prepacking", OursOpts { prepacked: false, ..OursOpts::paper() }),
        ("naive", OursOpts::naive()),
    ] {
        let t = s.simulate(&Scheme::Ours(p, opts), 4096, 4096, 4096).unwrap().time_s;
        assert!(t > base, "{name} should not be faster ({t:.3e} vs {base:.3e})");
    }
    let naive = s.simulate(&Scheme::Ours(p, OursOpts::naive()), 4096, 4096, 4096).unwrap().time_s;
    assert!(naive / base > 1.5, "all-off should cost ≥1.5×, got {:.2}", naive / base);
}

#[test]
fn prepacked_knob_splits_pack_time() {
    let s = sim();
    let p = PrecisionConfig::W2A2;
    let (m, k, n) = (1024, 4096, 4096);
    let base = s.simulate(&Scheme::ours(p), m, k, n).unwrap();
    assert_eq!(base.t_pack_s, 0.0, "pack-once config pays no inline pack");
    let inline = s
        .simulate(&Scheme::Ours(p, OursOpts { prepacked: false, ..OursOpts::paper() }), m, k, n)
        .unwrap();
    assert!(inline.t_pack_s > 0.0);
    let dt = inline.time_s - base.time_s;
    assert!(
        (dt - inline.t_pack_s).abs() < 1e-12,
        "pack is additive: dt={dt:.3e} t_pack={:.3e}",
        inline.t_pack_s
    );
    // the pack pass streams W once more: structural bytes match the knob
    let bytes = pack_pass_bytes(m, k, p.nw);
    assert!((inline.t_pack_s - bytes / s.gpu.eff_bandwidth()).abs() < 1e-15);
}

#[test]
fn pack_split_amortizes() {
    let s = sim();
    let rows =
        s.llm_pack_split(&crate::model::LlmArch::llama2_7b(), PrecisionConfig::W2A2, 1024).unwrap();
    assert!(rows.iter().any(|r| r.label == "lm_head"));
    let (pack, gemm): (f64, f64) = rows
        .iter()
        .fold((0.0, 0.0), |(p, g), r| (p + r.weight_pack_once_s, g + r.gemm_step_s));
    assert!(pack > 0.0 && gemm > 0.0);
    for r in &rows {
        assert!(r.weight_pack_once_s > 0.0 && r.act_pack_step_s > 0.0 && r.gemm_step_s > 0.0);
    }
}

#[test]
fn launch_geometry() {
    let opts = OursOpts::paper();
    assert_eq!(super::kernels::blocks_launched(4096, 4096, &opts), 64 * 64);
    assert_eq!(super::kernels::blocks_launched(65, 1, &opts), 2);
    // >SM-count launches wave-quantize, <SM-count underutilize
    let gpu = Gpu::rtx3090();
    assert!(super::kernels::blocks_launched(4096, 4096, &opts) > gpu.sms);
    assert!(super::kernels::blocks_launched(128, 128, &opts) < gpu.sms);
}

#[test]
fn smem_fits_hardware() {
    let gpu = Gpu::rtx3090();
    // the paper's evaluated precisions fit with the default tiles
    for (nw, nx) in [(1, 1), (1, 2), (2, 2), (3, 4), (4, 4)] {
        let b = smem_bytes_per_block(nw, nx, &OursOpts::paper());
        assert!(b <= gpu.smem_per_block, "W{nw}A{nx}: {b} bytes > block limit");
    }
    // wider precisions must shrink tiles to fit (TileConfig::fit)
    for (nw, nx) in [(8, 8), (6, 8), (8, 4)] {
        let t = TileConfig::fit(nw, nx, gpu.smem_per_block);
        let opts = OursOpts { tiles: t, ..OursOpts::paper() };
        let b = smem_bytes_per_block(nw, nx, &opts);
        assert!(b <= gpu.smem_per_block, "W{nw}A{nx} fitted: {b} bytes");
        assert!(t.bk < TileConfig::default().bk || t.bm < 64, "fit must shrink");
    }
}

#[test]
fn fig7_speedup_bands() {
    // paper: ours 3.9–6.7× over FP16; QLoRA < 1×; GPTQ(INT4 cutlass) and
    // OneBit(INT1 cutlass) in between; ours beats CUTLASS at equal bits
    let s = sim();
    for arch in crate::model::LlmArch::all_paper_models() {
        let m = 1024;
        let ours = |p: PrecisionConfig| {
            s.llm_speedup_vs_fp16(&arch, &Scheme::ours(p), m).unwrap()
        };
        let ours_w1a1 = ours(PrecisionConfig::W1A1);
        let ours_w2a2 = ours(PrecisionConfig::W2A2);
        let ours_w4a4 = ours(PrecisionConfig::W4A4);
        let qlora = s.llm_speedup_vs_fp16(&arch, &Scheme::QloraW4, m).unwrap();
        let gptq = s.llm_speedup_vs_fp16(&arch, &Scheme::CutlassInt4, m).unwrap();
        let onebit = s.llm_speedup_vs_fp16(&arch, &Scheme::CutlassInt1, m).unwrap();
        assert!(qlora < 1.05, "{}: QLoRA {qlora:.2}", arch.name);
        assert!((3.0..7.5).contains(&ours_w1a1), "{}: W1A1 {ours_w1a1:.2}", arch.name);
        assert!((2.5..7.5).contains(&ours_w4a4), "{}: W4A4 {ours_w4a4:.2}", arch.name);
        assert!(ours_w1a1 > onebit, "{}: ours must beat OneBit/CUTLASS-INT1", arch.name);
        assert!(ours_w4a4 > gptq, "{}: ours W4A4 must beat GPTQ/CUTLASS-INT4", arch.name);
        assert!(
            ours_w1a1 / onebit < 2.6 && ours_w1a1 / onebit > 1.1,
            "{}: ours/OneBit = {:.2} (paper: 1.2–2×)",
            arch.name,
            ours_w1a1 / onebit
        );
        assert!(ours_w2a2 > gptq, "{}: W2A2 vs GPTQ", arch.name);
    }
}

#[test]
fn uncalibrated_scheme_is_an_error_not_a_panic() {
    // APNN-TC beyond its documented W ≤ 2 limit has no anchors: the
    // lookup must return a recoverable error naming the valid keys
    let s = sim();
    let bad = Scheme::ApnnTc(PrecisionConfig::W8A8);
    let e = s.simulate(&bad, 64, 64, 64).unwrap_err().to_string();
    assert!(e.contains("no calibration"), "{e}");
    assert!(e.contains("calibrated schemes") && e.contains("FP16"), "must list options: {e}");
    assert!(s.scheme_params(&Scheme::Fp16).is_ok());
}

#[test]
fn roofline_reporting() {
    let gpu = Gpu::rtx3090();
    assert!((gpu.roofline_fraction(35.6e12, "fp32") - 1.0).abs() < 1e-9);
    assert!(gpu.roofline_fraction(2000e12, "int1") > 1.0); // over-roofline is representable
}

#[test]
fn prop_time_positive_and_finite() {
    let sim = sim();
    forall(24, |rng| {
        let (m, k, n) = (rng.usize(1, 8192), rng.usize(1, 16384), rng.usize(1, 8192));
        for scheme in [Scheme::Fp16, Scheme::CutlassInt1, Scheme::ours(PrecisionConfig::W2A2)] {
            let r = sim.simulate(&scheme, m, k, n).unwrap();
            assert!(r.time_s.is_finite() && r.time_s > 0.0);
            assert!(r.time_s >= r.launch_s);
            assert!(r.util > 0.0 && r.util < 1.0);
        }
    });
}

#[test]
fn prop_traffic_monotone_in_k() {
    forall(24, |rng| {
        let (m, n, k) = (rng.usize(32, 512), rng.usize(32, 512), rng.usize(64, 2048));
        let sch = Scheme::ours(PrecisionConfig::W2A2);
        let t1 = scheme_traffic(&sch, m, k, n).total();
        let t2 = scheme_traffic(&sch, m, 2 * k, n).total();
        assert!(t2 > t1);
    });
}
