//! Calibration: fit each scheme's `(L, R_max, s_half)` against the paper's
//! published latencies (Tables 1 & 2), plus synthesized anchors for the
//! comparators the paper only reports as speedup claims (APNN-TC, BSTC,
//! BTC — Fig. 5/6; QLoRA/W1A1/W4A4 — Fig. 7).
//!
//! The fitter minimizes the worst-case |log(T_model / T_anchor)| with a
//! coarse-to-fine grid search in log space — deterministic, ~1 ms per
//! scheme, no dependencies.

use super::{arch::Gpu, baselines, kernels::OursOpts, Scheme, SchemeParams};
use crate::anyhow::{anyhow, Result};
use crate::model::PrecisionConfig;

/// One anchor: (M, K, N, latency_seconds).
pub type Anchor = (usize, usize, usize, f64);

const US: f64 = 1e-6;
const MS: f64 = 1e-3;

/// Paper Table 1 (square 1k/2k/4k) + Table 2 (Llama2-7B shapes) anchors,
/// plus synthesized anchors (marked) derived from the paper's prose claims.
pub static ANCHORS: &[(&str, &[Anchor])] = &[
    (
        "FP32",
        &[
            (1024, 1024, 1024, 121.0 * US),
            (2048, 2048, 2048, 779.0 * US),
            (4096, 4096, 4096, 5690.0 * US),
            (1024, 4096, 4096, 3.12 * MS),
            (1024, 4096, 11008, 8.21 * MS),
            (1024, 11008, 4096, 8.36 * MS),
        ],
    ),
    (
        "FP16",
        &[
            (1024, 1024, 1024, 44.2 * US),
            (2048, 2048, 2048, 263.0 * US),
            (4096, 4096, 4096, 1960.0 * US),
            (1024, 4096, 4096, 1.07 * MS),
            (1024, 4096, 11008, 1.47 * MS),
            (1024, 11008, 4096, 1.58 * MS),
        ],
    ),
    (
        "CUTLASS INT4",
        &[
            (1024, 1024, 1024, 15.8 * US),
            (2048, 2048, 2048, 66.5 * US),
            (4096, 4096, 4096, 386.0 * US),
            (1024, 4096, 4096, 238.0 * US),
            (1024, 4096, 11008, 574.0 * US),
            (1024, 11008, 4096, 548.0 * US),
        ],
    ),
    (
        "CUTLASS INT1",
        &[
            (1024, 1024, 1024, 9.3 * US),
            (2048, 2048, 2048, 36.9 * US),
            (4096, 4096, 4096, 161.0 * US),
            (1024, 4096, 4096, 97.0 * US),
            (1024, 4096, 11008, 255.0 * US),
            (1024, 11008, 4096, 188.0 * US),
        ],
    ),
    (
        "ours-W3A4",
        &[
            (256, 256, 256, 8.0 * US), // Fig. 5 small-size series
            (1024, 1024, 1024, 12.4 * US),
            (2048, 2048, 2048, 50.4 * US),
            (4096, 4096, 4096, 184.0 * US),
            (1024, 4096, 4096, 194.0 * US),
            (1024, 4096, 11008, 523.0 * US),
            (1024, 11008, 4096, 540.0 * US),
        ],
    ),
    (
        "ours-W2A2",
        &[
            (256, 256, 256, 7.0 * US), // Fig. 5: APNN-TC wins below ~512
            (1024, 1024, 1024, 8.7 * US),
            (2048, 2048, 2048, 18.1 * US),
            (4096, 4096, 4096, 46.5 * US),
            (1024, 4096, 4096, 59.0 * US),
            (1024, 4096, 11008, 143.0 * US),
            (1024, 11008, 4096, 165.0 * US),
        ],
    ),
    (
        "ours-W1A2",
        &[
            (256, 256, 256, 6.5 * US), // Fig. 5: APNN-TC wins below ~512
            (1024, 1024, 1024, 9.0 * US),
            (2048, 2048, 2048, 11.7 * US),
            (4096, 4096, 4096, 29.5 * US),
            (1024, 4096, 4096, 34.0 * US),
            (1024, 4096, 11008, 84.0 * US),
            (1024, 11008, 4096, 82.0 * US),
        ],
    ),
    // ---- synthesized anchors (paper gives claims, not tables) ----
    (
        // Fig. 7 alignment with OneBit: W1A1 tracks W1A2 minus one
        // activation plane (~0.7× compute at saturated sizes).
        "ours-W1A1",
        &[
            (1024, 1024, 1024, 8.6 * US),
            (4096, 4096, 4096, 21.0 * US),
            (1024, 4096, 11008, 60.0 * US),
        ],
    ),
    (
        // Fig. 7's W4A4 configuration: 16 plane pairs ≈ 1.33× W3A4.
        "ours-W4A4",
        &[
            (1024, 1024, 1024, 15.5 * US),
            (4096, 4096, 4096, 245.0 * US),
            (1024, 4096, 11008, 700.0 * US),
        ],
    ),
    (
        // Fig. 5: "APNN-TC slightly outperforms for smaller matrices";
        // ours W1A2/W2A2 are 44×/50× faster at 4k; Fig. 6: 10× at LLM
        // shapes ≥ 1k/10.75k/4k.
        "APNN-TC W1A2",
        &[
            (256, 256, 256, 4.5 * US),
            (1024, 1024, 1024, 42.0 * US),
            (4096, 4096, 4096, 1.30 * MS),
            (1024, 4096, 11008, 1.6 * MS),
        ],
    ),
    (
        "APNN-TC W2A2",
        &[
            (256, 256, 256, 5.2 * US),
            (1024, 1024, 1024, 55.0 * US),
            (4096, 4096, 4096, 2.33 * MS),
            (1024, 4096, 11008, 2.6 * MS),
        ],
    ),
    (
        // BSTC/BTC: software/Turing bit-GEMMs, below CUTLASS INT1 at
        // scale (Fig. 5's lower series).
        "BSTC",
        &[(1024, 1024, 1024, 26.0 * US), (4096, 4096, 4096, 430.0 * US)],
    ),
    (
        "BTC",
        &[(1024, 1024, 1024, 18.0 * US), (4096, 4096, 4096, 300.0 * US)],
    ),
    (
        // QLoRA: 4-bit storage but FP16 compute + in-kernel dequant —
        // Fig. 7 shows inference *slower* than plain FP16 (~0.8×).
        "QLoRA W4",
        &[
            (1024, 1024, 1024, 56.0 * US),
            (4096, 4096, 4096, 2.45 * MS),
            (1024, 4096, 11008, 1.85 * MS),
        ],
    ),
];

/// The canonical `Scheme` a calibration key refers to (ablation variants
/// share their base key; their deltas are structural).  An unknown key is
/// a recoverable error naming every valid option — the same treatment
/// `Simulator::scheme_params` gives uncalibrated schemes; a bad key must
/// never kill a process that embeds the calibrator.
pub fn canonical_scheme(key: &str) -> Result<Scheme> {
    match key {
        "FP32" => Ok(Scheme::Fp32),
        "FP16" => Ok(Scheme::Fp16),
        "CUTLASS INT4" => Ok(Scheme::CutlassInt4),
        "CUTLASS INT1" => Ok(Scheme::CutlassInt1),
        "BSTC" => Ok(Scheme::Bstc),
        "BTC" => Ok(Scheme::Btc),
        "QLoRA W4" => Ok(Scheme::QloraW4),
        _ => {
            if let Some(p) = key.strip_prefix("ours-").and_then(PrecisionConfig::parse) {
                Ok(Scheme::Ours(p, OursOpts::paper()))
            } else if let Some(p) = key.strip_prefix("APNN-TC ").and_then(PrecisionConfig::parse) {
                Ok(Scheme::ApnnTc(p))
            } else {
                let mut keys: Vec<&str> = ANCHORS.iter().map(|(k, _)| *k).collect();
                keys.sort_unstable();
                Err(anyhow!(
                    "unknown calibration key {key:?} (valid keys: {}, plus any ours-wXaY / \
                     APNN-TC WxAy precision)",
                    keys.join(", ")
                ))
            }
        }
    }
}

/// Model time under candidate params (must mirror `Simulator::simulate`
/// for the canonical, fully-optimized configuration).
fn model_time(gpu: &Gpu, scheme: &Scheme, p: &SchemeParams, a: &Anchor) -> f64 {
    let (m, k, n, _) = *a;
    let work = baselines::scheme_work(scheme, m, k, n);
    let traffic = baselines::scheme_traffic(scheme, m, k, n);
    let t_compute = work / (p.rate_ops * p.util(m, k, n));
    // anchors were measured with the schemes' own on-chip reloads hidden
    // under compute (see Simulator::simulate) — only DRAM traffic floors
    let t_mem = traffic.dram / gpu.eff_bandwidth();
    p.launch_s + t_compute.max(t_mem)
}

fn fit_error(gpu: &Gpu, scheme: &Scheme, p: &SchemeParams, anchors: &[Anchor]) -> f64 {
    anchors
        .iter()
        .map(|a| (model_time(gpu, scheme, p, a) / a.3).ln().abs())
        .fold(0.0, f64::max)
}

/// Fit `(L, R_max, s_half)` for one scheme: coarse log-space grid followed
/// by two refinement passes around the best point.  Fails (listing the
/// valid options) when `key` names no known scheme.
pub fn fit_scheme(gpu: &Gpu, key: &str, anchors: &[Anchor]) -> Result<SchemeParams> {
    let scheme = canonical_scheme(key)?;
    let mut best = SchemeParams { launch_s: 5e-6, rate_ops: 1e14, s_half: 500.0 };
    let mut best_err = f64::INFINITY;
    // coarse grid (log space)
    let grid = |lo: f64, hi: f64, n: usize| -> Vec<f64> {
        (0..n)
            .map(|i| (lo.ln() + (hi.ln() - lo.ln()) * i as f64 / (n - 1) as f64).exp())
            .collect()
    };
    let search = |ls: &[f64], rs: &[f64], ss: &[f64], best: &mut SchemeParams, best_err: &mut f64| {
        for &l in ls {
            for &r in rs {
                for &s in ss {
                    let p = SchemeParams { launch_s: l, rate_ops: r, s_half: s };
                    let e = fit_error(gpu, &scheme, &p, anchors);
                    if e < *best_err {
                        *best_err = e;
                        *best = p;
                    }
                }
            }
        }
    };
    search(
        &grid(3e-7, 4e-5, 18),
        &grid(5e12, 5e16, 24),
        &grid(30.0, 8000.0, 18),
        &mut best,
        &mut best_err,
    );
    // refine twice around the incumbent
    for shrink in [3.0f64, 1.6] {
        let b = best;
        search(
            &grid(b.launch_s / shrink, b.launch_s * shrink, 13),
            &grid(b.rate_ops / shrink, b.rate_ops * shrink, 13),
            &grid(b.s_half / shrink, b.s_half * shrink, 13),
            &mut best,
            &mut best_err,
        );
    }
    Ok(best)
}

/// Per-anchor fit report (the calibrate CLI + EXPERIMENTS.md table).
#[derive(Debug, Clone)]
pub struct CalibrationReport {
    pub key: String,
    pub params: SchemeParams,
    /// (anchor, model_time_s, rel_err).
    pub rows: Vec<(Anchor, f64, f64)>,
    pub max_rel_err: f64,
}

impl CalibrationReport {
    /// Fit and report one scheme; an unknown `key` is a recoverable
    /// error listing the valid options.
    pub fn build(gpu: &Gpu, key: &str, anchors: &[Anchor]) -> Result<Self> {
        let params = fit_scheme(gpu, key, anchors)?;
        let scheme = canonical_scheme(key)?;
        let rows: Vec<_> = anchors
            .iter()
            .map(|a| {
                let t = model_time(gpu, &scheme, &params, a);
                (*a, t, (t - a.3).abs() / a.3)
            })
            .collect();
        let max_rel_err = rows.iter().map(|r| r.2).fold(0.0, f64::max);
        Ok(Self { key: key.to_string(), params, rows, max_rel_err })
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn unknown_calibration_key_is_an_error_listing_options() {
        let e = canonical_scheme("warp9").unwrap_err().to_string();
        assert!(e.contains("warp9"), "names the bad key: {e}");
        assert!(e.contains("FP16") && e.contains("BSTC"), "lists valid keys: {e}");
        let gpu = Gpu::rtx3090();
        assert!(fit_scheme(&gpu, "warp9", &[(64, 64, 64, 1e-6)]).is_err());
        assert!(CalibrationReport::build(&gpu, "warp9", &[(64, 64, 64, 1e-6)]).is_err());
        // every in-repo anchor key stays resolvable
        for (key, _) in ANCHORS.iter() {
            canonical_scheme(key).unwrap();
        }
    }
}
