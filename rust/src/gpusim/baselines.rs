//! Per-scheme work and traffic formulas (the structural half of the cost
//! model; the rate curves are fitted in `calibrate.rs`).

use super::kernels::{ours_traffic, OursOpts, TileConfig};
use super::Scheme;

/// Memory traffic split by hierarchy level: `dram` is compulsory traffic
/// (operands once + output once), `l2` is tile-reload traffic that hits
/// the (much faster) L2 after the first pass — operand matrices at these
/// precisions fit the GA102's 6 MB L2.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct Traffic {
    pub dram: f64,
    pub l2: f64,
}

impl Traffic {
    pub fn total(&self) -> f64 {
        self.dram + self.l2
    }
}

/// Native operation count of one `(M,K)×(K,N)` GEMM under `scheme`.
///
/// FP / CUTLASS / QLoRA count `2·M·N·K` native MACs; bit-decomposition
/// schemes (ours, APNN-TC) execute `n_w · n_x` 1-bit GEMMs, i.e.
/// `2·M·N·K·n_w·n_x` bit-ops.
pub fn scheme_work(scheme: &Scheme, m: usize, k: usize, n: usize) -> f64 {
    let base = 2.0 * m as f64 * n as f64 * k as f64;
    match scheme {
        Scheme::Ours(p, _) | Scheme::ApnnTc(p) => base * p.plane_pairs() as f64,
        _ => base,
    }
}

/// Memory traffic of one GEMM under `scheme`.
///
/// Output bytes follow the deployment pipeline: FP32 writes f32, FP16
/// writes f16, CUTLASS IGEMM writes i32 accumulators, and the quantized
/// inference paths (ours, APNN-TC, BSTC/BTC) requantize activations to
/// 8-bit before the next layer — the paper's LLM integration (§5.2)
/// implies the same, since its large-matrix latencies sit below the DRAM
/// cost of an i32 output.
pub fn scheme_traffic(scheme: &Scheme, m: usize, k: usize, n: usize) -> Traffic {
    let (mf, kf, nf) = (m as f64, k as f64, n as f64);
    match scheme {
        Scheme::Fp32 => Traffic { dram: 4.0 * (mf * kf + kf * nf) + 4.0 * mf * nf, l2: 0.0 },
        Scheme::Fp16 => Traffic { dram: 2.0 * (mf * kf + kf * nf) + 2.0 * mf * nf, l2: 0.0 },
        Scheme::QloraW4 => {
            // 4-bit stored weights dequantized in-kernel, FP16 compute
            Traffic { dram: 0.5 * mf * kf + 2.0 * kf * nf + 2.0 * mf * nf, l2: 0.0 }
        }
        Scheme::CutlassInt4 => {
            Traffic { dram: 0.5 * (mf * kf + kf * nf) + 4.0 * mf * nf, l2: 0.0 }
        }
        Scheme::CutlassInt1 => {
            Traffic { dram: (mf * kf + kf * nf) / 8.0 + 4.0 * mf * nf, l2: 0.0 }
        }
        Scheme::Bstc | Scheme::Btc => {
            Traffic { dram: (mf * kf + kf * nf) / 8.0 + mf * nf, l2: 0.0 }
        }
        Scheme::Ours(p, opts) => ours_traffic(m, k, n, p.nw, p.nx, opts),
        Scheme::ApnnTc(p) => {
            // APNN-TC uses smaller thread-block tiles (its smem layout is
            // sized for CNN-scale GEMMs) → more tile re-reads at LLM sizes.
            let opts = OursOpts {
                tiles: TileConfig { bm: 32, bn: 32, bk: 128 },
                ..OursOpts::paper()
            };
            ours_traffic(m, k, n, p.nw, p.nx, &opts)
        }
    }
}
