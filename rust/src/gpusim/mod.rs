//! Calibrated RTX 3090 tensor-core simulator — the substitute for the
//! paper's testbed (DESIGN.md §2).
//!
//! The paper's numbers come from CUDA kernels on an RTX 3090; this
//! environment has no NVIDIA GPU, so every scheme in the evaluation is
//! modeled as
//!
//! ```text
//! T(M,K,N) = L  +  { max(T_compute, T_mem)      with double buffering
//!                  { T_compute + T_mem           without (§4.2 ③ off)
//! T_compute = work / (R_max · util(s)),   util(s) = s / (s + s_half)
//! T_mem     = traffic / BW_eff
//! ```
//!
//! with `s = (M·N·K)^{1/3}` the effective size, `work` the scheme's native
//! op count and `traffic` derived from the kernel's *structural* tiling
//! model (`kernels.rs`).  The free parameters `(L, R_max, s_half)` of each
//! scheme are **fitted at construction time** against the paper's own
//! Table 1 + Table 2 anchor latencies (`calibrate.rs`), so the simulator
//! reproduces the paper's relative claims by construction and interpolates
//! structurally everywhere else (Fig. 5/6 sweeps, ablations, Fig. 7).
//!
//! **Honesty note** (recorded in EXPERIMENTS.md): fitting reveals that the
//! paper's W1A2/W2A2 large-matrix latencies imply bit-op throughputs of
//! ~9–13 P(bit)OPS — several times the GA102's documented INT1 tensor-core
//! roofline.  The simulator reproduces the paper's numbers anyway (that is
//! its job), but the fitted `R_max` values document the discrepancy.

mod arch;
mod baselines;
mod calibrate;
mod kernels;

pub use arch::Gpu;
pub use baselines::{scheme_traffic, scheme_work, Traffic};
pub use calibrate::{fit_scheme, CalibrationReport, ANCHORS};
pub use kernels::{pack_pass_bytes, smem_bytes_per_block, OursOpts, TileConfig};

use crate::anyhow::{anyhow, Result};
use crate::model::{LlmArch, MatMulShape, PrecisionConfig};
use std::collections::HashMap;

/// Every scheme the paper's evaluation section compares.
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum Scheme {
    /// PyTorch FP32 MatMul (CUDA cores).
    Fp32,
    /// PyTorch FP16 MatMul (tensor cores).
    Fp16,
    /// CUTLASS INT4 tensor-core GEMM.
    CutlassInt4,
    /// CUTLASS INT1 (BMMA) GEMM.
    CutlassInt1,
    /// This paper's kernel at a given precision, with the §4.1/§4.2
    /// optimization knobs (all-on = the paper's configuration).
    Ours(PrecisionConfig, OursOpts),
    /// APNN-TC [8] at a given precision (W ≤ 2 only — its documented limit).
    ApnnTc(PrecisionConfig),
    /// BSTC [17]: binarized soft tensor core, 1-bit only.
    Bstc,
    /// BTC [18]: bit tensor core, 1-bit only.
    Btc,
    /// QLoRA-style W4 with on-the-fly dequant to FP16.
    QloraW4,
}

impl Scheme {
    pub fn ours(p: PrecisionConfig) -> Self {
        Scheme::Ours(p, OursOpts::paper())
    }

    pub fn label(&self) -> String {
        match self {
            Scheme::Fp32 => "FP32".into(),
            Scheme::Fp16 => "FP16".into(),
            Scheme::CutlassInt4 => "CUTLASS INT4".into(),
            Scheme::CutlassInt1 => "CUTLASS INT1".into(),
            Scheme::Ours(p, o) if *o == OursOpts::paper() => format!("{} (ours)", p.label()),
            Scheme::Ours(p, _) => format!("{} (ours, ablated)", p.label()),
            Scheme::ApnnTc(p) => format!("APNN-TC {}", p.label()),
            Scheme::Bstc => "BSTC".into(),
            Scheme::Btc => "BTC".into(),
            Scheme::QloraW4 => "QLoRA W4".into(),
        }
    }

    /// Key used to look up fitted rate parameters (ablation knobs share
    /// the base scheme's calibration; their deltas are structural).
    fn fit_key(&self) -> String {
        match self {
            Scheme::Ours(p, _) => format!("ours-{}", p.label()),
            s => s.label(),
        }
    }
}

/// Simulated execution breakdown of one GEMM.
#[derive(Debug, Clone, Copy)]
pub struct SimResult {
    pub time_s: f64,
    pub t_compute_s: f64,
    pub t_mem_s: f64,
    /// Extra global-memory recovery pass (only when §4.2 fusion is off).
    pub t_recovery_s: f64,
    /// Inline weight decompose+pack pass (only when the §3.3 `prepacked`
    /// knob is off — the pack-once configuration pays this exactly once,
    /// offline, so it never shows up in a simulated GEMM).
    pub t_pack_s: f64,
    pub launch_s: f64,
    pub util: f64,
    pub traffic_bytes: f64,
    pub work_ops: f64,
}

impl SimResult {
    /// Tera-operations per second in the scheme's native ops (the paper's
    /// Fig. 5/6 metric counts 2·M·N·K ops regardless of precision).
    pub fn tops_effective(&self, m: usize, k: usize, n: usize) -> f64 {
        2.0 * m as f64 * k as f64 * n as f64 / self.time_s / 1e12
    }
}

/// Fitted per-scheme rate curve.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct SchemeParams {
    /// Fixed launch + tail overhead (s).
    pub launch_s: f64,
    /// Asymptotic throughput in native ops/s.
    pub rate_ops: f64,
    /// Size at which utilization reaches 50%.
    pub s_half: f64,
}

impl SchemeParams {
    /// Utilization at effective size `s = (M·N·K)^{1/3}` with an
    /// aspect-ratio penalty: skewed GEMMs (tall/flat, e.g. the paper's
    /// Table 2 LLM shapes) run at lower efficiency than square ones of
    /// equal volume on every scheme the paper measures.
    pub fn util(&self, m: usize, k: usize, n: usize) -> f64 {
        let s_eff = (m as f64 * n as f64 * k as f64).cbrt();
        let min_dim = m.min(k).min(n) as f64;
        let aspect = (min_dim / s_eff).min(1.0).powf(0.5);
        s_eff / (s_eff + self.s_half) * aspect
    }
}

/// The simulator: device + calibrated scheme curves.
pub struct Simulator {
    pub gpu: Gpu,
    params: HashMap<String, SchemeParams>,
}

impl Simulator {
    /// Build an RTX 3090 simulator calibrated against the paper's
    /// Table 1 / Table 2 anchors.  Deterministic; takes ~1 ms.
    pub fn rtx3090() -> Self {
        let gpu = Gpu::rtx3090();
        let mut params = HashMap::new();
        for (key, anchors) in calibrate::ANCHORS.iter() {
            // in-repo anchor keys are canonical by construction (pinned
            // by calibrate's unit test), so this cannot fail here; an
            // out-of-repo key reaches the Result-returning API instead
            let fitted = calibrate::fit_scheme(&gpu, key, anchors)
                .expect("ANCHORS keys are canonical");
            params.insert((*key).to_string(), fitted);
        }
        Self { gpu, params }
    }

    /// Fitted rate curve for `scheme`.  A scheme outside the calibrated
    /// set (e.g. an APNN-TC precision beyond its documented W ≤ 2 limit)
    /// is a recoverable error naming the valid keys — a bad user flag
    /// must never kill a serving process.
    pub fn scheme_params(&self, scheme: &Scheme) -> Result<SchemeParams> {
        let key = scheme.fit_key();
        self.params.get(&key).copied().ok_or_else(|| {
            let mut keys: Vec<&str> = self.params.keys().map(String::as_str).collect();
            keys.sort_unstable();
            anyhow!("no calibration for scheme {key} (calibrated schemes: {})", keys.join(", "))
        })
    }

    /// Simulate one `(M,K) × (K,N)` GEMM under `scheme`.
    pub fn simulate(&self, scheme: &Scheme, m: usize, k: usize, n: usize) -> Result<SimResult> {
        let p = self.scheme_params(scheme)?;
        let util = p.util(m, k, n);
        let work = baselines::scheme_work(scheme, m, k, n);
        let traffic = baselines::scheme_traffic(scheme, m, k, n);
        let t_compute = work / (p.rate_ops * util);
        // Exposed memory time: compulsory DRAM traffic, plus any on-chip
        // reload traffic *beyond* the paper configuration's own (the §4.2
        // schedule hides its own reloads under compute by construction —
        // that hiding is what the anchors were measured with; ablations
        // that add traffic pay the difference at L2 speed).
        let l2_exposed = match scheme {
            Scheme::Ours(prec, opts) => {
                let base = baselines::scheme_traffic(&Scheme::ours(*prec), m, k, n).l2;
                (kernels::ours_traffic(m, k, n, prec.nw, prec.nx, opts).l2 - base).max(0.0)
            }
            _ => 0.0,
        };
        let t_mem = traffic.dram / self.gpu.eff_bandwidth() + l2_exposed / self.gpu.l2_bw;
        let (overlap, t_recovery) = match scheme {
            Scheme::Ours(prec, opts) => {
                let rec = if opts.fused_recovery {
                    0.0
                } else {
                    // unfused: D_ij tiles round-trip global memory
                    let bytes = 8.0 * m as f64 * n as f64 * prec.plane_pairs() as f64;
                    bytes / self.gpu.eff_bandwidth()
                };
                (opts.double_buffer, rec)
            }
            _ => (true, 0.0),
        };
        // §3.3 off: the weight operand is decomposed+packed inline, a
        // serial bandwidth-bound pass before the kernel proper (the
        // pack-once configuration does this offline instead).
        let t_pack = match scheme {
            Scheme::Ours(prec, opts) if !opts.prepacked => {
                kernels::pack_pass_bytes(m, k, prec.nw) / self.gpu.eff_bandwidth()
            }
            _ => 0.0,
        };
        let body = if overlap { t_compute.max(t_mem) } else { t_compute + t_mem };
        Ok(SimResult {
            time_s: p.launch_s + body + t_recovery + t_pack,
            t_compute_s: t_compute,
            t_mem_s: t_mem,
            t_recovery_s: t_recovery,
            t_pack_s: t_pack,
            launch_s: p.launch_s,
            util,
            traffic_bytes: traffic.total(),
            work_ops: work,
        })
    }

    /// §3.3 pack-vs-compute split over a model's forward GEMMs: for each
    /// shape, the **one-time** weight pack cost, the **per-forward**
    /// activation pack cost, and the per-forward prepacked GEMM time.
    /// This is the structural argument for the pack-once pipeline: the
    /// weight column amortizes to zero while the compute column repeats
    /// every step.
    pub fn llm_pack_split(
        &self,
        arch: &LlmArch,
        prec: PrecisionConfig,
        m: usize,
    ) -> Result<Vec<PackSplitRow>> {
        let bw = self.gpu.eff_bandwidth();
        let scheme = Scheme::ours(prec);
        let mut rows = Vec::new();
        for s in arch.forward_shapes(m) {
            rows.push(PackSplitRow {
                label: s.label,
                weight_pack_once_s: kernels::pack_pass_bytes(s.k, s.n, prec.nw) / bw
                    * s.count as f64,
                act_pack_step_s: kernels::pack_pass_bytes(s.m, s.k, prec.nx) / bw
                    * s.count as f64,
                gemm_step_s: self.simulate(&scheme, s.m, s.k, s.n)?.time_s * s.count as f64,
            });
        }
        Ok(rows)
    }

    /// Total MatMul time of one forward pass over `m` tokens (Fig. 7).
    pub fn llm_matmul_time(&self, arch: &LlmArch, scheme: &Scheme, m: usize) -> Result<f64> {
        let mut total = 0.0;
        for s in arch.forward_shapes(m) {
            total += self.simulate(scheme, s.m, s.k, s.n)?.time_s * s.count as f64;
        }
        Ok(total)
    }

    /// End-to-end inference speedup over FP16 (Fig. 7's metric).
    ///
    /// Non-MatMul work (attention softmax, norms, KV traffic, sampling) is
    /// `NON_MATMUL_FRAC` of the FP16 MatMul time and identical across
    /// schemes — quantization does not touch it.
    pub fn llm_speedup_vs_fp16(&self, arch: &LlmArch, scheme: &Scheme, m: usize) -> Result<f64> {
        let fp16 = self.llm_matmul_time(arch, &Scheme::Fp16, m)?;
        let other = NON_MATMUL_FRAC * fp16;
        let t = self.llm_matmul_time(arch, scheme, m)?;
        Ok((fp16 + other) / (t + other))
    }

    /// Simulated per-GEMM times for a set of shapes (helper for benches).
    pub fn simulate_shapes(&self, scheme: &Scheme, shapes: &[MatMulShape]) -> Result<f64> {
        let mut total = 0.0;
        for s in shapes {
            total += self.simulate(scheme, s.m, s.k, s.n)?.time_s * s.count as f64;
        }
        Ok(total)
    }
}

/// One row of [`Simulator::llm_pack_split`].
#[derive(Debug, Clone, Copy)]
pub struct PackSplitRow {
    pub label: &'static str,
    /// Weight decompose+pack cost, paid ONCE at load time (§3.3).
    pub weight_pack_once_s: f64,
    /// Activation pack cost, paid every forward.
    pub act_pack_step_s: f64,
    /// Prepacked GEMM time per forward.
    pub gemm_step_s: f64,
}

/// Fraction of FP16 MatMul time spent on non-MatMul work per forward
/// (attention softmax/KV, norms, embeddings, sampling).  Calibrated so the
/// Fig. 7 FP16-relative speedups land in the paper's 3.9–6.7× band.
pub const NON_MATMUL_FRAC: f64 = 0.15;

#[cfg(test)]
mod tests;
