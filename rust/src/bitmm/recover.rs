//! Data-recovery dataflow (paper §3.2 step 3): reconstruct the output
//! matrix from intermediate 1-bit GEMM results by shifting each `D_ij` by
//! its bit positions `(i, j)` and summing.
//!
//! The production kernel fuses this into its accumulator (`apmm_bipolar`);
//! this standalone pass exists for the unfused/naive baseline and for
//! testing the recovery math in isolation.

/// `Y = Σ 2^{i+j} · D_ij` over `(i, j, D_ij)` tiles of shape `(m, n)`.
pub fn recover_tiles(m: usize, n: usize, tiles: &[(u32, u32, Vec<i32>)]) -> Vec<i32> {
    let mut y = vec![0i64; m * n];
    for (i, j, d) in tiles {
        assert_eq!(d.len(), m * n, "tile shape mismatch");
        let shift = i + j;
        for (acc, &v) in y.iter_mut().zip(d.iter()) {
            *acc += (v as i64) << shift;
        }
    }
    // same fail-loudly cast as the fused kernel, so the cross-check pair
    // cannot silently diverge in the overflow regime
    y.into_iter().map(super::apmm::checked_i32).collect()
}
