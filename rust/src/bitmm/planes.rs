//! Bit-plane decomposition and word packing (paper §4.1).
//!
//! `PackedPlanes` is the operand layout every kernel here consumes: plane
//! `i` of an n-bit code matrix is a `rows × kw` array of `u64` words, bit
//! `b` of word `w` holding the code's bit `i` at column `w·64 + b`
//! (LSB-first).  The n planes are stored **concatenated** in one contiguous
//! allocation (§4.1 step 3), so a row of all planes streams as one slice.

use crate::bitfmt::IntFormat;

/// A row-major matrix of n-bit integer codes (values `< 2^bits`).
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct CodeMatrix {
    pub rows: usize,
    pub cols: usize,
    pub bits: u32,
    pub data: Vec<u32>,
}

impl CodeMatrix {
    pub fn new(rows: usize, cols: usize, bits: u32, data: Vec<u32>) -> Self {
        assert_eq!(data.len(), rows * cols, "shape/data mismatch");
        debug_assert!(data.iter().all(|&c| c < (1 << bits)), "code out of range");
        Self { rows, cols, bits, data }
    }

    /// Filled with a constant code.
    pub fn splat(rows: usize, cols: usize, bits: u32, code: u32) -> Self {
        Self::new(rows, cols, bits, vec![code; rows * cols])
    }

    /// Uniform random codes from a seeded generator (tests/benches).
    pub fn random(rows: usize, cols: usize, bits: u32, seed: u64) -> Self {
        let mut rng = crate::util::Rng::with_seed(seed);
        let data = (0..rows * cols).map(|_| rng.u32(0, 1 << bits)).collect();
        Self::new(rows, cols, bits, data)
    }

    #[inline]
    pub fn at(&self, r: usize, c: usize) -> u32 {
        self.data[r * self.cols + c]
    }

    /// Decode every element under `fmt` into an `i32` matrix.
    pub fn decode(&self, fmt: IntFormat) -> Vec<i32> {
        use crate::bitfmt::{bipolar_decode, signed_decode, unsigned_decode};
        let f = match fmt {
            IntFormat::Bipolar => bipolar_decode,
            IntFormat::Signed => signed_decode,
            IntFormat::Unsigned => unsigned_decode,
        };
        self.data.iter().map(|&c| f(c, self.bits)).collect()
    }
}

/// Bit planes of a code matrix, packed along the column (K) axis into u64
/// words, planes concatenated (§4.1).
#[derive(Debug, Clone)]
pub struct PackedPlanes {
    pub rows: usize,
    /// Logical K (unpadded column count).
    pub cols: usize,
    /// Words per row: `ceil(cols / 64)`; padding bits are zero.
    pub kw: usize,
    pub bits: u32,
    data: Vec<u64>,
}

impl PackedPlanes {
    /// Plane `i`, row `r` as a word slice.
    #[inline(always)]
    pub fn row(&self, plane: u32, r: usize) -> &[u64] {
        let base = (plane as usize * self.rows + r) * self.kw;
        &self.data[base..base + self.kw]
    }

    /// All planes of row `r` are NOT contiguous (planes are outer) — this
    /// returns the full backing store for kernels that stride it manually.
    #[inline(always)]
    pub fn raw(&self) -> &[u64] {
        &self.data
    }

    #[inline(always)]
    pub fn plane_stride(&self) -> usize {
        self.rows * self.kw
    }

    /// Total packed footprint in bytes (the §4.1 memory-saving claim:
    /// exactly `bits` bits per element plus word-alignment padding).
    pub fn nbytes(&self) -> usize {
        self.data.len() * 8
    }
}

/// Decompose + pack into the **u32 kernel layout** the Pallas artifacts
/// consume: `(bits, rows, ceil(cols/32))` row-major, bit `b` of word `w`
/// holding column `w·32 + b` (LSB-first) — identical to
/// `python/compile/quant.pack_along_k`.
pub fn pack_codes_u32(m: &CodeMatrix) -> Vec<u32> {
    let kw = m.cols.div_ceil(32);
    let mut data = vec![0u32; m.bits as usize * m.rows * kw];
    for plane in 0..m.bits {
        for r in 0..m.rows {
            let base = (plane as usize * m.rows + r) * kw;
            for c in 0..m.cols {
                let bit = (m.at(r, c) >> plane) & 1;
                data[base + c / 32] |= bit << (c % 32);
            }
        }
    }
    data
}

/// Decompose + pack a code matrix (paper §4.1 steps 1–3).
///
/// Single pass over the codes: each 64-column chunk accumulates all `bits`
/// plane words in registers before scattering them to the plane-major
/// layout; rows are processed in parallel (each row's writes are disjoint).
pub fn pack_codes(m: &CodeMatrix) -> PackedPlanes {
    let kw = m.cols.div_ceil(64);
    let bits = m.bits as usize;
    let plane_stride = m.rows * kw;
    let mut data = vec![0u64; bits * plane_stride];

    // Disjoint-write parallelism over rows: every (plane, row) slot is
    // touched by exactly one row index, so the raw-pointer writes below
    // never alias across par_for workers.
    struct Ptr(*mut u64);
    unsafe impl Sync for Ptr {}
    let ptr = Ptr(data.as_mut_ptr());
    let rows = m.rows;
    let cols = m.cols;
    let src_all = &m.data;
    crate::util::par_for(rows, |r| {
        let p = &ptr;
        let src = &src_all[r * cols..(r + 1) * cols];
        for w in 0..kw {
            let c0 = w * 64;
            let chunk = &src[c0..cols.min(c0 + 64)];
            let mut acc = [0u64; 16]; // bits ≤ 16
            for (b, &code) in chunk.iter().enumerate() {
                let mut c = code as u64;
                for a in acc.iter_mut().take(bits) {
                    *a |= (c & 1) << b;
                    c >>= 1;
                }
            }
            for (plane, &a) in acc.iter().enumerate().take(bits) {
                // SAFETY: index (plane, r, w) is unique to this `r`
                unsafe { *p.0.add(plane * plane_stride + r * kw + w) = a };
            }
        }
    });
    PackedPlanes { rows: m.rows, cols: m.cols, kw, bits: m.bits, data }
}
