//! Bit-plane decomposition and word packing (paper §4.1) — the home of the
//! **prepacked kernel ABI**.
//!
//! `PackedPlanes` is the operand layout every kernel here consumes: plane
//! `i` of an n-bit code matrix is a `rows × kw` array of `u64` words, bit
//! `b` of word `w` holding the code's bit `i` at column `w·64 + b`
//! (LSB-first).  The n planes are stored **concatenated** in one contiguous
//! allocation (§4.1 step 3), so a row of all planes streams as one slice.
//!
//! Lifecycle (§3.3 pack-once): a `CodeMatrix` is a **construction-time**
//! artifact — quantizers produce it, `pack_codes` / `pack_codes_into`
//! decompose it into `PackedPlanes` exactly once (weights via
//! [`super::prepack::PlaneCache`] / [`super::prepack::PackedWeightStore`],
//! decode-step activations via the [`super::prepack::PackArena`]), and the
//! hot path only ever touches the packed form through the `apmm_*_packed`
//! kernels.
//!
//! ## Any-precision views
//!
//! Because recovery weights planes by `2^i`, the **most-significant `k`
//! planes of an n-bit pack are themselves a complete k-bit operand**: bit
//! `j` of `code >> (n−k)` is bit `(n−k)+j` of `code`, so a zero-copy
//! [`PlaneView`] over the top `k` planes decodes exactly like a fresh pack
//! of the truncated codes.  One packed superset weight therefore serves
//! *every* precision `k ≤ n` (the Any-Precision deployment model, per
//! PAPERS.md); the [`Planes`] trait is the operand abstraction that lets
//! the `apmm_*_packed` cores consume full packs and views alike.

use crate::bitfmt::IntFormat;

/// Widest per-operand bit-width the kernels support.  Bounded so plane
/// loops can use fixed-size register arrays and so `1 << bits` shifts are
/// always in range (shifting by ≥ 32 would be UB on the `u32` code type).
pub const MAX_BITS: u32 = 16;

#[inline]
fn assert_bits(bits: u32) {
    assert!(
        (1..=MAX_BITS).contains(&bits),
        "bit-width must be in 1..={MAX_BITS}, got {bits}"
    );
}

/// A row-major matrix of n-bit integer codes (values `< 2^bits`).
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct CodeMatrix {
    pub rows: usize,
    pub cols: usize,
    pub bits: u32,
    pub data: Vec<u32>,
}

impl CodeMatrix {
    /// Panics unless `bits ∈ 1..=16` (wider would overflow the shift-add
    /// recovery weights and the `u32` code storage).
    pub fn new(rows: usize, cols: usize, bits: u32, data: Vec<u32>) -> Self {
        assert_bits(bits);
        assert_eq!(data.len(), rows * cols, "shape/data mismatch");
        // widened shift: safe for every validated bits (incl. 16)
        debug_assert!(
            data.iter().all(|&c| (c as u64) < (1u64 << bits)),
            "code out of range"
        );
        Self { rows, cols, bits, data }
    }

    /// Filled with a constant code.
    pub fn splat(rows: usize, cols: usize, bits: u32, code: u32) -> Self {
        assert_bits(bits);
        Self::new(rows, cols, bits, vec![code; rows * cols])
    }

    /// Uniform random codes from a seeded generator (tests/benches).
    pub fn random(rows: usize, cols: usize, bits: u32, seed: u64) -> Self {
        assert_bits(bits);
        let mut rng = crate::util::Rng::with_seed(seed);
        // lint: allow(narrowing-cast) — bits ≤ 16, so 2^bits fits u32
        let hi = (1u64 << bits) as u32;
        let data = (0..rows * cols).map(|_| rng.u32(0, hi)).collect();
        Self::new(rows, cols, bits, data)
    }

    #[inline]
    pub fn at(&self, r: usize, c: usize) -> u32 {
        self.data[r * self.cols + c]
    }

    /// Decode every element under `fmt` into an `i32` matrix.
    pub fn decode(&self, fmt: IntFormat) -> Vec<i32> {
        use crate::bitfmt::{bipolar_decode, signed_decode, unsigned_decode};
        let f = match fmt {
            IntFormat::Bipolar => bipolar_decode,
            IntFormat::Signed => signed_decode,
            IntFormat::Unsigned => unsigned_decode,
        };
        self.data.iter().map(|&c| f(c, self.bits)).collect()
    }
}

/// Bit planes of a code matrix, packed along the column (K) axis into u64
/// words, planes concatenated (§4.1).  **This is the kernel operand**: the
/// `apmm_*_packed` cores take it directly and never re-pack; shape and
/// bit-width metadata travel with the planes so a prepacked weight is
/// self-describing.
#[derive(Debug, Clone)]
pub struct PackedPlanes {
    pub rows: usize,
    /// Logical K (unpadded column count).
    pub cols: usize,
    /// Words per row: `ceil(cols / 64)`; padding bits are zero.
    pub kw: usize,
    pub bits: u32,
    data: Vec<u64>,
}

impl PackedPlanes {
    /// Assemble from a raw plane-major buffer (the `PackArena` recycling
    /// path).  The buffer must hold exactly `bits · rows · ceil(cols/64)`
    /// words and the caller is responsible for every word being a freshly
    /// packed value (padding bits zero) — `pack_codes_into` guarantees
    /// both.
    pub fn from_raw_parts(rows: usize, cols: usize, bits: u32, data: Vec<u64>) -> Self {
        assert_bits(bits);
        let kw = cols.div_ceil(64);
        assert_eq!(data.len(), bits as usize * rows * kw, "plane buffer size");
        Self { rows, cols, kw, bits, data }
    }

    /// Tear down into the backing buffer (so an arena can recycle it).
    pub fn into_raw(self) -> Vec<u64> {
        self.data
    }

    /// Plane `i`, row `r` as a word slice.
    #[inline(always)]
    pub fn row(&self, plane: u32, r: usize) -> &[u64] {
        let base = (plane as usize * self.rows + r) * self.kw;
        &self.data[base..base + self.kw]
    }

    /// All planes of row `r` are NOT contiguous (planes are outer) — this
    /// returns the full backing store for kernels that stride it manually.
    #[inline(always)]
    pub fn raw(&self) -> &[u64] {
        &self.data
    }

    #[inline(always)]
    pub fn plane_stride(&self) -> usize {
        self.rows * self.kw
    }

    /// Total packed footprint in bytes (the §4.1 memory-saving claim:
    /// exactly `bits` bits per element plus word-alignment padding).
    pub fn nbytes(&self) -> usize {
        self.data.len() * 8
    }

    /// Borrow the most-significant `bits` planes as a zero-copy
    /// [`PlaneView`] — the any-precision slice: the view is exactly the
    /// pack of `code >> (self.bits − bits)` at `bits` bits, without
    /// repacking or copying a single word.  Panics unless
    /// `1 ≤ bits ≤ self.bits`.
    pub fn view(&self, bits: u32) -> PlaneView<'_> {
        assert!(
            (1..=self.bits).contains(&bits),
            "cannot view {bits} planes of a {}-bit pack",
            self.bits
        );
        PlaneView { planes: self, bits, skip: self.bits - bits }
    }
}

/// Read-only bit-plane operand — what every `apmm_*_packed` core consumes.
/// Implemented by [`PackedPlanes`] (all planes) and [`PlaneView`] (a
/// most-significant-plane prefix), so a single packed superset weight can
/// serve any lower precision without repacking.  Plane `i` carries
/// recovery weight `2^i` regardless of the implementor.  `Sync` is a
/// supertrait because the kernels fan row blocks out across scoped
/// threads, sharing the operands by reference.
pub trait Planes: Sync {
    fn rows(&self) -> usize;
    /// Logical K (unpadded column count).
    fn cols(&self) -> usize;
    /// Words per row: `ceil(cols / 64)`; padding bits are zero.
    fn kw(&self) -> usize;
    /// Planes exposed by this operand.
    fn bits(&self) -> u32;
    /// Plane `i`, row `r` as a word slice.
    fn row(&self, plane: u32, r: usize) -> &[u64];
}

impl Planes for PackedPlanes {
    #[inline(always)]
    fn rows(&self) -> usize {
        self.rows
    }

    #[inline(always)]
    fn cols(&self) -> usize {
        self.cols
    }

    #[inline(always)]
    fn kw(&self) -> usize {
        self.kw
    }

    #[inline(always)]
    fn bits(&self) -> u32 {
        self.bits
    }

    #[inline(always)]
    fn row(&self, plane: u32, r: usize) -> &[u64] {
        PackedPlanes::row(self, plane, r)
    }
}

/// A borrowed prefix of the **most-significant** `bits` planes of a
/// [`PackedPlanes`] — the any-precision operand.
///
/// View plane `j` is full plane `skip + j` (`skip = full_bits − bits`), so
/// the view is bit-for-bit the pack of the codes truncated to their top
/// `bits` bits (`code >> skip`).  Under bipolar decoding the full value
/// splits as `v = 2^skip · v_view + (2r + 1 − 2^skip)` with `r` the
/// dropped low bits, so serving a view *is* serving the weight at the
/// lower precision with its dequant scale multiplied by `2^skip` (see
/// `quant::view_scales`).  `Copy` and zero-copy: slicing allocates
/// nothing and never touches plane words.
#[derive(Debug, Clone, Copy)]
pub struct PlaneView<'a> {
    planes: &'a PackedPlanes,
    /// Planes exposed (`≤ planes.bits`).
    bits: u32,
    /// Dropped least-significant planes: `planes.bits − bits`.
    skip: u32,
}

impl PlaneView<'_> {
    /// Least-significant planes this view drops (`full_bits − bits`);
    /// the dequant scale of the view is the full pack's scale times
    /// `2^skip`.
    pub fn skip(&self) -> u32 {
        self.skip
    }

    /// Bytes this view's planes would occupy as a standalone pack — what
    /// a dedicated per-precision weight store would have to hold.
    pub fn nbytes(&self) -> usize {
        self.bits as usize * self.planes.rows * self.planes.kw * 8
    }
}

impl Planes for PlaneView<'_> {
    #[inline(always)]
    fn rows(&self) -> usize {
        self.planes.rows
    }

    #[inline(always)]
    fn cols(&self) -> usize {
        self.planes.cols
    }

    #[inline(always)]
    fn kw(&self) -> usize {
        self.planes.kw
    }

    #[inline(always)]
    fn bits(&self) -> u32 {
        self.bits
    }

    #[inline(always)]
    fn row(&self, plane: u32, r: usize) -> &[u64] {
        debug_assert!(plane < self.bits, "plane {plane} outside {}-plane view", self.bits);
        self.planes.row(self.skip + plane, r)
    }
}

/// Decompose + pack into the **u32 kernel layout** the Pallas artifacts
/// consume: `(bits, rows, ceil(cols/32))` row-major, bit `b` of word `w`
/// holding column `w·32 + b` (LSB-first) — identical to
/// `python/compile/quant.pack_along_k`.
pub fn pack_codes_u32(m: &CodeMatrix) -> Vec<u32> {
    let kw = m.cols.div_ceil(32);
    let mut data = vec![0u32; m.bits as usize * m.rows * kw];
    for plane in 0..m.bits {
        for r in 0..m.rows {
            let base = (plane as usize * m.rows + r) * kw;
            for c in 0..m.cols {
                let bit = (m.at(r, c) >> plane) & 1;
                data[base + c / 32] |= bit << (c % 32);
            }
        }
    }
    data
}

/// Decompose + pack a code matrix (paper §4.1 steps 1–3).
///
/// Single pass over the codes: each 64-column chunk accumulates all `bits`
/// plane words in registers before scattering them to the plane-major
/// layout; rows are processed in parallel (each row's writes are disjoint).
pub fn pack_codes(m: &CodeMatrix) -> PackedPlanes {
    let kw = m.cols.div_ceil(64);
    let mut data = vec![0u64; m.bits as usize * m.rows * kw];
    pack_codes_into(m, &mut data);
    PackedPlanes { rows: m.rows, cols: m.cols, kw, bits: m.bits, data }
}

/// As [`pack_codes`] but writing into a caller-provided buffer of exactly
/// `bits · rows · ceil(cols/64)` words — the allocation-free path the
/// [`super::prepack::PackArena`] uses on the decode hot path.  Every word
/// of `data` is overwritten (stale contents are fine).
pub fn pack_codes_into(m: &CodeMatrix, data: &mut [u64]) {
    pack_rows_into(m.rows, m.cols, m.bits, &m.data, data);
}

/// The `CodeMatrix`-free core of [`pack_codes_into`]: packs a raw
/// row-major code buffer (`rows × cols`, values `< 2^bits`).  This is the
/// **batched-activation pack entry** — the serving hot path stages each
/// decode step's activation rows into a recycled `u32` buffer
/// ([`super::prepack::PackArena::pack_batch`]) and packs them in one shot
/// without constructing an owning `CodeMatrix`.
pub fn pack_rows_into(rows: usize, cols: usize, bits: u32, codes: &[u32], data: &mut [u64]) {
    assert_bits(bits);
    assert_eq!(codes.len(), rows * cols, "codes shape");
    debug_assert!(
        codes.iter().all(|&c| (c as u64) < (1u64 << bits)),
        "code out of range"
    );
    let kw = cols.div_ceil(64);
    let bits = bits as usize;
    let plane_stride = rows * kw;
    assert_eq!(data.len(), bits * plane_stride, "plane buffer size");

    // Disjoint-write parallelism over rows: every (plane, row) slot is
    // touched by exactly one row index, so the raw-pointer writes below
    // never alias across pool workers.
    let ptr = crate::util::SendPtr::new(data.as_mut_ptr());
    let src_all = codes;
    crate::util::par_for(rows, |r| {
        let src = &src_all[r * cols..(r + 1) * cols];
        for w in 0..kw {
            let c0 = w * 64;
            let chunk = &src[c0..cols.min(c0 + 64)];
            let mut acc = [0u64; MAX_BITS as usize];
            for (b, &code) in chunk.iter().enumerate() {
                let mut c = code as u64;
                for a in acc.iter_mut().take(bits) {
                    *a |= (c & 1) << b;
                    c >>= 1;
                }
            }
            for (plane, &a) in acc.iter().enumerate().take(bits) {
                // SAFETY: index (plane, r, w) is unique to this `r`
                unsafe { *ptr.get().add(plane * plane_stride + r * kw + w) = a };
            }
        }
    });
}
