//! Pack-once operand management (paper §3.3 matrix preprocessing + §3.4
//! recovery-oriented memory management, realized on the CPU substrate).
//!
//! Three pieces, all keeping layout work **off the hot path**:
//!
//! * [`PlaneCache`] — key → `Arc<PackedPlanes>` memoizer: a weight matrix
//!   is decomposed+packed on first use and every later lookup returns the
//!   *same* buffer (no repack, no copy).
//! * [`PackedWeightStore`] — the model-level registry: named prepacked
//!   weights with their dequant scales, shared across serving steps and
//!   replicas.  Packed once at the **widest precision served**, it is an
//!   any-precision superset: [`PackedWeightStore::get_at`] slices any
//!   lower precision as a zero-copy plane-prefix view with rescaled
//!   scales, so a mixed-precision cluster holds one store, not one per
//!   precision.
//! * [`PackArena`] — shape-keyed scratch `u64` buffers for decode-step
//!   **activation** packing (the shared-memory staging analog): after
//!   warm-up, packing an activation batch performs zero heap allocations.

use super::planes::{
    pack_codes, pack_codes_into, pack_rows_into, CodeMatrix, PackedPlanes, PlaneView,
};
use std::collections::HashMap;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Mutex};

/// Composite plane-cache key: caller id plus the codes' (bits, rows,
/// cols).  The id alone is NOT the identity of a packed weight — the same
/// id requantized to a different bit-width, or an id collision across
/// differently-shaped layers, must pack fresh rather than silently return
/// stale planes of the wrong shape/bit-width.
type CacheKey = (u64, u32, usize, usize);

/// Pack-once memoizer for weight planes.
///
/// Keys combine a caller-chosen id (layer index, weight id, …) with the
/// codes' bit-width and shape (see [`CacheKey`]).  A hit returns a clone
/// of the stored `Arc` — the identical packed buffer, never a repack; the
/// hit/miss counters let tests and benches prove it.
#[derive(Default)]
pub struct PlaneCache {
    map: HashMap<CacheKey, Arc<PackedPlanes>>,
    hits: u64,
    misses: u64,
}

impl PlaneCache {
    pub fn new() -> Self {
        Self::default()
    }

    /// The pack-once entry point: packs `codes` on the first call for
    /// `(key, bits, rows, cols)`, returns the cached planes on every
    /// later call with the same id *and* the same shape/bit-width.
    pub fn get_or_pack(&mut self, key: u64, codes: &CodeMatrix) -> Arc<PackedPlanes> {
        let full = (key, codes.bits, codes.rows, codes.cols);
        if let Some(p) = self.map.get(&full) {
            self.hits += 1;
            debug_assert!(
                p.bits == codes.bits && p.rows == codes.rows && p.cols == codes.cols,
                "plane cache hit disagrees with the requested shape/bit-width"
            );
            return p.clone();
        }
        self.misses += 1;
        let p = Arc::new(pack_codes(codes));
        self.map.insert(full, p.clone());
        p
    }

    /// Lookup without packing (same composite identity as
    /// [`PlaneCache::get_or_pack`]).
    pub fn get(&self, key: u64, bits: u32, rows: usize, cols: usize) -> Option<Arc<PackedPlanes>> {
        self.map.get(&(key, bits, rows, cols)).cloned()
    }

    pub fn hits(&self) -> u64 {
        self.hits
    }

    pub fn misses(&self) -> u64 {
        self.misses
    }

    pub fn len(&self) -> usize {
        self.map.len()
    }

    pub fn is_empty(&self) -> bool {
        self.map.is_empty()
    }

    pub fn clear(&mut self) {
        self.map.clear();
    }
}

/// One named, prepacked weight: planes plus dequant scales (one per
/// output row, or a single per-tensor element).
#[derive(Clone)]
pub struct PackedWeight {
    pub planes: Arc<PackedPlanes>,
    pub scales: Vec<f32>,
}

/// A named weight served at a (possibly lower) precision out of the
/// superset pack: a zero-copy most-significant-plane view plus the
/// per-view rescaled dequant scales (`scale · 2^skip`; see
/// [`PlaneView`] and `quant::view_scales`).  The scales are an `Arc`
/// handle into the store's per-(name, bits) cache, so repeated `get_at`
/// calls — the speculative drafter hits this every decode step — share
/// one rescaled vector instead of recomputing it.
pub struct PackedWeightView<'a> {
    pub view: PlaneView<'a>,
    pub scales: Arc<Vec<f32>>,
}

/// Name → prepacked weight registry — what a model (or, packed at the
/// widest precision served, a whole **any-precision cluster**) loads once
/// at startup and every serving step reads from.  One superset entry per
/// weight serves every lower precision through
/// [`PackedWeightStore::get_at`] — no per-precision duplication.
#[derive(Default)]
pub struct PackedWeightStore {
    map: HashMap<String, PackedWeight>,
    /// Memoized `view_scales` rescales per (name → bits): [`get_at`]
    /// takes `&self` (the store is shared behind an `Arc` across
    /// replicas), so the cache sits behind a `Mutex` — the critical
    /// section is a map lookup/clone, never the rescale itself on a hit.
    ///
    /// [`get_at`]: PackedWeightStore::get_at
    scale_cache: Mutex<HashMap<String, HashMap<u32, Arc<Vec<f32>>>>>,
    scale_hits: AtomicU64,
    scale_misses: AtomicU64,
}

impl PackedWeightStore {
    pub fn new() -> Self {
        Self::default()
    }

    /// Pack `codes` once and register it under `name` (replacing any
    /// previous entry).  Returns the shared planes handle.
    pub fn insert_codes(
        &mut self,
        name: &str,
        codes: &CodeMatrix,
        scales: Vec<f32>,
    ) -> Arc<PackedPlanes> {
        let planes = Arc::new(pack_codes(codes));
        self.map.insert(name.to_string(), PackedWeight { planes: planes.clone(), scales });
        self.invalidate_scales(name);
        planes
    }

    /// Register an already-packed weight (e.g. from `Quantized::prepack`).
    pub fn insert_packed(&mut self, name: &str, planes: Arc<PackedPlanes>, scales: Vec<f32>) {
        self.map.insert(name.to_string(), PackedWeight { planes, scales });
        self.invalidate_scales(name);
    }

    /// Replacing a weight must drop its memoized view scales — a stale
    /// rescale of the *old* scales at the *old* width is silent logit
    /// corruption for every later `get_at`.
    fn invalidate_scales(&self, name: &str) {
        self.scale_cache.lock().expect("scale cache poisoned").remove(name);
    }

    pub fn get(&self, name: &str) -> Option<&PackedWeight> {
        self.map.get(name)
    }

    /// Serve `name` at `bits` precision from the single superset pack:
    /// the most-significant `bits` planes as a zero-copy [`PlaneView`],
    /// with the dequant scales rescaled for the dropped low planes.
    /// `None` if the name is unknown; panics if `bits` exceeds the stored
    /// pack (the superset must be packed at the widest precision served).
    ///
    /// The `×2^skip` rescale is memoized per (name, bits): the first call
    /// computes it, every later call — e.g. the speculative drafter
    /// slicing its low-bit prefix each decode step — clones a shared
    /// `Arc` handle.  [`insert_codes`]/[`insert_packed`] invalidate the
    /// entry for a replaced name.
    ///
    /// [`insert_codes`]: PackedWeightStore::insert_codes
    /// [`insert_packed`]: PackedWeightStore::insert_packed
    pub fn get_at(&self, name: &str, bits: u32) -> Option<PackedWeightView<'_>> {
        let w = self.map.get(name)?;
        let mut cache = self.scale_cache.lock().expect("scale cache poisoned");
        if let Some(s) = cache.get(name).and_then(|per_bits| per_bits.get(&bits)) {
            self.scale_hits.fetch_add(1, Ordering::Relaxed);
            return Some(PackedWeightView { view: w.planes.view(bits), scales: s.clone() });
        }
        self.scale_misses.fetch_add(1, Ordering::Relaxed);
        let scales = Arc::new(crate::quant::view_scales(&w.scales, w.planes.bits, bits));
        cache.entry(name.to_string()).or_default().insert(bits, scales.clone());
        Some(PackedWeightView { view: w.planes.view(bits), scales })
    }

    /// `(hits, misses)` of the per-(name, bits) view-scale cache — lets
    /// tests and benches prove the drafter's per-step `get_at` stopped
    /// recomputing the rescale.
    pub fn scale_cache_stats(&self) -> (u64, u64) {
        (self.scale_hits.load(Ordering::Relaxed), self.scale_misses.load(Ordering::Relaxed))
    }

    pub fn len(&self) -> usize {
        self.map.len()
    }

    pub fn is_empty(&self) -> bool {
        self.map.is_empty()
    }

    /// Total packed footprint across all stored weights (§4.1 claim at
    /// model scale).  With one superset store per cluster this is the
    /// entire weight memory, whatever mix of precisions is being served.
    pub fn packed_bytes(&self) -> usize {
        self.map.values().map(|w| w.planes.nbytes()).sum()
    }

    /// Bytes a dedicated per-precision store would need to serve every
    /// weight at `bits` — the baseline the one-superset-store design is
    /// measured against (`bits` is clamped to each weight's own width).
    pub fn packed_bytes_at(&self, bits: u32) -> usize {
        self.map.values().map(|w| w.planes.view(bits.min(w.planes.bits)).nbytes()).sum()
    }
}

/// Shape-keyed scratch buffers for hot-path activation packing.
///
/// `pack` pops a recycled buffer of the exact plane-buffer length (or
/// allocates on first sight of a shape), packs into it, and hands back an
/// owned [`PackedPlanes`]; `recycle` returns the buffer for the next
/// step.  Decode steps run fixed shapes, so steady state is 100% reuse.
#[derive(Default)]
pub struct PackArena {
    free: HashMap<usize, Vec<Vec<u64>>>,
    /// Recycled row-major code staging buffer for [`PackArena::pack_batch`]
    /// (grows to the largest batch seen, then never reallocates).
    stage: Vec<u32>,
    allocs: u64,
    reuses: u64,
}

impl PackArena {
    pub fn new() -> Self {
        Self::default()
    }

    /// Pop a recycled plane buffer of exactly `need` words (or allocate on
    /// first sight of a shape), updating the alloc/reuse counters.
    fn checkout(&mut self, need: usize) -> Vec<u64> {
        match self.free.get_mut(&need).and_then(Vec::pop) {
            Some(b) => {
                self.reuses += 1;
                debug_assert_eq!(b.len(), need);
                b
            }
            None => {
                self.allocs += 1;
                vec![0u64; need]
            }
        }
    }

    /// Pack `m` using a recycled buffer when one of the right size exists.
    pub fn pack(&mut self, m: &CodeMatrix) -> PackedPlanes {
        let need = m.bits as usize * m.rows * m.cols.div_ceil(64);
        let mut buf = self.checkout(need);
        pack_codes_into(m, &mut buf);
        PackedPlanes::from_raw_parts(m.rows, m.cols, m.bits, buf)
    }

    /// **Batched-activation pack entry** (the continuous-batching decode
    /// hot path): stage `rows` activation code rows via `fill(row, out)`
    /// into the arena's recycled staging buffer, then decompose+pack them
    /// in one shot.  After warm-up neither the staging codes nor the plane
    /// buffer allocate, and no intermediate `CodeMatrix` is built.
    pub fn pack_batch(
        &mut self,
        rows: usize,
        cols: usize,
        bits: u32,
        mut fill: impl FnMut(usize, &mut [u32]),
    ) -> PackedPlanes {
        let len = rows * cols;
        if self.stage.len() < len {
            // grow-only: `fill` overwrites the whole prefix below, so no
            // per-call zeroing of the staging buffer
            self.stage.resize(len, 0);
        }
        for r in 0..rows {
            fill(r, &mut self.stage[r * cols..(r + 1) * cols]);
        }
        let need = bits as usize * rows * cols.div_ceil(64);
        let mut buf = self.checkout(need);
        pack_rows_into(rows, cols, bits, &self.stage[..len], &mut buf);
        PackedPlanes::from_raw_parts(rows, cols, bits, buf)
    }

    /// Return a packed buffer to the arena for reuse.
    pub fn recycle(&mut self, p: PackedPlanes) {
        let buf = p.into_raw();
        self.free.entry(buf.len()).or_default().push(buf);
    }

    /// Fresh buffers allocated so far (stays flat once shapes are warm).
    pub fn allocs(&self) -> u64 {
        self.allocs
    }

    /// Packs served from recycled buffers.
    pub fn reuses(&self) -> u64 {
        self.reuses
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::bitmm::{apmm_bipolar, apmm_bipolar_packed, ApmmOpts};

    #[test]
    fn plane_cache_hits_return_identical_buffer() {
        let w = CodeMatrix::random(6, 70, 3, 1);
        let mut cache = PlaneCache::new();
        let a = cache.get_or_pack(42, &w);
        let b = cache.get_or_pack(42, &w);
        assert!(Arc::ptr_eq(&a, &b), "hit must return the same packed buffer");
        assert_eq!((cache.hits(), cache.misses()), (1, 1));
        // and a different key packs independently
        let c = cache.get_or_pack(43, &w);
        assert!(!Arc::ptr_eq(&a, &c));
        assert_eq!(cache.len(), 2);
    }

    #[test]
    fn cached_planes_feed_packed_kernel_without_repacking() {
        let w = CodeMatrix::random(8, 100, 2, 7);
        let xt = CodeMatrix::random(5, 100, 2, 8);
        let mut cache = PlaneCache::new();
        let wp = cache.get_or_pack(0, &w);
        let mut arena = PackArena::new();
        let want = apmm_bipolar(&w, &xt, ApmmOpts::default());
        // several "decode steps": weight planes come from the cache (one
        // miss total), activations from the arena (one alloc total)
        for step in 0..4 {
            let xp = arena.pack(&xt);
            let wp2 = cache.get_or_pack(0, &w);
            assert!(Arc::ptr_eq(&wp, &wp2), "step {step} repacked the weight");
            assert_eq!(apmm_bipolar_packed(&*wp2, &xp, ApmmOpts::default()), want);
            arena.recycle(xp);
        }
        assert_eq!(cache.misses(), 1, "weights packed exactly once");
        assert_eq!(arena.allocs(), 1, "one activation buffer total");
        assert_eq!(arena.reuses(), 3);
    }

    #[test]
    fn arena_reuses_the_same_allocation() {
        let m = CodeMatrix::random(4, 130, 2, 3);
        let mut arena = PackArena::new();
        let p1 = arena.pack(&m);
        let ptr1 = p1.raw().as_ptr();
        let reference = p1.clone();
        arena.recycle(p1);
        let p2 = arena.pack(&m);
        assert_eq!(p2.raw().as_ptr(), ptr1, "recycled buffer must be reused");
        assert_eq!(p2.raw(), reference.raw(), "repack into dirty buffer must be exact");
        assert_eq!((arena.allocs(), arena.reuses()), (1, 1));
        // a different shape takes a fresh buffer
        let other = CodeMatrix::random(4, 131, 2, 3);
        let p3 = arena.pack(&other);
        assert_eq!(arena.allocs(), 2);
        drop(p3);
    }

    #[test]
    fn pack_batch_matches_pack_and_recycles_everything() {
        let m = CodeMatrix::random(5, 130, 2, 9);
        let mut arena = PackArena::new();
        let via_batch = arena.pack_batch(m.rows, m.cols, m.bits, |r, out| {
            out.copy_from_slice(&m.data[r * m.cols..(r + 1) * m.cols]);
        });
        assert_eq!(via_batch.raw(), crate::bitmm::pack_codes(&m).raw());
        let ptr = via_batch.raw().as_ptr();
        arena.recycle(via_batch);
        // same shape again: plane buffer recycled, staging reused in place
        let again = arena.pack_batch(m.rows, m.cols, m.bits, |r, out| {
            out.copy_from_slice(&m.data[r * m.cols..(r + 1) * m.cols]);
        });
        assert_eq!(again.raw().as_ptr(), ptr, "plane buffer must be recycled");
        assert_eq!((arena.allocs(), arena.reuses()), (1, 1));
        // a smaller batch fits the existing staging buffer but takes a
        // fresh plane buffer (different word count)
        let small = arena.pack_batch(2, 130, 2, |r, out| {
            out.copy_from_slice(&m.data[r * m.cols..(r + 1) * m.cols]);
        });
        assert_eq!(small.rows, 2);
        assert_eq!(arena.allocs(), 2);
    }

    #[test]
    fn weight_store_registers_and_reports_footprint() {
        let mut store = PackedWeightStore::new();
        let w = CodeMatrix::random(16, 64, 2, 5);
        let planes = store.insert_codes("attn.q", &w, vec![0.5; 16]);
        assert_eq!(store.len(), 1);
        let got = store.get("attn.q").unwrap();
        assert!(Arc::ptr_eq(&got.planes, &planes));
        assert_eq!(got.scales.len(), 16);
        // 2 bits × 16 rows × 1 word = 32 u64 words
        assert_eq!(store.packed_bytes(), 2 * 16 * 8);
        assert!(store.get("mlp.up").is_none());
    }

    #[test]
    fn plane_cache_key_collision_cannot_return_stale_planes() {
        // regression: the cache used to trust the caller's u64 alone, so
        // reusing an id after requantizing to a different bit-width (or an
        // id collision across differently-shaped layers) silently returned
        // stale planes of the wrong shape/bit-width
        let w4 = CodeMatrix::random(6, 70, 4, 1);
        let w2 = CodeMatrix::new(6, 70, 2, w4.data.iter().map(|&c| c >> 2).collect());
        let other_shape = CodeMatrix::random(5, 64, 4, 2);
        let mut cache = PlaneCache::new();
        let p4 = cache.get_or_pack(7, &w4);
        let p2 = cache.get_or_pack(7, &w2); // same id, requantized width
        let po = cache.get_or_pack(7, &other_shape); // same id, other layer shape
        assert_eq!(cache.misses(), 3, "all three must pack fresh");
        assert_eq!(cache.len(), 3);
        assert_eq!((p4.bits, p4.rows, p4.cols), (4, 6, 70));
        assert_eq!((p2.bits, p2.rows, p2.cols), (2, 6, 70));
        assert_eq!((po.bits, po.rows, po.cols), (4, 5, 64));
        // hits still resolve to the matching entry, never a colliding one
        assert!(Arc::ptr_eq(&cache.get_or_pack(7, &w2), &p2));
        assert!(Arc::ptr_eq(&cache.get(7, 4, 6, 70).unwrap(), &p4));
        assert!(cache.get(7, 3, 6, 70).is_none());
        assert_eq!(cache.hits(), 1);
    }

    #[test]
    fn weight_store_serves_lower_precisions_from_the_superset_pack() {
        use crate::bitmm::{transpose_codes, Planes};

        // a 4-bit superset; the 2-bit view must behave exactly like a
        // fresh 2-bit quantize-and-pack of the truncated codes, scales
        // rescaled by 2^(4−2)
        let w4 = CodeMatrix::random(8, 100, 4, 9);
        let mut store = PackedWeightStore::new();
        store.insert_codes("lm_head", &w4, vec![0.25; 8]);

        let v = store.get_at("lm_head", 2).expect("registered");
        assert_eq!((v.view.bits(), v.view.rows(), v.view.cols()), (2, 8, 100));
        assert_eq!(v.view.skip(), 2);
        assert!(v.scales.iter().all(|&s| s == 1.0), "0.25 · 2^2");

        let trunc = CodeMatrix::new(8, 100, 2, w4.data.iter().map(|&c| c >> 2).collect());
        let x = transpose_codes(&CodeMatrix::random(100, 3, 2, 10));
        let want = apmm_bipolar(&trunc, &x, ApmmOpts::default());
        let xp = pack_codes(&x);
        assert_eq!(apmm_bipolar_packed(&v.view, &xp, ApmmOpts::default()), want);

        // footprints: the superset alone is the whole store; per-precision
        // stores would add a dedicated low-bit copy on top
        assert_eq!(store.packed_bytes(), 4 * 8 * 2 * 8); // 4 planes × 8 rows × 2 words
        assert_eq!(store.packed_bytes_at(2), 2 * 8 * 2 * 8);
        assert_eq!(v.view.nbytes(), store.packed_bytes_at(2));
        // the full-width view is the pack itself
        let full = store.get_at("lm_head", 4).unwrap();
        assert_eq!(full.view.skip(), 0);
        assert_eq!(*full.scales, vec![0.25; 8]);
        assert!(store.get_at("mlp.up", 2).is_none());
    }

    #[test]
    fn get_at_memoizes_view_scales_per_name_and_bits() {
        let w4 = CodeMatrix::random(8, 100, 4, 9);
        let mut store = PackedWeightStore::new();
        store.insert_codes("lm_head", &w4, vec![0.25; 8]);

        // first slice at each width computes the rescale; every repeat —
        // the drafter's per-step pattern — is a shared-Arc hit
        let a = store.get_at("lm_head", 2).unwrap().scales;
        let b = store.get_at("lm_head", 2).unwrap().scales;
        assert!(Arc::ptr_eq(&a, &b), "repeat get_at must share one rescaled vector");
        assert_eq!(store.scale_cache_stats(), (1, 1));
        let full = store.get_at("lm_head", 4).unwrap().scales;
        assert!(!Arc::ptr_eq(&a, &full), "distinct widths cache independently");
        assert_eq!(store.scale_cache_stats(), (1, 2));
        // a missing name is not a cache event at all
        assert!(store.get_at("mlp.up", 2).is_none());
        assert_eq!(store.scale_cache_stats(), (1, 2));

        // replacing the weight invalidates its memoized scales — the next
        // get_at must rescale the NEW scales, not serve the stale vector
        store.insert_codes("lm_head", &w4, vec![0.5; 8]);
        let fresh = store.get_at("lm_head", 2).unwrap().scales;
        assert!(fresh.iter().all(|&s| s == 2.0), "0.5 · 2^2 from the new scales");
        assert_eq!(store.scale_cache_stats(), (1, 3));
    }
}
