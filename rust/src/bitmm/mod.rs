//! Bit-wise MatMul reconstitution (paper §3.2) — the compute substrate.
//!
//! This is the CPU realization of the paper's tensor-core pipeline:
//!
//! 1. **decompose** the n-bit operands into 1-bit planes and pack them
//!    along K into 64-bit words (§4.1's decomposition + reassembly — we use
//!    the widest native word the host has, exactly as the paper picks the
//!    GPU-native 32-bit uint);
//! 2. run all `n_w · n_x` pairwise **1-bit GEMMs** as XNOR-popcount inner
//!    products (the BMMA-XOR substitute);
//! 3. **recover** `Y = Σ_{i,j} 2^{i+j} D_ij` by shift-add, fused into the
//!    accumulator loop so intermediate `D_ij` tiles never materialize
//!    (§4.2's "recover in shared memory, not global memory" — here:
//!    "recover in registers, not in a temporary buffer").
//!
//! ## Prepacked ABI and the pack-once lifecycle (§3.3)
//!
//! [`PackedPlanes`] — shape + bit-width + plane words — is the canonical
//! kernel operand; [`CodeMatrix`] is a construction-time artifact.  The
//! intended lifecycle:
//!
//! * **offline** — quantize weights, decompose+pack them once
//!   ([`pack_codes`], or memoized via [`prepack::PlaneCache`] /
//!   [`prepack::PackedWeightStore`]);
//! * **hot path** — pack each decode step's activations through a
//!   [`prepack::PackArena`] (recycled buffers, no allocation) and call the
//!   `apmm_*_packed` cores, which never call `pack_codes` and never
//!   allocate for weights.
//!
//! Hot-path-safe entry points: [`apmm_bipolar_packed`],
//! [`apmm_bipolar_packed_into`], [`apmm_signed_packed`],
//! [`apmm_unsigned_packed`], [`apmm_weighted_packed`],
//! [`apmm_bipolar_unfused_packed`], [`pack_codes_into`].  The `CodeMatrix`
//! entry points (`apmm_bipolar`, `apmm_signed`, …) are thin pack-then-call
//! wrappers that re-pack both operands per call — convenient for tests and
//! one-shot use, not for serving loops.
//!
//! ## Any-precision views (pack once, serve every precision)
//!
//! All `apmm_*_packed` cores are generic over the [`Planes`] operand
//! trait: a [`PlaneView`] — the zero-copy most-significant-plane prefix of
//! a packed superset ([`PackedPlanes::view`],
//! [`prepack::PackedWeightStore::get_at`]) — drops in wherever full
//! planes do.  An n-bit weight packed once serves every `k ≤ n` as its
//! top-k planes with scales rescaled by `2^(n−k)`
//! (`quant::view_scales`), which is what lets a mixed-precision serving
//! cluster hold **one** weight store instead of one per precision.
//!
//! The unfused variant (materializing every `D_ij`, then a second recovery
//! pass — the paper's *naive* Fig. 4 baseline) is kept for the ablation
//! bench and as an internal cross-check.
//!
//! ## Intra-GEMM sharding
//!
//! The packed cores fan out over a persistent worker pool
//! ([`crate::util::par::WorkerPool`]) along a [`ShardPolicy`]-selected
//! axis: output row blocks, output column blocks, or independent
//! bit-plane pairs recombined by shifted add (§3.2).  All policies and
//! worker counts are bit-identical to the serial kernel.

// `apmm` and `planes` are two of the three audited unsafe islands in the
// crate (with `util::par`): disjoint `SendPtr` writes on the column-shard
// and plane-pair paths, and the parallel plane-packing scatter.  Every
// site carries a SAFETY comment; `cargo run -p xtask -- lint` enforces
// the allowlist against the workspace `unsafe_code = "deny"` lint.
#[allow(unsafe_code)]
mod apmm;
mod gemm1b;
#[allow(unsafe_code)]
mod planes;
pub mod prepack;
mod recover;

pub use apmm::{
    apmm_bipolar, apmm_bipolar_into, apmm_bipolar_packed, apmm_bipolar_packed_into,
    apmm_bipolar_unfused, apmm_bipolar_unfused_packed, apmm_signed, apmm_signed_packed,
    apmm_unsigned, apmm_unsigned_packed, apmm_weighted_packed, apmm_weighted_packed_opts,
    gemm_f32, naive_gemm_decoded, transpose_codes, ApmmOpts, ShardPolicy,
};
pub use gemm1b::{and_popcount_dot, xnor_dot, xor_popcount_dot};
pub use planes::{
    pack_codes, pack_codes_into, pack_codes_u32, pack_rows_into, CodeMatrix, PackedPlanes,
    PlaneView, Planes, MAX_BITS,
};
pub use prepack::{PackArena, PackedWeight, PackedWeightStore, PackedWeightView, PlaneCache};
pub use recover::recover_tiles;

#[cfg(test)]
mod tests;
