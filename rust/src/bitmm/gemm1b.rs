//! 1-bit inner products — the BMMA instruction substitutes.
//!
//! NVIDIA TCs expose 1-bit GEMM with either XOR or AND accumulation
//! (§3.2); on the CPU the same two primitives are word-wise
//! `popcount(a ^ b)` and `popcount(a & b)` reductions.

/// `Σ_w popcount(a[w] XOR b[w])` — the raw BMMA-XOR accumulator.
#[inline(always)]
pub fn xor_popcount_dot(a: &[u64], b: &[u64]) -> u32 {
    debug_assert_eq!(a.len(), b.len());
    let mut acc = 0u32;
    for (&x, &y) in a.iter().zip(b.iter()) {
        acc += (x ^ y).count_ones();
    }
    acc
}

/// `Σ_w popcount(a[w] AND b[w])` — the BMMA-AND accumulator (used by the
/// signed / unsigned decomposition baselines).
#[inline(always)]
pub fn and_popcount_dot(a: &[u64], b: &[u64]) -> u32 {
    debug_assert_eq!(a.len(), b.len());
    let mut acc = 0u32;
    for (&x, &y) in a.iter().zip(b.iter()) {
        acc += (x & y).count_ones();
    }
    acc
}

/// The bipolar ±1 dot product over a logical length `k`:
/// `D = k − 2·popcount(a XOR b)` (zero-padding in both operands cancels).
#[inline(always)]
pub fn xnor_dot(a: &[u64], b: &[u64], k: usize) -> i32 {
    // lint: allow(narrowing-cast) — D ∈ [−k, k] and k < 2^31, exact in i32
    k as i32 - 2 * xor_popcount_dot(a, b) as i32
}
