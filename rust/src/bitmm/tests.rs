use super::*;
use crate::bitfmt::IntFormat;
use crate::util::proptest::forall;

fn check_bipolar(m: usize, k: usize, n: usize, nw: u32, nx: u32, seed: u64) {
    let w = CodeMatrix::random(m, k, nw, seed);
    let xt = CodeMatrix::random(n, k, nx, seed.wrapping_add(1));
    let want = naive_gemm_decoded(&w, &xt, IntFormat::Bipolar);
    let got = apmm_bipolar(&w, &xt, ApmmOpts::default());
    assert_eq!(got, want, "m={m} k={k} n={n} nw={nw} nx={nx}");
}

#[test]
fn fused_matches_naive_small() {
    check_bipolar(4, 32, 4, 1, 1, 0);
    check_bipolar(3, 17, 5, 2, 2, 1); // K not a word multiple
    check_bipolar(8, 64, 8, 3, 4, 2);
    check_bipolar(1, 1, 1, 2, 2, 3); // degenerate
    check_bipolar(5, 200, 7, 4, 3, 4);
}

#[test]
fn fused_matches_naive_parallel_threshold() {
    // large enough that Auto fans row blocks out across the worker pool
    check_bipolar(128, 256, 96, 2, 2, 5);
}

#[test]
fn unfused_matches_fused() {
    let w = CodeMatrix::random(9, 70, 3, 10);
    let xt = CodeMatrix::random(6, 70, 2, 11);
    assert_eq!(
        apmm_bipolar_unfused(&w, &xt),
        apmm_bipolar(&w, &xt, ApmmOpts::default())
    );
}

#[test]
fn signed_matches_naive() {
    let w = CodeMatrix::random(7, 48, 3, 20);
    let xt = CodeMatrix::random(5, 48, 4, 21);
    assert_eq!(apmm_signed(&w, &xt), naive_gemm_decoded(&w, &xt, IntFormat::Signed));
}

#[test]
fn unsigned_matches_naive() {
    let w = CodeMatrix::random(7, 48, 3, 22);
    let xt = CodeMatrix::random(5, 48, 4, 23);
    assert_eq!(apmm_unsigned(&w, &xt), naive_gemm_decoded(&w, &xt, IntFormat::Unsigned));
}

#[test]
fn extreme_codes() {
    for wf in [0u32, 7] {
        for xf in [0u32, 3] {
            let w = CodeMatrix::splat(4, 64, 3, wf);
            let xt = CodeMatrix::splat(4, 64, 2, xf);
            assert_eq!(
                apmm_bipolar(&w, &xt, ApmmOpts::default()),
                naive_gemm_decoded(&w, &xt, IntFormat::Bipolar)
            );
        }
    }
}

#[test]
fn packing_layout() {
    // bit b of word w == column w*64 + b of the plane
    let mut data = vec![0u32; 2 * 70];
    data[0 * 70 + 0] = 0b11; // row 0, col 0
    data[0 * 70 + 69] = 0b01; // row 0, col 69
    data[1 * 70 + 64] = 0b10; // row 1, col 64
    let m = CodeMatrix::new(2, 70, 2, data);
    let p = pack_codes(&m);
    assert_eq!(p.kw, 2);
    assert_eq!(p.row(0, 0)[0] & 1, 1); // plane0 row0 col0
    assert_eq!(p.row(1, 0)[0] & 1, 1); // plane1 row0 col0
    assert_eq!((p.row(0, 0)[1] >> 5) & 1, 1); // col 69 → word 1 bit 5
    assert_eq!((p.row(1, 0)[1] >> 5) & 1, 0);
    assert_eq!(p.row(1, 1)[1] & 1, 1); // row1 col64 plane1
    assert_eq!(p.row(0, 1)[1] & 1, 0);
    // padding bits beyond col 69 are zero
    assert_eq!(p.row(0, 0)[1] >> 6, 0);
}

#[test]
fn xnor_dot_identity() {
    // D = K − 2·popc(a^b) equals the ±1 dot product
    let a = CodeMatrix::random(1, 100, 1, 30);
    let b = CodeMatrix::random(1, 100, 1, 31);
    let pa = pack_codes(&a);
    let pb = pack_codes(&b);
    let d = xnor_dot(pa.row(0, 0), pb.row(0, 0), 100);
    let want: i32 = (0..100)
        .map(|c| (2 * a.at(0, c) as i32 - 1) * (2 * b.at(0, c) as i32 - 1))
        .sum();
    assert_eq!(d, want);
}

#[test]
fn recover_shift_weights() {
    let tiles = vec![(0u32, 0u32, vec![1i32]), (1, 0, vec![1]), (1, 1, vec![1]), (0, 2, vec![-3])];
    // 1 + 2 + 4 − 12 = −5
    assert_eq!(recover_tiles(1, 1, &tiles), vec![-5]);
}

#[test]
fn transpose_roundtrip() {
    let m = CodeMatrix::random(5, 9, 3, 40);
    let t = transpose_codes(&m);
    assert_eq!(t.rows, 9);
    assert_eq!(t.at(2, 3), m.at(3, 2));
    assert_eq!(transpose_codes(&t), m);
}

#[test]
fn gemm_f32_correct() {
    let a = vec![1.0f32, 2.0, 3.0, 4.0]; // 2x2
    let bt = vec![1.0f32, 0.0, 0.0, 1.0]; // identity^T
    assert_eq!(gemm_f32(&a, &bt, 2, 2, 2), a);
}

#[test]
fn into_buffer_reuse() {
    let w = CodeMatrix::random(6, 33, 2, 50);
    let xt = CodeMatrix::random(4, 33, 2, 51);
    let mut buf = vec![-1i32; 24];
    apmm_bipolar_into(&w, &xt, ApmmOpts::default(), &mut buf);
    assert_eq!(buf, naive_gemm_decoded(&w, &xt, IntFormat::Bipolar));
}

#[test]
fn large_k_no_overflow() {
    // worst case |Y| = K · qmax_w · qmax_x must still fit in i32
    let k = 8192;
    let w = CodeMatrix::splat(1, k, 4, 15); // all +15
    let xt = CodeMatrix::splat(1, k, 4, 15);
    let y = apmm_bipolar(&w, &xt, ApmmOpts::default());
    assert_eq!(y[0], (k as i32) * 15 * 15);
}

#[test]
fn empty_shapes_are_nops() {
    // regression: n == 0 used to hand par_chunks_mut a zero-length chunk
    // (panic) when m was large enough to take the parallel path
    let w = CodeMatrix::random(128, 64, 2, 60);
    let xt = CodeMatrix::random(0, 64, 2, 61);
    assert!(apmm_bipolar(&w, &xt, ApmmOpts::default()).is_empty());
    assert!(apmm_signed(&w, &xt).is_empty());
    assert!(apmm_unsigned(&w, &xt).is_empty());
    // m == 0 side
    let w0 = CodeMatrix::random(0, 64, 2, 62);
    let x5 = CodeMatrix::random(5, 64, 2, 63);
    assert!(apmm_bipolar(&w0, &x5, ApmmOpts::default()).is_empty());
    // into-buffer variant with an (correctly) empty output
    let mut buf: Vec<i32> = vec![];
    apmm_bipolar_into(&w, &xt, ApmmOpts::default(), &mut buf);
    assert!(buf.is_empty());
}

#[test]
fn ragged_last_row_block() {
    // regression: m % tile_m != 0 exercises the short final chunk's
    // rows_out.len()/n row-count math on the parallel path
    let (m, k, n) = (70usize, 96usize, 5usize);
    let w = CodeMatrix::random(m, k, 2, 64);
    let xt = CodeMatrix::random(n, k, 3, 65);
    let opts = ApmmOpts { shard: ShardPolicy::Rows, tile_m: 32, tile_n: 4, workers: 2 };
    assert_eq!(
        apmm_bipolar(&w, &xt, opts),
        naive_gemm_decoded(&w, &xt, IntFormat::Bipolar)
    );
}

#[test]
fn max_bits_construct_and_pack() {
    // bits = 16 is the widest supported width: construction, range checks
    // and packing must use widened shifts (1 << 16 overflows u16-minded
    // code paths).  The GEMM itself is i32-bounded, so only layout is
    // exercised here.
    let w = CodeMatrix::splat(2, 70, MAX_BITS, (1 << MAX_BITS) - 1);
    let p = pack_codes(&w);
    assert_eq!(p.bits, MAX_BITS);
    assert_eq!(p.kw, 2);
    // every plane of the all-ones code is all-ones over the 70 columns
    for plane in 0..MAX_BITS {
        assert_eq!(p.row(plane, 1)[0], u64::MAX);
        assert_eq!(p.row(plane, 1)[1], (1u64 << 6) - 1, "plane {plane} padding");
    }
    let r = CodeMatrix::random(3, 40, MAX_BITS, 9);
    assert!(r.data.iter().all(|&c| (c as u64) < (1u64 << MAX_BITS)));
}

#[test]
fn out_of_range_bits_rejected() {
    for bits in [0u32, 17, 32] {
        let r = std::panic::catch_unwind(|| CodeMatrix::splat(1, 1, bits, 0));
        assert!(r.is_err(), "bits={bits} must be rejected");
        let r = std::panic::catch_unwind(|| CodeMatrix::random(1, 1, bits, 0));
        assert!(r.is_err(), "random bits={bits} must be rejected");
    }
}

#[test]
fn prop_packed_cores_match_wrappers_and_naive() {
    // the refactor's contract: packed core ≡ CodeMatrix wrapper ≡ decoded
    // naive GEMM, across random shapes and bit-widths
    forall(48, |rng| {
        let (m, k, n) = (rng.usize(1, 12), rng.usize(1, 150), rng.usize(1, 12));
        let (nw, nx) = (rng.u32(1, 6), rng.u32(1, 6));
        let seed = rng.u64();
        let w = CodeMatrix::random(m, k, nw, seed);
        let xt = CodeMatrix::random(n, k, nx, seed ^ 0xbeef);
        let wp = pack_codes(&w);
        let xp = pack_codes(&xt);
        let naive = naive_gemm_decoded(&w, &xt, IntFormat::Bipolar);
        assert_eq!(
            apmm_bipolar_packed(&wp, &xp, ApmmOpts::default()),
            naive,
            "packed core: m={m} k={k} n={n} nw={nw} nx={nx}"
        );
        assert_eq!(
            apmm_bipolar(&w, &xt, ApmmOpts::default()),
            naive,
            "wrapper: m={m} k={k} n={n} nw={nw} nx={nx}"
        );
        assert_eq!(
            apmm_bipolar_unfused_packed(&wp, &xp),
            naive,
            "unfused packed: m={m} k={k} n={n}"
        );
        assert_eq!(
            apmm_signed_packed(&wp, &xp),
            naive_gemm_decoded(&w, &xt, IntFormat::Signed),
            "signed packed"
        );
        assert_eq!(
            apmm_unsigned_packed(&wp, &xp),
            naive_gemm_decoded(&w, &xt, IntFormat::Unsigned),
            "unsigned packed"
        );
    });
}

#[test]
fn packed_into_reuses_buffer_across_steps() {
    // the serving pattern: prepacked weights + arena-packed activations +
    // one output buffer, stepped repeatedly
    let w = CodeMatrix::random(6, 77, 3, 70);
    let wp = pack_codes(&w);
    let mut arena = prepack::PackArena::new();
    let mut y = vec![0i32; 6 * 4];
    for step in 0..3u64 {
        let xt = CodeMatrix::random(4, 77, 2, 80 + step);
        let want = naive_gemm_decoded(&w, &xt, IntFormat::Bipolar);
        let xp = arena.pack(&xt);
        apmm_bipolar_packed_into(&wp, &xp, ApmmOpts::default(), &mut y);
        assert_eq!(y, want, "step {step}");
        arena.recycle(xp);
    }
    assert_eq!(arena.allocs(), 1);
}

#[test]
fn prop_fused_matches_naive() {
    forall(48, |rng| {
        let (m, k, n) = (rng.usize(1, 12), rng.usize(1, 150), rng.usize(1, 12));
        let (nw, nx) = (rng.u32(1, 6), rng.u32(1, 6));
        let seed = rng.u64();
        let w = CodeMatrix::random(m, k, nw, seed);
        let xt = CodeMatrix::random(n, k, nx, seed ^ 0xdead);
        assert_eq!(
            apmm_bipolar(&w, &xt, ApmmOpts::default()),
            naive_gemm_decoded(&w, &xt, IntFormat::Bipolar),
            "m={m} k={k} n={n} nw={nw} nx={nx}"
        );
    });
}

#[test]
fn prop_tile_invariance() {
    forall(32, |rng| {
        let (m, n) = (rng.usize(1, 40), rng.usize(1, 40));
        let (tm, tn) = (rng.usize(1, 9), rng.usize(1, 9));
        let seed = rng.u64();
        let w = CodeMatrix::random(m, 64, 2, seed);
        let xt = CodeMatrix::random(n, 64, 2, seed ^ 1);
        let base =
            apmm_bipolar(&w, &xt, ApmmOpts { shard: ShardPolicy::Serial, ..Default::default() });
        let tiled =
            apmm_bipolar(&w, &xt, ApmmOpts { tile_m: tm, tile_n: tn, ..Default::default() });
        assert_eq!(base, tiled, "tm={tm} tn={tn}");
    });
}

#[test]
fn prop_signed_unsigned_match_naive() {
    forall(32, |rng| {
        let (m, k, n) = (rng.usize(1, 8), rng.usize(1, 100), rng.usize(1, 8));
        let (nw, nx) = (rng.u32(2, 6), rng.u32(2, 6));
        let seed = rng.u64();
        let w = CodeMatrix::random(m, k, nw, seed);
        let xt = CodeMatrix::random(n, k, nx, seed ^ 2);
        assert_eq!(apmm_signed(&w, &xt), naive_gemm_decoded(&w, &xt, IntFormat::Signed));
        assert_eq!(apmm_unsigned(&w, &xt), naive_gemm_decoded(&w, &xt, IntFormat::Unsigned));
    });
}

#[test]
fn prop_shard_policies_and_worker_counts_bit_identical_to_serial() {
    // the tentpole contract (§3.2): row-block, column-block and
    // bit-plane-pair sharding are pure scheduling choices — every policy ×
    // worker count must be **bit-identical** to the serial kernel, across
    // random shapes (forced m == 1 decode shapes included), ragged tiles,
    // the weighted AND-plane kernel, and any-precision PlaneView operands
    forall(16, |rng| {
        let m = if rng.u32(0, 4) == 0 { 1 } else { rng.usize(1, 70) };
        let (k, n) = (rng.usize(1, 150), rng.usize(1, 24));
        let (nw, nx) = (rng.u32(1, 6), rng.u32(1, 6));
        let (tm, tn) = (rng.usize(1, 9), rng.usize(1, 9));
        let seed = rng.u64();
        let w = CodeMatrix::random(m, k, nw, seed);
        let xt = CodeMatrix::random(n, k, nx, seed ^ 0xc0de);
        let wp = pack_codes(&w);
        let xp = pack_codes(&xt);
        let (kw_bits, kx_bits) = (rng.u32(1, nw + 1), rng.u32(1, nx + 1));
        let serial = ApmmOpts { shard: ShardPolicy::Serial, tile_m: tm, tile_n: tn, workers: 1 };
        let want = apmm_bipolar_packed(&wp, &xp, serial);
        let want_weighted = apmm_weighted_packed_opts(&wp, &xp, IntFormat::Signed, serial);
        let want_view = apmm_bipolar_packed(&wp.view(kw_bits), &xp.view(kx_bits), serial);
        for shard in ShardPolicy::ALL {
            for workers in [1usize, 2, 4] {
                let opts = ApmmOpts { shard, tile_m: tm, tile_n: tn, workers };
                let ctx = format!(
                    "{shard:?}@{workers}w m={m} k={k} n={n} nw={nw} nx={nx} tm={tm} tn={tn}"
                );
                assert_eq!(apmm_bipolar_packed(&wp, &xp, opts), want, "bipolar {ctx}");
                assert_eq!(
                    apmm_weighted_packed_opts(&wp, &xp, IntFormat::Signed, opts),
                    want_weighted,
                    "weighted {ctx}"
                );
                assert_eq!(
                    apmm_bipolar_packed(&wp.view(kw_bits), &xp.view(kx_bits), opts),
                    want_view,
                    "views kw={kw_bits} kx={kx_bits} {ctx}"
                );
            }
        }
    });
}

#[test]
fn fused_bipolar_llm_scale_k_8x8_matches_naive() {
    // the ISSUE shape: K=4096 at 8×8 bits — the fused kernel's
    // Σ popc·2^(i+j) partial sum runs right up against i32 here and the
    // headline logits must stay exact
    let k = 4096;
    let w = CodeMatrix::random(3, k, 8, 90);
    let xt = CodeMatrix::random(2, k, 8, 91);
    assert_eq!(
        apmm_bipolar(&w, &xt, ApmmOpts::default()),
        naive_gemm_decoded(&w, &xt, IntFormat::Bipolar)
    );
}

#[test]
fn fused_bipolar_huge_k_intermediate_exceeds_i32() {
    // K=100k at 8×8: the Σ popc·2^(i+j) intermediate is ≈ K·(2^8−1)²/2
    // ≈ 3.2e9 > i32::MAX, so the pre-widening i32 accumulator wrapped
    // here even though the true outputs (random ± codes concentrate near
    // zero) still fit the i32 output buffer comfortably
    let k = 100_000;
    let w = CodeMatrix::random(2, k, 8, 92);
    let xt = CodeMatrix::random(2, k, 8, 93);
    assert_eq!(
        apmm_bipolar(&w, &xt, ApmmOpts::default()),
        naive_gemm_decoded(&w, &xt, IntFormat::Bipolar)
    );
}

#[test]
#[should_panic(expected = "inner dimension mismatch")]
fn weighted_packed_rejects_mismatched_plane_widths() {
    // mismatched operands must die on the width asserts, not index out
    // of bounds or silently truncate the zipped inner product.  (Every
    // public constructor derives kw from cols, so the cols assert is the
    // one reachable here; the kw assert added alongside it is
    // defense-in-depth parity with `apmm_bipolar_packed_into` for any
    // future constructor that decouples them.)
    let wp = pack_codes(&CodeMatrix::random(4, 64, 2, 94));
    let xp = pack_codes(&CodeMatrix::random(4, 130, 2, 95));
    apmm_weighted_packed(&wp, &xp, IntFormat::Signed);
}

#[test]
#[should_panic(expected = "inner dimension mismatch")]
fn unfused_packed_rejects_mismatched_plane_widths() {
    let wp = pack_codes(&CodeMatrix::random(4, 64, 2, 96));
    let xp = pack_codes(&CodeMatrix::random(4, 70, 2, 97));
    apmm_bipolar_unfused_packed(&wp, &xp);
}

#[test]
fn plane_view_slices_msb_prefix_without_copy() {
    // deterministic layout check: view plane j must alias full plane
    // (skip + j) word-for-word, and the full-width view is the pack itself
    let w = CodeMatrix::random(5, 130, 4, 40);
    let wp = pack_codes(&w);
    for bits in 1..=4u32 {
        let v = wp.view(bits);
        assert_eq!((v.bits(), v.rows(), v.cols(), v.kw()), (bits, 5, 130, wp.kw));
        assert_eq!(v.skip(), 4 - bits);
        for j in 0..bits {
            for r in 0..5 {
                assert!(
                    std::ptr::eq(v.row(j, r).as_ptr(), wp.row(4 - bits + j, r).as_ptr()),
                    "view must borrow, not copy (bits={bits} plane={j} row={r})"
                );
            }
        }
        assert_eq!(v.nbytes(), bits as usize * 5 * wp.kw * 8);
    }
}

#[test]
#[should_panic(expected = "cannot view")]
fn plane_view_rejects_widths_beyond_the_pack() {
    pack_codes(&CodeMatrix::random(2, 64, 3, 41)).view(4);
}

#[test]
fn prop_plane_view_matches_fresh_low_bit_pack() {
    // the tentpole's view-consistency oracle: for every k ≤ bits, every
    // packed kernel over the superset's PlaneView(k) must equal the same
    // kernel over a FRESH quantize-and-pack at k bits (i.e. the codes
    // truncated to their top k bits) — on the weight side, the activation
    // side, and both at once
    forall(32, |rng| {
        let (m, k, n) = (rng.usize(1, 8), rng.usize(1, 140), rng.usize(1, 8));
        let (nw, nx) = (rng.u32(2, 9), rng.u32(1, 7));
        let seed = rng.u64();
        let w = CodeMatrix::random(m, k, nw, seed);
        let xt = CodeMatrix::random(n, k, nx, seed ^ 0xfeed);
        let wp = pack_codes(&w);
        let xp = pack_codes(&xt);
        let fresh = |c: &CodeMatrix, bits: u32| {
            CodeMatrix::new(
                c.rows,
                c.cols,
                bits,
                c.data.iter().map(|&v| v >> (c.bits - bits)).collect(),
            )
        };
        for kw_bits in 1..=nw {
            let wv = wp.view(kw_bits);
            let wt = pack_codes(&fresh(&w, kw_bits));
            assert_eq!(
                apmm_bipolar_packed(&wv, &xp, ApmmOpts::default()),
                apmm_bipolar_packed(&wt, &xp, ApmmOpts::default()),
                "weight view k={kw_bits} of nw={nw} (m={m} k={k} n={n} nx={nx})"
            );
            assert_eq!(
                apmm_weighted_packed(&wv, &xp, IntFormat::Unsigned),
                apmm_weighted_packed(&wt, &xp, IntFormat::Unsigned),
                "unsigned weight view k={kw_bits} of nw={nw}"
            );
            assert_eq!(
                apmm_bipolar_unfused_packed(&wv, &xp),
                apmm_bipolar_unfused_packed(&wt, &xp),
                "unfused weight view k={kw_bits} of nw={nw}"
            );
        }
        // activation-side and both-sided views reuse the same identity
        let kx_bits = rng.u32(1, nx + 1);
        let xv = xp.view(kx_bits);
        let xtp = pack_codes(&fresh(&xt, kx_bits));
        assert_eq!(
            apmm_bipolar_packed(&wp, &xv, ApmmOpts::default()),
            apmm_bipolar_packed(&wp, &xtp, ApmmOpts::default()),
            "activation view k={kx_bits} of nx={nx}"
        );
        let kw_bits = rng.u32(1, nw + 1);
        assert_eq!(
            apmm_bipolar_packed(&wp.view(kw_bits), &xv, ApmmOpts::default()),
            apmm_bipolar_packed(&pack_codes(&fresh(&w, kw_bits)), &xtp, ApmmOpts::default()),
            "both-sided views kw={kw_bits} kx={kx_bits}"
        );
    });
}

/// Builds an `rows.len() × k` matrix whose rows are splatted with the given
/// codes — the adversarial shape: every inner product hits the same
/// max-magnitude operand `k` times in a row.
fn splat_rows(codes: &[u32], k: usize, bits: u32) -> CodeMatrix {
    let mut data = Vec::with_capacity(codes.len() * k);
    for &c in codes {
        data.extend(vec![c; k]);
    }
    CodeMatrix::new(codes.len(), k, bits, data)
}

#[test]
fn adversarial_max_magnitude_all_shards_bipolar() {
    // PR 2's overflow regression, extended to every sharded path.  7-bit
    // bipolar max-magnitude codes (127 → +127, 0 → −127) at K = 100k push
    // the fused Σ 2^(i+j+1)·popc intermediate to ≈ ±3.2e9 — past i32 — while
    // the true product peaks at ±127²·K ≈ ±1.61e9, representable but right
    // at the i32 edge.  A single shard accumulating in i32 anywhere (row,
    // column, or plane-pair recombination) wraps and diverges from the
    // pure-i64 reference.
    let (k, bits) = (100_000usize, 7u32);
    let hi = (1u32 << bits) - 1;
    let w = splat_rows(&[hi, 0, hi], k, bits);
    let xt = splat_rows(&[0, hi, hi], k, bits);
    let want = naive_gemm_decoded(&w, &xt, IntFormat::Bipolar);
    // prove the fixture really reaches the adversarial magnitude
    assert!(want.iter().any(|&v| v.unsigned_abs() > 1_500_000_000));
    let wp = pack_codes(&w);
    let xp = pack_codes(&xt);
    for shard in ShardPolicy::ALL {
        for workers in [2usize, 4] {
            let opts = ApmmOpts { shard, tile_m: 2, tile_n: 2, workers };
            assert_eq!(
                apmm_bipolar_packed(&wp, &xp, opts),
                want,
                "bipolar shard={shard:?} workers={workers}"
            );
        }
    }
}

#[test]
fn adversarial_max_magnitude_all_shards_weighted() {
    // The weighted (AND-plane) core under the same regime: 7-bit signed
    // max-magnitude codes (64 → −64, 63 → +63) at K = 100k, so pair terms
    // w_i·w_j·popc reach 64·64·100k ≈ 4.1e8 with mixed signs, and unsigned
    // all-ones codes whose true product 127²·100k ≈ 1.61e9 sits at the i32
    // edge.  Every ShardPolicy × worker count must match the i64 reference
    // bit for bit.
    let (k, bits) = (100_000usize, 7u32);
    let ws = splat_rows(&[64, 63, 64], k, bits);
    let xs = splat_rows(&[63, 64, 63], k, bits);
    let want_signed = naive_gemm_decoded(&ws, &xs, IntFormat::Signed);
    let wsp = pack_codes(&ws);
    let xsp = pack_codes(&xs);

    let hi = (1u32 << bits) - 1;
    let wu = splat_rows(&[hi, 0, hi], k, bits);
    let xu = splat_rows(&[0, hi, hi], k, bits);
    let want_unsigned = naive_gemm_decoded(&wu, &xu, IntFormat::Unsigned);
    assert!(want_unsigned.iter().any(|&v| v.unsigned_abs() > 1_500_000_000));
    let wup = pack_codes(&wu);
    let xup = pack_codes(&xu);

    // default-opts entry point first (the PR 2 surface), then all shards
    assert_eq!(apmm_weighted_packed(&wsp, &xsp, IntFormat::Signed), want_signed);
    assert_eq!(apmm_weighted_packed(&wup, &xup, IntFormat::Unsigned), want_unsigned);
    for shard in ShardPolicy::ALL {
        for workers in [2usize, 4] {
            let opts = ApmmOpts { shard, tile_m: 2, tile_n: 2, workers };
            assert_eq!(
                apmm_weighted_packed_opts(&wsp, &xsp, IntFormat::Signed, opts),
                want_signed,
                "signed shard={shard:?} workers={workers}"
            );
            assert_eq!(
                apmm_weighted_packed_opts(&wup, &xup, IntFormat::Unsigned, opts),
                want_unsigned,
                "unsigned shard={shard:?} workers={workers}"
            );
        }
    }
}
