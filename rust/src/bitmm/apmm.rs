//! Arbitrary-precision MatMul kernels (fused recovery) + baselines.
//!
//! Operand convention: weights `W` are `(M, K)` codes; activations arrive
//! **transposed** as `Xᵀ` `(N, K)` so both sides stream along packed-K —
//! the same N-major layout the Pallas kernel uses.
//!
//! ## Prepacked ABI (§3.3)
//!
//! Every kernel has two entry points:
//!
//! * `apmm_*_packed` — the **hot-path core**: consumes any [`Planes`]
//!   operand ([`super::planes::PackedPlanes`], or a
//!   [`super::planes::PlaneView`] slicing a lower precision out of a
//!   packed superset), performs zero
//!   `pack_codes` calls and zero weight allocations.  Weights should be
//!   packed once (see [`super::prepack`]) and reused across calls;
//!   activations pack through a `PackArena`.
//! * `apmm_*` on [`CodeMatrix`] — thin pack-then-call convenience wrapper
//!   (construction-time / test use; it re-packs both operands per call
//!   and is therefore **not** hot-path-safe).
//!
//! ## Sharding (paper §3.2 on a worker pool)
//!
//! The cores fan out over a persistent [`WorkerPool`] along one of three
//! axes, selected by [`ApmmOpts::shard`]:
//!
//! * [`ShardPolicy::Rows`] — output row blocks of `tile_m` rows, the
//!   classic axis (best when `m` is large: the serving logits GEMM has
//!   `m = vocab`);
//! * [`ShardPolicy::Cols`] — output column blocks of `tile_n` columns,
//!   for wide-N shapes where `m` alone can't feed every worker;
//! * [`ShardPolicy::Planes`] — bit-plane pairs: each `(i, j)` plane
//!   product is an **independent partial sum** recombined by a
//!   `<< (i+j)`-weighted add (§3.2's decomposition), so shards accumulate
//!   disjoint pair subsets into per-shard `i64` buffers and a serial pass
//!   recombines them.  This parallelizes even the `m == 1`, small-`n`
//!   decode shape, where neither output axis has enough grains.
//!
//! All arithmetic is exact in `i64`, so every policy × worker count is
//! **bit-identical** to the serial kernel (property-tested in
//! `super::tests`).  [`ShardPolicy::Auto`] picks an axis from
//! `(m, n, nw·nx)` and the pool size.

use std::sync::Arc;

use super::gemm1b::{and_popcount_dot, xor_popcount_dot};
use super::planes::{pack_codes, CodeMatrix, Planes, MAX_BITS};
use crate::bitfmt::{plane_weight, IntFormat};
use crate::util::par::{chunks_on, par_chunks_mut, pool_of, SendPtr, WorkerPool};

/// Which axis of the output (or of the bit-plane decomposition) to shard
/// across pool workers.  Every policy is bit-identical to [`Serial`][Self::Serial].
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum ShardPolicy {
    /// Single-threaded reference path.
    Serial,
    /// Output row blocks of `tile_m` rows (today's axis; large-`m` shapes).
    Rows,
    /// Output column blocks of `tile_n` columns (small-`m`, wider-`n`).
    Cols,
    /// Bit-plane `(i, j)` pairs, recombined by shifted add (§3.2) — the
    /// only axis with grains left at the `m == 1` decode shape.
    Planes,
    /// Heuristic choice from `(m, n, nw·nx)` and the pool size.
    Auto,
}

impl ShardPolicy {
    /// Every policy, for exhaustive equivalence tests.
    pub const ALL: [ShardPolicy; 5] = [
        ShardPolicy::Serial,
        ShardPolicy::Rows,
        ShardPolicy::Cols,
        ShardPolicy::Planes,
        ShardPolicy::Auto,
    ];

    /// Resolve `Auto` (and degenerate worker counts) to a concrete axis.
    /// Preference order at saturation: rows (zero recombine cost, best
    /// locality), then columns (also recombine-free but finer-grained),
    /// then plane pairs (pays an `m·n·shards` recombine buffer, but is
    /// the only axis that scales the decode shape).
    fn resolve(
        self,
        m: usize,
        n: usize,
        pairs: usize,
        tile_m: usize,
        tile_n: usize,
        workers: usize,
    ) -> ShardPolicy {
        if workers <= 1 {
            return ShardPolicy::Serial;
        }
        match self {
            ShardPolicy::Auto => {
                let row_blocks = m.div_ceil(tile_m);
                let col_blocks = n.div_ceil(tile_n);
                if row_blocks >= workers {
                    ShardPolicy::Rows
                } else if col_blocks >= workers {
                    ShardPolicy::Cols
                } else if pairs >= workers {
                    ShardPolicy::Planes
                } else if row_blocks >= col_blocks && row_blocks >= pairs && row_blocks > 1 {
                    ShardPolicy::Rows
                } else if col_blocks >= pairs && col_blocks > 1 {
                    ShardPolicy::Cols
                } else if pairs > 1 {
                    ShardPolicy::Planes
                } else {
                    ShardPolicy::Serial
                }
            }
            p => p,
        }
    }
}

/// Kernel options (the §4.2 knobs that exist on a CPU).
#[derive(Debug, Clone, Copy)]
pub struct ApmmOpts {
    /// Sharding axis across pool workers (see [`ShardPolicy`]).
    pub shard: ShardPolicy,
    /// Output row/col tile (cache blocking — the shared-memory analog).
    pub tile_m: usize,
    pub tile_n: usize,
    /// Worker-pool size for this GEMM; `0` means the global
    /// [`crate::util::num_threads`] default.  Pools are shared per size
    /// process-wide, so replicas with equal budgets reuse one pool.
    pub workers: usize,
}

impl Default for ApmmOpts {
    fn default() -> Self {
        Self { shard: ShardPolicy::Auto, tile_m: 32, tile_n: 32, workers: 0 }
    }
}

impl ApmmOpts {
    /// The (cached, persistent) pool this GEMM dispatches on.
    fn pool(&self) -> Arc<WorkerPool> {
        pool_of(self.workers)
    }
}

/// Transpose a code matrix (used to put activations in N-major layout).
pub fn transpose_codes(m: &CodeMatrix) -> CodeMatrix {
    let mut data = vec![0u32; m.rows * m.cols];
    for r in 0..m.rows {
        for c in 0..m.cols {
            data[c * m.rows + r] = m.at(r, c);
        }
    }
    CodeMatrix::new(m.cols, m.rows, m.bits, data)
}

/// Fused bipolar AP-GEMM: `Y = W · X` with `W (M,K)`, `Xᵀ (N,K)` codes.
///
/// `Y[m,n] = C − 2 · Σ_{i,j} popc(W_i[m] ^ X_j[n]) << (i+j)`,
/// `C = K (2^{n_w}−1)(2^{n_x}−1)` — recovery runs entirely in registers.
///
/// Convenience wrapper: packs both operands, then delegates to
/// [`apmm_bipolar_packed_into`].
pub fn apmm_bipolar(w: &CodeMatrix, xt: &CodeMatrix, opts: ApmmOpts) -> Vec<i32> {
    let mut y = vec![0i32; w.rows * xt.rows];
    apmm_bipolar_into(w, xt, opts, &mut y);
    y
}

/// As [`apmm_bipolar`] but writing into a caller-provided buffer (the
/// serving hot path reuses output allocations).
pub fn apmm_bipolar_into(w: &CodeMatrix, xt: &CodeMatrix, opts: ApmmOpts, y: &mut [i32]) {
    assert_eq!(w.cols, xt.cols, "inner dimension mismatch");
    let wp = pack_codes(w);
    let xp = pack_codes(xt);
    apmm_bipolar_packed_into(&wp, &xp, opts, y);
}

/// Prepacked fused bipolar AP-GEMM core (allocates only the output).
pub fn apmm_bipolar_packed<W: Planes, X: Planes>(wp: &W, xp: &X, opts: ApmmOpts) -> Vec<i32> {
    let mut y = vec![0i32; wp.rows() * xp.rows()];
    apmm_bipolar_packed_into(wp, xp, opts, &mut y);
    y
}

/// The hot-path core: prepacked operands in (full packs or any-precision
/// [`super::planes::PlaneView`]s), caller-provided output buffer, **zero**
/// packing and zero heap allocation on the row/col shard paths (the
/// plane-pair path allocates its per-shard recombine buffer).
pub fn apmm_bipolar_packed_into<W: Planes, X: Planes>(
    wp: &W,
    xp: &X,
    opts: ApmmOpts,
    y: &mut [i32],
) {
    assert_eq!(wp.cols(), xp.cols(), "inner dimension mismatch");
    assert_eq!(wp.kw(), xp.kw(), "packed word-count mismatch");
    let k = wp.cols();
    let (nw, nx) = (wp.bits(), xp.bits());
    // bits ≤ MAX_BITS is a PackedPlanes construction invariant, so these
    // widened shifts cannot overflow.  C stays in i64: at 16×16 bits and
    // LLM-scale K it exceeds i32::MAX long before the final result does.
    let c_const = k as i64 * ((1i64 << nw) - 1) * ((1i64 << nx) - 1);
    apmm_pairs_sharded(
        wp,
        xp,
        opts,
        // the bipolar recovery weight: −2 · 2^{i+j} (i+j ≤ 30, exact)
        |i, j| -(1i64 << (i + j + 1)),
        xor_popcount_dot,
        |acc| checked_i32(c_const + acc),
        y,
    );
}

/// The shared sharded core of every prepacked plane-pair GEMM:
/// `Y[m,n] = finish(Σ_{i,j} pair_weight(i,j) · dot(W_i[m], X_j[n]))`.
///
/// All accumulation is exact `i64`, so any grouping of the `(i, j)` pair
/// sum — by row block, column block, or plane-pair shard — produces
/// bit-identical output; the shard axis is purely a scheduling choice.
fn apmm_pairs_sharded<W, X, PW, D, FIN>(
    wp: &W,
    xp: &X,
    opts: ApmmOpts,
    pair_weight: PW,
    dot: D,
    finish: FIN,
    y: &mut [i32],
) where
    W: Planes,
    X: Planes,
    PW: Fn(u32, u32) -> i64 + Sync,
    D: Fn(&[u64], &[u64]) -> u32 + Sync,
    FIN: Fn(i64) -> i32 + Sync,
{
    assert_eq!(y.len(), wp.rows() * xp.rows(), "output buffer size");
    assert!(opts.tile_m > 0 && opts.tile_n > 0, "tiles must be non-empty");
    let (m, n) = (wp.rows(), xp.rows());
    if m == 0 || n == 0 {
        return; // empty output; avoids zero-size chunks below
    }
    let (nw, nx) = (wp.bits() as usize, xp.bits() as usize);
    let pairs = nw * nx;
    let pool = opts.pool();
    let axis = opts.shard.resolve(m, n, pairs, opts.tile_m, opts.tile_n, pool.size());

    // Row-block body; Serial runs it over the whole output in one call.
    let body = |mb: usize, rows_out: &mut [i32]| {
        // rows_out holds whole output rows, so this division is exact even
        // for the ragged last chunk (m % tile_m != 0).
        let m_hi = (mb + rows_out.len() / n).min(m);
        // Fixed-size row-slice registers (bits ≤ MAX_BITS): plane slices
        // are hoisted per output row/column (§4.2 ④'s reuse analog)
        // without any per-tile allocation.
        let mut wr: [&[u64]; MAX_BITS as usize] = [&[]; MAX_BITS as usize];
        let mut xr: [&[u64]; MAX_BITS as usize] = [&[]; MAX_BITS as usize];
        for nb in (0..n).step_by(opts.tile_n) {
            let n_hi = (nb + opts.tile_n).min(n);
            for mi in mb..m_hi {
                for (i, slot) in wr.iter_mut().enumerate().take(nw) {
                    // lint: allow(narrowing-cast) — plane index < MAX_BITS = 16
                    *slot = wp.row(i as u32, mi);
                }
                let out_row = &mut rows_out[(mi - mb) * n..(mi - mb + 1) * n];
                for ni in nb..n_hi {
                    for (j, slot) in xr.iter_mut().enumerate().take(nx) {
                        // lint: allow(narrowing-cast) — plane index < MAX_BITS = 16
                        *slot = xp.row(j as u32, ni);
                    }
                    out_row[ni] = finish(pair_sum(&wr[..nw], &xr[..nx], &pair_weight, &dot));
                }
            }
        }
    };

    match axis {
        ShardPolicy::Serial | ShardPolicy::Auto => body(0, y),
        ShardPolicy::Rows => {
            chunks_on(&pool, y, opts.tile_m * n, |bi, chunk| body(bi * opts.tile_m, chunk));
        }
        ShardPolicy::Cols => {
            let col_blocks = n.div_ceil(opts.tile_n);
            let out = SendPtr::new(y.as_mut_ptr());
            pool.run(col_blocks, |cb| {
                let nb = cb * opts.tile_n;
                let n_hi = (nb + opts.tile_n).min(n);
                let mut wr: [&[u64]; MAX_BITS as usize] = [&[]; MAX_BITS as usize];
                let mut xr: [&[u64]; MAX_BITS as usize] = [&[]; MAX_BITS as usize];
                for mi in 0..m {
                    for (i, slot) in wr.iter_mut().enumerate().take(nw) {
                        // lint: allow(narrowing-cast) — plane index < MAX_BITS = 16
                        *slot = wp.row(i as u32, mi);
                    }
                    for ni in nb..n_hi {
                        for (j, slot) in xr.iter_mut().enumerate().take(nx) {
                            // lint: allow(narrowing-cast) — plane index < MAX_BITS = 16
                            *slot = xp.row(j as u32, ni);
                        }
                        let v = finish(pair_sum(&wr[..nw], &xr[..nx], &pair_weight, &dot));
                        // SAFETY: column block `cb` exclusively owns every
                        // `ni ∈ [nb, n_hi)`, so writes never alias.
                        unsafe { *out.get().add(mi * n + ni) = v };
                    }
                }
            });
        }
        ShardPolicy::Planes => {
            // §3.2: each (i, j) plane product is an independent partial
            // sum.  Shard the pair list round-robin; every shard owns a
            // private m·n i64 accumulator, recombined serially below —
            // exact integer adds, so grouping cannot change the result.
            let shards = pool.size().min(pairs);
            let mn = m * n;
            let mut partial = vec![0i64; shards * mn];
            let pp = SendPtr::new(partial.as_mut_ptr());
            pool.run(shards, |s| {
                // SAFETY: shard `s` exclusively owns its m·n slice.
                let acc = unsafe { std::slice::from_raw_parts_mut(pp.get().add(s * mn), mn) };
                let mut p = s;
                while p < pairs {
                    // lint: allow(narrowing-cast) — pair split: both < MAX_BITS = 16
                    let (i, j) = ((p / nx) as u32, (p % nx) as u32);
                    let wgt = pair_weight(i, j);
                    for mi in 0..m {
                        let wr = wp.row(i, mi);
                        let row = &mut acc[mi * n..(mi + 1) * n];
                        for (ni, a) in row.iter_mut().enumerate() {
                            *a += wgt * dot(wr, xp.row(j, ni)) as i64;
                        }
                    }
                    p += shards;
                }
            });
            for (e, out) in y.iter_mut().enumerate() {
                let mut acc = 0i64;
                for s in 0..shards {
                    acc += partial[s * mn + e];
                }
                *out = finish(acc);
            }
        }
    }
}

/// Σ_{i,j} pair_weight(i,j) · dot(W_i, X_j) for one output element.  Row
/// slices are hoisted by the caller (§4.2 ④'s reuse analog); each pair
/// runs a tight 4-way-unrolled popcount loop with independent
/// accumulators to break the popcnt dependency chain.
///
/// Accumulates in `i64`: popc ≤ K and the pair weight reaches
/// `2^{2·(bits−1)+1}`, so at LLM-scale K (≈4k–100k) with 8-bit operands
/// the partial sum overflows an `i32` accumulator — the result would wrap
/// silently and the kernel would return wrong logits at exactly the
/// shapes that matter.
#[inline(always)]
fn pair_sum<PW, D>(wr: &[&[u64]], xr: &[&[u64]], pair_weight: &PW, dot: &D) -> i64
where
    PW: Fn(u32, u32) -> i64,
    D: Fn(&[u64], &[u64]) -> u32,
{
    let mut acc = 0i64;
    for (i, w) in wr.iter().enumerate() {
        for (j, x) in xr.iter().enumerate() {
            // lint: allow(narrowing-cast) — plane indices < MAX_BITS = 16
            acc += pair_weight(i as u32, j as u32) * dot(w, x) as i64;
        }
    }
    acc
}

/// Final cast of a widened accumulator into the `i32` output buffer.
/// The *true* product fits i32 for every shape the kernels serve today;
/// if a caller ever exceeds it, fail loudly rather than wrap.  Shared
/// with the standalone recovery pass so fused and unfused paths agree
/// in the overflow regime too.
#[inline(always)]
pub(super) fn checked_i32(v: i64) -> i32 {
    i32::try_from(v)
        .unwrap_or_else(|_| panic!("AP-GEMM output {v} overflows i32 (widen the output type)"))
}

/// The *unfused* pipeline (paper's naive Fig. 4 flow): materialize every
/// intermediate `D_ij` matrix, then a separate shift-add recovery pass.
/// Same result, strictly worse memory behaviour — kept for the ablation
/// bench and as an internal cross-check of the fused kernel.
pub fn apmm_bipolar_unfused(w: &CodeMatrix, xt: &CodeMatrix) -> Vec<i32> {
    assert_eq!(w.cols, xt.cols);
    apmm_bipolar_unfused_packed(&pack_codes(w), &pack_codes(xt))
}

/// Prepacked unfused core (for the ablation bench to isolate recovery
/// dataflow cost from packing cost).
pub fn apmm_bipolar_unfused_packed<W: Planes, X: Planes>(wp: &W, xp: &X) -> Vec<i32> {
    assert_eq!(wp.cols(), xp.cols(), "inner dimension mismatch");
    assert_eq!(wp.kw(), xp.kw(), "packed word-count mismatch");
    let (m, n, k) = (wp.rows(), xp.rows(), wp.cols());
    let (nw, nx) = (wp.bits(), xp.bits());
    // 1-bit GEMMs → intermediate tiles in "global memory"
    let mut tiles: Vec<(u32, u32, Vec<i32>)> = Vec::with_capacity((nw * nx) as usize);
    for i in 0..nw {
        for j in 0..nx {
            let mut d = vec![0i32; m * n];
            for mi in 0..m {
                let wr = wp.row(i, mi);
                for ni in 0..n {
                    // lint: allow(narrowing-cast) — D_ij ∈ [−k, k], exact in i32
                    d[mi * n + ni] = k as i32 - 2 * xor_popcount_dot(wr, xp.row(j, ni)) as i32;
                }
            }
            tiles.push((i, j, d));
        }
    }
    super::recover::recover_tiles(m, n, &tiles)
}

/// Signed (two's-complement) decomposition GEMM via BMMA-AND planes:
/// `Y = Σ_{i,j} s_i s_j 2^{i+j} popc(W_i & X_j)` with the MSB planes
/// negative — note the sign special-case bipolar avoids.
pub fn apmm_signed(w: &CodeMatrix, xt: &CodeMatrix) -> Vec<i32> {
    apmm_weighted(w, xt, IntFormat::Signed)
}

/// Prepacked core of [`apmm_signed`].
pub fn apmm_signed_packed<W: Planes, X: Planes>(wp: &W, xp: &X) -> Vec<i32> {
    apmm_weighted_packed(wp, xp, IntFormat::Signed)
}

/// Unsigned decomposition GEMM via AND planes (values == codes; any
/// zero-point correction is the caller's extra `J` GEMMs, see
/// `IntFormat::correction_gemms`).
pub fn apmm_unsigned(w: &CodeMatrix, xt: &CodeMatrix) -> Vec<i32> {
    apmm_weighted(w, xt, IntFormat::Unsigned)
}

/// Prepacked core of [`apmm_unsigned`].
pub fn apmm_unsigned_packed<W: Planes, X: Planes>(wp: &W, xp: &X) -> Vec<i32> {
    apmm_weighted_packed(wp, xp, IntFormat::Unsigned)
}

fn apmm_weighted(w: &CodeMatrix, xt: &CodeMatrix, fmt: IntFormat) -> Vec<i32> {
    assert_eq!(w.cols, xt.cols);
    apmm_weighted_packed(&pack_codes(w), &pack_codes(xt), fmt)
}

/// Prepacked AND-plane GEMM with per-plane recovery weights under `fmt`
/// (the signed/unsigned baselines share this core), default options.
pub fn apmm_weighted_packed<W: Planes, X: Planes>(wp: &W, xp: &X, fmt: IntFormat) -> Vec<i32> {
    apmm_weighted_packed_opts(wp, xp, fmt, ApmmOpts::default())
}

/// As [`apmm_weighted_packed`] with explicit shard/tile/worker options —
/// the weighted kernels shard along the same three axes as bipolar.
pub fn apmm_weighted_packed_opts<W: Planes, X: Planes>(
    wp: &W,
    xp: &X,
    fmt: IntFormat,
    opts: ApmmOpts,
) -> Vec<i32> {
    assert_eq!(wp.cols(), xp.cols(), "inner dimension mismatch");
    assert_eq!(wp.kw(), xp.kw(), "packed word-count mismatch");
    let (nw, nx) = (wp.bits(), xp.bits());
    let mut y = vec![0i32; wp.rows() * xp.rows()];
    apmm_pairs_sharded(
        wp,
        xp,
        opts,
        |i, j| plane_weight(fmt, i, nw) * plane_weight(fmt, j, nx),
        and_popcount_dot,
        checked_i32,
        &mut y,
    );
    y
}

/// Ground truth: decode both operands under `fmt` and run a plain integer
/// GEMM (i64 accumulate).  `W (M,K)`, `Xᵀ (N,K)`.
pub fn naive_gemm_decoded(w: &CodeMatrix, xt: &CodeMatrix, fmt: IntFormat) -> Vec<i32> {
    assert_eq!(w.cols, xt.cols);
    let (m, n, k) = (w.rows, xt.rows, w.cols);
    let wd = w.decode(fmt);
    let xd = xt.decode(fmt);
    let mut y = vec![0i32; m * n];
    if m == 0 || n == 0 {
        return y;
    }
    par_chunks_mut(&mut y, n, |mi, row| {
        for (ni, out) in row.iter_mut().enumerate() {
            let mut acc = 0i64;
            for ki in 0..k {
                acc += wd[mi * k + ki] as i64 * xd[ni * k + ki] as i64;
            }
            *out = checked_i32(acc);
        }
    });
    y
}

/// Blocked f32 GEMM baseline: `a (M,K)`, `bᵀ (N,K)` → `(M,N)`.
/// The FP32 comparator for the measured bench.
pub fn gemm_f32(a: &[f32], bt: &[f32], m: usize, n: usize, k: usize) -> Vec<f32> {
    assert_eq!(a.len(), m * k);
    assert_eq!(bt.len(), n * k);
    let mut c = vec![0f32; m * n];
    if m == 0 || n == 0 {
        return c;
    }
    par_chunks_mut(&mut c, n, |mi, row| {
        let ar = &a[mi * k..(mi + 1) * k];
        for (ni, out) in row.iter_mut().enumerate() {
            let br = &bt[ni * k..(ni + 1) * k];
            let mut acc = 0f32;
            let mut ki = 0;
            while ki + 8 <= k {
                acc += ar[ki] * br[ki]
                    + ar[ki + 1] * br[ki + 1]
                    + ar[ki + 2] * br[ki + 2]
                    + ar[ki + 3] * br[ki + 3]
                    + ar[ki + 4] * br[ki + 4]
                    + ar[ki + 5] * br[ki + 5]
                    + ar[ki + 6] * br[ki + 6]
                    + ar[ki + 7] * br[ki + 7];
                ki += 8;
            }
            while ki < k {
                acc += ar[ki] * br[ki];
                ki += 1;
            }
            *out = acc;
        }
    });
    c
}
