//! Arbitrary-precision MatMul kernels (fused recovery) + baselines.
//!
//! Operand convention: weights `W` are `(M, K)` codes; activations arrive
//! **transposed** as `Xᵀ` `(N, K)` so both sides stream along packed-K —
//! the same N-major layout the Pallas kernel uses.
//!
//! ## Prepacked ABI (§3.3)
//!
//! Every kernel has two entry points:
//!
//! * `apmm_*_packed` — the **hot-path core**: consumes any [`Planes`]
//!   operand ([`super::planes::PackedPlanes`], or a
//!   [`super::planes::PlaneView`] slicing a lower precision out of a
//!   packed superset), performs zero
//!   `pack_codes` calls and zero weight allocations.  Weights should be
//!   packed once (see [`super::prepack`]) and reused across calls;
//!   activations pack through a `PackArena`.
//! * `apmm_*` on [`CodeMatrix`] — thin pack-then-call convenience wrapper
//!   (construction-time / test use; it re-packs both operands per call
//!   and is therefore **not** hot-path-safe).

use super::gemm1b::{and_popcount_dot, xor_popcount_dot};
use super::planes::{pack_codes, CodeMatrix, Planes, MAX_BITS};
use crate::bitfmt::{plane_weight, IntFormat};
use crate::util::par_chunks_mut;

/// Kernel options (the §4.2 knobs that exist on a CPU).
#[derive(Debug, Clone, Copy)]
pub struct ApmmOpts {
    /// Parallelize over output row blocks (util::par thread pool).
    pub parallel: bool,
    /// Output row/col tile (cache blocking — the shared-memory analog).
    pub tile_m: usize,
    pub tile_n: usize,
}

impl Default for ApmmOpts {
    fn default() -> Self {
        Self { parallel: true, tile_m: 32, tile_n: 32 }
    }
}

/// Transpose a code matrix (used to put activations in N-major layout).
pub fn transpose_codes(m: &CodeMatrix) -> CodeMatrix {
    let mut data = vec![0u32; m.rows * m.cols];
    for r in 0..m.rows {
        for c in 0..m.cols {
            data[c * m.rows + r] = m.at(r, c);
        }
    }
    CodeMatrix::new(m.cols, m.rows, m.bits, data)
}

/// Fused bipolar AP-GEMM: `Y = W · X` with `W (M,K)`, `Xᵀ (N,K)` codes.
///
/// `Y[m,n] = C − 2 · Σ_{i,j} popc(W_i[m] ^ X_j[n]) << (i+j)`,
/// `C = K (2^{n_w}−1)(2^{n_x}−1)` — recovery runs entirely in registers.
///
/// Convenience wrapper: packs both operands, then delegates to
/// [`apmm_bipolar_packed_into`].
pub fn apmm_bipolar(w: &CodeMatrix, xt: &CodeMatrix, opts: ApmmOpts) -> Vec<i32> {
    let mut y = vec![0i32; w.rows * xt.rows];
    apmm_bipolar_into(w, xt, opts, &mut y);
    y
}

/// As [`apmm_bipolar`] but writing into a caller-provided buffer (the
/// serving hot path reuses output allocations).
pub fn apmm_bipolar_into(w: &CodeMatrix, xt: &CodeMatrix, opts: ApmmOpts, y: &mut [i32]) {
    assert_eq!(w.cols, xt.cols, "inner dimension mismatch");
    let wp = pack_codes(w);
    let xp = pack_codes(xt);
    apmm_bipolar_packed_into(&wp, &xp, opts, y);
}

/// Prepacked fused bipolar AP-GEMM core (allocates only the output).
pub fn apmm_bipolar_packed<W: Planes, X: Planes>(wp: &W, xp: &X, opts: ApmmOpts) -> Vec<i32> {
    let mut y = vec![0i32; wp.rows() * xp.rows()];
    apmm_bipolar_packed_into(wp, xp, opts, &mut y);
    y
}

/// The hot-path core: prepacked operands in (full packs or any-precision
/// [`super::planes::PlaneView`]s), caller-provided output buffer, **zero**
/// packing and zero heap allocation.
pub fn apmm_bipolar_packed_into<W: Planes, X: Planes>(
    wp: &W,
    xp: &X,
    opts: ApmmOpts,
    y: &mut [i32],
) {
    assert_eq!(wp.cols(), xp.cols(), "inner dimension mismatch");
    assert_eq!(wp.kw(), xp.kw(), "packed word-count mismatch");
    assert_eq!(y.len(), wp.rows() * xp.rows(), "output buffer size");
    assert!(opts.tile_m > 0 && opts.tile_n > 0, "tiles must be non-empty");
    let (m, n, k) = (wp.rows(), xp.rows(), wp.cols());
    if m == 0 || n == 0 {
        return; // empty output; avoids the zero-size row-block chunks below
    }
    let (nw, nx) = (wp.bits(), xp.bits());
    // bits ≤ MAX_BITS is a PackedPlanes construction invariant, so these
    // widened shifts cannot overflow.  C stays in i64: at 16×16 bits and
    // LLM-scale K it exceeds i32::MAX long before the final result does.
    let c_const = k as i64 * ((1i64 << nw) - 1) * ((1i64 << nx) - 1);

    let body = |mb: usize, rows_out: &mut [i32]| {
        // rows_out holds whole output rows, so this division is exact even
        // for the ragged last chunk (m % tile_m != 0).
        let m_hi = (mb + rows_out.len() / n).min(m);
        // Fixed-size row-slice registers (bits ≤ MAX_BITS): plane slices
        // are hoisted per output row/column (§4.2 ④'s reuse analog)
        // without any per-tile allocation.
        let mut wr: [&[u64]; MAX_BITS as usize] = [&[]; MAX_BITS as usize];
        let mut xr: [&[u64]; MAX_BITS as usize] = [&[]; MAX_BITS as usize];
        for nb in (0..n).step_by(opts.tile_n) {
            let n_hi = (nb + opts.tile_n).min(n);
            for mi in mb..m_hi {
                for (i, slot) in wr.iter_mut().enumerate().take(nw as usize) {
                    *slot = wp.row(i as u32, mi);
                }
                let out_row = &mut rows_out[(mi - mb) * n..(mi - mb + 1) * n];
                for ni in nb..n_hi {
                    for (j, slot) in xr.iter_mut().enumerate().take(nx as usize) {
                        *slot = xp.row(j as u32, ni);
                    }
                    out_row[ni] = checked_i32(
                        c_const - 2 * plane_pair_sum(&wr[..nw as usize], &xr[..nx as usize]),
                    );
                }
            }
        }
    };

    if opts.parallel && m >= 2 * opts.tile_m {
        par_chunks_mut(y, opts.tile_m * n, |bi, chunk| body(bi * opts.tile_m, chunk));
    } else {
        body(0, y);
    }
}

/// Σ_{i,j} popc(W_i ^ X_j) << (i+j) for one output element.  Row slices
/// are hoisted by the caller (§4.2 ④'s reuse analog); each pair runs a
/// tight 4-way-unrolled XOR/popcount loop with independent accumulators
/// to break the popcnt dependency chain.
///
/// Accumulates in `i64`: popc ≤ K and the shift reaches 2·(bits−1), so at
/// LLM-scale K (≈4k–100k) with 8-bit operands the partial sum overflows
/// both the `u32` shift and an `i32` accumulator — the result would wrap
/// silently and the kernel would return wrong logits at exactly the
/// shapes that matter.
#[inline(always)]
fn plane_pair_sum(wr: &[&[u64]], xr: &[&[u64]]) -> i64 {
    let mut acc = 0i64;
    for (i, w) in wr.iter().enumerate() {
        for (j, x) in xr.iter().enumerate() {
            acc += (xor_popcount_dot(w, x) as i64) << (i + j);
        }
    }
    acc
}

/// Final cast of a widened accumulator into the `i32` output buffer.
/// The *true* product fits i32 for every shape the kernels serve today;
/// if a caller ever exceeds it, fail loudly rather than wrap.  Shared
/// with the standalone recovery pass so fused and unfused paths agree
/// in the overflow regime too.
#[inline(always)]
pub(super) fn checked_i32(v: i64) -> i32 {
    i32::try_from(v)
        .unwrap_or_else(|_| panic!("AP-GEMM output {v} overflows i32 (widen the output type)"))
}

/// The *unfused* pipeline (paper's naive Fig. 4 flow): materialize every
/// intermediate `D_ij` matrix, then a separate shift-add recovery pass.
/// Same result, strictly worse memory behaviour — kept for the ablation
/// bench and as an internal cross-check of the fused kernel.
pub fn apmm_bipolar_unfused(w: &CodeMatrix, xt: &CodeMatrix) -> Vec<i32> {
    assert_eq!(w.cols, xt.cols);
    apmm_bipolar_unfused_packed(&pack_codes(w), &pack_codes(xt))
}

/// Prepacked unfused core (for the ablation bench to isolate recovery
/// dataflow cost from packing cost).
pub fn apmm_bipolar_unfused_packed<W: Planes, X: Planes>(wp: &W, xp: &X) -> Vec<i32> {
    assert_eq!(wp.cols(), xp.cols(), "inner dimension mismatch");
    assert_eq!(wp.kw(), xp.kw(), "packed word-count mismatch");
    let (m, n, k) = (wp.rows(), xp.rows(), wp.cols());
    let (nw, nx) = (wp.bits(), xp.bits());
    // 1-bit GEMMs → intermediate tiles in "global memory"
    let mut tiles: Vec<(u32, u32, Vec<i32>)> = Vec::with_capacity((nw * nx) as usize);
    for i in 0..nw {
        for j in 0..nx {
            let mut d = vec![0i32; m * n];
            for mi in 0..m {
                let wr = wp.row(i, mi);
                for ni in 0..n {
                    d[mi * n + ni] = k as i32 - 2 * xor_popcount_dot(wr, xp.row(j, ni)) as i32;
                }
            }
            tiles.push((i, j, d));
        }
    }
    super::recover::recover_tiles(m, n, &tiles)
}

/// Signed (two's-complement) decomposition GEMM via BMMA-AND planes:
/// `Y = Σ_{i,j} s_i s_j 2^{i+j} popc(W_i & X_j)` with the MSB planes
/// negative — note the sign special-case bipolar avoids.
pub fn apmm_signed(w: &CodeMatrix, xt: &CodeMatrix) -> Vec<i32> {
    apmm_weighted(w, xt, IntFormat::Signed)
}

/// Prepacked core of [`apmm_signed`].
pub fn apmm_signed_packed<W: Planes, X: Planes>(wp: &W, xp: &X) -> Vec<i32> {
    apmm_weighted_packed(wp, xp, IntFormat::Signed)
}

/// Unsigned decomposition GEMM via AND planes (values == codes; any
/// zero-point correction is the caller's extra `J` GEMMs, see
/// `IntFormat::correction_gemms`).
pub fn apmm_unsigned(w: &CodeMatrix, xt: &CodeMatrix) -> Vec<i32> {
    apmm_weighted(w, xt, IntFormat::Unsigned)
}

/// Prepacked core of [`apmm_unsigned`].
pub fn apmm_unsigned_packed<W: Planes, X: Planes>(wp: &W, xp: &X) -> Vec<i32> {
    apmm_weighted_packed(wp, xp, IntFormat::Unsigned)
}

fn apmm_weighted(w: &CodeMatrix, xt: &CodeMatrix, fmt: IntFormat) -> Vec<i32> {
    assert_eq!(w.cols, xt.cols);
    apmm_weighted_packed(&pack_codes(w), &pack_codes(xt), fmt)
}

/// Prepacked AND-plane GEMM with per-plane recovery weights under `fmt`
/// (the signed/unsigned baselines share this core).
pub fn apmm_weighted_packed<W: Planes, X: Planes>(wp: &W, xp: &X, fmt: IntFormat) -> Vec<i32> {
    assert_eq!(wp.cols(), xp.cols(), "inner dimension mismatch");
    assert_eq!(wp.kw(), xp.kw(), "packed word-count mismatch");
    let (m, n) = (wp.rows(), xp.rows());
    let (nw, nx) = (wp.bits(), xp.bits());
    let mut y = vec![0i32; m * n];
    if m == 0 || n == 0 {
        return y;
    }
    par_chunks_mut(&mut y, n, |mi, row| {
        for (ni, out) in row.iter_mut().enumerate() {
            let mut acc = 0i64;
            for i in 0..nw {
                let wi = plane_weight(fmt, i, nw);
                let wr = wp.row(i, mi);
                for j in 0..nx {
                    let xj = plane_weight(fmt, j, nx);
                    acc += wi * xj * and_popcount_dot(wr, xp.row(j, ni)) as i64;
                }
            }
            *out = checked_i32(acc);
        }
    });
    y
}

/// Ground truth: decode both operands under `fmt` and run a plain integer
/// GEMM (i64 accumulate).  `W (M,K)`, `Xᵀ (N,K)`.
pub fn naive_gemm_decoded(w: &CodeMatrix, xt: &CodeMatrix, fmt: IntFormat) -> Vec<i32> {
    assert_eq!(w.cols, xt.cols);
    let (m, n, k) = (w.rows, xt.rows, w.cols);
    let wd = w.decode(fmt);
    let xd = xt.decode(fmt);
    let mut y = vec![0i32; m * n];
    if m == 0 || n == 0 {
        return y;
    }
    par_chunks_mut(&mut y, n, |mi, row| {
        for (ni, out) in row.iter_mut().enumerate() {
            let mut acc = 0i64;
            for ki in 0..k {
                acc += wd[mi * k + ki] as i64 * xd[ni * k + ki] as i64;
            }
            *out = checked_i32(acc);
        }
    });
    y
}

/// Blocked f32 GEMM baseline: `a (M,K)`, `bᵀ (N,K)` → `(M,N)`.
/// The FP32 comparator for the measured bench.
pub fn gemm_f32(a: &[f32], bt: &[f32], m: usize, n: usize, k: usize) -> Vec<f32> {
    assert_eq!(a.len(), m * k);
    assert_eq!(bt.len(), n * k);
    let mut c = vec![0f32; m * n];
    if m == 0 || n == 0 {
        return c;
    }
    par_chunks_mut(&mut c, n, |mi, row| {
        let ar = &a[mi * k..(mi + 1) * k];
        for (ni, out) in row.iter_mut().enumerate() {
            let br = &bt[ni * k..(ni + 1) * k];
            let mut acc = 0f32;
            let mut ki = 0;
            while ki + 8 <= k {
                acc += ar[ki] * br[ki]
                    + ar[ki + 1] * br[ki + 1]
                    + ar[ki + 2] * br[ki + 2]
                    + ar[ki + 3] * br[ki + 3]
                    + ar[ki + 4] * br[ki + 4]
                    + ar[ki + 5] * br[ki + 5]
                    + ar[ki + 6] * br[ki + 6]
                    + ar[ki + 7] * br[ki + 7];
                ki += 8;
            }
            while ki < k {
                acc += ar[ki] * br[ki];
                ki += 1;
            }
            *out = acc;
        }
    });
    c
}
