//! Transformer architecture descriptions + GEMM shape walks.

/// MLP block variant.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum MlpKind {
    /// Gated SiLU (Llama): gate + up + down → 3 projections.
    SwiGlu,
    /// Plain 2-projection MLP (OPT, BLOOM): up + down.
    Gelu,
}

/// One GEMM in an inference step: `(M, K) × (K, N)`, executed `count`
/// times per model forward (M = tokens processed).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct MatMulShape {
    pub m: usize,
    pub k: usize,
    pub n: usize,
    pub count: usize,
    pub label: &'static str,
}

impl MatMulShape {
    /// Multiply-accumulate count (`2·M·N·K` ops) for all `count` instances.
    pub fn flops(&self) -> u128 {
        2 * self.m as u128 * self.n as u128 * self.k as u128 * self.count as u128
    }

    /// Prepacked weight footprint for all `count` instances: the `(K, N)`
    /// operand at `nw` bits per element (§4.1 — what a
    /// `PackedWeightStore` holds resident for this layer).
    pub fn packed_weight_bytes(&self, nw: u32) -> usize {
        (self.k * self.n * nw as usize).div_ceil(8) * self.count
    }

    /// Packed activation footprint per forward: the `(M, K)` operand at
    /// `nx` bits (what the packing arena cycles through each step).
    pub fn packed_act_bytes(&self, nx: u32) -> usize {
        (self.m * self.k * nx as usize).div_ceil(8)
    }
}

/// An LLM architecture (decoder-only transformer).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct LlmArch {
    pub name: &'static str,
    pub dim: usize,
    pub n_layers: usize,
    pub n_heads: usize,
    pub n_kv_heads: usize,
    pub ffn: usize,
    pub vocab: usize,
    pub mlp: MlpKind,
}

impl LlmArch {
    /// Llama2-7B: dim 4096, ffn 11008 (the paper's "10.5k"), 32 layers.
    pub fn llama2_7b() -> Self {
        Self {
            name: "Llama2-7B",
            dim: 4096,
            n_layers: 32,
            n_heads: 32,
            n_kv_heads: 32,
            ffn: 11008,
            vocab: 32000,
            mlp: MlpKind::SwiGlu,
        }
    }

    /// OPT-6.7B: dim 4096, ffn 16384, 32 layers.
    pub fn opt_6_7b() -> Self {
        Self {
            name: "OPT-6.7B",
            dim: 4096,
            n_layers: 32,
            n_heads: 32,
            n_kv_heads: 32,
            ffn: 16384,
            vocab: 50272,
            mlp: MlpKind::Gelu,
        }
    }

    /// BLOOM-7B1: dim 4096, ffn 16384, 30 layers.
    pub fn bloom_7b() -> Self {
        Self {
            name: "BLOOM-7B",
            dim: 4096,
            n_layers: 30,
            n_heads: 32,
            n_kv_heads: 32,
            ffn: 16384,
            vocab: 250880,
            mlp: MlpKind::Gelu,
        }
    }

    pub fn all_paper_models() -> Vec<Self> {
        vec![Self::llama2_7b(), Self::opt_6_7b(), Self::bloom_7b()]
    }

    pub fn head_dim(&self) -> usize {
        self.dim / self.n_heads
    }

    /// Dense parameter count of the weight GEMMs (excludes embeddings'
    /// lookup use, includes the LM head).
    pub fn weight_params(&self) -> u128 {
        self.per_layer_shapes(1)
            .iter()
            .map(|s| (s.k * s.n * s.count) as u128)
            .sum::<u128>()
            * self.n_layers as u128
            + (self.dim * self.vocab) as u128
    }

    /// The weight GEMMs of ONE decoder layer when processing `m` tokens.
    pub fn per_layer_shapes(&self, m: usize) -> Vec<MatMulShape> {
        let kvd = self.n_kv_heads * self.head_dim();
        let mut v = vec![
            MatMulShape { m, k: self.dim, n: self.dim, count: 1, label: "attn.q" },
            MatMulShape { m, k: self.dim, n: kvd, count: 2, label: "attn.kv" },
            MatMulShape { m, k: self.dim, n: self.dim, count: 1, label: "attn.o" },
        ];
        match self.mlp {
            MlpKind::SwiGlu => {
                v.push(MatMulShape { m, k: self.dim, n: self.ffn, count: 2, label: "mlp.gate_up" });
                v.push(MatMulShape { m, k: self.ffn, n: self.dim, count: 1, label: "mlp.down" });
            }
            MlpKind::Gelu => {
                v.push(MatMulShape { m, k: self.dim, n: self.ffn, count: 1, label: "mlp.up" });
                v.push(MatMulShape { m, k: self.ffn, n: self.dim, count: 1, label: "mlp.down" });
            }
        }
        v
    }

    /// Every weight GEMM of a full forward over `m` tokens (all layers +
    /// LM head), aggregated by shape.
    pub fn forward_shapes(&self, m: usize) -> Vec<MatMulShape> {
        let mut v: Vec<MatMulShape> = self
            .per_layer_shapes(m)
            .into_iter()
            .map(|mut s| {
                s.count *= self.n_layers;
                s
            })
            .collect();
        v.push(MatMulShape { m, k: self.dim, n: self.vocab, count: 1, label: "lm_head" });
        v
    }

    /// The paper's Table 2 picks: the three most FLOP-intensive GEMMs of
    /// Llama2-7B at M = 1k (qkvo ≈ 1k/4k/4k, up ≈ 1k/10.5k/4k,
    /// down ≈ 1k/4k/10.5k).
    pub fn table2_shapes() -> [MatMulShape; 3] {
        [
            MatMulShape { m: 1024, k: 4096, n: 4096, count: 1, label: "1k/4k/4k" },
            MatMulShape { m: 1024, k: 4096, n: 11008, count: 1, label: "1k/10.5k/4k" },
            MatMulShape { m: 1024, k: 11008, n: 4096, count: 1, label: "1k/4k/10.5k" },
        ]
    }
}
