//! W{n}A{m} precision configurations.

/// A weight/activation bit-width pair, e.g. W1A2.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub struct PrecisionConfig {
    pub nw: u32,
    pub nx: u32,
}

impl PrecisionConfig {
    pub const W1A1: Self = Self { nw: 1, nx: 1 };
    pub const W1A2: Self = Self { nw: 1, nx: 2 };
    pub const W2A2: Self = Self { nw: 2, nx: 2 };
    pub const W3A2: Self = Self { nw: 3, nx: 2 };
    pub const W3A4: Self = Self { nw: 3, nx: 4 };
    pub const W4A4: Self = Self { nw: 4, nx: 4 };
    pub const W8A8: Self = Self { nw: 8, nx: 8 };

    pub fn new(nw: u32, nx: u32) -> Self {
        assert!((1..=8).contains(&nw) && (1..=8).contains(&nx), "bits must be 1..=8");
        Self { nw, nx }
    }

    /// Number of 1-bit plane-pair GEMMs the decomposition needs.
    pub fn plane_pairs(&self) -> u32 {
        self.nw * self.nx
    }

    /// e.g. "W2A2".
    pub fn label(&self) -> String {
        format!("W{}A{}", self.nw, self.nx)
    }

    /// Parse "W3A4" / "w3a4".
    pub fn parse(s: &str) -> Option<Self> {
        let s = s.trim().to_ascii_uppercase();
        let rest = s.strip_prefix('W')?;
        let (w, a) = rest.split_once('A')?;
        let (nw, nx) = (w.parse().ok()?, a.parse().ok()?);
        if (1..=8).contains(&nw) && (1..=8).contains(&nx) {
            Some(Self { nw, nx })
        } else {
            None
        }
    }

    /// Packed operand footprint for an (M,K)x(K,N) GEMM, in bytes
    /// (§4.1: exactly nw/nx bits per element).
    pub fn operand_bytes(&self, m: usize, k: usize, n: usize) -> usize {
        (m * k * self.nw as usize + k * n * self.nx as usize).div_ceil(8)
    }
}

impl std::fmt::Display for PrecisionConfig {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "W{}A{}", self.nw, self.nx)
    }
}
