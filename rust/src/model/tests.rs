use super::*;

#[test]
fn llama_params_about_7b() {
    let p = LlmArch::llama2_7b().weight_params();
    assert!((6_000_000_000..8_000_000_000u128).contains(&p), "params={p}");
}

#[test]
fn opt_and_bloom_sane() {
    for a in [LlmArch::opt_6_7b(), LlmArch::bloom_7b()] {
        let p = a.weight_params();
        assert!((5_000_000_000..9_000_000_000u128).contains(&p), "{}: {p}", a.name);
        assert_eq!(a.head_dim() * a.n_heads, a.dim);
    }
}

#[test]
fn table2_matches_paper_shapes() {
    let s = LlmArch::table2_shapes();
    assert_eq!((s[0].m, s[0].k, s[0].n), (1024, 4096, 4096));
    assert_eq!((s[1].k, s[1].n), (4096, 11008)); // "1k/10.5k/4k": N=10.5k... paper lists N/K
    assert_eq!((s[2].k, s[2].n), (11008, 4096));
}

#[test]
fn per_layer_shapes_cover_all_projections() {
    let a = LlmArch::llama2_7b();
    let shapes = a.per_layer_shapes(16);
    let total: usize = shapes.iter().map(|s| s.count).sum();
    assert_eq!(total, 7, "q + k + v + o + gate + up + down");
    assert!(shapes.iter().all(|s| s.m == 16));
    let b = LlmArch::opt_6_7b();
    let total: usize = b.per_layer_shapes(16).iter().map(|s| s.count).sum();
    assert_eq!(total, 6, "no gate for GELU MLP");
}

#[test]
fn forward_flops_scale_with_m() {
    let a = LlmArch::llama2_7b();
    let f1: u128 = a.forward_shapes(1).iter().map(|s| s.flops()).sum();
    let f8: u128 = a.forward_shapes(8).iter().map(|s| s.flops()).sum();
    assert_eq!(f8, 8 * f1);
    // ~2 FLOPs per weight param per token
    let per_tok = f1 / 2;
    let params = a.weight_params();
    assert!(per_tok > params * 9 / 10 && per_tok < params * 11 / 10);
}

#[test]
fn packed_footprints() {
    let s = MatMulShape { m: 16, k: 64, n: 32, count: 2, label: "t" };
    // 64·32 elems at 2 bits = 512 bytes, twice
    assert_eq!(s.packed_weight_bytes(2), 1024);
    // 16·64 elems at 3 bits = 384 bytes (per step, count-independent)
    assert_eq!(s.packed_act_bytes(3), 384);
    // whole-model resident packed weights stay far below the f16 footprint
    let a = LlmArch::llama2_7b();
    // forward_shapes already scales count by n_layers
    let packed: usize = a.forward_shapes(1).iter().map(|s| s.packed_weight_bytes(2)).sum();
    let f16 = a.weight_params() as usize * 2;
    assert!(packed < f16 / 4, "packed={packed} f16={f16}");
}

#[test]
fn precision_parse_roundtrip() {
    for p in [PrecisionConfig::W1A2, PrecisionConfig::W3A4, PrecisionConfig::W8A8] {
        assert_eq!(PrecisionConfig::parse(&p.label()), Some(p));
    }
    assert_eq!(PrecisionConfig::parse("w2a2"), Some(PrecisionConfig::W2A2));
    assert!(PrecisionConfig::parse("W9A1").is_none());
    assert!(PrecisionConfig::parse("FP16").is_none());
}

#[test]
fn precision_costs() {
    assert_eq!(PrecisionConfig::W2A2.plane_pairs(), 4);
    assert_eq!(PrecisionConfig::W3A4.plane_pairs(), 12);
    // 1-bit weights: M*K/8 bytes
    assert_eq!(PrecisionConfig::W1A1.operand_bytes(8, 64, 8), (8 * 64 + 64 * 8) / 8);
}
