//! LLM architecture tables and per-layer MatMul shape extraction.
//!
//! The paper's Table 2 / Fig. 6 / Fig. 7 workloads are defined by the
//! MatMul shapes of Llama2-7B, OPT-6.7B and BLOOM-7B.  This module encodes
//! those architectures and walks their layers to enumerate every GEMM an
//! inference step performs, so the simulator and benches can reproduce the
//! exact shape mix.

mod arch;
mod precision;

pub use arch::{LlmArch, MatMulShape, MlpKind};
pub use precision::PrecisionConfig;

#[cfg(test)]
mod tests;
