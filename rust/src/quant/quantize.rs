//! Round-to-nearest quantizers and error metrics.
//!
//! Weight quantizers can emit **prepacked** planes directly
//! (`quantize_*_packed`, [`Quantized::prepack`]) so the §3.3 pack-once
//! pipeline starts here: quantize → decompose+pack once → serve through
//! the `apmm_*_packed` kernels without ever touching codes again.

use crate::bitfmt::{bipolar_encode, bipolar_qmax, signed_range};
use crate::bitmm::{pack_codes, CodeMatrix, PackedPlanes};

/// A quantized matrix: codes + scales (`x ≈ decode(code) · scale`).
#[derive(Debug, Clone)]
pub struct Quantized {
    pub codes: CodeMatrix,
    /// One scale per row (per-channel) or a single element (per-tensor).
    pub scales: Vec<f32>,
}

impl Quantized {
    #[inline]
    pub fn scale_for_row(&self, r: usize) -> f32 {
        if self.scales.len() == 1 {
            self.scales[0]
        } else {
            self.scales[r]
        }
    }

    /// Decompose+pack the codes for the prepacked kernel ABI, keeping
    /// `self` (construction-time use; for weights prefer [`Self::into_packed`]).
    pub fn prepack(&self) -> QuantizedPacked {
        QuantizedPacked { planes: pack_codes(&self.codes), scales: self.scales.clone() }
    }

    /// Consume into the packed form — the codes are dropped, which is the
    /// point: after this, only the kernel-ready layout exists.
    pub fn into_packed(self) -> QuantizedPacked {
        QuantizedPacked { planes: pack_codes(&self.codes), scales: self.scales }
    }
}

/// A quantized matrix already decomposed+packed for the kernel ABI (§3.3
/// pack-once: the `CodeMatrix` is a construction-time artifact and is not
/// retained).
#[derive(Debug, Clone)]
pub struct QuantizedPacked {
    pub planes: PackedPlanes,
    /// One scale per row (per-channel) or a single element (per-tensor).
    pub scales: Vec<f32>,
}

impl QuantizedPacked {
    #[inline]
    pub fn scale_for_row(&self, r: usize) -> f32 {
        if self.scales.len() == 1 {
            self.scales[0]
        } else {
            self.scales[r]
        }
    }
}

fn round_to_odd(t: f32) -> f32 {
    2.0 * ((t - 1.0) / 2.0).round() + 1.0
}

fn quantize_rows(x: &[f32], rows: usize, cols: usize, bits: u32, per_channel: bool) -> Quantized {
    assert_eq!(x.len(), rows * cols);
    let qmax = bipolar_qmax(bits) as f32;
    let scale_of = |slice: &[f32]| -> f32 {
        let amax = slice.iter().fold(0f32, |a, &v| a.max(v.abs()));
        amax.max(1e-8) / qmax
    };
    let mut scales = Vec::new();
    let mut codes = vec![0u32; rows * cols];
    if per_channel {
        for r in 0..rows {
            let row = &x[r * cols..(r + 1) * cols];
            let s = scale_of(row);
            scales.push(s);
            for (c, &v) in row.iter().enumerate() {
                let q = round_to_odd(v / s).clamp(-qmax, qmax) as i32;
                codes[r * cols + c] = bipolar_encode(q, bits);
            }
        }
    } else {
        let s = scale_of(x);
        scales.push(s);
        for (idx, &v) in x.iter().enumerate() {
            let q = round_to_odd(v / s).clamp(-qmax, qmax) as i32;
            codes[idx] = bipolar_encode(q, bits);
        }
    }
    Quantized { codes: CodeMatrix::new(rows, cols, bits, codes), scales }
}

/// Per-tensor symmetric bipolar quantization of a `(rows, cols)` matrix.
pub fn quantize_bipolar_per_tensor(x: &[f32], rows: usize, cols: usize, bits: u32) -> Quantized {
    quantize_rows(x, rows, cols, bits, false)
}

/// Per-row (output-channel) symmetric bipolar quantization.
pub fn quantize_bipolar_per_channel(x: &[f32], rows: usize, cols: usize, bits: u32) -> Quantized {
    quantize_rows(x, rows, cols, bits, true)
}

/// Per-channel weight quantization that emits the prepacked kernel
/// operand directly (the §3.3 offline pipeline in one call).
pub fn quantize_bipolar_per_channel_packed(
    x: &[f32],
    rows: usize,
    cols: usize,
    bits: u32,
) -> QuantizedPacked {
    quantize_rows(x, rows, cols, bits, true).into_packed()
}

/// Per-tensor variant of [`quantize_bipolar_per_channel_packed`].
pub fn quantize_bipolar_per_tensor_packed(
    x: &[f32],
    rows: usize,
    cols: usize,
    bits: u32,
) -> QuantizedPacked {
    quantize_rows(x, rows, cols, bits, false).into_packed()
}

/// Rescale dequant scales for a `view_bits`-plane prefix view of a
/// `full_bits` superset pack (the Any-Precision serving trick, per
/// PAPERS.md): dropping the `full_bits − view_bits` least-significant
/// planes divides every decoded bipolar magnitude by `2^(full−view)`, so
/// the scale grows by the same factor —
/// `x ≈ decode(c, full)·s ≈ decode(c >> (full−view), view) · s·2^(full−view)`.
/// The residual of the dropped planes is bounded by `s·(2^(full−view)−1)`,
/// i.e. exactly the coarser precision's quantization step.
pub fn view_scales(scales: &[f32], full_bits: u32, view_bits: u32) -> Vec<f32> {
    assert!(
        (1..=full_bits).contains(&view_bits),
        "view bits {view_bits} outside 1..={full_bits}"
    );
    let f = (1u64 << (full_bits - view_bits)) as f32;
    scales.iter().map(|&s| s * f).collect()
}

/// Baseline: per-row signed (two's-complement) RTN quantization.  Returns
/// codes in `bits`-wide two's complement; used by the format ablation.
pub fn quantize_signed_per_channel(x: &[f32], rows: usize, cols: usize, bits: u32) -> Quantized {
    assert_eq!(x.len(), rows * cols);
    let (lo, hi) = signed_range(bits);
    let mut scales = Vec::with_capacity(rows);
    let mut codes = vec![0u32; rows * cols];
    for r in 0..rows {
        let row = &x[r * cols..(r + 1) * cols];
        let amax = row.iter().fold(0f32, |a, &v| a.max(v.abs()));
        let s = amax.max(1e-8) / hi as f32;
        scales.push(s);
        for (c, &v) in row.iter().enumerate() {
            let q = (v / s).round().clamp(lo as f32, hi as f32) as i32;
            codes[r * cols + c] = (q as u32) & ((1u32 << bits) - 1);
        }
    }
    Quantized { codes: CodeMatrix::new(rows, cols, bits, codes), scales }
}

/// Reconstruct floats from a quantized matrix under the given format.
pub fn dequantize(q: &Quantized, fmt: crate::bitfmt::IntFormat) -> Vec<f32> {
    let decoded = q.codes.decode(fmt);
    let cols = q.codes.cols;
    decoded
        .iter()
        .enumerate()
        .map(|(idx, &v)| v as f32 * q.scale_for_row(idx / cols))
        .collect()
}

/// Quantization error summary.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct QuantError {
    pub mse: f64,
    pub max_abs: f32,
    /// Relative L2: ‖x − x̂‖ / ‖x‖.
    pub rel_l2: f64,
}

/// Compare original vs reconstruction.
pub fn quant_error(x: &[f32], xhat: &[f32]) -> QuantError {
    assert_eq!(x.len(), xhat.len());
    let mut se = 0f64;
    let mut nx = 0f64;
    let mut max_abs = 0f32;
    for (&a, &b) in x.iter().zip(xhat.iter()) {
        let d = a - b;
        se += (d as f64) * (d as f64);
        nx += (a as f64) * (a as f64);
        max_abs = max_abs.max(d.abs());
    }
    QuantError {
        mse: se / x.len() as f64,
        max_abs,
        rel_l2: if nx > 0.0 { (se / nx).sqrt() } else { 0.0 },
    }
}
