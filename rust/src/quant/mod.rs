//! Quantizers onto the bipolar-INT grid (mirrors `python/compile/quant.py`).
//!
//! Symmetric round-to-nearest-odd quantization: with scale
//! `s = max|x| / (2^n − 1)`, each value maps to the nearest odd integer of
//! `x/s`, clipped to ±(2^n−1).  Per-tensor and per-channel (per-row)
//! granularities.  Baseline signed/asymmetric quantizers are included for
//! the format ablation.
//!
//! Weight quantizers can emit prepacked planes directly
//! (`quantize_*_packed` / [`Quantized::prepack`]) so serving never holds
//! unpacked weight codes — see `bitmm::prepack` for the pack-once stores.
//!
//! An n-bit pack quantized here is an **any-precision superset**: its
//! most-significant `k` planes are the k-bit quantization of the same
//! weights with scales rescaled by [`view_scales`] (see
//! `bitmm::PlaneView`), so one stored weight serves every `k ≤ n`.

mod quantize;

pub use quantize::{
    dequantize, quant_error, quantize_bipolar_per_channel, quantize_bipolar_per_channel_packed,
    quantize_bipolar_per_tensor, quantize_bipolar_per_tensor_packed, quantize_signed_per_channel,
    view_scales, QuantError, Quantized, QuantizedPacked,
};

#[cfg(test)]
mod tests;
