use super::*;
use crate::bitfmt::{bipolar_qmax, IntFormat};
use crate::util::proptest::forall;
use crate::util::Rng;

fn randn(n: usize, seed: u64) -> Vec<f32> {
    let mut rng = Rng::with_seed(seed);
    (0..n).map(|_| rng.normal()).collect()
}

#[test]
fn error_bound_per_channel() {
    // RTN on the odd grid: |x − s·q| ≤ s per row
    let x = randn(8 * 32, 0);
    for bits in [2u32, 3, 4, 6] {
        let q = quantize_bipolar_per_channel(&x, 8, 32, bits);
        let xh = dequantize(&q, IntFormat::Bipolar);
        for r in 0..8 {
            let s = q.scales[r];
            for c in 0..32 {
                let d = (x[r * 32 + c] - xh[r * 32 + c]).abs();
                assert!(d <= s * 1.0001, "bits={bits} r={r} c={c} d={d} s={s}");
            }
        }
    }
}

#[test]
fn error_decreases_with_bits() {
    let x = randn(4 * 64, 1);
    let mut last = f64::INFINITY;
    for bits in [1u32, 2, 3, 4, 6, 8] {
        let q = quantize_bipolar_per_channel(&x, 4, 64, bits);
        let e = quant_error(&x, &dequantize(&q, IntFormat::Bipolar));
        assert!(e.mse <= last * 1.0001, "bits={bits}: {} > {last}", e.mse);
        last = e.mse;
    }
}

#[test]
fn one_bit_is_sign() {
    let x = vec![0.5f32, -0.25, 1.5, -2.0];
    let q = quantize_bipolar_per_tensor(&x, 1, 4, 1);
    let d = q.codes.decode(IntFormat::Bipolar);
    assert_eq!(d, vec![1, -1, 1, -1]);
}

#[test]
fn per_tensor_single_scale() {
    let x = randn(6 * 10, 2);
    let q = quantize_bipolar_per_tensor(&x, 6, 10, 3);
    assert_eq!(q.scales.len(), 1);
    assert_eq!(q.scale_for_row(5), q.scales[0]);
    let qc = quantize_bipolar_per_channel(&x, 6, 10, 3);
    assert_eq!(qc.scales.len(), 6);
}

#[test]
fn signed_baseline_in_range() {
    let x = randn(4 * 16, 3);
    let q = quantize_signed_per_channel(&x, 4, 16, 4);
    for &c in &q.codes.data {
        assert!(c < 16);
    }
    let xh = dequantize(&q, IntFormat::Signed);
    let e = quant_error(&x, &xh);
    assert!(e.rel_l2 < 0.2, "rel_l2={}", e.rel_l2);
}

#[test]
fn quant_error_zero_for_identical() {
    let x = randn(16, 4);
    let e = quant_error(&x, &x);
    assert_eq!(e.mse, 0.0);
    assert_eq!(e.max_abs, 0.0);
}

#[test]
fn packed_quantizer_matches_quantize_then_pack() {
    use crate::bitmm::{apmm_bipolar, apmm_bipolar_packed, pack_codes, ApmmOpts, CodeMatrix};
    let w = randn(8 * 48, 5);
    let q = quantize_bipolar_per_channel(&w, 8, 48, 3);
    let qp = quantize_bipolar_per_channel_packed(&w, 8, 48, 3);
    assert_eq!(qp.scales, q.scales);
    assert_eq!(qp.planes.raw(), pack_codes(&q.codes).raw());
    assert_eq!(qp.scale_for_row(7), q.scale_for_row(7));
    // and the packed form drives the kernel identically to the codes
    let mut rng = Rng::with_seed(6);
    let xt = CodeMatrix::random(4, 48, 2, rng.u64());
    let xp = pack_codes(&xt);
    assert_eq!(
        apmm_bipolar_packed(&qp.planes, &xp, ApmmOpts::default()),
        apmm_bipolar(&q.codes, &xt, ApmmOpts::default())
    );
    // prepack on a borrowed Quantized agrees with into_packed
    assert_eq!(q.prepack().planes.raw(), qp.planes.raw());
}

#[test]
fn prop_codes_in_range_and_odd() {
    forall(32, |rng| {
        let bits = rng.u32(1, 8);
        let x = randn(3 * 20, rng.u64());
        let q = quantize_bipolar_per_channel(&x, 3, 20, bits);
        let qmax = bipolar_qmax(bits);
        for v in q.codes.decode(IntFormat::Bipolar) {
            assert!(v.abs() <= qmax);
            assert_eq!(v.rem_euclid(2), 1);
        }
    });
}

#[test]
fn prop_negation_symmetry() {
    forall(32, |rng| {
        let bits = rng.u32(1, 8);
        // quantizing −x gives −q (same scale), modulo grid ties
        let x = randn(40, rng.u64());
        let xn: Vec<f32> = x.iter().map(|v| -v).collect();
        let q1 = quantize_bipolar_per_tensor(&x, 1, 40, bits);
        let q2 = quantize_bipolar_per_tensor(&xn, 1, 40, bits);
        assert!((q1.scales[0] - q2.scales[0]).abs() < 1e-6);
        let d1 = q1.codes.decode(IntFormat::Bipolar);
        let d2 = q2.codes.decode(IntFormat::Bipolar);
        let s = q1.scales[0];
        for i in 0..40 {
            // ties (x/s exactly even) may round either way: allow 2s slack there
            let diff = (d1[i] + d2[i]).abs();
            assert!(diff <= 2, "i={} d1={} d2={}", i, d1[i], d2[i]);
            if diff != 0 {
                let t = x[i] / s;
                assert!(
                    ((t - 1.0) / 2.0).fract().abs() < 1e-3 || ((t + 1.0) / 2.0).fract().abs() < 1e-3
                );
            }
        }
    });
}

#[test]
fn view_scales_make_the_superset_serve_lower_precisions() {
    use crate::bitmm::CodeMatrix;
    // an n-bit quantization viewed at k bits (codes >> (n−k), scales ×
    // 2^(n−k)) must reconstruct within the K-BIT quantization step: the
    // dropped planes contribute at most s·(2^(n−k)−1) < rescaled scale
    let (full, view) = (5u32, 2u32);
    let x = randn(6 * 40, 7);
    let q = quantize_bipolar_per_channel(&x, 6, 40, full);
    let vs = view_scales(&q.scales, full, view);
    for (r, (&s, &v)) in q.scales.iter().zip(&vs).enumerate() {
        assert!((v - s * 8.0).abs() < 1e-12, "row {r}: 2^(5−2) rescale");
    }
    let shifted: Vec<u32> = q.codes.data.iter().map(|&c| c >> (full - view)).collect();
    let trunc = Quantized { codes: CodeMatrix::new(6, 40, view, shifted), scales: vs };
    let xh = dequantize(&trunc, IntFormat::Bipolar);
    let xf = dequantize(&q, IntFormat::Bipolar);
    for r in 0..6 {
        let step = trunc.scales[r];
        for c in 0..40 {
            let d = (xf[r * 40 + c] - xh[r * 40 + c]).abs();
            assert!(d < step, "r={r} c={c}: residual {d} ≥ view step {step}");
        }
    }
    // degenerate and boundary cases
    assert_eq!(view_scales(&[0.5], 4, 4), vec![0.5]);
    assert_eq!(view_scales(&[0.5], 4, 1), vec![4.0]);
}

#[test]
#[should_panic(expected = "view bits")]
fn view_scales_reject_widening() {
    view_scales(&[1.0], 2, 3);
}
