//! # apllm — Arbitrary-Precision LLM Acceleration
//!
//! Reproduction of *"Efficient Arbitrary Precision Acceleration for Large
//! Language Models on GPU Tensor Cores"* (Ma, Fang, Shao, Wang — ASPDAC '25)
//! as a three-layer Rust + JAX + Pallas stack.
//!
//! Layer map (see `DESIGN.md`):
//!
//! * [`bitfmt`]   — the bipolar-INT data format (§3.1) plus the signed /
//!   unsigned baselines it is compared against.
//! * [`bitmm`]    — bit-wise MatMul reconstitution (§3.2): plane
//!   decomposition, packed XNOR-popcount 1-bit GEMM, shift-add recovery.
//! * [`quant`]    — symmetric bipolar quantizers (per-tensor / per-channel)
//!   and baseline quantizers.
//! * [`gpusim`]   — calibrated RTX 3090 tensor-core simulator: the
//!   substitute for the paper's testbed (§5), including CUTLASS / APNN-TC /
//!   BSTC / BTC baseline cost models and the §4.1/§4.2 ablation knobs.
//! * [`model`]    — LLM architecture tables (Llama2-7B, OPT-6.7B, BLOOM-7B)
//!   and per-layer MatMul shape extraction.
//! * [`runtime`]  — PJRT engine loading the AOT artifacts emitted by
//!   `python/compile/aot.py` (HLO text → compile → execute).
//! * [`coordinator`] — the serving layer: router, dynamic batcher, KV
//!   manager, scheduler, metrics.
//! * [`bench`]    — harness regenerating every table/figure of the paper's
//!   evaluation section.

pub mod bench;
pub mod bitfmt;
pub mod bitmm;
pub mod coordinator;
pub mod gpusim;
pub mod model;
pub mod quant;
pub mod runtime;
pub mod util;
