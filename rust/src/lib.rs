//! # apllm — Arbitrary-Precision LLM Acceleration
//!
//! Reproduction of *"Efficient Arbitrary Precision Acceleration for Large
//! Language Models on GPU Tensor Cores"* (Ma, Fang, Shao, Wang — ASPDAC '25)
//! as a three-layer Rust + JAX + Pallas stack.
//!
//! Layer map (see `DESIGN.md`):
//!
//! * [`bitfmt`]   — the bipolar-INT data format (§3.1) plus the signed /
//!   unsigned baselines it is compared against.
//! * [`bitmm`]    — bit-wise MatMul reconstitution (§3.2) around a
//!   **prepacked kernel ABI**: every `apmm_*_packed` core consumes the
//!   [`bitmm::Planes`] operand — a full [`bitmm::PackedPlanes`] or a
//!   zero-copy [`bitmm::PlaneView`] slicing the most-significant `k`
//!   planes out of a packed superset (one n-bit weight serves every
//!   `k ≤ n`, the Any-Precision trick); `CodeMatrix` is a
//!   construction-time artifact packed **once** via [`bitmm::prepack`]
//!   (weight `PlaneCache` / `PackedWeightStore` with `get_at`
//!   precision slicing, activation `PackArena` — the paper's §3.3
//!   preprocessing + §3.4 recovery-oriented memory management, realized
//!   on the CPU substrate).  The packed cores shard across a persistent
//!   worker pool ([`util::par::WorkerPool`]) along a
//!   [`bitmm::ShardPolicy`]-selected axis — output row blocks, output
//!   columns, or independent bit-plane pairs recombined by shifted add —
//!   every policy bit-identical to the serial kernel.
//! * [`quant`]    — symmetric bipolar quantizers (per-tensor / per-channel)
//!   and baseline quantizers; weight quantizers can emit prepacked planes
//!   directly (`quantize_*_packed`, `Quantized::prepack`).
//! * [`gpusim`]   — calibrated RTX 3090 tensor-core simulator: the
//!   substitute for the paper's testbed (§5), including CUTLASS / APNN-TC /
//!   BSTC / BTC baseline cost models, the §4.1/§4.2 ablation knobs and the
//!   §3.3 `prepacked` knob (pack-once vs on-the-fly operand layout).
//! * [`model`]    — LLM architecture tables (Llama2-7B, OPT-6.7B, BLOOM-7B)
//!   and per-layer MatMul shape extraction (incl. packed-operand footprints).
//! * [`runtime`]  — PJRT engine loading the AOT artifacts emitted by
//!   `python/compile/aot.py` (HLO text → compile → execute).  The engine
//!   itself is gated behind the `pjrt` cargo feature; manifest parsing is
//!   always available.
//! * [`coordinator`] — the serving layer: a **multi-replica cluster**
//!   (`coordinator::cluster`) of continuous-batching engine replicas —
//!   each with its own KV pool and batcher, all serving their own W/A
//!   precision out of **one shared superset weight store** (packed once
//!   at the widest precision; no per-precision duplication) — behind a
//!   routing policy (round-robin / least-loaded, with per-request
//!   precision pinning).  Topologies are declared as a
//!   `ClusterSpec` of `ReplicaSpec`s and built in one `Cluster::new`
//!   call; **replica roles** make prefill/decode disaggregation
//!   first-class — `Prefill` replicas admit and prefill, then hand each
//!   freshly prefilled sequence (`TokenEvent::PrefillDone`) to the
//!   decode-capable peer `Engine::import_fit` admits, `Decode` replicas
//!   are fed exclusively by migration, and all-`Mixed` is the symmetric
//!   baseline, byte-for-byte.  Admission is a per-engine policy switch
//!   (`EngineConfig::admission`): `Optimistic` books only the prompt and
//!   grows per token, swap-preempting under KV pressure; `Reserve` books
//!   the full `prompt + max_new` budget up front and never preempts —
//!   the retired group scheduler's semantics, folded into the one
//!   serving engine.  The cluster also does **preemptive
//!   rebalancing**: swapped
//!   sequences an overloaded replica cannot resume migrate to
//!   same-precision peers and continue byte-identically, or — unpinned,
//!   with no same-precision escape — **across the precision boundary**:
//!   the KV is dropped and the target re-prefills prompt + generated
//!   tokens at its own precision (`TokenEvent::Requantized`), streamed
//!   bytes unchanged.  The KV allocator uses **refcounted
//!   copy-on-write blocks with a hash-based prefix cache** (shared
//!   prompt prefixes share physical blocks) over an **O(1) intrusive
//!   free list in LRU eviction order** (hot prefix content outlives cold
//!   under pressure), and delivery is **streaming**: every token is a
//!   `TokenEvent`, so TTFT/ITL land in `metrics` as real per-token
//!   measurements.  Its `SimBackend` serves real bitmm logits through
//!   the pack-once pipeline (`SimBackend::with_ap_gemm`), sharded
//!   across the worker pool on the hot path; `EngineConfig::workers`
//!   and `ClusterSpec::worker_budget` size the per-replica GEMM
//!   parallelism so N replicas never oversubscribe the host.  The
//!   engine can **self-speculate** (`EngineConfig::spec_k`): draft
//!   tokens from a low-bit plane prefix of the same superset pack and
//!   verify them in one wide batched decode — streams stay
//!   byte-identical to plain decode while accepted drafts cut decode
//!   steps (the Any-Precision store doubling as its own draft model).
//! * [`bench`]    — harness regenerating every table/figure of the paper's
//!   evaluation section, plus the §3.3 pack-vs-compute split table.
//! * [`anyhow`]   — in-tree error-handling substrate (offline substitute
//!   for the `anyhow` crate; see `util` for the other substrates).
//!
//! ## Concurrency & unsafety
//!
//! All threading in the crate funnels through [`util::par`]: a persistent
//! [`util::par::WorkerPool`] per size, driven by an epoch-counted
//! submit/drain protocol (epochs are monotonic; a worker runs each epoch's
//! job exactly once; the submitter participates as worker 0, so a pool can
//! never deadlock on its own submitter).  Raw-pointer sharing is confined
//! to [`util::par::SendPtr`], whose contract — every job writes a disjoint
//! region, reads happen only after the epoch handshake — is documented at
//! each site with a `// SAFETY:` comment.
//!
//! `unsafe` is **deny-by-default** across the workspace and re-allowed
//! only in three audited modules: `util::par`, `bitmm::apmm`,
//! `bitmm::planes`.  The boundary is machine-checked from three sides:
//!
//! * `cargo run -p xtask -- lint` — repo-local static analysis enforcing
//!   the allowlist, `// SAFETY:` adjacency, kernel narrowing-cast hygiene
//!   and the no-raw-`thread::spawn` rule;
//! * a **loom-style model checker** ([`util::loom`], in-tree, zero deps)
//!   that exhaustively explores WorkerPool schedules when built with
//!   `RUSTFLAGS="--cfg loom"`;
//! * **Miri** and **ThreadSanitizer** CI lanes replaying the pool and
//!   kernel suites under provenance and data-race instrumentation
//!   (`tests/miri_suite.rs`).
//!
//! See the `util::par` module docs for the full protocol invariants.

pub mod anyhow;
pub mod bench;
pub mod bitfmt;
pub mod bitmm;
pub mod coordinator;
pub mod gpusim;
pub mod model;
pub mod quant;
pub mod runtime;
pub mod util;
