//! The bipolar-INT data format (paper §3.1) and the integer formats it is
//! compared against.
//!
//! An `n`-bit **bipolar-INT** word `x = x_{n-1} … x_0` decodes as
//!
//! ```text
//! (x)_D = Σ_i (2·x_i − 1) · 2^i
//! ```
//!
//! so every bit is ±1 weighted by a power of two.  The representable set is
//! the `2^n` **odd** integers in `[-(2^n−1), 2^n−1]` — symmetric around
//! zero, with no zero-point and no special-cased sign bit.  That uniformity
//! is the property the whole kernel rides on: every bit plane participates
//! in the 1-bit GEMM + recovery with the *same* sign rule, unlike
//! two's-complement (negative MSB plane) or unsigned (zero-point correction
//! term).

mod formats;

pub use formats::{
    bipolar_decode, bipolar_encode, bipolar_qmax, plane_weight, signed_decode, signed_range,
    unsigned_decode, IntFormat,
};

#[cfg(test)]
mod tests;
