//! Encode/decode and per-plane sign rules for the three integer formats.

/// Integer interpretation of an n-bit code (paper Fig. 1).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum IntFormat {
    /// Every bit is ±1 weighted by 2^i (the paper's format).
    Bipolar,
    /// Two's-complement: MSB weighted −2^{n−1}, others +2^i.
    Signed,
    /// Plain binary with an external zero-point.
    Unsigned,
}

impl IntFormat {
    /// Human-readable name used in bench output.
    pub fn name(self) -> &'static str {
        match self {
            IntFormat::Bipolar => "bipolar-INT",
            IntFormat::Signed => "signed (two's-complement)",
            IntFormat::Unsigned => "unsigned (+zero-point)",
        }
    }

    /// Does plane `i` of an `bits`-wide word need sign-flipping during
    /// recovery?  This is the structural defect of two's-complement the
    /// paper calls out: its MSB plane carries the opposite sign, forcing a
    /// special case in the otherwise-uniform recovery loop.
    pub fn plane_negative(self, i: u32, bits: u32) -> bool {
        matches!(self, IntFormat::Signed) && i + 1 == bits
    }

    /// Number of extra correction GEMMs the format drags through the
    /// pipeline (paper §3.1: unsigned needs the all-ones `J` matrix terms).
    pub fn correction_gemms(self) -> u32 {
        match self {
            IntFormat::Bipolar => 0,
            IntFormat::Signed => 0,
            IntFormat::Unsigned => 2, // J·X and W·J zero-point terms
        }
    }
}

/// Largest magnitude representable by an n-bit bipolar-INT: `2^n − 1`.
#[inline]
pub fn bipolar_qmax(bits: u32) -> i32 {
    assert!((1..=16).contains(&bits), "bits must be in 1..=16");
    (1i32 << bits) - 1
}

/// Odd integer value → unsigned n-bit code: `code = (v + qmax) / 2`.
#[inline]
pub fn bipolar_encode(v: i32, bits: u32) -> u32 {
    let qmax = bipolar_qmax(bits);
    debug_assert!(v.abs() <= qmax && v.rem_euclid(2) == 1, "v={v} not an odd value in range");
    ((v + qmax) / 2) as u32
}

/// Unsigned n-bit code → odd integer value: `v = 2·code − qmax` (Eq. 1).
#[inline]
pub fn bipolar_decode(code: u32, bits: u32) -> i32 {
    debug_assert!(code < (1 << bits));
    2 * code as i32 - bipolar_qmax(bits)
}

/// Signed (two's-complement) decode of an n-bit code.
#[inline]
pub fn signed_decode(code: u32, bits: u32) -> i32 {
    debug_assert!(code < (1u32 << bits));
    let sign_bit = 1u32 << (bits - 1);
    if code & sign_bit != 0 {
        code as i32 - (1i32 << bits)
    } else {
        code as i32
    }
}

/// Unsigned decode (value == code).
#[inline]
pub fn unsigned_decode(code: u32, _bits: u32) -> i32 {
    code as i32
}

/// Representable range of an n-bit signed integer.
pub fn signed_range(bits: u32) -> (i32, i32) {
    (-(1i32 << (bits - 1)), (1i32 << (bits - 1)) - 1)
}

/// Recovery weight of plane `i` under `fmt`: the scalar the plane's 1-bit
/// GEMM result is multiplied by during reconstruction.
pub fn plane_weight(fmt: IntFormat, i: u32, bits: u32) -> i64 {
    let w = 1i64 << i;
    if fmt.plane_negative(i, bits) {
        -w
    } else {
        w
    }
}
