use super::*;
use crate::util::proptest::forall;

#[test]
fn qmax_values() {
    assert_eq!(bipolar_qmax(1), 1);
    assert_eq!(bipolar_qmax(2), 3);
    assert_eq!(bipolar_qmax(3), 7);
    assert_eq!(bipolar_qmax(8), 255);
}

#[test]
fn bipolar_roundtrip_all_values() {
    for bits in 1..=8u32 {
        let qmax = bipolar_qmax(bits);
        let mut seen = std::collections::HashSet::new();
        let mut v = -qmax;
        while v <= qmax {
            let code = bipolar_encode(v, bits);
            assert!(code < (1 << bits));
            assert_eq!(bipolar_decode(code, bits), v);
            seen.insert(code);
            v += 2;
        }
        assert_eq!(seen.len(), 1 << bits, "codes must be a bijection");
    }
}

#[test]
fn bipolar_is_symmetric() {
    for bits in 1..=8u32 {
        let qmax = bipolar_qmax(bits);
        let mut v = 1;
        while v <= qmax {
            // negating a value flips all code bits
            let c = bipolar_encode(v, bits);
            let cn = bipolar_encode(-v, bits);
            assert_eq!(c ^ cn, (1 << bits) - 1, "bits={bits} v={v}");
            v += 2;
        }
    }
}

#[test]
fn bipolar_plane_identity() {
    // (x)_D == Σ_i (2 x_i − 1) 2^i for every code
    for bits in 1..=6u32 {
        for code in 0..(1u32 << bits) {
            let mut acc = 0i32;
            for i in 0..bits {
                let bit = ((code >> i) & 1) as i32;
                acc += (2 * bit - 1) << i;
            }
            assert_eq!(acc, bipolar_decode(code, bits));
        }
    }
}

#[test]
fn signed_decode_matches_twos_complement() {
    assert_eq!(signed_decode(0b111, 3), -1);
    assert_eq!(signed_decode(0b100, 3), -4);
    assert_eq!(signed_decode(0b011, 3), 3);
    assert_eq!(signed_range(3), (-4, 3));
}

#[test]
fn plane_signs() {
    // only the signed MSB plane is negative
    assert!(!IntFormat::Bipolar.plane_negative(3, 4));
    assert!(IntFormat::Signed.plane_negative(3, 4));
    assert!(!IntFormat::Signed.plane_negative(2, 4));
    assert!(!IntFormat::Unsigned.plane_negative(3, 4));
    assert_eq!(plane_weight(IntFormat::Signed, 3, 4), -8);
    assert_eq!(plane_weight(IntFormat::Bipolar, 3, 4), 8);
}

#[test]
fn correction_cost() {
    assert_eq!(IntFormat::Bipolar.correction_gemms(), 0);
    assert_eq!(IntFormat::Unsigned.correction_gemms(), 2);
}

#[test]
fn signed_plane_identity() {
    // v == Σ_i plane_weight(i) · bit_i for two's complement
    for bits in 2..=6u32 {
        for code in 0..(1u32 << bits) {
            let mut acc = 0i64;
            for i in 0..bits {
                acc += plane_weight(IntFormat::Signed, i, bits) * ((code >> i) & 1) as i64;
            }
            assert_eq!(acc, signed_decode(code, bits) as i64);
        }
    }
}

#[test]
fn prop_bipolar_roundtrip() {
    forall(256, |rng| {
        let bits = rng.u32(1, 13);
        let code = rng.u32(0, 1 << bits);
        let v = bipolar_decode(code, bits);
        assert_eq!(v.rem_euclid(2), 1, "decoded values are odd");
        assert!(v.abs() <= bipolar_qmax(bits));
        assert_eq!(bipolar_encode(v, bits), code);
    });
}

#[test]
fn prop_decode_monotone() {
    forall(256, |rng| {
        let bits = rng.u32(1, 13);
        let a = rng.u32(0, 1 << bits);
        let b = rng.u32(0, 1 << bits);
        if a < b {
            assert!(bipolar_decode(a, bits) < bipolar_decode(b, bits));
        }
    });
}
