//! Minimal JSON parser + writer (the serde_json substitute).
//!
//! Supports the full JSON grammar the artifact manifest uses: objects,
//! arrays, strings (with escapes), numbers, booleans, null.  Numbers are
//! kept as `f64` (the manifest's integers are all < 2^53).

use std::collections::BTreeMap;
use std::fmt;

/// A parsed JSON value.
#[derive(Debug, Clone, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(BTreeMap<String, Json>),
}

impl Json {
    pub fn parse(src: &str) -> Result<Json, String> {
        let mut p = Parser { b: src.as_bytes(), i: 0 };
        p.skip_ws();
        let v = p.value()?;
        p.skip_ws();
        if p.i != p.b.len() {
            return Err(format!("trailing garbage at byte {}", p.i));
        }
        Ok(v)
    }

    // -- typed accessors (None on type/shape mismatch) --

    pub fn get(&self, key: &str) -> Option<&Json> {
        match self {
            Json::Obj(m) => m.get(key),
            _ => None,
        }
    }

    pub fn idx(&self, i: usize) -> Option<&Json> {
        match self {
            Json::Arr(v) => v.get(i),
            _ => None,
        }
    }

    pub fn as_arr(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(v) => Some(v),
            _ => None,
        }
    }

    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }

    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(n) => Some(*n),
            _ => None,
        }
    }

    pub fn as_usize(&self) -> Option<usize> {
        self.as_f64().filter(|n| *n >= 0.0 && n.fract() == 0.0).map(|n| n as usize)
    }

    pub fn as_bool(&self) -> Option<bool> {
        match self {
            Json::Bool(b) => Some(*b),
            _ => None,
        }
    }

    pub fn is_null(&self) -> bool {
        matches!(self, Json::Null)
    }
}

struct Parser<'a> {
    b: &'a [u8],
    i: usize,
}

impl<'a> Parser<'a> {
    fn skip_ws(&mut self) {
        while self.i < self.b.len() && matches!(self.b[self.i], b' ' | b'\t' | b'\n' | b'\r') {
            self.i += 1;
        }
    }

    fn peek(&self) -> Option<u8> {
        self.b.get(self.i).copied()
    }

    fn expect(&mut self, c: u8) -> Result<(), String> {
        if self.peek() == Some(c) {
            self.i += 1;
            Ok(())
        } else {
            Err(format!("expected '{}' at byte {}", c as char, self.i))
        }
    }

    fn value(&mut self) -> Result<Json, String> {
        self.skip_ws();
        match self.peek().ok_or("unexpected end of input")? {
            b'{' => self.object(),
            b'[' => self.array(),
            b'"' => Ok(Json::Str(self.string()?)),
            b't' => self.lit("true", Json::Bool(true)),
            b'f' => self.lit("false", Json::Bool(false)),
            b'n' => self.lit("null", Json::Null),
            b'-' | b'0'..=b'9' => self.number(),
            c => Err(format!("unexpected byte '{}' at {}", c as char, self.i)),
        }
    }

    fn lit(&mut self, word: &str, v: Json) -> Result<Json, String> {
        if self.b[self.i..].starts_with(word.as_bytes()) {
            self.i += word.len();
            Ok(v)
        } else {
            Err(format!("bad literal at byte {}", self.i))
        }
    }

    fn object(&mut self) -> Result<Json, String> {
        self.expect(b'{')?;
        let mut m = BTreeMap::new();
        self.skip_ws();
        if self.peek() == Some(b'}') {
            self.i += 1;
            return Ok(Json::Obj(m));
        }
        loop {
            self.skip_ws();
            let k = self.string()?;
            self.skip_ws();
            self.expect(b':')?;
            let v = self.value()?;
            m.insert(k, v);
            self.skip_ws();
            match self.peek() {
                Some(b',') => self.i += 1,
                Some(b'}') => {
                    self.i += 1;
                    return Ok(Json::Obj(m));
                }
                _ => return Err(format!("expected ',' or '}}' at byte {}", self.i)),
            }
        }
    }

    fn array(&mut self) -> Result<Json, String> {
        self.expect(b'[')?;
        let mut v = Vec::new();
        self.skip_ws();
        if self.peek() == Some(b']') {
            self.i += 1;
            return Ok(Json::Arr(v));
        }
        loop {
            v.push(self.value()?);
            self.skip_ws();
            match self.peek() {
                Some(b',') => self.i += 1,
                Some(b']') => {
                    self.i += 1;
                    return Ok(Json::Arr(v));
                }
                _ => return Err(format!("expected ',' or ']' at byte {}", self.i)),
            }
        }
    }

    fn string(&mut self) -> Result<String, String> {
        self.expect(b'"')?;
        let mut s = String::new();
        loop {
            match self.peek().ok_or("unterminated string")? {
                b'"' => {
                    self.i += 1;
                    return Ok(s);
                }
                b'\\' => {
                    self.i += 1;
                    let e = self.peek().ok_or("unterminated escape")?;
                    self.i += 1;
                    match e {
                        b'"' => s.push('"'),
                        b'\\' => s.push('\\'),
                        b'/' => s.push('/'),
                        b'n' => s.push('\n'),
                        b't' => s.push('\t'),
                        b'r' => s.push('\r'),
                        b'b' => s.push('\u{8}'),
                        b'f' => s.push('\u{c}'),
                        b'u' => {
                            if self.i + 4 > self.b.len() {
                                return Err("bad \\u escape".into());
                            }
                            let hex = std::str::from_utf8(&self.b[self.i..self.i + 4])
                                .map_err(|_| "bad \\u escape")?;
                            let cp = u32::from_str_radix(hex, 16).map_err(|_| "bad \\u escape")?;
                            self.i += 4;
                            s.push(char::from_u32(cp).unwrap_or('\u{FFFD}'));
                        }
                        c => return Err(format!("bad escape '\\{}'", c as char)),
                    }
                }
                _ => {
                    // consume one UTF-8 scalar
                    let start = self.i;
                    self.i += 1;
                    while self.i < self.b.len() && (self.b[self.i] & 0xC0) == 0x80 {
                        self.i += 1;
                    }
                    s.push_str(
                        std::str::from_utf8(&self.b[start..self.i]).map_err(|e| e.to_string())?,
                    );
                }
            }
        }
    }

    fn number(&mut self) -> Result<Json, String> {
        let start = self.i;
        if self.peek() == Some(b'-') {
            self.i += 1;
        }
        while self
            .peek()
            .map(|c| c.is_ascii_digit() || matches!(c, b'.' | b'e' | b'E' | b'+' | b'-'))
            .unwrap_or(false)
        {
            self.i += 1;
        }
        std::str::from_utf8(&self.b[start..self.i])
            .ok()
            .and_then(|s| s.parse().ok())
            .map(Json::Num)
            .ok_or_else(|| format!("bad number at byte {start}"))
    }
}

impl fmt::Display for Json {
    /// Compact JSON serialization.
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            Json::Null => write!(f, "null"),
            Json::Bool(b) => write!(f, "{b}"),
            Json::Num(n) => {
                if n.fract() == 0.0 && n.abs() < 9e15 {
                    write!(f, "{}", *n as i64)
                } else {
                    write!(f, "{n}")
                }
            }
            Json::Str(s) => {
                write!(f, "\"")?;
                for c in s.chars() {
                    match c {
                        '"' => write!(f, "\\\"")?,
                        '\\' => write!(f, "\\\\")?,
                        '\n' => write!(f, "\\n")?,
                        '\t' => write!(f, "\\t")?,
                        '\r' => write!(f, "\\r")?,
                        c if (c as u32) < 0x20 => write!(f, "\\u{:04x}", c as u32)?,
                        c => write!(f, "{c}")?,
                    }
                }
                write!(f, "\"")
            }
            Json::Arr(v) => {
                write!(f, "[")?;
                for (i, x) in v.iter().enumerate() {
                    if i > 0 {
                        write!(f, ",")?;
                    }
                    write!(f, "{x}")?;
                }
                write!(f, "]")
            }
            Json::Obj(m) => {
                write!(f, "{{")?;
                for (i, (k, v)) in m.iter().enumerate() {
                    if i > 0 {
                        write!(f, ",")?;
                    }
                    write!(f, "{}:{}", Json::Str(k.clone()), v)?;
                }
                write!(f, "}}")
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_manifest_like() {
        let src = r#"{"version": 1, "model": null, "executables": [
            {"name": "a", "meta": {"m": 64, "nw": 2}, "inputs": [{"shape": [2, 64, 8]}]}
        ]}"#;
        let j = Json::parse(src).unwrap();
        assert_eq!(j.get("version").unwrap().as_usize(), Some(1));
        assert!(j.get("model").unwrap().is_null());
        let exe = j.get("executables").unwrap().idx(0).unwrap();
        assert_eq!(exe.get("name").unwrap().as_str(), Some("a"));
        let shape = exe.get("inputs").unwrap().idx(0).unwrap().get("shape").unwrap();
        let dims: Vec<usize> = shape.as_arr().unwrap().iter().map(|d| d.as_usize().unwrap()).collect();
        assert_eq!(dims, vec![2, 64, 8]);
    }

    #[test]
    fn roundtrip() {
        let src = r#"{"a":[1,2.5,-3],"b":"x\"y\n","c":true,"d":null,"e":{}}"#;
        let j = Json::parse(src).unwrap();
        let j2 = Json::parse(&j.to_string()).unwrap();
        assert_eq!(j, j2);
    }

    #[test]
    fn rejects_garbage() {
        assert!(Json::parse("{").is_err());
        assert!(Json::parse("[1,]").is_err());
        assert!(Json::parse("1 2").is_err());
        assert!(Json::parse("'single'").is_err());
        assert!(Json::parse("").is_err());
    }

    #[test]
    fn unicode_and_escapes() {
        let j = Json::parse(r#""café ☕""#).unwrap();
        assert_eq!(j.as_str(), Some("café ☕"));
    }

    #[test]
    fn numbers() {
        assert_eq!(Json::parse("-0.5e2").unwrap().as_f64(), Some(-50.0));
        assert_eq!(Json::parse("1750016").unwrap().as_usize(), Some(1750016));
        assert_eq!(Json::parse("1.5").unwrap().as_usize(), None);
        assert_eq!(Json::parse("-3").unwrap().as_usize(), None);
    }
}
