//! In-tree model checker for the concurrency substrate (the `loom`
//! substitute — the build is fully offline, see Cargo.toml).
//!
//! [`model`] / [`Builder::check`] run a closure many times, exploring the
//! distinct thread interleavings of every [`sync`] primitive it touches.
//! Execution is *serialized*: model threads are real OS threads, but a
//! cooperative scheduler grants exactly one of them the token at a time,
//! and every visible operation (mutex acquire, condvar wait/notify,
//! atomic access, spawn) is a *decision point* where the scheduler picks
//! which runnable thread continues.  A depth-first search over those
//! decisions replays the closure once per distinct schedule, so the test
//! body re-runs deterministically under each interleaving.
//!
//! What it checks:
//!
//! * **assertion failures** in the model body, under every explored
//!   schedule (reported with the schedule trace that triggered them);
//! * **deadlocks** — a state where no thread is runnable (all blocked on
//!   mutexes / condvars / joins) is reported, not hung;
//! * **panics** on spawned model threads (reported with the trace).
//!
//! Known limitations, by design (this is a bounded checker, not a proof):
//!
//! * **SC memory model only.** Atomics execute with `SeqCst` semantics
//!   regardless of the `Ordering` requested; weak-memory reorderings are
//!   *not* explored.  The Miri and ThreadSanitizer CI lanes complement
//!   this (they run the real orderings).
//! * **Preemption bounding.** Unforced context switches are limited to
//!   [`Builder::preemption_bound`] per schedule (CHESS-style); voluntary
//!   blocking switches are always free.  Most concurrency bugs manifest
//!   within two preemptions.
//! * `notify_one` deterministically wakes the lowest-tid waiter.
//!
//! Outside a model (no [`Builder::check`] on the call stack) every
//! [`sync`] primitive delegates straight to `std`, so a `--cfg loom`
//! build still runs the regular test suite unchanged; only tests that
//! enter [`model`] pay for exploration.

use std::cell::RefCell;
use std::sync::atomic::{AtomicBool as StdAtomicBool, AtomicU64 as StdAtomicU64};
use std::sync::atomic::{AtomicUsize as StdAtomicUsize, Ordering};
use std::sync::{Arc, Condvar as StdCondvar, Mutex as StdMutex, MutexGuard as StdMutexGuard};

// ---------------------------------------------------------------------------
// Scheduler runtime
// ---------------------------------------------------------------------------

/// Why a thread is not currently eligible to run.
#[derive(Clone, Copy, PartialEq, Eq, Debug)]
enum Run {
    /// Eligible; waiting only for the scheduler to grant the token.
    Runnable,
    /// Blocked acquiring the model mutex at this address.
    Mutex(usize),
    /// Waiting on the model condvar at this address.
    Cond(usize),
    /// Waiting for this tid to finish.
    Join(usize),
    Finished,
}

struct Th {
    run: Run,
}

/// One recorded scheduling decision: which of `options` (thread ids,
/// ascending) was granted.  Replayed verbatim up to the DFS frontier.
struct Decision {
    chosen: usize,
    options: Vec<usize>,
}

struct Cfg {
    preemption_bound: Option<usize>,
    max_depth: usize,
    max_threads: usize,
}

struct St {
    threads: Vec<Th>,
    /// The thread holding the execution token, if any.
    current: Option<usize>,
    /// The previously scheduled thread (for preemption accounting).
    last: Option<usize>,
    /// Spawned threads that have not yet parked at their initial yield;
    /// scheduling is deferred until they register (keeps replay
    /// deterministic regardless of OS spawn latency).
    pending_start: usize,
    depth: usize,
    preemptions: usize,
    decisions: Vec<Decision>,
    trace: Vec<usize>,
    failure: Option<String>,
    cfg: Cfg,
}

struct Rt {
    mx: StdMutex<St>,
    cv: StdCondvar,
}

#[derive(Clone)]
struct Ctx {
    rt: Arc<Rt>,
    tid: usize,
}

thread_local! {
    static CTX: RefCell<Option<Ctx>> = const { RefCell::new(None) };
}

fn ctx() -> Option<Ctx> {
    CTX.with(|c| c.borrow().clone())
}

fn lock_rt(rt: &Rt) -> StdMutexGuard<'_, St> {
    // Poison-tolerant: a failing schedule panics on the test thread and
    // may poison `mx`; leaked threads must still be able to observe the
    // failure flag instead of double-panicking.
    rt.mx.lock().unwrap_or_else(|e| e.into_inner())
}

fn describe_threads(st: &St) -> String {
    st.threads
        .iter()
        .enumerate()
        .map(|(i, t)| format!("t{i}:{:?}", t.run))
        .collect::<Vec<_>>()
        .join(" ")
}

fn fail(st: &mut St, msg: String) {
    if st.failure.is_none() {
        st.failure = Some(format!(
            "{msg}\n  threads: [{}]\n  schedule: {:?}",
            describe_threads(st),
            st.trace
        ));
    }
}

/// Pick the next thread to run.  No-op unless the token is free and all
/// spawned threads have registered.  Every call that grants is a recorded
/// decision (even forced, single-option ones — keeps replay depths
/// aligned across schedules).
fn maybe_schedule(st: &mut St) {
    if st.failure.is_some() || st.current.is_some() || st.pending_start > 0 {
        return;
    }
    if st.threads.iter().all(|t| t.run == Run::Finished) {
        return;
    }
    let mut cands: Vec<usize> = st
        .threads
        .iter()
        .enumerate()
        .filter(|(_, t)| t.run == Run::Runnable)
        .map(|(i, _)| i)
        .collect();
    if cands.is_empty() {
        fail(st, "deadlock: no runnable thread".to_string());
        return;
    }
    if let (Some(bound), Some(last)) = (st.cfg.preemption_bound, st.last) {
        if st.preemptions >= bound && cands.contains(&last) {
            // Budget spent: the previously running thread must continue.
            cands = vec![last];
        }
    }
    let d = st.depth;
    st.depth += 1;
    if st.depth > st.cfg.max_depth {
        fail(st, format!("schedule depth exceeded max_depth ({})", st.cfg.max_depth));
        return;
    }
    let idx = if d < st.decisions.len() {
        if st.decisions[d].options != cands {
            fail(
                st,
                format!(
                    "nondeterministic execution: replay expected options {:?}, got {:?}",
                    st.decisions[d].options, cands
                ),
            );
            return;
        }
        st.decisions[d].chosen
    } else {
        st.decisions.push(Decision { chosen: 0, options: cands.clone() });
        0
    };
    let tid = cands[idx];
    if let Some(last) = st.last {
        if tid != last && st.threads[last].run == Run::Runnable {
            st.preemptions += 1;
        }
    }
    st.last = Some(tid);
    st.current = Some(tid);
    st.trace.push(tid);
}

/// Park until the scheduler grants `me` the token.  On a model failure:
/// the checker thread (tid 0) panics with the report; any other thread
/// parks forever (it is leaked — waking it to unwind through whatever
/// model state it holds could only cascade).
fn wait_for_token<'a>(
    rt: &'a Rt,
    mut st: StdMutexGuard<'a, St>,
    me: usize,
) -> StdMutexGuard<'a, St> {
    loop {
        if let Some(f) = st.failure.clone() {
            if me == 0 {
                drop(st);
                panic!("loom model failed: {f}");
            }
            loop {
                st = rt.cv.wait(st).unwrap_or_else(|e| e.into_inner());
            }
        }
        if st.current == Some(me) {
            return st;
        }
        st = rt.cv.wait(st).unwrap_or_else(|e| e.into_inner());
    }
}

/// A decision point: release the token (staying runnable) and wait to be
/// rescheduled.  Called before every visible operation.
fn yield_point(c: &Ctx) {
    let mut st = lock_rt(&c.rt);
    if st.failure.is_some() {
        drop(st);
        // Re-enter the park path so failure handling stays in one place.
        let st2 = lock_rt(&c.rt);
        let _ = wait_for_token(&c.rt, st2, c.tid);
        return;
    }
    debug_assert_eq!(st.current, Some(c.tid), "yield from a thread without the token");
    st.current = None;
    maybe_schedule(&mut st);
    c.rt.cv.notify_all();
    let st = wait_for_token(&c.rt, st, c.tid);
    drop(st);
}

/// Block `me` in state `why` and wait until some event flips it back to
/// `Runnable` *and* the scheduler grants the token.
fn block_on(c: &Ctx, why: Run) {
    let mut st = lock_rt(&c.rt);
    st.threads[c.tid].run = why;
    st.current = None;
    maybe_schedule(&mut st);
    c.rt.cv.notify_all();
    let st = wait_for_token(&c.rt, st, c.tid);
    drop(st);
}

/// Flip every thread blocked in state `from` back to runnable.  Does not
/// reschedule — the caller still holds the token.
fn wake_matching(st: &mut St, from: Run) {
    for t in st.threads.iter_mut() {
        if t.run == from {
            t.run = Run::Runnable;
        }
    }
}

fn finish_thread(c: &Ctx, panic_msg: Option<String>) {
    let mut st = lock_rt(&c.rt);
    if let Some(msg) = panic_msg {
        fail(&mut st, format!("model thread t{} panicked: {msg}", c.tid));
    }
    st.threads[c.tid].run = Run::Finished;
    wake_matching(&mut st, Run::Join(c.tid));
    if st.current == Some(c.tid) {
        st.current = None;
    }
    maybe_schedule(&mut st);
    c.rt.cv.notify_all();
}

fn panic_payload_to_string(e: &(dyn std::any::Any + Send)) -> String {
    if let Some(s) = e.downcast_ref::<&str>() {
        (*s).to_string()
    } else if let Some(s) = e.downcast_ref::<String>() {
        s.clone()
    } else {
        "<non-string panic payload>".to_string()
    }
}

// ---------------------------------------------------------------------------
// Builder / exploration driver
// ---------------------------------------------------------------------------

/// Exploration knobs.  `Default` is sized for protocol-scale models
/// (a pool of two, a couple of jobs): bound 2 preemptions, cap the
/// search at 50k schedules.
pub struct Builder {
    /// Max unforced context switches per schedule (`None` = unbounded —
    /// expect exponential blowup on anything non-trivial).
    pub preemption_bound: Option<usize>,
    /// Stop exploring (with a stderr warning) after this many schedules.
    pub max_schedules: usize,
    /// Fail any schedule exceeding this many decision points.
    pub max_depth: usize,
    /// Fail a schedule that spawns more than this many threads.
    pub max_threads: usize,
}

impl Default for Builder {
    fn default() -> Self {
        Self { preemption_bound: Some(2), max_schedules: 50_000, max_depth: 20_000, max_threads: 8 }
    }
}

impl Builder {
    /// Explore `f` under every schedule within the bounds; panics (with
    /// the offending schedule trace) on the first failing one.  Returns
    /// the number of schedules explored.
    pub fn check<F: Fn() + Send + Sync + 'static>(&self, f: F) -> usize {
        assert!(ctx().is_none(), "nested loom models are not supported");
        let mut prefix: Vec<Decision> = Vec::new();
        let mut schedules = 0usize;
        loop {
            schedules += 1;
            let decisions = self.explore_one(&f, prefix);
            // DFS advance: increment the deepest decision with an
            // untried option, drop everything after it.
            let mut next = None;
            for (i, d) in decisions.iter().enumerate().rev() {
                if d.chosen + 1 < d.options.len() {
                    next = Some(i);
                    break;
                }
            }
            match next {
                None => return schedules,
                Some(i) => {
                    let mut pre: Vec<Decision> = decisions.into_iter().take(i + 1).collect();
                    pre[i].chosen += 1;
                    prefix = pre;
                }
            }
            if schedules >= self.max_schedules {
                eprintln!(
                    "loom: warning: stopping after {schedules} schedules \
                     (max_schedules); exploration is incomplete"
                );
                return schedules;
            }
        }
    }

    /// Run one schedule, replaying `prefix`; returns the decision log.
    fn explore_one<F: Fn() + Send + Sync>(&self, f: &F, prefix: Vec<Decision>) -> Vec<Decision> {
        let rt = Arc::new(Rt {
            mx: StdMutex::new(St {
                threads: vec![Th { run: Run::Runnable }],
                current: Some(0),
                last: Some(0),
                pending_start: 0,
                depth: 0,
                preemptions: 0,
                decisions: prefix,
                trace: vec![0],
                failure: None,
                cfg: Cfg {
                    preemption_bound: self.preemption_bound,
                    max_depth: self.max_depth,
                    max_threads: self.max_threads,
                },
            }),
            cv: StdCondvar::new(),
        });
        let c = Ctx { rt: Arc::clone(&rt), tid: 0 };
        CTX.with(|x| *x.borrow_mut() = Some(c.clone()));
        let body = std::panic::catch_unwind(std::panic::AssertUnwindSafe(f));
        // Hand the token off and wait for every spawned thread to finish
        // (model bodies normally join their threads, so this is instant).
        if body.is_ok() {
            let mut st = lock_rt(&rt);
            if st.failure.is_none() {
                st.threads[0].run = Run::Finished;
                if st.current == Some(0) {
                    st.current = None;
                }
                maybe_schedule(&mut st);
                rt.cv.notify_all();
                while st.failure.is_none() && st.threads.iter().any(|t| t.run != Run::Finished) {
                    st = rt.cv.wait(st).unwrap_or_else(|e| e.into_inner());
                }
            }
        }
        CTX.with(|x| *x.borrow_mut() = None);
        let mut st = lock_rt(&rt);
        let failure = st.failure.take();
        let decisions = std::mem::take(&mut st.decisions);
        drop(st);
        match (body, failure) {
            (Ok(()), None) => decisions,
            (_, Some(f)) => panic!("loom model failed: {f}"),
            (Err(e), None) => {
                panic!("loom model failed: body panicked: {}", panic_payload_to_string(&*e))
            }
        }
    }
}

/// [`Builder::check`] with default bounds.
pub fn model<F: Fn() + Send + Sync + 'static>(f: F) {
    Builder::default().check(f);
}

// ---------------------------------------------------------------------------
// Model sync primitives (delegate to std outside a model)
// ---------------------------------------------------------------------------

/// Drop-in `std::sync` / `std::thread` replacements that hit scheduler
/// decision points inside a [`model`] and delegate to `std` outside one.
pub mod sync {
    use super::*;

    pub struct Mutex<T> {
        inner: StdMutex<T>,
    }

    pub struct MutexGuard<'a, T> {
        lock: &'a Mutex<T>,
        inner: Option<StdMutexGuard<'a, T>>,
        /// `Some(ctx)` when acquired under a model (release must wake
        /// model waiters).
        model: Option<Ctx>,
    }

    impl<T> Mutex<T> {
        pub const fn new(t: T) -> Self {
            Self { inner: StdMutex::new(t) }
        }

        fn addr(&self) -> usize {
            &self.inner as *const _ as usize
        }

        /// Acquire without an entry yield — used on re-lock after a
        /// condvar wait (the wait itself was the decision point).
        fn lock_model(&self, c: &Ctx) -> MutexGuard<'_, T> {
            loop {
                match self.inner.try_lock() {
                    Ok(g) => {
                        return MutexGuard { lock: self, inner: Some(g), model: Some(c.clone()) };
                    }
                    Err(_) => block_on(c, Run::Mutex(self.addr())),
                }
            }
        }

        #[allow(clippy::result_unit_err)]
        pub fn lock(&self) -> Result<MutexGuard<'_, T>, ()> {
            match ctx() {
                Some(c) => {
                    yield_point(&c);
                    Ok(self.lock_model(&c))
                }
                None => match self.inner.lock() {
                    Ok(g) => Ok(MutexGuard { lock: self, inner: Some(g), model: None }),
                    // Map poisoning through (the repo treats lock
                    // poisoning as fatal and unwraps everywhere).
                    Err(_) => Err(()),
                },
            }
        }
    }

    impl<T> std::ops::Deref for MutexGuard<'_, T> {
        type Target = T;
        fn deref(&self) -> &T {
            self.inner.as_ref().expect("guard accessed after release")
        }
    }

    impl<T> std::ops::DerefMut for MutexGuard<'_, T> {
        fn deref_mut(&mut self) -> &mut T {
            self.inner.as_mut().expect("guard accessed after release")
        }
    }

    impl<T> Drop for MutexGuard<'_, T> {
        fn drop(&mut self) {
            let addr = self.lock.addr();
            drop(self.inner.take());
            if let Some(c) = self.model.take() {
                let mut st = lock_rt(&c.rt);
                wake_matching(&mut st, Run::Mutex(addr));
                // No reschedule: the releasing thread keeps the token
                // until its next decision point.
            }
        }
    }

    pub struct Condvar {
        inner: StdCondvar,
    }

    impl Default for Condvar {
        fn default() -> Self {
            Self::new()
        }
    }

    impl Condvar {
        pub const fn new() -> Self {
            Self { inner: StdCondvar::new() }
        }

        fn addr(&self) -> usize {
            &self.inner as *const _ as usize
        }

        #[allow(clippy::result_unit_err)]
        pub fn wait<'a, T>(&self, mut guard: MutexGuard<'a, T>) -> Result<MutexGuard<'a, T>, ()> {
            match guard.model.clone() {
                Some(c) => {
                    let lock = guard.lock;
                    // Atomically (no other thread runs until we park):
                    // mark ourselves waiting, release the mutex, park.
                    {
                        let mut st = lock_rt(&c.rt);
                        st.threads[c.tid].run = Run::Cond(self.addr());
                    }
                    drop(guard); // releases the mutex, wakes its waiters
                    let mut st = lock_rt(&c.rt);
                    st.current = None;
                    maybe_schedule(&mut st);
                    c.rt.cv.notify_all();
                    let st = wait_for_token(&c.rt, st, c.tid);
                    drop(st);
                    Ok(lock.lock_model(&c))
                }
                None => {
                    let lock = guard.lock;
                    let inner = guard.inner.take().expect("guard accessed after release");
                    // `guard` now has no model ctx and no inner guard;
                    // its Drop is a no-op.
                    drop(guard);
                    match self.inner.wait(inner) {
                        Ok(g) => Ok(MutexGuard { lock, inner: Some(g), model: None }),
                        Err(_) => Err(()),
                    }
                }
            }
        }

        pub fn notify_all(&self) {
            match ctx() {
                Some(c) => {
                    yield_point(&c);
                    let mut st = lock_rt(&c.rt);
                    wake_matching(&mut st, Run::Cond(self.addr()));
                }
                None => self.inner.notify_all(),
            }
        }

        /// Model limitation: wakes the lowest-tid waiter (deterministic).
        pub fn notify_one(&self) {
            match ctx() {
                Some(c) => {
                    yield_point(&c);
                    let mut st = lock_rt(&c.rt);
                    let addr = self.addr();
                    if let Some(t) = st.threads.iter_mut().find(|t| t.run == Run::Cond(addr)) {
                        t.run = Run::Runnable;
                    }
                }
                None => self.inner.notify_one(),
            }
        }
    }

    macro_rules! model_atomic {
        ($name:ident, $std:ident, $ty:ty) => {
            pub struct $name {
                inner: $std,
            }

            impl $name {
                pub const fn new(v: $ty) -> Self {
                    Self { inner: $std::new(v) }
                }

                fn pre(&self) {
                    if let Some(c) = ctx() {
                        yield_point(&c);
                    }
                }

                /// Model limitation: every access is `SeqCst` under a
                /// model regardless of the requested ordering.
                pub fn load(&self, o: Ordering) -> $ty {
                    match ctx() {
                        Some(c) => {
                            yield_point(&c);
                            self.inner.load(Ordering::SeqCst)
                        }
                        None => self.inner.load(o),
                    }
                }

                pub fn store(&self, v: $ty, o: Ordering) {
                    match ctx() {
                        Some(c) => {
                            yield_point(&c);
                            self.inner.store(v, Ordering::SeqCst)
                        }
                        None => self.inner.store(v, o),
                    }
                }

                pub fn fetch_add(&self, v: $ty, o: Ordering) -> $ty {
                    match ctx() {
                        Some(c) => {
                            yield_point(&c);
                            self.inner.fetch_add(v, Ordering::SeqCst)
                        }
                        None => self.inner.fetch_add(v, o),
                    }
                }

                pub fn swap(&self, v: $ty, o: Ordering) -> $ty {
                    self.pre();
                    match ctx() {
                        Some(_) => self.inner.swap(v, Ordering::SeqCst),
                        None => self.inner.swap(v, o),
                    }
                }
            }
        };
    }

    model_atomic!(AtomicUsize, StdAtomicUsize, usize);
    model_atomic!(AtomicU64, StdAtomicU64, u64);

    pub struct AtomicBool {
        inner: StdAtomicBool,
    }

    impl AtomicBool {
        pub const fn new(v: bool) -> Self {
            Self { inner: StdAtomicBool::new(v) }
        }

        pub fn load(&self, o: Ordering) -> bool {
            match ctx() {
                Some(c) => {
                    yield_point(&c);
                    self.inner.load(Ordering::SeqCst)
                }
                None => self.inner.load(o),
            }
        }

        pub fn store(&self, v: bool, o: Ordering) {
            match ctx() {
                Some(c) => {
                    yield_point(&c);
                    self.inner.store(v, Ordering::SeqCst)
                }
                None => self.inner.store(v, o),
            }
        }

        pub fn swap(&self, v: bool, o: Ordering) -> bool {
            match ctx() {
                Some(c) => {
                    yield_point(&c);
                    self.inner.swap(v, Ordering::SeqCst)
                }
                None => self.inner.swap(v, o),
            }
        }
    }
}

// ---------------------------------------------------------------------------
// Model threads
// ---------------------------------------------------------------------------

/// Model-aware thread spawn/join.
pub mod thread {
    use super::*;

    pub struct JoinHandle<T>(Inner<T>);

    enum Inner<T> {
        Std(std::thread::JoinHandle<T>),
        Model { tid: usize, rt: Arc<Rt>, os: std::thread::JoinHandle<T> },
    }

    impl<T> JoinHandle<T> {
        pub fn join(self) -> std::thread::Result<T> {
            match self.0 {
                Inner::Std(h) => h.join(),
                Inner::Model { tid, rt, os } => {
                    let c = ctx().expect("joining a model thread from outside its model");
                    loop {
                        let finished = {
                            let st = lock_rt(&rt);
                            if let Some(f) = st.failure.clone() {
                                if c.tid == 0 {
                                    drop(st);
                                    panic!("loom model failed: {f}");
                                }
                            }
                            st.threads[tid].run == Run::Finished
                        };
                        if finished {
                            return os.join();
                        }
                        block_on(&c, Run::Join(tid));
                    }
                }
            }
        }
    }

    /// Spawn a named thread.  Inside a model this registers a model
    /// thread (spawn is a decision point); outside it is
    /// `std::thread::Builder` with the name applied.
    pub fn spawn_named<T, F>(name: &str, f: F) -> JoinHandle<T>
    where
        T: Send + 'static,
        F: FnOnce() -> T + Send + 'static,
    {
        match ctx() {
            None => JoinHandle(Inner::Std(
                std::thread::Builder::new()
                    .name(name.to_string())
                    .spawn(f)
                    .expect("spawn thread"),
            )),
            Some(c) => {
                let tid = {
                    let mut st = lock_rt(&c.rt);
                    if st.threads.len() >= st.cfg.max_threads {
                        let cap = st.cfg.max_threads;
                        fail(&mut st, format!("model spawned more than {cap} threads"));
                        drop(st);
                        let st2 = lock_rt(&c.rt);
                        let _ = wait_for_token(&c.rt, st2, c.tid);
                        unreachable!("wait_for_token returns only on grant");
                    }
                    st.threads.push(Th { run: Run::Runnable });
                    st.pending_start += 1;
                    st.threads.len() - 1
                };
                let child = Ctx { rt: Arc::clone(&c.rt), tid };
                let os = std::thread::Builder::new()
                    .name(name.to_string())
                    .spawn(move || child_main(child, f))
                    .expect("spawn model thread");
                // The spawn itself is a decision point: the child may run
                // immediately or the spawner may continue.
                yield_point(&c);
                JoinHandle(Inner::Model { tid, rt: Arc::clone(&c.rt), os })
            }
        }
    }

    fn child_main<T, F>(c: Ctx, f: F) -> T
    where
        T: Send + 'static,
        F: FnOnce() -> T + Send + 'static,
    {
        CTX.with(|x| *x.borrow_mut() = Some(c.clone()));
        // Initial park: register as started, then wait for the token.
        {
            let mut st = lock_rt(&c.rt);
            st.pending_start -= 1;
            maybe_schedule(&mut st);
            c.rt.cv.notify_all();
            let st = wait_for_token(&c.rt, st, c.tid);
            drop(st);
        }
        let r = std::panic::catch_unwind(std::panic::AssertUnwindSafe(f));
        match r {
            Ok(v) => {
                finish_thread(&c, None);
                v
            }
            Err(e) => {
                finish_thread(&c, Some(panic_payload_to_string(&*e)));
                // The model has failed; this OS thread's return value is
                // never observed (the checker panics).  Park forever.
                let st = lock_rt(&c.rt);
                let _ = wait_for_token(&c.rt, st, c.tid);
                unreachable!("failed model thread must not be rescheduled");
            }
        }
    }
}

// ---------------------------------------------------------------------------
// Self-tests (run in tier-1: they only use the checker, not cfg(loom))
// ---------------------------------------------------------------------------

#[cfg(test)]
mod tests {
    use super::sync::{AtomicUsize, Condvar, Mutex};
    use super::thread::spawn_named;
    use super::*;
    use std::collections::BTreeMap;

    /// Count how many distinct final values a racy read-modify-write
    /// produces across schedules: the model must find both the correct
    /// (2) and the lost-update (1) outcome.
    #[test]
    fn explores_both_outcomes_of_a_race() {
        let outcomes: Arc<StdMutex<BTreeMap<usize, usize>>> =
            Arc::new(StdMutex::new(BTreeMap::new()));
        let oc = Arc::clone(&outcomes);
        Builder::default().check(move || {
            let a = Arc::new(AtomicUsize::new(0));
            let b = Arc::clone(&a);
            let t = spawn_named("racer", move || {
                let v = b.load(Ordering::SeqCst);
                b.store(v + 1, Ordering::SeqCst);
            });
            let v = a.load(Ordering::SeqCst);
            a.store(v + 1, Ordering::SeqCst);
            t.join().unwrap();
            let v = a.load(Ordering::SeqCst);
            *oc.lock().unwrap().entry(v).or_insert(0) += 1;
        });
        let seen = outcomes.lock().unwrap();
        assert!(seen.contains_key(&2), "must find the serialized outcome: {seen:?}");
        assert!(seen.contains_key(&1), "must find the lost-update interleaving: {seen:?}");
    }

    /// With a mutex around the read-modify-write the lost update is
    /// impossible under every schedule.
    #[test]
    fn mutex_prevents_lost_update() {
        model(|| {
            let a = Arc::new(Mutex::new(0usize));
            let b = Arc::clone(&a);
            let t = spawn_named("locked", move || {
                let mut g = b.lock().unwrap();
                *g += 1;
            });
            {
                let mut g = a.lock().unwrap();
                *g += 1;
            }
            t.join().unwrap();
            assert_eq!(*a.lock().unwrap(), 2);
        });
    }

    /// ABBA lock ordering must be reported as a deadlock, not hang.
    #[test]
    fn detects_abba_deadlock() {
        let r = std::panic::catch_unwind(|| {
            model(|| {
                let a = Arc::new(Mutex::new(()));
                let b = Arc::new(Mutex::new(()));
                let (a2, b2) = (Arc::clone(&a), Arc::clone(&b));
                let t = spawn_named("ba", move || {
                    let _gb = b2.lock().unwrap();
                    let _ga = a2.lock().unwrap();
                });
                {
                    let _ga = a.lock().unwrap();
                    let _gb = b.lock().unwrap();
                }
                t.join().unwrap();
            });
        });
        let e = r.expect_err("ABBA must fail the model");
        let msg = panic_payload_to_string(&*e);
        assert!(msg.contains("deadlock"), "unexpected failure: {msg}");
    }

    /// Classic condvar handoff: consumer waits for the flag under every
    /// interleaving (including notify-before-wait, which the
    /// waiter-marks-before-release protocol must not lose).
    #[test]
    fn condvar_handoff_completes() {
        model(|| {
            let pair = Arc::new((Mutex::new(false), Condvar::new()));
            let p2 = Arc::clone(&pair);
            let t = spawn_named("producer", move || {
                let (mx, cv) = &*p2;
                let mut g = mx.lock().unwrap();
                *g = true;
                cv.notify_all();
            });
            let (mx, cv) = &*pair;
            let mut g = mx.lock().unwrap();
            while !*g {
                g = cv.wait(g).unwrap();
            }
            drop(g);
            t.join().unwrap();
        });
    }

    /// A panic on a spawned model thread is reported with a trace.
    #[test]
    fn reports_spawned_thread_panic() {
        let r = std::panic::catch_unwind(|| {
            model(|| {
                let t = spawn_named("bomb", || panic!("boom"));
                let _ = t.join();
            });
        });
        let e = r.expect_err("spawned panic must fail the model");
        let msg = panic_payload_to_string(&*e);
        assert!(msg.contains("panicked") && msg.contains("boom"), "unexpected: {msg}");
    }

    /// Outside a model every primitive is plain std behaviour.
    #[test]
    fn delegates_to_std_outside_models() {
        let m = Mutex::new(7usize);
        *m.lock().unwrap() += 1;
        assert_eq!(*m.lock().unwrap(), 8);
        let a = AtomicUsize::new(1);
        assert_eq!(a.fetch_add(2, Ordering::Relaxed), 1);
        assert_eq!(a.load(Ordering::Relaxed), 3);
        let t = spawn_named("std", || 41 + 1);
        assert_eq!(t.join().unwrap(), 42);
    }
}
