//! Persistent worker-pool data parallelism (the rayon substitute).
//!
//! The old implementation spawned and joined OS threads through
//! `std::thread::scope` on **every** call — unaffordable per decode step,
//! which is why the serving backend used to pin its GEMMs to
//! `parallel: false`.  [`WorkerPool`] replaces that with long-lived
//! threads and condvar dispatch: submitting a job is a mutex store plus a
//! `notify_all`, so the per-call cost is amortized to (near) zero and the
//! serving hot path can fan every GEMM out.
//!
//! Entry points:
//!
//! * [`par_shards`] / [`par_for`] — run `f(i)` for `i in 0..n` on the
//!   global pool (dynamic scheduling through an atomic counter);
//! * [`par_chunks_mut`] — split a mutable slice into contiguous chunks and
//!   process them on the global pool, handing out disjoint `&mut` chunks
//!   **lock-free** (the atomic index already guarantees disjointness);
//! * [`WorkerPool::run`] / [`chunks_on`] — same, on an explicitly sized
//!   pool ([`pool_of`]) so N replicas × T workers share one T-sized pool
//!   instead of oversubscribing the host.
//!
//! Sizing: the global pool has [`num_threads`] workers (`APLLM_THREADS`,
//! overridable in-process via [`set_threads`]).  Pools are cached by size
//! in a process-wide registry and never torn down; a pool of size 1 runs
//! inline and owns no threads.  Nested submissions from inside a worker
//! run inline too, so kernels may freely compose with parallel callers.

use std::cell::Cell;
use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::{Arc, Condvar, Mutex};

/// Default worker count: an in-process [`set_threads`] override wins,
/// then `APLLM_THREADS`, then available parallelism (capped at 16 — the
/// kernels saturate memory bandwidth well before that).
pub fn num_threads() -> usize {
    let o = OVERRIDE.load(Ordering::Relaxed);
    if o != 0 {
        return o;
    }
    let c = ENV_CACHE.load(Ordering::Relaxed);
    if c != 0 {
        return c;
    }
    let n = std::env::var("APLLM_THREADS")
        .ok()
        .and_then(|v| v.parse().ok())
        .filter(|&v| v > 0)
        .unwrap_or_else(|| {
            std::thread::available_parallelism().map(|n| n.get()).unwrap_or(4).min(16)
        });
    ENV_CACHE.store(n, Ordering::Relaxed);
    n
}

/// In-process worker-count override (`0` clears back to the
/// `APLLM_THREADS` / available-parallelism default).  The env cache used
/// to latch the first read forever; benches, the CLI and tests use this
/// to vary worker count without re-execing.
pub fn set_threads(n: usize) {
    OVERRIDE.store(n, Ordering::Relaxed);
}

static OVERRIDE: AtomicUsize = AtomicUsize::new(0);
static ENV_CACHE: AtomicUsize = AtomicUsize::new(0);

/// The shared registry of pools, keyed by size.  Replicas asking for the
/// same worker budget get the *same* pool (they step sequentially, so N
/// replicas × T workers never oversubscribe the host), and repeated
/// benches at a given size reuse warm threads.
static REGISTRY: Mutex<Vec<Arc<WorkerPool>>> = Mutex::new(Vec::new());

/// The pool of exactly `size` workers, created on first use and cached
/// for the process lifetime.  `size == 0` is treated as [`num_threads`].
pub fn pool_of(size: usize) -> Arc<WorkerPool> {
    let size = if size == 0 { num_threads() } else { size };
    let mut reg = REGISTRY.lock().unwrap();
    if let Some(p) = reg.iter().find(|p| p.size() == size) {
        return Arc::clone(p);
    }
    let p = Arc::new(WorkerPool::new(size));
    reg.push(Arc::clone(&p));
    p
}

/// The [`num_threads`]-sized pool (re-resolved per call, so
/// [`set_threads`] takes effect immediately).
pub fn global_pool() -> Arc<WorkerPool> {
    pool_of(num_threads())
}

thread_local! {
    /// Set while this thread is executing a pool job (worker threads and
    /// the submitter during its own participation).  A nested `run` from
    /// such a thread executes inline: re-submitting to the same pool
    /// would deadlock on the submit lock while the outer job waits on us.
    static IN_POOL: Cell<bool> = const { Cell::new(false) };
}

/// One dispatched job: a type-erased `Fn(usize) + Sync` plus the shared
/// index counter.  Raw pointers into the submitting `run` call's stack —
/// sound because `run` blocks until every worker has finished the epoch.
#[derive(Clone, Copy)]
struct Job {
    data: *const (),
    call: unsafe fn(*const (), usize),
    next: *const AtomicUsize,
    n: usize,
}

// SAFETY: the pointers are only dereferenced by workers between job
// publication and the `active == 0` handshake, during which the borrowed
// closure and counter are kept alive (and shareable: F: Sync) by `run`.
unsafe impl Send for Job {}

struct State {
    /// Bumped once per dispatched job; workers run each epoch exactly once.
    epoch: u64,
    job: Option<Job>,
    /// Workers still in (or not yet through) the current epoch.
    active: usize,
    /// A worker's closure panicked during the current epoch.
    panicked: bool,
    shutdown: bool,
}

struct Shared {
    state: Mutex<State>,
    /// Workers wait here for a new epoch (or shutdown).
    work: Condvar,
    /// The submitter waits here for `active == 0`.
    done: Condvar,
}

/// A persistent pool of `size − 1` worker threads (the submitting thread
/// participates as the `size`-th worker, so `size == 1` owns no threads
/// and runs inline).  Dispatch is a single mutex store + condvar
/// broadcast; threads live until the pool is dropped — for registry pools
/// ([`pool_of`]) that is never, which is the point.
pub struct WorkerPool {
    size: usize,
    shared: Arc<Shared>,
    /// Serializes concurrent `run` calls from different threads.
    submit: Mutex<()>,
    handles: Vec<std::thread::JoinHandle<()>>,
}

impl WorkerPool {
    /// Spawn a pool of `size.max(1)` workers (inline submitter included).
    pub fn new(size: usize) -> Self {
        let size = size.max(1);
        let shared = Arc::new(Shared {
            state: Mutex::new(State {
                epoch: 0,
                job: None,
                active: 0,
                panicked: false,
                shutdown: false,
            }),
            work: Condvar::new(),
            done: Condvar::new(),
        });
        let handles = (1..size)
            .map(|i| {
                let shared = Arc::clone(&shared);
                std::thread::Builder::new()
                    .name(format!("apllm-par-{i}"))
                    .spawn(move || worker_loop(&shared))
                    .expect("spawn pool worker")
            })
            .collect();
        Self { size, shared, submit: Mutex::new(()), handles }
    }

    /// Worker count (including the submitting thread).
    pub fn size(&self) -> usize {
        self.size
    }

    /// Run `f(i)` for `i in 0..n` across the pool (dynamic scheduling).
    /// Returns once every index has been processed; panics from `f`
    /// propagate to the caller.  Runs inline when the pool has one
    /// worker, when `n <= 1`, or when called from inside a pool job.
    pub fn run<F: Fn(usize) + Sync>(&self, n: usize, f: F) {
        if n == 0 {
            return;
        }
        if self.size <= 1 || n == 1 || IN_POOL.with(|c| c.get()) {
            for i in 0..n {
                f(i);
            }
            return;
        }

        /// Monomorphized un-eraser for [`Job::call`].
        unsafe fn call_thunk<F: Fn(usize) + Sync>(data: *const (), i: usize) {
            (*(data as *const F))(i);
        }

        let _turn = self.submit.lock().unwrap();
        let next = AtomicUsize::new(0);
        let job = Job { data: &f as *const F as *const (), call: call_thunk::<F>, next: &next, n };
        {
            let mut st = self.shared.state.lock().unwrap();
            st.epoch += 1;
            st.job = Some(job);
            st.active = self.handles.len();
            st.panicked = false;
            self.shared.work.notify_all();
        }

        // Participate as worker 0.  Catch our own panic so the epoch
        // handshake below still runs — workers hold pointers into this
        // stack frame and must be drained before we unwind out of it.
        IN_POOL.with(|c| c.set(true));
        let mine = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| run_job(&job)));
        IN_POOL.with(|c| c.set(false));

        let mut st = self.shared.state.lock().unwrap();
        while st.active > 0 {
            st = self.shared.done.wait(st).unwrap();
        }
        st.job = None;
        let worker_panicked = st.panicked;
        drop(st);
        if let Err(e) = mine {
            std::panic::resume_unwind(e);
        }
        if worker_panicked {
            panic!("worker-pool job panicked (see worker thread output above)");
        }
    }
}

impl Drop for WorkerPool {
    fn drop(&mut self) {
        {
            let mut st = self.shared.state.lock().unwrap();
            st.shutdown = true;
            self.shared.work.notify_all();
        }
        for h in self.handles.drain(..) {
            let _ = h.join();
        }
    }
}

/// Pull indices off the job's shared counter until it runs dry.
fn run_job(job: &Job) {
    // SAFETY: the submitter keeps `next` and `data` alive until the
    // `active == 0` handshake; see `Job`.
    let next = unsafe { &*job.next };
    loop {
        let i = next.fetch_add(1, Ordering::Relaxed);
        if i >= job.n {
            return;
        }
        unsafe { (job.call)(job.data, i) };
    }
}

fn worker_loop(shared: &Shared) {
    IN_POOL.with(|c| c.set(true));
    let mut seen = 0u64;
    loop {
        let job = {
            let mut st = shared.state.lock().unwrap();
            loop {
                if st.shutdown {
                    return;
                }
                if st.epoch != seen {
                    seen = st.epoch;
                    break st.job.expect("epoch bumped without a job");
                }
                st = shared.work.wait(st).unwrap();
            }
        };
        let r = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| run_job(&job)));
        let mut st = shared.state.lock().unwrap();
        if r.is_err() {
            st.panicked = true;
        }
        st.active -= 1;
        if st.active == 0 {
            shared.done.notify_all();
        }
    }
}

/// A raw mutable pointer that pool workers may share.
///
/// # Safety contract
/// The *caller* must guarantee every worker writes a disjoint region (the
/// pool hands each index out exactly once, so indexing by job index is the
/// canonical pattern).  Reads of the written data after `run` returns are
/// synchronized by the pool's epoch handshake.
pub struct SendPtr<T>(*mut T);

unsafe impl<T: Send> Send for SendPtr<T> {}
unsafe impl<T: Send> Sync for SendPtr<T> {}

impl<T> SendPtr<T> {
    pub fn new(p: *mut T) -> Self {
        Self(p)
    }

    #[inline(always)]
    pub fn get(&self) -> *mut T {
        self.0
    }
}

/// Process `data` in contiguous chunks of `chunk_len` elements on `pool`.
/// `f(chunk_index, chunk)` — the pool hands each chunk index out exactly
/// once, so the `&mut` chunks are disjoint by construction and no lock or
/// `Option::take` handoff is needed.
pub fn chunks_on<T: Send, F: Fn(usize, &mut [T]) + Sync>(
    pool: &WorkerPool,
    data: &mut [T],
    chunk_len: usize,
    f: F,
) {
    assert!(chunk_len > 0);
    let len = data.len();
    let n_chunks = len.div_ceil(chunk_len);
    let base = SendPtr::new(data.as_mut_ptr());
    pool.run(n_chunks, |ci| {
        let lo = ci * chunk_len;
        let hi = len.min(lo + chunk_len);
        // SAFETY: chunk `ci` is handed out exactly once and [lo, hi)
        // ranges are pairwise disjoint across chunk indices.
        let chunk = unsafe { std::slice::from_raw_parts_mut(base.get().add(lo), hi - lo) };
        f(ci, chunk);
    });
}

/// [`chunks_on`] over the global [`num_threads`]-sized pool.
pub fn par_chunks_mut<T: Send, F: Fn(usize, &mut [T]) + Sync>(
    data: &mut [T],
    chunk_len: usize,
    f: F,
) {
    chunks_on(&global_pool(), data, chunk_len, f);
}

/// Run `f(i)` for `i in 0..n` on the global pool (dynamic scheduling).
pub fn par_shards<F: Fn(usize) + Sync>(n: usize, f: F) {
    global_pool().run(n, f);
}

/// Alias of [`par_shards`], kept for the original scoped-thread API name.
pub fn par_for<F: Fn(usize) + Sync>(n: usize, f: F) {
    par_shards(n, f);
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::AtomicU64;

    #[test]
    fn chunks_cover_everything_once() {
        let mut v = vec![0u32; 1003];
        par_chunks_mut(&mut v, 17, |_, chunk| {
            for x in chunk {
                *x += 1;
            }
        });
        assert!(v.iter().all(|&x| x == 1));
    }

    #[test]
    fn chunk_indices_match_offsets() {
        let mut v = vec![0usize; 256];
        par_chunks_mut(&mut v, 10, |ci, chunk| {
            for (j, x) in chunk.iter_mut().enumerate() {
                *x = ci * 10 + j;
            }
        });
        for (i, &x) in v.iter().enumerate() {
            assert_eq!(x, i);
        }
    }

    #[test]
    fn par_for_runs_each_index_once() {
        let sum = AtomicU64::new(0);
        par_for(1000, |i| {
            sum.fetch_add(i as u64, Ordering::Relaxed);
        });
        assert_eq!(sum.load(Ordering::Relaxed), 999 * 1000 / 2);
    }

    #[test]
    fn empty_and_tiny() {
        let mut v: Vec<u8> = vec![];
        par_chunks_mut(&mut v, 4, |_, _| panic!("no chunks expected"));
        par_for(0, |_| panic!("no iterations expected"));
        let mut one = vec![5u8];
        par_chunks_mut(&mut one, 4, |_, c| c[0] = 6);
        assert_eq!(one[0], 6);
    }

    #[test]
    fn pool_is_reused_across_jobs_and_registry_lookups() {
        let a = pool_of(3);
        let b = pool_of(3);
        assert!(Arc::ptr_eq(&a, &b), "registry must hand back the same pool");
        // many dispatches over the same long-lived threads
        let sum = AtomicU64::new(0);
        for _ in 0..50 {
            a.run(37, |i| {
                sum.fetch_add(i as u64 + 1, Ordering::Relaxed);
            });
        }
        assert_eq!(sum.load(Ordering::Relaxed), 50 * (37 * 38 / 2));
    }

    #[test]
    fn set_threads_override_wins_and_clears() {
        // serialize with other tests touching the override
        let _guard = OVERRIDE_TEST_LOCK.lock().unwrap();
        set_threads(3);
        assert_eq!(num_threads(), 3);
        set_threads(1);
        assert_eq!(num_threads(), 1);
        set_threads(0);
        assert!(num_threads() >= 1, "cleared override falls back to default");
    }

    static OVERRIDE_TEST_LOCK: Mutex<()> = Mutex::new(());

    #[test]
    fn nested_run_from_inside_a_job_runs_inline() {
        let pool = pool_of(2);
        let sum = AtomicU64::new(0);
        pool.run(8, |_| {
            // would deadlock on the submit lock if not inlined
            pool.run(4, |j| {
                sum.fetch_add(j as u64, Ordering::Relaxed);
            });
        });
        assert_eq!(sum.load(Ordering::Relaxed), 8 * 6);
    }

    #[test]
    fn worker_panic_propagates_to_submitter() {
        let pool = pool_of(4);
        let r = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
            pool.run(64, |i| {
                if i == 13 {
                    panic!("planted worker failure");
                }
            });
        }));
        assert!(r.is_err(), "worker panic must surface on the submitter");
        // the pool must still be usable after a panicked epoch
        let sum = AtomicU64::new(0);
        pool.run(16, |i| {
            sum.fetch_add(i as u64, Ordering::Relaxed);
        });
        assert_eq!(sum.load(Ordering::Relaxed), 120);
    }

    #[test]
    fn size_one_pool_runs_inline_without_threads() {
        let pool = WorkerPool::new(1);
        let mut hit = vec![false; 9];
        let ptr = SendPtr::new(hit.as_mut_ptr());
        pool.run(9, |i| unsafe { *ptr.get().add(i) = true });
        assert!(hit.iter().all(|&h| h));
    }
}
