//! Persistent worker-pool data parallelism (the rayon substitute).
//!
//! The old implementation spawned and joined OS threads through
//! `std::thread::scope` on **every** call — unaffordable per decode step,
//! which is why the serving backend used to pin its GEMMs to
//! `parallel: false`.  [`WorkerPool`] replaces that with long-lived
//! threads and condvar dispatch: submitting a job is a mutex store plus a
//! `notify_all`, so the per-call cost is amortized to (near) zero and the
//! serving hot path can fan every GEMM out.
//!
//! Entry points:
//!
//! * [`par_shards`] / [`par_for`] — run `f(i)` for `i in 0..n` on the
//!   global pool (dynamic scheduling through an atomic counter);
//! * [`par_chunks_mut`] — split a mutable slice into contiguous chunks and
//!   process them on the global pool, handing out disjoint `&mut` chunks
//!   **lock-free** (the atomic index already guarantees disjointness);
//! * [`WorkerPool::run`] / [`chunks_on`] — same, on an explicitly sized
//!   pool ([`pool_of`]) so N replicas × T workers share one T-sized pool
//!   instead of oversubscribing the host.
//!
//! Sizing: the global pool has [`num_threads`] workers (`APLLM_THREADS`,
//! overridable in-process via [`set_threads`]; both live in a
//! [`ThreadConfig`]).  Pools are cached by size in a process-wide
//! [`PoolRegistry`] and torn down only by [`shutdown_pools`] (a test /
//! Miri affordance); a pool of size 1 runs inline and owns no threads.
//! Nested submissions from inside a worker run inline too, so kernels may
//! freely compose with parallel callers.
//!
//! # Concurrency & unsafety
//!
//! This module is one of the three audited `unsafe` islands in the crate
//! (with `bitmm::apmm` and `bitmm::planes`); everything else is built
//! with `unsafe_code = "deny"`, and `cargo run -p xtask -- lint` enforces
//! the allowlist, the `// SAFETY:` comments, and the no-raw-`thread::spawn`
//! rule in CI.  The dispatch protocol invariants:
//!
//! * **Epoch monotonicity.**  `State::epoch` strictly increases, by
//!   exactly one per submitted job, always under the state mutex.  Each
//!   worker tracks the last epoch it executed (`seen`) and runs every
//!   epoch **at most once** — a worker that misses the condvar window
//!   still observes `epoch != seen` on its next wakeup, and a worker that
//!   already ran the epoch blocks until the next bump.  The `submit`
//!   mutex serializes submitters, so there is never more than one live
//!   epoch.
//! * **Job-data lifetime.**  A [`Job`] carries raw pointers into the
//!   submitting `run` call's stack frame (the closure and the shared
//!   index counter).  That is sound because `run` does not return — and
//!   does not even begin unwinding — until the `active == 0` handshake
//!   confirms every worker has left the epoch: the submitter's own share
//!   of the work runs under `catch_unwind`, so a panicking closure still
//!   drains the epoch before the panic resumes.
//! * **Submitter-as-worker-0 can't deadlock.**  The submitter
//!   participates in its own epoch instead of waiting for a free worker,
//!   so a pool is never needed to make progress on its own submission;
//!   workers themselves never submit (a nested [`WorkerPool::run`] from
//!   inside a job detects `IN_POOL` and runs inline), so the `submit`
//!   mutex can only be held by a thread that is not a pool worker, and
//!   the `done` wait terminates because each of the `handles.len()`
//!   workers decrements `active` exactly once per epoch (their jobs run
//!   under `catch_unwind`, so a panic cannot skip the decrement).
//! * **`SendPtr` disjointness.**  [`SendPtr`] lets workers write through
//!   a shared raw pointer; the *caller* owes the proof that concurrent
//!   writes land in disjoint regions.  The canonical pattern — indexing
//!   by the job index the pool hands out exactly once — is what
//!   [`chunks_on`] packages, and its debug assertions turn a violated
//!   hand-out (a chunk dispatched twice, a range out of bounds, a slice
//!   not exactly covered) into a loud failure on ordinary test runs, not
//!   just under Miri.
//!
//! These invariants are machine-checked three ways in CI: the
//! `loom_model` tests below exhaustively model the protocol under
//! `--cfg loom` (see [`crate::util::loom`]), Miri runs the
//! `tests/miri_suite.rs` walk of every unsafe path, and ThreadSanitizer
//! runs the native suite.  The primitives themselves are imported from
//! [`crate::util::sync`] so the loom build swaps them for model-checked
//! twins without touching this file's logic.

use std::cell::Cell;
use std::sync::Arc;

use crate::util::sync::atomic::{AtomicUsize, Ordering};
use crate::util::sync::{thread, Condvar, Mutex};

/// Worker-count resolution state: an in-process override (highest
/// priority) plus a latched environment-derived default.  The process
/// global lives behind [`num_threads`] / [`set_threads`]; loom tests
/// instantiate their own to model the override/cache race.
pub struct ThreadConfig {
    overridden: AtomicUsize,
    env_cache: AtomicUsize,
}

impl ThreadConfig {
    pub const fn new() -> Self {
        Self { overridden: AtomicUsize::new(0), env_cache: AtomicUsize::new(0) }
    }

    /// Resolution order: the [`Self::set_override`] value if nonzero,
    /// then the cached default, then `env_default()` (invoked at most
    /// once per cache fill and latched).
    pub fn resolve<F: FnOnce() -> usize>(&self, env_default: F) -> usize {
        let o = self.overridden.load(Ordering::Relaxed);
        if o != 0 {
            return o;
        }
        let c = self.env_cache.load(Ordering::Relaxed);
        if c != 0 {
            return c;
        }
        let n = env_default().max(1);
        self.env_cache.store(n, Ordering::Relaxed);
        n
    }

    /// `0` clears the override back to the environment default.
    pub fn set_override(&self, n: usize) {
        self.overridden.store(n, Ordering::Relaxed);
    }
}

impl Default for ThreadConfig {
    fn default() -> Self {
        Self::new()
    }
}

static CONFIG: ThreadConfig = ThreadConfig::new();

/// Default worker count: an in-process [`set_threads`] override wins,
/// then `APLLM_THREADS`, then available parallelism (capped at 16 — the
/// kernels saturate memory bandwidth well before that).
pub fn num_threads() -> usize {
    CONFIG.resolve(|| {
        std::env::var("APLLM_THREADS")
            .ok()
            .and_then(|v| v.parse().ok())
            .filter(|&v| v > 0)
            .unwrap_or_else(|| {
                std::thread::available_parallelism().map(|n| n.get()).unwrap_or(4).min(16)
            })
    })
}

/// In-process worker-count override (`0` clears back to the
/// `APLLM_THREADS` / available-parallelism default).  The env cache used
/// to latch the first read forever; benches, the CLI and tests use this
/// to vary worker count without re-execing.
pub fn set_threads(n: usize) {
    CONFIG.set_override(n);
}

/// A registry of pools keyed by size.  Threads asking for the same
/// worker budget get the *same* pool (N replicas × T workers never
/// oversubscribe the host — they step sequentially), and repeated
/// benches at a given size reuse warm threads.  The process global lives
/// behind [`pool_of`]; loom tests instantiate their own to model the
/// concurrent first-use race.
pub struct PoolRegistry {
    pools: Mutex<Vec<Arc<WorkerPool>>>,
}

impl PoolRegistry {
    pub const fn new() -> Self {
        Self { pools: Mutex::new(Vec::new()) }
    }

    /// The pool of exactly `size` workers, created on first use and
    /// cached until [`Self::shutdown`].
    pub fn get(&self, size: usize) -> Arc<WorkerPool> {
        let mut reg = self.pools.lock().unwrap();
        if let Some(p) = reg.iter().find(|p| p.size() == size) {
            return Arc::clone(p);
        }
        let p = Arc::new(WorkerPool::new(size));
        reg.push(Arc::clone(&p));
        p
    }

    /// Drop every cached pool.  A pool whose last `Arc` dies here joins
    /// its worker threads before returning.
    pub fn shutdown(&self) {
        self.pools.lock().unwrap().clear();
    }
}

impl Default for PoolRegistry {
    fn default() -> Self {
        Self::new()
    }
}

static REGISTRY: PoolRegistry = PoolRegistry::new();

/// The pool of exactly `size` workers, created on first use and cached
/// for the process lifetime.  `size == 0` is treated as [`num_threads`].
pub fn pool_of(size: usize) -> Arc<WorkerPool> {
    let size = if size == 0 { num_threads() } else { size };
    REGISTRY.get(size)
}

/// The [`num_threads`]-sized pool (re-resolved per call, so
/// [`set_threads`] takes effect immediately).
pub fn global_pool() -> Arc<WorkerPool> {
    pool_of(num_threads())
}

/// Tear down every registry pool, joining worker threads whose last
/// reference lived in the registry.  Subsequent [`pool_of`] calls
/// recreate pools on demand.  Ordinary runs never need this (warm pools
/// for the process lifetime are the point); the Miri suite calls it so
/// the interpreter sees every thread joined at exit.
pub fn shutdown_pools() {
    REGISTRY.shutdown();
}

thread_local! {
    /// Set while this thread is executing a pool job (worker threads and
    /// the submitter during its own participation).  A nested `run` from
    /// such a thread executes inline: re-submitting to the same pool
    /// would deadlock on the submit lock while the outer job waits on us.
    static IN_POOL: Cell<bool> = const { Cell::new(false) };
}

/// One dispatched job: a type-erased `Fn(usize) + Sync` plus the shared
/// index counter.  Raw pointers into the submitting `run` call's stack —
/// sound because `run` blocks until every worker has finished the epoch.
#[derive(Clone, Copy)]
struct Job {
    data: *const (),
    call: unsafe fn(*const (), usize),
    next: *const AtomicUsize,
    n: usize,
}

// SAFETY: the pointers are only dereferenced by workers between job
// publication and the `active == 0` handshake, during which the borrowed
// closure and counter are kept alive (and shareable: F: Sync) by `run`.
unsafe impl Send for Job {}

struct State {
    /// Bumped once per dispatched job; workers run each epoch exactly once.
    epoch: u64,
    job: Option<Job>,
    /// Workers still in (or not yet through) the current epoch.
    active: usize,
    /// A worker's closure panicked during the current epoch.
    panicked: bool,
    shutdown: bool,
}

struct Shared {
    state: Mutex<State>,
    /// Workers wait here for a new epoch (or shutdown).
    work: Condvar,
    /// The submitter waits here for `active == 0`.
    done: Condvar,
}

/// A persistent pool of `size − 1` worker threads (the submitting thread
/// participates as the `size`-th worker, so `size == 1` owns no threads
/// and runs inline).  Dispatch is a single mutex store + condvar
/// broadcast; threads live until the pool is dropped — for registry pools
/// ([`pool_of`]) that is normally never, which is the point.
pub struct WorkerPool {
    size: usize,
    shared: Arc<Shared>,
    /// Serializes concurrent `run` calls from different threads.
    submit: Mutex<()>,
    handles: Vec<thread::JoinHandle<()>>,
}

impl WorkerPool {
    /// Spawn a pool of `size.max(1)` workers (inline submitter included).
    pub fn new(size: usize) -> Self {
        let size = size.max(1);
        let shared = Arc::new(Shared {
            state: Mutex::new(State {
                epoch: 0,
                job: None,
                active: 0,
                panicked: false,
                shutdown: false,
            }),
            work: Condvar::new(),
            done: Condvar::new(),
        });
        let handles = (1..size)
            .map(|i| {
                let shared = Arc::clone(&shared);
                thread::spawn_named(&format!("apllm-par-{i}"), move || worker_loop(&shared))
            })
            .collect();
        Self { size, shared, submit: Mutex::new(()), handles }
    }

    /// Worker count (including the submitting thread).
    pub fn size(&self) -> usize {
        self.size
    }

    /// Run `f(i)` for `i in 0..n` across the pool (dynamic scheduling).
    /// Returns once every index has been processed; panics from `f`
    /// propagate to the caller.  Runs inline when the pool has one
    /// worker, when `n <= 1`, or when called from inside a pool job.
    pub fn run<F: Fn(usize) + Sync>(&self, n: usize, f: F) {
        if n == 0 {
            return;
        }
        if self.size <= 1 || n == 1 || IN_POOL.with(|c| c.get()) {
            for i in 0..n {
                f(i);
            }
            return;
        }

        /// Monomorphized un-eraser for [`Job::call`].
        // SAFETY (to call): `data` must be `&F` erased for this exact `F`
        // and outlive the call; the only caller is the `run` that
        // published the job, which upholds both.
        unsafe fn call_thunk<F: Fn(usize) + Sync>(data: *const (), i: usize) {
            // SAFETY: `data` was erased from `&F` by the `run` call that
            // published this job, and stays borrowed until the epoch
            // handshake completes (see `Job`); `F: Sync` makes the shared
            // call sound.
            unsafe { (*(data as *const F))(i) };
        }

        let _turn = self.submit.lock().unwrap();
        let next = AtomicUsize::new(0);
        let job = Job { data: &f as *const F as *const (), call: call_thunk::<F>, next: &next, n };
        {
            let mut st = self.shared.state.lock().unwrap();
            st.epoch += 1;
            st.job = Some(job);
            st.active = self.handles.len();
            st.panicked = false;
            self.shared.work.notify_all();
        }

        // Participate as worker 0.  Catch our own panic so the epoch
        // handshake below still runs — workers hold pointers into this
        // stack frame and must be drained before we unwind out of it.
        IN_POOL.with(|c| c.set(true));
        let mine = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| run_job(&job)));
        IN_POOL.with(|c| c.set(false));

        let mut st = self.shared.state.lock().unwrap();
        while st.active > 0 {
            st = self.shared.done.wait(st).unwrap();
        }
        st.job = None;
        let worker_panicked = st.panicked;
        drop(st);
        if let Err(e) = mine {
            std::panic::resume_unwind(e);
        }
        if worker_panicked {
            panic!("worker-pool job panicked (see worker thread output above)");
        }
    }
}

impl Drop for WorkerPool {
    fn drop(&mut self) {
        {
            let mut st = self.shared.state.lock().unwrap();
            st.shutdown = true;
            self.shared.work.notify_all();
        }
        for h in self.handles.drain(..) {
            let _ = h.join();
        }
    }
}

/// Pull indices off the job's shared counter until it runs dry.
fn run_job(job: &Job) {
    // SAFETY: the submitter keeps `next` and `data` alive until the
    // `active == 0` handshake; see `Job`.
    let next = unsafe { &*job.next };
    loop {
        let i = next.fetch_add(1, Ordering::Relaxed);
        if i >= job.n {
            return;
        }
        // SAFETY: same lifetime argument as above; `call` is the
        // monomorphized thunk for the published closure's exact type.
        unsafe { (job.call)(job.data, i) };
    }
}

fn worker_loop(shared: &Shared) {
    IN_POOL.with(|c| c.set(true));
    let mut seen = 0u64;
    loop {
        let job = {
            let mut st = shared.state.lock().unwrap();
            loop {
                if st.shutdown {
                    return;
                }
                if st.epoch != seen {
                    seen = st.epoch;
                    break st.job.expect("epoch bumped without a job");
                }
                st = shared.work.wait(st).unwrap();
            }
        };
        let r = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| run_job(&job)));
        let mut st = shared.state.lock().unwrap();
        if r.is_err() {
            st.panicked = true;
        }
        st.active -= 1;
        if st.active == 0 {
            shared.done.notify_all();
        }
    }
}

/// A raw mutable pointer that pool workers may share.
///
/// # Safety contract
/// The *caller* must guarantee every worker writes a disjoint region (the
/// pool hands each index out exactly once, so indexing by job index is the
/// canonical pattern).  Reads of the written data after `run` returns are
/// synchronized by the pool's epoch handshake.  The `xtask lint` pass
/// keeps every use of this escape hatch inside the audited modules.
pub struct SendPtr<T>(*mut T);

// SAFETY: SendPtr is a plain pointer wrapper; sending or sharing it moves
// no data.  All dereferences happen inside pool jobs whose callers uphold
// the disjoint-writes contract above, and the epoch handshake sequences
// those writes before any post-`run` read.
unsafe impl<T: Send> Send for SendPtr<T> {}
// SAFETY: as above — `&SendPtr` only exposes the raw pointer value;
// dereferencing it is the caller's audited responsibility.
unsafe impl<T: Send> Sync for SendPtr<T> {}

impl<T> SendPtr<T> {
    pub fn new(p: *mut T) -> Self {
        Self(p)
    }

    #[inline(always)]
    pub fn get(&self) -> *mut T {
        self.0
    }
}

/// Process `data` in contiguous chunks of `chunk_len` elements on `pool`.
/// `f(chunk_index, chunk)` — the pool hands each chunk index out exactly
/// once, so the `&mut` chunks are disjoint by construction and no lock or
/// `Option::take` handoff is needed.
///
/// Debug builds verify the construction: every handed-out range must be
/// in-bounds, every chunk index must be dispatched exactly once, and the
/// dispatched chunks must cover the slice exactly — so a future scheduling
/// bug surfaces as a loud assertion on the ordinary test path, not only
/// under Miri.
pub fn chunks_on<T: Send, F: Fn(usize, &mut [T]) + Sync>(
    pool: &WorkerPool,
    data: &mut [T],
    chunk_len: usize,
    f: F,
) {
    assert!(chunk_len > 0);
    let len = data.len();
    let n_chunks = len.div_ceil(chunk_len);
    let base = SendPtr::new(data.as_mut_ptr());
    #[cfg(debug_assertions)]
    let handed_out: Vec<std::sync::atomic::AtomicBool> =
        (0..n_chunks).map(|_| std::sync::atomic::AtomicBool::new(false)).collect();
    #[cfg(debug_assertions)]
    let covered = std::sync::atomic::AtomicUsize::new(0);
    pool.run(n_chunks, |ci| {
        let lo = ci * chunk_len;
        let hi = len.min(lo + chunk_len);
        #[cfg(debug_assertions)]
        {
            assert!(ci < n_chunks, "chunk index {ci} out of range ({n_chunks} chunks)");
            assert!(
                lo < hi && hi <= len,
                "chunk {ci} range [{lo}, {hi}) out of bounds for slice of {len}"
            );
            assert!(
                !handed_out[ci].swap(true, std::sync::atomic::Ordering::Relaxed),
                "chunk {ci} handed out twice (would alias &mut)"
            );
            covered.fetch_add(hi - lo, std::sync::atomic::Ordering::Relaxed);
        }
        // SAFETY: chunk `ci` is handed out exactly once and [lo, hi)
        // ranges are pairwise disjoint across chunk indices.
        let chunk = unsafe { std::slice::from_raw_parts_mut(base.get().add(lo), hi - lo) };
        f(ci, chunk);
    });
    #[cfg(debug_assertions)]
    {
        assert!(
            handed_out.iter().all(|b| b.load(std::sync::atomic::Ordering::Relaxed)),
            "some chunk was never dispatched"
        );
        assert_eq!(
            covered.load(std::sync::atomic::Ordering::Relaxed),
            len,
            "dispatched chunks do not cover the slice exactly"
        );
    }
}

/// [`chunks_on`] over the global [`num_threads`]-sized pool.
pub fn par_chunks_mut<T: Send, F: Fn(usize, &mut [T]) + Sync>(
    data: &mut [T],
    chunk_len: usize,
    f: F,
) {
    chunks_on(&global_pool(), data, chunk_len, f);
}

/// Run `f(i)` for `i in 0..n` on the global pool (dynamic scheduling).
pub fn par_shards<F: Fn(usize) + Sync>(n: usize, f: F) {
    global_pool().run(n, f);
}

/// Alias of [`par_shards`], kept for the original scoped-thread API name.
pub fn par_for<F: Fn(usize) + Sync>(n: usize, f: F) {
    par_shards(n, f);
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::AtomicU64;

    #[test]
    fn chunks_cover_everything_once() {
        let mut v = vec![0u32; 1003];
        par_chunks_mut(&mut v, 17, |_, chunk| {
            for x in chunk {
                *x += 1;
            }
        });
        assert!(v.iter().all(|&x| x == 1));
    }

    #[test]
    fn chunk_indices_match_offsets() {
        let mut v = vec![0usize; 256];
        par_chunks_mut(&mut v, 10, |ci, chunk| {
            for (j, x) in chunk.iter_mut().enumerate() {
                *x = ci * 10 + j;
            }
        });
        for (i, &x) in v.iter().enumerate() {
            assert_eq!(x, i);
        }
    }

    #[test]
    fn par_for_runs_each_index_once() {
        let sum = AtomicU64::new(0);
        par_for(1000, |i| {
            sum.fetch_add(i as u64, std::sync::atomic::Ordering::Relaxed);
        });
        assert_eq!(sum.load(std::sync::atomic::Ordering::Relaxed), 999 * 1000 / 2);
    }

    #[test]
    fn empty_and_tiny() {
        let mut v: Vec<u8> = vec![];
        par_chunks_mut(&mut v, 4, |_, _| panic!("no chunks expected"));
        par_for(0, |_| panic!("no iterations expected"));
        let mut one = vec![5u8];
        par_chunks_mut(&mut one, 4, |_, c| c[0] = 6);
        assert_eq!(one[0], 6);
    }

    #[test]
    fn pool_is_reused_across_jobs_and_registry_lookups() {
        let a = pool_of(3);
        let b = pool_of(3);
        assert!(Arc::ptr_eq(&a, &b), "registry must hand back the same pool");
        // many dispatches over the same long-lived threads
        let sum = AtomicU64::new(0);
        for _ in 0..50 {
            a.run(37, |i| {
                sum.fetch_add(i as u64 + 1, std::sync::atomic::Ordering::Relaxed);
            });
        }
        assert_eq!(sum.load(std::sync::atomic::Ordering::Relaxed), 50 * (37 * 38 / 2));
    }

    #[test]
    fn set_threads_override_wins_and_clears() {
        // serialize with other tests touching the override
        let _guard = OVERRIDE_TEST_LOCK.lock().unwrap();
        set_threads(3);
        assert_eq!(num_threads(), 3);
        set_threads(1);
        assert_eq!(num_threads(), 1);
        set_threads(0);
        assert!(num_threads() >= 1, "cleared override falls back to default");
    }

    static OVERRIDE_TEST_LOCK: std::sync::Mutex<()> = std::sync::Mutex::new(());

    #[test]
    fn fresh_thread_config_resolves_and_latches() {
        let cfg = ThreadConfig::new();
        assert_eq!(cfg.resolve(|| 7), 7);
        // latched: a different default no longer matters
        assert_eq!(cfg.resolve(|| 9), 7);
        cfg.set_override(2);
        assert_eq!(cfg.resolve(|| 9), 2);
        cfg.set_override(0);
        assert_eq!(cfg.resolve(|| 9), 7);
    }

    #[test]
    fn private_registry_caches_by_size_and_shuts_down() {
        let reg = PoolRegistry::new();
        let a = reg.get(2);
        let b = reg.get(2);
        assert!(Arc::ptr_eq(&a, &b));
        let c = reg.get(3);
        assert!(!Arc::ptr_eq(&a, &c));
        drop((b, c));
        reg.shutdown();
        // registry refs gone; ours still works, then joins on drop
        let sum = AtomicU64::new(0);
        a.run(8, |i| {
            sum.fetch_add(i as u64, std::sync::atomic::Ordering::Relaxed);
        });
        assert_eq!(sum.load(std::sync::atomic::Ordering::Relaxed), 28);
    }

    #[test]
    fn nested_run_from_inside_a_job_runs_inline() {
        let pool = pool_of(2);
        let sum = AtomicU64::new(0);
        pool.run(8, |_| {
            // would deadlock on the submit lock if not inlined
            pool.run(4, |j| {
                sum.fetch_add(j as u64, std::sync::atomic::Ordering::Relaxed);
            });
        });
        assert_eq!(sum.load(std::sync::atomic::Ordering::Relaxed), 8 * 6);
    }

    #[test]
    fn worker_panic_propagates_to_submitter() {
        let pool = pool_of(4);
        let r = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
            pool.run(64, |i| {
                if i == 13 {
                    panic!("planted worker failure");
                }
            });
        }));
        assert!(r.is_err(), "worker panic must surface on the submitter");
        // the pool must still be usable after a panicked epoch
        let sum = AtomicU64::new(0);
        pool.run(16, |i| {
            sum.fetch_add(i as u64, std::sync::atomic::Ordering::Relaxed);
        });
        assert_eq!(sum.load(std::sync::atomic::Ordering::Relaxed), 120);
    }

    #[test]
    fn size_one_pool_runs_inline_without_threads() {
        let pool = WorkerPool::new(1);
        let mut hit = vec![false; 9];
        let ptr = SendPtr::new(hit.as_mut_ptr());
        // SAFETY: each index `i` is handed out exactly once, so the
        // writes target disjoint elements of `hit`, which outlives `run`.
        pool.run(9, |i| unsafe { *ptr.get().add(i) = true });
        assert!(hit.iter().all(|&h| h));
    }
}

/// Exhaustive protocol models, run by the loom CI lane:
/// `RUSTFLAGS="--cfg loom" cargo test --lib loom_model`.
/// Each test re-executes its body under every bounded interleaving of the
/// pool's mutexes, condvars and atomics (see [`crate::util::loom`]).
#[cfg(all(test, loom))]
mod loom_model {
    use super::*;
    use crate::util::loom;
    use crate::util::sync::thread::spawn_named;
    use std::sync::atomic::{AtomicUsize as StdAtomicUsize, Ordering as O};

    fn bounded(preemptions: usize) -> loom::Builder {
        loom::Builder { preemption_bound: Some(preemptions), ..Default::default() }
    }

    /// Submit one epoch to a two-thread pool and drain it: the epoch
    /// bump, condvar wakeup, shared index counter and `active == 0`
    /// handshake all run under every bounded schedule, and shutdown/join
    /// (pool drop) completes from any of them.
    #[test]
    fn submit_and_drain_two_workers() {
        bounded(2).check(|| {
            let pool = WorkerPool::new(2);
            let sum = StdAtomicUsize::new(0);
            pool.run(2, |i| {
                sum.fetch_add(i + 1, O::Relaxed);
            });
            assert_eq!(sum.load(O::Relaxed), 3);
        });
    }

    /// Epoch monotonicity across consecutive submissions: a worker that
    /// raced ahead (or lagged behind) on epoch N must still run epoch
    /// N+1 exactly once.
    #[test]
    fn epoch_advance_runs_each_epoch_once() {
        bounded(1).check(|| {
            let pool = WorkerPool::new(2);
            let sum = StdAtomicUsize::new(0);
            pool.run(2, |i| {
                sum.fetch_add(i + 1, O::Relaxed);
            });
            pool.run(2, |i| {
                sum.fetch_add(10 * (i + 1), O::Relaxed);
            });
            assert_eq!(sum.load(O::Relaxed), 33);
        });
    }

    /// Nested submission from inside a job must inline (`IN_POOL`), not
    /// re-enter the submit lock.
    #[test]
    fn nested_submit_runs_inline() {
        bounded(2).check(|| {
            let pool = WorkerPool::new(2);
            let sum = StdAtomicUsize::new(0);
            pool.run(2, |_| {
                pool.run(2, |j| {
                    sum.fetch_add(j + 1, O::Relaxed);
                });
            });
            assert_eq!(sum.load(O::Relaxed), 6);
        });
    }

    /// A panicking job must drain the epoch *before* the panic resumes
    /// (workers hold pointers into the submitter's frame), and the pool
    /// must accept the next epoch afterwards.
    #[test]
    fn panic_drains_epoch_before_unwinding() {
        // Silence the planted payload (every explored schedule panics
        // once); everything else still reaches the previous hook.
        let prev = std::panic::take_hook();
        std::panic::set_hook(Box::new(move |info| {
            let msg = info
                .payload()
                .downcast_ref::<&str>()
                .map(|s| (*s).to_string())
                .or_else(|| info.payload().downcast_ref::<String>().cloned())
                .unwrap_or_default();
            if !(msg.contains("planted") || msg.contains("worker-pool job panicked")) {
                prev(info);
            }
        }));
        bounded(1).check(|| {
            let pool = WorkerPool::new(2);
            let r = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
                pool.run(2, |i| {
                    if i == 0 {
                        panic!("planted");
                    }
                });
            }));
            assert!(r.is_err(), "planted panic must surface on the submitter");
            let sum = StdAtomicUsize::new(0);
            pool.run(2, |i| {
                sum.fetch_add(i + 1, O::Relaxed);
            });
            assert_eq!(sum.load(O::Relaxed), 3);
        });
    }

    /// Two threads race `PoolRegistry::get` on first use: both must end
    /// up holding the *same* pool (no duplicate pools of one size).
    #[test]
    fn concurrent_registry_first_use_yields_one_pool() {
        bounded(2).check(|| {
            let reg = Arc::new(PoolRegistry::new());
            let out = Arc::new(Mutex::new(Vec::new()));
            let (r2, o2) = (Arc::clone(&reg), Arc::clone(&out));
            let t = spawn_named("reg-race", move || {
                let p = r2.get(1);
                o2.lock().unwrap().push(p);
            });
            let p0 = reg.get(1);
            t.join().unwrap();
            let got = out.lock().unwrap();
            assert_eq!(got.len(), 1);
            assert!(Arc::ptr_eq(&got[0], &p0), "racing first use must cache exactly one pool");
        });
    }

    /// `set_override` racing `resolve`: the racing read may see either
    /// value, but once the override write settles every later resolve
    /// must return it (the env cache latch cannot shadow the override).
    #[test]
    fn override_beats_env_cache_once_set() {
        bounded(2).check(|| {
            let cfg = Arc::new(ThreadConfig::new());
            let c2 = Arc::clone(&cfg);
            let t = spawn_named("override", move || {
                c2.set_override(3);
            });
            let first = cfg.resolve(|| 8);
            assert!(first == 3 || first == 8, "racing resolve returned {first}");
            t.join().unwrap();
            assert_eq!(cfg.resolve(|| 8), 3, "override must win after the race settles");
        });
    }
}
