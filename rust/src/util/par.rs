//! Scoped-thread data parallelism (the rayon substitute).
//!
//! `par_chunks_mut` splits a mutable slice into contiguous chunks and
//! processes them on `num_threads()` OS threads via `std::thread::scope`;
//! `par_for` runs an index range the same way.  Closures receive the chunk
//! (or index) plus its global offset.

use std::sync::atomic::{AtomicUsize, Ordering};

/// Worker count: respects `APLLM_THREADS`, defaults to available
/// parallelism (capped at 16 — the kernels saturate memory bandwidth well
/// before that).
pub fn num_threads() -> usize {
    static CACHED: AtomicUsize = AtomicUsize::new(0);
    let c = CACHED.load(Ordering::Relaxed);
    if c != 0 {
        return c;
    }
    let n = std::env::var("APLLM_THREADS")
        .ok()
        .and_then(|v| v.parse().ok())
        .filter(|&v| v > 0)
        .unwrap_or_else(|| {
            std::thread::available_parallelism().map(|n| n.get()).unwrap_or(4).min(16)
        });
    CACHED.store(n, Ordering::Relaxed);
    n
}

/// Process `data` in contiguous chunks of `chunk_len` elements, in
/// parallel.  `f(chunk_index, chunk)` — chunks are disjoint so no locking
/// is needed.  Falls back to sequential for small inputs.
pub fn par_chunks_mut<T: Send, F: Fn(usize, &mut [T]) + Sync>(
    data: &mut [T],
    chunk_len: usize,
    f: F,
) {
    assert!(chunk_len > 0);
    let n_chunks = data.len().div_ceil(chunk_len);
    let threads = num_threads().min(n_chunks);
    if threads <= 1 {
        for (i, chunk) in data.chunks_mut(chunk_len).enumerate() {
            f(i, chunk);
        }
        return;
    }
    let next = AtomicUsize::new(0);
    // hand out chunks through a work-stealing counter so uneven chunk
    // costs balance across threads
    let chunks: Vec<(usize, &mut [T])> = data.chunks_mut(chunk_len).enumerate().collect();
    let chunks = std::sync::Mutex::new(chunks.into_iter().map(Some).collect::<Vec<_>>());
    std::thread::scope(|s| {
        for _ in 0..threads {
            s.spawn(|| loop {
                let idx = next.fetch_add(1, Ordering::Relaxed);
                let item = {
                    let mut guard = chunks.lock().unwrap();
                    if idx >= guard.len() {
                        return;
                    }
                    guard[idx].take()
                };
                if let Some((i, chunk)) = item {
                    f(i, chunk);
                }
            });
        }
    });
}

/// Run `f(i)` for `i in 0..n` across threads (dynamic scheduling).
pub fn par_for<F: Fn(usize) + Sync>(n: usize, f: F) {
    let threads = num_threads().min(n.max(1));
    if threads <= 1 {
        for i in 0..n {
            f(i);
        }
        return;
    }
    let next = AtomicUsize::new(0);
    std::thread::scope(|s| {
        for _ in 0..threads {
            s.spawn(|| loop {
                let i = next.fetch_add(1, Ordering::Relaxed);
                if i >= n {
                    return;
                }
                f(i);
            });
        }
    });
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::AtomicU64;

    #[test]
    fn chunks_cover_everything_once() {
        let mut v = vec![0u32; 1003];
        par_chunks_mut(&mut v, 17, |_, chunk| {
            for x in chunk {
                *x += 1;
            }
        });
        assert!(v.iter().all(|&x| x == 1));
    }

    #[test]
    fn chunk_indices_match_offsets() {
        let mut v = vec![0usize; 256];
        par_chunks_mut(&mut v, 10, |ci, chunk| {
            for (j, x) in chunk.iter_mut().enumerate() {
                *x = ci * 10 + j;
            }
        });
        for (i, &x) in v.iter().enumerate() {
            assert_eq!(x, i);
        }
    }

    #[test]
    fn par_for_runs_each_index_once() {
        let sum = AtomicU64::new(0);
        par_for(1000, |i| {
            sum.fetch_add(i as u64, Ordering::Relaxed);
        });
        assert_eq!(sum.load(Ordering::Relaxed), 999 * 1000 / 2);
    }

    #[test]
    fn empty_and_tiny() {
        let mut v: Vec<u8> = vec![];
        par_chunks_mut(&mut v, 4, |_, _| panic!("no chunks expected"));
        par_for(0, |_| panic!("no iterations expected"));
        let mut one = vec![5u8];
        par_chunks_mut(&mut one, 4, |_, c| c[0] = 6);
        assert_eq!(one[0], 6);
    }
}
