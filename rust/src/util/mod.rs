//! In-tree substrates replacing ecosystem crates (the build is fully
//! offline — see Cargo.toml): a seeded PRNG (`rng`), persistent
//! worker-pool data parallelism (`par` — long-lived threads with condvar
//! dispatch, sized pools shared through a process-wide registry), a JSON
//! parser/writer (`json`), and a lightweight property-testing harness
//! (`proptest`).

pub mod json;
pub mod par;
pub mod proptest;
pub mod rng;

pub use json::Json;
pub use par::{
    global_pool, num_threads, par_chunks_mut, par_for, par_shards, pool_of, set_threads, SendPtr,
    WorkerPool,
};
pub use rng::Rng;
