//! In-tree substrates replacing ecosystem crates (the build is fully
//! offline — see Cargo.toml): a seeded PRNG (`rng`), scoped-thread data
//! parallelism (`par`), a JSON parser/writer (`json`), and a lightweight
//! property-testing harness (`proptest`).

pub mod json;
pub mod par;
pub mod proptest;
pub mod rng;

pub use json::Json;
pub use par::{num_threads, par_chunks_mut, par_for};
pub use rng::Rng;
