//! In-tree substrates replacing ecosystem crates (the build is fully
//! offline — see Cargo.toml): a seeded PRNG (`rng`), persistent
//! worker-pool data parallelism (`par` — long-lived threads with condvar
//! dispatch, sized pools shared through a process-wide registry), a JSON
//! parser/writer (`json`), a lightweight property-testing harness
//! (`proptest`), and the concurrency-correctness tooling around `par`: a
//! bounded model checker (`loom` — the loom-crate substitute) and the
//! `sync` shim that swaps `par`'s primitives for their model-checked
//! twins under `--cfg loom`.

pub mod json;
pub mod loom;
// `par` owns the audited unsafe core of the data-parallel substrate
// (type-erased job pointers, SendPtr, raw chunk handout); every site
// carries a SAFETY comment and `cargo run -p xtask -- lint` enforces the
// allowlist (see the workspace `unsafe_code = "deny"` lint).
#[allow(unsafe_code)]
pub mod par;
pub mod proptest;
pub mod rng;
pub mod sync;

pub use json::Json;
pub use par::{
    global_pool, num_threads, par_chunks_mut, par_for, par_shards, pool_of, set_threads,
    shutdown_pools, PoolRegistry, SendPtr, ThreadConfig, WorkerPool,
};
pub use rng::Rng;
