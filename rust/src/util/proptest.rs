//! Lightweight property-testing harness (the proptest substitute).
//!
//! `forall(cases, |rng| { ... })` runs the closure `cases` times with
//! independent seeded RNGs; on failure it reports the failing seed so the
//! case replays deterministically via `replay(seed, f)`.  Shrinking is the
//! caller's job (generate from small ranges).

use super::rng::Rng;

/// Base seed: override with `APLLM_PROPTEST_SEED` to replay a CI failure.
fn base_seed() -> u64 {
    std::env::var("APLLM_PROPTEST_SEED").ok().and_then(|v| v.parse().ok()).unwrap_or(0xA11A)
}

/// Run `f` for `cases` independent seeds; panics with the failing seed.
pub fn forall<F: Fn(&mut Rng)>(cases: u64, f: F) {
    let base = base_seed();
    for i in 0..cases {
        let seed = base.wrapping_add(i.wrapping_mul(0x9E3779B97F4A7C15));
        let result = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
            let mut rng = Rng::with_seed(seed);
            f(&mut rng);
        }));
        if let Err(e) = result {
            eprintln!("property failed at case {i} (seed {seed}); replay with APLLM_PROPTEST_SEED={seed} and 1 case");
            std::panic::resume_unwind(e);
        }
    }
}

/// Replay a single failing seed.
pub fn replay<F: Fn(&mut Rng)>(seed: u64, f: F) {
    let mut rng = Rng::with_seed(seed);
    f(&mut rng);
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn passes_when_property_holds() {
        forall(32, |rng| {
            let a = rng.usize(0, 100);
            let b = rng.usize(0, 100);
            assert_eq!(a + b, b + a);
        });
    }

    #[test]
    fn reports_failing_seed() {
        let r = std::panic::catch_unwind(|| {
            forall(64, |rng| {
                assert!(rng.usize(0, 10) < 10);
                assert_ne!(rng.usize(0, 4), 3, "planted failure");
            })
        });
        assert!(r.is_err(), "planted failure must surface");
    }
}
