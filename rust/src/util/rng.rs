//! Seeded xoshiro256** PRNG — deterministic test/bench data without the
//! `rand` crate.

/// xoshiro256** (Blackman & Vigna). Not cryptographic; excellent
/// statistical quality for workload generation.
#[derive(Debug, Clone)]
pub struct Rng {
    s: [u64; 4],
}

impl Rng {
    /// Seed via SplitMix64 so nearby seeds give uncorrelated streams.
    pub fn with_seed(seed: u64) -> Self {
        let mut x = seed;
        let mut next = || {
            x = x.wrapping_add(0x9E3779B97F4A7C15);
            let mut z = x;
            z = (z ^ (z >> 30)).wrapping_mul(0xBF58476D1CE4E5B9);
            z = (z ^ (z >> 27)).wrapping_mul(0x94D049BB133111EB);
            z ^ (z >> 31)
        };
        Self { s: [next(), next(), next(), next()] }
    }

    #[inline]
    pub fn u64(&mut self) -> u64 {
        let r = self.s[1].wrapping_mul(5).rotate_left(7).wrapping_mul(9);
        let t = self.s[1] << 17;
        self.s[2] ^= self.s[0];
        self.s[3] ^= self.s[1];
        self.s[1] ^= self.s[2];
        self.s[0] ^= self.s[3];
        self.s[2] ^= t;
        self.s[3] = self.s[3].rotate_left(45);
        r
    }

    /// Uniform in `[lo, hi)` (hi > lo).
    #[inline]
    pub fn u32(&mut self, lo: u32, hi: u32) -> u32 {
        debug_assert!(hi > lo);
        lo + (self.u64() % (hi - lo) as u64) as u32
    }

    /// Uniform in `[lo, hi)`.
    #[inline]
    pub fn usize(&mut self, lo: usize, hi: usize) -> usize {
        debug_assert!(hi > lo);
        lo + (self.u64() % (hi - lo) as u64) as usize
    }

    /// Uniform in `[0, 1)`.
    #[inline]
    pub fn f32(&mut self) -> f32 {
        (self.u64() >> 40) as f32 / (1u64 << 24) as f32
    }

    /// Uniform in `[0, 1)`, double precision.
    #[inline]
    pub fn f64(&mut self) -> f64 {
        (self.u64() >> 11) as f64 / (1u64 << 53) as f64
    }

    /// Standard normal via Box–Muller.
    pub fn normal(&mut self) -> f32 {
        let u1 = (1.0 - self.f64()).max(1e-12);
        let u2 = self.f64();
        ((-2.0 * u1.ln()).sqrt() * (2.0 * std::f64::consts::PI * u2).cos()) as f32
    }

    /// Exponential with rate `lambda` (inter-arrival sampling).
    pub fn exponential(&mut self, lambda: f64) -> f64 {
        -(1.0 - self.f64()).max(1e-12).ln() / lambda
    }

    pub fn bool(&mut self) -> bool {
        self.u64() & 1 == 1
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic() {
        let mut a = Rng::with_seed(42);
        let mut b = Rng::with_seed(42);
        for _ in 0..100 {
            assert_eq!(a.u64(), b.u64());
        }
    }

    #[test]
    fn seeds_decorrelated() {
        let mut a = Rng::with_seed(1);
        let mut b = Rng::with_seed(2);
        let same = (0..1000).filter(|_| a.u64() == b.u64()).count();
        assert_eq!(same, 0);
    }

    #[test]
    fn ranges_respected() {
        let mut r = Rng::with_seed(7);
        for _ in 0..10_000 {
            let v = r.u32(3, 17);
            assert!((3..17).contains(&v));
            let f = r.f32();
            assert!((0.0..1.0).contains(&f));
        }
    }

    #[test]
    fn normal_moments() {
        let mut r = Rng::with_seed(9);
        let n = 50_000;
        let xs: Vec<f32> = (0..n).map(|_| r.normal()).collect();
        let mean = xs.iter().sum::<f32>() / n as f32;
        let var = xs.iter().map(|x| (x - mean) * (x - mean)).sum::<f32>() / n as f32;
        assert!(mean.abs() < 0.03, "mean={mean}");
        assert!((var - 1.0).abs() < 0.05, "var={var}");
    }

    #[test]
    fn exponential_mean() {
        let mut r = Rng::with_seed(11);
        let n = 50_000;
        let mean = (0..n).map(|_| r.exponential(4.0)).sum::<f64>() / n as f64;
        assert!((mean - 0.25).abs() < 0.01, "mean={mean}");
    }
}
