//! Sync-primitive shim: `std::sync` by default, the [`crate::util::loom`]
//! model types under `--cfg loom`.
//!
//! `util::par` (and any future concurrent module) imports its mutexes,
//! condvars, atomics and thread spawns from here instead of `std`, so a
//! loom build (`RUSTFLAGS="--cfg loom" cargo test --lib loom_model`)
//! swaps every primitive for its model-checked twin without touching the
//! protocol code.  The model types delegate to `std` whenever no model is
//! active, so a loom build still runs the regular suite unchanged.

#[cfg(not(loom))]
pub use std::sync::{Condvar, Mutex, MutexGuard};

#[cfg(loom)]
pub use crate::util::loom::sync::{Condvar, Mutex, MutexGuard};

pub mod atomic {
    #[cfg(not(loom))]
    pub use std::sync::atomic::{AtomicBool, AtomicU64, AtomicUsize};

    #[cfg(loom)]
    pub use crate::util::loom::sync::{AtomicBool, AtomicU64, AtomicUsize};

    // Orderings are plain values in both worlds (the model upgrades every
    // access to SeqCst internally; see `util::loom` for the limitation).
    pub use std::sync::atomic::Ordering;
}

pub mod thread {
    #[cfg(not(loom))]
    pub struct JoinHandle<T>(std::thread::JoinHandle<T>);

    #[cfg(not(loom))]
    impl<T> JoinHandle<T> {
        pub fn join(self) -> std::thread::Result<T> {
            self.0.join()
        }
    }

    /// Spawn a named thread (`std::thread::Builder` under the hood; a
    /// model thread under `--cfg loom` inside a model).
    #[cfg(not(loom))]
    pub fn spawn_named<T, F>(name: &str, f: F) -> JoinHandle<T>
    where
        T: Send + 'static,
        F: FnOnce() -> T + Send + 'static,
    {
        JoinHandle(
            std::thread::Builder::new()
                .name(name.to_string())
                .spawn(f)
                .expect("spawn thread"),
        )
    }

    #[cfg(loom)]
    pub use crate::util::loom::thread::{spawn_named, JoinHandle};
}
