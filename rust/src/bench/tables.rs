//! Table/figure generators: one function per paper artifact (DESIGN.md §5
//! experiment index).  Each returns a formatted string so tests can check
//! structure; `print_*` wrappers go to stdout.

use crate::gpusim::{OursOpts, Scheme, SimResult, Simulator};
use crate::model::{LlmArch, PrecisionConfig};

/// Every scheme the tables print is built from the repo's own enums and
/// calibrated at `Simulator::rtx3090` construction, so the fallible
/// lookup cannot miss here; user-supplied schemes go through the CLI's
/// error path instead.
fn sim1(sim: &Simulator, sch: &Scheme, m: usize, k: usize, n: usize) -> SimResult {
    sim.simulate(sch, m, k, n).expect("paper-table scheme is calibrated")
}

const T1_SIZES: [usize; 3] = [1024, 2048, 4096];

/// Paper Table 1 reference latencies (µs) for the comparison column.
fn paper_t1(label: &str) -> Option<[f64; 3]> {
    Some(match label {
        "FP32" => [121.0, 779.0, 5690.0],
        "FP16" => [44.2, 263.0, 1960.0],
        "CUTLASS INT4" => [15.8, 66.5, 386.0],
        "CUTLASS INT1" => [9.3, 36.9, 161.0],
        "W3A4 (ours)" => [12.4, 50.4, 184.0],
        "W2A2 (ours)" => [8.7, 18.1, 46.5],
        "W1A2 (ours)" => [9.0, 11.7, 29.5],
        _ => return None,
    })
}

fn t1_schemes() -> Vec<Scheme> {
    vec![
        Scheme::Fp32,
        Scheme::Fp16,
        Scheme::CutlassInt4,
        Scheme::CutlassInt1,
        Scheme::ours(PrecisionConfig::W3A4),
        Scheme::ours(PrecisionConfig::W2A2),
        Scheme::ours(PrecisionConfig::W1A2),
    ]
}

/// (label, [(size, time_s, speedup_vs_fp32)]) rows for Table 1.
pub fn table1_rows() -> Vec<(String, Vec<(usize, f64, f64)>)> {
    let sim = Simulator::rtx3090();
    let fp32: Vec<f64> =
        T1_SIZES.iter().map(|&s| sim1(&sim, &Scheme::Fp32, s, s, s).time_s).collect();
    t1_schemes()
        .into_iter()
        .map(|sch| {
            let rows = T1_SIZES
                .iter()
                .enumerate()
                .map(|(i, &s)| {
                    let t = sim1(&sim, &sch, s, s, s).time_s;
                    (s, t, fp32[i] / t)
                })
                .collect();
            (sch.label(), rows)
        })
        .collect()
}

pub fn table1_string() -> String {
    let mut out = String::from(
        "Table 1 — square MatMul latency & speedup vs FP32 (simulated RTX 3090; paper value in parens)\n",
    );
    out.push_str(&format!(
        "{:<16} {:>26} {:>26} {:>26}\n",
        "scheme", "1k/1k/1k", "2k/2k/2k", "4k/4k/4k"
    ));
    for (label, rows) in table1_rows() {
        let paper = paper_t1(&label);
        let cell = |i: usize, (_, t, sp): (usize, f64, f64)| -> String {
            let p = paper.map(|p| format!(" ({:.1})", p[i])).unwrap_or_default();
            format!("{:>8.1}µs{p} {sp:>6.1}×", t * 1e6)
        };
        out.push_str(&format!(
            "{:<16} {:>26} {:>26} {:>26}\n",
            label,
            cell(0, rows[0]),
            cell(1, rows[1]),
            cell(2, rows[2])
        ));
    }
    out
}

/// Paper Table 2 shapes + reference latencies (µs).
const T2_PAPER: [(&str, usize, usize, usize); 3] = [
    ("1k/4k/4k", 1024, 4096, 4096),
    ("1k/10.5k/4k", 1024, 4096, 11008),
    ("1k/4k/10.5k", 1024, 11008, 4096),
];

fn paper_t2(label: &str) -> Option<[f64; 3]> {
    Some(match label {
        "FP32" => [3120.0, 8210.0, 8360.0],
        "FP16" => [1070.0, 1470.0, 1580.0],
        "CUTLASS INT4" => [238.0, 574.0, 548.0],
        "CUTLASS INT1" => [97.0, 255.0, 188.0],
        "W3A4 (ours)" => [194.0, 523.0, 540.0],
        "W2A2 (ours)" => [59.0, 143.0, 165.0],
        "W1A2 (ours)" => [34.0, 84.0, 82.0],
        _ => return None,
    })
}

pub fn table2_string() -> String {
    let sim = Simulator::rtx3090();
    let fp32: Vec<f64> =
        T2_PAPER.iter().map(|&(_, m, k, n)| sim1(&sim, &Scheme::Fp32, m, k, n).time_s).collect();
    let mut out = String::from(
        "Table 2 — Llama2-7B MatMul latency & speedup vs FP32 (simulated; paper value in parens)\n",
    );
    out.push_str(&format!(
        "{:<16} {:>28} {:>28} {:>28}\n",
        "scheme", T2_PAPER[0].0, T2_PAPER[1].0, T2_PAPER[2].0
    ));
    for sch in t1_schemes() {
        let label = sch.label();
        let paper = paper_t2(&label);
        let mut cells = Vec::new();
        for (i, &(_, m, k, n)) in T2_PAPER.iter().enumerate() {
            let t = sim1(&sim, &sch, m, k, n).time_s;
            let p = paper.map(|p| format!(" ({:.0})", p[i])).unwrap_or_default();
            cells.push(format!("{:>8.1}µs{p} {:>6.1}×", t * 1e6, fp32[i] / t));
        }
        out.push_str(&format!(
            "{:<16} {:>28} {:>28} {:>28}\n",
            label, cells[0], cells[1], cells[2]
        ));
    }
    out
}

/// Fig. 5 — effective TOPS (2·M·N·K ops) on square matrices 128→4096.
pub fn fig5_string() -> String {
    let sim = Simulator::rtx3090();
    let sizes = [128usize, 256, 512, 1024, 2048, 4096];
    let series: Vec<(String, Scheme)> = vec![
        ("W1A2 (ours)".into(), Scheme::ours(PrecisionConfig::W1A2)),
        ("W2A2 (ours)".into(), Scheme::ours(PrecisionConfig::W2A2)),
        ("W3A4 (ours)".into(), Scheme::ours(PrecisionConfig::W3A4)),
        ("CUTLASS INT1".into(), Scheme::CutlassInt1),
        ("CUTLASS INT4".into(), Scheme::CutlassInt4),
        ("APNN-TC W1A2".into(), Scheme::ApnnTc(PrecisionConfig::W1A2)),
        ("APNN-TC W2A2".into(), Scheme::ApnnTc(PrecisionConfig::W2A2)),
        ("BSTC".into(), Scheme::Bstc),
        ("BTC".into(), Scheme::Btc),
    ];
    let mut out = String::from("Fig. 5 — throughput (effective TOPS) on square MatMuls\n");
    out.push_str(&format!("{:<16}", "scheme"));
    for s in sizes {
        out.push_str(&format!("{s:>9}"));
    }
    out.push('\n');
    for (label, sch) in series {
        out.push_str(&format!("{label:<16}"));
        for &s in &sizes {
            let r = sim1(&sim, &sch, s, s, s);
            out.push_str(&format!("{:>9.2}", r.tops_effective(s, s, s)));
        }
        out.push('\n');
    }
    out
}

/// Fig. 6 — effective TOPS on the Llama2-7B layer shapes (M = 1024).
pub fn fig6_string() -> String {
    let sim = Simulator::rtx3090();
    let arch = LlmArch::llama2_7b();
    let mut shapes = arch.per_layer_shapes(1024);
    shapes.push(crate::model::MatMulShape {
        m: 1024,
        k: arch.dim,
        n: arch.vocab,
        count: 1,
        label: "lm_head",
    });
    let series: Vec<(String, Scheme)> = vec![
        ("W1A2 (ours)".into(), Scheme::ours(PrecisionConfig::W1A2)),
        ("W2A2 (ours)".into(), Scheme::ours(PrecisionConfig::W2A2)),
        ("W3A4 (ours)".into(), Scheme::ours(PrecisionConfig::W3A4)),
        ("CUTLASS INT1".into(), Scheme::CutlassInt1),
        ("CUTLASS INT4".into(), Scheme::CutlassInt4),
        ("APNN-TC W2A2".into(), Scheme::ApnnTc(PrecisionConfig::W2A2)),
    ];
    let mut out = String::from("Fig. 6 — throughput (effective TOPS) on Llama2-7B MatMul shapes (M=1024)\n");
    out.push_str(&format!("{:<16}", "scheme"));
    for s in &shapes {
        out.push_str(&format!("{:>16}", format!("{}", s.label)));
    }
    out.push('\n');
    for (label, sch) in series {
        out.push_str(&format!("{label:<16}"));
        for s in &shapes {
            let r = sim1(&sim, &sch, s.m, s.k, s.n);
            out.push_str(&format!("{:>16.2}", r.tops_effective(s.m, s.k, s.n)));
        }
        out.push('\n');
    }
    out
}

/// Fig. 7 — end-to-end inference speedup over FP16 per model.
pub fn fig7_string() -> String {
    let sim = Simulator::rtx3090();
    let schemes: Vec<(&str, Scheme)> = vec![
        ("FP16 (baseline)", Scheme::Fp16),
        ("QLoRA W4", Scheme::QloraW4),
        ("GPTQ / CUTLASS INT4", Scheme::CutlassInt4),
        ("OneBit / CUTLASS INT1", Scheme::CutlassInt1),
        ("ours W4A4", Scheme::ours(PrecisionConfig::W4A4)),
        ("ours W2A2", Scheme::ours(PrecisionConfig::W2A2)),
        ("ours W1A1", Scheme::ours(PrecisionConfig::W1A1)),
    ];
    let models = LlmArch::all_paper_models();
    let mut out = String::from(
        "Fig. 7 — inference speedup vs FP16 (M=1024 forward; paper band: ours 3.9–6.7×, QLoRA <1×, ours/OneBit 1.2–2×)\n",
    );
    out.push_str(&format!("{:<22}", "scheme"));
    for m in &models {
        out.push_str(&format!("{:>12}", m.name));
    }
    out.push('\n');
    for (label, sch) in schemes {
        out.push_str(&format!("{label:<22}"));
        for m in &models {
            let sp = sim
                .llm_speedup_vs_fp16(m, &sch, 1024)
                .expect("paper-table scheme is calibrated");
            out.push_str(&format!("{sp:>11.2}×"));
        }
        out.push('\n');
    }
    out
}

/// Ablation AB2 — §4.1/§4.2 knobs off, one at a time (simulated).
pub fn ablation_sched_string() -> String {
    let sim = Simulator::rtx3090();
    let p = PrecisionConfig::W2A2;
    let variants: Vec<(&str, OursOpts)> = vec![
        ("paper config (all on)", OursOpts::paper()),
        ("no fused recovery (§4.2 ①②)", OursOpts { fused_recovery: false, ..OursOpts::paper() }),
        ("no bit-plane packing (§4.1)", OursOpts { packed: false, ..OursOpts::paper() }),
        ("no double buffering (§4.2 ③)", OursOpts { double_buffer: false, ..OursOpts::paper() }),
        ("no fragment reuse (§4.2 ④)", OursOpts { frag_reuse: false, ..OursOpts::paper() }),
        ("on-the-fly weight packing (§3.3 off)", OursOpts { prepacked: false, ..OursOpts::paper() }),
        ("naive (all off)", OursOpts::naive()),
    ];
    let sizes = [(1024usize, "1k³"), (4096, "4k³")];
    let mut out = String::from("Ablation — memory-scheduling knobs, W2A2 (simulated latency, × vs paper config)\n");
    out.push_str(&format!("{:<34}{:>16}{:>16}\n", "variant", sizes[0].1, sizes[1].1));
    let base: Vec<f64> =
        sizes.iter().map(|&(s, _)| sim1(&sim, &Scheme::ours(p), s, s, s).time_s).collect();
    for (label, opts) in variants {
        out.push_str(&format!("{label:<34}"));
        for (i, &(s, _)) in sizes.iter().enumerate() {
            let t = sim1(&sim, &Scheme::Ours(p, opts), s, s, s).time_s;
            out.push_str(&format!("{:>9.1}µs {:>4.2}×", t * 1e6, t / base[i]));
        }
        out.push('\n');
    }
    out
}

/// Ablation AB1 — integer format comparison (measured on the CPU bitmm
/// substrate + structural costs).
pub fn ablation_format_string() -> String {
    use crate::bitfmt::IntFormat;
    use crate::bitmm::{apmm_bipolar, apmm_signed, apmm_unsigned, transpose_codes, ApmmOpts, CodeMatrix};

    let (m, k, n, bits) = (128usize, 1024usize, 128usize, 3u32);
    let w = CodeMatrix::random(m, k, bits, 1);
    let x = CodeMatrix::random(k, n, bits, 2);
    let xt = transpose_codes(&x);
    let time = |f: &mut dyn FnMut()| {
        f(); // warm
        let t0 = std::time::Instant::now();
        for _ in 0..5 {
            f();
        }
        t0.elapsed().as_secs_f64() / 5.0
    };
    let t_bip = time(&mut || {
        std::hint::black_box(apmm_bipolar(&w, &xt, ApmmOpts::default()));
    });
    let t_sig = time(&mut || {
        std::hint::black_box(apmm_signed(&w, &xt));
    });
    let t_uns = time(&mut || {
        std::hint::black_box(apmm_unsigned(&w, &xt));
    });
    let mut out = String::from(
        "Ablation — integer format (W3A3, 128×1024×128, CPU bitmm; plus structural costs)\n",
    );
    out.push_str(&format!(
        "{:<28}{:>12}{:>18}{:>22}\n",
        "format", "CPU time", "correction GEMMs", "MSB sign special-case"
    ));
    for (fmt, t) in [
        (IntFormat::Bipolar, t_bip),
        (IntFormat::Signed, t_sig),
        (IntFormat::Unsigned, t_uns),
    ] {
        out.push_str(&format!(
            "{:<28}{:>9.2} ms{:>18}{:>22}\n",
            fmt.name(),
            t * 1e3,
            fmt.correction_gemms(),
            if fmt.plane_negative(bits - 1, bits) { "yes" } else { "no" }
        ));
    }
    out.push_str("note: unsigned additionally needs zero-point correction GEMMs (J·X, W·J) that\n");
    out.push_str("the bipolar format eliminates (paper §3.1); signed forces a sign-flipped MSB\n");
    out.push_str("plane, breaking the uniform recovery loop.\n");
    out
}

/// §3.3 pack-vs-compute split on the Llama2-7B forward shapes: the
/// one-time weight pack cost vs the recurring activation-pack + GEMM cost
/// — the structural win of the prepacked ABI, per layer.
pub fn pack_split_string() -> String {
    let sim = Simulator::rtx3090();
    let prec = PrecisionConfig::W2A2;
    let m = 1024;
    let rows = sim
        .llm_pack_split(&LlmArch::llama2_7b(), prec, m)
        .expect("paper-table scheme is calibrated");
    let mut out = format!(
        "Pack-once split — Llama2-7B forward, {} @ M={m} (simulated; weight pack paid ONCE at load)\n",
        prec.label()
    );
    out.push_str(&format!(
        "{:<12}{:>20}{:>20}{:>16}{:>22}\n",
        "layer", "weight pack (once)", "act pack (step)", "GEMM (step)", "pack/GEMM if inline"
    ));
    let (mut tp, mut ta, mut tg) = (0.0, 0.0, 0.0);
    for r in &rows {
        tp += r.weight_pack_once_s;
        ta += r.act_pack_step_s;
        tg += r.gemm_step_s;
        out.push_str(&format!(
            "{:<12}{:>17.1}µs{:>17.1}µs{:>13.1}µs{:>21.2}×\n",
            r.label,
            r.weight_pack_once_s * 1e6,
            r.act_pack_step_s * 1e6,
            r.gemm_step_s * 1e6,
            r.weight_pack_once_s / r.gemm_step_s
        ));
    }
    out.push_str(&format!(
        "{:<12}{:>17.1}µs{:>17.1}µs{:>13.1}µs{:>21.2}×\n",
        "TOTAL",
        tp * 1e6,
        ta * 1e6,
        tg * 1e6,
        tp / tg
    ));
    out.push_str("note: re-packing weights inline would add the first column to EVERY forward;\n");
    out.push_str("the prepacked ABI pays it once and the serving loop keeps only the act-pack cost.\n");
    out
}

pub fn print_table1() {
    println!("{}", table1_string());
}
pub fn print_table2() {
    println!("{}", table2_string());
}
pub fn print_fig5() {
    println!("{}", fig5_string());
}
pub fn print_fig6() {
    println!("{}", fig6_string());
}
pub fn print_fig7() {
    println!("{}", fig7_string());
}
pub fn print_ablation_sched() {
    println!("{}", ablation_sched_string());
}
pub fn print_ablation_format() {
    println!("{}", ablation_format_string());
}
pub fn print_pack_split() {
    println!("{}", pack_split_string());
}

/// Everything, in paper order (the `apllm tables` subcommand).
pub fn print_all_tables() {
    print_table1();
    print_table2();
    print_fig5();
    print_fig6();
    print_fig7();
    print_ablation_sched();
    print_ablation_format();
    print_pack_split();
}
