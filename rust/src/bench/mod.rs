//! Benchmark harness: regenerates every table and figure of the paper's
//! evaluation section (DESIGN.md §5) and provides the measurement
//! utilities the `rust/benches/*` targets use (the build is offline, so
//! a small in-tree harness replaces criterion).

mod harness;
mod tables;

pub use harness::{bench_fn, BenchResult};
pub use tables::{
    print_ablation_format, print_ablation_sched, print_all_tables, print_fig5, print_fig6,
    print_fig7, print_pack_split, print_table1, print_table2,
};

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bench_fn_measures() {
        let r = bench_fn("spin", 3, 5, || {
            let mut x = 0u64;
            for i in 0..10_000 {
                x = x.wrapping_add(i);
            }
            std::hint::black_box(x);
        });
        assert!(r.median_s > 0.0);
        assert!(r.mean_s > 0.0);
        assert_eq!(r.samples, 5);
        assert!(r.median_s < 1.0);
    }

    #[test]
    fn tables_render_without_panicking() {
        // smoke: every table generator runs and mentions its headline rows
        let t1 = tables::table1_string();
        assert!(t1.contains("CUTLASS INT1") && t1.contains("W1A2 (ours)"));
        let t2 = tables::table2_string();
        assert!(t2.contains("1k/4k/10.5k") || t2.contains("11008"));
        let f7 = tables::fig7_string();
        assert!(f7.contains("Llama2-7B") && f7.contains("OPT-6.7B") && f7.contains("BLOOM-7B"));
        let ps = tables::pack_split_string();
        assert!(ps.contains("attn.q") && ps.contains("lm_head") && ps.contains("TOTAL"));
        let ab = tables::ablation_sched_string();
        assert!(ab.contains("§3.3 off"), "prepacked knob must appear in the ablation");
    }

    #[test]
    fn table1_speedup_column_consistent() {
        // the speedup column must equal fp32_time / row_time within rounding
        let rows = tables::table1_rows();
        for (label, per_size) in rows {
            for (size, time_s, speedup) in per_size {
                if label == "FP32" {
                    assert!((speedup - 1.0).abs() < 1e-9);
                }
                assert!(time_s > 0.0, "{label} at {size}");
                assert!(speedup > 0.0);
            }
        }
    }
}
