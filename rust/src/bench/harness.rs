//! Tiny measurement harness (criterion substitute): warmup + N samples,
//! median/mean/min reporting.

use std::time::Instant;

#[derive(Debug, Clone, Copy)]
pub struct BenchResult {
    pub median_s: f64,
    pub mean_s: f64,
    pub min_s: f64,
    pub samples: usize,
}

impl BenchResult {
    pub fn fmt_time(s: f64) -> String {
        if s >= 1.0 {
            format!("{s:.3} s")
        } else if s >= 1e-3 {
            format!("{:.3} ms", s * 1e3)
        } else {
            format!("{:.1} µs", s * 1e6)
        }
    }
}

/// Run `f` `warmup` times unmeasured, then `samples` measured times.
pub fn bench_fn<F: FnMut()>(name: &str, warmup: usize, samples: usize, mut f: F) -> BenchResult {
    for _ in 0..warmup {
        f();
    }
    let mut times = Vec::with_capacity(samples);
    for _ in 0..samples {
        let t0 = Instant::now();
        f();
        times.push(t0.elapsed().as_secs_f64());
    }
    times.sort_by(|a, b| a.partial_cmp(b).unwrap());
    let res = BenchResult {
        median_s: times[times.len() / 2],
        mean_s: times.iter().sum::<f64>() / times.len() as f64,
        min_s: times[0],
        samples,
    };
    println!(
        "{name:<44} median {:>10}  mean {:>10}  min {:>10}  (n={})",
        BenchResult::fmt_time(res.median_s),
        BenchResult::fmt_time(res.mean_s),
        BenchResult::fmt_time(res.min_s),
        res.samples
    );
    res
}
