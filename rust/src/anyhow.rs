//! In-tree `anyhow` substitute (the build is fully offline — see
//! Cargo.toml).  Modules import it as `use crate::anyhow::{...}`; only
//! the subset this codebase uses is provided: [`Error`], [`Result`],
//! [`Context`], and the `anyhow!` / `bail!` macros.

use std::fmt;

/// A string-backed error with `context: inner` chaining via [`Context`].
pub struct Error {
    msg: String,
}

impl Error {
    /// Construct from anything displayable.
    pub fn msg<M: fmt::Display>(m: M) -> Self {
        Self { msg: m.to_string() }
    }

    fn wrap<C: fmt::Display>(self, context: C) -> Self {
        Self { msg: format!("{context}: {}", self.msg) }
    }
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.msg)
    }
}

impl fmt::Debug for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.msg)
    }
}

// Mirrors anyhow: `Error` deliberately does NOT implement
// `std::error::Error`, which is what makes this blanket `?`-conversion
// coherent alongside `impl From<T> for T`.
impl<E: std::error::Error + Send + Sync + 'static> From<E> for Error {
    fn from(e: E) -> Self {
        Error::msg(e)
    }
}

/// `anyhow`-style result alias (error type defaults to [`Error`]).
pub type Result<T, E = Error> = std::result::Result<T, E>;

/// Attach context to the error/none arm of a `Result` or `Option`.
pub trait Context<T> {
    fn context<C: fmt::Display>(self, context: C) -> Result<T>;
    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T>;
}

impl<T, E: fmt::Display> Context<T> for std::result::Result<T, E> {
    fn context<C: fmt::Display>(self, context: C) -> Result<T> {
        self.map_err(|e| Error::msg(e).wrap(context))
    }

    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T> {
        self.map_err(|e| Error::msg(e).wrap(f()))
    }
}

impl<T> Context<T> for Option<T> {
    fn context<C: fmt::Display>(self, context: C) -> Result<T> {
        self.ok_or_else(|| Error::msg(context))
    }

    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T> {
        self.ok_or_else(|| Error::msg(f()))
    }
}

/// `anyhow!(fmt, ...)` — build an [`Error`] from a format string.
#[macro_export]
macro_rules! __apllm_anyhow {
    ($($arg:tt)*) => {
        $crate::anyhow::Error::msg(format!($($arg)*))
    };
}

/// `bail!(fmt, ...)` — early-return `Err(anyhow!(...))`.
#[macro_export]
macro_rules! __apllm_bail {
    ($($arg:tt)*) => {
        return Err($crate::anyhow::Error::msg(format!($($arg)*)).into())
    };
}

pub use crate::__apllm_anyhow as anyhow;
pub use crate::__apllm_bail as bail;

#[cfg(test)]
mod tests {
    use super::*;

    fn io_fail() -> Result<String> {
        let s = std::fs::read_to_string("/definitely/not/a/path").context("reading config")?;
        Ok(s)
    }

    #[test]
    fn question_mark_converts_std_errors() {
        let e = io_fail().unwrap_err().to_string();
        assert!(e.starts_with("reading config: "), "got: {e}");
    }

    #[test]
    fn option_context_and_macros() {
        let none: Option<u32> = None;
        assert_eq!(none.context("missing field").unwrap_err().to_string(), "missing field");
        let e: Error = anyhow!("bad value {}", 7);
        assert_eq!(e.to_string(), "bad value 7");
        fn bails() -> Result<()> {
            bail!("nope: {}", 1 + 1)
        }
        assert_eq!(bails().unwrap_err().to_string(), "nope: 2");
    }

    #[test]
    fn with_context_is_lazy() {
        let mut called = false;
        let ok: std::result::Result<u32, String> = Ok(3);
        let v = ok
            .with_context(|| {
                called = true;
                "ctx"
            })
            .unwrap();
        assert_eq!(v, 3);
        assert!(!called, "context closure must not run on Ok");
    }
}
