//! Serving coordinator — the L3 layer.
//!
//! The paper integrates its kernels into LLM inference (§5.2); this module
//! is the serving system that integration needs in production:
//!
//! * [`request`]  — request/response types and generation parameters.
//! * [`batcher`]  — dynamic batcher: collects arrivals into the batch
//!   sizes the AOT artifacts support, under a deadline (vLLM-style
//!   admission, group-static execution — see DESIGN.md).
//! * [`kv`]       — paged KV-cache block allocator (the continuous-
//!   batching substrate; exercised by the scheduler + property tests).
//! * [`backend`]  — execution backend trait: `PjrtBackend` (real model
//!   artifacts, `pjrt` feature) and `SimBackend` (deterministic stand-in
//!   for tests and the coordinator bench; `with_ap_gemm` serves real
//!   bitmm logits through the §3.3 pack-once pipeline).
//! * [`scheduler`]— group scheduler over the backend trait: admission,
//!   prefill/decode interleaving, slot recycling (reserves each
//!   sequence's full budget up front).
//! * [`engine`]   — **continuous-batching decode engine**: batcher-fed
//!   admission, incremental KV growth with swap-style preemption on the
//!   allocator's clean failure, per-step join/leave batching over the
//!   pack-once kernel path — the serving loop the ROADMAP's heavy-traffic
//!   north star needs.
//! * [`metrics`]  — counters + latency percentiles.
//! * [`server`]   — the [`server::Stepper`] abstraction (scheduler and
//!   engine both implement it), the channel serve loop, and the
//!   wall-clock trace replay driver.

pub mod backend;
pub mod batcher;
pub mod cli;
pub mod engine;
pub mod kv;
pub mod metrics;
pub mod request;
pub mod router;
pub mod scheduler;
pub mod server;
pub mod trace;

pub use backend::{drive_unbatched, ApStats, Backend, SimBackend};
pub use batcher::{Batcher, BatcherConfig};
pub use engine::{Engine, EngineConfig, EngineCounters};
pub use kv::{BlockId, KvPool};
pub use metrics::{LatencyStats, Metrics};
pub use request::{sample_token, GenParams, Request, RequestId, Response};
pub use router::{RoutePolicy, Router};
pub use scheduler::{Scheduler, SchedulerConfig};
pub use server::{replay_trace, Server, ServerConfig, Stepper};
pub use trace::{ArrivalKind, TraceConfig};
