//! Serving coordinator — the L3 layer.
//!
//! The paper integrates its kernels into LLM inference (§5.2); this module
//! is the serving system that integration needs in production:
//!
//! * [`request`]  — request/response types and generation parameters.
//! * [`batcher`]  — dynamic batcher: collects arrivals into the batch
//!   sizes the AOT artifacts support, under a deadline (vLLM-style
//!   admission, group-static execution — see DESIGN.md).
//! * [`kv`]       — paged KV-cache block allocator (the continuous-
//!   batching substrate; exercised by the scheduler + property tests).
//! * [`backend`]  — execution backend trait: `PjrtBackend` (real model
//!   artifacts, `pjrt` feature) and `SimBackend` (deterministic stand-in
//!   for tests and the coordinator bench; `with_ap_gemm` serves real
//!   bitmm logits through the §3.3 pack-once pipeline).
//! * [`scheduler`]— continuous-batching scheduler over the backend trait:
//!   admission, prefill/decode interleaving, slot recycling.
//! * [`metrics`]  — counters + latency percentiles.
//! * [`server`]   — ties engine + batcher into a multi-threaded serve
//!   loop over mpsc channels (PJRT handles stay on one executor thread).

pub mod backend;
pub mod batcher;
pub mod cli;
pub mod kv;
pub mod metrics;
pub mod request;
pub mod router;
pub mod scheduler;
pub mod server;
pub mod trace;

pub use backend::{ApStats, Backend, SimBackend};
pub use batcher::{Batcher, BatcherConfig};
pub use kv::{BlockId, KvPool};
pub use metrics::{LatencyStats, Metrics};
pub use request::{GenParams, Request, RequestId, Response};
pub use router::{RoutePolicy, Router};
pub use scheduler::{Scheduler, SchedulerConfig};
pub use server::{Server, ServerConfig};
pub use trace::{ArrivalKind, TraceConfig};
