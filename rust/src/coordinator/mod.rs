//! Serving coordinator — the L3 layer.
//!
//! The paper integrates its kernels into LLM inference (§5.2); this module
//! is the serving system that integration needs in production:
//!
//! * [`request`]  — request/response types, generation parameters, and
//!   the streaming [`TokenEvent`] protocol (admission, per-token,
//!   preempt/resume, terminal — tokens reach clients as generated).
//! * [`batcher`]  — dynamic batcher: collects arrivals into the batch
//!   sizes the AOT artifacts support, under a deadline (vLLM-style
//!   admission, group-static execution — see DESIGN.md).
//! * [`kv`]       — paged KV-cache allocator with **refcounted
//!   copy-on-write blocks and a hash-based prefix cache**: requests
//!   sharing a prompt prefix map their block-table heads onto shared
//!   physical blocks; released full blocks stay content-addressable until
//!   reallocated; `fork` clones tables refcount-only and the first
//!   divergent append copy-on-writes.  Free blocks live on an **O(1)
//!   intrusive doubly-linked list whose order is the eviction order**
//!   (`EvictionPolicy::Lru` by default — releasing is the recency touch,
//!   so hot prefix content survives; `Lifo` is the PR 3 baseline kept
//!   for the bench), with cache restores unlinking from the middle in
//!   O(1) instead of the retired O(free) scan.
//! * [`backend`]  — execution backend trait: `PjrtBackend` (real model
//!   artifacts, `pjrt` feature) and `SimBackend` (deterministic stand-in
//!   for tests and the coordinator bench; `with_ap_gemm` serves real
//!   bitmm logits through the §3.3 pack-once pipeline).  Weights live in
//!   **one shared any-precision superset store per cluster**
//!   (`superset_store` + `SimBackend::with_shared_store`): the pack
//!   happens once at the widest precision served and every replica
//!   slices its own plane prefix per step — no per-precision weight
//!   duplication.  The AP-GEMM logits shard across the persistent
//!   worker pool (`Backend::set_workers`, sized per replica by
//!   `EngineConfig::workers` / `ClusterSpec::worker_budget` so N
//!   replicas split the host instead of oversubscribing it).
//! * [`engine`]   — **continuous-batching decode engine**, the one
//!   serving state machine: batcher-fed admission under a selectable
//!   [`AdmissionPolicy`] (`Optimistic` reserves the prompt and grows per
//!   token with swap-style preemption on the allocator's clean failure;
//!   `Reserve` books the full `prompt + max_new` budget up front and
//!   never preempts — the retired group scheduler's semantics, folded in
//!   as a config switch), per-step join/leave batching over
//!   the pack-once kernel path, streaming every token as an event.
//!   Swapped sequences are exportable (`Engine::export_swapped` →
//!   `ExportedSeq` → `Engine::import_swapped`) so a peer replica can
//!   take the work over byte-identically.  **Self-speculative decoding**
//!   (`EngineConfig::spec_k`/`draft_bits`): each decode step drafts up
//!   to `spec_k` tokens per sequence from the `draft_bits`-wide MSB
//!   plane prefix of the *same* weight pack — zero extra weight bytes —
//!   then verifies every position in ONE wide batched decode and keeps
//!   the longest agreeing prefix; greedy (and seeded-Gumbel) acceptance
//!   keeps streams byte-identical to plain decode, so accepted drafts
//!   are pure decode-step savings.  Un-accepted KV rolls back inside
//!   the step, so exported/migrated sequences never carry draft state.
//! * [`router`]   — per-request replica selection (round-robin or
//!   least-loaded, with optional precision pinning and **replica roles**:
//!   every request is admitted to a prefill-capable replica, decode-only
//!   replicas are fed by migration) and conserved load accounting split
//!   into prefill/decode components, transferred by `Router::migrate`
//!   when a sequence moves and topped up by `Router::charge_reprefill`
//!   when an import must re-prefill.
//! * [`cluster`]  — **the multi-replica composition**: N engine replicas
//!   (each its own `KvPool`/batcher, all slicing one shared superset
//!   weight store at their own W/A precision) behind the router, itself
//!   a [`Stepper`] — the serving topology the ROADMAP's heavy-traffic
//!   north star calls for.  A whole topology is declared as a
//!   [`ClusterSpec`] of [`ReplicaSpec`]s (name, precision, role, engine
//!   shape, speculation, worker budget) and built in one
//!   [`Cluster::new`] call.  After every step it **rebalances**: the
//!   oldest swapped sequences on overloaded replicas migrate to
//!   same-precision decode-capable peers with KV headroom
//!   (`TokenEvent::Migrated` between `Preempted` and the target's
//!   `Resumed`), and — for unpinned requests with no same-precision
//!   escape — **across the precision boundary**: the KV is dropped and
//!   the target re-prefills the prompt + generated tokens at its own
//!   precision (`TokenEvent::Requantized` after `Migrated`), streamed
//!   bytes unchanged.
//!
//! ## Replica roles: disaggregated prefill/decode serving
//!
//! [`ReplicaRole`] makes prefill/decode disaggregation first-class:
//! `Prefill` replicas admit and prefill but hand every freshly prefilled
//! sequence to a decode-capable peer (`Engine::prefilled_ready` /
//! `Engine::export_running` under `EngineConfig::prefill_hold`, with
//! `TokenEvent::PrefillDone` streamed immediately before the `Migrated`);
//! `Decode` replicas never admit fresh requests and are fed exclusively
//! by handoffs and rebalancing; `Mixed` (the default) does both — an
//! all-`Mixed` cluster is byte-for-byte the symmetric baseline.  Both
//! migration paths gate on `Engine::import_fit`, which answers
//! fits / needs-requant / rejected-with-reason for a candidate import.
//! A held sequence no peer admits decodes locally on the next step, so a
//! saturated decode tier degrades to mixed behavior instead of stranding
//! streams.
//! * [`metrics`]  — counters, latency percentiles (incl. streamed
//!   TTFT/ITL), resident-vs-swapped KV and prefix-cache hit/eviction
//!   gauges, the migration counter, and cross-replica merge.
//! * [`server`]   — the [`server::Stepper`] abstraction (engine and
//!   cluster both implement it), the channel serve loop that streams
//!   events, and the wall-clock trace replay driver.

pub mod backend;
pub mod batcher;
pub mod cli;
pub mod cluster;
pub mod engine;
pub mod kv;
pub mod metrics;
pub mod request;
pub mod router;
pub mod server;
pub mod trace;

pub use backend::{drive_unbatched, superset_store, ApStats, Backend, SimBackend};
pub use batcher::{Batcher, BatcherConfig};
pub use cluster::{Cluster, ClusterSpec, ReplicaSpec};
pub use engine::{
    AdmissionPolicy, Engine, EngineConfig, EngineCounters, ExportedSeq, ImportFit, SwappedPeek,
};
pub use kv::{BlockId, EvictionPolicy, KvPool, KvSharing};
pub use metrics::{LatencySnapshot, LatencyStats, Metrics};
pub use request::{
    responses_of, sample_token, GenParams, Request, RequestId, Response, TokenEvent,
};
pub use router::{Replica, ReplicaRole, RoutePolicy, Router};
pub use server::{drain, replay_trace, Server, ServerConfig, Stepper};
pub use trace::{ArrivalKind, TraceConfig};
