//! Paged KV-cache block allocator with **refcounted copy-on-write blocks
//! and a hash-based prefix cache** (vLLM-style automatic prefix caching)
//! — the memory-management substrate for continuous batching.
//!
//! The cache is a pool of fixed-size blocks (`block_tokens` KV slots
//! each); a sequence owns an ordered block list that grows as it decodes.
//! Unlike the PR 2 allocator, blocks are no longer private: every block
//! carries a reference count, and **full** blocks are content-addressed
//! by a chained hash of the tokens they hold.  Admitting a prompt through
//! [`KvPool::admit_shared`] maps its leading full blocks onto any cached
//! block with the same chained hash — requests sharing a system prompt
//! share physical KV blocks instead of duplicating them.  Three sharing
//! mechanisms compose:
//!
//! * **prefix hits** — an admit whose leading blocks hash-match blocks
//!   another live sequence holds bumps their refcounts (`shared_live`);
//! * **cache restores** — a hash-match against a block whose last owner
//!   already released it (refcount 0, content retained on the free list)
//!   revives it without a fresh allocation (`cache_restores`);
//! * **fork** — [`KvPool::fork`] clones a whole table refcount-only, and
//!   the first append into a shared *partial* block triggers a true
//!   **copy-on-write** split (`cow_copies`).
//!
//! ## Eviction order: an O(1) intrusive free list
//!
//! Free blocks (refcount 0, cached content retained) live on an
//! **intrusive doubly-linked list** threaded through per-block
//! `next`/`prev` slots, so every operation the churn path needs is O(1):
//! freeing a block links it in, a cache restore **unlinks it from the
//! middle** (the PR 3 `Vec` free list paid an O(free) scan here), and a
//! fresh allocation pops the eviction end.  The list order *is* the
//! eviction order, selected by [`EvictionPolicy`]:
//!
//! * [`EvictionPolicy::Lru`] (default) — freed blocks join the warm end;
//!   allocations evict the **least-recently-used** block.  A restore or a
//!   live share keeps a block off the list while referenced, and its next
//!   release re-files it at the warm end — touch-on-hit recency, so hot
//!   prefix blocks survive and cold ones are cannibalized first.
//! * [`EvictionPolicy::Lifo`] — the PR 3 baseline (freed blocks are
//!   evicted newest-first), kept so the serving bench can report what LRU
//!   buys: under cyclic prefix reuse LIFO reallocates exactly the blocks
//!   that were just registered, destroying the cache it just built.
//!
//! The allocator guarantees: a block's refcount always equals the number
//! of table references to it, a block is freed exactly when its last
//! reference drops, frees never orphan a live reference, and capacity is
//! respected (allocation fails cleanly when the pool is exhausted — the
//! engine's preemption signal).  [`KvPool::check_invariants`] proves
//! block conservation under sharing after every churn step of the
//! property tests, plus the free-list laws: both link directions agree,
//! the list holds exactly the refcount-0 blocks, and the free timestamps
//! are monotone in eviction order (the LRU/LIFO law).

use std::collections::HashMap;

/// Index of a physical cache block.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub struct BlockId(pub u32);

/// Sentinel for "no link" in the intrusive free list.
const NIL: u32 = u32::MAX;

/// Which free block a fresh allocation cannibalizes (and therefore which
/// cached prefix content dies first).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub enum EvictionPolicy {
    /// Evict the least-recently-freed block: freed blocks join the warm
    /// end of the list, allocations pop the cold end.  Recency-aware —
    /// the production default.
    #[default]
    Lru,
    /// Evict the most-recently-freed block (stack order) — the PR 3
    /// baseline, kept for the bench comparison.
    Lifo,
}

/// Per-sequence block table.
#[derive(Debug, Default, Clone)]
pub struct BlockTable {
    pub blocks: Vec<BlockId>,
    pub tokens: usize,
}

/// Sharing / allocation counters (cumulative for the pool's lifetime).
#[derive(Debug, Default, Clone, Copy)]
pub struct KvSharing {
    /// Blocks taken fresh off the free list (cached content, if any,
    /// invalidated).  The no-sharing baseline allocates one of these per
    /// logical block; the difference is the blocks sharing saved.
    pub fresh_allocs: u64,
    /// Admitted blocks mapped onto a block another sequence holds
    /// (refcount bumped — zero allocation cost).
    pub shared_live: u64,
    /// Admitted blocks revived from the free list by hash (content
    /// retained from a released sequence — costs a free slot, saves the
    /// prefill recompute).
    pub cache_restores: u64,
    /// Copy-on-write splits: appends into a block with refcount > 1.
    pub cow_copies: u64,
    /// Prefix-cache registrations invalidated: a fresh allocation reused
    /// the block (the eviction the policy chooses), or a re-registration
    /// displaced a stale deeper-chain entry.
    pub evictions: u64,
    /// High-water mark of simultaneously used (refcount > 0) blocks.
    pub peak_used: usize,
}

impl KvSharing {
    /// Logical blocks admitted = fresh + shared + restored.
    pub fn logical_blocks(&self) -> u64 {
        self.fresh_allocs + self.shared_live + self.cache_restores
    }

    /// Fraction of admitted blocks served by the prefix cache (live
    /// shares + restores over all logical blocks); 0 when nothing was
    /// admitted yet.
    pub fn hit_rate(&self) -> f64 {
        let logical = self.logical_blocks();
        if logical == 0 {
            return 0.0;
        }
        (self.shared_live + self.cache_restores) as f64 / logical as f64
    }

    /// Fraction of admitted blocks revived off the free list — the rate
    /// the eviction policy directly controls (live shares don't touch the
    /// free list; restores only exist while their content survives it).
    pub fn restore_rate(&self) -> f64 {
        let logical = self.logical_blocks();
        if logical == 0 {
            return 0.0;
        }
        self.cache_restores as f64 / logical as f64
    }
}

/// Chained FNV-1a over a block's tokens: `prev` is the hash of the whole
/// prefix before this block, so equal hashes mean equal full prefixes
/// (modulo 64-bit collisions).
fn chain_hash(prev: u64, tokens: &[i32]) -> u64 {
    let mut h = prev ^ 0xcbf2_9ce4_8422_2325;
    for &t in tokens {
        for b in t.to_le_bytes() {
            h ^= b as u64;
            h = h.wrapping_mul(0x100_0000_01b3);
        }
    }
    h
}

/// Fixed-capacity refcounted block pool with a prefix cache.
pub struct KvPool {
    block_tokens: usize,
    total_blocks: usize,
    policy: EvictionPolicy,
    /// Per-block reference count; 0 = free (possibly still cached).
    refs: Vec<u32>,
    /// The chained content hash a block is registered under, if any.
    hash_of: Vec<Option<u64>>,
    /// Intrusive free-list links (NIL when the block is referenced).
    next: Vec<u32>,
    prev: Vec<u32>,
    /// Cold end — `alloc_fresh` evicts here.
    free_head: u32,
    /// Warm end — LRU frees land here.
    free_tail: u32,
    free_len: usize,
    /// Monotone stamp assigned when a block joins the free list; the
    /// invariant checker asserts it is monotone along the list (the
    /// LRU/LIFO ordering law).
    freed_at: Vec<u64>,
    free_clock: u64,
    /// Prefix cache: chained hash → the block holding that content.
    cache: HashMap<u64, BlockId>,
    tables: HashMap<u64, BlockTable>,
    /// Used-block counter (kept in lockstep; verified by the invariants).
    used: usize,
    stats: KvSharing,
}

/// One admit's sharing plan: which leading full blocks hit the cache.
struct SharePlan {
    /// (block, was_live) per hash hit, in prefix order.
    hits: Vec<(BlockId, bool)>,
    /// Hashes of ALL full blocks (hits first, then misses to register).
    full_hashes: Vec<u64>,
    /// Total blocks the sequence needs.
    need_total: usize,
    /// How many must come off the free list (misses + partial tail +
    /// refcount-0 cache hits — live hits are free).
    need_from_free: usize,
}

impl KvPool {
    /// Pool with the default [`EvictionPolicy::Lru`].
    pub fn new(total_blocks: usize, block_tokens: usize) -> Self {
        Self::with_policy(total_blocks, block_tokens, EvictionPolicy::default())
    }

    pub fn with_policy(total_blocks: usize, block_tokens: usize, policy: EvictionPolicy) -> Self {
        assert!(block_tokens > 0 && total_blocks > 0);
        let mut pool = Self {
            block_tokens,
            total_blocks,
            policy,
            refs: vec![0; total_blocks],
            hash_of: vec![None; total_blocks],
            next: vec![NIL; total_blocks],
            prev: vec![NIL; total_blocks],
            free_head: NIL,
            free_tail: NIL,
            free_len: 0,
            freed_at: vec![0; total_blocks],
            free_clock: 0,
            cache: HashMap::new(),
            tables: HashMap::new(),
            used: 0,
            stats: KvSharing::default(),
        };
        // never-used blocks start coldest, lowest index first — both
        // policies allocate 0, 1, 2, … from an empty pool
        match policy {
            EvictionPolicy::Lru => {
                (0..total_blocks as u32).for_each(|b| pool.free_push(BlockId(b)))
            }
            EvictionPolicy::Lifo => {
                (0..total_blocks as u32).rev().for_each(|b| pool.free_push(BlockId(b)))
            }
        }
        pool
    }

    pub fn free_blocks(&self) -> usize {
        self.free_len
    }

    /// Blocks with at least one live reference.
    pub fn used_blocks(&self) -> usize {
        self.used
    }

    /// Pool capacity — `used_blocks() + free_blocks()` always equals this
    /// (the conservation law the churn tests pin down).
    pub fn total_blocks(&self) -> usize {
        self.total_blocks
    }

    pub fn block_tokens(&self) -> usize {
        self.block_tokens
    }

    pub fn eviction_policy(&self) -> EvictionPolicy {
        self.policy
    }

    /// Sharing/allocation counters.
    pub fn sharing(&self) -> KvSharing {
        self.stats
    }

    /// Reference count of one block (tests / introspection).
    pub fn refcount(&self, b: BlockId) -> u32 {
        self.refs[b.0 as usize]
    }

    /// The free list in eviction order (next victim first).  O(free) —
    /// tests and introspection only; the churn path never materializes
    /// this.
    pub fn free_order(&self) -> Vec<BlockId> {
        let mut out = Vec::with_capacity(self.free_len);
        let mut cur = self.free_head;
        while cur != NIL && out.len() <= self.total_blocks {
            out.push(BlockId(cur));
            cur = self.next[cur as usize];
        }
        out
    }

    /// Blocks needed to hold `tokens` KV entries.
    pub fn blocks_for(&self, tokens: usize) -> usize {
        tokens.div_ceil(self.block_tokens)
    }

    /// Can a sequence of `tokens` be admitted privately right now?
    pub fn can_admit(&self, tokens: usize) -> bool {
        self.blocks_for(tokens.max(1)) <= self.free_len
    }

    /// Can `prompt` be admitted through the prefix cache right now?
    /// (Live hash hits cost nothing, so this can pass where [`can_admit`]
    /// fails — sharing is what lets more sequences fit the pool.)
    pub fn can_admit_shared(&self, prompt: &[i32]) -> bool {
        self.plan_shared(prompt).need_from_free <= self.free_len
    }

    // ------------------------------------------- intrusive free list --

    /// Link a refcount-0 block into the free list at the position the
    /// eviction policy dictates (LRU: warm end; LIFO: cold end).  O(1).
    fn free_push(&mut self, b: BlockId) {
        let i = b.0 as usize;
        debug_assert!(self.next[i] == NIL && self.prev[i] == NIL, "block {} double-linked", b.0);
        self.freed_at[i] = self.free_clock;
        self.free_clock += 1;
        match self.policy {
            EvictionPolicy::Lru => {
                self.prev[i] = self.free_tail;
                self.next[i] = NIL;
                if self.free_tail == NIL {
                    self.free_head = b.0;
                } else {
                    self.next[self.free_tail as usize] = b.0;
                }
                self.free_tail = b.0;
            }
            EvictionPolicy::Lifo => {
                self.next[i] = self.free_head;
                self.prev[i] = NIL;
                if self.free_head == NIL {
                    self.free_tail = b.0;
                } else {
                    self.prev[self.free_head as usize] = b.0;
                }
                self.free_head = b.0;
            }
        }
        self.free_len += 1;
    }

    /// Unlink a block from anywhere in the free list — the O(1) middle
    /// removal cache restores ride on (the PR 3 `Vec` scan retired).
    fn free_unlink(&mut self, b: BlockId) {
        let i = b.0 as usize;
        let (p, n) = (self.prev[i], self.next[i]);
        if p == NIL {
            debug_assert_eq!(self.free_head, b.0, "unlink of unlisted block {}", b.0);
            self.free_head = n;
        } else {
            self.next[p as usize] = n;
        }
        if n == NIL {
            debug_assert_eq!(self.free_tail, b.0, "unlink of unlisted block {}", b.0);
            self.free_tail = p;
        } else {
            self.prev[n as usize] = p;
        }
        self.next[i] = NIL;
        self.prev[i] = NIL;
        self.free_len -= 1;
    }

    /// Pop the eviction end (the policy's next victim).  O(1).
    fn free_pop_evict(&mut self) -> Option<BlockId> {
        if self.free_head == NIL {
            return None;
        }
        let b = BlockId(self.free_head);
        self.free_unlink(b);
        Some(b)
    }

    /// Pop one block off the free list for exclusive use, invalidating
    /// whatever cached content it retained.
    fn alloc_fresh(&mut self) -> Option<BlockId> {
        let b = self.free_pop_evict()?;
        if let Some(h) = self.hash_of[b.0 as usize].take() {
            self.cache.remove(&h);
            self.stats.evictions += 1;
        }
        self.refs[b.0 as usize] = 1;
        self.used += 1;
        self.stats.fresh_allocs += 1;
        self.note_peak();
        Some(b)
    }

    fn note_peak(&mut self) {
        self.stats.peak_used = self.stats.peak_used.max(self.used);
    }

    /// Compute the sharing plan for a prompt without mutating anything.
    /// Sharing stops at the first cache miss: a chained hash identifies
    /// the entire prefix, so anything after a miss is new content.
    fn plan_shared(&self, prompt: &[i32]) -> SharePlan {
        let tokens = prompt.len();
        let full = tokens / self.block_tokens;
        let need_total = self.blocks_for(tokens.max(1));
        let mut full_hashes = Vec::with_capacity(full);
        let mut hits = Vec::new();
        let mut h = 0u64;
        let mut missed = false;
        for i in 0..full {
            h = chain_hash(h, &prompt[i * self.block_tokens..(i + 1) * self.block_tokens]);
            full_hashes.push(h);
            if !missed {
                match self.cache.get(&h) {
                    Some(&b) => hits.push((b, self.refs[b.0 as usize] > 0)),
                    None => missed = true,
                }
            }
        }
        let live_hits = hits.iter().filter(|(_, live)| *live).count();
        SharePlan { hits, full_hashes, need_total, need_from_free: need_total - live_hits }
    }

    /// Allocate the blocks for a new sequence of `tokens` (its prompt)
    /// **privately** — no prefix sharing, every block fresh.  Fails
    /// (without side effects) if the pool can't hold it.  This is the
    /// baseline path (and the only one `AdmissionPolicy::Reserve` takes).
    pub fn admit(&mut self, seq: u64, tokens: usize) -> Result<(), KvError> {
        if self.tables.contains_key(&seq) {
            return Err(KvError::AlreadyAdmitted(seq));
        }
        let need = self.blocks_for(tokens.max(1));
        if need > self.free_len {
            return Err(KvError::OutOfBlocks { need, free: self.free_len });
        }
        let blocks: Vec<BlockId> = (0..need).map(|_| self.alloc_fresh().unwrap()).collect();
        self.tables.insert(seq, BlockTable { blocks, tokens });
        Ok(())
    }

    /// Allocate the blocks for a new sequence whose KV holds exactly
    /// `prompt`, mapping leading full blocks onto cached blocks with the
    /// same chained content hash.  Newly filled full blocks are
    /// registered in the prefix cache for later arrivals; the partial
    /// tail block (where decoding writes) is always private.  Fails
    /// without side effects when even sharing can't fit the prompt.
    pub fn admit_shared(&mut self, seq: u64, prompt: &[i32]) -> Result<(), KvError> {
        if self.tables.contains_key(&seq) {
            return Err(KvError::AlreadyAdmitted(seq));
        }
        let plan = self.plan_shared(prompt);
        if plan.need_from_free > self.free_len {
            return Err(KvError::OutOfBlocks {
                need: plan.need_from_free,
                free: self.free_len,
            });
        }
        let mut blocks = Vec::with_capacity(plan.need_total);
        for &(b, live) in &plan.hits {
            if live {
                self.refs[b.0 as usize] += 1;
                self.stats.shared_live += 1;
            } else {
                // revive the cached block: O(1) unlink from wherever it
                // sits in the list.  Its content survives untouched; its
                // recency resets when the new owner releases it.
                self.free_unlink(b);
                self.refs[b.0 as usize] = 1;
                self.used += 1;
                self.stats.cache_restores += 1;
                self.note_peak();
            }
            blocks.push(b);
        }
        // full blocks past the hit prefix: fresh, and registered so the
        // NEXT request with this prefix shares them.  A deeper-chain
        // entry can outlive an evicted earlier-chain one (eviction is
        // per-block), so the plan's first-miss cutoff does not mean the
        // later hashes are absent — displace any stale registration or
        // the cache↔hash_of bijection breaks.
        for &h in &plan.full_hashes[blocks.len()..] {
            let b = self.alloc_fresh().unwrap();
            if let Some(old) = self.cache.insert(h, b) {
                self.hash_of[old.0 as usize] = None;
                self.stats.evictions += 1;
            }
            self.hash_of[b.0 as usize] = Some(h);
            blocks.push(b);
        }
        // private partial tail (where decode appends land)
        while blocks.len() < plan.need_total {
            blocks.push(self.alloc_fresh().unwrap());
        }
        self.tables.insert(seq, BlockTable { blocks, tokens: prompt.len() });
        Ok(())
    }

    /// Clone `parent`'s table for `child` by bumping refcounts only —
    /// zero blocks allocated.  The first divergent append on either side
    /// copy-on-writes the shared partial tail.
    pub fn fork(&mut self, parent: u64, child: u64) -> Result<(), KvError> {
        if self.tables.contains_key(&child) {
            return Err(KvError::AlreadyAdmitted(child));
        }
        let t = self.tables.get(&parent).ok_or(KvError::UnknownSeq(parent))?.clone();
        for b in &t.blocks {
            self.refs[b.0 as usize] += 1;
        }
        self.tables.insert(child, t);
        Ok(())
    }

    /// Extend a sequence by one decoded token.  Crossing a block boundary
    /// allocates a fresh private block; writing into a block shared with
    /// another table (refcount > 1) first splits it copy-on-write.
    pub fn append_token(&mut self, seq: u64) -> Result<(), KvError> {
        let t = self.tables.get(&seq).ok_or(KvError::UnknownSeq(seq))?;
        let write_block = t.tokens / self.block_tokens;
        if write_block >= t.blocks.len() {
            // boundary: the write lands past every owned block
            let b = self
                .alloc_fresh()
                .ok_or(KvError::OutOfBlocks { need: 1, free: 0 })?;
            self.tables.get_mut(&seq).unwrap().blocks.push(b);
        } else {
            let b = t.blocks[write_block];
            if self.refs[b.0 as usize] > 1 {
                // copy-on-write: split before mutating shared content
                let nb = self
                    .alloc_fresh()
                    .ok_or(KvError::OutOfBlocks { need: 1, free: 0 })?;
                self.refs[b.0 as usize] -= 1;
                self.stats.cow_copies += 1;
                // (on a real device this is where the block's KV rows
                // would be memcpy'd; here content lives host-side)
                self.tables.get_mut(&seq).unwrap().blocks[write_block] = nb;
            }
        }
        self.tables.get_mut(&seq).unwrap().tokens += 1;
        Ok(())
    }

    /// Release every reference a sequence holds; blocks whose refcount
    /// drops to zero return to the free list **with their prefix-cache
    /// registration retained**, so a later identical prompt can revive
    /// them until the slot is reallocated.  Under LRU the freed blocks
    /// land at the warm end — releasing IS the recency touch — in
    /// **reverse table order**, so the unregistered decode tail is the
    /// coldest of the batch and dies first while the chain-head prefix
    /// block (the one `plan_shared` must hit for any of the chain to be
    /// reachable) stays warmest.  LIFO keeps PR 3's forward order
    /// exactly (head-first stack pushes → the tail still pops first),
    /// so the bench baseline really is the behavior it claims to be.
    pub fn release(&mut self, seq: u64) -> Result<(), KvError> {
        let t = self.tables.remove(&seq).ok_or(KvError::UnknownSeq(seq))?;
        let ordered: Vec<BlockId> = match self.policy {
            EvictionPolicy::Lru => t.blocks.into_iter().rev().collect(),
            EvictionPolicy::Lifo => t.blocks,
        };
        for b in ordered {
            let r = &mut self.refs[b.0 as usize];
            debug_assert!(*r > 0, "release of unreferenced block {}", b.0);
            *r -= 1;
            if *r == 0 {
                self.used -= 1;
                self.free_push(b);
            }
        }
        Ok(())
    }

    /// Roll back the last `n` tokens of a sequence — the speculative
    /// decode **rejection path**: the engine appends KV slots for drafted
    /// positions *before* the wide verify step, and the slots of the
    /// rejected suffix must return to the pool as if never written.
    /// Blocks that drop past the new boundary lose one reference each;
    /// those reaching refcount 0 rejoin the free list exactly as
    /// [`KvPool::release`] files them (cache registration retained, same
    /// per-policy ordering), so rollback is indistinguishable from a
    /// release of just the tail.  A block the surviving prefix still
    /// covers is kept even if the rolled-back tokens wrote into it — its
    /// slots are simply overwritten by the next append.  Shared blocks
    /// (e.g. a CoW split that happened during the speculative appends)
    /// only shed this sequence's reference, never another holder's.
    pub fn truncate_tokens(&mut self, seq: u64, n: usize) -> Result<(), KvError> {
        if n == 0 {
            return Ok(());
        }
        let block_tokens = self.block_tokens;
        let t = self.tables.get_mut(&seq).ok_or(KvError::UnknownSeq(seq))?;
        if n > t.tokens {
            return Err(KvError::TruncateUnderflow { tokens: t.tokens, drop: n });
        }
        t.tokens -= n;
        // a table always holds ≥ 1 block (admit reserves for max(1))
        let keep = t.tokens.max(1).div_ceil(block_tokens);
        let mut dropped = Vec::new();
        while t.blocks.len() > keep {
            dropped.push(t.blocks.pop().unwrap());
        }
        // match release's per-policy free order: LRU frees deepest-first
        // (popped order) so the shallower block stays warmer; LIFO keeps
        // the forward table order
        if self.policy == EvictionPolicy::Lifo {
            dropped.reverse();
        }
        for b in dropped {
            let r = &mut self.refs[b.0 as usize];
            debug_assert!(*r > 0, "truncate of unreferenced block {}", b.0);
            *r -= 1;
            if *r == 0 {
                self.used -= 1;
                self.free_push(b);
            }
        }
        Ok(())
    }

    pub fn table(&self, seq: u64) -> Option<&BlockTable> {
        self.tables.get(&seq)
    }

    pub fn active_seqs(&self) -> usize {
        self.tables.len()
    }

    /// Internal consistency under sharing:
    /// * every block's refcount equals the number of table references;
    /// * the free list holds exactly the refcount-0 blocks, once each,
    ///   with forward/backward links agreeing, both ends terminating,
    ///   and no cycle;
    /// * free stamps are monotone along the list — increasing for LRU
    ///   (head is the least recently freed), decreasing for LIFO — so
    ///   the eviction order provably matches the policy;
    /// * referenced blocks are fully unlinked;
    /// * no table references the same block twice;
    /// * every cache entry is a bijection with `hash_of`;
    /// * `used + free == total` (block conservation);
    /// * every table holds enough blocks for its token count.
    pub fn check_invariants(&self) -> Result<(), String> {
        let mut counted = vec![0u32; self.total_blocks];
        for (seq, t) in &self.tables {
            let mut seen = std::collections::HashSet::new();
            for b in &t.blocks {
                if !seen.insert(b.0) {
                    return Err(format!("seq {seq} references block {} twice", b.0));
                }
                counted[b.0 as usize] += 1;
            }
            if t.blocks.len() < self.blocks_for(t.tokens) {
                return Err(format!("seq {seq}: {} tokens in {} blocks", t.tokens, t.blocks.len()));
            }
        }
        for (i, (&c, &r)) in counted.iter().zip(&self.refs).enumerate() {
            if c != r {
                return Err(format!("block {i}: refcount {r} but {c} table references"));
            }
        }
        // intrusive free-list integrity + the eviction-order law
        let mut free_seen = std::collections::HashSet::new();
        let mut cur = self.free_head;
        let mut prev = NIL;
        let mut last_stamp: Option<u64> = None;
        let mut walked = 0usize;
        while cur != NIL {
            walked += 1;
            if walked > self.total_blocks {
                return Err("free list cycle".into());
            }
            let i = cur as usize;
            if !free_seen.insert(cur) {
                return Err(format!("block {cur} linked twice"));
            }
            if self.refs[i] != 0 {
                return Err(format!("block {cur} on the free list with refcount {}", self.refs[i]));
            }
            if self.prev[i] != prev {
                return Err(format!(
                    "block {cur}: prev link {} but walked from {prev}",
                    self.prev[i]
                ));
            }
            if let Some(last) = last_stamp {
                let ordered = match self.policy {
                    EvictionPolicy::Lru => self.freed_at[i] > last,
                    EvictionPolicy::Lifo => self.freed_at[i] < last,
                };
                if !ordered {
                    return Err(format!(
                        "eviction order violates {:?}: stamp {} after {last}",
                        self.policy, self.freed_at[i]
                    ));
                }
            }
            last_stamp = Some(self.freed_at[i]);
            prev = cur;
            cur = self.next[i];
        }
        if prev != self.free_tail {
            return Err(format!("free tail {} but walk ended at {prev}", self.free_tail));
        }
        if walked != self.free_len {
            return Err(format!("free_len {} but {walked} linked blocks", self.free_len));
        }
        for (i, &r) in self.refs.iter().enumerate() {
            if r == 0 && !free_seen.contains(&(i as u32)) {
                return Err(format!("refcount-0 block {i} missing from the free list"));
            }
            if r > 0 && (self.next[i] != NIL || self.prev[i] != NIL) {
                return Err(format!("referenced block {i} still linked"));
            }
        }
        let used = self.refs.iter().filter(|&&r| r > 0).count();
        if used != self.used {
            return Err(format!("used counter {} but {used} referenced blocks", self.used));
        }
        if used + self.free_len != self.total_blocks {
            return Err(format!(
                "{} used + {} free != {} total",
                used, self.free_len, self.total_blocks
            ));
        }
        for (&h, &b) in &self.cache {
            if self.hash_of[b.0 as usize] != Some(h) {
                return Err(format!("cache hash {h:#x} points at block {} not holding it", b.0));
            }
        }
        for (i, h) in self.hash_of.iter().enumerate() {
            if let Some(h) = h {
                if self.cache.get(h) != Some(&BlockId(i as u32)) {
                    return Err(format!("block {i} registered under {h:#x} but cache disagrees"));
                }
            }
        }
        Ok(())
    }
}

#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum KvError {
    OutOfBlocks { need: usize, free: usize },
    UnknownSeq(u64),
    AlreadyAdmitted(u64),
    /// [`KvPool::truncate_tokens`] asked to drop more tokens than the
    /// sequence holds — always a caller bookkeeping bug.
    TruncateUnderflow { tokens: usize, drop: usize },
}

impl std::fmt::Display for KvError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            KvError::OutOfBlocks { need, free } => {
                write!(f, "out of KV blocks (need {need}, free {free})")
            }
            KvError::UnknownSeq(s) => write!(f, "unknown sequence {s}"),
            KvError::AlreadyAdmitted(s) => write!(f, "sequence {s} already admitted"),
            KvError::TruncateUnderflow { tokens, drop } => {
                write!(f, "truncate of {drop} tokens from a {tokens}-token sequence")
            }
        }
    }
}

impl std::error::Error for KvError {}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::proptest::forall;
    use std::collections::VecDeque;

    #[test]
    fn admit_and_release() {
        let mut p = KvPool::new(10, 16);
        assert!(p.can_admit(160));
        assert!(!p.can_admit(161));
        p.admit(1, 100).unwrap();
        assert_eq!(p.used_blocks(), 7);
        assert_eq!(p.table(1).unwrap().tokens, 100);
        p.release(1).unwrap();
        assert_eq!(p.free_blocks(), 10);
        p.check_invariants().unwrap();
    }

    #[test]
    fn admission_is_atomic() {
        let mut p = KvPool::new(4, 16);
        p.admit(1, 40).unwrap(); // 3 blocks
        let err = p.admit(2, 40).unwrap_err();
        assert!(matches!(err, KvError::OutOfBlocks { need: 3, free: 1 }));
        assert_eq!(p.free_blocks(), 1, "failed admit must not leak");
        p.check_invariants().unwrap();
    }

    #[test]
    fn append_grows_at_boundary() {
        let mut p = KvPool::new(4, 4);
        p.admit(7, 4).unwrap(); // exactly one block
        assert_eq!(p.table(7).unwrap().blocks.len(), 1);
        p.append_token(7).unwrap(); // 5th token → second block
        assert_eq!(p.table(7).unwrap().blocks.len(), 2);
        for _ in 0..3 {
            p.append_token(7).unwrap();
        }
        assert_eq!(p.table(7).unwrap().blocks.len(), 2, "8 tokens fit 2 blocks");
        p.append_token(7).unwrap();
        assert_eq!(p.table(7).unwrap().blocks.len(), 3);
        p.check_invariants().unwrap();
    }

    #[test]
    fn double_admit_and_unknown_release() {
        let mut p = KvPool::new(4, 4);
        p.admit(1, 2).unwrap();
        assert!(matches!(p.admit(1, 2), Err(KvError::AlreadyAdmitted(1))));
        assert!(matches!(p.release(9), Err(KvError::UnknownSeq(9))));
        assert!(matches!(p.append_token(9), Err(KvError::UnknownSeq(9))));
        assert!(matches!(p.fork(9, 10), Err(KvError::UnknownSeq(9))));
        assert!(matches!(p.fork(1, 1), Err(KvError::AlreadyAdmitted(1))));
    }

    #[test]
    fn exhaustion_on_append() {
        let mut p = KvPool::new(2, 2);
        p.admit(1, 4).unwrap(); // both blocks
        let err = p.append_token(1).unwrap_err();
        assert!(matches!(err, KvError::OutOfBlocks { .. }));
        p.check_invariants().unwrap();
    }

    #[test]
    fn truncate_rolls_back_appended_tokens_and_blocks() {
        let mut p = KvPool::new(8, 4);
        p.admit(1, 6).unwrap(); // 2 blocks, tail at 6 % 4 = 2
        // speculative appends: two stay in the tail block, three more
        // cross into fresh blocks
        for _ in 0..5 {
            p.append_token(1).unwrap();
        }
        assert_eq!(p.table(1).unwrap().tokens, 11);
        assert_eq!(p.table(1).unwrap().blocks.len(), 3);
        // reject all 5: both the in-block writes and the grown block
        p.truncate_tokens(1, 5).unwrap();
        assert_eq!(p.table(1).unwrap().tokens, 6);
        assert_eq!(p.table(1).unwrap().blocks.len(), 2, "grown block returned");
        assert_eq!(p.free_blocks(), 6);
        p.check_invariants().unwrap();
        // truncating zero is a no-op; over-truncating is a clean error
        p.truncate_tokens(1, 0).unwrap();
        assert!(matches!(
            p.truncate_tokens(1, 7),
            Err(KvError::TruncateUnderflow { tokens: 6, drop: 7 })
        ));
        assert!(matches!(p.truncate_tokens(9, 1), Err(KvError::UnknownSeq(9))));
        p.check_invariants().unwrap();
        p.release(1).unwrap();
        assert_eq!(p.free_blocks(), 8, "rollback leaks nothing");
    }

    #[test]
    fn truncate_after_cow_keeps_the_other_holder_intact() {
        // a CoW split during speculative appends must survive the
        // rollback: the forked sibling keeps the ORIGINAL tail block and
        // its content, the speculating sequence only returns its copy
        let mut p = KvPool::new(8, 4);
        p.admit(1, 6).unwrap();
        p.fork(1, 2).unwrap();
        let shared_tail = p.table(1).unwrap().blocks[1];
        // seq 1 speculates: first append CoW-splits the shared tail,
        // three more fill the copy and grow a fresh block
        for _ in 0..4 {
            p.append_token(1).unwrap();
        }
        assert_eq!(p.sharing().cow_copies, 1);
        let cow_tail = p.table(1).unwrap().blocks[1];
        assert_ne!(cow_tail, shared_tail);
        // reject everything speculated
        p.truncate_tokens(1, 4).unwrap();
        assert_eq!(p.table(1).unwrap().tokens, 6);
        assert_eq!(p.table(1).unwrap().blocks.len(), 2);
        // the CoW copy stays split (seq 1 still holds it privately);
        // the sibling still holds the original tail untouched
        assert_eq!(p.table(1).unwrap().blocks[1], cow_tail);
        assert_eq!(p.table(2).unwrap().blocks[1], shared_tail);
        assert_eq!(p.refcount(shared_tail), 1);
        assert_eq!(p.refcount(cow_tail), 1);
        p.check_invariants().unwrap();
        p.release(1).unwrap();
        p.release(2).unwrap();
        assert_eq!(p.free_blocks(), 8);
        p.check_invariants().unwrap();
    }

    // ------------------------------------------------ prefix sharing --

    fn prompt(len: usize, tag: i32) -> Vec<i32> {
        (0..len as i32).map(|i| i * 31 + tag).collect()
    }

    #[test]
    fn shared_prefix_maps_onto_live_blocks() {
        let mut p = KvPool::new(16, 4);
        // 10-token prompt: 2 full blocks + 1 partial
        let a: Vec<i32> = prompt(10, 1);
        p.admit_shared(1, &a).unwrap();
        assert_eq!(p.used_blocks(), 3);
        // identical prompt: shares both full blocks, private tail only
        p.admit_shared(2, &a).unwrap();
        assert_eq!(p.used_blocks(), 4, "only the partial tail is new");
        assert_eq!(p.table(1).unwrap().blocks[..2], p.table(2).unwrap().blocks[..2]);
        assert_ne!(p.table(1).unwrap().blocks[2], p.table(2).unwrap().blocks[2]);
        let s = p.sharing();
        assert_eq!(s.shared_live, 2);
        assert_eq!(s.fresh_allocs, 4);
        p.check_invariants().unwrap();

        // divergent prompt with the same FIRST block only
        let mut b = a.clone();
        b[5] += 1000; // mutate inside block 1
        p.admit_shared(3, &b).unwrap();
        assert_eq!(p.table(3).unwrap().blocks[0], p.table(1).unwrap().blocks[0]);
        assert_ne!(p.table(3).unwrap().blocks[1], p.table(1).unwrap().blocks[1]);
        assert_eq!(p.refcount(p.table(1).unwrap().blocks[0]), 3);
        p.check_invariants().unwrap();
    }

    #[test]
    fn release_keeps_cache_and_restores() {
        let mut p = KvPool::new(8, 4);
        let a = prompt(8, 7); // exactly 2 full blocks
        p.admit_shared(1, &a).unwrap();
        let blocks: Vec<BlockId> = p.table(1).unwrap().blocks.clone();
        p.release(1).unwrap();
        assert_eq!(p.free_blocks(), 8, "released blocks are free again");
        // same prompt revives the SAME physical blocks off the free list
        p.admit_shared(2, &a).unwrap();
        assert_eq!(p.table(2).unwrap().blocks, blocks, "cache restore reuses content");
        assert_eq!(p.sharing().cache_restores, 2);
        assert_eq!(p.sharing().fresh_allocs, 2, "no new fills for the restore");
        p.check_invariants().unwrap();
    }

    #[test]
    fn fresh_alloc_evicts_cached_content() {
        let mut p = KvPool::new(2, 4);
        let a = prompt(8, 3);
        p.admit_shared(1, &a).unwrap();
        p.release(1).unwrap();
        // a private admit cycles both blocks through alloc_fresh,
        // invalidating the cached hashes
        p.admit(2, 8).unwrap();
        assert_eq!(p.sharing().evictions, 2, "both registrations invalidated");
        p.release(2).unwrap();
        p.admit_shared(3, &a).unwrap();
        assert_eq!(p.sharing().cache_restores, 0, "evicted content cannot restore");
        p.check_invariants().unwrap();
    }

    #[test]
    fn sharing_admits_where_private_cannot() {
        let mut p = KvPool::new(3, 4);
        let a = prompt(12, 5); // 3 full blocks
        p.admit_shared(1, &a).unwrap();
        assert_eq!(p.free_blocks(), 0);
        assert!(!p.can_admit(12), "no free blocks for a private admit");
        assert!(p.can_admit_shared(&a), "but the full-prefix hit needs none");
        p.admit_shared(2, &a).unwrap();
        assert_eq!(p.used_blocks(), 3);
        p.release(1).unwrap();
        p.release(2).unwrap();
        assert_eq!(p.free_blocks(), 3);
        p.check_invariants().unwrap();
    }

    #[test]
    fn reregistering_a_prefix_displaces_a_stale_deeper_chain_entry() {
        // eviction is per-block, so the hA entry can die while the hAB
        // entry survives; a later admit of [A,B] misses hA, re-fills both
        // blocks, and must displace the stale hAB registration instead of
        // leaving two blocks claiming the same hash (bijection break).
        // The choreography below steers eviction through the LIFO order
        // the scenario was built on; the displacement fix itself is
        // policy-independent (the LRU variant is covered by the churn
        // property tests).
        let mut p = KvPool::with_policy(5, 4, EvictionPolicy::Lifo);
        let ab = prompt(8, 1); // blocks [A|B] → hashes hA, hAB
        p.admit_shared(1, &ab).unwrap();
        p.admit(2, 8).unwrap(); // pins two more blocks
        p.release(1).unwrap();
        p.admit_shared(3, &ab[..4]).unwrap(); // restores the hA block...
        p.release(3).unwrap(); // ...and re-frees it above the hAB block
        p.admit(4, 4).unwrap(); // pops exactly the hA block → hA evicted
        p.release(2).unwrap(); // buries the stale hAB block in the free list
        p.admit_shared(5, &ab).unwrap(); // miss on hA → re-registers hAB
        p.check_invariants().unwrap_or_else(|e| panic!("bijection broke: {e}"));
        // and the fresh registration is the live one: a sixth admit
        // shares the new blocks rather than the stale ones
        p.admit_shared(6, &ab).unwrap();
        assert_eq!(p.table(5).unwrap().blocks, p.table(6).unwrap().blocks);
        p.check_invariants().unwrap();
    }

    #[test]
    fn failed_shared_admit_has_no_side_effects() {
        let mut p = KvPool::new(3, 4);
        p.admit(1, 8).unwrap(); // 2 blocks used, 1 free
        let big = prompt(12, 9); // needs 3 fresh
        let err = p.admit_shared(2, &big).unwrap_err();
        assert!(matches!(err, KvError::OutOfBlocks { need: 3, free: 1 }));
        assert_eq!(p.free_blocks(), 1);
        assert_eq!(p.sharing().fresh_allocs, 2, "only the first admit allocated");
        p.check_invariants().unwrap();
    }

    // -------------------------------------------------- LRU eviction --

    fn ids(raw: &[u32]) -> Vec<BlockId> {
        raw.iter().map(|&b| BlockId(b)).collect()
    }

    #[test]
    fn free_list_is_o1_ordered_and_restores_from_the_middle() {
        let mut p = KvPool::new(6, 4);
        // empty pool evicts lowest index first under either policy
        assert_eq!(p.free_order(), ids(&[0, 1, 2, 3, 4, 5]));
        let a = prompt(8, 1); // 2 full blocks
        p.admit_shared(1, &a).unwrap(); // takes 0, 1
        p.admit(2, 4).unwrap(); // takes 2
        // re-freed at the warm end, deepest chain block first — the
        // chain head (block 0) is the warmest of the batch
        p.release(1).unwrap();
        assert_eq!(p.free_order(), ids(&[3, 4, 5, 1, 0]));
        // the restore unlinks 0 and 1 from the MIDDLE of the list
        p.admit_shared(3, &a).unwrap();
        assert_eq!(p.sharing().cache_restores, 2);
        assert_eq!(p.free_order(), ids(&[3, 4, 5]));
        p.check_invariants().unwrap();
        // releasing again re-files them warm (touch-on-hit recency)
        p.release(3).unwrap();
        assert_eq!(p.free_order(), ids(&[3, 4, 5, 1, 0]));
        p.check_invariants().unwrap();
    }

    #[test]
    fn lru_restores_where_lifo_churns() {
        // two 9-token prompts (2 full blocks + tail each) alternating
        // through a 6-block pool, one sequence live at a time.  LRU keeps
        // both prefixes' registered blocks warm — every re-admit restores
        // them — while LIFO's tail allocations pop exactly the blocks the
        // previous request just registered, so its cache never survives.
        let run = |policy: EvictionPolicy| {
            let mut p = KvPool::with_policy(6, 4, policy);
            let pa = prompt(9, 1);
            let pb = prompt(9, 2);
            for i in 0..10u64 {
                let pr = if i % 2 == 0 { &pa } else { &pb };
                p.admit_shared(i, pr).unwrap();
                p.release(i).unwrap();
                p.check_invariants().unwrap_or_else(|e| panic!("{policy:?}: {e}"));
            }
            p.sharing()
        };
        let lru = run(EvictionPolicy::Lru);
        let lifo = run(EvictionPolicy::Lifo);
        assert_eq!(lru.cache_restores, 16, "8 warm re-admits × 2 blocks");
        assert_eq!(lifo.cache_restores, 0, "LIFO cannibalizes its own cache");
        assert!(lru.restore_rate() > lifo.restore_rate());
        assert!(lru.hit_rate() > lifo.hit_rate());
        assert!(lru.evictions < lifo.evictions);
    }

    #[test]
    fn prop_free_list_is_exact_lru_under_churn() {
        // shadow model: a VecDeque holding the expected eviction order.
        // Private admits must pop the shadow FRONT block-for-block (the
        // exact-LRU law); shared admits remove their table's blocks from
        // wherever the shadow holds them; releases re-file at the policy
        // end; appends (growth or CoW) pop the front.  After EVERY op the
        // real list must equal the shadow exactly.
        forall(48, |rng| {
            let blocks = rng.usize(2, 24);
            let btok = rng.usize(1, 6);
            let policy =
                if rng.bool() { EvictionPolicy::Lru } else { EvictionPolicy::Lifo };
            let mut p = KvPool::with_policy(blocks, btok, policy);
            // both policies start evicting lowest index first
            let mut shadow: VecDeque<u32> = (0..blocks as u32).collect();
            let push = |shadow: &mut VecDeque<u32>, b: u32| match policy {
                EvictionPolicy::Lru => shadow.push_back(b),
                EvictionPolicy::Lifo => shadow.push_front(b),
            };
            let mut live: Vec<u64> = Vec::new();
            let mut next = 0u64;
            let prompts: Vec<Vec<i32>> =
                (0..3).map(|t| prompt(rng.usize(1, 3 * btok + 1), t)).collect();
            for _ in 0..rng.usize(10, 150) {
                match rng.u32(0, 5) {
                    0 => {
                        let toks = rng.usize(1, 3 * btok + 1);
                        if p.admit(next, toks).is_ok() {
                            // exact-LRU: private admits take the shadow
                            // front in order
                            for b in &p.table(next).unwrap().blocks {
                                assert_eq!(shadow.pop_front(), Some(b.0), "fresh alloc order");
                            }
                            live.push(next);
                        }
                        next += 1;
                    }
                    1 => {
                        let pr = &prompts[rng.usize(0, prompts.len())];
                        if p.admit_shared(next, pr).is_ok() {
                            for b in p.table(next).unwrap().blocks.clone() {
                                if let Some(pos) = shadow.iter().position(|&x| x == b.0) {
                                    shadow.remove(pos);
                                }
                            }
                            live.push(next);
                        }
                        next += 1;
                    }
                    2 => {
                        if !live.is_empty() {
                            let s = live[rng.usize(0, live.len())];
                            let before = p.table(s).unwrap().blocks.clone();
                            if p.append_token(s).is_ok() {
                                let after = &p.table(s).unwrap().blocks;
                                // growth or CoW consumed at most one block
                                // — it must have been the eviction victim
                                for (i, b) in after.iter().enumerate() {
                                    if before.get(i) != Some(b) {
                                        assert_eq!(shadow.pop_front(), Some(b.0), "append alloc");
                                        break;
                                    }
                                }
                            }
                        }
                    }
                    3 => {
                        if !live.is_empty() {
                            let s = live[rng.usize(0, live.len())];
                            if p.fork(s, next).is_ok() {
                                live.push(next); // no free-list effect
                            }
                            next += 1;
                        }
                    }
                    _ => {
                        if !live.is_empty() {
                            let i = rng.usize(0, live.len());
                            let s = live.swap_remove(i);
                            let table = p.table(s).unwrap().blocks.clone();
                            p.release(s).unwrap();
                            // LRU frees in reverse table order (chain
                            // head warmest); LIFO keeps PR 3's forward
                            // order
                            let ordered: Vec<BlockId> = match policy {
                                EvictionPolicy::Lru => table.into_iter().rev().collect(),
                                EvictionPolicy::Lifo => table,
                            };
                            for b in ordered {
                                if p.refcount(b) == 0 && !shadow.contains(&b.0) {
                                    push(&mut shadow, b.0);
                                }
                            }
                        }
                    }
                }
                let got: Vec<u32> = p.free_order().iter().map(|b| b.0).collect();
                let want: Vec<u32> = shadow.iter().copied().collect();
                assert_eq!(got, want, "eviction order diverged from the {policy:?} model");
                p.check_invariants().unwrap_or_else(|e| panic!("invariant: {e}"));
            }
        });
    }

    // ------------------------------------------------- fork + CoW --

    #[test]
    fn fork_shares_everything_and_cow_splits_on_append() {
        let mut p = KvPool::new(8, 4);
        p.admit(1, 6).unwrap(); // 2 blocks, partial tail at 6 % 4 = 2
        p.fork(1, 2).unwrap();
        assert_eq!(p.used_blocks(), 2, "fork allocates nothing");
        assert_eq!(p.table(1).unwrap().blocks, p.table(2).unwrap().blocks);
        // appending on the child writes into the shared partial tail →
        // copy-on-write
        p.append_token(2).unwrap();
        assert_eq!(p.sharing().cow_copies, 1);
        assert_eq!(p.used_blocks(), 3);
        assert_eq!(p.table(1).unwrap().blocks[0], p.table(2).unwrap().blocks[0]);
        assert_ne!(p.table(1).unwrap().blocks[1], p.table(2).unwrap().blocks[1]);
        // the parent's tail is private again: no further CoW
        p.append_token(1).unwrap();
        assert_eq!(p.sharing().cow_copies, 1);
        p.check_invariants().unwrap();
        // releases free everything exactly once
        p.release(1).unwrap();
        p.release(2).unwrap();
        assert_eq!(p.free_blocks(), 8);
        p.check_invariants().unwrap();
    }

    #[test]
    fn fork_at_block_boundary_needs_no_cow() {
        let mut p = KvPool::new(8, 4);
        p.admit(1, 4).unwrap(); // exactly one full block
        p.fork(1, 2).unwrap();
        // both appends cross the boundary into fresh private blocks
        p.append_token(1).unwrap();
        p.append_token(2).unwrap();
        assert_eq!(p.sharing().cow_copies, 0);
        assert_eq!(p.used_blocks(), 3);
        p.check_invariants().unwrap();
    }

    #[test]
    fn prop_invariants_under_shared_churn() {
        // admit/admit_shared/append/fork/release churn: refcounts always
        // match table references, no block is freed with live references,
        // used + free == total after EVERY op, and a full drain frees all
        forall(48, |rng| {
            let blocks = rng.usize(1, 32);
            let btok = rng.usize(1, 9);
            let policy =
                if rng.bool() { EvictionPolicy::Lru } else { EvictionPolicy::Lifo };
            let mut p = KvPool::with_policy(blocks, btok, policy);
            let mut live: Vec<u64> = Vec::new();
            let mut next = 0u64;
            // a small set of shared prompts so admit_shared actually hits
            let prompts: Vec<Vec<i32>> = (0..3)
                .map(|t| prompt(rng.usize(1, 3 * btok + 1), t))
                .collect();
            for _ in 0..rng.usize(10, 200) {
                match rng.u32(0, 6) {
                    0 => {
                        let toks = rng.usize(1, 3 * btok + 1);
                        if p.admit(next, toks).is_ok() {
                            live.push(next);
                        }
                        next += 1;
                    }
                    1 => {
                        let pr = &prompts[rng.usize(0, prompts.len())];
                        if p.admit_shared(next, pr).is_ok() {
                            live.push(next);
                        }
                        next += 1;
                    }
                    2 => {
                        if !live.is_empty() {
                            let i = rng.usize(0, live.len());
                            let _ = p.append_token(live[i]);
                        }
                    }
                    3 => {
                        if !live.is_empty() {
                            let i = rng.usize(0, live.len());
                            if p.fork(live[i], next).is_ok() {
                                live.push(next);
                            }
                            next += 1;
                        }
                    }
                    4 => {
                        // speculative rollback: drop a random tail slice
                        // (possibly the whole sequence's tokens)
                        if !live.is_empty() {
                            let s = live[rng.usize(0, live.len())];
                            let have = p.table(s).unwrap().tokens;
                            let n = rng.usize(0, have + 1);
                            p.truncate_tokens(s, n).unwrap();
                        }
                    }
                    _ => {
                        if !live.is_empty() {
                            let i = rng.usize(0, live.len());
                            let s = live.swap_remove(i);
                            p.release(s).unwrap();
                        }
                    }
                }
                p.check_invariants().unwrap_or_else(|e| panic!("invariant: {e}"));
                assert_eq!(p.used_blocks() + p.free_blocks(), p.total_blocks());
            }
            // drain
            for s in live {
                p.release(s).unwrap();
            }
            assert_eq!(p.free_blocks(), blocks);
            p.check_invariants().unwrap();
        });
    }
}
