//! Paged KV-cache block allocator (vLLM-style) — the memory-management
//! substrate for continuous batching.
//!
//! The cache is a pool of fixed-size blocks (`block_tokens` KV slots
//! each); a sequence owns an ordered block list that grows as it decodes.
//! The allocator guarantees: no block is owned twice, frees are idempotent
//! per sequence, and capacity is respected (allocation fails cleanly when
//! the pool is exhausted — the scheduler's preemption signal).

use std::collections::HashMap;

/// Index of a physical cache block.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub struct BlockId(pub u32);

/// Per-sequence block table.
#[derive(Debug, Default, Clone)]
pub struct BlockTable {
    pub blocks: Vec<BlockId>,
    pub tokens: usize,
}

/// Fixed-capacity block pool.
pub struct KvPool {
    block_tokens: usize,
    free: Vec<BlockId>,
    tables: HashMap<u64, BlockTable>,
    total_blocks: usize,
}

impl KvPool {
    pub fn new(total_blocks: usize, block_tokens: usize) -> Self {
        assert!(block_tokens > 0 && total_blocks > 0);
        Self {
            block_tokens,
            free: (0..total_blocks as u32).rev().map(BlockId).collect(),
            tables: HashMap::new(),
            total_blocks,
        }
    }

    pub fn free_blocks(&self) -> usize {
        self.free.len()
    }

    pub fn used_blocks(&self) -> usize {
        self.total_blocks - self.free.len()
    }

    /// Pool capacity — `used_blocks() + free_blocks()` always equals this
    /// (the conservation law the churn tests pin down).
    pub fn total_blocks(&self) -> usize {
        self.total_blocks
    }

    pub fn block_tokens(&self) -> usize {
        self.block_tokens
    }

    /// Blocks needed to hold `tokens` KV entries.
    pub fn blocks_for(&self, tokens: usize) -> usize {
        tokens.div_ceil(self.block_tokens)
    }

    /// Can a sequence of `tokens` be admitted right now?
    pub fn can_admit(&self, tokens: usize) -> bool {
        self.blocks_for(tokens) <= self.free.len()
    }

    /// Allocate the blocks for a new sequence of `tokens` (its prompt).
    /// Fails (without side effects) if the pool can't hold it.
    pub fn admit(&mut self, seq: u64, tokens: usize) -> Result<(), KvError> {
        if self.tables.contains_key(&seq) {
            return Err(KvError::AlreadyAdmitted(seq));
        }
        let need = self.blocks_for(tokens.max(1));
        if need > self.free.len() {
            return Err(KvError::OutOfBlocks { need, free: self.free.len() });
        }
        let blocks = self.free.split_off(self.free.len() - need);
        self.tables.insert(seq, BlockTable { blocks, tokens });
        Ok(())
    }

    /// Extend a sequence by one decoded token, growing its table if it
    /// crosses a block boundary.
    pub fn append_token(&mut self, seq: u64) -> Result<(), KvError> {
        let t = self.tables.get_mut(&seq).ok_or(KvError::UnknownSeq(seq))?;
        if t.tokens % self.block_tokens == 0 && t.tokens > 0 || t.blocks.is_empty() {
            // need a fresh block (or first block for an empty admit)
            if t.tokens.div_ceil(self.block_tokens) >= t.blocks.len() {
                let b = self.free.pop().ok_or(KvError::OutOfBlocks { need: 1, free: 0 })?;
                t.blocks.push(b);
            }
        }
        t.tokens += 1;
        Ok(())
    }

    /// Release every block a sequence holds.
    pub fn release(&mut self, seq: u64) -> Result<(), KvError> {
        let t = self.tables.remove(&seq).ok_or(KvError::UnknownSeq(seq))?;
        self.free.extend(t.blocks);
        Ok(())
    }

    pub fn table(&self, seq: u64) -> Option<&BlockTable> {
        self.tables.get(&seq)
    }

    pub fn active_seqs(&self) -> usize {
        self.tables.len()
    }

    /// Internal consistency: every block owned exactly once.
    pub fn check_invariants(&self) -> Result<(), String> {
        let mut seen = std::collections::HashSet::new();
        for b in &self.free {
            if !seen.insert(b.0) {
                return Err(format!("block {} double-freed", b.0));
            }
        }
        for (seq, t) in &self.tables {
            for b in &t.blocks {
                if !seen.insert(b.0) {
                    return Err(format!("block {} owned twice (seq {seq})", b.0));
                }
            }
            if t.blocks.len() < self.blocks_for(t.tokens) {
                return Err(format!("seq {seq}: {} tokens in {} blocks", t.tokens, t.blocks.len()));
            }
        }
        if seen.len() != self.total_blocks {
            return Err(format!("{} blocks tracked, expected {}", seen.len(), self.total_blocks));
        }
        Ok(())
    }
}

#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum KvError {
    OutOfBlocks { need: usize, free: usize },
    UnknownSeq(u64),
    AlreadyAdmitted(u64),
}

impl std::fmt::Display for KvError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            KvError::OutOfBlocks { need, free } => {
                write!(f, "out of KV blocks (need {need}, free {free})")
            }
            KvError::UnknownSeq(s) => write!(f, "unknown sequence {s}"),
            KvError::AlreadyAdmitted(s) => write!(f, "sequence {s} already admitted"),
        }
    }
}

impl std::error::Error for KvError {}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::proptest::forall;

    #[test]
    fn admit_and_release() {
        let mut p = KvPool::new(10, 16);
        assert!(p.can_admit(160));
        assert!(!p.can_admit(161));
        p.admit(1, 100).unwrap();
        assert_eq!(p.used_blocks(), 7);
        assert_eq!(p.table(1).unwrap().tokens, 100);
        p.release(1).unwrap();
        assert_eq!(p.free_blocks(), 10);
        p.check_invariants().unwrap();
    }

    #[test]
    fn admission_is_atomic() {
        let mut p = KvPool::new(4, 16);
        p.admit(1, 40).unwrap(); // 3 blocks
        let err = p.admit(2, 40).unwrap_err();
        assert!(matches!(err, KvError::OutOfBlocks { need: 3, free: 1 }));
        assert_eq!(p.free_blocks(), 1, "failed admit must not leak");
        p.check_invariants().unwrap();
    }

    #[test]
    fn append_grows_at_boundary() {
        let mut p = KvPool::new(4, 4);
        p.admit(7, 4).unwrap(); // exactly one block
        assert_eq!(p.table(7).unwrap().blocks.len(), 1);
        p.append_token(7).unwrap(); // 5th token → second block
        assert_eq!(p.table(7).unwrap().blocks.len(), 2);
        for _ in 0..3 {
            p.append_token(7).unwrap();
        }
        assert_eq!(p.table(7).unwrap().blocks.len(), 2, "8 tokens fit 2 blocks");
        p.append_token(7).unwrap();
        assert_eq!(p.table(7).unwrap().blocks.len(), 3);
        p.check_invariants().unwrap();
    }

    #[test]
    fn double_admit_and_unknown_release() {
        let mut p = KvPool::new(4, 4);
        p.admit(1, 2).unwrap();
        assert!(matches!(p.admit(1, 2), Err(KvError::AlreadyAdmitted(1))));
        assert!(matches!(p.release(9), Err(KvError::UnknownSeq(9))));
        assert!(matches!(p.append_token(9), Err(KvError::UnknownSeq(9))));
    }

    #[test]
    fn exhaustion_on_append() {
        let mut p = KvPool::new(2, 2);
        p.admit(1, 4).unwrap(); // both blocks
        let err = p.append_token(1).unwrap_err();
        assert!(matches!(err, KvError::OutOfBlocks { .. }));
        p.check_invariants().unwrap();
    }

    #[test]
    fn prop_invariants_under_random_ops() {
        forall(64, |rng| {
            let blocks = rng.usize(1, 32);
            let btok = rng.usize(1, 9);
            let mut p = KvPool::new(blocks, btok);
            let mut live: Vec<u64> = Vec::new();
            let mut next = 0u64;
            for _ in 0..rng.usize(10, 200) {
                match rng.u32(0, 3) {
                    0 => {
                        let toks = rng.usize(1, 3 * btok + 1);
                        if p.admit(next, toks).is_ok() {
                            live.push(next);
                        }
                        next += 1;
                    }
                    1 => {
                        if !live.is_empty() {
                            let i = rng.usize(0, live.len());
                            let _ = p.append_token(live[i]);
                        }
                    }
                    _ => {
                        if !live.is_empty() {
                            let i = rng.usize(0, live.len());
                            let s = live.swap_remove(i);
                            p.release(s).unwrap();
                        }
                    }
                }
                p.check_invariants().unwrap_or_else(|e| panic!("invariant: {e}"));
            }
            // drain
            for s in live {
                p.release(s).unwrap();
            }
            assert_eq!(p.free_blocks(), blocks);
        });
    }
}
