//! The serve loop: channels in, responses out.
//!
//! PJRT handles are not `Send`, so the backend lives on the thread that
//! calls [`Server::serve`]; request producers feed the `Sender` from any
//! thread.  The loop interleaves admission (non-blocking channel drain)
//! with scheduler steps and parks briefly when idle.

use super::backend::Backend;
use super::request::{Request, Response};
use super::scheduler::{Scheduler, SchedulerConfig};
use crate::anyhow::Result;
use std::sync::mpsc::{Receiver, RecvTimeoutError, Sender};
use std::time::Duration;

#[derive(Debug, Clone)]
pub struct ServerConfig {
    pub scheduler: SchedulerConfig,
    /// Idle park time when no work is queued.
    pub idle_wait: Duration,
}

impl Default for ServerConfig {
    fn default() -> Self {
        Self { scheduler: SchedulerConfig::default(), idle_wait: Duration::from_millis(1) }
    }
}

/// Single-replica server.
pub struct Server<B: Backend> {
    sched: Scheduler<B>,
    cfg: ServerConfig,
}

impl<B: Backend> Server<B> {
    pub fn new(backend: B, cfg: ServerConfig) -> Self {
        Self { sched: Scheduler::new(backend, cfg.scheduler.clone()), cfg }
    }

    /// Run until `rx` disconnects AND all admitted work drained.  Sends
    /// every completion to `tx`.  Returns the scheduler (for metrics).
    pub fn serve(mut self, rx: Receiver<Request>, tx: Sender<Response>) -> Result<Scheduler<B>> {
        self.sched.metrics.start();
        let mut open = true;
        loop {
            // drain arrivals; block briefly only when fully idle
            loop {
                if self.sched.is_idle() && open {
                    match rx.recv_timeout(self.cfg.idle_wait) {
                        Ok(r) => self.sched.submit(r),
                        Err(RecvTimeoutError::Timeout) => break,
                        Err(RecvTimeoutError::Disconnected) => {
                            open = false;
                            break;
                        }
                    }
                } else {
                    match rx.try_recv() {
                        Ok(r) => self.sched.submit(r),
                        Err(std::sync::mpsc::TryRecvError::Empty) => break,
                        Err(std::sync::mpsc::TryRecvError::Disconnected) => {
                            open = false;
                            break;
                        }
                    }
                }
            }
            if self.sched.is_idle() {
                if !open {
                    break;
                }
                continue;
            }
            for resp in self.sched.step()? {
                let _ = tx.send(resp); // receiver may have hung up; fine
            }
        }
        self.sched.metrics.finish();
        Ok(self.sched)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::backend::SimBackend;
    use crate::coordinator::request::GenParams;
    use std::sync::mpsc::channel;

    #[test]
    fn serve_loop_drains_and_exits() {
        let backend = SimBackend::new(64, 64, vec![1, 2, 4]);
        let server = Server::new(backend, ServerConfig::default());
        let (tx_req, rx_req) = channel();
        let (tx_resp, rx_resp) = channel();

        let producer = std::thread::spawn(move || {
            for i in 0..10u64 {
                let r = Request::new(
                    i,
                    vec![1, 2, 3],
                    GenParams { max_new_tokens: 4, sample: false, seed: i },
                );
                tx_req.send(r).unwrap();
                std::thread::sleep(Duration::from_millis(1));
            }
            // tx_req drops → server drains and exits
        });

        let sched = server.serve(rx_req, tx_resp).unwrap();
        producer.join().unwrap();
        let responses: Vec<Response> = rx_resp.iter().collect();
        assert_eq!(responses.len(), 10);
        assert!(responses.iter().all(|r| r.tokens.len() == 4));
        assert_eq!(sched.metrics.requests_done, 10);
        assert!(sched.metrics.throughput_tok_s() > 0.0);
    }

    #[test]
    fn serve_with_sampling_varies_but_is_seeded() {
        let run = |seed: u64| {
            let backend = SimBackend::new(64, 64, vec![1, 2]);
            let server = Server::new(backend, ServerConfig::default());
            let (tx_req, rx_req) = channel();
            let (tx_resp, rx_resp) = channel();
            tx_req
                .send(Request::new(
                    0,
                    vec![1, 2],
                    GenParams { max_new_tokens: 5, sample: true, seed },
                ))
                .unwrap();
            drop(tx_req);
            server.serve(rx_req, tx_resp).unwrap();
            rx_resp.iter().next().unwrap().tokens
        };
        // sampling path produces tokens (cannot assert equality across
        // seeds — scheduler rng is shared — but lengths are exact)
        assert_eq!(run(1).len(), 5);
        assert_eq!(run(2).len(), 5);
    }
}
