//! The serve loop: requests in, **streamed [`TokenEvent`]s out** — plus
//! the [`Stepper`] abstraction every serving state machine implements
//! (the continuous-batching engine — under either
//! [`AdmissionPolicy`](super::engine::AdmissionPolicy) — and the
//! multi-replica [`Cluster`](super::cluster::Cluster)) and the
//! wall-clock trace replay driver the demos and benches share.
//!
//! Delivery is streaming: each `step()` returns the events the iteration
//! produced (admissions, individual tokens, preempt/migrate/resume
//! transitions, completions), and [`Server::serve`] forwards them to the
//! response channel as they happen — clients see tokens at generation
//! time, which is what makes TTFT/ITL real measurements instead of
//! end-to-end latencies sliced after the fact.  A cluster's
//! [`TokenEvent::Migrated`] (and, across precision boundaries,
//! [`TokenEvent::Requantized`]) rides the same channel: the client
//! observes the replica hand-off as a pause annotation, never as a
//! change in the already-streamed token bytes.  On a disaggregated
//! cluster [`TokenEvent::PrefillDone`] streams the same way, marking the
//! voluntary prefill→decode handoff immediately before its `Migrated`.
//!
//! PJRT handles are not `Send`, so the backend lives on the thread that
//! calls [`Server::serve`]; request producers feed the `Sender` from any
//! thread.  The loop interleaves admission (non-blocking channel drain)
//! with stepper iterations and parks briefly when idle.

use super::backend::Backend;
use super::engine::{Engine, EngineConfig};
use super::metrics::Metrics;
use super::request::{Request, TokenEvent};
use super::trace::TimedRequest;
use crate::anyhow::Result;
use std::sync::mpsc::{Receiver, RecvTimeoutError, Sender};
use std::time::{Duration, Instant};

pub use super::request::responses_of;

/// One serving state machine the serve loop can drive.  Implemented by
/// the continuous-batching [`Engine`] and the multi-replica
/// [`Cluster`](super::cluster::Cluster); everything above this trait
/// (channel serve loop, trace replay, demos, benches) works with any.
pub trait Stepper {
    fn submit(&mut self, r: Request);
    /// One scheduling iteration; returns the events it produced, in
    /// order (tokens stream — completions are just the terminal events).
    fn step(&mut self) -> Result<Vec<TokenEvent>>;
    fn is_idle(&self) -> bool;
    /// Metrics snapshot.  Single steppers clone their own; a cluster
    /// merges per-replica metrics into one view.
    fn metrics(&self) -> Metrics;
    /// Bracket a run's wall clock (throughput denominators).
    fn start_clock(&mut self);
    fn stop_clock(&mut self);
}

#[derive(Debug, Clone)]
pub struct ServerConfig {
    /// Engine shape (pool size, admission policy, batcher, …).
    pub engine: EngineConfig,
    /// Idle park time when no work is queued.
    pub idle_wait: Duration,
}

impl Default for ServerConfig {
    fn default() -> Self {
        Self { engine: EngineConfig::default(), idle_wait: Duration::from_millis(1) }
    }
}

/// Serve any [`Stepper`] behind a channel pair (single replica or a
/// whole cluster — the loop is the same).
pub struct Server<S: Stepper> {
    inner: S,
    idle_wait: Duration,
}

impl<B: Backend> Server<Engine<B>> {
    /// Convenience: wrap a backend in a continuous-batching engine.
    pub fn new(backend: B, cfg: ServerConfig) -> Self {
        Self::from_stepper(Engine::new(backend, cfg.engine.clone()), cfg.idle_wait)
    }
}

impl<S: Stepper> Server<S> {
    /// Wrap an already-built stepper (e.g. a continuous-batching
    /// [`Engine`](super::engine::Engine) or a
    /// [`Cluster`](super::cluster::Cluster)).
    pub fn from_stepper(inner: S, idle_wait: Duration) -> Self {
        Self { inner, idle_wait }
    }

    /// Run until `rx` disconnects AND all admitted work drained.
    /// **Streams every event** to `tx` as its step produces it — tokens
    /// reach the receiver while the request is still decoding.  Returns
    /// the stepper (for metrics).
    pub fn serve(mut self, rx: Receiver<Request>, tx: Sender<TokenEvent>) -> Result<S> {
        self.inner.start_clock();
        let mut open = true;
        loop {
            // drain arrivals; block briefly only when fully idle
            loop {
                if self.inner.is_idle() && open {
                    match rx.recv_timeout(self.idle_wait) {
                        Ok(r) => self.inner.submit(r),
                        Err(RecvTimeoutError::Timeout) => break,
                        Err(RecvTimeoutError::Disconnected) => {
                            open = false;
                            break;
                        }
                    }
                } else {
                    match rx.try_recv() {
                        Ok(r) => self.inner.submit(r),
                        Err(std::sync::mpsc::TryRecvError::Empty) => break,
                        Err(std::sync::mpsc::TryRecvError::Disconnected) => {
                            open = false;
                            break;
                        }
                    }
                }
            }
            if self.inner.is_idle() {
                if !open {
                    break;
                }
                continue;
            }
            for ev in self.inner.step()? {
                let _ = tx.send(ev); // receiver may have hung up; fine
            }
        }
        self.inner.stop_clock();
        Ok(self.inner)
    }
}

/// Replay a timed trace against a stepper in wall-clock time (the serving
/// demos and the steady-state bench share this driver): each request is
/// submitted at its arrival offset, the stepper steps whenever work is
/// outstanding, and the loop parks only when fully idle.  Returns the
/// full event stream; [`responses_of`] extracts the completion view.
pub fn replay_trace<S: Stepper>(s: &mut S, trace: &[TimedRequest]) -> Result<Vec<TokenEvent>> {
    s.start_clock();
    let start = Instant::now();
    let mut next = 0;
    let mut out = Vec::new();
    while next < trace.len() || !s.is_idle() {
        let now = start.elapsed().as_secs_f64();
        while next < trace.len() && trace[next].at_s <= now {
            let mut r = trace[next].request.clone();
            r.arrived = Instant::now();
            s.submit(r);
            next += 1;
        }
        if s.is_idle() {
            if next < trace.len() {
                let wait = (trace[next].at_s - now).max(0.0).min(0.05);
                std::thread::sleep(Duration::from_secs_f64(wait));
            }
            continue;
        }
        out.extend(s.step()?);
    }
    s.stop_clock();
    Ok(out)
}

/// Drive a stepper to completion outside wall-clock replay — the
/// step-until-idle loop behind every `run_to_completion*` (callers
/// bracket their own clocks).
pub fn drain<S: Stepper>(s: &mut S) -> Result<Vec<TokenEvent>> {
    let mut out = Vec::new();
    while !s.is_idle() {
        out.extend(s.step()?);
    }
    Ok(out)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::backend::SimBackend;
    use crate::coordinator::engine::{AdmissionPolicy, Engine, EngineConfig};
    use crate::coordinator::request::{GenParams, Response};
    use std::sync::mpsc::channel;

    #[test]
    fn serve_loop_drains_and_exits() {
        // drive the loop over a Reserve engine — the retired group
        // scheduler's admission semantics behind the same Server::new
        let backend = SimBackend::new(64, 64, vec![1, 2, 4]);
        let cfg = ServerConfig {
            engine: EngineConfig {
                admission: AdmissionPolicy::Reserve,
                ..EngineConfig::default()
            },
            ..ServerConfig::default()
        };
        let server = Server::new(backend, cfg);
        let (tx_req, rx_req) = channel();
        let (tx_ev, rx_ev) = channel();

        let producer = std::thread::spawn(move || {
            for i in 0..10u64 {
                let r = Request::new(
                    i,
                    vec![1, 2, 3],
                    GenParams { max_new_tokens: 4, sample: false, seed: i },
                );
                tx_req.send(r).unwrap();
                std::thread::sleep(Duration::from_millis(1));
            }
            // tx_req drops → server drains and exits
        });

        let sched = server.serve(rx_req, tx_ev).unwrap();
        producer.join().unwrap();
        let events: Vec<TokenEvent> = rx_ev.iter().collect();
        let responses: Vec<Response> = responses_of(&events);
        assert_eq!(responses.len(), 10);
        assert!(responses.iter().all(|r| r.tokens.len() == 4));
        assert_eq!(sched.metrics.requests_done, 10);
        assert!(sched.metrics.throughput_tok_s() > 0.0);
        // streaming: one Token event per generated token, and per request
        // the token payloads concatenate to the final response
        let n_tok = events.iter().filter(|e| matches!(e, TokenEvent::Token { .. })).count();
        assert_eq!(n_tok, 40);
        for resp in &responses {
            let streamed: Vec<i32> = events
                .iter()
                .filter_map(|e| match e {
                    TokenEvent::Token { id, token, .. } if *id == resp.id => Some(*token),
                    _ => None,
                })
                .collect();
            assert_eq!(streamed, resp.tokens, "stream ≠ response for {:?}", resp.id);
        }
    }

    #[test]
    fn serve_loop_over_continuous_batching_engine() {
        let eng = Engine::new(SimBackend::new(64, 64, vec![1, 2, 4, 8]), EngineConfig::default());
        let server = Server::from_stepper(eng, Duration::from_millis(1));
        let (tx_req, rx_req) = channel();
        let (tx_ev, rx_ev) = channel();
        for i in 0..12u64 {
            tx_req
                .send(Request::new(
                    i,
                    vec![1, 2, 3],
                    GenParams { max_new_tokens: 3 + (i as usize % 4), sample: false, seed: i },
                ))
                .unwrap();
        }
        drop(tx_req);
        let eng = server.serve(rx_req, tx_ev).unwrap();
        let events: Vec<TokenEvent> = rx_ev.iter().collect();
        let responses = responses_of(&events);
        assert_eq!(responses.len(), 12);
        assert_eq!(eng.metrics.requests_done, 12);
        assert_eq!(eng.pool().free_blocks(), eng.pool().total_blocks());
        // every request is admitted exactly once before its tokens
        for resp in &responses {
            let admits = events
                .iter()
                .filter(|e| matches!(e, TokenEvent::Admitted { id } if *id == resp.id))
                .count();
            assert_eq!(admits, 1, "{:?}", resp.id);
        }
    }

    #[test]
    fn serve_with_sampling_varies_but_is_seeded() {
        let run = |seed: u64| {
            let backend = SimBackend::new(64, 64, vec![1, 2]);
            let server = Server::new(backend, ServerConfig::default());
            let (tx_req, rx_req) = channel();
            let (tx_ev, rx_ev) = channel();
            tx_req
                .send(Request::new(
                    0,
                    vec![1, 2],
                    GenParams { max_new_tokens: 5, sample: true, seed },
                ))
                .unwrap();
            drop(tx_req);
            server.serve(rx_req, tx_ev).unwrap();
            let events: Vec<TokenEvent> = rx_ev.iter().collect();
            responses_of(&events).remove(0).tokens
        };
        // sampling is fully seeded per request: same seed → same tokens
        assert_eq!(run(1), run(1));
        assert_eq!(run(1).len(), 5);
        assert_eq!(run(2).len(), 5);
    }
}
