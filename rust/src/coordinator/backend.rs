//! Execution backends for the serving engine.
//!
//! `Backend` abstracts one model replica at the granularity continuous
//! batching needs: per-sequence prefill and per-slot batched decode.
//! `PjrtBackend` runs the real AOT artifacts (`pjrt` cargo feature);
//! `SimBackend` is a deterministic stand-in (fake logits, optional
//! synthetic step latency) for engine tests and the coordinator bench.
//! `SimBackend::with_ap_gemm` upgrades the stand-in to compute real
//! logits through the **pack-once bitmm pipeline**: the weight matrix is
//! decomposed+packed exactly once at construction and every decode step
//! only packs its activation batch through a recycling arena — the §3.3
//! flow, exercised end to end by the serving loop.
//!
//! ## One weight store per cluster (any-precision serving)
//!
//! Weights live in an `Arc<PackedWeightStore>` packed **once at the
//! widest precision served** ([`superset_store`]).  Every replica of a
//! mixed-precision cluster shares that one store
//! ([`SimBackend::with_shared_store`]) and slices its own precision out
//! of the superset per step as a zero-copy
//! [`PlaneView`](crate::bitmm::PlaneView) — W2A2 and W4A4 replicas serve
//! the *same* packed bytes, so `packed_bytes` is reported once for the
//! whole cluster instead of once per precision.

use super::request::{sample_token, GenParams};
use crate::anyhow::{bail, Result};
#[cfg(feature = "pjrt")]
use crate::anyhow::{anyhow, Context};

use crate::bitmm::prepack::{PackArena, PackedWeightStore};
use crate::bitmm::{apmm_bipolar_packed_into, ApmmOpts, CodeMatrix};
#[cfg(feature = "pjrt")]
use crate::runtime::{lit_f32, ModelRunner};
use std::sync::Arc;

/// Host-resident KV state of ONE sequence: `(L, max_seq, Hkv, Dh)` f32,
/// plus the next write position.  The engine owns these; backends
/// gather them into device group tensors per step.
#[derive(Debug, Clone)]
pub struct SeqKv {
    pub k: Vec<f32>,
    pub v: Vec<f32>,
    pub pos: usize,
}

/// Drive one request through a backend **unbatched**: prefill plus a
/// chain of single-row decode steps, sampled with the serving layer's
/// own [`sample_token`].  This is the reference oracle the
/// continuous-batching tests compare token streams against — exported so
/// the engine's unit tests and the integration tests share one
/// definition and cannot drift apart.
pub fn drive_unbatched<B: Backend>(
    backend: &mut B,
    prompt: &[i32],
    params: &GenParams,
) -> Result<Vec<i32>> {
    let (logits, mut kv) = backend.prefill_one(prompt)?;
    let mut toks = vec![sample_token(&logits, params, 0)];
    while toks.len() < params.max_new_tokens {
        let step = toks.len();
        let l = backend.decode_batch(&[toks[step - 1]], &mut [&mut kv])?;
        toks.push(sample_token(&l[0], params, step));
    }
    Ok(toks)
}

/// Per-sequence decode state that exposes its KV buffer — lets
/// [`gather_kv_refs`] serve both steppers' private sequence structs.
pub(crate) trait HasSeqKv {
    fn kv_mut(&mut self) -> &mut SeqKv;
}

/// Collect `&mut SeqKv` at the ascending `idx` positions of `seqs`
/// without unsafe or a double mutable borrow (split_at_mut
/// partitioning).  Used by the engine's batched-decode gather so the
/// tricky slice arithmetic lives once.
pub(crate) fn gather_kv_refs<'a, T: HasSeqKv>(
    seqs: &'a mut [T],
    idx: &[usize],
) -> Vec<&'a mut SeqKv> {
    debug_assert!(idx.windows(2).all(|w| w[0] < w[1]), "indices must ascend");
    let mut out = Vec::with_capacity(idx.len());
    let mut rest = seqs;
    let mut base = 0usize;
    for &i in idx {
        let (_, tail) = rest.split_at_mut(i - base);
        let (head, tail2) = tail.split_at_mut(1);
        out.push(head[0].kv_mut());
        rest = tail2;
        base = i + 1;
    }
    out
}

/// One model replica.
pub trait Backend {
    fn vocab(&self) -> usize;
    fn max_seq(&self) -> usize;
    /// Decode group sizes the replica supports, ascending.
    fn supported_batches(&self) -> &[usize];
    /// Longest admissible prompt.
    fn max_prompt(&self) -> usize;
    /// Prefill a single prompt; returns the last-token logits and the
    /// sequence's KV state (positioned at `prompt.len()`).
    fn prefill_one(&mut self, prompt: &[i32]) -> Result<(Vec<f32>, SeqKv)>;
    /// One decode step over `kvs.len()` sequences (`tokens[i]` is row i's
    /// input).  Returns per-row logits; advances every `SeqKv` in place.
    fn decode_batch(&mut self, tokens: &[i32], kvs: &mut [&mut SeqKv]) -> Result<Vec<Vec<f32>>>;
    /// Set this replica's GEMM worker budget (`0` = the global
    /// [`crate::util::num_threads`] default).  Replicas with equal budgets
    /// share one pool process-wide, so N replicas × T workers never
    /// oversubscribe the host.  Default: ignored (backends without an
    /// intra-step parallel substrate).
    fn set_workers(&mut self, _workers: usize) {}

    /// Enable the self-speculative **draft path**: logits computed from
    /// the most-significant-`bits` plane prefix of the SAME weight pack
    /// the serving width uses (zero extra weight bytes).  Returns `true`
    /// if this backend can draft at `bits`; `false` declines and the
    /// engine falls back to plain decode.  A backend may only accept if
    /// (a) `bits` is a strict subset of its serving width and (b) its
    /// host KV state is position-only, so speculated-then-rejected
    /// positions roll back by resetting `SeqKv::pos` (`PjrtBackend`
    /// carries real device KV tensors and must keep the default).
    fn set_draft_bits(&mut self, _bits: u32) -> bool {
        false
    }

    /// One **draft** forward row: logits for the next position given
    /// `token` at position `pos`, computed at the draft precision
    /// ([`Backend::set_draft_bits`]).  Never touches or advances any
    /// `SeqKv` — drafted positions are provisional until the wide-width
    /// verify step accepts them.  Default: unsupported.
    fn draft_one(&mut self, _token: i32, _pos: usize) -> Result<Vec<f32>> {
        bail!("this backend has no draft path (set_draft_bits declined or was never called)")
    }
}

// ------------------------------------------------------------------ PJRT --

/// Real backend over the AOT model artifacts.
#[cfg(feature = "pjrt")]
pub struct PjrtBackend<'e> {
    runner: &'e ModelRunner<'e>,
    batches: Vec<usize>,
    prefill_buckets: Vec<usize>,
    /// Elements of one sequence's per-tensor KV: L * max_seq * Hkv * Dh.
    seq_kv_elems: usize,
}

#[cfg(feature = "pjrt")]
impl<'e> PjrtBackend<'e> {
    pub fn new(runner: &'e ModelRunner<'e>) -> Result<Self> {
        let man = runner.engine().manifest();
        let mut batches: Vec<usize> =
            man.by_kind("decode").iter().filter_map(|e| e.meta.get("batch").copied()).collect();
        batches.sort_unstable();
        if batches.is_empty() {
            bail!("no decode executables in manifest");
        }
        let mut prefill_buckets: Vec<usize> = man
            .by_kind("prefill")
            .iter()
            .filter(|e| e.meta.get("batch") == Some(&1))
            .filter_map(|e| e.meta.get("seq").copied())
            .collect();
        prefill_buckets.sort_unstable();
        if prefill_buckets.is_empty() {
            bail!("no batch-1 prefill executable in manifest");
        }
        let cfg = runner.cfg;
        let seq_kv_elems = cfg.n_layers * cfg.max_seq * cfg.n_kv_heads * cfg.head_dim();
        Ok(Self { runner, batches, prefill_buckets, seq_kv_elems })
    }

    /// Group layout: (L, b, S, Hkv, Dh); sequence layout: (L, S, Hkv, Dh).
    fn gather(&self, kvs: &[&mut SeqKv], b: usize, pick_k: bool) -> Vec<f32> {
        let cfg = self.runner.cfg;
        let layer_elems = cfg.max_seq * cfg.n_kv_heads * cfg.head_dim();
        let mut out = vec![0f32; cfg.n_layers * b * layer_elems];
        for (i, kv) in kvs.iter().enumerate() {
            let src = if pick_k { &kv.k } else { &kv.v };
            for l in 0..cfg.n_layers {
                let s0 = l * layer_elems;
                let d0 = (l * b + i) * layer_elems;
                out[d0..d0 + layer_elems].copy_from_slice(&src[s0..s0 + layer_elems]);
            }
        }
        out
    }

    fn scatter(&self, group: &[f32], kvs: &mut [&mut SeqKv], b: usize, pick_k: bool) {
        let cfg = self.runner.cfg;
        let layer_elems = cfg.max_seq * cfg.n_kv_heads * cfg.head_dim();
        for (i, kv) in kvs.iter_mut().enumerate() {
            let dst = if pick_k { &mut kv.k } else { &mut kv.v };
            for l in 0..cfg.n_layers {
                let d0 = l * layer_elems;
                let s0 = (l * b + i) * layer_elems;
                dst[d0..d0 + layer_elems].copy_from_slice(&group[s0..s0 + layer_elems]);
            }
        }
    }
}

#[cfg(feature = "pjrt")]
impl<'e> Backend for PjrtBackend<'e> {
    fn vocab(&self) -> usize {
        self.runner.cfg.vocab
    }

    fn max_seq(&self) -> usize {
        self.runner.cfg.max_seq
    }

    fn supported_batches(&self) -> &[usize] {
        &self.batches
    }

    fn max_prompt(&self) -> usize {
        *self.prefill_buckets.last().unwrap()
    }

    fn prefill_one(&mut self, prompt: &[i32]) -> Result<(Vec<f32>, SeqKv)> {
        let t = prompt.len();
        if t == 0 || t > self.max_prompt() {
            bail!("prompt length {t} outside (0, {}]", self.max_prompt());
        }
        let (logits, kv) = self.runner.prefill(prompt, 1, t)?;
        let cfg = self.runner.cfg;
        // last REAL token's logits (prefill pads to its bucket)
        let row = &logits[(t - 1) * cfg.vocab..t * cfg.vocab];
        let k = kv.k.to_vec::<f32>().map_err(|e| anyhow!("kv k: {e:?}"))?;
        let v = kv.v.to_vec::<f32>().map_err(|e| anyhow!("kv v: {e:?}"))?;
        debug_assert_eq!(k.len(), self.seq_kv_elems);
        // next write position is the true prompt end — pad-slot KV beyond
        // it is garbage but masked (rows only attend to [0, pos])
        Ok((row.to_vec(), SeqKv { k, v, pos: t }))
    }

    fn decode_batch(&mut self, tokens: &[i32], kvs: &mut [&mut SeqKv]) -> Result<Vec<Vec<f32>>> {
        let n = kvs.len();
        if n == 0 || tokens.len() != n {
            bail!("decode_batch: {} tokens for {n} sequences", tokens.len());
        }
        let b = *self
            .supported_batches()
            .iter()
            .find(|&&b| b >= n)
            .with_context(|| format!("no decode executable holds {n} sequences"))?;
        let cfg = self.runner.cfg;

        let mut toks = tokens.to_vec();
        toks.resize(b, 0);
        let mut pos: Vec<i32> = kvs.iter().map(|kv| kv.pos as i32).collect();
        pos.resize(b, 0); // idle slots write pos 0 of their own (zero) rows

        let kvshape = [cfg.n_layers, b, cfg.max_seq, cfg.n_kv_heads, cfg.head_dim()];
        let k_lit = lit_f32(&self.gather(kvs, b, true), &kvshape)?;
        let v_lit = lit_f32(&self.gather(kvs, b, false), &kvshape)?;
        let (logits, k_out, v_out) = self.runner.decode_raw(&toks, &pos, &k_lit, &v_lit)?;
        let k_host = k_out.to_vec::<f32>().map_err(|e| anyhow!("k out: {e:?}"))?;
        let v_host = v_out.to_vec::<f32>().map_err(|e| anyhow!("v out: {e:?}"))?;
        self.scatter(&k_host, kvs, b, true);
        self.scatter(&v_host, kvs, b, false);
        for kv in kvs.iter_mut() {
            kv.pos += 1;
        }
        Ok((0..n).map(|i| logits[i * cfg.vocab..(i + 1) * cfg.vocab].to_vec()).collect())
    }
}

// ------------------------------------------------------------------- sim --

/// Name the sim backend's single weight is registered under in its
/// [`PackedWeightStore`].
const LM_HEAD: &str = "lm_head";

/// Build the demo model's **any-precision superset store**: one
/// LM-head-style `(vocab, dim)` weight, decomposed+packed exactly once at
/// `bits` — the widest precision the deployment serves.  Share the
/// returned `Arc` across every replica of a cluster
/// ([`SimBackend::with_shared_store`]); each replica slices its own
/// precision prefix per step, so the cluster's whole weight memory is
/// this one pack (`store.packed_bytes()`), whatever precision mix it
/// serves.
pub fn superset_store(vocab: usize, dim: usize, bits: u32, seed: u64) -> Arc<PackedWeightStore> {
    // construction-time artifact: the codes are dropped right after the
    // one and only pack, into the store
    let codes = CodeMatrix::random(vocab, dim, bits, seed);
    let mut store = PackedWeightStore::new();
    store.insert_codes(LM_HEAD, &codes, vec![1.0; vocab]);
    Arc::new(store)
}

/// Pack-once AP-GEMM state for the sim backend: a shared
/// [`PackedWeightStore`] holding the superset weight (packed once,
/// possibly outside this backend), a serving precision `(nw, nx)` that
/// selects the plane prefix per step, and the recycling activation arena
/// ([`PackArena::pack_batch`]) feeding the prepacked kernel core.
struct ApGemm {
    /// Prepacked weight registry — the only weight form the hot path ever
    /// touches (here one entry, `LM_HEAD`; a full model registers one per
    /// layer weight).  Shared: a mixed-precision cluster clones one `Arc`
    /// into every replica.
    store: Arc<PackedWeightStore>,
    arena: PackArena,
    dim: usize,
    /// Weight bits this backend serves — the plane-prefix width sliced
    /// out of the superset each step (≤ the stored pack's width).
    nw: u32,
    nx: u32,
    /// Per-row dequant scales at THIS serving precision, materialized
    /// once at construction through [`PackedWeightStore::get_at`] (the
    /// `×2^skip` rescale for the dropped low planes) — the hot path
    /// multiplies them per logit row instead of re-deriving per step.
    /// An `Arc` handle into the store's per-(name, bits) scale cache.
    scales: Arc<Vec<f32>>,
    /// Self-speculative draft precision: `(bits, scales)` for the
    /// most-significant-`bits` plane prefix of the SAME pack, enabled by
    /// [`Backend::set_draft_bits`].  `bits < nw` always — the draft is a
    /// strictly cheaper model of the same weights, the serving width is
    /// its verifier.
    draft: Option<(u32, Arc<Vec<f32>>)>,
    /// Reused output buffer, grown to the largest batch seen.
    y: Vec<i32>,
    /// Reused flat dequant buffer (`n × vocab`, batch-major) — the old
    /// path allocated a `Vec<Vec<f32>>` per step.
    yf: Vec<f32>,
    /// GEMM worker-pool budget for this replica (`0` = global default).
    workers: usize,
    /// Times THIS backend decomposed+packed the weight matrix: 1 when it
    /// built its own store, 0 when it joined a shared superset store
    /// (packed once, elsewhere, for the whole cluster).
    weight_packs: u64,
    /// Activation batches packed (one per prefill tail + decode step).
    act_packs: u64,
}

impl ApGemm {
    fn shared(store: Arc<PackedWeightStore>, nw: u32, nx: u32) -> Self {
        let w = store.get(LM_HEAD).expect("superset store must register the lm head");
        assert!(
            (1..=w.planes.bits).contains(&nw),
            "serving precision W{nw} exceeds the {}-bit superset pack",
            w.planes.bits
        );
        let dim = w.planes.cols;
        // the model-level any-precision entry point: slice this serving
        // precision out of the superset once, keeping the rescaled
        // dequant scales for the per-step logit normalization
        let scales = store
            .get_at(LM_HEAD, nw)
            .expect("superset store must register the lm head")
            .scales;
        Self {
            store,
            arena: PackArena::new(),
            dim,
            nw,
            nx,
            scales,
            draft: None,
            y: Vec::new(),
            yf: Vec::new(),
            workers: 0,
            weight_packs: 0,
            act_packs: 0,
        }
    }

    fn new(vocab: usize, dim: usize, nw: u32, nx: u32, seed: u64) -> Self {
        let mut ap = Self::shared(superset_store(vocab, dim, nw, seed), nw, nx);
        ap.weight_packs = 1; // this backend owns the one-and-only pack
        ap
    }

    /// Deterministic activation codes for one (token, pos) slot.
    fn act_row(nx: u32, token: i32, pos: usize, out: &mut [u32]) {
        let mut rng = crate::util::Rng::with_seed(
            (token as u64).wrapping_mul(0x9E37_79B9).wrapping_add(pos as u64),
        );
        let hi = 1u32 << nx;
        for c in out.iter_mut() {
            *c = rng.u32(0, hi);
        }
    }

    /// Logits for a batch of (token, pos) rows via the prepacked kernel,
    /// the weight sliced at this backend's **serving** precision out of
    /// the shared superset (zero-copy, zero repack).
    fn logits(&mut self, rows: &[(i32, usize)]) -> Vec<Vec<f32>> {
        let scales = self.scales.clone();
        self.logits_at(rows, self.nw, &scales)
    }

    /// Draft-precision logits (the `bits < nw` plane prefix of the same
    /// pack), for the speculative drafter.  Errors until
    /// [`Backend::set_draft_bits`] armed the path.
    fn draft_logits(&mut self, rows: &[(i32, usize)]) -> Result<Vec<Vec<f32>>> {
        let Some((bits, scales)) = self.draft.clone() else {
            bail!("draft path not armed (call set_draft_bits first)");
        };
        Ok(self.logits_at(rows, bits, &scales))
    }

    /// Shared GEMM+dequant core: the weight sliced at `nw` planes with
    /// the matching rescaled `scales` — the serving path and the draft
    /// path differ ONLY in this pair; the activation pack, the kernel,
    /// and the dequant walk are one code path.
    fn logits_at(&mut self, rows: &[(i32, usize)], nw: u32, scales: &[f32]) -> Vec<Vec<f32>> {
        let w = self.store.get(LM_HEAD).expect("registered at construction");
        let planes = w.planes.view(nw);
        let (vocab, n) = (w.planes.rows, rows.len());
        let (dim, nx) = (self.dim, self.nx);
        let xp = self.arena.pack_batch(n, dim, nx, |i, out| {
            let (tok, pos) = rows[i];
            Self::act_row(nx, tok, pos, out);
        });
        self.act_packs += 1;
        self.y.resize(vocab * n, 0);
        // zero pack_codes calls, zero weight allocations from here on;
        // Auto sharding fans the GEMM out over this replica's worker pool
        // (the old scoped-thread spawn cost forced `parallel: false` here)
        apmm_bipolar_packed_into(
            &planes,
            &xp,
            ApmmOpts { workers: self.workers, ..ApmmOpts::default() },
            &mut self.y,
        );
        self.arena.recycle(xp);
        // dequant into the reused flat buffer, walking `y` m-major (its
        // own layout) with the row scale hoisted — the old nested collect
        // strided `y` by `n` per element and allocated per step
        let inv_dim = 1.0 / (dim as f32);
        self.yf.resize(n * vocab, 0.0);
        for mi in 0..vocab {
            let s = scales[mi] * inv_dim;
            let row = &self.y[mi * n..(mi + 1) * n];
            for (ni, &v) in row.iter().enumerate() {
                self.yf[ni * vocab + mi] = v as f32 * s;
            }
        }
        self.yf.chunks(vocab).map(|c| c.to_vec()).collect()
    }
}

/// Counters proving the pack-once flow (see [`SimBackend::ap_stats`]).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct ApStats {
    /// Times the weight matrix was packed — 1 for the whole lifetime.
    pub weight_packs: u64,
    /// Activation batches packed (one per backend step).
    pub act_packs: u64,
    /// Arena buffers allocated (one per distinct activation shape).
    pub arena_allocs: u64,
    /// Arena packs served from recycled buffers.
    pub arena_reuses: u64,
}

/// Deterministic fake backend: logits depend only on (last token, pos) so
/// serving behaviour is reproducible; per-step latency is configurable
/// to emulate a device.  With [`SimBackend::with_ap_gemm`], logits come
/// from a real prepacked bitmm GEMM instead of the hash rule.
pub struct SimBackend {
    pub vocab: usize,
    pub max_seq: usize,
    pub batches: Vec<usize>,
    pub step_latency: std::time::Duration,
    pub prefills: u64,
    pub decode_steps: u64,
    /// Single-row draft forwards served ([`Backend::draft_one`]).
    pub draft_steps: u64,
    ap: Option<ApGemm>,
}

impl SimBackend {
    pub fn new(vocab: usize, max_seq: usize, batches: Vec<usize>) -> Self {
        Self {
            vocab,
            max_seq,
            batches,
            step_latency: std::time::Duration::ZERO,
            prefills: 0,
            decode_steps: 0,
            draft_steps: 0,
            ap: None,
        }
    }

    /// A sim backend whose logits are computed by the pack-once AP-GEMM
    /// pipeline: a `(vocab, dim)` weight at `nw` bits packed once here,
    /// activations at `nx` bits packed per step through the arena.
    pub fn with_ap_gemm(
        vocab: usize,
        max_seq: usize,
        batches: Vec<usize>,
        dim: usize,
        nw: u32,
        nx: u32,
        seed: u64,
    ) -> Self {
        let mut b = Self::new(vocab, max_seq, batches);
        b.ap = Some(ApGemm::new(vocab, dim, nw, nx, seed));
        b
    }

    /// A sim backend serving at `W{nw}A{nx}` out of a **shared**
    /// any-precision superset store ([`superset_store`]) — the weight is
    /// packed once for the whole cluster, and this replica slices its
    /// `nw`-plane prefix per step (zero-copy).  Panics if `nw` exceeds
    /// the stored pack's width.  Vocab and hidden dim come from the
    /// store's weight shape, so every replica sharing a store serves the
    /// same model.
    pub fn with_shared_store(
        max_seq: usize,
        batches: Vec<usize>,
        store: Arc<PackedWeightStore>,
        nw: u32,
        nx: u32,
    ) -> Self {
        let vocab =
            store.get(LM_HEAD).expect("superset store must register the lm head").planes.rows;
        let mut b = Self::new(vocab, max_seq, batches);
        b.ap = Some(ApGemm::shared(store, nw, nx));
        b
    }

    /// Pack-once instrumentation (None for the hash-logits backend).
    pub fn ap_stats(&self) -> Option<ApStats> {
        self.ap.as_ref().map(|ap| ApStats {
            weight_packs: ap.weight_packs,
            act_packs: ap.act_packs,
            arena_allocs: ap.arena.allocs(),
            arena_reuses: ap.arena.reuses(),
        })
    }

    /// Resident packed-weight footprint of the AP path, if enabled.
    /// Replicas built over one shared store all report the same superset
    /// pack — count it **once** per cluster, not per replica.
    pub fn packed_weight_bytes(&self) -> usize {
        self.ap.as_ref().map(|ap| ap.store.packed_bytes()).unwrap_or(0)
    }

    /// The weight store this backend serves from (None for the
    /// hash-logits backend).  Replicas sharing a superset return clones
    /// of the same `Arc`.
    pub fn weight_store(&self) -> Option<Arc<PackedWeightStore>> {
        self.ap.as_ref().map(|ap| ap.store.clone())
    }

    /// Serving precision `(nw, nx)` of the AP path, if enabled.
    pub fn serving_bits(&self) -> Option<(u32, u32)> {
        self.ap.as_ref().map(|ap| (ap.nw, ap.nx))
    }

    /// Armed draft precision, if [`Backend::set_draft_bits`] accepted one.
    pub fn draft_bits(&self) -> Option<u32> {
        self.ap.as_ref().and_then(|ap| ap.draft.as_ref()).map(|(bits, _)| *bits)
    }

    /// GEMM worker budget of the AP path (`0` = global default), if
    /// enabled — set through [`Backend::set_workers`].
    pub fn gemm_workers(&self) -> Option<usize> {
        self.ap.as_ref().map(|ap| ap.workers)
    }

    fn logits_for(&mut self, rows: &[(i32, usize)]) -> Vec<Vec<f32>> {
        if let Some(ap) = self.ap.as_mut() {
            return ap.logits(rows);
        }
        rows.iter()
            .map(|&(token, pos)| {
                let mut v = vec![0f32; self.vocab];
                // deterministic "next token": mix of token and pos
                let top = ((token as usize).wrapping_mul(31).wrapping_add(pos * 7)) % self.vocab;
                v[top] = 10.0;
                v
            })
            .collect()
    }
}

impl Backend for SimBackend {
    fn vocab(&self) -> usize {
        self.vocab
    }

    fn max_seq(&self) -> usize {
        self.max_seq
    }

    fn supported_batches(&self) -> &[usize] {
        &self.batches
    }

    fn max_prompt(&self) -> usize {
        self.max_seq / 2
    }

    fn prefill_one(&mut self, prompt: &[i32]) -> Result<(Vec<f32>, SeqKv)> {
        if prompt.is_empty() || prompt.len() > self.max_prompt() {
            bail!("prompt length {} outside (0, {}]", prompt.len(), self.max_prompt());
        }
        self.prefills += 1;
        if !self.step_latency.is_zero() {
            std::thread::sleep(self.step_latency);
        }
        let last = *prompt.last().unwrap();
        let logits = self.logits_for(&[(last, prompt.len())]).remove(0);
        Ok((logits, SeqKv { k: vec![], v: vec![], pos: prompt.len() }))
    }

    fn decode_batch(&mut self, tokens: &[i32], kvs: &mut [&mut SeqKv]) -> Result<Vec<Vec<f32>>> {
        if tokens.len() != kvs.len() {
            bail!("token/kv mismatch");
        }
        if kvs.iter().any(|kv| kv.pos >= self.max_seq) {
            bail!("KV exhausted");
        }
        self.decode_steps += 1;
        if !self.step_latency.is_zero() {
            std::thread::sleep(self.step_latency);
        }
        let rows: Vec<(i32, usize)> =
            tokens.iter().zip(kvs.iter()).map(|(&t, kv)| (t, kv.pos)).collect();
        let out = self.logits_for(&rows);
        for kv in kvs.iter_mut() {
            kv.pos += 1;
        }
        Ok(out)
    }

    fn set_workers(&mut self, workers: usize) {
        if let Some(ap) = self.ap.as_mut() {
            ap.workers = workers;
        }
    }

    fn set_draft_bits(&mut self, bits: u32) -> bool {
        // only the AP path can draft: the hash-logits stand-in has no
        // plane prefix to slice, and the draft must be a STRICT subset of
        // the serving width (an equal-width "draft" would just double the
        // work for zero information)
        match self.ap.as_mut() {
            Some(ap) if bits >= 1 && bits < ap.nw => {
                let scales = ap
                    .store
                    .get_at(LM_HEAD, bits)
                    .expect("registered at construction")
                    .scales;
                ap.draft = Some((bits, scales));
                true
            }
            _ => false,
        }
    }

    fn draft_one(&mut self, token: i32, pos: usize) -> Result<Vec<f32>> {
        if pos >= self.max_seq {
            bail!("KV exhausted");
        }
        let Some(ap) = self.ap.as_mut() else {
            bail!("hash-logits sim backend has no draft path");
        };
        let row = ap.draft_logits(&[(token, pos)])?.remove(0);
        self.draft_steps += 1;
        Ok(row)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn sim_backend_deterministic() {
        let mut b1 = SimBackend::new(64, 32, vec![1, 2, 4]);
        let mut b2 = SimBackend::new(64, 32, vec![1, 2, 4]);
        let (l1, kv1) = b1.prefill_one(&[1, 2, 3]).unwrap();
        let (l2, kv2) = b2.prefill_one(&[1, 2, 3]).unwrap();
        assert_eq!(l1, l2);
        assert_eq!(kv1.pos, 3);
        assert_eq!(kv2.pos, 3);
    }

    #[test]
    fn sim_decode_advances_positions() {
        let mut b = SimBackend::new(64, 32, vec![1, 2]);
        let (_, mut kva) = b.prefill_one(&[1]).unwrap();
        let (_, mut kvb) = b.prefill_one(&[2, 3]).unwrap();
        let logits = b.decode_batch(&[5, 6], &mut [&mut kva, &mut kvb]).unwrap();
        assert_eq!(logits.len(), 2);
        assert_eq!(kva.pos, 2);
        assert_eq!(kvb.pos, 3);
        assert_eq!(b.decode_steps, 1);
    }

    #[test]
    fn sim_rejects_bad_prompts() {
        let mut b = SimBackend::new(64, 32, vec![1]);
        assert!(b.prefill_one(&[]).is_err());
        assert!(b.prefill_one(&vec![1; 17]).is_err());
    }

    #[test]
    fn ap_backend_packs_weights_once() {
        let mut b = SimBackend::with_ap_gemm(48, 64, vec![1, 2, 4], 96, 2, 2, 11);
        assert!(b.packed_weight_bytes() > 0);
        let (l, mut kva) = b.prefill_one(&[3, 1, 4]).unwrap();
        assert_eq!(l.len(), 48);
        assert!(l.iter().any(|&x| x != 0.0), "AP logits must be real GEMM output");
        let (_, mut kvb) = b.prefill_one(&[1, 5]).unwrap();
        for step in 0..5 {
            let out = b.decode_batch(&[step, step + 1], &mut [&mut kva, &mut kvb]).unwrap();
            assert_eq!(out.len(), 2);
            assert_eq!(out[0].len(), 48);
        }
        let s = b.ap_stats().unwrap();
        assert_eq!(s.weight_packs, 1, "weights must be packed exactly once");
        assert_eq!(s.act_packs, 7, "2 prefills + 5 decode steps");
        // activation shapes: batch 1 (prefills) and batch 2 (decodes) →
        // two distinct arena buffers, everything else recycled
        assert_eq!(s.arena_allocs, 2);
        assert_eq!(s.arena_reuses, 5);
    }

    #[test]
    fn shared_store_replicas_serve_one_superset_pack() {
        // the any-precision memory model: a W4A4 and a W2A2 replica share
        // ONE 4-bit superset pack; neither packs anything itself, and the
        // full-width replica is bit-identical to a privately-built backend
        let store = superset_store(48, 96, 4, 11);
        let mut w4 = SimBackend::with_shared_store(64, vec![1, 2, 4], store.clone(), 4, 4);
        let mut w2 = SimBackend::with_shared_store(64, vec![1, 2, 4], store.clone(), 2, 2);
        assert_eq!(w4.vocab, 48, "vocab comes from the store's weight shape");
        assert_eq!(w4.packed_weight_bytes(), store.packed_bytes());
        assert_eq!(w2.packed_weight_bytes(), store.packed_bytes());
        assert!(Arc::ptr_eq(&w4.weight_store().unwrap(), &store), "same physical store");
        assert!(Arc::ptr_eq(&w2.weight_store().unwrap(), &store));
        assert_eq!(w4.serving_bits(), Some((4, 4)));
        assert_eq!(w2.serving_bits(), Some((2, 2)));

        let (l4, _) = w4.prefill_one(&[3, 1, 4]).unwrap();
        let (l2, _) = w2.prefill_one(&[3, 1, 4]).unwrap();
        assert_ne!(l4, l2, "precisions really select different plane prefixes");
        assert_eq!(w4.ap_stats().unwrap().weight_packs, 0, "shared store: packed elsewhere");
        assert_eq!(w2.ap_stats().unwrap().weight_packs, 0);

        let mut own = SimBackend::with_ap_gemm(48, 64, vec![1, 2, 4], 96, 4, 4, 11);
        let (lo, _) = own.prefill_one(&[3, 1, 4]).unwrap();
        assert_eq!(lo, l4, "full-width view ≡ privately packed weight");
        assert_eq!(own.ap_stats().unwrap().weight_packs, 1, "private store packs once, here");
    }

    #[test]
    #[should_panic(expected = "exceeds the")]
    fn shared_store_rejects_precisions_beyond_the_superset() {
        let store = superset_store(16, 32, 2, 3);
        SimBackend::with_shared_store(64, vec![1], store, 4, 4);
    }

    #[test]
    fn ap_logits_identical_across_worker_counts() {
        // the parallel hot path must be invisible in the outputs: the
        // GEMM is exact-i64 under every shard policy, and the dequant
        // multiplies in the same order regardless of worker count
        let run = |workers: usize| {
            let mut b = SimBackend::with_ap_gemm(48, 64, vec![1, 2, 4], 96, 3, 2, 21);
            b.set_workers(workers);
            assert_eq!(b.gemm_workers(), Some(workers));
            let (l, mut kv) = b.prefill_one(&[3, 1, 4]).unwrap();
            let d = b.decode_batch(&[5], &mut [&mut kv]).unwrap();
            (l, d)
        };
        let base = run(1);
        assert_eq!(run(2), base);
        assert_eq!(run(4), base);
    }

    #[test]
    fn draft_path_is_exactly_the_low_bit_replica_of_the_same_pack() {
        // a W4 backend drafting at W2 must produce, row for row, the
        // logits a W2-serving replica of the SAME superset store computes
        // — the draft is not an approximation of a different model, it IS
        // the lower-precision model the any-precision store already serves
        let store = superset_store(48, 96, 4, 11);
        let mut w4 = SimBackend::with_shared_store(64, vec![1, 2, 4], store.clone(), 4, 2);
        let mut w2 = SimBackend::with_shared_store(64, vec![1, 2, 4], store.clone(), 2, 2);
        assert!(w4.set_draft_bits(2), "W2 is a strict subset of the W4 serving width");
        assert_eq!(w4.draft_bits(), Some(2));

        let (_, mut kv) = w2.prefill_one(&[3, 1, 4]).unwrap();
        let wide = w2.decode_batch(&[5], &mut [&mut kv]).unwrap().remove(0);
        let draft = w4.draft_one(5, 3).unwrap();
        assert_eq!(draft, wide, "draft logits ≡ the W2 replica's serving logits");
        assert_eq!(w4.draft_steps, 1);
        // drafting never advanced the verifier's own step counters
        assert_eq!(w4.decode_steps, 0);
    }

    #[test]
    fn set_draft_bits_rejects_non_subset_widths_and_hash_backends() {
        let mut hash = SimBackend::new(64, 32, vec![1, 2]);
        assert!(!hash.set_draft_bits(1), "hash backend has no planes to slice");
        assert!(hash.draft_one(1, 0).is_err());

        let mut ap = SimBackend::with_ap_gemm(48, 64, vec![1, 2, 4], 96, 2, 2, 11);
        assert!(!ap.set_draft_bits(2), "draft must be strictly below the serving width");
        assert!(!ap.set_draft_bits(3), "wider than serving is not a draft");
        assert!(!ap.set_draft_bits(0));
        assert_eq!(ap.draft_bits(), None);
        assert!(ap.draft_one(1, 0).is_err(), "unarmed draft path must error");
        assert!(ap.set_draft_bits(1), "W1 of W2 is valid");
    }

    #[test]
    fn ap_backend_deterministic() {
        let run = || {
            let mut b = SimBackend::with_ap_gemm(32, 64, vec![1, 2], 64, 1, 2, 9);
            let (l, mut kv) = b.prefill_one(&[7, 8]).unwrap();
            let d = b.decode_batch(&[9], &mut [&mut kv]).unwrap();
            (l, d)
        };
        assert_eq!(run(), run());
    }
}
