//! Group scheduler (the original iteration-level path) over a
//! [`Backend`], now a streaming [`Stepper`]: every iteration emits
//! [`TokenEvent`]s as sequences admit, generate, and finish.  It
//! reserves each sequence's full budget up front, so it never preempts
//! — and therefore never emits `Preempted`/`Migrated`/`Resumed` (nor
//! the cluster-only `PrefillDone` handoff marker); its
//! KV pool keeps the default LRU eviction order but the order is moot
//! without a prefix cache on this path (`KvPool::admit` only).
//!
//! Every `step()`:
//!   1. **Admission** — move queued requests into the running set while a
//!      decode slot AND enough KV blocks are free (prompt + max_new
//!      tokens, reserved up front so a running sequence can never hit an
//!      out-of-blocks mid-generation).
//!   2. **Prefill** — new admissions prefill individually (batch-1
//!      artifact) and stream their first token.
//!   3. **Decode** — all running sequences advance one token in a single
//!      batched step (per-slot positions; the decode artifacts accept
//!      mixed depths), each token streamed as produced.
//!   4. **Completion** — finished sequences release their blocks and
//!      stream a terminal [`TokenEvent::Finished`].

use super::backend::{gather_kv_refs, Backend, HasSeqKv, SeqKv};
use super::kv::KvPool;
use super::metrics::Metrics;
use super::request::{responses_of, sample_token, Request, Response, TokenEvent};
use super::server::Stepper;
use crate::anyhow::Result;
use std::collections::VecDeque;
use std::time::Instant;

#[derive(Debug, Clone)]
pub struct SchedulerConfig {
    /// KV pool capacity in blocks.
    pub kv_blocks: usize,
    /// Tokens per KV block.
    pub block_tokens: usize,
    /// Max sequences decoding concurrently (≤ backend max batch).
    pub max_running: usize,
}

impl Default for SchedulerConfig {
    fn default() -> Self {
        Self { kv_blocks: 64, block_tokens: 16, max_running: 8 }
    }
}

struct Active {
    req: Request,
    kv: SeqKv,
    next_token: i32,
    generated: Vec<i32>,
    first_token_at: Instant,
    /// When this sequence's previous token streamed (ITL measurement).
    last_token_at: Instant,
}

impl HasSeqKv for Active {
    fn kv_mut(&mut self) -> &mut SeqKv {
        &mut self.kv
    }
}

/// The scheduler: single-threaded state machine (the server wraps it).
pub struct Scheduler<B: Backend> {
    backend: B,
    cfg: SchedulerConfig,
    pool: KvPool,
    queue: VecDeque<Request>,
    running: Vec<Active>,
    pub metrics: Metrics,
}

impl<B: Backend> Scheduler<B> {
    pub fn new(backend: B, cfg: SchedulerConfig) -> Self {
        let cap = cfg.max_running.min(*backend.supported_batches().last().unwrap());
        let cfg = SchedulerConfig { max_running: cap, ..cfg };
        Self {
            pool: KvPool::new(cfg.kv_blocks, cfg.block_tokens),
            backend,
            cfg,
            queue: VecDeque::new(),
            running: Vec::new(),
            metrics: Metrics::default(),
        }
    }

    pub fn backend(&self) -> &B {
        &self.backend
    }

    pub fn submit(&mut self, req: Request) {
        self.metrics.requests_in += 1;
        self.queue.push_back(req);
    }

    pub fn queued(&self) -> usize {
        self.queue.len()
    }

    pub fn running(&self) -> usize {
        self.running.len()
    }

    pub fn is_idle(&self) -> bool {
        self.queue.is_empty() && self.running.is_empty()
    }

    /// One scheduling iteration.  Returns the events it produced.
    pub fn step(&mut self) -> Result<Vec<TokenEvent>> {
        let now = Instant::now();
        let mut events = Vec::new();

        // 1+2: admission + prefill
        while self.running.len() < self.cfg.max_running {
            let Some(front) = self.queue.front() else { break };
            if front.prompt.is_empty() || front.prompt.len() > self.backend.max_prompt() {
                // reject malformed request: terminal event, empty stream
                let req = self.queue.pop_front().unwrap();
                self.metrics.requests_done += 1;
                events.push(TokenEvent::Finished {
                    id: req.id,
                    response: Response::rejected(req.id),
                });
                continue;
            }
            let budget = front.prompt.len() + front.params.max_new_tokens;
            if !self.pool.can_admit(budget) {
                break; // head-of-line blocks until memory frees
            }
            let req = self.queue.pop_front().unwrap();
            self.pool.admit(req.id.0, budget)?;
            self.metrics.queue.record(now.duration_since(req.arrived).as_secs_f64());
            events.push(TokenEvent::Admitted { id: req.id });
            let (logits, kv) = match self.backend.prefill_one(&req.prompt) {
                Ok(r) => r,
                Err(e) => {
                    // a failed prefill must not strand the admission's
                    // blocks — release before surfacing the error
                    self.pool.release(req.id.0)?;
                    return Err(e);
                }
            };
            let tok = sample_token(&logits, &req.params, 0);
            let first_token_at = Instant::now();
            self.metrics.ttft.record(first_token_at.duration_since(req.arrived).as_secs_f64());
            self.metrics.tokens_generated += 1;
            events.push(TokenEvent::Token { id: req.id, token: tok, step: 0 });
            self.running.push(Active {
                req,
                kv,
                next_token: tok,
                generated: vec![tok],
                first_token_at,
                last_token_at: first_token_at,
            });
        }

        // 3: batched decode for sequences still needing tokens
        let mut decode_idx: Vec<usize> = (0..self.running.len())
            .filter(|&i| {
                self.running[i].generated.len() < self.running[i].req.params.max_new_tokens
            })
            .collect();
        // cap at the largest supported group; the rest advances next step
        if let Some(&maxb) = self.backend.supported_batches().last() {
            decode_idx.truncate(maxb);
        }
        if !decode_idx.is_empty() {
            let tokens: Vec<i32> = decode_idx.iter().map(|&i| self.running[i].next_token).collect();
            let mut kv_refs = gather_kv_refs(&mut self.running, &decode_idx);
            let logits = self.backend.decode_batch(&tokens, &mut kv_refs)?;
            self.metrics.groups_executed += 1;
            self.metrics.batch_occupancy_sum += decode_idx.len() as u64;
            for (j, &i) in decode_idx.iter().enumerate() {
                let step = self.running[i].generated.len();
                let tok = sample_token(&logits[j], &self.running[i].req.params, step);
                let a = &mut self.running[i];
                a.next_token = tok;
                a.generated.push(tok);
                let t = Instant::now();
                self.metrics.itl.record(t.duration_since(a.last_token_at).as_secs_f64());
                a.last_token_at = t;
                // no pool.append_token here: admission reserved the full
                // prompt+max_new budget up front, so decoding can't OOM
                self.metrics.tokens_generated += 1;
                events.push(TokenEvent::Token { id: a.req.id, token: tok, step });
            }
        }

        // 4: completion
        let mut i = 0;
        while i < self.running.len() {
            let finished = self.running[i].generated.len()
                >= self.running[i].req.params.max_new_tokens
                || self.running[i].kv.pos >= self.backend.max_seq();
            if finished {
                let a = self.running.swap_remove(i);
                self.pool.release(a.req.id.0)?;
                let now = Instant::now();
                self.metrics.requests_done += 1;
                let total = now.duration_since(a.req.arrived).as_secs_f64();
                self.metrics.total.record(total);
                events.push(TokenEvent::Finished {
                    id: a.req.id,
                    response: Response {
                        id: a.req.id,
                        tokens: a.generated,
                        queue_s: 0.0, // recorded in metrics; per-response uses ttft/total
                        total_s: total,
                        ttft_s: a.first_token_at.duration_since(a.req.arrived).as_secs_f64(),
                    },
                });
            } else {
                i += 1;
            }
        }
        Ok(events)
    }

    /// Step until every submitted request resolved; returns the terminal
    /// responses (rejected requests appear with empty token streams).
    pub fn run_to_completion(&mut self) -> Result<Vec<Response>> {
        self.metrics.start();
        let events = super::server::drain(self)?;
        self.metrics.finish();
        Ok(responses_of(&events))
    }

    /// KV pool introspection for tests.
    pub fn pool(&self) -> &KvPool {
        &self.pool
    }
}

impl<B: Backend> Stepper for Scheduler<B> {
    fn submit(&mut self, r: Request) {
        Scheduler::submit(self, r);
    }

    fn step(&mut self) -> Result<Vec<TokenEvent>> {
        Scheduler::step(self)
    }

    fn is_idle(&self) -> bool {
        Scheduler::is_idle(self)
    }

    fn metrics(&self) -> Metrics {
        self.metrics.clone()
    }

    fn start_clock(&mut self) {
        self.metrics.start();
    }

    fn stop_clock(&mut self) {
        self.metrics.finish();
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::backend::SimBackend;
    use crate::coordinator::request::GenParams;
    use crate::util::proptest::forall;

    fn mk(max_running: usize, kv_blocks: usize) -> Scheduler<SimBackend> {
        Scheduler::new(
            SimBackend::new(64, 64, vec![1, 2, 4, 8]),
            SchedulerConfig { kv_blocks, block_tokens: 8, max_running },
        )
    }

    fn req(id: u64, prompt_len: usize, max_new: usize) -> Request {
        Request::new(
            id,
            (0..prompt_len as i32).collect(),
            GenParams { max_new_tokens: max_new, sample: false, seed: id },
        )
    }

    #[test]
    fn single_request_generates_exactly_max_new() {
        let mut s = mk(4, 64);
        s.submit(req(1, 5, 7));
        let out = s.run_to_completion().unwrap();
        assert_eq!(out.len(), 1);
        assert_eq!(out[0].tokens.len(), 7);
        assert_eq!(s.pool().free_blocks(), 64, "all blocks returned");
    }

    #[test]
    fn batching_actually_batches() {
        let mut s = mk(8, 64);
        for i in 0..8 {
            s.submit(req(i, 4, 10));
        }
        let out = s.run_to_completion().unwrap();
        assert_eq!(out.len(), 8);
        // 8 concurrent sequences, 9 decode steps each (first token from
        // prefill) → occupancy near 8
        assert!(s.metrics.mean_occupancy() > 6.0, "occ {}", s.metrics.mean_occupancy());
        assert_eq!(s.metrics.tokens_generated, 80);
        // streaming ITL: one inter-token gap per decoded (non-first) token
        assert_eq!(s.metrics.itl.count() as u64, s.metrics.tokens_generated - 8);
    }

    #[test]
    fn determinism() {
        let run = || {
            let mut s = mk(4, 64);
            for i in 0..6 {
                s.submit(req(i, 3 + i as usize % 4, 6));
            }
            let mut out = s.run_to_completion().unwrap();
            out.sort_by_key(|r| r.id);
            out.iter().map(|r| r.tokens.clone()).collect::<Vec<_>>()
        };
        assert_eq!(run(), run());
    }

    #[test]
    fn kv_pressure_serializes_but_completes() {
        // pool fits only ~1 sequence at a time
        let mut s = mk(8, 3); // 3 blocks × 8 tokens = 24 token budget
        for i in 0..5 {
            s.submit(req(i, 8, 8)); // budget 16 → 2 blocks each
        }
        let out = s.run_to_completion().unwrap();
        assert_eq!(out.len(), 5, "head-of-line blocking must not deadlock");
        assert_eq!(s.pool().free_blocks(), 3);
    }

    #[test]
    fn mixed_depth_requests_complete_with_correct_lengths() {
        let mut s = mk(8, 64);
        s.submit(req(0, 2, 3));
        s.submit(req(1, 9, 12));
        s.submit(req(2, 1, 1));
        let mut out = s.run_to_completion().unwrap();
        out.sort_by_key(|r| r.id);
        assert_eq!(out[0].tokens.len(), 3);
        assert_eq!(out[1].tokens.len(), 12);
        assert_eq!(out[2].tokens.len(), 1);
    }

    #[test]
    fn oversized_prompt_rejected_not_wedged() {
        let mut s = mk(4, 64);
        s.submit(req(0, 33, 4)); // SimBackend max_prompt = 32
        s.submit(req(1, 4, 4));
        let out = s.run_to_completion().unwrap();
        // the reject resolves terminally (empty stream), the valid
        // request completes normally
        assert_eq!(out.len(), 2);
        let rejected: Vec<_> = out.iter().filter(|r| r.tokens.is_empty()).collect();
        assert_eq!(rejected.len(), 1);
        assert_eq!(rejected[0].id.0, 0);
        assert_eq!(out.iter().find(|r| r.id.0 == 1).unwrap().tokens.len(), 4);
    }

    #[test]
    fn step_streams_tokens_in_generation_order() {
        let mut s = mk(2, 64);
        s.submit(req(0, 3, 4));
        let mut events = Vec::new();
        while !s.is_idle() {
            events.extend(s.step().unwrap());
        }
        let toks: Vec<(i32, usize)> = events
            .iter()
            .filter_map(|e| match e {
                TokenEvent::Token { token, step, .. } => Some((*token, *step)),
                _ => None,
            })
            .collect();
        assert_eq!(toks.len(), 4);
        assert!(toks.iter().enumerate().all(|(i, &(_, st))| st == i), "steps ascend");
        let resp = responses_of(&events).remove(0);
        assert_eq!(resp.tokens, toks.iter().map(|&(t, _)| t).collect::<Vec<_>>());
        assert!(matches!(events.first(), Some(TokenEvent::Admitted { .. })));
        assert!(matches!(events.last(), Some(TokenEvent::Finished { .. })));
    }

    #[test]
    fn prop_scheduler_conserves_and_bounds() {
        forall(24, |rng| {
            let max_running = [1, 2, 4, 8][rng.usize(0, 4)];
            let blocks = rng.usize(4, 40);
            let mut s = mk(max_running, blocks);
            let n = rng.usize(1, 16);
            let mut want_tokens = 0usize;
            for i in 0..n {
                let plen = rng.usize(1, 12);
                let mnew = rng.usize(1, 10);
                // only submit requests the pool can EVER hold
                if s.pool().blocks_for(plen + mnew) <= blocks {
                    s.submit(req(i as u64, plen, mnew));
                    want_tokens += mnew;
                }
            }
            let out = s.run_to_completion().unwrap();
            let got: usize = out.iter().map(|r| r.tokens.len()).sum();
            assert_eq!(got, want_tokens, "every request gets exactly max_new tokens");
            assert_eq!(s.pool().free_blocks(), blocks, "no leaked blocks");
            assert!(s.is_idle());
            s.pool().check_invariants().unwrap();
            // occupancy never exceeded the cap (implied by supported sizes)
            assert!(s.metrics.mean_occupancy() <= max_running as f64 + 1e-9);
        });
    }
}
