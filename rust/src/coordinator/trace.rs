//! Synthetic workload traces (the substitute for production request logs
//! — DESIGN.md §2): Poisson and bursty arrival processes with
//! configurable prompt/output length distributions, used by the serving
//! demo, the coordinator bench, and capacity tests.  Traces can draw
//! prompts from a small pool of **shared system prefixes** (the workload
//! shape the KV prefix cache exists for).

use super::request::{GenParams, Request};
use crate::util::Rng;

/// Arrival process shape.
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum ArrivalKind {
    /// Memoryless arrivals at `rate` req/s.
    Poisson { rate: f64 },
    /// `burst_size` back-to-back requests every `period_s`.
    Bursty { burst_size: usize, period_s: f64 },
}

/// Trace generator configuration.
#[derive(Debug, Clone)]
pub struct TraceConfig {
    pub kind: ArrivalKind,
    pub requests: usize,
    /// Prompt length range `[lo, hi)` (uniform).  With shared prefixes,
    /// this is the per-request *tail* length after the prefix.
    pub prompt_len: (usize, usize),
    /// max_new_tokens range `[lo, hi)` (uniform).
    pub max_new: (usize, usize),
    pub vocab: usize,
    pub seed: u64,
    /// Number of distinct shared system prompts to draw from (0 = every
    /// prompt fully random — no sharing opportunity).
    pub shared_prefixes: usize,
    /// Tokens per shared prefix.
    pub prefix_len: usize,
    /// Popularity skew across the prefix pool, in `[0, 1)`: 0.0 draws
    /// prefixes uniformly; otherwise each successive prefix is `skew`×
    /// as likely as the one before it (P(i) ∝ skewⁱ, remaining mass on
    /// the last), so **smaller** non-zero values concentrate traffic
    /// harder on the first prefixes — the hot-system-prompt shape where
    /// recency-aware KV eviction pays.  Values ≥ 1.0 are rejected.
    pub prefix_skew: f64,
}

impl Default for TraceConfig {
    fn default() -> Self {
        Self {
            kind: ArrivalKind::Poisson { rate: 20.0 },
            requests: 32,
            prompt_len: (4, 16),
            max_new: (4, 12),
            vocab: 1024,
            seed: 0,
            shared_prefixes: 0,
            prefix_len: 0,
            prefix_skew: 0.0,
        }
    }
}

impl TraceConfig {
    /// Decode-heavy preset: short prompts, long greedy generations —
    /// per-step decode work dominates prefill by an order of magnitude,
    /// which is the workload speculative decoding exists for (every
    /// accepted draft token is one decode step saved; prefill-bound
    /// traces would bury the effect).  Greedy sampling is part of the
    /// shape: the spec bench compares `spec_k` settings stream-for-stream
    /// and greedy keeps the reference cheap to reason about.  `requests`
    /// and `rate` stay caller-chosen so smoke and full bench runs can
    /// size it.
    pub fn decode_heavy(requests: usize, rate: f64, seed: u64) -> Self {
        Self {
            kind: ArrivalKind::Poisson { rate },
            requests,
            prompt_len: (2, 5),
            max_new: (16, 33),
            seed,
            ..Self::default()
        }
    }

    /// Prefill-heavy bursty preset: long prompts, short generations,
    /// arriving in back-to-back bursts — the workload disaggregated
    /// prefill/decode serving exists for.  Each burst drops several long
    /// prefills on the cluster at once; on a mixed deployment those
    /// prefills stall the inter-token latency of every sequence already
    /// decoding, while a prefill/decode split absorbs the burst on the
    /// prefill tier and keeps the decode tier's ITL flat.  The serving
    /// bench's `disaggregated` section replays this trace against both
    /// topologies and reports per-role TTFT/ITL.
    pub fn prefill_heavy(requests: usize, burst_size: usize, period_s: f64, seed: u64) -> Self {
        Self {
            kind: ArrivalKind::Bursty { burst_size, period_s },
            requests,
            prompt_len: (24, 49),
            max_new: (4, 9),
            seed,
            ..Self::default()
        }
    }
}

/// A request plus its arrival offset from trace start.
#[derive(Debug, Clone)]
pub struct TimedRequest {
    pub at_s: f64,
    pub request: Request,
}

/// Generate a deterministic trace.
pub fn generate(cfg: &TraceConfig) -> Vec<TimedRequest> {
    assert!(
        (0.0..1.0).contains(&cfg.prefix_skew),
        "prefix_skew must be in [0, 1), got {}",
        cfg.prefix_skew
    );
    let mut rng = Rng::with_seed(cfg.seed);
    // the prefix pool lives on its own stream, so the same seed yields
    // the same prefixes regardless of the request count
    let prefixes: Vec<Vec<i32>> = if cfg.shared_prefixes > 0 && cfg.prefix_len > 0 {
        let mut prng = Rng::with_seed(cfg.seed ^ 0x5EED_F00D_CAFE_D00D);
        (0..cfg.shared_prefixes)
            .map(|_| (0..cfg.prefix_len).map(|_| prng.u32(1, cfg.vocab as u32) as i32).collect())
            .collect()
    } else {
        Vec::new()
    };
    let mut out = Vec::with_capacity(cfg.requests);
    let mut t = 0.0f64;
    for i in 0..cfg.requests {
        t = match cfg.kind {
            ArrivalKind::Poisson { rate } => t + rng.exponential(rate),
            ArrivalKind::Bursty { burst_size, period_s } => {
                (i / burst_size.max(1)) as f64 * period_s
            }
        };
        let plen = rng.usize(cfg.prompt_len.0, cfg.prompt_len.1.max(cfg.prompt_len.0 + 1));
        let mnew = rng.usize(cfg.max_new.0, cfg.max_new.1.max(cfg.max_new.0 + 1));
        let mut prompt: Vec<i32> = if prefixes.is_empty() {
            Vec::with_capacity(plen)
        } else if cfg.prefix_skew > 0.0 {
            // geometric popularity: keep advancing past each prefix with
            // probability `skew`, so low indices dominate
            let mut idx = 0;
            while idx + 1 < prefixes.len() && rng.f64() < cfg.prefix_skew {
                idx += 1;
            }
            prefixes[idx].clone()
        } else {
            prefixes[rng.usize(0, prefixes.len())].clone()
        };
        prompt.extend((0..plen).map(|_| rng.u32(1, cfg.vocab as u32) as i32));
        out.push(TimedRequest {
            at_s: t,
            request: Request::new(
                i as u64,
                prompt,
                GenParams { max_new_tokens: mnew, sample: false, seed: i as u64 },
            ),
        });
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::proptest::forall;

    #[test]
    fn poisson_trace_is_sorted_and_deterministic() {
        let cfg = TraceConfig::default();
        let a = generate(&cfg);
        let b = generate(&cfg);
        assert_eq!(a.len(), 32);
        for (x, y) in a.iter().zip(&b) {
            assert_eq!(x.at_s, y.at_s);
            assert_eq!(x.request.prompt, y.request.prompt);
        }
        assert!(a.windows(2).all(|w| w[0].at_s <= w[1].at_s));
    }

    #[test]
    fn poisson_rate_approximately_holds() {
        let cfg = TraceConfig {
            kind: ArrivalKind::Poisson { rate: 50.0 },
            requests: 2000,
            ..Default::default()
        };
        let tr = generate(&cfg);
        let span = tr.last().unwrap().at_s;
        let rate = 2000.0 / span;
        assert!((40.0..60.0).contains(&rate), "empirical rate {rate}");
    }

    #[test]
    fn bursty_trace_groups() {
        let cfg = TraceConfig {
            kind: ArrivalKind::Bursty { burst_size: 4, period_s: 1.0 },
            requests: 12,
            ..Default::default()
        };
        let tr = generate(&cfg);
        assert_eq!(tr[0].at_s, 0.0);
        assert_eq!(tr[3].at_s, 0.0);
        assert_eq!(tr[4].at_s, 1.0);
        assert_eq!(tr[11].at_s, 2.0);
    }

    #[test]
    fn shared_prefixes_actually_share() {
        let cfg = TraceConfig {
            requests: 40,
            shared_prefixes: 3,
            prefix_len: 12,
            prompt_len: (2, 6),
            ..Default::default()
        };
        let tr = generate(&cfg);
        let heads: std::collections::HashSet<Vec<i32>> =
            tr.iter().map(|t| t.request.prompt[..12].to_vec()).collect();
        assert!(heads.len() <= 3, "{} distinct heads from 3 prefixes", heads.len());
        assert!(heads.len() >= 2, "40 draws should hit ≥2 of 3 prefixes");
        for t in &tr {
            let plen = t.request.prompt.len();
            assert!((12 + 2..12 + 6).contains(&plen), "prefix + tail length, got {plen}");
        }
        // same seed → same prefix pool even at different request counts
        let tr2 = generate(&TraceConfig { requests: 5, ..cfg.clone() });
        let heads2: std::collections::HashSet<Vec<i32>> =
            tr2.iter().map(|t| t.request.prompt[..12].to_vec()).collect();
        assert!(heads.union(&heads2).count() <= 3, "both draws use the same 3-prefix pool");
    }

    #[test]
    fn prefix_skew_biases_toward_hot_prefixes() {
        let cfg = TraceConfig {
            requests: 300,
            shared_prefixes: 4,
            prefix_len: 8,
            prompt_len: (1, 3),
            prefix_skew: 0.3,
            ..Default::default()
        };
        let tr = generate(&cfg);
        let mut counts: std::collections::HashMap<Vec<i32>, usize> = Default::default();
        for t in &tr {
            *counts.entry(t.request.prompt[..8].to_vec()).or_default() += 1;
        }
        assert!((2..=4).contains(&counts.len()));
        let mut by_pop: Vec<usize> = counts.values().copied().collect();
        by_pop.sort_unstable();
        by_pop.reverse();
        // geometric skew: P(hot) = 0.7 → ~210 of 300; the cold tail is tiny
        assert!(by_pop[0] > 150, "hot prefix drew {} of 300", by_pop[0]);
        assert!(*by_pop.last().unwrap() < 60, "cold prefix drew {}", by_pop.last().unwrap());
        // same seed → same draws
        let tr2 = generate(&cfg);
        assert!(tr.iter().zip(&tr2).all(|(a, b)| a.request.prompt == b.request.prompt));
    }

    #[test]
    fn decode_heavy_preset_is_decode_dominated_and_greedy() {
        let tr = generate(&TraceConfig::decode_heavy(50, 100.0, 7));
        assert_eq!(tr.len(), 50);
        let (mut prompt_tokens, mut decode_tokens) = (0usize, 0usize);
        for t in &tr {
            assert!((2..5).contains(&t.request.prompt.len()));
            assert!((16..33).contains(&t.request.params.max_new_tokens));
            assert!(!t.request.params.sample, "preset must be greedy");
            prompt_tokens += t.request.prompt.len();
            decode_tokens += t.request.params.max_new_tokens;
        }
        assert!(
            decode_tokens >= 4 * prompt_tokens,
            "decode ({decode_tokens}) must dominate prefill ({prompt_tokens})"
        );
        // deterministic like every other preset
        let tr2 = generate(&TraceConfig::decode_heavy(50, 100.0, 7));
        assert!(tr.iter().zip(&tr2).all(|(a, b)| a.request.prompt == b.request.prompt));
    }

    #[test]
    fn prefill_heavy_preset_is_prefill_dominated_and_bursty() {
        let tr = generate(&TraceConfig::prefill_heavy(24, 6, 0.5, 9));
        assert_eq!(tr.len(), 24);
        let (mut prompt_tokens, mut decode_tokens) = (0usize, 0usize);
        for t in &tr {
            assert!((24..49).contains(&t.request.prompt.len()));
            assert!((4..9).contains(&t.request.params.max_new_tokens));
            assert!(!t.request.params.sample, "preset must be greedy");
            prompt_tokens += t.request.prompt.len();
            decode_tokens += t.request.params.max_new_tokens;
        }
        assert!(
            prompt_tokens >= 3 * decode_tokens,
            "prefill ({prompt_tokens}) must dominate decode ({decode_tokens})"
        );
        // bursts of 6 land together
        assert_eq!(tr[0].at_s, 0.0);
        assert_eq!(tr[5].at_s, 0.0);
        assert_eq!(tr[6].at_s, 0.5);
        assert_eq!(tr[23].at_s, 1.5);
        let tr2 = generate(&TraceConfig::prefill_heavy(24, 6, 0.5, 9));
        assert!(tr.iter().zip(&tr2).all(|(a, b)| a.request.prompt == b.request.prompt));
    }

    #[test]
    fn prop_lengths_in_range() {
        forall(24, |rng| {
            let lo = rng.usize(1, 8);
            let hi = lo + rng.usize(1, 8);
            let cfg = TraceConfig {
                prompt_len: (lo, hi),
                max_new: (lo, hi),
                requests: 20,
                seed: rng.u64(),
                ..Default::default()
            };
            for tr in generate(&cfg) {
                assert!((lo..hi).contains(&tr.request.prompt.len()));
                assert!((lo..hi).contains(&tr.request.params.max_new_tokens));
                assert!(tr.request.prompt.iter().all(|&t| t >= 1 && t < 1024));
            }
        });
    }
}
