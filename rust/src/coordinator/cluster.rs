//! Multi-replica serving cluster: N independent continuous-batching
//! [`Engine`] replicas — each with its own [`KvPool`](super::kv::KvPool),
//! batcher, and pack-once backend, possibly at different W/A precisions —
//! driven behind the [`Router`].
//!
//! This is the deployment shape the related work motivates: FP6-LLM
//! frames low-bit kernels as one half of an end-to-end serving co-design,
//! and Any-Precision LLM serves several precisions from one deployment —
//! which is exactly what a router over per-precision replicas provides.
//! A request optionally pins a [`PrecisionConfig`]
//! ([`Request::precision`]); the router narrows to matching replicas and
//! picks by policy (round-robin, or least outstanding token budget).
//!
//! The cluster is itself a [`Stepper`]: `submit` routes, `step` advances
//! every busy replica and merges their streamed [`TokenEvent`]s (tagging
//! completions back to the router so its load accounting drains), and
//! `metrics` merges per-replica metrics into one view.  Everything that
//! serves a single engine — [`Server`](super::server::Server),
//! [`replay_trace`](super::server::replay_trace), the benches — serves a
//! cluster unchanged.
//!
//! ## Preemptive rebalancing
//!
//! Admission no longer pins a sequence to its replica for life: after
//! every step the cluster **migrates the oldest swapped sequences away
//! from overloaded replicas** ([`Engine::is_overloaded`] — a swapped
//! sequence the replica cannot resume itself) onto same-precision peers
//! with KV headroom ([`Engine::can_import`], ties broken toward the most
//! free blocks, then the lowest index — deterministic).  The sequence
//! travels as an [`ExportedSeq`](super::engine::ExportedSeq) (request +
//! host KV + generated tokens), re-admits through the target's prefix
//! cache, and its stream continues byte-identically — the client just
//! sees `Preempted`, [`TokenEvent::Migrated`], `Resumed`.  The router's
//! load accounting transfers conservatively ([`Router::migrate`]), so
//! conservation holds mid-flight.  Same-precision replicas are assumed
//! to be identical model replicas (the standard scale-out deployment);
//! that is what makes the migrated stream's logits — and therefore its
//! tokens — identical.
//!
//! ## Cross-precision migration (re-prefill)
//!
//! With the **one-superset-store** memory model (every replica slices
//! its precision out of one shared `PackedWeightStore`), precision is a
//! runtime choice — so when no same-precision peer has headroom, the
//! rebalancer falls back to ANY peer with headroom: the export drops the
//! carried `SeqKv` ([`ExportedSeq::strip_kv_for_requant`]) and the
//! importing engine **re-prefills** the prompt + generated tokens at its
//! own precision.  Streamed bytes never change (they are teacher-forced
//! as context); only subsequent tokens are generated at the new
//! precision, and the client sees [`TokenEvent::Requantized`] between
//! `Migrated` and `Resumed`.  Requests that pinned a precision
//! ([`Request::with_precision`]) never cross — the pin is a contract.
//! The trade-off is compute for memory/latency: a re-prefill costs one
//! prefill over the carried tokens, against the alternative of the
//! sequence waiting out an overloaded replica.
//!
//! Per-replica prefix caches stay sound under requantization because a
//! replica serves exactly one precision: every KV block a replica caches
//! was produced at that precision, and a re-prefilled arrival rebuilds
//! (and may then share) content at the target's own precision.
//!
//! ## Speculation across replicas
//!
//! Speculative decoding is configured **per replica**
//! ([`EngineConfig::spec_k`] / [`EngineConfig::draft_bits`]): each
//! replica drafts from the most-significant plane prefix of its *own*
//! serving width, so a mixed-precision cluster naturally drafts W2-of-W4
//! on one replica and W1-of-W2 on another, all out of the one shared
//! superset store.  Draft state never travels: speculation is committed
//! or rolled back within the step that opened it, so an exported
//! sequence carries only accepted tokens and KV — on a cross-precision
//! requant migration the draft context is dropped along with the carried
//! KV, and the target replica simply resumes drafting (or not) at its
//! own `spec_k`/`draft_bits` after the re-prefill.  Streams stay
//! byte-identical throughout, whatever combination of speculation
//! settings the replicas run.

use super::backend::Backend;
use super::engine::{Engine, EngineConfig};
use super::metrics::Metrics;
use super::request::{Request, Response, TokenEvent};
use super::router::{RoutePolicy, Router};
use super::server::Stepper;
use crate::anyhow::Result;
use crate::model::PrecisionConfig;

/// N engine replicas behind one router.
pub struct Cluster<B: Backend> {
    router: Router,
    engines: Vec<Engine<B>>,
    /// Cluster-level clock + router-reject accounting; per-replica
    /// metrics merge into this for the aggregate view.
    clock: Metrics,
    /// Requests no replica could serve (precision pinned to nothing).
    unroutable: u64,
    /// Terminal events for unroutable requests, drained next step.
    pending_events: Vec<TokenEvent>,
    /// Preemptive rebalancing of swapped sequences (on by default;
    /// `set_migration(false)` restores the PR 3 pinned behavior).
    migration: bool,
}

impl<B: Backend> Cluster<B> {
    pub fn new(policy: RoutePolicy) -> Self {
        Self {
            router: Router::new(policy),
            engines: Vec::new(),
            clock: Metrics::default(),
            unroutable: 0,
            pending_events: Vec::new(),
            migration: true,
        }
    }

    /// Enable/disable cross-replica migration of swapped sequences
    /// (enabled by default).  Off restores the PR 3 behavior: a request
    /// stays pinned to its admission replica forever.
    pub fn set_migration(&mut self, enabled: bool) {
        self.migration = enabled;
    }

    /// Swapped sequences moved between replicas so far.
    pub fn migrations(&self) -> u64 {
        self.clock.migrations
    }

    /// Migrations that crossed a precision boundary (KV dropped, target
    /// re-prefills at its own precision).  Subset of
    /// [`Cluster::migrations`].
    pub fn requants(&self) -> u64 {
        self.clock.requants
    }

    /// Register a replica: a backend wrapped in its own engine, serving
    /// `precision`.  Returns the replica index.
    pub fn add_replica(
        &mut self,
        name: impl Into<String>,
        precision: PrecisionConfig,
        backend: B,
        cfg: EngineConfig,
    ) -> usize {
        let idx = self.router.add_replica(name, precision);
        self.engines.push(Engine::new(backend, cfg));
        debug_assert_eq!(self.engines.len(), idx + 1);
        idx
    }

    pub fn replicas(&self) -> usize {
        self.engines.len()
    }

    /// Split a host-wide GEMM worker budget evenly across replicas (each
    /// gets at least 1).  Replicas stepping sequentially share pools by
    /// size ([`crate::util::pool_of`]), so N replicas × T workers resolve
    /// to one T-sized pool rather than N·T threads.
    pub fn set_worker_budget(&mut self, total_workers: usize) {
        let per = (total_workers / self.engines.len().max(1)).max(1);
        for e in &mut self.engines {
            e.set_workers(per);
        }
    }

    pub fn router(&self) -> &Router {
        &self.router
    }

    pub fn engines(&self) -> &[Engine<B>] {
        &self.engines
    }

    pub fn engine(&self, idx: usize) -> &Engine<B> {
        &self.engines[idx]
    }

    /// Requests rejected at the router (no replica for the pinned
    /// precision).
    pub fn unroutable(&self) -> u64 {
        self.unroutable
    }

    /// Whole-cluster consistency: router load accounting conserves,
    /// every replica's pool holds its block invariants, and migration
    /// bookkeeping balances (exports == imports — a sequence is never
    /// in transit between steps — and the router counted every move).
    pub fn check_invariants(&self) -> Result<(), String> {
        self.router.check_invariants()?;
        for (i, e) in self.engines.iter().enumerate() {
            e.pool().check_invariants().map_err(|err| format!("replica {i}: {err}"))?;
        }
        let exported: u64 = self.engines.iter().map(|e| e.counters().exported).sum();
        let imported: u64 = self.engines.iter().map(|e| e.counters().imported).sum();
        if exported != imported {
            return Err(format!("{exported} exported sequences but {imported} imported"));
        }
        if exported != self.clock.migrations || self.router.migrated != self.clock.migrations {
            return Err(format!(
                "migration accounting drift: {} moved, router saw {}, clock saw {}",
                exported, self.router.migrated, self.clock.migrations
            ));
        }
        // requantization bookkeeping: every cross-precision move is a
        // migration, and every one eventually re-prefills exactly once
        // (≤ mid-flight: an import may not have reached its swap-in yet)
        let reprefills: u64 = self.engines.iter().map(|e| e.counters().reprefills).sum();
        if self.clock.requants > self.clock.migrations {
            return Err(format!(
                "{} requants exceed {} migrations",
                self.clock.requants, self.clock.migrations
            ));
        }
        if reprefills > self.clock.requants {
            return Err(format!(
                "{reprefills} re-prefills but only {} cross-precision moves",
                self.clock.requants
            ));
        }
        Ok(())
    }

    /// Best import target among `src`'s peers for a swapped sequence:
    /// when `same_precision`, only peers serving `src`'s precision and
    /// passing [`Engine::can_import`] qualify (the KV travels verbatim);
    /// otherwise only peers at a *different* precision passing
    /// [`Engine::can_import_requant`] (the KV is dropped and re-prefilled
    /// there).  The acceptable peer with the most free KV blocks wins,
    /// lowest index on ties — deterministic.
    fn best_target(
        &self,
        src: usize,
        peek: &super::engine::SwappedPeek<'_>,
        same_precision: bool,
    ) -> Option<usize> {
        let precision = self.router.replicas()[src].precision;
        let mut best: Option<(usize, usize)> = None; // (free_blocks, idx)
        for (i, e) in self.engines.iter().enumerate() {
            if i == src || (self.router.replicas()[i].precision == precision) != same_precision {
                continue;
            }
            // a same-precision move carries the KV verbatim — unless an
            // earlier cross-precision hop already stripped it, in which
            // case the final host re-prefills whatever its precision is
            let ok = if same_precision && !peek.reprefill_pending {
                e.can_import(peek.content, peek.budget)
            } else {
                e.can_import_requant(peek.content, peek.budget)
            };
            if ok {
                let free = e.pool().free_blocks();
                let better = match best {
                    None => true,
                    Some((bf, bi)) => free > bf || (free == bf && i < bi),
                };
                if better {
                    best = Some((free, i));
                }
            }
        }
        best.map(|(_, i)| i)
    }

    /// Move the oldest swapped sequences off overloaded replicas —
    /// preferably onto same-precision peers with headroom (KV travels
    /// verbatim), otherwise, for unpinned requests, onto **any** peer
    /// with headroom via the cross-precision re-prefill path.
    /// Deterministic: sources in replica order, target = the acceptable
    /// peer with the most free KV blocks (lowest index on ties).  Each
    /// move streams [`TokenEvent::Migrated`] (plus
    /// [`TokenEvent::Requantized`] when crossing the boundary); the
    /// target's own next step streams the `Resumed`.
    fn rebalance(&mut self, events: &mut Vec<TokenEvent>) {
        if !self.migration || self.engines.len() < 2 {
            return;
        }
        for src in 0..self.engines.len() {
            while self.engines[src].is_overloaded() {
                let Some(peek) = self.engines[src].peek_swapped() else { break };
                // cheap pre-filter (the peek borrows, it doesn't clone):
                // some peer must have no swapped backlog of its own AND
                // be reachable — same precision, or any precision when
                // the request is unpinned.  A saturated cluster, or a
                // pinned head with only foreign-precision peers, breaks
                // here without scanning targets every step.
                let precision = self.router.replicas()[src].precision;
                let any_peer = self.engines.iter().enumerate().any(|(i, e)| {
                    i != src
                        && e.swapped() == 0
                        && (self.router.replicas()[i].precision == precision
                            || peek.pinned.is_none())
                });
                if !any_peer {
                    break;
                }
                // same-precision first — carrying KV beats recomputing it
                let target = match self.best_target(src, &peek, true) {
                    Some(dst) => Some((dst, false)),
                    // a precision pin is a contract: pinned requests
                    // never requantize, they wait for their own replica
                    None if peek.pinned.is_none() => {
                        self.best_target(src, &peek, false).map(|dst| (dst, true))
                    }
                    None => None,
                };
                let Some((dst, cross)) = target else { break };
                let id = peek.id;
                let mut seq = self.engines[src].export_swapped().expect("peeked above");
                if cross {
                    seq.strip_kv_for_requant();
                }
                self.engines[dst].import_swapped(seq);
                let from = self.router.migrate(id, dst).expect("migrated seq must be in flight");
                debug_assert_eq!(from, src);
                self.clock.migrations += 1;
                events.push(TokenEvent::Migrated { id, from: src, to: dst });
                if cross {
                    self.clock.requants += 1;
                    events.push(TokenEvent::Requantized {
                        id,
                        from_bits: self.router.replicas()[src].precision,
                        to_bits: self.router.replicas()[dst].precision,
                    });
                }
            }
        }
    }

    /// Step until every submitted request resolved; returns the full
    /// event stream.
    pub fn run_to_completion_events(&mut self) -> Result<Vec<TokenEvent>> {
        self.start_clock();
        let out = super::server::drain(self)?;
        self.stop_clock();
        Ok(out)
    }
}

impl<B: Backend> Stepper for Cluster<B> {
    /// Route to a replica by policy (respecting the request's precision
    /// pin).  An unroutable request resolves with a terminal empty-stream
    /// `Finished` on the next step.
    fn submit(&mut self, r: Request) {
        match self.router.route(&r, r.precision) {
            Some(idx) => self.engines[idx].submit(r),
            None => {
                self.unroutable += 1;
                self.clock.requests_in += 1;
                self.clock.requests_done += 1;
                self.pending_events
                    .push(TokenEvent::Finished { id: r.id, response: Response::rejected(r.id) });
            }
        }
    }

    /// Advance every busy replica one iteration, rebalance swapped
    /// sequences off overloaded replicas, then merge the event streams
    /// and drain completions out of the router's load accounting.
    fn step(&mut self) -> Result<Vec<TokenEvent>> {
        let mut events = std::mem::take(&mut self.pending_events);
        for e in &mut self.engines {
            if !e.is_idle() {
                events.extend(e.step()?);
            }
        }
        self.rebalance(&mut events);
        for ev in &events {
            if let TokenEvent::Finished { id, .. } = ev {
                // unroutable terminals were never routed; ignore those
                let _ = self.router.complete(*id);
            }
        }
        Ok(events)
    }

    fn is_idle(&self) -> bool {
        self.pending_events.is_empty() && self.engines.iter().all(|e| e.is_idle())
    }

    /// Merged snapshot: per-replica counters/latencies summed onto the
    /// cluster clock (wall time is the cluster's own bracket).
    fn metrics(&self) -> Metrics {
        let mut m = self.clock.clone();
        for e in &self.engines {
            m.merge(&e.metrics);
        }
        m
    }

    fn start_clock(&mut self) {
        self.clock.start();
        for e in &mut self.engines {
            e.metrics.start();
        }
    }

    fn stop_clock(&mut self) {
        self.clock.finish();
        for e in &mut self.engines {
            e.metrics.finish();
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::backend::SimBackend;
    use crate::coordinator::request::{responses_of, GenParams};

    fn sim() -> SimBackend {
        SimBackend::new(64, 64, vec![1, 2, 4, 8])
    }

    fn req(id: u64, prompt_len: usize, max_new: usize) -> Request {
        Request::new(
            id,
            (1..=prompt_len as i32).collect(),
            GenParams { max_new_tokens: max_new, sample: false, seed: id },
        )
    }

    fn cluster3() -> Cluster<SimBackend> {
        let mut c = Cluster::new(RoutePolicy::LeastLoaded);
        for i in 0..3 {
            c.add_replica(
                format!("r{i}"),
                PrecisionConfig::W2A2,
                sim(),
                EngineConfig { kv_blocks: 16, block_tokens: 4, ..EngineConfig::default() },
            );
        }
        c
    }

    #[test]
    fn worker_budget_splits_evenly_across_replicas() {
        let mut c = Cluster::new(RoutePolicy::RoundRobin);
        for i in 0..3u64 {
            c.add_replica(
                format!("r{i}"),
                PrecisionConfig::W2A2,
                SimBackend::with_ap_gemm(32, 64, vec![1, 2, 4], 64, 2, 2, i),
                EngineConfig::default(),
            );
        }
        c.set_worker_budget(8);
        for e in c.engines() {
            assert_eq!(e.backend().gemm_workers(), Some(2), "8 workers / 3 replicas → 2 each");
        }
        c.set_worker_budget(1);
        for e in c.engines() {
            assert_eq!(e.backend().gemm_workers(), Some(1), "budget floor is 1 per replica");
        }
    }

    #[test]
    fn cluster_serves_and_drains_router_accounting() {
        let mut c = cluster3();
        for i in 0..12u64 {
            c.submit(req(i, 4, 5));
        }
        let events = c.run_to_completion_events().unwrap();
        let out = responses_of(&events);
        assert_eq!(out.len(), 12);
        assert!(out.iter().all(|r| r.tokens.len() == 5));
        assert_eq!(c.router().inflight(), 0, "completions drained the router");
        assert_eq!(c.router().routed, 12);
        assert_eq!(c.router().completed, 12);
        c.check_invariants().unwrap();
        // least-loaded actually spread the work
        let busy = c.engines().iter().filter(|e| e.counters().completed > 0).count();
        assert_eq!(busy, 3, "all replicas served");
        let m = c.metrics();
        assert_eq!(m.requests_done, 12);
        assert_eq!(m.tokens_generated, 60);
    }

    #[test]
    fn precision_pinning_routes_or_rejects() {
        let mut c = Cluster::new(RoutePolicy::RoundRobin);
        c.add_replica("w2", PrecisionConfig::W2A2, sim(), EngineConfig::default());
        c.add_replica("w1", PrecisionConfig::W1A1, sim(), EngineConfig::default());
        c.submit(req(0, 4, 3).with_precision(PrecisionConfig::W1A1));
        c.submit(req(1, 4, 3).with_precision(PrecisionConfig::W8A8)); // nobody serves this
        c.submit(req(2, 4, 3));
        let events = c.run_to_completion_events().unwrap();
        let out = responses_of(&events);
        assert_eq!(out.len(), 3);
        assert_eq!(c.unroutable(), 1);
        let rej: Vec<_> = out.iter().filter(|r| r.tokens.is_empty()).collect();
        assert_eq!(rej.len(), 1);
        assert_eq!(rej[0].id.0, 1);
        // the pinned request landed on the W1A1 replica
        assert_eq!(c.engine(1).counters().completed, 1);
        c.check_invariants().unwrap();
    }

    #[test]
    fn engine_level_rejects_still_drain_the_router() {
        let mut c = Cluster::new(RoutePolicy::RoundRobin);
        c.add_replica(
            "r0",
            PrecisionConfig::W2A2,
            sim(),
            EngineConfig { kv_blocks: 2, block_tokens: 4, ..EngineConfig::default() },
        );
        // routed fine, but the engine's capacity guard rejects it (budget
        // 40 tokens > 2×4 pool) — the Finished event must still release
        // the router's load accounting
        c.submit(req(0, 8, 32));
        let events = c.run_to_completion_events().unwrap();
        let out = responses_of(&events);
        assert_eq!(out.len(), 1);
        assert!(out[0].tokens.is_empty());
        assert_eq!(c.router().inflight(), 0);
        c.check_invariants().unwrap();
    }

    #[test]
    fn overloaded_replica_migrates_swapped_sequence_to_peer() {
        use crate::coordinator::backend::drive_unbatched;

        // r0: 4-block pool (two 16-token-budget residents overflow it);
        // r1: plenty of headroom.  LeastLoaded lands A and C on r0 (ties
        // break by index) and B on r1; decoding preempts C, which r0 can
        // never resume while A runs — the rebalancer must move it to r1.
        let mk_prompt = |base: i32| (base..base + 8).collect::<Vec<i32>>();
        let reqs: Vec<Request> = [10, 50, 30]
            .iter()
            .enumerate()
            .map(|(i, &base)| {
                Request::new(
                    i as u64,
                    mk_prompt(base),
                    GenParams { max_new_tokens: 8, sample: false, seed: i as u64 },
                )
            })
            .collect();
        let mut oracle = sim();
        let want: Vec<Vec<i32>> = reqs
            .iter()
            .map(|r| drive_unbatched(&mut oracle, &r.prompt, &r.params).unwrap())
            .collect();

        let run = |migration: bool| {
            let mut c = Cluster::new(RoutePolicy::LeastLoaded);
            c.add_replica(
                "hot",
                PrecisionConfig::W2A2,
                sim(),
                EngineConfig { kv_blocks: 4, block_tokens: 4, ..EngineConfig::default() },
            );
            c.add_replica(
                "cold",
                PrecisionConfig::W2A2,
                sim(),
                EngineConfig { kv_blocks: 32, block_tokens: 4, ..EngineConfig::default() },
            );
            c.set_migration(migration);
            for r in &reqs {
                c.submit(r.clone());
            }
            let events = c.run_to_completion_events().unwrap();
            c.check_invariants().unwrap();
            assert_eq!(c.router().inflight(), 0);
            for (i, e) in c.engines().iter().enumerate() {
                assert_eq!(
                    e.pool().free_blocks(),
                    e.pool().total_blocks(),
                    "replica {i} leaked blocks"
                );
            }
            let mut out = responses_of(&events);
            out.sort_by_key(|r| r.id);
            assert_eq!(out.len(), 3);
            for (resp, want) in out.iter().zip(&want) {
                let id = resp.id.0;
                assert_eq!(resp.tokens, *want, "req {id} ≠ oracle (migration={migration})");
            }
            (c, events)
        };

        // with migration: the swapped sequence finishes on the peer
        let (c, events) = run(true);
        let migrated: Vec<_> = events
            .iter()
            .filter_map(|ev| match ev {
                TokenEvent::Migrated { id, from, to } => Some((id.0, *from, *to)),
                _ => None,
            })
            .collect();
        assert_eq!(migrated, vec![(2, 0, 1)], "C moves hot → cold exactly once");
        assert!(c.migrations() >= 1);
        assert_eq!(c.engine(0).counters().exported, 1);
        assert_eq!(c.engine(1).counters().imported, 1);
        assert_eq!(c.engine(1).counters().resumes, 1, "C resumed on the peer");
        assert_eq!(c.engine(0).counters().completed, 1, "only A finished on hot");
        assert_eq!(c.engine(1).counters().completed, 2, "B and the migrated C on cold");
        assert_eq!(c.router().migrated, 1);
        assert_eq!(c.metrics().migrations, 1);

        // without migration: same streams, but C waits out A on r0
        let (c, events) = run(false);
        assert!(events.iter().all(|ev| !matches!(ev, TokenEvent::Migrated { .. })));
        assert_eq!(c.migrations(), 0);
        assert_eq!(c.engine(0).counters().completed, 2, "C stayed pinned to hot");
    }

    #[test]
    fn pinned_requests_never_requantize_across_precision_boundaries() {
        // the only peer serves a different precision, and both requests
        // PINNED theirs: the pin is a contract, so the swapped sequence
        // must NOT migrate (not even via the re-prefill path) and still
        // completes locally
        let mut c = Cluster::new(RoutePolicy::LeastLoaded);
        c.add_replica(
            "hot-w2",
            PrecisionConfig::W2A2,
            sim(),
            EngineConfig { kv_blocks: 4, block_tokens: 4, ..EngineConfig::default() },
        );
        c.add_replica("cold-w1", PrecisionConfig::W1A1, sim(), EngineConfig::default());
        // pin both to the W2A2 replica so it overloads
        for i in 0..2u64 {
            let r = Request::new(
                i,
                ((i as i32 * 40)..(i as i32 * 40) + 8).collect(),
                GenParams { max_new_tokens: 8, sample: false, seed: i },
            )
            .with_precision(PrecisionConfig::W2A2);
            c.submit(r);
        }
        let events = c.run_to_completion_events().unwrap();
        assert!(events.iter().all(|ev| !matches!(
            ev,
            TokenEvent::Migrated { .. } | TokenEvent::Requantized { .. }
        )));
        assert_eq!(c.migrations(), 0);
        assert_eq!(c.requants(), 0);
        assert_eq!(c.engine(0).counters().completed, 2);
        assert_eq!(c.engine(1).counters().completed, 0);
        c.check_invariants().unwrap();
    }

    #[test]
    fn unpinned_swapped_sequence_requantizes_to_a_different_precision_peer() {
        // same topology, but UNPINNED requests: with no same-precision
        // peer, the rebalancer takes the cross-precision path — the KV is
        // dropped, the W1A1 replica re-prefills, and the client sees
        // Preempted → Migrated → Requantized → Resumed in order
        let mut c = Cluster::new(RoutePolicy::LeastLoaded);
        c.add_replica(
            "hot-w2",
            PrecisionConfig::W2A2,
            sim(),
            EngineConfig { kv_blocks: 4, block_tokens: 4, ..EngineConfig::default() },
        );
        c.add_replica("cold-w1", PrecisionConfig::W1A1, sim(), EngineConfig::default());
        // LeastLoaded with ties broken by index: A→hot, B→cold, C→hot.
        // A + C (budget 16 tokens each) overflow hot's 4-block pool
        // mid-decode, so C is preempted with no same-precision peer —
        // the cross-precision fallback is the only way off the replica.
        for (i, &base) in [10i32, 50, 30].iter().enumerate() {
            c.submit(Request::new(
                i as u64,
                (base..base + 8).collect(),
                GenParams { max_new_tokens: 8, sample: false, seed: i as u64 },
            ));
        }
        let events = c.run_to_completion_events().unwrap();
        let requants: Vec<_> = events
            .iter()
            .filter_map(|ev| match ev {
                TokenEvent::Requantized { id, from_bits, to_bits } => {
                    Some((id.0, *from_bits, *to_bits))
                }
                _ => None,
            })
            .collect();
        assert_eq!(
            requants,
            vec![(2, PrecisionConfig::W2A2, PrecisionConfig::W1A1)],
            "C requantizes hot-w2 → cold-w1 exactly once"
        );
        assert_eq!(c.migrations(), 1);
        assert_eq!(c.requants(), 1);
        assert_eq!(c.engine(1).counters().imported, 1);
        assert_eq!(c.engine(1).counters().reprefills, 1, "the W1A1 peer rebuilt the KV");
        assert_eq!(c.engine(1).counters().resumes, 1);
        // stream grammar: Preempted → Migrated → Requantized → Resumed
        let lifecycle: Vec<&TokenEvent> = events
            .iter()
            .filter(|ev| {
                ev.id().0 == 2
                    && !matches!(ev, TokenEvent::Token { .. } | TokenEvent::Admitted { .. })
            })
            .collect();
        assert!(matches!(lifecycle[0], TokenEvent::Preempted { .. }), "{lifecycle:?}");
        assert!(
            matches!(lifecycle[1], TokenEvent::Migrated { from: 0, to: 1, .. }),
            "{lifecycle:?}"
        );
        assert!(matches!(lifecycle[2], TokenEvent::Requantized { .. }), "{lifecycle:?}");
        assert!(matches!(lifecycle[3], TokenEvent::Resumed { .. }), "{lifecycle:?}");
        for (i, e) in c.engines().iter().enumerate() {
            assert_eq!(e.pool().free_blocks(), e.pool().total_blocks(), "replica {i} leaked");
        }
        c.check_invariants().unwrap();
        assert_eq!(c.router().inflight(), 0);
        // migration off restores strict pinning-to-admission-replica
        let mut c2 = Cluster::new(RoutePolicy::LeastLoaded);
        c2.add_replica(
            "hot-w2",
            PrecisionConfig::W2A2,
            sim(),
            EngineConfig { kv_blocks: 4, block_tokens: 4, ..EngineConfig::default() },
        );
        c2.add_replica("cold-w1", PrecisionConfig::W1A1, sim(), EngineConfig::default());
        c2.set_migration(false);
        for (i, &base) in [10i32, 50, 30].iter().enumerate() {
            c2.submit(Request::new(
                i as u64,
                (base..base + 8).collect(),
                GenParams { max_new_tokens: 8, sample: false, seed: i as u64 },
            ));
        }
        let events = c2.run_to_completion_events().unwrap();
        assert!(events.iter().all(|ev| !matches!(ev, TokenEvent::Requantized { .. })));
        assert_eq!(c2.requants(), 0);
        c2.check_invariants().unwrap();
    }

    #[test]
    fn speculating_mixed_precision_cluster_requantizes_and_keeps_streams_identical() {
        use crate::coordinator::backend::superset_store;

        // one 4-bit superset store; the hot replica serves W4 and drafts
        // its W2 plane prefix, the cold replica serves W2 and drafts W1 —
        // per-replica speculation out of one pack.  The hot pool is sized
        // so decode pressure preempts the younger resident, which can
        // only leave via the cross-precision requant path; the migrated
        // sequence's draft state must not travel (it never exists between
        // steps), and every stream must match a spec-less run byte for
        // byte.
        let run = |spec: bool| {
            let store = superset_store(64, 64, 4, 77);
            let mut c = Cluster::new(RoutePolicy::LeastLoaded);
            let (spec_k, hot_draft, cold_draft) = if spec { (2, 2, 1) } else { (0, 0, 0) };
            c.add_replica(
                "hot-w4",
                PrecisionConfig::W4A4,
                SimBackend::with_shared_store(64, vec![1, 2, 4, 8, 16], store.clone(), 4, 2),
                EngineConfig {
                    kv_blocks: 4,
                    block_tokens: 4,
                    spec_k,
                    draft_bits: hot_draft,
                    ..EngineConfig::default()
                },
            );
            c.add_replica(
                "cold-w2",
                PrecisionConfig::W2A2,
                SimBackend::with_shared_store(64, vec![1, 2, 4, 8, 16], store, 2, 2),
                EngineConfig {
                    kv_blocks: 32,
                    block_tokens: 4,
                    spec_k,
                    draft_bits: cold_draft,
                    ..EngineConfig::default()
                },
            );
            for (i, &base) in [10i32, 50, 30].iter().enumerate() {
                c.submit(Request::new(
                    i as u64,
                    (base..base + 8).collect(),
                    GenParams { max_new_tokens: 8, sample: false, seed: i as u64 },
                ));
            }
            let events = c.run_to_completion_events().unwrap();
            c.check_invariants().unwrap();
            for (i, e) in c.engines().iter().enumerate() {
                assert_eq!(e.pool().free_blocks(), e.pool().total_blocks(), "replica {i} leaked");
            }
            let mut out = responses_of(&events);
            out.sort_by_key(|r| r.id);
            (c, out.into_iter().map(|r| r.tokens).collect::<Vec<_>>())
        };

        let (plain_c, plain) = run(false);
        let (spec_c, spec) = run(true);
        assert_eq!(spec, plain, "speculation must not change a single byte of any stream");
        assert!(plain.iter().all(|t| t.len() == 8));
        // both runs took the same migration decisions (preemption is
        // driven by KV pressure, which speculation never adds to)
        for c in [&plain_c, &spec_c] {
            assert_eq!(c.migrations(), 1, "the preempted sequence moved hot → cold");
            assert_eq!(c.requants(), 1, "and crossed the precision boundary");
            assert_eq!(c.engine(1).counters().reprefills, 1);
        }
        // speculation was actually live on both precisions of the spec run
        assert_eq!(spec_c.engine(0).spec_k(), 2, "W4 replica drafts W2");
        assert_eq!(spec_c.engine(1).spec_k(), 2, "W2 replica drafts W1");
        let drafted: u64 = spec_c.engines().iter().map(|e| e.counters().drafted).sum();
        let accepted: u64 = spec_c.engines().iter().map(|e| e.counters().accepted).sum();
        assert!(drafted > 0, "decode-heavy load must have drafted");
        assert!(accepted <= drafted);
        // the merged cluster metrics carry the speculation counters
        let m = spec_c.metrics();
        assert_eq!(m.spec_drafted, drafted);
        assert_eq!(m.spec_accepted, accepted);
        assert_eq!(plain_c.metrics().spec_drafted, 0, "spec-less run drafts nothing");
    }

    #[test]
    fn cluster_is_deterministic() {
        let run = || {
            let mut c = cluster3();
            for i in 0..9u64 {
                c.submit(req(i, 3 + i as usize % 4, 4));
            }
            let mut out = responses_of(&c.run_to_completion_events().unwrap());
            out.sort_by_key(|r| r.id);
            out.iter().map(|r| r.tokens.clone()).collect::<Vec<_>>()
        };
        assert_eq!(run(), run());
    }
}
