//! Multi-replica serving cluster: N independent continuous-batching
//! [`Engine`] replicas — each with its own [`KvPool`](super::kv::KvPool),
//! batcher, and pack-once backend, possibly at different W/A precisions
//! and possibly specialized by [`ReplicaRole`] — driven behind the
//! [`Router`].
//!
//! This is the deployment shape the related work motivates: FP6-LLM
//! frames low-bit kernels as one half of an end-to-end serving co-design,
//! and Any-Precision LLM serves several precisions from one deployment —
//! which is exactly what a router over per-precision replicas provides.
//! A request optionally pins a [`PrecisionConfig`]
//! ([`Request::precision`]); the router narrows to matching
//! prefill-capable replicas and picks by policy (round-robin, or least
//! outstanding token budget).
//!
//! The cluster is itself a [`Stepper`]: `submit` routes, `step` advances
//! every busy replica and merges their streamed [`TokenEvent`]s (tagging
//! completions back to the router so its load accounting drains), and
//! `metrics` merges per-replica metrics into one view.  Everything that
//! serves a single engine — [`Server`](super::server::Server),
//! [`replay_trace`](super::server::replay_trace), the benches — serves a
//! cluster unchanged.
//!
//! ## Construction: [`ClusterSpec`] / [`ReplicaSpec`]
//!
//! A topology is declared up front and consumed whole by
//! [`Cluster::new`] — role, precision, engine shape, speculation, and
//! worker budget all live on the spec, replacing the setter sprawl
//! (`add_replica` + `set_migration` + `set_worker_budget` + per-engine
//! pokes) that grew across PRs 3–8:
//!
//! ```
//! use apllm::coordinator::{Cluster, ClusterSpec, ReplicaRole, ReplicaSpec, RoutePolicy, SimBackend};
//! use apllm::model::PrecisionConfig;
//!
//! let spec = ClusterSpec::new(RoutePolicy::LeastLoaded)
//!     .replica(ReplicaSpec::new("p0", PrecisionConfig::W2A2).role(ReplicaRole::Prefill))
//!     .replica(
//!         ReplicaSpec::new("d0", PrecisionConfig::W2A2)
//!             .role(ReplicaRole::Decode)
//!             .kv_blocks(128),
//!     );
//! let cluster = Cluster::new(spec, |_spec| SimBackend::new(64, 64, vec![1, 2, 4, 8]));
//! assert_eq!(cluster.replicas(), 2);
//! ```
//!
//! The backend factory runs once per replica (in declaration order) so
//! mixed-precision clusters can slice each replica's width out of one
//! shared superset store.
//!
//! ## Disaggregated prefill/decode serving
//!
//! With [`ReplicaRole::Prefill`] / [`ReplicaRole::Decode`] replicas the
//! cluster splits the two phases of a request's life onto specialized
//! replicas, so long prefills stop inflating the inter-token latency of
//! sequences decoding elsewhere:
//!
//! 1. the router admits every request to a **prefill-capable** replica
//!    (decode-only replicas never admit — they are fed by migration);
//! 2. a prefill-role engine runs under [`EngineConfig::prefill_hold`]:
//!    a freshly prefilled sequence streams its first token, then sits
//!    decode out for one step, surfacing via
//!    [`Engine::prefilled_ready`](super::engine::Engine::prefilled_ready);
//! 3. between steps the cluster hands each held sequence to the
//!    decode-capable peer with the least outstanding decode load that
//!    [`Engine::import_fit`] admits — streaming
//!    [`TokenEvent::PrefillDone`] immediately before the
//!    [`TokenEvent::Migrated`] (no `Preempted`: the move is voluntary),
//!    with the importer's `Resumed` picking the stream back up;
//! 4. a held sequence **no** peer can take simply decodes locally next
//!    step — the hold expires, so a missing or saturated decode tier
//!    degrades to mixed behavior instead of stranding streams.
//!
//! The handoff rides the same export/import machinery as rebalancing, so
//! streams stay byte-identical to a mixed-role cluster; `Mixed` replicas
//! (the default) never hold and preserve the symmetric behavior exactly.
//!
//! ## Preemptive rebalancing
//!
//! Admission no longer pins a sequence to its replica for life: after
//! every step the cluster **migrates the oldest swapped sequences away
//! from overloaded replicas** ([`Engine::is_overloaded`] — a swapped
//! sequence the replica cannot resume itself) onto **decode-capable**
//! same-precision peers that pass [`Engine::import_fit`] (a decoding
//! sequence is never parked on a prefill-only replica; ties broken
//! toward the most free blocks, then the lowest index — deterministic).
//! The sequence travels as an [`ExportedSeq`](super::engine::ExportedSeq)
//! (request + host KV + generated tokens), re-admits through the
//! target's prefix cache, and its stream continues byte-identically —
//! the client just sees `Preempted`, [`TokenEvent::Migrated`],
//! `Resumed`.  The router's load accounting transfers conservatively
//! ([`Router::migrate`]), so conservation holds mid-flight.
//! Same-precision replicas are assumed to be identical model replicas
//! (the standard scale-out deployment); that is what makes the migrated
//! stream's logits — and therefore its tokens — identical.
//!
//! ## Cross-precision migration (re-prefill)
//!
//! With the **one-superset-store** memory model (every replica slices
//! its precision out of one shared `PackedWeightStore`), precision is a
//! runtime choice — so when no same-precision peer has headroom, the
//! rebalancer falls back to ANY decode-capable peer admitting the
//! [`SwappedPeek::as_requant`] view: the export drops the carried
//! `SeqKv` ([`ExportedSeq::strip_kv_for_requant`]) and the importing
//! engine **re-prefills** the prompt + generated tokens at its own
//! precision.  Streamed bytes never change (they are teacher-forced as
//! context); only subsequent tokens are generated at the new precision,
//! and the client sees [`TokenEvent::Requantized`] between `Migrated`
//! and `Resumed`.  Requests that pinned a precision
//! ([`Request::with_precision`]) never cross — the pin is a contract.
//! The trade-off is compute for memory/latency: a re-prefill costs one
//! prefill over the carried tokens — and that cost is **charged to the
//! importer's load accounting** ([`Router::charge_reprefill`]), so a
//! requantized import is visible to placement instead of looking free.
//!
//! Per-replica prefix caches stay sound under requantization because a
//! replica serves exactly one precision: every KV block a replica caches
//! was produced at that precision, and a re-prefilled arrival rebuilds
//! (and may then share) content at the target's own precision.
//!
//! ## Speculation across replicas
//!
//! Speculative decoding is configured **per replica**
//! ([`ReplicaSpec::speculation`] → [`EngineConfig::spec_k`] /
//! [`EngineConfig::draft_bits`]): each replica drafts from the
//! most-significant plane prefix of its *own* serving width, so a
//! mixed-precision cluster naturally drafts W2-of-W4 on one replica and
//! W1-of-W2 on another, all out of the one shared superset store.  Draft
//! state never travels: speculation is committed or rolled back within
//! the step that opened it, so an exported sequence carries only
//! accepted tokens and KV — on a cross-precision requant migration the
//! draft context is dropped along with the carried KV, and the target
//! replica simply resumes drafting (or not) at its own
//! `spec_k`/`draft_bits` after the re-prefill.  Streams stay
//! byte-identical throughout, whatever combination of speculation
//! settings the replicas run.

use super::backend::Backend;
use super::engine::{Engine, EngineConfig, SwappedPeek};
use super::metrics::Metrics;
use super::request::{Request, Response, TokenEvent};
use super::router::{ReplicaRole, RoutePolicy, Router};
use super::server::Stepper;
use crate::anyhow::Result;
use crate::model::PrecisionConfig;

/// Declarative description of one replica, consumed by [`Cluster::new`].
/// Defaults: [`ReplicaRole::Mixed`], [`EngineConfig::default`].
#[derive(Debug, Clone)]
pub struct ReplicaSpec {
    pub name: String,
    pub precision: PrecisionConfig,
    pub role: ReplicaRole,
    pub engine: EngineConfig,
}

impl ReplicaSpec {
    pub fn new(name: impl Into<String>, precision: PrecisionConfig) -> Self {
        Self {
            name: name.into(),
            precision,
            role: ReplicaRole::Mixed,
            engine: EngineConfig::default(),
        }
    }

    /// What work this replica accepts ([`ReplicaRole::Mixed`] default).
    pub fn role(mut self, role: ReplicaRole) -> Self {
        self.role = role;
        self
    }

    /// Replace the whole engine config (the shorthands below tweak the
    /// common fields without spelling out an [`EngineConfig`] literal).
    pub fn engine(mut self, cfg: EngineConfig) -> Self {
        self.engine = cfg;
        self
    }

    /// KV pool capacity in blocks.
    pub fn kv_blocks(mut self, blocks: usize) -> Self {
        self.engine.kv_blocks = blocks;
        self
    }

    /// Tokens per KV block.
    pub fn block_tokens(mut self, tokens: usize) -> Self {
        self.engine.block_tokens = tokens;
        self
    }

    /// Per-replica GEMM worker budget (overridden by
    /// [`ClusterSpec::worker_budget`] when one is set).
    pub fn workers(mut self, workers: usize) -> Self {
        self.engine.workers = workers;
        self
    }

    /// Self-speculative decoding: draft `spec_k` tokens per sequence per
    /// step at the `draft_bits`-wide plane prefix (`spec_k = 0` off).
    pub fn speculation(mut self, spec_k: usize, draft_bits: u32) -> Self {
        self.engine.spec_k = spec_k;
        self.engine.draft_bits = draft_bits;
        self
    }
}

/// Declarative description of a whole cluster topology — the one
/// construction API ([`Cluster::new`] consumes it).
#[derive(Debug, Clone)]
pub struct ClusterSpec {
    pub policy: RoutePolicy,
    /// Preemptive rebalancing of swapped sequences (on by default; off
    /// restores the PR 3 behavior — every request stays pinned to its
    /// admission replica — and also disables prefill→decode handoffs).
    pub migration: bool,
    /// Host-wide GEMM worker budget, split evenly across replicas (each
    /// gets at least 1); `None` keeps each replica's own
    /// [`ReplicaSpec::workers`] setting.
    pub worker_budget: Option<usize>,
    pub replicas: Vec<ReplicaSpec>,
}

impl ClusterSpec {
    pub fn new(policy: RoutePolicy) -> Self {
        Self { policy, migration: true, worker_budget: None, replicas: Vec::new() }
    }

    /// Enable/disable cross-replica migration (see the field docs).
    pub fn migration(mut self, enabled: bool) -> Self {
        self.migration = enabled;
        self
    }

    /// Split a host-wide GEMM worker budget evenly across replicas (each
    /// gets at least 1).  Replicas stepping sequentially share pools by
    /// size ([`crate::util::pool_of`]), so N replicas × T workers resolve
    /// to one T-sized pool rather than N·T threads.
    pub fn worker_budget(mut self, total_workers: usize) -> Self {
        self.worker_budget = Some(total_workers);
        self
    }

    /// Append a replica (declaration order is replica-index order).
    pub fn replica(mut self, spec: ReplicaSpec) -> Self {
        self.replicas.push(spec);
        self
    }
}

/// N engine replicas behind one router.
pub struct Cluster<B: Backend> {
    router: Router,
    engines: Vec<Engine<B>>,
    /// Cluster-level clock + router-reject accounting; per-replica
    /// metrics merge into this for the aggregate view.
    clock: Metrics,
    /// Requests no replica could serve (precision pinned to nothing).
    unroutable: u64,
    /// Terminal events for unroutable requests, drained next step.
    pending_events: Vec<TokenEvent>,
    /// Preemptive rebalancing + prefill→decode handoffs (from the spec).
    migration: bool,
}

impl<B: Backend> Cluster<B> {
    /// Build the cluster a [`ClusterSpec`] describes.  `make_backend`
    /// runs once per replica in declaration order (so mixed-precision
    /// topologies can slice each replica's width out of one shared
    /// superset store).  Prefill-role replicas get
    /// [`EngineConfig::prefill_hold`] switched on — the engine-side half
    /// of the disaggregated handoff; a [`ClusterSpec::worker_budget`]
    /// overrides per-replica worker settings with an even split.
    ///
    /// Panics if the spec has no replicas or no prefill-capable replica
    /// (nothing could ever admit a request) — topology bugs surface at
    /// construction, not as every request mysteriously rejecting.
    pub fn new(spec: ClusterSpec, mut make_backend: impl FnMut(&ReplicaSpec) -> B) -> Self {
        assert!(!spec.replicas.is_empty(), "a cluster needs at least one replica");
        assert!(
            spec.replicas.iter().any(|r| r.role.accepts_prefill()),
            "no prefill-capable replica: every request would be unroutable"
        );
        let per_worker = spec.worker_budget.map(|t| (t / spec.replicas.len()).max(1));
        let mut router = Router::new(spec.policy);
        let mut engines = Vec::with_capacity(spec.replicas.len());
        for r in &spec.replicas {
            router.add_replica(r.name.clone(), r.precision, r.role);
            let mut cfg = r.engine.clone();
            if let Some(w) = per_worker {
                cfg.workers = w;
            }
            cfg.prefill_hold = r.role == ReplicaRole::Prefill;
            let backend = make_backend(r);
            engines.push(Engine::new(backend, cfg));
        }
        Self {
            router,
            engines,
            clock: Metrics::default(),
            unroutable: 0,
            pending_events: Vec::new(),
            migration: spec.migration,
        }
    }

    /// Sequences moved between replicas so far (rebalanced swapped
    /// sequences plus prefill→decode handoffs).
    pub fn migrations(&self) -> u64 {
        self.clock.migrations
    }

    /// Migrations that crossed a precision boundary (KV dropped, target
    /// re-prefills at its own precision).  Subset of
    /// [`Cluster::migrations`].
    pub fn requants(&self) -> u64 {
        self.clock.requants
    }

    /// Migrations that were disaggregated prefill→decode handoffs.
    /// Subset of [`Cluster::migrations`].
    pub fn prefill_handoffs(&self) -> u64 {
        self.clock.prefill_handoffs
    }

    pub fn replicas(&self) -> usize {
        self.engines.len()
    }

    pub fn router(&self) -> &Router {
        &self.router
    }

    pub fn engines(&self) -> &[Engine<B>] {
        &self.engines
    }

    pub fn engine(&self, idx: usize) -> &Engine<B> {
        &self.engines[idx]
    }

    /// Requests rejected at the router (no replica for the pinned
    /// precision).
    pub fn unroutable(&self) -> u64 {
        self.unroutable
    }

    /// Merged metrics of every replica serving `role` — the per-role
    /// TTFT/ITL view the disaggregated bench reports (a prefill replica
    /// owns the TTFT samples of the requests it admitted; a decode
    /// replica owns the ITL gaps of the tokens it streamed).
    pub fn metrics_for_role(&self, role: ReplicaRole) -> Metrics {
        let mut m = Metrics::default();
        for (i, e) in self.engines.iter().enumerate() {
            if self.router.replicas()[i].role == role {
                m.merge(&e.metrics);
            }
        }
        m
    }

    /// Whole-cluster consistency: router load accounting conserves,
    /// every replica's pool holds its block invariants, and migration
    /// bookkeeping balances (exports == imports — a sequence is never
    /// in transit between steps — and the router counted every move).
    pub fn check_invariants(&self) -> Result<(), String> {
        self.router.check_invariants()?;
        for (i, e) in self.engines.iter().enumerate() {
            e.pool().check_invariants().map_err(|err| format!("replica {i}: {err}"))?;
        }
        let exported: u64 = self.engines.iter().map(|e| e.counters().exported).sum();
        let imported: u64 = self.engines.iter().map(|e| e.counters().imported).sum();
        if exported != imported {
            return Err(format!("{exported} exported sequences but {imported} imported"));
        }
        if exported != self.clock.migrations || self.router.migrated != self.clock.migrations {
            return Err(format!(
                "migration accounting drift: {} moved, router saw {}, clock saw {}",
                exported, self.router.migrated, self.clock.migrations
            ));
        }
        // requantization bookkeeping: every cross-precision move is a
        // migration, and every one eventually re-prefills exactly once
        // (≤ mid-flight: an import may not have reached its swap-in yet)
        let reprefills: u64 = self.engines.iter().map(|e| e.counters().reprefills).sum();
        if self.clock.requants > self.clock.migrations {
            return Err(format!(
                "{} requants exceed {} migrations",
                self.clock.requants, self.clock.migrations
            ));
        }
        if reprefills > self.clock.requants {
            return Err(format!(
                "{reprefills} re-prefills but only {} cross-precision moves",
                self.clock.requants
            ));
        }
        // every handoff is a migration too
        if self.clock.prefill_handoffs > self.clock.migrations {
            return Err(format!(
                "{} prefill handoffs exceed {} migrations",
                self.clock.prefill_handoffs, self.clock.migrations
            ));
        }
        // role topology: a prefill-only replica must never be decoding
        // an imported sequence (its own fresh admissions may decode
        // locally as the expired-hold fallback — that is allowed)
        for (i, e) in self.engines.iter().enumerate() {
            if !self.router.replicas()[i].role.accepts_decode()
                && e.counters().imported > 0
            {
                return Err(format!(
                    "prefill-only replica {i} imported {} sequences",
                    e.counters().imported
                ));
            }
        }
        Ok(())
    }

    /// Best rebalance target among `src`'s **decode-capable** peers for a
    /// swapped (mid-decode) sequence: when `same_precision`, only peers
    /// serving `src`'s precision qualify (the KV travels verbatim unless
    /// an earlier hop already stripped it); otherwise only peers at a
    /// *different* precision, queried via the [`SwappedPeek::as_requant`]
    /// view (the KV is dropped and re-prefilled there).  Acceptance is
    /// [`Engine::import_fit`]; the admitting peer with the most free KV
    /// blocks wins, lowest index on ties — deterministic.
    fn best_target(
        &self,
        src: usize,
        peek: &SwappedPeek<'_>,
        same_precision: bool,
    ) -> Option<usize> {
        let precision = self.router.replicas()[src].precision;
        let mut best: Option<(usize, usize)> = None; // (free_blocks, idx)
        for (i, e) in self.engines.iter().enumerate() {
            let rep = &self.router.replicas()[i];
            if i == src
                || !rep.role.accepts_decode()
                || (rep.precision == precision) != same_precision
            {
                continue;
            }
            let query = if same_precision { *peek } else { peek.as_requant() };
            if e.import_fit(&query).admissible() {
                let free = e.pool().free_blocks();
                let better = match best {
                    None => true,
                    Some((bf, bi)) => free > bf || (free == bf && i < bi),
                };
                if better {
                    best = Some((free, i));
                }
            }
        }
        best.map(|(_, i)| i)
    }

    /// Best prefill→decode handoff target: the decode-capable peer with
    /// the **least outstanding decode load** (lowest index on ties) that
    /// [`Engine::import_fit`] admits — handoffs steer by decode pressure,
    /// which is exactly the component the router's split accounting
    /// isolates.  Same-precision peers adopt the prefilled KV verbatim;
    /// cross-precision ones are queried via [`SwappedPeek::as_requant`].
    fn pick_decode_target(
        &self,
        src: usize,
        peek: &SwappedPeek<'_>,
        same_precision: bool,
    ) -> Option<usize> {
        let precision = self.router.replicas()[src].precision;
        let mut best: Option<(u64, usize)> = None; // (outstanding_decode, idx)
        for (i, e) in self.engines.iter().enumerate() {
            let rep = &self.router.replicas()[i];
            if i == src
                || !rep.role.accepts_decode()
                || (rep.precision == precision) != same_precision
            {
                continue;
            }
            let query = if same_precision { *peek } else { peek.as_requant() };
            if e.import_fit(&query).admissible() {
                let load = rep.outstanding_decode();
                let better = match best {
                    None => true,
                    Some((bl, bi)) => load < bl || (load == bl && i < bi),
                };
                if better {
                    best = Some((load, i));
                }
            }
        }
        best.map(|(_, i)| i)
    }

    /// Hand freshly prefilled sequences off prefill-role replicas to
    /// decode-capable peers (the disaggregated migration path).  Runs in
    /// the between-steps window [`EngineConfig::prefill_hold`] opens:
    /// each held sequence streams [`TokenEvent::PrefillDone`] immediately
    /// before its [`TokenEvent::Migrated`] (no `Preempted` — the move is
    /// voluntary), and the importer's next step streams the `Resumed`.
    /// Sequences no peer admits are left alone — their hold expires and
    /// they decode locally, so saturation degrades to mixed behavior.
    fn handoff_prefilled(&mut self, events: &mut Vec<TokenEvent>) {
        if !self.migration || self.engines.len() < 2 {
            return;
        }
        for src in 0..self.engines.len() {
            if self.router.replicas()[src].role != ReplicaRole::Prefill {
                continue;
            }
            for id in self.engines[src].prefilled_ready() {
                let Some(peek) = self.engines[src].peek_prefilled(id) else { continue };
                let content_tokens = peek.content.len() as u64;
                // same-precision first — adopting KV beats recomputing it
                let target = match self.pick_decode_target(src, &peek, true) {
                    Some(dst) => Some((dst, false)),
                    // a precision pin is a contract: pinned requests
                    // never requantize — they decode locally instead
                    None if peek.pinned.is_none() => {
                        self.pick_decode_target(src, &peek, false).map(|dst| (dst, true))
                    }
                    None => None,
                };
                let Some((dst, cross)) = target else { continue };
                let mut seq =
                    self.engines[src].export_running(id).expect("held sequence peeked above");
                if cross {
                    seq.strip_kv_for_requant();
                }
                let importer_reprefills = seq.needs_reprefill();
                self.engines[dst].import_swapped(seq);
                let from = self.router.migrate(id, dst).expect("handed-off seq in flight");
                debug_assert_eq!(from, src);
                if importer_reprefills {
                    // the importer teacher-forces the content again —
                    // placement must see that work (ROADMAP item 1)
                    self.router.charge_reprefill(id, content_tokens);
                }
                self.clock.migrations += 1;
                self.clock.prefill_handoffs += 1;
                events.push(TokenEvent::PrefillDone { id });
                events.push(TokenEvent::Migrated { id, from: src, to: dst });
                if cross {
                    self.clock.requants += 1;
                    events.push(TokenEvent::Requantized {
                        id,
                        from_bits: self.router.replicas()[src].precision,
                        to_bits: self.router.replicas()[dst].precision,
                    });
                }
            }
        }
    }

    /// Move the oldest swapped sequences off overloaded replicas —
    /// preferably onto same-precision decode-capable peers with headroom
    /// (KV travels verbatim), otherwise, for unpinned requests, onto
    /// **any** decode-capable peer with headroom via the cross-precision
    /// re-prefill path.  Deterministic: sources in replica order, target
    /// = the admitting peer with the most free KV blocks (lowest index
    /// on ties).  Each move streams [`TokenEvent::Migrated`] (plus
    /// [`TokenEvent::Requantized`] when crossing the boundary); the
    /// target's own next step streams the `Resumed`.
    fn rebalance(&mut self, events: &mut Vec<TokenEvent>) {
        if !self.migration || self.engines.len() < 2 {
            return;
        }
        for src in 0..self.engines.len() {
            while self.engines[src].is_overloaded() {
                let Some(peek) = self.engines[src].peek_swapped() else { break };
                // cheap pre-filter (the peek borrows, it doesn't clone):
                // some decode-capable peer must not be overloaded itself
                // AND be reachable — same precision, or any precision
                // when the request is unpinned.  A saturated cluster, or
                // a pinned head with only foreign-precision peers,
                // breaks here without scanning targets every step.
                let precision = self.router.replicas()[src].precision;
                let any_peer = self.engines.iter().enumerate().any(|(i, e)| {
                    i != src
                        && self.router.replicas()[i].role.accepts_decode()
                        && !e.is_overloaded()
                        && (self.router.replicas()[i].precision == precision
                            || peek.pinned.is_none())
                });
                if !any_peer {
                    break;
                }
                let content_tokens = peek.content.len() as u64;
                // same-precision first — carrying KV beats recomputing it
                let target = match self.best_target(src, &peek, true) {
                    Some(dst) => Some((dst, false)),
                    // a precision pin is a contract: pinned requests
                    // never requantize, they wait for their own replica
                    None if peek.pinned.is_none() => {
                        self.best_target(src, &peek, false).map(|dst| (dst, true))
                    }
                    None => None,
                };
                let Some((dst, cross)) = target else { break };
                let id = peek.id;
                let mut seq = self.engines[src].export_swapped().expect("peeked above");
                if cross {
                    seq.strip_kv_for_requant();
                }
                let importer_reprefills = seq.needs_reprefill();
                self.engines[dst].import_swapped(seq);
                let from = self.router.migrate(id, dst).expect("migrated seq must be in flight");
                debug_assert_eq!(from, src);
                if importer_reprefills {
                    // a requantized (or still-stripped) import costs the
                    // target a full re-prefill over the carried tokens —
                    // charge it so placement sees the work (ROADMAP 1)
                    self.router.charge_reprefill(id, content_tokens);
                }
                self.clock.migrations += 1;
                events.push(TokenEvent::Migrated { id, from: src, to: dst });
                if cross {
                    self.clock.requants += 1;
                    events.push(TokenEvent::Requantized {
                        id,
                        from_bits: self.router.replicas()[src].precision,
                        to_bits: self.router.replicas()[dst].precision,
                    });
                }
            }
        }
    }

    /// Step until every submitted request resolved; returns the full
    /// event stream.
    pub fn run_to_completion_events(&mut self) -> Result<Vec<TokenEvent>> {
        self.start_clock();
        let out = super::server::drain(self)?;
        self.stop_clock();
        Ok(out)
    }
}

impl<B: Backend> Stepper for Cluster<B> {
    /// Route to a prefill-capable replica by policy (respecting the
    /// request's precision pin).  An unroutable request resolves with a
    /// terminal empty-stream `Finished` on the next step.
    fn submit(&mut self, r: Request) {
        match self.router.route(&r, r.precision) {
            Some(idx) => self.engines[idx].submit(r),
            None => {
                self.unroutable += 1;
                self.clock.requests_in += 1;
                self.clock.requests_done += 1;
                self.pending_events
                    .push(TokenEvent::Finished { id: r.id, response: Response::rejected(r.id) });
            }
        }
    }

    /// Advance every busy replica one iteration, hand freshly prefilled
    /// sequences from prefill-role replicas to decode peers, rebalance
    /// swapped sequences off overloaded replicas, then merge the event
    /// streams and drain completions out of the router's load accounting.
    fn step(&mut self) -> Result<Vec<TokenEvent>> {
        let mut events = std::mem::take(&mut self.pending_events);
        for e in &mut self.engines {
            if !e.is_idle() {
                events.extend(e.step()?);
            }
        }
        self.handoff_prefilled(&mut events);
        self.rebalance(&mut events);
        for ev in &events {
            if let TokenEvent::Finished { id, .. } = ev {
                // unroutable terminals were never routed; ignore those
                let _ = self.router.complete(*id);
            }
        }
        Ok(events)
    }

    fn is_idle(&self) -> bool {
        self.pending_events.is_empty() && self.engines.iter().all(|e| e.is_idle())
    }

    /// Merged snapshot: per-replica counters/latencies summed onto the
    /// cluster clock (wall time is the cluster's own bracket).
    fn metrics(&self) -> Metrics {
        let mut m = self.clock.clone();
        for e in &self.engines {
            m.merge(&e.metrics);
        }
        m
    }

    fn start_clock(&mut self) {
        self.clock.start();
        for e in &mut self.engines {
            e.metrics.start();
        }
    }

    fn stop_clock(&mut self) {
        self.clock.finish();
        for e in &mut self.engines {
            e.metrics.finish();
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::backend::SimBackend;
    use crate::coordinator::request::{responses_of, GenParams, RequestId};

    fn sim() -> SimBackend {
        SimBackend::new(64, 64, vec![1, 2, 4, 8])
    }

    fn req(id: u64, prompt_len: usize, max_new: usize) -> Request {
        Request::new(
            id,
            (1..=prompt_len as i32).collect(),
            GenParams { max_new_tokens: max_new, sample: false, seed: id },
        )
    }

    fn small_engine(kv_blocks: usize) -> EngineConfig {
        EngineConfig { kv_blocks, block_tokens: 4, ..EngineConfig::default() }
    }

    fn cluster3() -> Cluster<SimBackend> {
        let mut spec = ClusterSpec::new(RoutePolicy::LeastLoaded);
        for i in 0..3 {
            spec = spec.replica(
                ReplicaSpec::new(format!("r{i}"), PrecisionConfig::W2A2)
                    .engine(small_engine(16)),
            );
        }
        Cluster::new(spec, |_| sim())
    }

    #[test]
    fn worker_budget_splits_evenly_across_replicas() {
        let build = |budget: usize| {
            let mut spec = ClusterSpec::new(RoutePolicy::RoundRobin).worker_budget(budget);
            for i in 0..3u64 {
                spec = spec.replica(ReplicaSpec::new(format!("r{i}"), PrecisionConfig::W2A2));
            }
            Cluster::new(spec, |r| {
                let seed = r.name.trim_start_matches('r').parse::<u64>().unwrap();
                SimBackend::with_ap_gemm(32, 64, vec![1, 2, 4], 64, 2, 2, seed)
            })
        };
        for e in build(8).engines() {
            assert_eq!(e.backend().gemm_workers(), Some(2), "8 workers / 3 replicas → 2 each");
        }
        for e in build(1).engines() {
            assert_eq!(e.backend().gemm_workers(), Some(1), "budget floor is 1 per replica");
        }
    }

    #[test]
    #[should_panic(expected = "no prefill-capable replica")]
    fn all_decode_topology_is_rejected_at_construction() {
        let spec = ClusterSpec::new(RoutePolicy::LeastLoaded)
            .replica(ReplicaSpec::new("d0", PrecisionConfig::W2A2).role(ReplicaRole::Decode));
        let _ = Cluster::new(spec, |_| sim());
    }

    #[test]
    fn cluster_serves_and_drains_router_accounting() {
        let mut c = cluster3();
        for i in 0..12u64 {
            c.submit(req(i, 4, 5));
        }
        let events = c.run_to_completion_events().unwrap();
        let out = responses_of(&events);
        assert_eq!(out.len(), 12);
        assert!(out.iter().all(|r| r.tokens.len() == 5));
        assert_eq!(c.router().inflight(), 0, "completions drained the router");
        assert_eq!(c.router().routed, 12);
        assert_eq!(c.router().completed, 12);
        c.check_invariants().unwrap();
        // least-loaded actually spread the work
        let busy = c.engines().iter().filter(|e| e.counters().completed > 0).count();
        assert_eq!(busy, 3, "all replicas served");
        let m = c.metrics();
        assert_eq!(m.requests_done, 12);
        assert_eq!(m.tokens_generated, 60);
    }

    #[test]
    fn precision_pinning_routes_or_rejects() {
        let spec = ClusterSpec::new(RoutePolicy::RoundRobin)
            .replica(ReplicaSpec::new("w2", PrecisionConfig::W2A2))
            .replica(ReplicaSpec::new("w1", PrecisionConfig::W1A1));
        let mut c = Cluster::new(spec, |_| sim());
        c.submit(req(0, 4, 3).with_precision(PrecisionConfig::W1A1));
        c.submit(req(1, 4, 3).with_precision(PrecisionConfig::W8A8)); // nobody serves this
        c.submit(req(2, 4, 3));
        let events = c.run_to_completion_events().unwrap();
        let out = responses_of(&events);
        assert_eq!(out.len(), 3);
        assert_eq!(c.unroutable(), 1);
        let rej: Vec<_> = out.iter().filter(|r| r.tokens.is_empty()).collect();
        assert_eq!(rej.len(), 1);
        assert_eq!(rej[0].id.0, 1);
        // the pinned request landed on the W1A1 replica
        assert_eq!(c.engine(1).counters().completed, 1);
        c.check_invariants().unwrap();
    }

    #[test]
    fn engine_level_rejects_still_drain_the_router() {
        let spec = ClusterSpec::new(RoutePolicy::RoundRobin)
            .replica(ReplicaSpec::new("r0", PrecisionConfig::W2A2).engine(small_engine(2)));
        let mut c = Cluster::new(spec, |_| sim());
        // routed fine, but the engine's capacity guard rejects it (budget
        // 40 tokens > 2×4 pool) — the Finished event must still release
        // the router's load accounting
        c.submit(req(0, 8, 32));
        let events = c.run_to_completion_events().unwrap();
        let out = responses_of(&events);
        assert_eq!(out.len(), 1);
        assert!(out[0].tokens.is_empty());
        assert_eq!(c.router().inflight(), 0);
        c.check_invariants().unwrap();
    }

    /// The hot/cold two-replica fixture the migration tests share:
    /// replica 0 has a 4-block pool (two 16-token-budget residents
    /// overflow it), replica 1 is roomy.
    fn hot_cold(migration: bool, cold_precision: PrecisionConfig) -> Cluster<SimBackend> {
        let spec = ClusterSpec::new(RoutePolicy::LeastLoaded)
            .migration(migration)
            .replica(ReplicaSpec::new("hot", PrecisionConfig::W2A2).engine(small_engine(4)))
            .replica(ReplicaSpec::new("cold", cold_precision).engine(small_engine(32)));
        Cluster::new(spec, |_| sim())
    }

    #[test]
    fn overloaded_replica_migrates_swapped_sequence_to_peer() {
        use crate::coordinator::backend::drive_unbatched;

        // LeastLoaded lands A and C on r0 (ties break by index) and B on
        // r1; decoding preempts C, which r0 can never resume while A
        // runs — the rebalancer must move it to r1.
        let mk_prompt = |base: i32| (base..base + 8).collect::<Vec<i32>>();
        let reqs: Vec<Request> = [10, 50, 30]
            .iter()
            .enumerate()
            .map(|(i, &base)| {
                Request::new(
                    i as u64,
                    mk_prompt(base),
                    GenParams { max_new_tokens: 8, sample: false, seed: i as u64 },
                )
            })
            .collect();
        let mut oracle = sim();
        let want: Vec<Vec<i32>> = reqs
            .iter()
            .map(|r| drive_unbatched(&mut oracle, &r.prompt, &r.params).unwrap())
            .collect();

        let run = |migration: bool| {
            let mut c = hot_cold(migration, PrecisionConfig::W2A2);
            for r in &reqs {
                c.submit(r.clone());
            }
            let events = c.run_to_completion_events().unwrap();
            c.check_invariants().unwrap();
            assert_eq!(c.router().inflight(), 0);
            for (i, e) in c.engines().iter().enumerate() {
                assert_eq!(
                    e.pool().free_blocks(),
                    e.pool().total_blocks(),
                    "replica {i} leaked blocks"
                );
            }
            let mut out = responses_of(&events);
            out.sort_by_key(|r| r.id);
            assert_eq!(out.len(), 3);
            for (resp, want) in out.iter().zip(&want) {
                let id = resp.id.0;
                assert_eq!(resp.tokens, *want, "req {id} ≠ oracle (migration={migration})");
            }
            (c, events)
        };

        // with migration: the swapped sequence finishes on the peer
        let (c, events) = run(true);
        let migrated: Vec<_> = events
            .iter()
            .filter_map(|ev| match ev {
                TokenEvent::Migrated { id, from, to } => Some((id.0, *from, *to)),
                _ => None,
            })
            .collect();
        assert_eq!(migrated, vec![(2, 0, 1)], "C moves hot → cold exactly once");
        assert!(c.migrations() >= 1);
        assert_eq!(c.engine(0).counters().exported, 1);
        assert_eq!(c.engine(1).counters().imported, 1);
        assert_eq!(c.engine(1).counters().resumes, 1, "C resumed on the peer");
        assert_eq!(c.engine(0).counters().completed, 1, "only A finished on hot");
        assert_eq!(c.engine(1).counters().completed, 2, "B and the migrated C on cold");
        assert_eq!(c.router().migrated, 1);
        assert_eq!(c.metrics().migrations, 1);
        assert_eq!(c.prefill_handoffs(), 0, "mixed replicas never hand off");

        // without migration: same streams, but C waits out A on r0
        let (c, events) = run(false);
        assert!(events.iter().all(|ev| !matches!(ev, TokenEvent::Migrated { .. })));
        assert_eq!(c.migrations(), 0);
        assert_eq!(c.engine(0).counters().completed, 2, "C stayed pinned to hot");
    }

    #[test]
    fn pinned_requests_never_requantize_across_precision_boundaries() {
        // the only peer serves a different precision, and both requests
        // PINNED theirs: the pin is a contract, so the swapped sequence
        // must NOT migrate (not even via the re-prefill path) and still
        // completes locally
        let mut c = hot_cold(true, PrecisionConfig::W1A1);
        // pin both to the W2A2 replica so it overloads
        for i in 0..2u64 {
            let r = Request::new(
                i,
                ((i as i32 * 40)..(i as i32 * 40) + 8).collect(),
                GenParams { max_new_tokens: 8, sample: false, seed: i },
            )
            .with_precision(PrecisionConfig::W2A2);
            c.submit(r);
        }
        let events = c.run_to_completion_events().unwrap();
        assert!(events.iter().all(|ev| !matches!(
            ev,
            TokenEvent::Migrated { .. } | TokenEvent::Requantized { .. }
        )));
        assert_eq!(c.migrations(), 0);
        assert_eq!(c.requants(), 0);
        assert_eq!(c.engine(0).counters().completed, 2);
        assert_eq!(c.engine(1).counters().completed, 0);
        c.check_invariants().unwrap();
    }

    #[test]
    fn unpinned_swapped_sequence_requantizes_to_a_different_precision_peer() {
        // same topology, but UNPINNED requests: with no same-precision
        // peer, the rebalancer takes the cross-precision path — the KV is
        // dropped, the W1A1 replica re-prefills, and the client sees
        // Preempted → Migrated → Requantized → Resumed in order
        let mut c = hot_cold(true, PrecisionConfig::W1A1);
        // LeastLoaded with ties broken by index: A→hot, B→cold, C→hot.
        // A + C (budget 16 tokens each) overflow hot's 4-block pool
        // mid-decode, so C is preempted with no same-precision peer —
        // the cross-precision fallback is the only way off the replica.
        for (i, &base) in [10i32, 50, 30].iter().enumerate() {
            c.submit(Request::new(
                i as u64,
                (base..base + 8).collect(),
                GenParams { max_new_tokens: 8, sample: false, seed: i as u64 },
            ));
        }
        let events = c.run_to_completion_events().unwrap();
        let requants: Vec<_> = events
            .iter()
            .filter_map(|ev| match ev {
                TokenEvent::Requantized { id, from_bits, to_bits } => {
                    Some((id.0, *from_bits, *to_bits))
                }
                _ => None,
            })
            .collect();
        assert_eq!(
            requants,
            vec![(2, PrecisionConfig::W2A2, PrecisionConfig::W1A1)],
            "C requantizes hot-w2 → cold-w1 exactly once"
        );
        assert_eq!(c.migrations(), 1);
        assert_eq!(c.requants(), 1);
        assert_eq!(c.engine(1).counters().imported, 1);
        assert_eq!(c.engine(1).counters().reprefills, 1, "the W1A1 peer rebuilt the KV");
        assert_eq!(c.engine(1).counters().resumes, 1);
        // stream grammar: Preempted → Migrated → Requantized → Resumed
        let lifecycle: Vec<&TokenEvent> = events
            .iter()
            .filter(|ev| {
                ev.id().0 == 2
                    && !matches!(ev, TokenEvent::Token { .. } | TokenEvent::Admitted { .. })
            })
            .collect();
        assert!(matches!(lifecycle[0], TokenEvent::Preempted { .. }), "{lifecycle:?}");
        assert!(
            matches!(lifecycle[1], TokenEvent::Migrated { from: 0, to: 1, .. }),
            "{lifecycle:?}"
        );
        assert!(matches!(lifecycle[2], TokenEvent::Requantized { .. }), "{lifecycle:?}");
        assert!(matches!(lifecycle[3], TokenEvent::Resumed { .. }), "{lifecycle:?}");
        for (i, e) in c.engines().iter().enumerate() {
            assert_eq!(e.pool().free_blocks(), e.pool().total_blocks(), "replica {i} leaked");
        }
        c.check_invariants().unwrap();
        assert_eq!(c.router().inflight(), 0);
        // migration off restores strict pinning-to-admission-replica
        let mut c2 = hot_cold(false, PrecisionConfig::W1A1);
        for (i, &base) in [10i32, 50, 30].iter().enumerate() {
            c2.submit(Request::new(
                i as u64,
                (base..base + 8).collect(),
                GenParams { max_new_tokens: 8, sample: false, seed: i as u64 },
            ));
        }
        let events = c2.run_to_completion_events().unwrap();
        assert!(events.iter().all(|ev| !matches!(ev, TokenEvent::Requantized { .. })));
        assert_eq!(c2.requants(), 0);
        c2.check_invariants().unwrap();
    }

    #[test]
    fn reprefill_cost_is_charged_to_the_importing_replica() {
        // freeze the cluster right after the requantizing migration (step
        // until the Requantized event lands, before the stream drains)
        // and check the router's split accounting: the importer's prefill
        // load must include the re-prefill charge — prompt + generated —
        // on top of the migrated request's original budget
        let mut c = hot_cold(true, PrecisionConfig::W1A1);
        for (i, &base) in [10i32, 50, 30].iter().enumerate() {
            c.submit(Request::new(
                i as u64,
                (base..base + 8).collect(),
                GenParams { max_new_tokens: 8, sample: false, seed: i as u64 },
            ));
        }
        let mut carried = 0u64;
        'outer: for _ in 0..64 {
            for ev in c.step().unwrap() {
                if let TokenEvent::Preempted { id } = ev {
                    // C's KV content at preemption = what the importer
                    // will re-prefill (peek before the rebalance exports)
                    assert_eq!(id.0, 2);
                }
                if let TokenEvent::Requantized { id, .. } = ev {
                    assert_eq!(id.0, 2);
                    carried = 1; // found
                    break 'outer;
                }
            }
        }
        assert_eq!(carried, 1, "the cross-precision migration must happen");
        // importer (replica 1) now carries B's budget + C's budget + C's
        // re-prefill charge; the charge is visible as prefill load beyond
        // the two prompts (8 tokens each)
        let rep = &c.router().replicas()[1];
        assert!(
            rep.outstanding_prefill() > 16,
            "re-prefill charge missing: prefill load {} ≤ two prompts",
            rep.outstanding_prefill()
        );
        c.check_invariants().unwrap();
        // and completion drains every charged token
        c.run_to_completion_events().unwrap();
        assert_eq!(c.router().inflight(), 0);
        assert!(c.router().replicas().iter().all(|r| r.outstanding() == 0));
        c.check_invariants().unwrap();
    }

    #[test]
    fn prefill_replica_hands_off_to_decode_peer_with_identical_streams() {
        // the disaggregated tentpole at cluster level: a prefill/decode
        // split cluster must stream every byte a mixed cluster streams,
        // with each request prefilled on the prefill replica, handed off
        // (PrefillDone immediately before Migrated), and decoded to
        // completion on the decode replica
        let reqs: Vec<Request> = (0..6u64).map(|i| req(i, 4 + (i as usize % 3), 6)).collect();
        let split_spec = ClusterSpec::new(RoutePolicy::LeastLoaded)
            .replica(
                ReplicaSpec::new("p0", PrecisionConfig::W2A2)
                    .role(ReplicaRole::Prefill)
                    .engine(small_engine(16)),
            )
            .replica(
                ReplicaSpec::new("d0", PrecisionConfig::W2A2)
                    .role(ReplicaRole::Decode)
                    .engine(small_engine(32)),
            );
        let mixed_spec = ClusterSpec::new(RoutePolicy::LeastLoaded)
            .replica(ReplicaSpec::new("m0", PrecisionConfig::W2A2).engine(small_engine(16)))
            .replica(ReplicaSpec::new("m1", PrecisionConfig::W2A2).engine(small_engine(32)));

        let stream_of = |events: &[TokenEvent]| {
            let mut s: Vec<(u64, usize, i32)> = events
                .iter()
                .filter_map(|ev| match ev {
                    TokenEvent::Token { id, token, step } => Some((id.0, *step, *token)),
                    _ => None,
                })
                .collect();
            s.sort();
            s
        };

        let mut split = Cluster::new(split_spec, |_| sim());
        let mut mixed = Cluster::new(mixed_spec, |_| sim());
        for r in &reqs {
            split.submit(r.clone());
            mixed.submit(r.clone());
        }
        let split_events = split.run_to_completion_events().unwrap();
        let mixed_events = mixed.run_to_completion_events().unwrap();
        assert_eq!(
            stream_of(&split_events),
            stream_of(&mixed_events),
            "disaggregation changed a streamed byte"
        );

        // every request was handed off exactly once, prefill → decode
        assert_eq!(split.prefill_handoffs(), 6);
        assert_eq!(split.migrations(), 6);
        assert_eq!(split.requants(), 0, "same-precision handoff adopts the KV");
        assert_eq!(split.engine(0).counters().prefills, 6, "all prefills on p0");
        assert_eq!(split.engine(0).counters().completed, 0, "nothing finished on p0");
        assert_eq!(split.engine(1).counters().completed, 6, "all streams finished on d0");
        assert_eq!(split.engine(1).counters().prefills, 0, "d0 never prefills");

        // grammar: PrefillDone streams immediately before its Migrated,
        // and every Migrated targets the decode replica
        for (i, ev) in split_events.iter().enumerate() {
            if let TokenEvent::PrefillDone { id } = ev {
                match &split_events[i + 1] {
                    TokenEvent::Migrated { id: mid, from, to } => {
                        assert_eq!(mid, id, "PrefillDone must pair with its own Migrated");
                        assert_eq!((*from, *to), (0, 1));
                    }
                    other => panic!("PrefillDone followed by {other:?}"),
                }
            }
        }
        let handoff_events =
            split_events.iter().filter(|e| matches!(e, TokenEvent::PrefillDone { .. })).count();
        assert_eq!(handoff_events, 6);
        // no Preempted accompanies a voluntary handoff
        assert!(split_events.iter().all(|e| !matches!(e, TokenEvent::Preempted { .. })));

        // per-role metrics views split cleanly
        let p = split.metrics_for_role(ReplicaRole::Prefill);
        let d = split.metrics_for_role(ReplicaRole::Decode);
        assert_eq!(p.ttft.count(), 6, "prefill replica owns every TTFT sample");
        assert!(d.itl.count() > 0, "decode replica owns the ITL gaps");
        assert_eq!(d.ttft.count(), 0);

        // zero leaks on both roles, router drained, invariants hold
        for (i, e) in split.engines().iter().enumerate() {
            assert_eq!(e.pool().free_blocks(), e.pool().total_blocks(), "replica {i} leaked");
        }
        assert_eq!(split.router().inflight(), 0);
        split.check_invariants().unwrap();
        mixed.check_invariants().unwrap();
    }

    #[test]
    fn handoff_without_decode_headroom_falls_back_to_local_decode() {
        // decode replica too small to ever admit (2-block pool, budget
        // needs 3): the prefill replica's holds expire and every stream
        // completes locally — disaggregation must degrade, not strand
        let spec = ClusterSpec::new(RoutePolicy::LeastLoaded)
            .replica(
                ReplicaSpec::new("p0", PrecisionConfig::W2A2)
                    .role(ReplicaRole::Prefill)
                    .engine(small_engine(16)),
            )
            .replica(
                ReplicaSpec::new("d0", PrecisionConfig::W2A2)
                    .role(ReplicaRole::Decode)
                    .engine(small_engine(2)),
            );
        let mut c = Cluster::new(spec, |_| sim());
        for i in 0..3u64 {
            c.submit(req(i, 6, 6)); // budget 12 tokens = 3 blocks > d0's 2
        }
        let events = c.run_to_completion_events().unwrap();
        let out = responses_of(&events);
        assert_eq!(out.len(), 3);
        assert!(out.iter().all(|r| r.tokens.len() == 6), "every stream completed");
        assert_eq!(c.prefill_handoffs(), 0, "nothing could be handed off");
        assert_eq!(c.engine(0).counters().completed, 3, "all decoded locally on p0");
        assert!(events.iter().all(|e| !matches!(e, TokenEvent::PrefillDone { .. })));
        c.check_invariants().unwrap();
    }

    #[test]
    fn cross_precision_handoff_requantizes_and_charges_the_importer() {
        // prefill replica at W4, decode replica at W2, unpinned request:
        // the handoff must take the requant path — PrefillDone, Migrated,
        // Requantized adjacent in the stream, the decode replica
        // re-prefills, and the router charges it the re-prefill
        use crate::coordinator::backend::superset_store;
        let store = superset_store(64, 64, 4, 77);
        let spec = ClusterSpec::new(RoutePolicy::LeastLoaded)
            .replica(
                ReplicaSpec::new("p-w4", PrecisionConfig::W4A4)
                    .role(ReplicaRole::Prefill)
                    .engine(small_engine(16)),
            )
            .replica(
                ReplicaSpec::new("d-w2", PrecisionConfig::W2A2)
                    .role(ReplicaRole::Decode)
                    .engine(small_engine(32)),
            );
        let mut c = Cluster::new(spec, move |r| {
            let (nw, nx) = if r.precision == PrecisionConfig::W4A4 { (4, 2) } else { (2, 2) };
            SimBackend::with_shared_store(64, vec![1, 2, 4, 8], store.clone(), nw, nx)
        });
        c.submit(req(0, 5, 6));
        let events = c.run_to_completion_events().unwrap();
        let out = responses_of(&events);
        assert_eq!(out.len(), 1);
        assert_eq!(out[0].tokens.len(), 6);
        assert_eq!(c.prefill_handoffs(), 1);
        assert_eq!(c.requants(), 1, "W4 → W2 handoff crosses the precision boundary");
        assert_eq!(c.engine(1).counters().reprefills, 1, "d-w2 rebuilt the KV");
        // lifecycle: PrefillDone → Migrated → Requantized → Resumed
        let lifecycle: Vec<&TokenEvent> = events
            .iter()
            .filter(|ev| {
                ev.id() == RequestId(0)
                    && !matches!(ev, TokenEvent::Token { .. } | TokenEvent::Admitted { .. })
            })
            .collect();
        assert!(matches!(lifecycle[0], TokenEvent::PrefillDone { .. }), "{lifecycle:?}");
        assert!(matches!(lifecycle[1], TokenEvent::Migrated { .. }), "{lifecycle:?}");
        assert!(matches!(lifecycle[2], TokenEvent::Requantized { .. }), "{lifecycle:?}");
        assert!(matches!(lifecycle[3], TokenEvent::Resumed { .. }), "{lifecycle:?}");
        assert_eq!(c.router().inflight(), 0);
        c.check_invariants().unwrap();
    }

    #[test]
    fn speculating_mixed_precision_cluster_requantizes_and_keeps_streams_identical() {
        use crate::coordinator::backend::superset_store;

        // one 4-bit superset store; the hot replica serves W4 and drafts
        // its W2 plane prefix, the cold replica serves W2 and drafts W1 —
        // per-replica speculation out of one pack.  The hot pool is sized
        // so decode pressure preempts the younger resident, which can
        // only leave via the cross-precision requant path; the migrated
        // sequence's draft state must not travel (it never exists between
        // steps), and every stream must match a spec-less run byte for
        // byte.
        let run = |spec_on: bool| {
            let store = superset_store(64, 64, 4, 77);
            let (spec_k, hot_draft, cold_draft) = if spec_on { (2, 2, 1) } else { (0, 0, 0) };
            let spec = ClusterSpec::new(RoutePolicy::LeastLoaded)
                .replica(
                    ReplicaSpec::new("hot-w4", PrecisionConfig::W4A4)
                        .engine(small_engine(4))
                        .speculation(spec_k, hot_draft),
                )
                .replica(
                    ReplicaSpec::new("cold-w2", PrecisionConfig::W2A2)
                        .engine(small_engine(32))
                        .speculation(spec_k, cold_draft),
                );
            let mut c = Cluster::new(spec, move |r| {
                let (nw, nx) =
                    if r.precision == PrecisionConfig::W4A4 { (4, 2) } else { (2, 2) };
                SimBackend::with_shared_store(64, vec![1, 2, 4, 8, 16], store.clone(), nw, nx)
            });
            for (i, &base) in [10i32, 50, 30].iter().enumerate() {
                c.submit(Request::new(
                    i as u64,
                    (base..base + 8).collect(),
                    GenParams { max_new_tokens: 8, sample: false, seed: i as u64 },
                ));
            }
            let events = c.run_to_completion_events().unwrap();
            c.check_invariants().unwrap();
            for (i, e) in c.engines().iter().enumerate() {
                assert_eq!(e.pool().free_blocks(), e.pool().total_blocks(), "replica {i} leaked");
            }
            let mut out = responses_of(&events);
            out.sort_by_key(|r| r.id);
            (c, out.into_iter().map(|r| r.tokens).collect::<Vec<_>>())
        };

        let (plain_c, plain) = run(false);
        let (spec_c, spec) = run(true);
        assert_eq!(spec, plain, "speculation must not change a single byte of any stream");
        assert!(plain.iter().all(|t| t.len() == 8));
        // both runs took the same migration decisions (preemption is
        // driven by KV pressure, which speculation never adds to)
        for c in [&plain_c, &spec_c] {
            assert_eq!(c.migrations(), 1, "the preempted sequence moved hot → cold");
            assert_eq!(c.requants(), 1, "and crossed the precision boundary");
            assert_eq!(c.engine(1).counters().reprefills, 1);
        }
        // speculation was actually live on both precisions of the spec run
        assert_eq!(spec_c.engine(0).spec_k(), 2, "W4 replica drafts W2");
        assert_eq!(spec_c.engine(1).spec_k(), 2, "W2 replica drafts W1");
        let drafted: u64 = spec_c.engines().iter().map(|e| e.counters().drafted).sum();
        let accepted: u64 = spec_c.engines().iter().map(|e| e.counters().accepted).sum();
        assert!(drafted > 0, "decode-heavy load must have drafted");
        assert!(accepted <= drafted);
        // the merged cluster metrics carry the speculation counters
        let m = spec_c.metrics();
        assert_eq!(m.spec_drafted, drafted);
        assert_eq!(m.spec_accepted, accepted);
        assert_eq!(plain_c.metrics().spec_drafted, 0, "spec-less run drafts nothing");
    }

    #[test]
    fn cluster_is_deterministic() {
        let run = || {
            let mut c = cluster3();
            for i in 0..9u64 {
                c.submit(req(i, 3 + i as usize % 4, 4));
            }
            let mut out = responses_of(&c.run_to_completion_events().unwrap());
            out.sort_by_key(|r| r.id);
            out.iter().map(|r| r.tokens.clone()).collect::<Vec<_>>()
        };
        assert_eq!(run(), run());
    }
}
