//! Request router: dispatches requests across model replicas.
//!
//! The paper's system serves one quantized model per precision config; a
//! deployment runs several replicas (possibly at different W/A precisions)
//! behind one endpoint.  The router picks a replica per request by
//! policy; replicas report queue depth so least-loaded routing can steer
//! around stragglers.  When the cluster's rebalancer migrates a swapped
//! sequence, [`Router::migrate`] transfers its load accounting to the
//! target **conservatively** — the full original budget moves, so the
//! conservation law (Σ outstanding == Σ inflight budgets) survives
//! migration and completions drain the replica actually doing the work.

use super::request::{Request, RequestId};
use crate::model::PrecisionConfig;
use std::collections::HashMap;

/// Routing policy.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum RoutePolicy {
    RoundRobin,
    /// Pick the replica with the smallest outstanding token budget.
    LeastLoaded,
}

impl RoutePolicy {
    /// Parse a CLI spelling (`round-robin`/`rr`, `least-loaded`/`least`).
    pub fn parse(s: &str) -> Option<Self> {
        match s.to_ascii_lowercase().as_str() {
            "round-robin" | "rr" => Some(RoutePolicy::RoundRobin),
            "least-loaded" | "least" => Some(RoutePolicy::LeastLoaded),
            _ => None,
        }
    }
}

/// A registered replica.
#[derive(Debug, Clone)]
pub struct Replica {
    pub name: String,
    pub precision: PrecisionConfig,
    /// Outstanding work in tokens (prompt + max_new of in-flight requests).
    outstanding: u64,
}

impl Replica {
    /// Outstanding token budget (load the router steers by).
    pub fn outstanding(&self) -> u64 {
        self.outstanding
    }
}

/// The router: owns replica bookkeeping, returns an index per request.
pub struct Router {
    replicas: Vec<Replica>,
    policy: RoutePolicy,
    rr_next: usize,
    /// request → replica index (so completions decrement the right one).
    inflight: HashMap<RequestId, (usize, u64)>,
    pub routed: u64,
    pub completed: u64,
    /// In-flight requests transferred between replicas by the rebalancer.
    pub migrated: u64,
}

impl Router {
    pub fn new(policy: RoutePolicy) -> Self {
        Self {
            replicas: Vec::new(),
            policy,
            rr_next: 0,
            inflight: HashMap::new(),
            routed: 0,
            completed: 0,
            migrated: 0,
        }
    }

    pub fn add_replica(&mut self, name: impl Into<String>, precision: PrecisionConfig) -> usize {
        self.replicas.push(Replica { name: name.into(), precision, outstanding: 0 });
        self.replicas.len() - 1
    }

    pub fn replicas(&self) -> &[Replica] {
        &self.replicas
    }

    pub fn policy(&self) -> RoutePolicy {
        self.policy
    }

    /// Replicas able to serve a precision (exact match).
    fn candidates(&self, precision: Option<PrecisionConfig>) -> Vec<usize> {
        self.replicas
            .iter()
            .enumerate()
            .filter(|(_, r)| precision.map(|p| r.precision == p).unwrap_or(true))
            .map(|(i, _)| i)
            .collect()
    }

    /// Route a request (optionally pinned to a precision).  Returns the
    /// replica index, or None if no candidate exists.
    pub fn route(&mut self, req: &Request, precision: Option<PrecisionConfig>) -> Option<usize> {
        let cands = self.candidates(precision);
        if cands.is_empty() {
            return None;
        }
        let idx = match self.policy {
            RoutePolicy::RoundRobin => {
                // advance rr cursor to the next candidate
                let pos = cands.iter().position(|&c| c >= self.rr_next % self.replicas.len());
                let pick = cands[pos.unwrap_or(0) % cands.len()];
                self.rr_next = pick + 1;
                pick
            }
            RoutePolicy::LeastLoaded => *cands
                .iter()
                .min_by_key(|&&c| (self.replicas[c].outstanding, c))
                .unwrap(),
        };
        let budget = (req.prompt.len() + req.params.max_new_tokens) as u64;
        self.replicas[idx].outstanding += budget;
        self.inflight.insert(req.id, (idx, budget));
        self.routed += 1;
        Some(idx)
    }

    /// Transfer an in-flight request's load accounting to replica `to`
    /// (cross-replica migration of a swapped sequence).  The full
    /// original budget moves — conservative, since the remaining work is
    /// unknowable mid-stream — so conservation holds and the eventual
    /// completion drains the target.  Returns the source replica, or
    /// None if the request isn't in flight (never routed, or already
    /// completed).  A self-migration is a no-op.
    pub fn migrate(&mut self, id: RequestId, to: usize) -> Option<usize> {
        let (from, budget) = *self.inflight.get(&id)?;
        if from == to {
            return Some(from);
        }
        assert!(to < self.replicas.len(), "migrate to unknown replica {to}");
        self.replicas[from].outstanding = self.replicas[from].outstanding.saturating_sub(budget);
        self.replicas[to].outstanding += budget;
        self.inflight.insert(id, (to, budget));
        self.migrated += 1;
        Some(from)
    }

    /// Mark a routed request finished; releases its load accounting.
    pub fn complete(&mut self, id: RequestId) -> Option<usize> {
        let (idx, budget) = self.inflight.remove(&id)?;
        self.replicas[idx].outstanding = self.replicas[idx].outstanding.saturating_sub(budget);
        self.completed += 1;
        Some(idx)
    }

    pub fn inflight(&self) -> usize {
        self.inflight.len()
    }

    /// Conservation check: Σ outstanding == Σ inflight budgets.
    pub fn check_invariants(&self) -> Result<(), String> {
        let tracked: u64 = self.inflight.values().map(|(_, b)| b).sum();
        let held: u64 = self.replicas.iter().map(|r| r.outstanding).sum();
        if tracked != held {
            return Err(format!("load accounting drift: inflight {tracked} vs held {held}"));
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::request::GenParams;
    use crate::util::proptest::forall;

    fn req(id: u64, plen: usize, mnew: usize) -> Request {
        Request::new(
            id,
            vec![1; plen],
            GenParams { max_new_tokens: mnew, sample: false, seed: id },
        )
    }

    fn router3(policy: RoutePolicy) -> Router {
        let mut r = Router::new(policy);
        r.add_replica("r0", PrecisionConfig::W2A2);
        r.add_replica("r1", PrecisionConfig::W2A2);
        r.add_replica("r2", PrecisionConfig::W1A1);
        r
    }

    #[test]
    fn round_robin_cycles() {
        let mut r = router3(RoutePolicy::RoundRobin);
        let picks: Vec<usize> =
            (0..6).map(|i| r.route(&req(i, 4, 4), None).unwrap()).collect();
        assert_eq!(picks, vec![0, 1, 2, 0, 1, 2]);
    }

    #[test]
    fn precision_pinning() {
        let mut r = router3(RoutePolicy::RoundRobin);
        for i in 0..4 {
            let idx = r.route(&req(i, 4, 4), Some(PrecisionConfig::W1A1)).unwrap();
            assert_eq!(idx, 2, "only r2 serves W1A1");
        }
        assert!(r.route(&req(99, 4, 4), Some(PrecisionConfig::W8A8)).is_none());
    }

    #[test]
    fn least_loaded_balances() {
        let mut r = router3(RoutePolicy::LeastLoaded);
        // heavy request to r0 (it's least-loaded first, ties break by index)
        let a = r.route(&req(0, 100, 100), None).unwrap();
        assert_eq!(a, 0);
        // next requests avoid the loaded replica
        let b = r.route(&req(1, 4, 4), None).unwrap();
        let c = r.route(&req(2, 4, 4), None).unwrap();
        assert_ne!(b, 0);
        assert_ne!(c, 0);
        assert_ne!(b, c, "spread across the two idle replicas");
        // completion releases the load
        r.complete(RequestId(0)).unwrap();
        r.check_invariants().unwrap();
        let d = r.route(&req(3, 4, 4), None).unwrap();
        assert_eq!(d, 0, "r0 is idle again");
    }

    #[test]
    fn complete_unknown_is_none() {
        let mut r = router3(RoutePolicy::RoundRobin);
        assert!(r.complete(RequestId(42)).is_none());
    }

    #[test]
    fn migrate_transfers_load_conservatively() {
        let mut r = router3(RoutePolicy::RoundRobin);
        let rq = req(0, 10, 6); // budget 16
        let from = r.route(&rq, None).unwrap();
        assert_eq!(r.replicas()[from].outstanding(), 16);
        let to = (from + 1) % 3;
        assert_eq!(r.migrate(rq.id, to), Some(from));
        assert_eq!(r.replicas()[from].outstanding(), 0, "source drained");
        assert_eq!(r.replicas()[to].outstanding(), 16, "full budget moved");
        assert_eq!(r.migrated, 1);
        r.check_invariants().unwrap();
        // completion now drains the TARGET, not the source
        r.complete(rq.id).unwrap();
        assert_eq!(r.replicas()[to].outstanding(), 0);
        r.check_invariants().unwrap();
        // unknown / self migrations are harmless
        assert!(r.migrate(RequestId(42), 0).is_none());
        let rq2 = req(1, 4, 4);
        let at = r.route(&rq2, None).unwrap();
        assert_eq!(r.migrate(rq2.id, at), Some(at), "self-migration is a no-op");
        assert_eq!(r.migrated, 1, "no-op not counted");
        r.check_invariants().unwrap();
    }

    #[test]
    fn prop_conservation() {
        forall(48, |rng| {
            let policy =
                if rng.bool() { RoutePolicy::RoundRobin } else { RoutePolicy::LeastLoaded };
            let mut r = Router::new(policy);
            let n_rep = rng.usize(1, 5);
            for i in 0..n_rep {
                r.add_replica(format!("r{i}"), PrecisionConfig::W2A2);
            }
            let mut live: Vec<RequestId> = Vec::new();
            let mut next = 0u64;
            for _ in 0..rng.usize(5, 80) {
                match rng.u32(0, 3) {
                    0 if !live.is_empty() => {
                        // migration must conserve load accounting too
                        let id = live[rng.usize(0, live.len())];
                        r.migrate(id, rng.usize(0, n_rep)).unwrap();
                    }
                    1 if !live.is_empty() => {
                        let i = rng.usize(0, live.len());
                        let id = live.swap_remove(i);
                        r.complete(id).unwrap();
                    }
                    _ => {
                        let rq = req(next, rng.usize(1, 32), rng.usize(1, 32));
                        if r.route(&rq, None).is_some() {
                            live.push(rq.id);
                        }
                        next += 1;
                    }
                }
                r.check_invariants().unwrap_or_else(|e| panic!("{e}"));
            }
            for id in live {
                r.complete(id).unwrap();
            }
            assert_eq!(r.inflight(), 0);
            assert!(r.replicas().iter().all(|rep| rep.outstanding == 0));
        });
    }
}
