//! Request router: dispatches requests across model replicas.
//!
//! The paper's system serves one quantized model per precision config; a
//! deployment runs several replicas (possibly at different W/A precisions)
//! behind one endpoint.  The router picks a replica per request by
//! policy; replicas report queue depth so least-loaded routing can steer
//! around stragglers.  When the cluster's rebalancer migrates a swapped
//! sequence, [`Router::migrate`] transfers its load accounting to the
//! target **conservatively** — the full original budget moves, so the
//! conservation law (Σ outstanding == Σ inflight budgets) survives
//! migration and completions drain the replica actually doing the work.
//!
//! ## Replica roles
//!
//! Every replica carries a [`ReplicaRole`].  Admission routing only
//! considers **prefill-capable** replicas (`Prefill` or `Mixed`) — every
//! accepted request starts with a prefill — while decode-only replicas
//! receive work exclusively through migration (the cluster's
//! prefill→decode handoff and rebalancer, both of which refuse
//! prefill-only targets for decoding sequences).  `Mixed` is the default
//! and preserves the symmetric pre-role behavior exactly.
//!
//! Load accounting is split along the same axis: each in-flight request
//! contributes a **prefill component** (its prompt tokens, plus any
//! re-prefill a requantizing migration charges the importer via
//! [`Router::charge_reprefill`]) and a **decode component** (its
//! `max_new` budget).  The split lets the cluster steer prefill→decode
//! handoffs by decode load specifically, and makes requantized imports
//! visible to placement instead of looking free.

use super::request::{Request, RequestId};
use crate::model::PrecisionConfig;
use std::collections::HashMap;

/// Routing policy.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum RoutePolicy {
    RoundRobin,
    /// Pick the replica with the smallest outstanding token budget.
    LeastLoaded,
}

impl RoutePolicy {
    /// Parse a CLI spelling (`round-robin`/`rr`, `least-loaded`/`least`).
    pub fn parse(s: &str) -> Option<Self> {
        match s.to_ascii_lowercase().as_str() {
            "round-robin" | "rr" => Some(RoutePolicy::RoundRobin),
            "least-loaded" | "least" => Some(RoutePolicy::LeastLoaded),
            _ => None,
        }
    }
}

/// What work a replica accepts in a disaggregated topology.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub enum ReplicaRole {
    /// Admits and prefills requests, then hands them to a decode replica
    /// (decoding locally only as a graceful fallback when no decode
    /// replica can take the sequence).
    Prefill,
    /// Never admits requests; receives prefilled sequences via migration
    /// and decodes them to completion.
    Decode,
    /// Both — the symmetric pre-role behavior, and the pinned baseline.
    #[default]
    Mixed,
}

impl ReplicaRole {
    /// Parse a CLI spelling (`p`/`prefill`, `d`/`decode`, `m`/`mixed`).
    pub fn parse(s: &str) -> Option<Self> {
        match s.to_ascii_lowercase().as_str() {
            "p" | "prefill" => Some(ReplicaRole::Prefill),
            "d" | "decode" => Some(ReplicaRole::Decode),
            "m" | "mixed" => Some(ReplicaRole::Mixed),
            _ => None,
        }
    }

    /// May admission routing hand this replica a fresh request?
    pub fn accepts_prefill(self) -> bool {
        !matches!(self, ReplicaRole::Decode)
    }

    /// May a decoding (post-prefill) sequence land here?
    pub fn accepts_decode(self) -> bool {
        !matches!(self, ReplicaRole::Prefill)
    }

    pub fn label(self) -> &'static str {
        match self {
            ReplicaRole::Prefill => "prefill",
            ReplicaRole::Decode => "decode",
            ReplicaRole::Mixed => "mixed",
        }
    }
}

/// A registered replica.
#[derive(Debug, Clone)]
pub struct Replica {
    pub name: String,
    pub precision: PrecisionConfig,
    pub role: ReplicaRole,
    /// Outstanding prefill-side work in tokens: prompt budgets of
    /// in-flight requests, plus re-prefill charges from requantizing
    /// imports ([`Router::charge_reprefill`]).
    outstanding_prefill: u64,
    /// Outstanding decode-side work in tokens (max_new budgets).
    outstanding_decode: u64,
}

impl Replica {
    /// Outstanding token budget (load the router steers by).
    pub fn outstanding(&self) -> u64 {
        self.outstanding_prefill + self.outstanding_decode
    }

    /// The prefill component of [`Replica::outstanding`].
    pub fn outstanding_prefill(&self) -> u64 {
        self.outstanding_prefill
    }

    /// The decode component of [`Replica::outstanding`] — what the
    /// cluster steers prefill→decode handoffs by.
    pub fn outstanding_decode(&self) -> u64 {
        self.outstanding_decode
    }
}

/// The router: owns replica bookkeeping, returns an index per request.
pub struct Router {
    replicas: Vec<Replica>,
    policy: RoutePolicy,
    rr_next: usize,
    /// request → (replica index, prefill budget, decode budget) so
    /// completions — and migrations — move the right components.
    inflight: HashMap<RequestId, (usize, u64, u64)>,
    pub routed: u64,
    pub completed: u64,
    /// In-flight requests transferred between replicas by the rebalancer.
    pub migrated: u64,
}

impl Router {
    pub fn new(policy: RoutePolicy) -> Self {
        Self {
            replicas: Vec::new(),
            policy,
            rr_next: 0,
            inflight: HashMap::new(),
            routed: 0,
            completed: 0,
            migrated: 0,
        }
    }

    pub fn add_replica(
        &mut self,
        name: impl Into<String>,
        precision: PrecisionConfig,
        role: ReplicaRole,
    ) -> usize {
        self.replicas.push(Replica {
            name: name.into(),
            precision,
            role,
            outstanding_prefill: 0,
            outstanding_decode: 0,
        });
        self.replicas.len() - 1
    }

    pub fn replicas(&self) -> &[Replica] {
        &self.replicas
    }

    pub fn policy(&self) -> RoutePolicy {
        self.policy
    }

    /// Replicas able to admit a fresh request: exact precision match AND
    /// a prefill-capable role (every admitted request starts with a
    /// prefill; decode-only replicas receive work via migration only).
    fn candidates(&self, precision: Option<PrecisionConfig>) -> Vec<usize> {
        self.replicas
            .iter()
            .enumerate()
            .filter(|(_, r)| {
                r.role.accepts_prefill()
                    && precision.map(|p| r.precision == p).unwrap_or(true)
            })
            .map(|(i, _)| i)
            .collect()
    }

    /// Route a request (optionally pinned to a precision).  Returns the
    /// replica index, or None if no candidate exists.
    pub fn route(&mut self, req: &Request, precision: Option<PrecisionConfig>) -> Option<usize> {
        let cands = self.candidates(precision);
        if cands.is_empty() {
            return None;
        }
        let idx = match self.policy {
            RoutePolicy::RoundRobin => {
                // advance rr cursor to the next candidate
                let pos = cands.iter().position(|&c| c >= self.rr_next % self.replicas.len());
                let pick = cands[pos.unwrap_or(0) % cands.len()];
                self.rr_next = pick + 1;
                pick
            }
            RoutePolicy::LeastLoaded => *cands
                .iter()
                .min_by_key(|&&c| (self.replicas[c].outstanding(), c))
                .unwrap(),
        };
        let prefill = req.prompt.len() as u64;
        let decode = req.params.max_new_tokens as u64;
        self.replicas[idx].outstanding_prefill += prefill;
        self.replicas[idx].outstanding_decode += decode;
        self.inflight.insert(req.id, (idx, prefill, decode));
        self.routed += 1;
        Some(idx)
    }

    /// Transfer an in-flight request's load accounting to replica `to`
    /// (cross-replica migration of a swapped sequence).  The full
    /// original budget moves — conservative, since the remaining work is
    /// unknowable mid-stream — so conservation holds and the eventual
    /// completion drains the target.  Returns the source replica, or
    /// None if the request isn't in flight (never routed, or already
    /// completed).  A self-migration is a no-op.
    pub fn migrate(&mut self, id: RequestId, to: usize) -> Option<usize> {
        let (from, prefill, decode) = *self.inflight.get(&id)?;
        if from == to {
            return Some(from);
        }
        assert!(to < self.replicas.len(), "migrate to unknown replica {to}");
        self.replicas[from].outstanding_prefill =
            self.replicas[from].outstanding_prefill.saturating_sub(prefill);
        self.replicas[from].outstanding_decode =
            self.replicas[from].outstanding_decode.saturating_sub(decode);
        self.replicas[to].outstanding_prefill += prefill;
        self.replicas[to].outstanding_decode += decode;
        self.inflight.insert(id, (to, prefill, decode));
        self.migrated += 1;
        Some(from)
    }

    /// Charge a requantizing migration's re-prefill to the importing
    /// replica: the importer must teacher-force `tokens` (prompt +
    /// generated so far) before the sequence can resume, and that work
    /// was invisible to placement before this accounting existed.  The
    /// charge grows both the in-flight record and the replica's prefill
    /// load, so the conservation law is untouched and the eventual
    /// completion drains exactly what was charged.  No-op for requests
    /// not in flight.
    pub fn charge_reprefill(&mut self, id: RequestId, tokens: u64) {
        if let Some((idx, prefill, _)) = self.inflight.get_mut(&id) {
            *prefill += tokens;
            self.replicas[*idx].outstanding_prefill += tokens;
        }
    }

    /// Mark a routed request finished; releases its load accounting.
    pub fn complete(&mut self, id: RequestId) -> Option<usize> {
        let (idx, prefill, decode) = self.inflight.remove(&id)?;
        self.replicas[idx].outstanding_prefill =
            self.replicas[idx].outstanding_prefill.saturating_sub(prefill);
        self.replicas[idx].outstanding_decode =
            self.replicas[idx].outstanding_decode.saturating_sub(decode);
        self.completed += 1;
        Some(idx)
    }

    pub fn inflight(&self) -> usize {
        self.inflight.len()
    }

    /// Conservation check: Σ outstanding == Σ inflight budgets, on each
    /// component of the prefill/decode load split independently.
    pub fn check_invariants(&self) -> Result<(), String> {
        let tracked_p: u64 = self.inflight.values().map(|(_, p, _)| p).sum();
        let tracked_d: u64 = self.inflight.values().map(|(_, _, d)| d).sum();
        let held_p: u64 = self.replicas.iter().map(|r| r.outstanding_prefill).sum();
        let held_d: u64 = self.replicas.iter().map(|r| r.outstanding_decode).sum();
        if tracked_p != held_p {
            return Err(format!(
                "prefill load accounting drift: inflight {tracked_p} vs held {held_p}"
            ));
        }
        if tracked_d != held_d {
            return Err(format!(
                "decode load accounting drift: inflight {tracked_d} vs held {held_d}"
            ));
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::request::GenParams;
    use crate::util::proptest::forall;

    fn req(id: u64, plen: usize, mnew: usize) -> Request {
        Request::new(
            id,
            vec![1; plen],
            GenParams { max_new_tokens: mnew, sample: false, seed: id },
        )
    }

    fn router3(policy: RoutePolicy) -> Router {
        let mut r = Router::new(policy);
        r.add_replica("r0", PrecisionConfig::W2A2, ReplicaRole::Mixed);
        r.add_replica("r1", PrecisionConfig::W2A2, ReplicaRole::Mixed);
        r.add_replica("r2", PrecisionConfig::W1A1, ReplicaRole::Mixed);
        r
    }

    #[test]
    fn round_robin_cycles() {
        let mut r = router3(RoutePolicy::RoundRobin);
        let picks: Vec<usize> =
            (0..6).map(|i| r.route(&req(i, 4, 4), None).unwrap()).collect();
        assert_eq!(picks, vec![0, 1, 2, 0, 1, 2]);
    }

    #[test]
    fn precision_pinning() {
        let mut r = router3(RoutePolicy::RoundRobin);
        for i in 0..4 {
            let idx = r.route(&req(i, 4, 4), Some(PrecisionConfig::W1A1)).unwrap();
            assert_eq!(idx, 2, "only r2 serves W1A1");
        }
        assert!(r.route(&req(99, 4, 4), Some(PrecisionConfig::W8A8)).is_none());
    }

    #[test]
    fn least_loaded_balances() {
        let mut r = router3(RoutePolicy::LeastLoaded);
        // heavy request to r0 (it's least-loaded first, ties break by index)
        let a = r.route(&req(0, 100, 100), None).unwrap();
        assert_eq!(a, 0);
        // next requests avoid the loaded replica
        let b = r.route(&req(1, 4, 4), None).unwrap();
        let c = r.route(&req(2, 4, 4), None).unwrap();
        assert_ne!(b, 0);
        assert_ne!(c, 0);
        assert_ne!(b, c, "spread across the two idle replicas");
        // completion releases the load
        r.complete(RequestId(0)).unwrap();
        r.check_invariants().unwrap();
        let d = r.route(&req(3, 4, 4), None).unwrap();
        assert_eq!(d, 0, "r0 is idle again");
    }

    #[test]
    fn complete_unknown_is_none() {
        let mut r = router3(RoutePolicy::RoundRobin);
        assert!(r.complete(RequestId(42)).is_none());
    }

    #[test]
    fn migrate_transfers_load_conservatively() {
        let mut r = router3(RoutePolicy::RoundRobin);
        let rq = req(0, 10, 6); // budget 16
        let from = r.route(&rq, None).unwrap();
        assert_eq!(r.replicas()[from].outstanding(), 16);
        let to = (from + 1) % 3;
        assert_eq!(r.migrate(rq.id, to), Some(from));
        assert_eq!(r.replicas()[from].outstanding(), 0, "source drained");
        assert_eq!(r.replicas()[to].outstanding(), 16, "full budget moved");
        assert_eq!(r.migrated, 1);
        r.check_invariants().unwrap();
        // completion now drains the TARGET, not the source
        r.complete(rq.id).unwrap();
        assert_eq!(r.replicas()[to].outstanding(), 0);
        r.check_invariants().unwrap();
        // unknown / self migrations are harmless
        assert!(r.migrate(RequestId(42), 0).is_none());
        let rq2 = req(1, 4, 4);
        let at = r.route(&rq2, None).unwrap();
        assert_eq!(r.migrate(rq2.id, at), Some(at), "self-migration is a no-op");
        assert_eq!(r.migrated, 1, "no-op not counted");
        r.check_invariants().unwrap();
    }

    #[test]
    fn prop_conservation() {
        forall(48, |rng| {
            let policy =
                if rng.bool() { RoutePolicy::RoundRobin } else { RoutePolicy::LeastLoaded };
            let mut r = Router::new(policy);
            let n_rep = rng.usize(1, 5);
            for i in 0..n_rep {
                r.add_replica(format!("r{i}"), PrecisionConfig::W2A2, ReplicaRole::Mixed);
            }
            let mut live: Vec<RequestId> = Vec::new();
            let mut next = 0u64;
            for _ in 0..rng.usize(5, 80) {
                match rng.u32(0, 4) {
                    0 if !live.is_empty() => {
                        // migration must conserve load accounting too
                        let id = live[rng.usize(0, live.len())];
                        r.migrate(id, rng.usize(0, n_rep)).unwrap();
                    }
                    3 if !live.is_empty() => {
                        // a re-prefill charge must conserve too
                        let id = live[rng.usize(0, live.len())];
                        r.charge_reprefill(id, rng.usize(1, 48) as u64);
                    }
                    1 if !live.is_empty() => {
                        let i = rng.usize(0, live.len());
                        let id = live.swap_remove(i);
                        r.complete(id).unwrap();
                    }
                    _ => {
                        let rq = req(next, rng.usize(1, 32), rng.usize(1, 32));
                        if r.route(&rq, None).is_some() {
                            live.push(rq.id);
                        }
                        next += 1;
                    }
                }
                r.check_invariants().unwrap_or_else(|e| panic!("{e}"));
            }
            for id in live {
                r.complete(id).unwrap();
            }
            assert_eq!(r.inflight(), 0);
            assert!(r.replicas().iter().all(|rep| rep.outstanding() == 0));
        });
    }

    #[test]
    fn decode_only_replicas_never_receive_admissions() {
        for policy in [RoutePolicy::RoundRobin, RoutePolicy::LeastLoaded] {
            let mut r = Router::new(policy);
            r.add_replica("p", PrecisionConfig::W2A2, ReplicaRole::Prefill);
            r.add_replica("d", PrecisionConfig::W2A2, ReplicaRole::Decode);
            r.add_replica("m", PrecisionConfig::W2A2, ReplicaRole::Mixed);
            for i in 0..8u64 {
                let idx = r.route(&req(i, 4, 4), None).unwrap();
                assert_ne!(idx, 1, "decode-only replica admitted a fresh request");
            }
            r.check_invariants().unwrap();
        }
        // a decode-only topology has no admission candidates at all
        let mut r = Router::new(RoutePolicy::LeastLoaded);
        r.add_replica("d", PrecisionConfig::W2A2, ReplicaRole::Decode);
        assert!(r.route(&req(0, 4, 4), None).is_none());
    }

    #[test]
    fn reprefill_charge_lands_on_the_importer_and_conserves() {
        let mut r = router3(RoutePolicy::RoundRobin);
        let rq = req(0, 10, 6); // prefill 10, decode 6
        let from = r.route(&rq, None).unwrap();
        assert_eq!(r.replicas()[from].outstanding_prefill(), 10);
        assert_eq!(r.replicas()[from].outstanding_decode(), 6);
        let to = (from + 1) % 3;
        r.migrate(rq.id, to).unwrap();
        // a requantizing import re-prefills prompt + generated (say 12
        // tokens): the importer's prefill load must grow by exactly that
        r.charge_reprefill(rq.id, 12);
        assert_eq!(r.replicas()[to].outstanding_prefill(), 22);
        assert_eq!(r.replicas()[to].outstanding_decode(), 6);
        assert_eq!(r.replicas()[from].outstanding(), 0);
        r.check_invariants().unwrap();
        // completion drains the grown budget, not the original
        r.complete(rq.id).unwrap();
        assert_eq!(r.replicas()[to].outstanding(), 0);
        r.check_invariants().unwrap();
        // charging an unknown request is a harmless no-op
        r.charge_reprefill(RequestId(99), 7);
        r.check_invariants().unwrap();
    }
}
