//! Continuous-batching decode engine — the serving loop that finally
//! composes the coordinator's pieces end to end (Orca/vLLM-style
//! iteration-level scheduling, per PAPERS.md):
//!
//! * the [`Batcher`] shapes raw arrivals into admission groups (flushed
//!   early whenever the engine is otherwise idle);
//! * the [`KvPool`] owns per-sequence block tables, growing them one
//!   token at a time as sequences decode;
//! * each [`Engine::step`] runs **one batched decode over whatever is
//!   resident** — sequences join and leave the batch every step instead
//!   of waiting for a group to drain;
//! * logits come from the backend's pack-once pipeline
//!   ([`SimBackend::with_ap_gemm`](super::backend::SimBackend::with_ap_gemm)
//!   routes them through the `PackedWeightStore`/`PackArena` prepacked
//!   kernel path), so the §3.4 memory-management story is exercised under
//!   real churn.
//!
//! ## The step loop
//!
//! 1. **Arrivals** — poll the batcher; released groups enter the
//!    admission queue (FIFO).
//! 2. **Swap-in** — preempted sequences re-acquire KV blocks and rejoin
//!    the batch, oldest first, before any new admission.
//! 3. **Admission + prefill** — while a decode slot and the *prompt's*
//!    KV blocks are free, pop the queue, prefill (batch-1) and emit the
//!    first token.  Only the prompt is reserved up front — unlike the
//!    group scheduler, decode-time KV is claimed incrementally, which is
//!    what lets more sequences share the pool (and what makes preemption
//!    reachable).
//! 4. **Decode** — every resident sequence first grows its block table by
//!    one slot through the pool; an [`KvError::OutOfBlocks`] clean
//!    failure triggers **preemption** (below).  Survivors then advance
//!    one token in a single batched backend call.
//! 5. **Completion** — finished sequences release their blocks and emit
//!    a [`Response`].  (Completion also runs *before* decode so freshly
//!    finished sequences free blocks for the current step.)
//!
//! ## Preemption policy
//!
//! Swap-style, youngest-victim-first: when the pool cannot grow a
//! sequence, the most recently admitted *other* sequence is swapped out —
//! its (host-resident) [`SeqKv`] state is kept, its pool blocks are
//! released, and it joins a FIFO resume queue that has priority over new
//! admissions.  Submission rejects any request whose full
//! `prompt + max_new` stream exceeds the backend context window (no
//! silently truncated tails) or whose KV could never fit the pool alone,
//! the latter of which guarantees
//! the block-requester can always be satisfied after preempting — the
//! engine cannot deadlock, and every step a non-empty batch generates at
//! least one token, so it cannot livelock either.  Because resume keeps
//! the KV state and [`sample_token`] is seeded per (request, step),
//! preemption never changes a request's token stream.

use super::backend::{gather_kv_refs, Backend, HasSeqKv, SeqKv};
use super::batcher::{Batcher, BatcherConfig};
use super::kv::{KvError, KvPool};
use super::metrics::Metrics;
use super::request::{sample_token, Request, Response};
use super::server::Stepper;
use crate::anyhow::{bail, Result};
use std::collections::VecDeque;
use std::time::{Duration, Instant};

#[derive(Debug, Clone)]
pub struct EngineConfig {
    /// KV pool capacity in blocks.
    pub kv_blocks: usize,
    /// Tokens per KV block.
    pub block_tokens: usize,
    /// Max sequences decoding concurrently (clamped to the backend's
    /// largest supported batch).
    pub max_running: usize,
    /// Admission batcher (deadline + supported group sizes).
    pub batcher: BatcherConfig,
}

impl Default for EngineConfig {
    fn default() -> Self {
        Self {
            kv_blocks: 64,
            block_tokens: 16,
            max_running: 8,
            // zero deadline: groups release as soon as the engine polls —
            // iteration-level scheduling rarely wants to hold arrivals back
            batcher: BatcherConfig { batch_sizes: vec![1, 2, 4, 8], max_wait: Duration::ZERO },
        }
    }
}

/// Conservation/churn counters the integration tests assert on.
#[derive(Debug, Default, Clone, Copy)]
pub struct EngineCounters {
    pub submitted: u64,
    /// Requests dropped at submit (empty/oversized prompt, zero budget, or
    /// a KV footprint the pool could never hold).
    pub rejected: u64,
    pub prefills: u64,
    pub preemptions: u64,
    pub resumes: u64,
    pub completed: u64,
    pub steps: u64,
}

/// One resident (or swapped-out) sequence.
struct RunSeq {
    req: Request,
    kv: SeqKv,
    next_token: i32,
    generated: Vec<i32>,
    first_token_at: Instant,
    /// Admission order (monotone, assigned once at first admission and
    /// kept across preemption) — victim selection preempts the largest,
    /// so a just-resumed old sequence is never mistaken for the youngest.
    admitted_at: u64,
}

impl HasSeqKv for RunSeq {
    fn kv_mut(&mut self) -> &mut SeqKv {
        &mut self.kv
    }
}

/// The continuous-batching engine.  Single-threaded state machine — wrap
/// it in a [`Server`](super::server::Server) for the channel serve loop.
pub struct Engine<B: Backend> {
    backend: B,
    cfg: EngineConfig,
    pool: KvPool,
    batcher: Batcher,
    /// Admission queue (batcher-released groups, FIFO).
    wait: VecDeque<Request>,
    /// Resident sequences.  Mostly admission-ordered (resumes re-append
    /// at the back), so victim selection compares `admitted_at` rather
    /// than trusting positions.
    running: Vec<RunSeq>,
    /// Swapped-out sequences awaiting blocks, FIFO.
    swapped: VecDeque<RunSeq>,
    /// Monotone admission counter feeding `RunSeq::admitted_at`.
    admissions: u64,
    pub metrics: Metrics,
    counters: EngineCounters,
}

impl<B: Backend> Engine<B> {
    pub fn new(backend: B, cfg: EngineConfig) -> Self {
        let cap = cfg.max_running.min(*backend.supported_batches().last().unwrap()).max(1);
        let cfg = EngineConfig { max_running: cap, ..cfg };
        Self {
            pool: KvPool::new(cfg.kv_blocks, cfg.block_tokens),
            batcher: Batcher::new(cfg.batcher.clone()),
            backend,
            cfg,
            wait: VecDeque::new(),
            running: Vec::new(),
            swapped: VecDeque::new(),
            admissions: 0,
            metrics: Metrics::default(),
            counters: EngineCounters::default(),
        }
    }

    pub fn backend(&self) -> &B {
        &self.backend
    }

    pub fn pool(&self) -> &KvPool {
        &self.pool
    }

    pub fn counters(&self) -> EngineCounters {
        self.counters
    }

    pub fn queued(&self) -> usize {
        self.batcher.queued() + self.wait.len()
    }

    pub fn running(&self) -> usize {
        self.running.len()
    }

    pub fn swapped(&self) -> usize {
        self.swapped.len()
    }

    pub fn is_idle(&self) -> bool {
        self.batcher.queued() == 0
            && self.wait.is_empty()
            && self.running.is_empty()
            && self.swapped.is_empty()
    }

    /// Submit a request.  Requests that could never run to completion —
    /// empty or oversized prompt, zero token budget, a `prompt + max_new`
    /// stream exceeding the backend's context window, or a KV footprint
    /// exceeding the whole pool (the preemption progress guarantee needs
    /// one sequence to fit alone) — are rejected immediately and counted,
    /// never queued.  Rejecting up front keeps the engine's contract
    /// honest: an accepted request always gets its full `max_new` tokens,
    /// identical to the unbatched path, never a silently truncated tail.
    pub fn submit(&mut self, req: Request) {
        self.metrics.requests_in += 1;
        self.counters.submitted += 1;
        let budget = req.prompt.len() + req.params.max_new_tokens;
        if req.prompt.is_empty()
            || req.prompt.len() > self.backend.max_prompt()
            || req.params.max_new_tokens == 0
            || budget > self.backend.max_seq()
            || self.pool.blocks_for(budget) > self.pool.total_blocks()
        {
            self.counters.rejected += 1;
            self.metrics.requests_done += 1;
            return;
        }
        self.batcher.push(req);
    }

    /// Swap out the youngest resident sequence other than `keep`: its pool
    /// blocks are released (the KV data itself lives host-side in `SeqKv`)
    /// and it joins the resume queue.  Youth is judged by the original
    /// admission order, not the position in `running` — a resumed old
    /// sequence sits at the back of the vec but must not ping-pong
    /// straight back out.
    fn preempt_youngest_except(&mut self, keep: u64) -> Result<()> {
        let victim_idx = self
            .running
            .iter()
            .enumerate()
            .filter(|(_, s)| s.req.id.0 != keep)
            .max_by_key(|(_, s)| s.admitted_at)
            .map(|(i, _)| i);
        let Some(vi) = victim_idx else {
            // unreachable given the submit() capacity guard — a lone
            // sequence can always grow to its own prompt+max_new budget
            bail!("KV pool exhausted by a single sequence (pool smaller than one request)");
        };
        let victim = self.running.remove(vi);
        self.pool.release(victim.req.id.0)?;
        self.counters.preemptions += 1;
        self.metrics.preemptions += 1;
        self.swapped.push_back(victim);
        Ok(())
    }

    /// Move finished sequences out of the running set, releasing blocks.
    fn collect_finished(&mut self, done: &mut Vec<Response>) -> Result<()> {
        let mut i = 0;
        while i < self.running.len() {
            let finished = self.running[i].generated.len()
                >= self.running[i].req.params.max_new_tokens
                || self.running[i].kv.pos >= self.backend.max_seq();
            if !finished {
                i += 1;
                continue;
            }
            // Vec::remove, not swap_remove: keeps `running` (and thus the
            // decode batch) in a stable order; victim selection itself
            // goes by `admitted_at`, not position.
            let a = self.running.remove(i);
            self.pool.release(a.req.id.0)?;
            self.counters.completed += 1;
            self.metrics.requests_done += 1;
            let total = Instant::now().duration_since(a.req.arrived).as_secs_f64();
            self.metrics.total.record(total);
            done.push(Response {
                id: a.req.id,
                tokens: a.generated,
                queue_s: 0.0,
                total_s: total,
                ttft_s: a.first_token_at.duration_since(a.req.arrived).as_secs_f64(),
            });
        }
        Ok(())
    }

    /// One engine iteration (see the module docs for the five phases).
    /// Returns the responses completed this step.
    pub fn step(&mut self) -> Result<Vec<Response>> {
        let now = Instant::now();
        self.counters.steps += 1;

        // 1: arrivals — batcher groups flow into the admission queue; an
        // otherwise-empty engine flushes the batcher instead of idling
        // through its deadline.
        while let Some(group) = self.batcher.poll(now) {
            self.wait.extend(group);
        }
        if self.wait.is_empty() && self.running.is_empty() && self.swapped.is_empty() {
            self.wait.extend(self.batcher.flush());
        }

        // 2: swap-in — resume preempted sequences (FIFO) before admitting
        // anything new; they are older by definition.
        while self.running.len() < self.cfg.max_running {
            let Some(front) = self.swapped.front() else { break };
            let kv_tokens = front.kv.pos;
            if !self.pool.can_admit(kv_tokens) {
                break;
            }
            let seq = self.swapped.pop_front().unwrap();
            self.pool.admit(seq.req.id.0, kv_tokens)?;
            self.counters.resumes += 1;
            self.metrics.resumes += 1;
            self.running.push(seq);
        }

        // 3: admission + prefill — reserve only the prompt's KV; decode
        // growth is incremental (that is the continuous-batching bet).
        while self.swapped.is_empty() && self.running.len() < self.cfg.max_running {
            let Some(front) = self.wait.front() else { break };
            if !self.pool.can_admit(front.prompt.len()) {
                break; // head-of-line waits for memory
            }
            let req = self.wait.pop_front().unwrap();
            self.pool.admit(req.id.0, req.prompt.len())?;
            self.metrics.queue.record(now.duration_since(req.arrived).as_secs_f64());
            let (logits, kv) = match self.backend.prefill_one(&req.prompt) {
                Ok(r) => r,
                Err(e) => {
                    // a failed prefill must not strand the admission's
                    // blocks — release before surfacing the error
                    self.pool.release(req.id.0)?;
                    return Err(e);
                }
            };
            self.counters.prefills += 1;
            let tok = sample_token(&logits, &req.params, 0);
            let first_token_at = Instant::now();
            self.metrics.ttft.record(first_token_at.duration_since(req.arrived).as_secs_f64());
            self.metrics.tokens_generated += 1;
            let admitted_at = self.admissions;
            self.admissions += 1;
            self.running.push(RunSeq {
                req,
                kv,
                next_token: tok,
                generated: vec![tok],
                first_token_at,
                admitted_at,
            });
        }

        let mut done = Vec::new();
        // early completion: a prefill can satisfy max_new == 1 outright,
        // and freshly freed blocks should help the decode below
        self.collect_finished(&mut done)?;

        // 4: decode — secure one KV slot per participant (preempting on
        // the allocator's clean failure), then one batched call.
        let mut ids: Vec<u64> = self.running.iter().map(|s| s.req.id.0).collect();
        let mut i = 0;
        while i < ids.len() {
            let id = ids[i];
            if !self.running.iter().any(|s| s.req.id.0 == id) {
                // was preempted as a victim below: drop from this batch
                // (its pool table — including any slot it secured this
                // step — was released wholesale; resume re-admits at the
                // sequence's true KV length)
                ids.remove(i);
                continue;
            }
            match self.pool.append_token(id) {
                Ok(()) => i += 1,
                Err(KvError::OutOfBlocks { .. }) => self.preempt_youngest_except(id)?,
                Err(e) => return Err(e.into()),
            }
        }
        if !ids.is_empty() {
            let idx: Vec<usize> = self
                .running
                .iter()
                .enumerate()
                .filter(|(_, s)| ids.contains(&s.req.id.0))
                .map(|(i, _)| i)
                .collect();
            let tokens: Vec<i32> = idx.iter().map(|&i| self.running[i].next_token).collect();
            let mut kv_refs = gather_kv_refs(&mut self.running, &idx);
            let logits = self.backend.decode_batch(&tokens, &mut kv_refs)?;
            self.metrics.groups_executed += 1;
            self.metrics.batch_occupancy_sum += idx.len() as u64;
            for (j, &i) in idx.iter().enumerate() {
                let step = self.running[i].generated.len();
                let tok = sample_token(&logits[j], &self.running[i].req.params, step);
                let a = &mut self.running[i];
                a.next_token = tok;
                a.generated.push(tok);
                self.metrics.tokens_generated += 1;
            }
        }

        // 5: completion
        self.collect_finished(&mut done)?;
        Ok(done)
    }

    /// Step until every submitted request completed; returns all responses.
    pub fn run_to_completion(&mut self) -> Result<Vec<Response>> {
        let mut out = Vec::new();
        self.metrics.start();
        while !self.is_idle() {
            out.extend(self.step()?);
        }
        self.metrics.finish();
        Ok(out)
    }
}

impl<B: Backend> Stepper for Engine<B> {
    fn submit(&mut self, r: Request) {
        Engine::submit(self, r);
    }

    fn step(&mut self) -> Result<Vec<Response>> {
        Engine::step(self)
    }

    fn is_idle(&self) -> bool {
        Engine::is_idle(self)
    }

    fn metrics(&self) -> &Metrics {
        &self.metrics
    }

    fn metrics_mut(&mut self) -> &mut Metrics {
        &mut self.metrics
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::backend::SimBackend;
    use crate::coordinator::request::GenParams;
    use crate::util::proptest::forall;

    fn cfg(kv_blocks: usize, block_tokens: usize, max_running: usize) -> EngineConfig {
        EngineConfig { kv_blocks, block_tokens, max_running, ..EngineConfig::default() }
    }

    fn req(id: u64, prompt_len: usize, max_new: usize) -> Request {
        Request::new(
            id,
            (1..=prompt_len as i32).collect(),
            GenParams { max_new_tokens: max_new, sample: false, seed: id },
        )
    }

    /// Unbatched ground truth: the same request driven alone, straight
    /// against a backend with identical construction parameters.
    fn reference(backend: &mut SimBackend, prompt: &[i32], params: &GenParams) -> Vec<i32> {
        super::super::backend::drive_unbatched(backend, prompt, params).unwrap()
    }

    #[test]
    fn single_request_generates_exactly_max_new() {
        let mut e = Engine::new(SimBackend::new(64, 64, vec![1, 2, 4, 8]), cfg(64, 8, 4));
        e.submit(req(1, 5, 7));
        let out = e.run_to_completion().unwrap();
        assert_eq!(out.len(), 1);
        assert_eq!(out[0].tokens.len(), 7);
        assert_eq!(e.pool().free_blocks(), 64, "all blocks returned");
        assert_eq!(e.counters().completed, 1);
    }

    #[test]
    fn sequences_join_and_leave_mid_flight() {
        // iteration-level scheduling: short and long requests share steps
        let mut e = Engine::new(SimBackend::new(64, 64, vec![1, 2, 4, 8]), cfg(64, 8, 8));
        e.submit(req(0, 2, 2));
        e.submit(req(1, 3, 12));
        e.submit(req(2, 4, 1));
        let mut out = e.run_to_completion().unwrap();
        out.sort_by_key(|r| r.id);
        assert_eq!(out[0].tokens.len(), 2);
        assert_eq!(out[1].tokens.len(), 12);
        assert_eq!(out[2].tokens.len(), 1);
        // the long request kept decoding after the short ones left
        assert!(e.metrics.groups_executed >= 11);
    }

    #[test]
    fn preemption_swaps_out_and_resumes_correctly() {
        // pool: 4 blocks × 4 tokens.  Two requests of budget 16 tokens
        // (4 blocks) each — both admit on their 8-token prompts (2 blocks
        // each), then decode growth exhausts the pool and the younger one
        // must be swapped out and finish later.
        let mut plain = SimBackend::new(64, 64, vec![1, 2, 4, 8]);
        let want_a = reference(&mut plain, &req(0, 8, 8).prompt, &req(0, 8, 8).params);
        let want_b = reference(&mut plain, &req(1, 8, 8).prompt, &req(1, 8, 8).params);

        let mut e = Engine::new(SimBackend::new(64, 64, vec![1, 2, 4, 8]), cfg(4, 4, 4));
        e.submit(req(0, 8, 8));
        e.submit(req(1, 8, 8));
        let mut out = e.run_to_completion().unwrap();
        out.sort_by_key(|r| r.id);
        assert_eq!(out.len(), 2);
        assert_eq!(out[0].tokens, want_a, "preemption must not change tokens");
        assert_eq!(out[1].tokens, want_b);
        let c = e.counters();
        assert!(c.preemptions >= 1, "pool pressure must trigger preemption");
        assert_eq!(c.resumes, c.preemptions, "every swap-out swapped back in");
        assert_eq!(e.pool().free_blocks(), 4, "no leaked blocks");
        e.pool().check_invariants().unwrap();
    }

    #[test]
    fn rejects_what_can_never_run() {
        let mut e = Engine::new(SimBackend::new(64, 64, vec![1, 2]), cfg(2, 4, 2));
        e.submit(req(0, 0, 4)); // empty prompt
        e.submit(req(1, 33, 4)); // over max_prompt (32)
        e.submit(req(2, 4, 0)); // zero budget
        e.submit(req(3, 6, 8)); // 14 tokens > 2×4 pool capacity
        e.submit(req(4, 3, 4)); // fits
        let out = e.run_to_completion().unwrap();
        assert_eq!(out.len(), 1);
        assert_eq!(out[0].id.0, 4);
        assert_eq!(e.counters().rejected, 4);
        assert_eq!(e.metrics.requests_done, 5, "rejects are accounted");

        // context-window guard, with a pool big enough that capacity is
        // not the binding constraint: 20 + 60 > max_seq 64 must reject
        // up front rather than return a silently truncated stream
        let mut e2 = Engine::new(SimBackend::new(64, 64, vec![1, 2]), cfg(64, 4, 2));
        e2.submit(req(0, 20, 60));
        assert_eq!(e2.counters().rejected, 1);
        e2.submit(req(1, 20, 44)); // exactly max_seq: runs to completion
        let out = e2.run_to_completion().unwrap();
        assert_eq!(out.len(), 1);
        assert_eq!(out[0].tokens.len(), 44);
    }

    #[test]
    fn deterministic_across_runs() {
        let run = || {
            let mut e = Engine::new(SimBackend::new(64, 64, vec![1, 2, 4, 8]), cfg(8, 4, 4));
            for i in 0..6 {
                e.submit(req(i, 3 + i as usize % 4, 6));
            }
            let mut out = e.run_to_completion().unwrap();
            out.sort_by_key(|r| r.id);
            out.iter().map(|r| r.tokens.clone()).collect::<Vec<_>>()
        };
        assert_eq!(run(), run());
    }

    #[test]
    fn batch_composition_does_not_change_tokens() {
        // the core continuous-batching correctness claim: whatever the
        // admission interleaving, each request's stream matches the
        // unbatched reference
        let mut plain = SimBackend::new(64, 64, vec![1, 2, 4, 8]);
        let reqs: Vec<Request> = (0..10)
            .map(|i| req(i, 1 + (i as usize * 3) % 9, 1 + (i as usize * 5) % 11))
            .collect();
        let want: Vec<Vec<i32>> =
            reqs.iter().map(|r| reference(&mut plain, &r.prompt, &r.params)).collect();
        for (kv_blocks, max_running) in [(64, 8), (6, 3), (5, 8)] {
            let backend = SimBackend::new(64, 64, vec![1, 2, 4, 8]);
            let mut e = Engine::new(backend, cfg(kv_blocks, 4, max_running));
            for r in &reqs {
                e.submit(r.clone());
            }
            let mut out = e.run_to_completion().unwrap();
            out.sort_by_key(|r| r.id);
            assert_eq!(out.len(), reqs.len());
            for (r, w) in out.iter().zip(&want) {
                assert_eq!(&r.tokens, w, "req {} under pool={kv_blocks}", r.id.0);
            }
            assert_eq!(e.pool().free_blocks(), kv_blocks);
        }
    }

    #[test]
    fn prop_kv_churn_conserves_blocks() {
        // the KvPool + engine churn property: random admit/decode/finish/
        // preempt interleavings hold used+free == total and never
        // double-own a block, checked after EVERY step
        forall(24, |rng| {
            let block_tokens = rng.usize(2, 6);
            let kv_blocks = rng.usize(3, 16);
            let max_running = rng.usize(1, 9);
            let mut e = Engine::new(
                SimBackend::new(32, 128, vec![1, 2, 4, 8]),
                cfg(kv_blocks, block_tokens, max_running),
            );
            let n = rng.usize(1, 20);
            let mut pending: Vec<Request> = (0..n)
                .map(|i| req(i as u64, rng.usize(1, 12), rng.usize(1, 10)))
                .collect();
            let mut out = Vec::new();
            while !pending.is_empty() || !e.is_idle() {
                // interleave arrivals with steps
                for _ in 0..rng.usize(0, 3).min(pending.len()) {
                    e.submit(pending.remove(0));
                }
                out.extend(e.step().unwrap());
                e.pool().check_invariants().unwrap_or_else(|err| panic!("invariant: {err}"));
                assert_eq!(
                    e.pool().used_blocks() + e.pool().free_blocks(),
                    e.pool().total_blocks()
                );
            }
            assert_eq!(e.pool().free_blocks(), kv_blocks, "drained pool leaks nothing");
            let c = e.counters();
            assert_eq!(c.completed + c.rejected, c.submitted, "every request resolves");
            assert_eq!(out.len() as u64, c.completed);
            assert_eq!(c.resumes, c.preemptions);
        });
    }
}
