//! Continuous-batching decode engine — the serving loop that composes
//! the coordinator's pieces end to end (Orca/vLLM-style iteration-level
//! scheduling, per PAPERS.md):
//!
//! * the [`Batcher`] shapes raw arrivals into admission groups (flushed
//!   early whenever the engine is otherwise idle);
//! * the [`KvPool`] owns per-sequence block tables — admission maps
//!   shared prompt prefixes onto refcounted cached blocks
//!   ([`KvPool::admit_shared`]), decode grows tables one token at a time;
//! * each [`Engine::step`] runs **one batched decode over whatever is
//!   resident** — sequences join and leave the batch every step instead
//!   of waiting for a group to drain;
//! * every step **streams [`TokenEvent`]s**: admissions, each generated
//!   token, preempt/resume transitions, and terminal completions — the
//!   delivery path TTFT/ITL metrics are measured on;
//! * logits come from the backend's pack-once pipeline
//!   ([`SimBackend::with_ap_gemm`](super::backend::SimBackend::with_ap_gemm)
//!   routes them through the `PackedWeightStore`/`PackArena` prepacked
//!   kernel path), so the §3.4 memory-management story is exercised under
//!   real churn.
//!
//! ## The step loop
//!
//! 1. **Arrivals** — poll the batcher; released groups enter the
//!    admission queue (FIFO).
//! 2. **Swap-in** — preempted sequences re-acquire KV blocks (back
//!    through the prefix cache, so a resumed sequence re-shares what it
//!    shared before) and rejoin the batch, oldest first, before any new
//!    admission.
//! 3. **Admission + prefill** — while a decode slot and the *prompt's*
//!    KV blocks are free, pop the queue, prefill (batch-1) and stream the
//!    first token.  Only the prompt is reserved up front — and with the
//!    prefix cache, a prompt whose leading blocks are already resident
//!    admits without allocating them at all.
//! 4. **Decode** — every resident sequence first grows its block table by
//!    one slot through the pool; an [`KvError::OutOfBlocks`] clean
//!    failure triggers **preemption** (below).  Survivors then advance
//!    one token in a single batched backend call, streaming each token —
//!    or several tokens, when **speculative decoding** (below) drafted
//!    ahead and the verify rows agreed.  Under
//!    [`EngineConfig::prefill_hold`] (prefill-role replicas in a
//!    disaggregated cluster), sequences admitted *this step* sit decode
//!    out once, surfacing through [`Engine::prefilled_ready`] /
//!    [`Engine::export_running`] so the between-steps window can hand
//!    them to a decode replica; unexported holds expire next step.
//! 5. **Completion** — finished sequences release their block references
//!    and stream a terminal [`TokenEvent::Finished`].  (Under
//!    [`AdmissionPolicy::Optimistic`] completion also runs *before*
//!    decode so freshly finished sequences free blocks for the current
//!    step.)
//!
//! ## Admission policy
//!
//! [`EngineConfig::admission`] selects how admission books KV — the one
//! semantic that used to distinguish the legacy group scheduler from
//! this engine, folded in here when that scheduler was retired:
//!
//! * [`AdmissionPolicy::Optimistic`] (default) reserves only the
//!   prompt's blocks; decode grows the table one token at a time and
//!   preempts under pressure — continuous batching's overcommit bet.
//! * [`AdmissionPolicy::Reserve`] books the full `prompt + max_new`
//!   budget up front, so a running sequence can never hit
//!   out-of-blocks mid-generation and the engine **never preempts**
//!   (nor emits `Preempted`/`Resumed`); a head-of-line request waits
//!   until its whole stream is guaranteed to fit.  Streams are
//!   byte-identical to the retired scheduler's — completions scan by
//!   `swap_remove` strictly after decode — pinned by golden-fixture
//!   parity tests in both integration suites.  Speculation and prefix
//!   sharing are forced off ([`Engine::new`]): full-budget tables
//!   leave no optimistic slack to draft into and never match the
//!   prefix cache's content hashing.
//!
//! ## Preemption policy
//!
//! Swap-style, youngest-victim-first: when the pool cannot grow a
//! sequence, the most recently admitted *other* sequence is swapped out —
//! its (host-resident) [`SeqKv`] state is kept, its pool block references
//! are released (shared blocks stay resident for their other owners), and
//! it joins a FIFO resume queue that has priority over new admissions.
//! Submission rejects any request whose full `prompt + max_new` stream
//! exceeds the backend context window (no silently truncated tails) or
//! whose KV could never fit the pool alone, the latter of which
//! guarantees the block-requester can always be satisfied after
//! preempting — once every other sequence is swapped out, only the
//! requester's own references remain, so its next block is free; the
//! engine cannot deadlock, and every step a non-empty batch generates at
//! least one token, so it cannot livelock either.  Because resume keeps
//! the KV state and [`sample_token`] is seeded per (request, step),
//! preemption never changes a request's token stream.  Swapped sequences
//! report their retained token footprint
//! ([`Metrics::kv_swapped_tokens`]), so capacity planning can tell
//! resident from swapped KV.
//!
//! ## Cross-replica migration
//!
//! A swapped-out sequence is exactly the state a peer replica needs to
//! take the work over: [`Engine::export_swapped`] pops the **oldest**
//! swapped sequence as an [`ExportedSeq`] (request + host-resident KV +
//! generated tokens; sampling stays seeded per (request, step), so the
//! stream continues byte-identically wherever it resumes), and
//! [`Engine::import_swapped`] files it into the target's resume queue,
//! where the next step re-admits it through the target's prefix cache.
//! [`Engine::is_overloaded`] is the migration trigger (a swapped
//! sequence this engine cannot resume right now) and
//! [`Engine::import_fit`] the acceptance gate: one admission API
//! answering fits / needs-requant / rejected-with-reason from a
//! [`SwappedPeek`] (a free decode slot, no unresumed backlog, and KV
//! headroom for the content *and* the remaining budget — counting the
//! arrivals already queued ahead of it).  The
//! [`Cluster`](super::cluster::Cluster) drives the actual rebalancing
//! and streams [`TokenEvent::Migrated`] between the victim's `Preempted`
//! and the target's `Resumed`.
//!
//! Migration is no longer confined to same-precision peers: for a
//! **cross-precision** move the exporter calls
//! [`ExportedSeq::strip_kv_for_requant`] (the carried KV encodes the
//! source precision's activations and is useless elsewhere) and the
//! importing engine **re-prefills** the prompt + generated tokens at its
//! own precision during swap-in (queried via
//! [`SwappedPeek::as_requant`], [`Engine::import_fit`] additionally
//! gates on the content fitting the prompt window).  Streamed bytes
//! never change —
//! they are teacher-forced as context — and only subsequent tokens are
//! generated at the new precision; the cluster streams
//! [`TokenEvent::Requantized`] between `Migrated` and `Resumed` so the
//! client sees the switch.
//!
//! ## Speculative decoding (self-drafting from the plane-prefix store)
//!
//! With [`EngineConfig::spec_k`] > 0 the engine drafts ahead on the
//! *same weights it serves*: the backend slices the most-significant
//! [`EngineConfig::draft_bits`] planes out of its packed superset
//! ([`Backend::set_draft_bits`]) — a valid low-bit model of the same
//! weights, zero extra bytes — and each decode step every surviving
//! sequence
//!
//! 1. **drafts** up to `spec_k` tokens autoregressively with cheap
//!    single-row low-bit calls ([`Backend::draft_one`]), sampling each
//!    with the *same* seeded [`sample_token`] call the serving path
//!    would make at that step;
//! 2. **verifies** all `k + 1` positions in the ONE wide-precision
//!    [`Backend::decode_batch`] the plain path already makes — the extra
//!    verify rows ride alongside the other sequences' rows, bounded by
//!    the widest supported batch;
//! 3. **accepts** the longest prefix on which the wide model's sampled
//!    token agrees with the draft, emitting `accepted + 1` tokens (the
//!    first disagreeing verify token is itself correct output).
//!
//! Position 0's verify row is *exactly* the row plain decode would have
//! computed, and each accepted draft token reproduces the token the wide
//! model samples at that position — so by induction the emitted stream
//! is **byte-identical** to `spec_k = 0`: speculation changes how many
//! steps a stream takes, never its bytes.  Rejected positions roll back
//! cleanly: their KV slots were appended *optimistically* (speculative
//! growth never preempts a peer — an [`KvError::OutOfBlocks`] refusal
//! just caps the draft length) and [`KvPool::truncate_tokens`] returns
//! the unused tail, CoW and prefix-cache blocks included, so pool
//! invariants hold and a sequence swapped out or exported mid-flight
//! carries only accepted state.  Backends whose KV is device-resident
//! decline [`Backend::set_draft_bits`] and the engine silently falls
//! back to plain decode.

use super::backend::{gather_kv_refs, Backend, HasSeqKv, SeqKv};
use super::batcher::{Batcher, BatcherConfig};
use super::kv::{EvictionPolicy, KvError, KvPool};
use super::metrics::Metrics;
use super::request::{responses_of, sample_token, Request, RequestId, Response, TokenEvent};
use super::server::Stepper;
use crate::anyhow::{bail, Result};
use crate::model::PrecisionConfig;
use std::collections::VecDeque;
use std::time::{Duration, Instant};

/// Admission-time KV booking policy (see the module docs) — the one
/// semantic that used to distinguish the legacy group scheduler from
/// the engine.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub enum AdmissionPolicy {
    /// Reserve only the prompt's KV at admission; decode grows the
    /// block table per token and preempts the youngest resident on a
    /// clean out-of-blocks refusal.
    #[default]
    Optimistic,
    /// Reserve the full `prompt + max_new` budget at admission and
    /// never preempt; head-of-line requests wait until their whole
    /// stream fits.  The retired group scheduler's semantics, stream
    /// order included.
    Reserve,
}

#[derive(Debug, Clone)]
pub struct EngineConfig {
    /// KV pool capacity in blocks.
    pub kv_blocks: usize,
    /// Tokens per KV block.
    pub block_tokens: usize,
    /// Max sequences decoding concurrently (clamped to the backend's
    /// largest supported batch).
    pub max_running: usize,
    /// How admission books KV: [`AdmissionPolicy::Optimistic`] reserves
    /// the prompt only and grows per token (preempting under pressure);
    /// [`AdmissionPolicy::Reserve`] books the full `prompt + max_new`
    /// budget up front and never preempts.  Reserve forces
    /// [`EngineConfig::spec_k`] to 0 and
    /// [`EngineConfig::prefix_sharing`] off at construction.
    pub admission: AdmissionPolicy,
    /// Admission batcher (deadline + supported group sizes).
    pub batcher: BatcherConfig,
    /// Admit through the hash-based prefix cache (copy-on-write shared
    /// blocks).  Off = the PR 2 private-allocation baseline, kept so the
    /// serving bench can report the blocks sharing saves.
    pub prefix_sharing: bool,
    /// Which free block a fresh allocation evicts (LRU keeps hot prefix
    /// content cached; LIFO is the PR 3 baseline the bench compares).
    pub eviction: EvictionPolicy,
    /// Intra-replica GEMM worker budget, threaded to the backend at
    /// construction ([`Backend::set_workers`]).  `0` = the global
    /// [`crate::util::num_threads`] default.  Replicas with equal budgets
    /// share one worker pool process-wide (they step sequentially), so a
    /// cluster of N replicas × T workers never oversubscribes the host.
    pub workers: usize,
    /// Speculative decoding: tokens drafted ahead per sequence per decode
    /// step at the low-bit plane-prefix width (`0` = plain decode).
    /// Requires a backend that accepts [`Backend::set_draft_bits`];
    /// otherwise the engine silently falls back to plain decode (check
    /// [`Engine::spec_k`] for the width actually in effect).
    pub spec_k: usize,
    /// Draft precision in bit-planes — the most-significant prefix of the
    /// serving pack the drafter runs at.  Backends require
    /// `1 ≤ draft_bits < serving bits` (a strict subset; an equal-width
    /// "draft" would double the work for zero information).
    pub draft_bits: u32,
    /// Hold each freshly prefilled sequence out of the same step's decode
    /// phase, exposing it through [`Engine::prefilled_ready`] until the
    /// next step.  A disaggregated cluster sets this on prefill-role
    /// replicas so the between-steps window can hand the sequence to a
    /// decode replica ([`Engine::export_running`]); without the hold
    /// there is no post-step moment at which a just-prefilled sequence
    /// still sits exactly at its prompt boundary (phase 4 decodes
    /// same-step admissions).  A held sequence nobody exports simply
    /// decodes next step — the hold never strands a stream.
    pub prefill_hold: bool,
}

impl Default for EngineConfig {
    fn default() -> Self {
        Self {
            kv_blocks: 64,
            block_tokens: 16,
            max_running: 8,
            admission: AdmissionPolicy::Optimistic,
            // zero deadline: groups release as soon as the engine polls —
            // iteration-level scheduling rarely wants to hold arrivals back
            batcher: BatcherConfig { batch_sizes: vec![1, 2, 4, 8], max_wait: Duration::ZERO },
            prefix_sharing: true,
            eviction: EvictionPolicy::Lru,
            workers: 0,
            spec_k: 0,
            draft_bits: 0,
            prefill_hold: false,
        }
    }
}

/// Conservation/churn counters the integration tests assert on.
#[derive(Debug, Default, Clone, Copy)]
pub struct EngineCounters {
    pub submitted: u64,
    /// Requests dropped at submit (empty/oversized prompt, zero budget, or
    /// a KV footprint the pool could never hold).
    pub rejected: u64,
    pub prefills: u64,
    pub preemptions: u64,
    pub resumes: u64,
    pub completed: u64,
    pub steps: u64,
    /// Swapped sequences handed to a peer replica ([`Engine::export_swapped`]).
    pub exported: u64,
    /// Sequences taken over from a peer replica ([`Engine::import_swapped`]).
    pub imported: u64,
    /// Imported sequences whose KV was rebuilt here by re-prefilling the
    /// prompt + generated tokens at this replica's precision
    /// (cross-precision migration).
    pub reprefills: u64,
    /// Tokens drafted at the low-bit plane-prefix width (speculative
    /// decoding; zero when [`EngineConfig::spec_k`] is 0 or the backend
    /// declined to draft).
    pub drafted: u64,
    /// Drafted tokens the wide-precision verify pass accepted
    /// (`accepted / drafted` is the acceptance rate; each accepted token
    /// is one decode step the stream did not have to spend).
    pub accepted: u64,
}

/// One resident (or swapped-out) sequence.
struct RunSeq {
    req: Request,
    kv: SeqKv,
    next_token: i32,
    generated: Vec<i32>,
    first_token_at: Instant,
    /// When this sequence's previous token streamed (ITL measurement;
    /// spans swap-out time, so preemption is visible in the percentiles).
    last_token_at: Instant,
    /// KV content tokens, materialized once at preemption so the swap-in
    /// loop doesn't rebuild prompt+decoded every blocked step.  Invariant:
    /// `Some` for every entry on the swapped queue (preemption, failed
    /// resume re-park, and import all file it), `None` while resident.
    swap_content: Option<Vec<i32>>,
    /// Admission order (monotone, assigned once at first admission and
    /// kept across preemption) — victim selection preempts the largest,
    /// so a just-resumed old sequence is never mistaken for the youngest.
    admitted_at: u64,
    /// This sequence arrived by cross-precision migration with its KV
    /// dropped: the next swap-in must re-prefill `swap_content` at this
    /// replica's precision instead of trusting `kv`.
    needs_reprefill: bool,
    /// Freshly prefilled under [`EngineConfig::prefill_hold`]: sit out
    /// this step's decode phase so the cluster's between-steps window can
    /// hand the sequence to a decode replica.  Expires at the start of
    /// the next step's admission phase — a hold nobody acted on decodes
    /// normally.
    hold_decode: bool,
}

impl RunSeq {
    /// The tokens whose KV this sequence currently holds (prompt plus
    /// the decoded inputs) — what a prefix-cache re-admission hashes.
    fn kv_content(&self) -> Vec<i32> {
        let decoded = self.kv.pos - self.req.prompt.len();
        let mut c = self.req.prompt.clone();
        c.extend_from_slice(&self.generated[..decoded]);
        c
    }
}

impl HasSeqKv for RunSeq {
    fn kv_mut(&mut self) -> &mut SeqKv {
        &mut self.kv
    }
}

/// A swapped-out sequence packaged for **cross-replica migration**: the
/// request (prompt, sampling params, seed), every token generated so far,
/// the host-resident KV state, and the latency clocks — everything a peer
/// replica of the *same model* needs to continue the stream
/// byte-identically.  Produced by [`Engine::export_swapped`], consumed by
/// [`Engine::import_swapped`]; opaque to everything in between.
///
/// For a **cross-precision** move the carried KV is useless — it was
/// computed at the source's precision.  [`ExportedSeq::strip_kv_for_requant`]
/// drops it and marks the sequence for **re-prefill**: the importing
/// engine rebuilds the KV at its own precision by teacher-forcing the
/// prompt plus every already-streamed token, so streamed bytes never
/// change; only subsequent tokens are generated at the new precision.
pub struct ExportedSeq {
    pub(crate) req: Request,
    pub(crate) kv: SeqKv,
    pub(crate) next_token: i32,
    pub(crate) generated: Vec<i32>,
    pub(crate) first_token_at: Instant,
    pub(crate) last_token_at: Instant,
    /// KV content tokens (prompt + decoded inputs) — what the target's
    /// prefix-cache re-admission hashes, and what a re-prefill
    /// teacher-forces.
    pub(crate) swap_content: Vec<i32>,
    /// The carried KV was dropped; the importer must re-prefill
    /// `swap_content` at its own precision before resuming.
    pub(crate) reprefill: bool,
}

impl ExportedSeq {
    pub fn id(&self) -> RequestId {
        self.req.id
    }

    /// KV tokens the sequence carries (the target must admit this many).
    pub fn kv_tokens(&self) -> usize {
        self.swap_content.len()
    }

    /// Total token budget (prompt + max_new) — the capacity the target
    /// must eventually be able to hold.
    pub fn budget(&self) -> usize {
        self.req.prompt.len() + self.req.params.max_new_tokens
    }

    /// Prepare for a cross-precision migration: drop the carried
    /// [`SeqKv`] (it encodes the source precision's activations) and mark
    /// the sequence for re-prefill on the importing engine.  The token
    /// stream so far is untouched — it travels in `swap_content` and is
    /// teacher-forced verbatim.
    pub fn strip_kv_for_requant(&mut self) {
        self.kv = SeqKv { k: Vec::new(), v: Vec::new(), pos: 0 };
        self.reprefill = true;
    }

    /// Will the importer re-prefill instead of reusing carried KV?
    pub fn needs_reprefill(&self) -> bool {
        self.reprefill
    }
}

/// What [`Engine::peek_swapped`] (or [`Engine::peek_prefilled`], for a
/// disaggregated prefill→decode handoff) exposes about a migratable
/// sequence: everything a cluster's rebalancer needs to pick a target
/// without exporting anything yet.  Borrows the engine — peeking a
/// sequence every step must not clone its token content.
#[derive(Debug, Clone, Copy)]
pub struct SwappedPeek<'a> {
    pub id: RequestId,
    /// KV content tokens (prompt + decoded inputs) the target must admit
    /// — and re-prefill, if the move crosses a precision boundary.
    pub content: &'a [i32],
    /// Total token budget (prompt + max_new) the target must eventually
    /// be able to hold.
    pub budget: usize,
    /// The request's precision pin, if any — pinned requests never take
    /// the cross-precision path.
    pub pinned: Option<PrecisionConfig>,
    /// The sequence's KV was already stripped by an earlier
    /// cross-precision hop and it has not re-prefilled yet: ANY further
    /// target (same precision included) must pass the re-prefill gate
    /// in [`Engine::import_fit`].
    pub reprefill_pending: bool,
}

impl<'a> SwappedPeek<'a> {
    /// The same peek viewed as a **cross-precision** arrival: the cluster
    /// queries [`Engine::import_fit`] with this when the move it is
    /// considering would strip the carried KV, so the target answers for
    /// the re-prefill path (content through its prompt window) instead of
    /// a plain KV adoption.
    pub fn as_requant(&self) -> SwappedPeek<'a> {
        SwappedPeek { reprefill_pending: true, ..*self }
    }
}

/// Verdict of [`Engine::import_fit`] — the one admission API a cluster
/// consults before moving a sequence here.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum ImportFit {
    /// Admissible as-is: the carried KV state swaps straight in.
    Fits,
    /// Admissible, but this engine must **re-prefill** the content at its
    /// own precision before resuming (the peek's KV was — or would be —
    /// stripped for the move).
    NeedsRequant,
    /// Not admissible right now; the message names the failed gate (for
    /// rebalancer diagnostics and tests).
    Rejected(&'static str),
}

impl ImportFit {
    /// Either [`ImportFit::Fits`] or [`ImportFit::NeedsRequant`] — the
    /// target can take the sequence.
    pub fn admissible(self) -> bool {
        !matches!(self, ImportFit::Rejected(_))
    }
}

/// The continuous-batching engine.  Single-threaded state machine — wrap
/// it in a [`Server`](super::server::Server) for the channel serve loop,
/// or several of them in a [`Cluster`](super::cluster::Cluster).
pub struct Engine<B: Backend> {
    backend: B,
    cfg: EngineConfig,
    pool: KvPool,
    batcher: Batcher,
    /// Admission queue (batcher-released groups, FIFO).
    wait: VecDeque<Request>,
    /// Resident sequences.  Mostly admission-ordered (resumes re-append
    /// at the back), so victim selection compares `admitted_at` rather
    /// than trusting positions.
    running: Vec<RunSeq>,
    /// Swapped-out sequences awaiting blocks, FIFO.
    swapped: VecDeque<RunSeq>,
    /// Monotone admission counter feeding `RunSeq::admitted_at`.
    admissions: u64,
    /// Recorded by the last step: the swap-in phase failed for blocks,
    /// or a preemption proved the pool dry.  [`Engine::is_overloaded`]
    /// reads this instead of re-hashing the swapped content per call.
    resume_blocked: bool,
    /// Events produced outside `step` (submit-time rejections), drained
    /// into the next step's stream.
    pending_events: Vec<TokenEvent>,
    pub metrics: Metrics,
    counters: EngineCounters,
}

impl<B: Backend> Engine<B> {
    pub fn new(mut backend: B, cfg: EngineConfig) -> Self {
        let cap = cfg.max_running.min(*backend.supported_batches().last().unwrap()).max(1);
        let mut cfg = EngineConfig { max_running: cap, ..cfg };
        if cfg.admission == AdmissionPolicy::Reserve {
            // full-budget reservation leaves no optimistic slack to
            // draft into, and a whole-budget table never matches the
            // prefix cache's content hashing — plain private
            // reservations, plain decode: the retired group scheduler's
            // exact serving contract
            cfg.spec_k = 0;
            cfg.prefix_sharing = false;
        }
        if cfg.spec_k > 0 && !backend.set_draft_bits(cfg.draft_bits) {
            // the backend cannot draft at this width (no plane store to
            // slice, a non-subset width, or device-resident KV that
            // cannot roll back): plain decode, byte-identical anyway
            cfg.spec_k = 0;
        }
        backend.set_workers(cfg.workers);
        Self {
            pool: KvPool::with_policy(cfg.kv_blocks, cfg.block_tokens, cfg.eviction),
            batcher: Batcher::new(cfg.batcher.clone()),
            backend,
            cfg,
            wait: VecDeque::new(),
            running: Vec::new(),
            swapped: VecDeque::new(),
            admissions: 0,
            resume_blocked: false,
            pending_events: Vec::new(),
            metrics: Metrics::default(),
            counters: EngineCounters::default(),
        }
    }

    pub fn backend(&self) -> &B {
        &self.backend
    }

    /// Re-budget this replica's GEMM worker pool (`0` = global default) —
    /// the cluster splits a host-wide budget across replicas with this.
    pub fn set_workers(&mut self, workers: usize) {
        self.cfg.workers = workers;
        self.backend.set_workers(workers);
    }

    pub fn pool(&self) -> &KvPool {
        &self.pool
    }

    pub fn counters(&self) -> EngineCounters {
        self.counters
    }

    /// Draft length actually in effect: [`EngineConfig::spec_k`], or `0`
    /// when the backend declined [`Backend::set_draft_bits`] at
    /// construction and the engine fell back to plain decode.
    pub fn spec_k(&self) -> usize {
        self.cfg.spec_k
    }

    pub fn queued(&self) -> usize {
        self.batcher.queued() + self.wait.len()
    }

    pub fn running(&self) -> usize {
        self.running.len()
    }

    pub fn swapped(&self) -> usize {
        self.swapped.len()
    }

    /// KV tokens retained host-side by swapped-out sequences.
    pub fn swapped_tokens(&self) -> usize {
        self.swapped.iter().map(|s| s.kv.pos).sum()
    }

    /// Could the pool admit `content` right now, respecting the
    /// configured sharing mode?
    fn pool_can_admit(&self, content: &[i32]) -> bool {
        if self.cfg.prefix_sharing {
            self.pool.can_admit_shared(content)
        } else {
            self.pool.can_admit(content.len())
        }
    }

    /// The oldest swapped sequence's migration-relevant state — what a
    /// target must be able to admit ([`Engine::import_fit`]) and what
    /// decides whether a cross-precision fallback is even allowed (a
    /// pinned request is a contract: it never requantizes).
    pub fn peek_swapped(&self) -> Option<SwappedPeek<'_>> {
        self.swapped.front().map(|s| SwappedPeek {
            id: s.req.id,
            // invariant: every producer of swapped-queue entries
            // (preemption, failed resume re-park, import) files the
            // content — see `swap_content`'s field docs
            content: s.swap_content.as_deref().expect("swapped entries retain their KV content"),
            budget: s.req.prompt.len() + s.req.params.max_new_tokens,
            pinned: s.req.precision,
            reprefill_pending: s.needs_reprefill,
        })
    }

    /// Migration trigger: this engine holds a swapped sequence it could
    /// not resume — the step's own swap-in attempt failed for blocks, a
    /// preemption just proved the pool dry, or every decode slot is
    /// taken.  Reads the step's recorded outcome instead of re-hashing
    /// the swapped content on every call; conservatively eager (a
    /// completion later in the same step may have freed blocks), which
    /// at worst migrates a sequence one step before it could have
    /// resumed locally — the stream is identical either way.
    pub fn is_overloaded(&self) -> bool {
        !self.swapped.is_empty()
            && (self.running.len() >= self.cfg.max_running || self.resume_blocked)
    }

    /// KV blocks the already-queued swapped sequences will claim when
    /// they swap back in — headroom an import must leave untouched, or
    /// the newcomer starves the arrivals queued ahead of it.
    fn swapped_block_demand(&self) -> usize {
        self.swapped
            .iter()
            .map(|s| {
                let len = s.swap_content.as_ref().map_or(s.kv.pos, |c| c.len());
                self.pool.blocks_for(len)
            })
            .sum()
    }

    /// Acceptance gate for a migrated sequence — the ONE admission API a
    /// cluster consults, answering fits / needs-requant /
    /// rejected-with-reason for the peeked sequence.  Admissible means: a
    /// decode slot will be free once the resume queue drains (so several
    /// handoffs may target one replica in the same between-steps window),
    /// this engine's own backlog is not stuck, the full `budget`
    /// (prompt + max_new) fits the context window and the pool alone (the
    /// no-deadlock guarantee carries over to imports), and there is KV
    /// headroom for the content *beyond* what the arrivals already queued
    /// ahead of it will claim.  A `reprefill_pending` peek (or a
    /// [`SwappedPeek::as_requant`] view of one the caller intends to
    /// strip) additionally needs the content to fit the prompt window —
    /// admissible then means [`ImportFit::NeedsRequant`].
    pub fn import_fit(&self, peek: &SwappedPeek<'_>) -> ImportFit {
        if self.is_overloaded() {
            return ImportFit::Rejected("target's own swapped backlog is stuck");
        }
        if self.running.len() + self.swapped.len() >= self.cfg.max_running {
            return ImportFit::Rejected("no decode slot free (running + queued arrivals)");
        }
        if peek.budget > self.backend.max_seq() {
            return ImportFit::Rejected("budget exceeds the context window");
        }
        if self.pool.blocks_for(peek.budget) > self.pool.total_blocks() {
            return ImportFit::Rejected("budget exceeds the whole pool");
        }
        if !self.pool_can_admit(peek.content) {
            return ImportFit::Rejected("no KV headroom for the carried content");
        }
        // with arrivals already queued, their swap-in demand comes first;
        // conservative (prefix sharing could stretch the pool further),
        // which at worst delays this move one step
        let queued = self.swapped_block_demand();
        if queued > 0
            && self.pool.blocks_for(peek.content.len()) + queued > self.pool.free_blocks()
        {
            return ImportFit::Rejected("KV headroom already promised to queued arrivals");
        }
        if peek.reprefill_pending {
            if peek.content.len() > self.backend.max_prompt() {
                return ImportFit::Rejected("re-prefill content exceeds the prompt window");
            }
            return ImportFit::NeedsRequant;
        }
        ImportFit::Fits
    }

    /// Pop the **oldest** swapped sequence for migration to a peer
    /// replica.  Its `Preempted` event already streamed; the importer's
    /// next step streams `Resumed` and the token stream continues
    /// exactly where it paused ([`sample_token`] is seeded per
    /// (request, step), and the KV state travels with it).
    pub fn export_swapped(&mut self) -> Option<ExportedSeq> {
        let mut s = self.swapped.pop_front()?;
        if self.swapped.is_empty() {
            // hygiene: keep the flag describing the live backlog.  Not
            // observable through `is_overloaded` (it ANDs with a
            // non-empty queue) — the load-bearing clear for the
            // rebalancer ping-pong is the one in `import_swapped`.
            self.resume_blocked = false;
        }
        self.counters.exported += 1;
        let swap_content =
            s.swap_content.take().expect("swapped entries retain their KV content");
        Some(ExportedSeq {
            req: s.req,
            kv: s.kv,
            next_token: s.next_token,
            generated: s.generated,
            first_token_at: s.first_token_at,
            last_token_at: s.last_token_at,
            swap_content,
            // a pending re-prefill travels with the sequence: its KV is
            // already stripped, and whoever finally resumes it must
            // rebuild the state whatever path it took to get there
            reprefill: s.needs_reprefill,
        })
    }

    /// File a migrated sequence into this engine's resume queue; the
    /// next step re-admits it through the prefix cache (so a migrated
    /// shared prefix hits the target's cache) and streams `Resumed` —
    /// after re-prefilling the content at this replica's precision if the
    /// exporter stripped the KV ([`ExportedSeq::strip_kv_for_requant`]).
    /// Counts as a fresh admission for victim selection — an import must
    /// not displace this replica's own older residents.
    pub fn import_swapped(&mut self, seq: ExportedSeq) {
        // [`Engine::import_fit`] rejected overloaded targets, so at
        // import time either the swapped queue was empty (any recorded
        // resume-blocked outcome described a backlog that has since
        // drained) or it is non-empty with the flag already false; the
        // newcomer itself has not attempted a resume yet.  Without this
        // clear, an idle engine that last blocked long ago would
        // advertise overload the moment it imports — and the rebalancer
        // would bounce the sequence straight back out.
        self.resume_blocked = false;
        self.counters.imported += 1;
        let admitted_at = self.admissions;
        self.admissions += 1;
        self.swapped.push_back(RunSeq {
            req: seq.req,
            kv: seq.kv,
            next_token: seq.next_token,
            generated: seq.generated,
            first_token_at: seq.first_token_at,
            last_token_at: seq.last_token_at,
            swap_content: Some(seq.swap_content),
            admitted_at,
            needs_reprefill: seq.reprefill,
            hold_decode: false,
        });
    }

    /// Sequences whose prefill completed THIS step and are being held out
    /// of decode under [`EngineConfig::prefill_hold`] — each sits exactly
    /// at its prompt boundary (one streamed token, KV = the prompt).  A
    /// disaggregated cluster polls this between steps and hands each to a
    /// decode replica via [`Engine::export_running`]; holds nobody acts
    /// on expire next step and the sequences decode locally.
    pub fn prefilled_ready(&self) -> Vec<RequestId> {
        self.running.iter().filter(|s| s.hold_decode).map(|s| s.req.id).collect()
    }

    /// Migration-relevant state of a held just-prefilled sequence (see
    /// [`Engine::prefilled_ready`]).  Its KV holds exactly the prompt, so
    /// the peek borrows the request's prompt — no content is rebuilt.
    pub fn peek_prefilled(&self, id: RequestId) -> Option<SwappedPeek<'_>> {
        self.running.iter().find(|s| s.req.id == id && s.hold_decode).map(|s| SwappedPeek {
            id: s.req.id,
            content: &s.req.prompt,
            budget: s.req.prompt.len() + s.req.params.max_new_tokens,
            pinned: s.req.precision,
            reprefill_pending: s.needs_reprefill,
        })
    }

    /// Pop a held just-prefilled **running** sequence for a
    /// prefill→decode handoff — the disaggregated analogue of
    /// [`Engine::export_swapped`].  Its first token already streamed and
    /// the move is voluntary (no KV pressure), so no `Preempted` is
    /// involved: the cluster streams `PrefillDone` + `Migrated`, and the
    /// importer's `Resumed` continues the stream byte-identically.
    pub fn export_running(&mut self, id: RequestId) -> Option<ExportedSeq> {
        let i = self.running.iter().position(|s| s.req.id == id && s.hold_decode)?;
        let mut s = self.running.remove(i);
        // release fails only on a bookkeeping bug — the id is resident
        self.pool.release(s.req.id.0).expect("resident sequence owns a pool table");
        self.counters.exported += 1;
        s.hold_decode = false;
        let swap_content = s.kv_content();
        Some(ExportedSeq {
            req: s.req,
            kv: s.kv,
            next_token: s.next_token,
            generated: s.generated,
            first_token_at: s.first_token_at,
            last_token_at: s.last_token_at,
            swap_content,
            reprefill: s.needs_reprefill,
        })
    }

    pub fn is_idle(&self) -> bool {
        self.batcher.queued() == 0
            && self.wait.is_empty()
            && self.running.is_empty()
            && self.swapped.is_empty()
            && self.pending_events.is_empty()
    }

    /// Submit a request.  Requests that could never run to completion —
    /// empty or oversized prompt, zero token budget, a `prompt + max_new`
    /// stream exceeding the backend's context window, or a KV footprint
    /// exceeding the whole pool (the preemption progress guarantee needs
    /// one sequence to fit alone) — are rejected immediately and resolve
    /// with a terminal empty-stream [`TokenEvent::Finished`] on the next
    /// step.  Rejecting up front keeps the engine's contract honest: an
    /// accepted request always gets its full `max_new` tokens, identical
    /// to the unbatched path, never a silently truncated tail.
    pub fn submit(&mut self, req: Request) {
        self.metrics.requests_in += 1;
        self.counters.submitted += 1;
        let budget = req.prompt.len() + req.params.max_new_tokens;
        if req.prompt.is_empty()
            || req.prompt.len() > self.backend.max_prompt()
            || req.params.max_new_tokens == 0
            || budget > self.backend.max_seq()
            || self.pool.blocks_for(budget) > self.pool.total_blocks()
        {
            self.counters.rejected += 1;
            self.metrics.requests_done += 1;
            self.pending_events
                .push(TokenEvent::Finished { id: req.id, response: Response::rejected(req.id) });
            return;
        }
        self.batcher.push(req);
    }

    /// Admit a sequence's KV — through the prefix cache when sharing is
    /// on, privately otherwise.  `content` is the tokens the KV holds
    /// (the prompt at first admission, prompt+decoded at resume).  Fails
    /// without side effects, so admission loops simply try and break on
    /// the allocator's clean refusal.
    fn pool_admit(&mut self, seq: u64, content: &[i32]) -> Result<(), KvError> {
        if self.cfg.prefix_sharing {
            self.pool.admit_shared(seq, content)
        } else {
            self.pool.admit(seq, content.len())
        }
    }

    /// Admit a fresh sequence's KV according to the admission policy:
    /// the prompt only (optimistic growth, may preempt later) or the
    /// full `prompt + max_new` budget up front (never preempts).  Fails
    /// without side effects either way.
    fn admit_new(&mut self, req: &Request) -> Result<(), KvError> {
        match self.cfg.admission {
            AdmissionPolicy::Optimistic => self.pool_admit(req.id.0, &req.prompt),
            AdmissionPolicy::Reserve => {
                self.pool.admit(req.id.0, req.prompt.len() + req.params.max_new_tokens)
            }
        }
    }

    /// Swap out the youngest resident sequence other than `keep`: its pool
    /// block references are released (the KV data itself lives host-side
    /// in `SeqKv`; shared blocks stay resident for their other owners)
    /// and it joins the resume queue.  Youth is judged by the original
    /// admission order, not the position in `running` — a resumed old
    /// sequence sits at the back of the vec but must not ping-pong
    /// straight back out.
    fn preempt_youngest_except(
        &mut self,
        keep: u64,
        events: &mut Vec<TokenEvent>,
    ) -> Result<()> {
        let victim_idx = self
            .running
            .iter()
            .enumerate()
            .filter(|(_, s)| s.req.id.0 != keep)
            .max_by_key(|(_, s)| s.admitted_at)
            .map(|(i, _)| i);
        let Some(vi) = victim_idx else {
            // unreachable given the submit() capacity guard — a lone
            // sequence can always grow to its own prompt+max_new budget
            bail!("KV pool exhausted by a single sequence (pool smaller than one request)");
        };
        let mut victim = self.running.remove(vi);
        victim.swap_content = Some(victim.kv_content());
        self.pool.release(victim.req.id.0)?;
        self.counters.preemptions += 1;
        self.metrics.preemptions += 1;
        // the pool just proved dry — flag the victim as locally
        // unresumable so a cluster can rebalance it this very step
        self.resume_blocked = true;
        events.push(TokenEvent::Preempted { id: victim.req.id });
        self.swapped.push_back(victim);
        Ok(())
    }

    /// Move finished sequences out of the running set, releasing blocks
    /// and streaming their terminal events.
    fn collect_finished(&mut self, events: &mut Vec<TokenEvent>) -> Result<()> {
        let mut i = 0;
        while i < self.running.len() {
            let finished = self.running[i].generated.len()
                >= self.running[i].req.params.max_new_tokens
                || self.running[i].kv.pos >= self.backend.max_seq();
            if !finished {
                i += 1;
                continue;
            }
            let a = match self.cfg.admission {
                // Vec::remove keeps `running` (and thus the decode
                // batch) in a stable order; victim selection itself
                // goes by `admitted_at`, not position.
                AdmissionPolicy::Optimistic => self.running.remove(i),
                // swap_remove replays the retired scheduler's completion
                // scan exactly — the scrambled order it leaves behind
                // shapes subsequent decode interleaving, which the
                // Reserve parity fixtures pin byte-for-byte.
                AdmissionPolicy::Reserve => self.running.swap_remove(i),
            };
            self.pool.release(a.req.id.0)?;
            self.counters.completed += 1;
            self.metrics.requests_done += 1;
            let total = Instant::now().duration_since(a.req.arrived).as_secs_f64();
            self.metrics.total.record(total);
            events.push(TokenEvent::Finished {
                id: a.req.id,
                response: Response {
                    id: a.req.id,
                    tokens: a.generated,
                    queue_s: 0.0,
                    total_s: total,
                    ttft_s: a.first_token_at.duration_since(a.req.arrived).as_secs_f64(),
                },
            });
        }
        Ok(())
    }

    /// Refresh the resident/swapped KV footprint and prefix-cache gauges.
    fn note_kv_footprint(&mut self) {
        self.metrics.kv_resident_tokens =
            self.running.iter().map(|s| s.kv.pos as u64).sum();
        self.metrics.kv_swapped_tokens = self.swapped_tokens() as u64;
        self.metrics.kv_swapped_peak =
            self.metrics.kv_swapped_peak.max(self.metrics.kv_swapped_tokens);
        let sh = self.pool.sharing();
        self.metrics.prefix_hits = sh.shared_live + sh.cache_restores;
        self.metrics.prefix_logical = sh.logical_blocks();
        self.metrics.prefix_evictions = sh.evictions;
    }

    /// One engine iteration (see the module docs for the five phases).
    /// Returns the events produced this step, in order.
    pub fn step(&mut self) -> Result<Vec<TokenEvent>> {
        let now = Instant::now();
        self.counters.steps += 1;
        let mut events = std::mem::take(&mut self.pending_events);

        // 1: arrivals — batcher groups flow into the admission queue; an
        // otherwise-empty engine flushes the batcher instead of idling
        // through its deadline.
        while let Some(group) = self.batcher.poll(now) {
            self.wait.extend(group);
        }
        if self.wait.is_empty() && self.running.is_empty() && self.swapped.is_empty() {
            self.wait.extend(self.batcher.flush());
        }

        // 2: swap-in — resume preempted sequences (FIFO) before admitting
        // anything new; they are older by definition.  Resume goes back
        // through the prefix cache: an identical prefix another sequence
        // kept resident is re-shared instead of re-allocated.  The
        // blocked/unblocked outcome is recorded for `is_overloaded`.
        self.resume_blocked = false;
        while self.running.len() < self.cfg.max_running {
            let Some(mut seq) = self.swapped.pop_front() else { break };
            let content = seq.swap_content.take().unwrap_or_else(|| seq.kv_content());
            let admitted = match self.cfg.admission {
                AdmissionPolicy::Optimistic => self.pool_admit(seq.req.id.0, &content),
                // an import re-books the full remaining budget, so the
                // never-preempt invariant holds for the rest of the
                // stream (content.len() ≤ budget always)
                AdmissionPolicy::Reserve => self
                    .pool
                    .admit(seq.req.id.0, seq.req.prompt.len() + seq.req.params.max_new_tokens),
            };
            match admitted {
                Ok(()) => {
                    if seq.needs_reprefill {
                        // cross-precision arrival: the carried KV was
                        // dropped at export, so rebuild it at THIS
                        // replica's precision by teacher-forcing the
                        // prompt + already-streamed tokens.  The prefill
                        // logits are discarded — the token at this
                        // position already streamed from the source and
                        // must keep its bytes; decode continues from it.
                        match self.backend.prefill_one(&content) {
                            Ok((_logits, kv)) => {
                                debug_assert_eq!(kv.pos, content.len());
                                seq.kv = kv;
                                seq.needs_reprefill = false;
                                self.counters.reprefills += 1;
                                self.metrics.reprefills += 1;
                            }
                            Err(e) => {
                                // don't strand the admission's blocks on
                                // a failed re-prefill
                                self.pool.release(seq.req.id.0)?;
                                return Err(e);
                            }
                        }
                    }
                    self.counters.resumes += 1;
                    self.metrics.resumes += 1;
                    events.push(TokenEvent::Resumed { id: seq.req.id });
                    self.running.push(seq);
                }
                Err(e) => {
                    // still blocked (or an engine bug): park it back at
                    // the head, content retained for the next attempt
                    seq.swap_content = Some(content);
                    self.swapped.push_front(seq);
                    match e {
                        KvError::OutOfBlocks { .. } => {
                            self.resume_blocked = true;
                            break;
                        }
                        other => return Err(other.into()),
                    }
                }
            }
        }

        // holds from the previous step expire here: the cluster had its
        // between-steps window to export them, and whoever is still
        // resident decodes this step (also scrubs any stale flag a
        // preempted-then-resumed sequence carried back in).
        for s in &mut self.running {
            s.hold_decode = false;
        }

        // 3: admission + prefill — reserve only the prompt's KV; decode
        // growth is incremental (that is the continuous-batching bet).
        while self.swapped.is_empty() && self.running.len() < self.cfg.max_running {
            let Some(req) = self.wait.pop_front() else { break };
            if let Err(e) = self.admit_new(&req) {
                // head-of-line waits for memory (admit has no side
                // effects on refusal)
                self.wait.push_front(req);
                match e {
                    KvError::OutOfBlocks { .. } => break,
                    other => return Err(other.into()),
                }
            }
            self.metrics.queue.record(now.duration_since(req.arrived).as_secs_f64());
            events.push(TokenEvent::Admitted { id: req.id });
            let (logits, kv) = match self.backend.prefill_one(&req.prompt) {
                Ok(r) => r,
                Err(e) => {
                    // a failed prefill must not strand the admission's
                    // blocks — release before surfacing the error
                    self.pool.release(req.id.0)?;
                    return Err(e);
                }
            };
            self.counters.prefills += 1;
            let tok = sample_token(&logits, &req.params, 0);
            let first_token_at = Instant::now();
            self.metrics.ttft.record(first_token_at.duration_since(req.arrived).as_secs_f64());
            self.metrics.tokens_generated += 1;
            events.push(TokenEvent::Token { id: req.id, token: tok, step: 0 });
            let admitted_at = self.admissions;
            self.admissions += 1;
            self.running.push(RunSeq {
                req,
                kv,
                next_token: tok,
                generated: vec![tok],
                first_token_at,
                last_token_at: first_token_at,
                swap_content: None,
                admitted_at,
                needs_reprefill: false,
                hold_decode: self.cfg.prefill_hold,
            });
        }

        // early completion: a prefill can satisfy max_new == 1 outright,
        // and freshly freed blocks should help the decode below.
        // Reserve keeps the legacy single completion pass after decode —
        // completions streaming strictly last is part of its
        // byte-for-byte parity contract with the retired scheduler.
        if self.cfg.admission == AdmissionPolicy::Optimistic {
            self.collect_finished(&mut events)?;
        }

        // 4: decode — secure one KV slot per participant (preempting on
        // the allocator's clean failure), then one batched call.
        // Sequences under a prefill hold sit this phase out; the flag
        // survives to the between-steps window so the cluster can see
        // (and export) them, and expires above next step.
        // the budget filter is a no-op under Optimistic (the early
        // completion pass already removed satisfied sequences) but
        // load-bearing under Reserve, where a max_new == 1 prefill is
        // still resident here and must sit decode out
        let mut ids: Vec<u64> = self
            .running
            .iter()
            .filter(|s| !s.hold_decode && s.generated.len() < s.req.params.max_new_tokens)
            .map(|s| s.req.id.0)
            .collect();
        let mut i = 0;
        while i < ids.len() {
            let id = ids[i];
            if !self.running.iter().any(|s| s.req.id.0 == id) {
                // was preempted as a victim below: drop from this batch
                // (its pool table — including any slot it secured this
                // step — was released wholesale; resume re-admits at the
                // sequence's true KV length)
                ids.remove(i);
                continue;
            }
            match self.cfg.admission {
                // Reserve booked the full budget at admission: growth is
                // already paid for and preemption impossible
                AdmissionPolicy::Reserve => i += 1,
                AdmissionPolicy::Optimistic => match self.pool.append_token(id) {
                    Ok(()) => i += 1,
                    Err(KvError::OutOfBlocks { .. }) => {
                        self.preempt_youngest_except(id, &mut events)?
                    }
                    Err(e) => return Err(e.into()),
                },
            }
        }
        if !ids.is_empty() {
            let idx: Vec<usize> = self
                .running
                .iter()
                .enumerate()
                .filter(|(_, s)| ids.contains(&s.req.id.0))
                .map(|(i, _)| i)
                .collect();

            // speculation plan: how many draft positions each participant
            // verifies this step.  Bounded per sequence by the remaining
            // token budget (the step must not overshoot max_new), the
            // context window, the spare rows the widest supported decode
            // batch has left, and the pool's willingness to grow —
            // speculative appends NEVER preempt a peer; a clean
            // OutOfBlocks refusal just caps the draft length.
            let last_batch = *self.backend.supported_batches().last().unwrap();
            let mut spare = last_batch.saturating_sub(idx.len());
            let mut plan = vec![0usize; idx.len()];
            if self.cfg.spec_k > 0 {
                for (row, &i) in idx.iter().enumerate() {
                    let (id, budget_left, pos) = {
                        let s = &self.running[i];
                        (s.req.id.0, s.req.params.max_new_tokens - s.generated.len(), s.kv.pos)
                    };
                    let want = self
                        .cfg
                        .spec_k
                        .min(budget_left.saturating_sub(1))
                        .min((self.backend.max_seq() - 1).saturating_sub(pos))
                        .min(spare);
                    let mut got = 0;
                    while got < want {
                        match self.pool.append_token(id) {
                            Ok(()) => got += 1,
                            Err(KvError::OutOfBlocks { .. }) => break,
                            Err(e) => return Err(e.into()),
                        }
                    }
                    plan[row] = got;
                    spare -= got;
                }
            }

            // draft: chain cheap low-bit single-row forwards per sequence.
            // The draft sampler runs at the same (seed, step) pair the
            // verify sampler will use, so agreement is exact whenever the
            // two widths induce the same choice.
            let mut drafts: Vec<Vec<i32>> = Vec::with_capacity(idx.len());
            for (row, &i) in idx.iter().enumerate() {
                let k = plan[row];
                let mut d = Vec::with_capacity(k);
                let (mut prev, pos0, step0) = {
                    let s = &self.running[i];
                    (s.next_token, s.kv.pos, s.generated.len())
                };
                for j in 0..k {
                    let logits = self.backend.draft_one(prev, pos0 + j)?;
                    prev = sample_token(&logits, &self.running[i].req.params, step0 + j);
                    d.push(prev);
                }
                drafts.push(d);
            }

            // verify: ONE wide-precision batched call — the real rows
            // (advancing each sequence's own SeqKv) plus one provisional
            // row per drafted position, carried by position-preset clones
            // that are discarded after the call (only position-only KV
            // backends draft — the set_draft_bits contract — so a clone's
            // position IS its state).
            let mut all_tokens: Vec<i32> =
                idx.iter().map(|&i| self.running[i].next_token).collect();
            let mut spec_kvs: Vec<SeqKv> = Vec::new();
            let mut spec_offsets = vec![0usize; idx.len()];
            for (row, &i) in idx.iter().enumerate() {
                spec_offsets[row] = idx.len() + spec_kvs.len();
                let base = &self.running[i].kv;
                for (j, &d) in drafts[row].iter().enumerate() {
                    let mut kv = base.clone();
                    kv.pos = base.pos + 1 + j;
                    spec_kvs.push(kv);
                    all_tokens.push(d);
                }
            }
            let mut kv_refs = gather_kv_refs(&mut self.running, &idx);
            kv_refs.extend(spec_kvs.iter_mut());
            let logits = self.backend.decode_batch(&all_tokens, &mut kv_refs)?;
            drop(kv_refs);
            self.metrics.groups_executed += 1;
            self.metrics.batch_occupancy_sum += all_tokens.len() as u64;

            for (row, &i) in idx.iter().enumerate() {
                let k = plan[row];
                let step0 = self.running[i].generated.len();
                // longest agreeing prefix: position j's token comes from
                // the SAME seeded sampler call the plain path would make,
                // on the wide-width logits row — the bytes cannot change,
                // only the number of steps they take
                let mut emitted = Vec::with_capacity(k + 1);
                let mut j = 0;
                loop {
                    let lrow = if j == 0 {
                        &logits[row]
                    } else {
                        &logits[spec_offsets[row] + j - 1]
                    };
                    let tok = sample_token(lrow, &self.running[i].req.params, step0 + j);
                    emitted.push(tok);
                    if j < k && tok == drafts[row][j] {
                        j += 1;
                    } else {
                        break;
                    }
                }
                let e = emitted.len();
                if k > 0 {
                    self.counters.drafted += k as u64;
                    self.counters.accepted += (e - 1) as u64;
                    self.metrics.record_spec_step(k as u64, (e - 1) as u64);
                }
                // commit: decode_batch advanced the real row P → P+1; the
                // step consumed e KV positions in total but the pool gave
                // 1 + k slots — return the unused speculative tail
                let unused = (1 + k) - e;
                if unused > 0 {
                    self.pool.truncate_tokens(self.running[i].req.id.0, unused)?;
                }
                let a = &mut self.running[i];
                a.kv.pos = a.kv.pos - 1 + e;
                a.next_token = *emitted.last().unwrap();
                for (dj, &tok) in emitted.iter().enumerate() {
                    a.generated.push(tok);
                    let t = Instant::now();
                    self.metrics.itl.record(t.duration_since(a.last_token_at).as_secs_f64());
                    a.last_token_at = t;
                    self.metrics.tokens_generated += 1;
                    events.push(TokenEvent::Token { id: a.req.id, token: tok, step: step0 + dj });
                }
            }
        }

        // 5: completion
        self.collect_finished(&mut events)?;
        self.note_kv_footprint();
        Ok(events)
    }

    /// Step until every submitted request resolved; returns the terminal
    /// responses (rejected requests appear with empty token streams).
    pub fn run_to_completion(&mut self) -> Result<Vec<Response>> {
        Ok(responses_of(&self.run_to_completion_events()?))
    }

    /// Step until idle, returning the full event stream.
    pub fn run_to_completion_events(&mut self) -> Result<Vec<TokenEvent>> {
        self.metrics.start();
        let out = super::server::drain(self)?;
        self.metrics.finish();
        Ok(out)
    }
}

impl<B: Backend> Stepper for Engine<B> {
    fn submit(&mut self, r: Request) {
        Engine::submit(self, r);
    }

    fn step(&mut self) -> Result<Vec<TokenEvent>> {
        Engine::step(self)
    }

    fn is_idle(&self) -> bool {
        Engine::is_idle(self)
    }

    fn metrics(&self) -> Metrics {
        self.metrics.clone()
    }

    fn start_clock(&mut self) {
        self.metrics.start();
    }

    fn stop_clock(&mut self) {
        self.metrics.finish();
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::backend::SimBackend;
    use crate::coordinator::request::GenParams;
    use crate::util::proptest::forall;

    fn cfg(kv_blocks: usize, block_tokens: usize, max_running: usize) -> EngineConfig {
        EngineConfig { kv_blocks, block_tokens, max_running, ..EngineConfig::default() }
    }

    fn req(id: u64, prompt_len: usize, max_new: usize) -> Request {
        Request::new(
            id,
            (1..=prompt_len as i32).collect(),
            GenParams { max_new_tokens: max_new, sample: false, seed: id },
        )
    }

    /// Unbatched ground truth: the same request driven alone, straight
    /// against a backend with identical construction parameters.
    fn reference(backend: &mut SimBackend, prompt: &[i32], params: &GenParams) -> Vec<i32> {
        super::super::backend::drive_unbatched(backend, prompt, params).unwrap()
    }

    #[test]
    fn single_request_generates_exactly_max_new() {
        let mut e = Engine::new(SimBackend::new(64, 64, vec![1, 2, 4, 8]), cfg(64, 8, 4));
        e.submit(req(1, 5, 7));
        let out = e.run_to_completion().unwrap();
        assert_eq!(out.len(), 1);
        assert_eq!(out[0].tokens.len(), 7);
        assert_eq!(e.pool().free_blocks(), 64, "all blocks returned");
        assert_eq!(e.counters().completed, 1);
    }

    #[test]
    fn sequences_join_and_leave_mid_flight() {
        // iteration-level scheduling: short and long requests share steps
        let mut e = Engine::new(SimBackend::new(64, 64, vec![1, 2, 4, 8]), cfg(64, 8, 8));
        e.submit(req(0, 2, 2));
        e.submit(req(1, 3, 12));
        e.submit(req(2, 4, 1));
        let mut out = e.run_to_completion().unwrap();
        out.sort_by_key(|r| r.id);
        assert_eq!(out[0].tokens.len(), 2);
        assert_eq!(out[1].tokens.len(), 12);
        assert_eq!(out[2].tokens.len(), 1);
        // the long request kept decoding after the short ones left
        assert!(e.metrics.groups_executed >= 11);
    }

    #[test]
    fn streams_tokens_and_lifecycle_events_in_order() {
        let mut e = Engine::new(SimBackend::new(64, 64, vec![1, 2, 4, 8]), cfg(64, 8, 4));
        e.submit(req(0, 3, 5));
        let events = e.run_to_completion_events().unwrap();
        // exactly: Admitted, 5 Tokens with ascending steps, Finished
        assert!(matches!(events[0], TokenEvent::Admitted { id } if id.0 == 0));
        let toks: Vec<(i32, usize)> = events
            .iter()
            .filter_map(|ev| match ev {
                TokenEvent::Token { token, step, .. } => Some((*token, *step)),
                _ => None,
            })
            .collect();
        assert_eq!(toks.len(), 5);
        assert!(toks.iter().enumerate().all(|(i, &(_, st))| st == i));
        let resp = match events.last().unwrap() {
            TokenEvent::Finished { response, .. } => response.clone(),
            other => panic!("last event {other:?}"),
        };
        assert_eq!(resp.tokens, toks.iter().map(|&(t, _)| t).collect::<Vec<_>>());
        // per-token ITL: one gap per non-first token
        assert_eq!(e.metrics.itl.count(), 4);
        assert_eq!(e.metrics.ttft.count(), 1);
    }

    #[test]
    fn preemption_swaps_out_and_resumes_correctly() {
        // pool: 4 blocks × 4 tokens.  Two requests of budget 16 tokens
        // (4 blocks) each — both admit on their 8-token prompts (2 blocks
        // each), then decode growth exhausts the pool and the younger one
        // must be swapped out and finish later.  Sharing is OFF so the
        // identical prompts don't defuse the pressure.
        let mut plain = SimBackend::new(64, 64, vec![1, 2, 4, 8]);
        let want_a = reference(&mut plain, &req(0, 8, 8).prompt, &req(0, 8, 8).params);
        let want_b = reference(&mut plain, &req(1, 8, 8).prompt, &req(1, 8, 8).params);

        let mut e = Engine::new(
            SimBackend::new(64, 64, vec![1, 2, 4, 8]),
            EngineConfig { prefix_sharing: false, ..cfg(4, 4, 4) },
        );
        e.submit(req(0, 8, 8));
        e.submit(req(1, 8, 8));
        let events = e.run_to_completion_events().unwrap();
        let mut out = responses_of(&events);
        out.sort_by_key(|r| r.id);
        assert_eq!(out.len(), 2);
        assert_eq!(out[0].tokens, want_a, "preemption must not change tokens");
        assert_eq!(out[1].tokens, want_b);
        let c = e.counters();
        assert!(c.preemptions >= 1, "pool pressure must trigger preemption");
        assert_eq!(c.resumes, c.preemptions, "every swap-out swapped back in");
        assert_eq!(e.pool().free_blocks(), 4, "no leaked blocks");
        e.pool().check_invariants().unwrap();
        // the lifecycle is streamed: Preempted/Resumed pairs in order
        let preempts =
            events.iter().filter(|ev| matches!(ev, TokenEvent::Preempted { .. })).count();
        let resumes = events.iter().filter(|ev| matches!(ev, TokenEvent::Resumed { .. })).count();
        assert_eq!(preempts as u64, c.preemptions);
        assert_eq!(resumes as u64, c.resumes);
        // swapped footprint was visible while a sequence was out
        assert!(e.metrics.kv_swapped_peak >= 8, "peak {}", e.metrics.kv_swapped_peak);
        assert_eq!(e.metrics.kv_swapped_tokens, 0, "nothing swapped after drain");
    }

    #[test]
    fn exported_swapped_sequence_resumes_identically_on_a_peer_engine() {
        // the migration building block: force a swap-out on a tight
        // pool, export the swapped sequence, import it into an idle
        // identically-built peer, drain both — the migrated stream must
        // continue byte-identically to the unbatched oracle
        let mut plain = SimBackend::new(64, 64, vec![1, 2, 4, 8]);
        let want_a = reference(&mut plain, &req(0, 8, 8).prompt, &req(0, 8, 8).params);
        let want_b = reference(&mut plain, &req(1, 8, 8).prompt, &req(1, 8, 8).params);

        let mk = || {
            Engine::new(
                SimBackend::new(64, 64, vec![1, 2, 4, 8]),
                EngineConfig { prefix_sharing: false, ..cfg(4, 4, 4) },
            )
        };
        let mut src = mk();
        let mut dst = mk();
        src.submit(req(0, 8, 8));
        src.submit(req(1, 8, 8));
        let mut events = Vec::new();
        while src.swapped() == 0 {
            assert!(!src.is_idle(), "must preempt before draining");
            events.extend(src.step().unwrap());
        }
        assert!(src.is_overloaded(), "swapped seq can't resume on the full pool");
        let peek = src.peek_swapped().unwrap();
        assert_eq!(peek.budget, 16);
        assert_eq!(peek.pinned, None, "unpinned request");
        assert_eq!(dst.import_fit(&peek), ImportFit::Fits, "idle peer must accept");
        let (id, content_len) = (peek.id, peek.content.len());
        let exported = src.export_swapped().unwrap();
        assert_eq!(exported.id(), id);
        assert_eq!(exported.kv_tokens(), content_len);
        assert_eq!(exported.budget(), 16);
        dst.import_swapped(exported);
        assert_eq!(src.swapped(), 0);
        assert_eq!(dst.swapped(), 1);

        events.extend(src.run_to_completion_events().unwrap());
        events.extend(dst.run_to_completion_events().unwrap());
        let mut out = responses_of(&events);
        out.sort_by_key(|r| r.id);
        assert_eq!(out.len(), 2);
        assert_eq!(out[0].tokens, want_a, "source-resident stream unchanged");
        assert_eq!(out[1].tokens, want_b, "migrated stream identical to the oracle");
        // streamed Token events concatenate to the same streams
        for resp in &out {
            let streamed: Vec<i32> = events
                .iter()
                .filter_map(|ev| match ev {
                    TokenEvent::Token { id, token, .. } if *id == resp.id => Some(*token),
                    _ => None,
                })
                .collect();
            assert_eq!(streamed, resp.tokens, "stream ≠ response for {:?}", resp.id);
        }
        // accounting: export/import counters, resume on the peer only,
        // zero leaks on either pool
        assert_eq!(src.counters().exported, 1);
        assert_eq!(dst.counters().imported, 1);
        assert_eq!(src.counters().resumes, 0);
        assert_eq!(dst.counters().resumes, 1);
        assert_eq!(src.counters().completed, 1);
        assert_eq!(dst.counters().completed, 1);
        assert_eq!(src.pool().free_blocks(), 4, "source leaked blocks");
        assert_eq!(dst.pool().free_blocks(), 4, "target leaked blocks");
        src.pool().check_invariants().unwrap();
        dst.pool().check_invariants().unwrap();
    }

    #[test]
    fn overload_flag_drains_with_the_swapped_queue_and_never_bounces_imports() {
        // regression (rebalancer ping-pong): the per-step resume-blocked
        // flag must die with the backlog it described.  The stale-flag
        // window: an engine preempts (flag set), its swapped sequence is
        // exported the same step, and — with no step in between to clear
        // the flag — something is imported.  Without the clears in
        // export_swapped/import_swapped the engine advertises overload
        // for a sequence that never attempted a resume, and the cluster's
        // rebalance loop immediately re-exports it (the ping-pong).
        let mut hot = Engine::new(
            SimBackend::new(64, 64, vec![1, 2, 4, 8]),
            EngineConfig { prefix_sharing: false, ..cfg(4, 4, 4) },
        );
        hot.submit(req(0, 8, 8));
        hot.submit(req(1, 8, 8));
        while hot.swapped() == 0 {
            hot.step().unwrap();
        }
        assert!(hot.is_overloaded(), "blocked backlog must advertise overload");
        let exported = hot.export_swapped().unwrap();
        assert!(
            !hot.is_overloaded(),
            "drained engine must stop advertising overload without another step"
        );

        // hand the very same sequence back (as the rebalancer would when
        // a peer bounces it): no step has run on `hot` since its flag was
        // set, which is exactly the stale window
        hot.import_swapped(exported);
        assert_eq!(hot.swapped(), 1);
        assert!(
            !hot.is_overloaded(),
            "freshly imported sequence hasn't attempted a resume; stale flag must not count"
        );
        // and the engine still finishes everything cleanly
        let mut out = hot.run_to_completion().unwrap();
        out.sort_by_key(|r| r.id);
        assert_eq!(out.len(), 2);
        assert!(out.iter().all(|r| r.tokens.len() == 8));
        assert_eq!(hot.counters().exported, 1);
        assert_eq!(hot.counters().imported, 1);
        assert_eq!(hot.pool().free_blocks(), 4);
        hot.pool().check_invariants().unwrap();
    }

    #[test]
    fn stripped_export_reprefills_on_import_and_continues_the_stream() {
        // the cross-precision building block at engine level: strip the
        // KV at export (as the cluster does when crossing a precision
        // boundary) and verify the importer re-prefills and continues
        // with exactly the tokens a teacher-forced oracle produces — here
        // both engines share one precision, so the composite equals the
        // plain unbatched stream and byte-identity is checkable directly
        let mut plain = SimBackend::new(64, 64, vec![1, 2, 4, 8]);
        let want = reference(&mut plain, &req(1, 8, 8).prompt, &req(1, 8, 8).params);

        let mk = || {
            Engine::new(
                SimBackend::new(64, 64, vec![1, 2, 4, 8]),
                EngineConfig { prefix_sharing: false, ..cfg(4, 4, 4) },
            )
        };
        let mut src = mk();
        let mut dst = mk();
        src.submit(req(0, 8, 8));
        src.submit(req(1, 8, 8));
        while src.swapped() == 0 {
            src.step().unwrap();
        }
        let peek = src.peek_swapped().unwrap();
        assert_eq!(dst.import_fit(&peek.as_requant()), ImportFit::NeedsRequant);
        let mut exported = src.export_swapped().unwrap();
        assert!(!exported.needs_reprefill());
        exported.strip_kv_for_requant();
        assert!(exported.needs_reprefill());
        assert_eq!(exported.kv.pos, 0, "carried KV dropped");
        dst.import_swapped(exported);
        let out = dst.run_to_completion().unwrap();
        assert_eq!(out.len(), 1);
        assert_eq!(out[0].tokens, want, "re-prefilled stream ≡ oracle");
        assert_eq!(dst.counters().reprefills, 1, "exactly one re-prefill");
        assert_eq!(dst.counters().resumes, 1);
        assert_eq!(dst.pool().free_blocks(), 4, "no leaked blocks after re-prefill");
        dst.pool().check_invariants().unwrap();
        src.run_to_completion().unwrap();
        assert_eq!(src.pool().free_blocks(), 4);
    }

    #[test]
    fn shared_prefixes_decode_identically_and_save_blocks() {
        // 6 requests over ONE long shared prompt, sharing on vs off: the
        // token streams must match the unbatched oracle bit-for-bit both
        // ways, and sharing must allocate measurably fewer fresh blocks.
        let shared: Vec<i32> = (1..=16).collect();
        let reqs: Vec<Request> = (0..6u64)
            .map(|i| {
                Request::new(
                    i,
                    shared.clone(),
                    GenParams { max_new_tokens: 4, sample: false, seed: i },
                )
            })
            .collect();
        let mut plain = SimBackend::new(64, 64, vec![1, 2, 4, 8]);
        let want: Vec<Vec<i32>> =
            reqs.iter().map(|r| reference(&mut plain, &r.prompt, &r.params)).collect();

        let mut fresh = [0u64; 2];
        for (slot, sharing) in [(0usize, true), (1usize, false)] {
            let mut e = Engine::new(
                SimBackend::new(64, 64, vec![1, 2, 4, 8]),
                EngineConfig { prefix_sharing: sharing, ..cfg(32, 4, 8) },
            );
            for r in &reqs {
                e.submit(r.clone());
            }
            let mut out = e.run_to_completion().unwrap();
            out.sort_by_key(|r| r.id);
            for (r, w) in out.iter().zip(&want) {
                assert_eq!(&r.tokens, w, "sharing={sharing} req {}", r.id.0);
            }
            assert_eq!(e.pool().free_blocks(), 32, "no leaks (sharing={sharing})");
            e.pool().check_invariants().unwrap();
            fresh[slot] = e.pool().sharing().fresh_allocs;
            if sharing {
                assert!(e.pool().sharing().shared_live > 0, "prefix cache must hit");
            }
        }
        assert!(
            fresh[0] < fresh[1],
            "sharing allocated {} fresh blocks, baseline {}",
            fresh[0],
            fresh[1]
        );
    }

    #[test]
    fn rejects_what_can_never_run() {
        let mut e = Engine::new(SimBackend::new(64, 64, vec![1, 2]), cfg(2, 4, 2));
        e.submit(req(0, 0, 4)); // empty prompt
        e.submit(req(1, 33, 4)); // over max_prompt (32)
        e.submit(req(2, 4, 0)); // zero budget
        e.submit(req(3, 6, 8)); // 14 tokens > 2×4 pool capacity
        e.submit(req(4, 3, 4)); // fits
        let out = e.run_to_completion().unwrap();
        // rejects resolve terminally with empty streams
        assert_eq!(out.len(), 5);
        let (ok, rejected): (Vec<_>, Vec<_>) = out.iter().partition(|r| !r.tokens.is_empty());
        assert_eq!(ok.len(), 1);
        assert_eq!(ok[0].id.0, 4);
        assert_eq!(rejected.len(), 4);
        assert_eq!(e.counters().rejected, 4);
        assert_eq!(e.metrics.requests_done, 5, "rejects are accounted");

        // context-window guard, with a pool big enough that capacity is
        // not the binding constraint: 20 + 60 > max_seq 64 must reject
        // up front rather than return a silently truncated stream
        let mut e2 = Engine::new(SimBackend::new(64, 64, vec![1, 2]), cfg(64, 4, 2));
        e2.submit(req(0, 20, 60));
        assert_eq!(e2.counters().rejected, 1);
        e2.submit(req(1, 20, 44)); // exactly max_seq: runs to completion
        let out = e2.run_to_completion().unwrap();
        let ok: Vec<_> = out.iter().filter(|r| !r.tokens.is_empty()).collect();
        assert_eq!(ok.len(), 1);
        assert_eq!(ok[0].tokens.len(), 44);
    }

    #[test]
    fn deterministic_across_runs() {
        let run = || {
            let mut e = Engine::new(SimBackend::new(64, 64, vec![1, 2, 4, 8]), cfg(8, 4, 4));
            for i in 0..6 {
                e.submit(req(i, 3 + i as usize % 4, 6));
            }
            let mut out = e.run_to_completion().unwrap();
            out.sort_by_key(|r| r.id);
            out.iter().map(|r| r.tokens.clone()).collect::<Vec<_>>()
        };
        assert_eq!(run(), run());
    }

    #[test]
    fn batch_composition_does_not_change_tokens() {
        // the core continuous-batching correctness claim: whatever the
        // admission interleaving (and whether or not prefixes share
        // blocks), each request's stream matches the unbatched reference
        let mut plain = SimBackend::new(64, 64, vec![1, 2, 4, 8]);
        let reqs: Vec<Request> = (0..10)
            .map(|i| req(i, 1 + (i as usize * 3) % 9, 1 + (i as usize * 5) % 11))
            .collect();
        let want: Vec<Vec<i32>> =
            reqs.iter().map(|r| reference(&mut plain, &r.prompt, &r.params)).collect();
        for (kv_blocks, max_running, sharing) in
            [(64, 8, true), (6, 3, true), (5, 8, true), (6, 3, false)]
        {
            let backend = SimBackend::new(64, 64, vec![1, 2, 4, 8]);
            let mut e = Engine::new(
                backend,
                EngineConfig { prefix_sharing: sharing, ..cfg(kv_blocks, 4, max_running) },
            );
            for r in &reqs {
                e.submit(r.clone());
            }
            let mut out = e.run_to_completion().unwrap();
            out.sort_by_key(|r| r.id);
            assert_eq!(out.len(), reqs.len());
            for (r, w) in out.iter().zip(&want) {
                assert_eq!(&r.tokens, w, "req {} under pool={kv_blocks}", r.id.0);
            }
            assert_eq!(e.pool().free_blocks(), kv_blocks);
        }
    }

    #[test]
    fn speculative_decoding_is_byte_identical_and_saves_steps() {
        // the tentpole claim: drafting from the 3-bit plane prefix and
        // verifying at W4 must change WHICH backend calls run, never what
        // the client sees — every (id, step, token) triple matches the
        // spec_k=0 engine exactly, while accepted drafts cut decode steps
        let mk = |spec_k: usize| {
            Engine::new(
                SimBackend::with_ap_gemm(64, 128, vec![1, 2, 4, 8, 16], 64, 4, 2, 5),
                EngineConfig { spec_k, draft_bits: 3, ..cfg(32, 4, 4) },
            )
        };
        // varied budgets, including max_new=1 (the budget clamp must
        // stop the drafter from overshooting a 1-token budget)
        let reqs: Vec<Request> =
            [(0u64, 3usize, 1usize), (1, 5, 9), (2, 2, 16), (3, 7, 6), (4, 4, 12)]
                .iter()
                .map(|&(id, p, m)| req(id, p, m))
                .collect();
        let run = |spec_k: usize| {
            let mut e = mk(spec_k);
            assert_eq!(e.spec_k(), spec_k, "ap backend accepts the draft config");
            for r in &reqs {
                e.submit(r.clone());
            }
            let events = e.run_to_completion_events().unwrap();
            let stream: Vec<(u64, usize, i32)> = events
                .iter()
                .filter_map(|ev| match ev {
                    TokenEvent::Token { id, token, step } => Some((id.0, *step, *token)),
                    _ => None,
                })
                .collect();
            let mut out = responses_of(&events);
            out.sort_by_key(|r| r.id);
            (stream, out, e)
        };
        let (plain_stream, plain_out, plain) = run(0);
        let (spec_stream, spec_out, spec) = run(4);
        assert_eq!(spec_stream, plain_stream, "speculation changed a streamed token");
        for (s, p) in spec_out.iter().zip(&plain_out) {
            assert_eq!(s.tokens, p.tokens, "req {}", p.id.0);
        }
        // budgets respected exactly — the clamp never overshoots max_new
        for (r, q) in spec_out.iter().zip(&reqs) {
            assert_eq!(r.tokens.len(), q.params.max_new_tokens, "req {}", r.id.0);
        }
        let (pc, sc) = (plain.counters(), spec.counters());
        assert_eq!(pc.drafted, 0, "spec_k=0 never drafts");
        assert!(sc.drafted > 0, "speculation must actually run");
        assert!(sc.accepted <= sc.drafted);
        assert!(sc.accepted > 0, "W3-of-W4 drafts must land sometimes");
        assert!(
            spec.metrics.groups_executed < plain.metrics.groups_executed,
            "accepted drafts must save decode steps ({} vs {})",
            spec.metrics.groups_executed,
            plain.metrics.groups_executed
        );
        // counters and metrics tell the same story
        assert_eq!(spec.metrics.spec_drafted, sc.drafted);
        assert_eq!(spec.metrics.spec_accepted, sc.accepted);
        assert!(spec.metrics.spec_accept_rate() > 0.0);
        // no speculative residue in the pool
        assert_eq!(spec.pool().free_blocks(), 32, "un-accepted drafts leaked blocks");
        spec.pool().check_invariants().unwrap();
    }

    #[test]
    fn speculation_falls_back_to_plain_decode_when_the_backend_cannot_draft() {
        // hash backend: no plane-prefix store, so set_draft_bits refuses
        let e = Engine::new(
            SimBackend::new(64, 64, vec![1, 2, 4, 8]),
            EngineConfig { spec_k: 4, draft_bits: 1, ..cfg(8, 4, 4) },
        );
        assert_eq!(e.spec_k(), 0, "hash backend cannot draft");
        // ap backend but draft as wide as serving: refused the same way
        let e = Engine::new(
            SimBackend::with_ap_gemm(64, 64, vec![1, 2, 4, 8], 64, 4, 2, 5),
            EngineConfig { spec_k: 4, draft_bits: 4, ..cfg(8, 4, 4) },
        );
        assert_eq!(e.spec_k(), 0, "draft must be strictly narrower than serving");
    }

    #[test]
    fn mid_speculation_export_import_discards_unaccepted_kv() {
        // satellite: a sequence preempted and exported while its engine
        // speculates carries ONLY accepted state — the rollback inside
        // each step means no draft token ever travels.  Same dual-engine
        // migration scenario as above, run with speculation on and off;
        // the spec run must draft (pool 5×4 leaves a spare block once a
        // victim is swapped out) yet produce identical bytes and a clean
        // export
        let run = |spec_k: usize| {
            let mk = || {
                Engine::new(
                    SimBackend::with_ap_gemm(64, 64, vec![1, 2, 4, 8, 16], 64, 4, 2, 9),
                    EngineConfig {
                        prefix_sharing: false,
                        spec_k,
                        draft_bits: 3,
                        ..cfg(5, 4, 4)
                    },
                )
            };
            let mut src = mk();
            let mut dst = mk();
            src.submit(req(0, 8, 8));
            src.submit(req(1, 8, 8));
            let mut events = Vec::new();
            while src.swapped() == 0 {
                assert!(!src.is_idle(), "must preempt before draining");
                events.extend(src.step().unwrap());
            }
            let peek = src.peek_swapped().unwrap();
            let content_len = peek.content.len();
            let exported = src.export_swapped().unwrap();
            // the cleanliness claim: exported KV covers exactly the
            // prompt + accepted tokens, nothing speculative
            assert_eq!(exported.kv_tokens(), content_len, "draft residue in exported KV");
            dst.import_swapped(exported);
            events.extend(src.run_to_completion_events().unwrap());
            events.extend(dst.run_to_completion_events().unwrap());
            let mut out = responses_of(&events);
            out.sort_by_key(|r| r.id);
            assert_eq!(out.len(), 2);
            assert_eq!(src.pool().free_blocks(), 5, "source leaked blocks");
            assert_eq!(dst.pool().free_blocks(), 5, "target leaked blocks");
            src.pool().check_invariants().unwrap();
            dst.pool().check_invariants().unwrap();
            let drafted = src.counters().drafted + dst.counters().drafted;
            (out.into_iter().map(|r| r.tokens).collect::<Vec<_>>(), drafted)
        };
        let (plain, plain_drafted) = run(0);
        let (spec, spec_drafted) = run(3);
        assert_eq!(plain_drafted, 0);
        assert!(spec_drafted > 0, "the spec run must actually speculate");
        assert_eq!(spec, plain, "migration under speculation changed a stream");
    }

    #[test]
    fn prop_kv_churn_conserves_blocks() {
        // the KvPool + engine churn property: random admit/decode/finish/
        // preempt interleavings — with prefix sharing on and off — hold
        // used+free == total and never double-own a block, checked after
        // EVERY step
        forall(24, |rng| {
            let block_tokens = rng.usize(2, 6);
            let kv_blocks = rng.usize(3, 16);
            let max_running = rng.usize(1, 9);
            let sharing = rng.bool();
            let mut e = Engine::new(
                SimBackend::new(32, 128, vec![1, 2, 4, 8]),
                EngineConfig {
                    prefix_sharing: sharing,
                    ..cfg(kv_blocks, block_tokens, max_running)
                },
            );
            let n = rng.usize(1, 20);
            // a few shared prompt shapes so the sharing path actually hits
            let mut pending: Vec<Request> = (0..n)
                .map(|i| {
                    let shape = rng.u32(0, 3);
                    let plen = match shape {
                        0 => 2 * block_tokens, // full shared blocks
                        _ => rng.usize(1, 12),
                    };
                    let mut r = req(i as u64, plen.max(1), rng.usize(1, 10));
                    if shape == 0 {
                        r.prompt = (100..100 + plen as i32).collect();
                    }
                    r
                })
                .collect();
            let mut out = Vec::new();
            while !pending.is_empty() || !e.is_idle() {
                // interleave arrivals with steps
                for _ in 0..rng.usize(0, 3).min(pending.len()) {
                    e.submit(pending.remove(0));
                }
                out.extend(responses_of(&e.step().unwrap()));
                e.pool().check_invariants().unwrap_or_else(|err| panic!("invariant: {err}"));
                assert_eq!(
                    e.pool().used_blocks() + e.pool().free_blocks(),
                    e.pool().total_blocks()
                );
            }
            assert_eq!(e.pool().free_blocks(), kv_blocks, "drained pool leaks nothing");
            let c = e.counters();
            assert_eq!(c.completed + c.rejected, c.submitted, "every request resolves");
            assert_eq!(out.len() as u64, c.completed + c.rejected);
            assert_eq!(c.resumes, c.preemptions);
        });
    }

    #[test]
    fn import_fit_names_the_failing_gate_and_allows_queued_arrivals_headroom() {
        // exercise every verdict of the unified admission API on a peek
        // we can shape freely
        let peek = |content: &'static [i32], budget: usize, requant: bool| SwappedPeek {
            id: RequestId(99),
            content,
            budget,
            pinned: None,
            reprefill_pending: requant,
        };
        let idle = Engine::new(SimBackend::new(64, 64, vec![1, 2, 4, 8]), cfg(8, 4, 4));
        assert_eq!(idle.import_fit(&peek(&[1, 2, 3], 8, false)), ImportFit::Fits);
        assert_eq!(idle.import_fit(&peek(&[1, 2, 3], 8, true)), ImportFit::NeedsRequant);
        // as_requant flips only the reprefill axis of the same peek
        let p = peek(&[1, 2, 3], 8, false);
        assert_eq!(idle.import_fit(&p.as_requant()), ImportFit::NeedsRequant);
        // budget beyond the context window / whole pool
        assert!(!idle.import_fit(&peek(&[1, 2], 100, false)).admissible());
        assert!(!idle.import_fit(&peek(&[1, 2], 40, false)).admissible(), "pool is 8×4");
        // re-prefill content must fit the prompt window (max_prompt =
        // 32); the pool is sized up so every earlier gate passes and the
        // rejection is attributable to the re-prefill gate alone
        let big = Engine::new(SimBackend::new(64, 64, vec![1, 2, 4, 8]), cfg(16, 4, 4));
        static LONG: [i32; 33] = [7; 33];
        assert_eq!(big.import_fit(&peek(&LONG, 40, false)), ImportFit::Fits);
        assert!(!big.import_fit(&peek(&LONG, 40, true)).admissible());

        // a stuck backlog rejects outright; a merely-present one only
        // reserves its own headroom
        let mut hot = Engine::new(
            SimBackend::new(64, 64, vec![1, 2, 4, 8]),
            EngineConfig { prefix_sharing: false, ..cfg(4, 4, 4) },
        );
        hot.submit(req(0, 8, 8));
        hot.submit(req(1, 8, 8));
        while hot.swapped() == 0 {
            hot.step().unwrap();
        }
        assert!(hot.is_overloaded());
        assert!(
            !hot.import_fit(&peek(&[1], 2, false)).admissible(),
            "an overloaded engine must refuse imports"
        );
        // queued-arrival headroom: an idle engine with an imported-but-
        // not-yet-resumed sequence must reserve that sequence's blocks
        let mut busy = Engine::new(
            SimBackend::new(64, 64, vec![1, 2, 4, 8]),
            EngineConfig { prefix_sharing: false, ..cfg(4, 4, 4) },
        );
        busy.import_swapped(hot.export_swapped().unwrap());
        // the queued arrival (an 8-token prompt preempted before any
        // decode) reserves ceil(8/4) = 2 of 4 blocks: a 3-block newcomer
        // no longer fits, a 1-block one still does
        static NINE: [i32; 9] = [3; 9];
        assert!(!busy.import_fit(&peek(&NINE, 12, false)).admissible());
        assert_eq!(busy.import_fit(&peek(&[1, 2], 4, false)), ImportFit::Fits);
        // drain both so the scenario stays leak-free
        let mut all = hot.run_to_completion().unwrap();
        all.extend(busy.run_to_completion().unwrap());
        assert_eq!(all.len(), 2);
        assert!(all.iter().all(|r| r.tokens.len() == 8));
        assert_eq!(hot.pool().free_blocks(), 4);
        assert_eq!(busy.pool().free_blocks(), 4);
    }

    #[test]
    fn prefill_hold_surfaces_the_sequence_then_expires_without_a_taker() {
        // prefill_hold: the just-prefilled sequence must be visible at
        // its prompt boundary after the step (exactly one streamed
        // token), and — if nobody exports it — decode normally from the
        // next step on, finishing byte-identical to a no-hold engine
        let mut plain = SimBackend::new(64, 64, vec![1, 2, 4, 8]);
        let want = reference(&mut plain, &req(0, 5, 7).prompt, &req(0, 5, 7).params);

        let mut e = Engine::new(
            SimBackend::new(64, 64, vec![1, 2, 4, 8]),
            EngineConfig { prefill_hold: true, ..cfg(64, 8, 4) },
        );
        e.submit(req(0, 5, 7));
        let events = e.step().unwrap();
        let toks = events
            .iter()
            .filter(|ev| matches!(ev, TokenEvent::Token { .. }))
            .count();
        assert_eq!(toks, 1, "held sequence streams its prefill token only");
        let ready = e.prefilled_ready();
        assert_eq!(ready, vec![RequestId(0)]);
        let p = e.peek_prefilled(RequestId(0)).unwrap();
        assert_eq!(p.content, &req(0, 5, 7).prompt[..], "peek borrows the prompt");
        assert_eq!(p.budget, 12);
        assert!(!p.reprefill_pending);
        // nobody takes it: the hold expires and the stream completes
        let out = e.run_to_completion().unwrap();
        assert_eq!(out.len(), 1);
        assert_eq!(out[0].tokens, want, "an expired hold must not change the stream");
        assert!(e.prefilled_ready().is_empty(), "hold gone after the next step");
        assert_eq!(e.pool().free_blocks(), 64);

        // without the flag nothing is ever held
        let mut m = Engine::new(SimBackend::new(64, 64, vec![1, 2, 4, 8]), cfg(64, 8, 4));
        m.submit(req(0, 5, 7));
        m.step().unwrap();
        assert!(m.prefilled_ready().is_empty(), "mixed engines never hold");
        assert!(m.peek_prefilled(RequestId(0)).is_none());
        assert!(m.export_running(RequestId(0)).is_none());
    }

    #[test]
    fn export_running_hands_a_held_prefill_to_a_peer_byte_identically() {
        // the disaggregated handoff at engine level: prefill on a held
        // engine, export the running sequence between steps, import into
        // a peer — the composite stream must equal the unbatched oracle
        let mut plain = SimBackend::new(64, 64, vec![1, 2, 4, 8]);
        let want = reference(&mut plain, &req(0, 6, 8).prompt, &req(0, 6, 8).params);

        let mut pre = Engine::new(
            SimBackend::new(64, 64, vec![1, 2, 4, 8]),
            EngineConfig { prefill_hold: true, ..cfg(64, 8, 4) },
        );
        let mut dec = Engine::new(SimBackend::new(64, 64, vec![1, 2, 4, 8]), cfg(64, 8, 4));
        pre.submit(req(0, 6, 8));
        let mut events = pre.step().unwrap();
        let id = *pre.prefilled_ready().first().expect("prefill held");
        let p = pre.peek_prefilled(id).unwrap();
        assert_eq!(dec.import_fit(&p), ImportFit::Fits);
        let exported = pre.export_running(id).unwrap();
        assert_eq!(exported.id(), id);
        assert_eq!(exported.kv_tokens(), 6, "exported KV covers exactly the prompt");
        assert!(!exported.needs_reprefill());
        dec.import_swapped(exported);
        assert!(pre.is_idle(), "source fully handed off");
        assert_eq!(pre.pool().free_blocks(), 64, "source released the prompt blocks");
        events.extend(dec.run_to_completion_events().unwrap());
        let out = responses_of(&events);
        assert_eq!(out.len(), 1);
        assert_eq!(out[0].tokens, want, "handoff changed the stream");
        // streamed tokens concatenate across the two engines
        let streamed: Vec<i32> = events
            .iter()
            .filter_map(|ev| match ev {
                TokenEvent::Token { token, .. } => Some(*token),
                _ => None,
            })
            .collect();
        assert_eq!(streamed, want);
        assert_eq!(pre.counters().exported, 1);
        assert_eq!(dec.counters().imported, 1);
        assert_eq!(dec.counters().resumes, 1, "decode side resumes the stream");
        assert_eq!(dec.pool().free_blocks(), 64);
        dec.pool().check_invariants().unwrap();
    }

    // ---- AdmissionPolicy::Reserve: the retired group scheduler's
    // contract, ported test-for-test when scheduler.rs was deleted ----

    fn rcfg(kv_blocks: usize, block_tokens: usize, max_running: usize) -> EngineConfig {
        EngineConfig {
            admission: AdmissionPolicy::Reserve,
            ..cfg(kv_blocks, block_tokens, max_running)
        }
    }

    #[test]
    fn reserve_single_request_generates_exactly_max_new() {
        let mut e = Engine::new(SimBackend::new(64, 64, vec![1, 2, 4, 8]), rcfg(64, 8, 4));
        e.submit(req(1, 5, 7));
        let out = e.run_to_completion().unwrap();
        assert_eq!(out.len(), 1);
        assert_eq!(out[0].tokens.len(), 7);
        assert_eq!(e.pool().free_blocks(), 64, "all blocks returned");
        assert_eq!(e.counters().preemptions, 0);
    }

    #[test]
    fn reserve_batching_actually_batches() {
        let mut e = Engine::new(SimBackend::new(64, 64, vec![1, 2, 4, 8]), rcfg(64, 8, 8));
        for i in 0..8 {
            e.submit(req(i, 4, 10));
        }
        let out = e.run_to_completion().unwrap();
        assert_eq!(out.len(), 8);
        // 8 concurrent sequences, 9 decode steps each (first token from
        // prefill) → occupancy near 8
        assert!(e.metrics.mean_occupancy() > 6.0, "occ {}", e.metrics.mean_occupancy());
        assert_eq!(e.metrics.tokens_generated, 80);
        // streaming ITL: one inter-token gap per decoded (non-first) token
        assert_eq!(e.metrics.itl.count() as u64, e.metrics.tokens_generated - 8);
    }

    #[test]
    fn reserve_kv_pressure_serializes_without_preempting() {
        // pool fits only ~1 full budget at a time: head-of-line requests
        // wait for memory instead of overcommitting — completes with
        // ZERO preemptions where Optimistic would swap
        let mut e = Engine::new(SimBackend::new(64, 64, vec![1, 2, 4, 8]), rcfg(3, 8, 8));
        for i in 0..5 {
            e.submit(req(i, 8, 8)); // budget 16 → 2 of 3 blocks each
        }
        let out = e.run_to_completion().unwrap();
        assert_eq!(out.len(), 5, "head-of-line blocking must not deadlock");
        assert_eq!(e.pool().free_blocks(), 3);
        assert_eq!(e.counters().preemptions, 0, "Reserve never preempts");
        assert_eq!(e.counters().resumes, 0);
        // per-request bytes still match the unbatched oracle
        let mut plain = SimBackend::new(64, 64, vec![1, 2, 4, 8]);
        for r in &out {
            let rq = req(r.id.0, 8, 8);
            assert_eq!(r.tokens, reference(&mut plain, &rq.prompt, &rq.params));
        }
    }

    #[test]
    fn reserve_and_optimistic_agree_on_bytes_but_not_preemptions() {
        // the differential: a pool too tight for both budgets makes
        // Optimistic overcommit-and-swap while Reserve serializes; the
        // per-request token bytes are identical either way
        let run = |admission: AdmissionPolicy| {
            let mut e = Engine::new(
                SimBackend::new(64, 64, vec![1, 2, 4, 8]),
                EngineConfig {
                    admission,
                    prefix_sharing: false,
                    ..cfg(4, 4, 4)
                },
            );
            e.submit(req(0, 8, 8));
            e.submit(req(1, 8, 8));
            let mut out = e.run_to_completion().unwrap();
            out.sort_by_key(|r| r.id);
            assert_eq!(e.pool().free_blocks(), 4);
            (out.into_iter().map(|r| r.tokens).collect::<Vec<_>>(), e.counters().preemptions)
        };
        let (opt_tokens, opt_preempts) = run(AdmissionPolicy::Optimistic);
        let (res_tokens, res_preempts) = run(AdmissionPolicy::Reserve);
        assert!(opt_preempts > 0, "the tight pool must force Optimistic to swap");
        assert_eq!(res_preempts, 0, "Reserve never preempts");
        assert_eq!(res_tokens, opt_tokens, "admission policy changed a stream");
    }

    #[test]
    fn reserve_mixed_depths_and_rejects_resolve() {
        let mut e = Engine::new(SimBackend::new(64, 64, vec![1, 2, 4, 8]), rcfg(64, 8, 8));
        e.submit(req(0, 2, 3));
        e.submit(req(1, 9, 12));
        e.submit(req(2, 1, 1));
        e.submit(req(3, 33, 4)); // SimBackend max_prompt = 32 → rejected
        let mut out = e.run_to_completion().unwrap();
        out.sort_by_key(|r| r.id);
        assert_eq!(out.len(), 4);
        assert_eq!(out[0].tokens.len(), 3);
        assert_eq!(out[1].tokens.len(), 12);
        assert_eq!(out[2].tokens.len(), 1);
        assert!(out[3].tokens.is_empty(), "oversized prompt resolves terminally");
        assert_eq!(e.counters().rejected, 1);
        assert_eq!(e.pool().free_blocks(), 64);
    }

    #[test]
    fn reserve_disarms_speculation_and_sharing() {
        // spec_k and prefix_sharing are forced off at construction: a
        // Reserve engine never drafts (zero drafted counter) even on a
        // backend that would accept the draft width
        let mut e = Engine::new(
            SimBackend::with_ap_gemm(64, 64, vec![1, 2, 4, 8], 64, 4, 2, 9),
            EngineConfig { spec_k: 4, draft_bits: 2, ..rcfg(64, 8, 4) },
        );
        assert_eq!(e.spec_k(), 0, "Reserve must disarm speculation");
        e.submit(req(0, 5, 9));
        let out = e.run_to_completion().unwrap();
        assert_eq!(out[0].tokens.len(), 9);
        assert_eq!(e.counters().drafted, 0);
        let sh = e.pool().sharing();
        assert_eq!(sh.shared_live + sh.cache_restores, 0, "no prefix cache under Reserve");
    }

    #[test]
    fn prop_reserve_conserves_and_never_preempts() {
        forall(24, |rng| {
            let max_running = [1, 2, 4, 8][rng.usize(0, 4)];
            let blocks = rng.usize(4, 40);
            let mut e =
                Engine::new(SimBackend::new(64, 64, vec![1, 2, 4, 8]), rcfg(blocks, 8, max_running));
            let n = rng.usize(1, 16);
            let mut want_tokens = 0usize;
            for i in 0..n {
                let plen = rng.usize(1, 12);
                let mnew = rng.usize(1, 10);
                // only submit requests the pool can EVER hold
                if e.pool().blocks_for(plen + mnew) <= blocks {
                    e.submit(req(i as u64, plen, mnew));
                    want_tokens += mnew;
                }
            }
            let out = e.run_to_completion().unwrap();
            let got: usize = out.iter().map(|r| r.tokens.len()).sum();
            assert_eq!(got, want_tokens, "every request gets exactly max_new tokens");
            assert_eq!(e.pool().free_blocks(), blocks, "no leaked blocks");
            assert!(e.is_idle());
            e.pool().check_invariants().unwrap();
            assert_eq!(e.counters().preemptions, 0, "Reserve never preempts");
            // occupancy never exceeded the cap (implied by supported sizes)
            assert!(e.metrics.mean_occupancy() <= max_running as f64 + 1e-9);
        });
    }
}
