//! Request/response types and the streaming [`TokenEvent`] protocol.

use crate::model::PrecisionConfig;
use std::time::Instant;

/// Monotonically assigned request identifier.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash, PartialOrd, Ord)]
pub struct RequestId(pub u64);

/// Generation parameters.
#[derive(Debug, Clone, Copy)]
pub struct GenParams {
    pub max_new_tokens: usize,
    /// Greedy if false; seeded multinomial-ish (argmax over perturbed
    /// logits) if true.
    pub sample: bool,
    pub seed: u64,
}

impl Default for GenParams {
    fn default() -> Self {
        Self { max_new_tokens: 8, sample: false, seed: 0 }
    }
}

/// An inference request.
#[derive(Debug, Clone)]
pub struct Request {
    pub id: RequestId,
    pub prompt: Vec<i32>,
    pub params: GenParams,
    pub arrived: Instant,
    /// Pin the request to replicas serving this W/A precision (a cluster
    /// routes it; `None` accepts any replica).
    pub precision: Option<PrecisionConfig>,
}

impl Request {
    pub fn new(id: u64, prompt: Vec<i32>, params: GenParams) -> Self {
        Self { id: RequestId(id), prompt, params, arrived: Instant::now(), precision: None }
    }

    /// Pin this request to replicas serving `precision`.
    pub fn with_precision(mut self, precision: PrecisionConfig) -> Self {
        self.precision = Some(precision);
        self
    }
}

/// Pick the next token from a logits row: greedy argmax, or (when
/// `params.sample`) argmax over Gumbel-perturbed logits seeded by the
/// request seed and the decode step.  The perturbation stream depends on
/// nothing else, so batched, unbatched and preempted-then-resumed
/// execution of the same request produce the **identical** token stream —
/// the property the engine's correctness tests pin down.
///
/// Ties break to the **lowest token id** (strict `>` keeps the first
/// maximum seen).  This is a load-bearing contract, not an accident: the
/// speculative drafter and the wide-precision verifier each run this
/// function independently on their own logits rows, and acceptance
/// compares the results token-by-token — a tie resolved differently on
/// the two passes would break byte-identity with plain decode.  The
/// duplicated-max regression test below pins it.
pub fn sample_token(logits: &[f32], params: &GenParams, step: usize) -> i32 {
    let mut rng = crate::util::Rng::with_seed(
        params.seed ^ (step as u64).wrapping_mul(0x9E37_79B9_7F4A_7C15),
    );
    let mut best = 0usize;
    let mut best_v = f32::NEG_INFINITY;
    for (i, &v) in logits.iter().enumerate() {
        let v = if params.sample {
            // seeded Gumbel-max: argmax(v + G) samples softmax(v)
            v - (-rng.f64().max(1e-12).ln()).ln() as f32
        } else {
            v
        };
        // strictly greater ONLY: an equal value never displaces the
        // earlier (lower-id) holder, whatever order the row is walked
        if v > best_v {
            best_v = v;
            best = i;
        }
    }
    best as i32
}

/// A completed generation.
#[derive(Debug, Clone)]
pub struct Response {
    pub id: RequestId,
    /// Generated tokens; **empty means the request was rejected** (an
    /// accepted request always streams at least its first token).
    pub tokens: Vec<i32>,
    /// Queue time (arrival → prefill start).
    pub queue_s: f64,
    /// Total latency (arrival → last token).
    pub total_s: f64,
    /// Time to first token.
    pub ttft_s: f64,
}

impl Response {
    /// A rejected request's terminal response (zero tokens).
    pub fn rejected(id: RequestId) -> Self {
        Self { id, tokens: Vec::new(), queue_s: 0.0, total_s: 0.0, ttft_s: 0.0 }
    }
}

/// One streamed serving event.  Every [`Stepper`](super::server::Stepper)
/// `step` returns the events its iteration produced, in order, so
/// tokens reach clients as they are generated instead of at completion —
/// per-request lifecycle plus one [`TokenEvent::Token`] per token.  Per
/// request the stream is: `Admitted`, then `Token*` interleaved with
/// `Preempted`/`Resumed` pairs (a cluster may insert `Migrated` — and,
/// when the move crosses a precision boundary, `Requantized` — between
/// them when the rebalancer moves a swapped sequence to a peer replica),
/// then `Finished`; a rejected request emits only `Finished` with an
/// empty response.  On a disaggregated cluster a prefill-role replica
/// additionally streams `PrefillDone` right after the prefill's first
/// token and immediately before the `Migrated` that hands the sequence
/// to a decode replica — the marker that makes prefill→decode handoffs
/// auditable in the stream (a voluntary move, so no `Preempted` precedes
/// it; the decode replica's `Resumed` picks the stream back up).  The concatenation of a request's `Token` payloads is
/// byte-identical to its final [`Response::tokens`] — migration included
/// — pinned by the integration tests.  Tokens streamed before a
/// `Requantized` keep their bytes (the new replica re-prefills them as
/// context); only *subsequent* tokens are generated at the new precision.
#[derive(Debug, Clone)]
pub enum TokenEvent {
    /// The request acquired KV blocks and prefilled.
    Admitted { id: RequestId },
    /// One generated token (`step` is its index in the output stream).
    Token { id: RequestId, token: i32, step: usize },
    /// Swapped out under KV pressure (stream pauses, nothing is lost).
    Preempted { id: RequestId },
    /// The prefill completed on a prefill-role replica and the sequence
    /// is leaving for a decode replica: streams immediately before the
    /// corresponding `Migrated`.  A marker, not a pause — the handoff is
    /// voluntary (no KV pressure), so no `Preempted` accompanies it.
    PrefillDone { id: RequestId },
    /// A swapped-out sequence moved to another replica (`from`/`to` are
    /// cluster replica indices); the stream stays paused until the
    /// target's `Resumed`.
    Migrated { id: RequestId, from: usize, to: usize },
    /// The migration above crossed a precision boundary: the carried KV
    /// was dropped and the target replica will re-prefill the prompt plus
    /// every generated token at its own precision (`to_bits`) before
    /// resuming.  Streams between `Migrated` and the target's `Resumed`.
    Requantized { id: RequestId, from_bits: PrecisionConfig, to_bits: PrecisionConfig },
    /// Swapped back in; the stream resumes where it paused.
    Resumed { id: RequestId },
    /// Terminal: the full response (empty tokens = rejected).
    Finished { id: RequestId, response: Response },
}

impl TokenEvent {
    /// The request this event belongs to.
    pub fn id(&self) -> RequestId {
        match self {
            TokenEvent::Admitted { id }
            | TokenEvent::Token { id, .. }
            | TokenEvent::Preempted { id }
            | TokenEvent::PrefillDone { id }
            | TokenEvent::Migrated { id, .. }
            | TokenEvent::Requantized { id, .. }
            | TokenEvent::Resumed { id }
            | TokenEvent::Finished { id, .. } => *id,
        }
    }
}

/// Extract the terminal responses from an event stream (completion-style
/// view for callers that don't stream).
pub fn responses_of(events: &[TokenEvent]) -> Vec<Response> {
    events
        .iter()
        .filter_map(|e| match e {
            TokenEvent::Finished { response, .. } => Some(response.clone()),
            _ => None,
        })
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn greedy_argmax_ties_break_to_the_lowest_token_id() {
        // duplicated maxima everywhere the tie could hide: leading,
        // interior, trailing, and an all-equal row.  Speculative
        // acceptance compares a draft-pass argmax against a verify-pass
        // argmax — both must land on the SAME token whenever the rows
        // agree, so the tie-break has to be deterministic and positional.
        let greedy = GenParams { max_new_tokens: 1, sample: false, seed: 42 };
        assert_eq!(sample_token(&[7.0, 7.0, 1.0], &greedy, 0), 0, "leading tie");
        assert_eq!(sample_token(&[1.0, 7.0, 7.0, 2.0], &greedy, 0), 1, "interior tie");
        assert_eq!(sample_token(&[1.0, 2.0, 9.0, 9.0], &greedy, 0), 2, "trailing tie");
        assert_eq!(sample_token(&[3.0, 3.0, 3.0, 3.0], &greedy, 0), 0, "all equal");
        // the step seed must not perturb greedy ties (only sampling draws
        // from the rng)
        for step in 0..16 {
            assert_eq!(sample_token(&[5.0, 5.0, 5.0], &greedy, step), 0);
        }
        // non-finite guards: -inf rows still resolve to the first index
        assert_eq!(sample_token(&[f32::NEG_INFINITY, f32::NEG_INFINITY], &greedy, 0), 0);
    }
}
