//! Request/response types.

use std::time::Instant;

/// Monotonically assigned request identifier.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash, PartialOrd, Ord)]
pub struct RequestId(pub u64);

/// Generation parameters.
#[derive(Debug, Clone, Copy)]
pub struct GenParams {
    pub max_new_tokens: usize,
    /// Greedy if false; seeded multinomial-ish (argmax over perturbed
    /// logits) if true.
    pub sample: bool,
    pub seed: u64,
}

impl Default for GenParams {
    fn default() -> Self {
        Self { max_new_tokens: 8, sample: false, seed: 0 }
    }
}

/// An inference request.
#[derive(Debug, Clone)]
pub struct Request {
    pub id: RequestId,
    pub prompt: Vec<i32>,
    pub params: GenParams,
    pub arrived: Instant,
}

impl Request {
    pub fn new(id: u64, prompt: Vec<i32>, params: GenParams) -> Self {
        Self { id: RequestId(id), prompt, params, arrived: Instant::now() }
    }
}

/// A completed generation.
#[derive(Debug, Clone)]
pub struct Response {
    pub id: RequestId,
    pub tokens: Vec<i32>,
    /// Queue time (arrival → prefill start).
    pub queue_s: f64,
    /// Total latency (arrival → last token).
    pub total_s: f64,
    /// Time to first token.
    pub ttft_s: f64,
}
